"""Calibration pipeline (paper §3.3 'weights preprocessing'):

  1. run the FP32 model over calibration batches with an explicit
     ``StatsScope(capture=True)`` pass, accumulating per-channel activation
     absmax AND per-batch outlier hit scores (the xi criterion, Eq. 6 —
     adapted: a channel scores a hit in a batch when its absmax exceeds
     ``ratio`` x the median channel absmax; see core/outliers.py for why the
     paper's literal form is a typo);
  2. pick the top-k channels per layer under the per-layer-type budget
     (q/k/v/up: 0.03%, o_proj: 4%, down_proj: 10%, §4.1);
  3. convert the FP32 weight tree to the target mode through the
     ``QuantBackend`` registry: each backend declares which calibration
     artifacts it wants (``wants_absmax`` / ``wants_outliers``), receives a
     ``Calibration`` and returns its frozen weights (+ optional state) —
     no mode branching here, new backends convert with zero edits.

The path-matching between the frozen tree and the captured stats tree is
suffix-normalized (drop structural tokens like "blocks"/"experts") so it
works for every family in the zoo.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as BK
from repro.core import baselines as B
from repro.core import outliers as OUT
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.runtime.treepath import path_str as _path_str

_DROP_TOKENS = {"blocks", "w", "experts", "ffn", "attn"}

LAYER_TYPE_MAP = {
    "wq": "q_proj", "wk": "k_proj", "wv": "v_proj", "wo": "o_proj",
    "gate": "gate_proj", "up": "up_proj", "down": "down_proj",
    "in_proj": "up_proj", "out_proj": "down_proj",
    "w_in": "up_proj", "w_out": "o_proj",
}


def _norm(path_s: str) -> str:
    return "/".join(t for t in path_s.split("/") if t not in _DROP_TOKENS)


def capture_stats(frozen, adapters, quant_state, cfg: ModelConfig,
                  batches: List[Dict[str, np.ndarray]], ratio: float = 20.0):
    """Returns (absmax_tree, score_tree): per-layer (stack..., c_in) arrays.
    absmax = max over batches; score = xi hit count + magnitude tiebreak."""
    absmax = None
    scores = None
    fwd = None
    for batch in batches:
        tokens = jnp.asarray(batch["tokens"])
        embeds = batch.get("embeds")
        if embeds is not None:
            embeds = jnp.asarray(embeds)
        if fwd is None:
            def run(tok, emb):
                return M.forward(frozen, adapters, quant_state, tok, cfg,
                                 input_embeds=emb, scope=BK.CAPTURE).stats
            fwd = jax.jit(run)
        stats = jax.device_get(fwd(tokens, embeds))

        def hit(st):
            med = np.median(st, axis=-1, keepdims=True)
            return (st > ratio * np.maximum(med, 1e-8)).astype(np.float32)

        if absmax is None:
            absmax = stats
            scores = jax.tree.map(hit, stats)
        else:
            absmax = jax.tree.map(np.maximum, absmax, stats)
            scores = jax.tree.map(lambda s, st: s + hit(st), scores, stats)
    # magnitude tiebreak keeps top-k deterministic
    scores = jax.tree.map(
        lambda s, a: s + a / (np.max(a, axis=-1, keepdims=True) + 1e-9),
        scores, absmax)
    return absmax, scores


def _stats_lookup(stats_tree) -> Dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(stats_tree)[0]:
        out[_norm(_path_str(path))] = np.asarray(leaf)
    return out


def _topk_indices(score: np.ndarray, k: int) -> np.ndarray:
    """score: (..., c_in) -> (..., k) sorted channel indices per layer."""
    idx = np.argsort(-score, axis=-1)[..., :k]
    return np.sort(idx, axis=-1).astype(np.int32)


def _match_stack(arr: np.ndarray, n: int) -> np.ndarray:
    """Repeat stats rows when the stats stack is shorter than the weight
    stack (MoE: the expert dim shares one stat row)."""
    if arr.shape[0] != n:
        arr = np.repeat(arr, n // arr.shape[0], axis=0)
    return arr


def convert(frozen_fp32, stats: Optional[Tuple[Any, Any]], cfg: ModelConfig,
            target_mode: str):
    """Convert an FP32-mode frozen tree to ``target_mode`` via the registry.
    Returns (frozen_converted, quant_state)."""
    backend = BK.get_backend(target_mode)
    absmax_lut = _stats_lookup(stats[0]) if stats is not None else {}
    score_lut = _stats_lookup(stats[1]) if stats is not None else {}
    qcfg = cfg.quant

    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(
        frozen_fp32, is_leaf=lambda x: isinstance(x, B.FPWeights))

    new_leaves = []
    qstate_flat: Dict[str, Any] = {}
    for path, leaf in paths_leaves:
        if not isinstance(leaf, B.FPWeights):
            new_leaves.append(leaf)
            continue
        ps = _path_str(path)
        key = _norm(ps.rsplit("/w", 1)[0] if ps.endswith("/w") else ps)
        lname = key.split("/")[-1]
        ltype = LAYER_TYPE_MAP.get(lname, lname)
        w, bias = leaf.w, leaf.bias
        c_in = w.shape[-2]
        stack = w.shape[:-2]
        n_flat = int(np.prod(stack)) if stack else 1

        if target_mode == "fp32":
            new_leaves.append(leaf)
            continue

        # calibration artifacts this backend asked for, (n_flat, ...) aligned
        absmax2 = idx2 = None
        if backend.wants_absmax:
            if key not in absmax_lut:
                raise ValueError(
                    f"backend {backend.name!r} needs calibration absmax but "
                    f"none was captured for {key!r}; run capture_stats first")
            absmax2 = _match_stack(
                np.maximum(np.asarray(absmax_lut[key]), 1e-6).reshape(
                    (-1, c_in)), n_flat)
        if backend.wants_outliers:
            if key not in score_lut:
                raise ValueError(
                    f"backend {backend.name!r} needs calibration outlier "
                    f"scores but none were captured for {key!r}; run "
                    f"capture_stats first")
            k = OUT.outlier_count(c_in, ltype, qcfg.budgets)
            idx2 = _match_stack(
                _topk_indices(np.asarray(score_lut[key]), k).reshape((-1, k)),
                n_flat)

        w2 = w.reshape((-1,) + w.shape[-2:])
        # calibration pieces ride in one dict so vmap's in_axes stay uniform
        extras = {}
        if bias is not None:
            extras["bias"] = bias.reshape((-1,) + bias.shape[-1:])
        if absmax2 is not None:
            extras["absmax"] = jnp.asarray(absmax2)
        if idx2 is not None:
            extras["idx"] = jnp.asarray(idx2)

        def prep_one(wi, ex):
            calib = BK.Calibration(
                absmax=ex.get("absmax"), outlier_idx=ex.get("idx"),
                layer_type=ltype, budgets=qcfg.budgets,
                group_size=qcfg.group_size)
            wts_i = backend.prepare(wi, ex.get("bias"), calib=calib,
                                    bits=qcfg.bits)
            return wts_i, backend.init_state(wts_i)

        if not stack:
            wts, st = prep_one(w2[0], jax.tree.map(lambda a: a[0], extras))
        else:
            try:
                wts, st = jax.vmap(prep_one)(w2, extras)
            except (TypeError, jax.errors.JAXTypeError):
                # non-traceable custom backend: eager per-slice fallback
                # (real prepare() bugs re-raise from the eager path below)
                pairs = [prep_one(w2[i], jax.tree.map(lambda a: a[i], extras))
                         for i in range(n_flat)]
                wts = jax.tree.map(lambda *xs: jnp.stack(xs),
                                   *[p[0] for p in pairs])
                st = (None if pairs[0][1] is None else
                      jax.tree.map(lambda *xs: jnp.stack(xs),
                                   *[p[1] for p in pairs]))
            wts = jax.tree.map(lambda a: a.reshape(stack + a.shape[1:]), wts)
            if st is not None:
                st = jax.tree.map(lambda a: a.reshape(stack + a.shape[1:]), st)
            # MoE: expert dim of state/outlier set is layer-shared
            if cfg.n_experts and "experts" in ps:
                wts, st = backend.collapse_expert_state(wts, st)

        new_leaves.append(wts)
        if st is not None:
            qstate_flat[key] = st

    frozen_new = jax.tree_util.tree_unflatten(treedef, new_leaves)
    # rebuild quant_state in the same structure init_params would produce
    _, _, qstate_like = jax.eval_shape(
        lambda k: M.init_params(k, _with_mode(cfg, target_mode)),
        jax.random.PRNGKey(0))
    if not qstate_flat:
        return frozen_new, jax.tree.map(lambda x: None, qstate_like)
    return frozen_new, _rebuild_qstate(qstate_like, qstate_flat)


def _with_mode(cfg: ModelConfig, mode: str) -> ModelConfig:
    import dataclasses
    return dataclasses.replace(cfg, quant=dataclasses.replace(cfg.quant,
                                                              mode=mode))


def _rebuild_qstate(qstate_like, qstate_flat: Dict[str, Any]):
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(
        qstate_like, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    # group leaves back into per-layer states by path prefix
    out_leaves = []
    for path, leaf in paths_leaves:
        ps = _path_str(path)
        # path ends with .../<lin>/<field> where field names the state leaf
        parts = ps.split("/")
        field = parts[-1]
        key = _norm("/".join(parts[:-1]))
        st = qstate_flat[key]
        out_leaves.append(getattr(st, field))
    return jax.tree_util.tree_unflatten(treedef, out_leaves)
