"""Calibration pipeline (paper §3.3 'weights preprocessing'):

  1. run the FP32 model over calibration batches with stats capture on,
     accumulating per-channel activation absmax AND per-batch outlier hit
     scores (the xi criterion, Eq. 6 — adapted: a channel scores a hit in a
     batch when its absmax exceeds ``ratio`` x the median channel absmax;
     see core/outliers.py for why the paper's literal form is a typo);
  2. pick the top-k channels per layer under the per-layer-type budget
     (q/k/v/up: 0.03%, o_proj: 4%, down_proj: 10%, §4.1);
  3. convert the FP32 weight tree to the target quant mode — for Quaff this
     quantizes W once, stashes fp W_O rows and initializes the momentum
     ScaleState; for SmoothQuant-static it bakes the calibration s into W.

The path-matching between the frozen tree and the captured stats tree is
suffix-normalized (drop structural tokens like "blocks"/"experts") so it
works for every family in the zoo.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines as B
from repro.core.baselines import QuantMode
from repro.core.quaff_linear import prepare_quaff_weights
from repro.models import layers as L
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.core import outliers as OUT

_DROP_TOKENS = {"blocks", "w", "experts", "ffn", "attn"}

LAYER_TYPE_MAP = {
    "wq": "q_proj", "wk": "k_proj", "wv": "v_proj", "wo": "o_proj",
    "gate": "gate_proj", "up": "up_proj", "down": "down_proj",
    "in_proj": "up_proj", "out_proj": "down_proj",
    "w_in": "up_proj", "w_out": "o_proj",
}


from repro.runtime.treepath import path_str as _path_str


def _norm(path_s: str) -> str:
    return "/".join(t for t in path_s.split("/") if t not in _DROP_TOKENS)


def capture_stats(frozen, adapters, quant_state, cfg: ModelConfig,
                  batches: List[Dict[str, np.ndarray]], ratio: float = 20.0):
    """Returns (absmax_tree, score_tree): per-layer (stack..., c_in) arrays.
    absmax = max over batches; score = xi hit count + magnitude tiebreak."""
    absmax = None
    scores = None
    fwd = None
    for batch in batches:
        tokens = jnp.asarray(batch["tokens"])
        embeds = batch.get("embeds")
        if embeds is not None:
            embeds = jnp.asarray(embeds)
        with L.capture_stats():
            if fwd is None:
                def run(tok, emb):
                    _, stats, _, _ = M.forward(frozen, adapters, quant_state,
                                               tok, cfg, input_embeds=emb)
                    return stats
                fwd = jax.jit(run) if embeds is None else jax.jit(run)
            stats = fwd(tokens, embeds)
        stats = jax.device_get(stats)

        def hit(st):
            med = np.median(st, axis=-1, keepdims=True)
            return (st > ratio * np.maximum(med, 1e-8)).astype(np.float32)

        if absmax is None:
            absmax = stats
            scores = jax.tree.map(hit, stats)
        else:
            absmax = jax.tree.map(np.maximum, absmax, stats)
            scores = jax.tree.map(lambda s, st: s + hit(st), scores, stats)
    # magnitude tiebreak keeps top-k deterministic
    scores = jax.tree.map(
        lambda s, a: s + a / (np.max(a, axis=-1, keepdims=True) + 1e-9),
        scores, absmax)
    return absmax, scores


def _stats_lookup(stats_tree) -> Dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(stats_tree)[0]:
        out[_norm(_path_str(path))] = np.asarray(leaf)
    return out


def _topk_indices(score: np.ndarray, k: int) -> np.ndarray:
    """score: (..., c_in) -> (..., k) sorted channel indices per layer."""
    idx = np.argsort(-score, axis=-1)[..., :k]
    return np.sort(idx, axis=-1).astype(np.int32)


def convert(frozen_fp32, stats: Tuple[Any, Any], cfg: ModelConfig,
            target_mode: str):
    """Convert an FP32-mode frozen tree to ``target_mode``.
    Returns (frozen_converted, quant_state)."""
    mode = QuantMode(target_mode)
    absmax_lut = _stats_lookup(stats[0]) if stats is not None else {}
    score_lut = _stats_lookup(stats[1]) if stats is not None else {}
    qcfg = cfg.quant

    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(
        frozen_fp32, is_leaf=lambda x: isinstance(x, B.FPWeights))

    new_leaves = []
    qstate_flat: Dict[str, Any] = {}
    for path, leaf in paths_leaves:
        if not isinstance(leaf, B.FPWeights):
            new_leaves.append(leaf)
            continue
        ps = _path_str(path)
        key = _norm(ps.rsplit("/w", 1)[0] if ps.endswith("/w") else ps)
        lname = key.split("/")[-1]
        ltype = LAYER_TYPE_MAP.get(lname, lname)
        w, bias = leaf.w, leaf.bias
        c_in = w.shape[-2]

        if mode == QuantMode.FP32:
            new_leaves.append(leaf)
            continue
        if mode in (QuantMode.NAIVE, QuantMode.LLM_INT8, QuantMode.SMOOTH_DYNAMIC):
            fn = lambda wi, bi=None: B.prepare(mode, wi, bi, bits=qcfg.bits)
        elif mode == QuantMode.SMOOTH_STATIC:
            calib = absmax_lut[key]  # (stack..., c_in)
            fn = lambda wi, cal: B.prepare(mode, wi, None,
                                           calib_absmax=jnp.maximum(cal, 1e-6),
                                           bits=qcfg.bits)
        elif mode == QuantMode.QUAFF:
            score = score_lut[key]
            k = max(1, min(c_in, int(round(
                OUT.budget_for(ltype, qcfg.budgets) * c_in))))
            idx = _topk_indices(score, k)  # (stack..., k)
        else:
            raise ValueError(mode)

        stack = w.shape[:-2]
        if mode == QuantMode.QUAFF:
            if len(stack) == 0:
                wts, st = prepare_quaff_weights(w, jnp.asarray(idx), bias,
                                                qcfg.bits)
            else:
                w2 = w.reshape((-1,) + w.shape[-2:])
                # stats stacks may be shorter than the weight stack (MoE: the
                # expert dim shares one stat row) — repeat the index rows.
                idx2 = idx.reshape((-1, idx.shape[-1]))
                if idx2.shape[0] != w2.shape[0]:
                    idx2 = np.repeat(idx2, w2.shape[0] // idx2.shape[0], axis=0)
                b2 = (None if bias is None
                      else bias.reshape((-1,) + bias.shape[-1:]))
                if b2 is None:
                    wts, st = jax.vmap(
                        lambda wi, ii: prepare_quaff_weights(wi, ii, None,
                                                             qcfg.bits)
                    )(w2, jnp.asarray(idx2))
                else:
                    wts, st = jax.vmap(
                        lambda wi, ii, bi: prepare_quaff_weights(wi, ii, bi,
                                                                 qcfg.bits)
                    )(w2, jnp.asarray(idx2), b2)
                wts = jax.tree.map(
                    lambda a: a.reshape(stack + a.shape[1:]), wts)
                st = jax.tree.map(lambda a: a.reshape(stack + a.shape[1:]), st)
            # MoE: collapse expert dim of state + idx (shared across experts)
            if cfg.n_experts and "experts" in ps:
                st = jax.tree.map(lambda a: jnp.max(a, axis=1), st)
                wts = wts._replace(outlier_idx=wts.outlier_idx[:, 0])
            qstate_flat[key] = st
            new_leaves.append(wts)
            continue

        # non-quaff modes
        if len(stack) == 0:
            if mode == QuantMode.SMOOTH_STATIC:
                new_leaves.append(fn(w, jnp.asarray(absmax_lut[key])))
            else:
                new_leaves.append(fn(w, bias))
        else:
            w2 = w.reshape((-1,) + w.shape[-2:])
            if mode == QuantMode.SMOOTH_STATIC:
                cal = np.asarray(absmax_lut[key]).reshape((-1, c_in))
                if cal.shape[0] != w2.shape[0]:
                    cal = np.repeat(cal, w2.shape[0] // cal.shape[0], axis=0)
                out = jax.vmap(fn)(w2, jnp.asarray(cal))
            else:
                b2 = None if bias is None else bias.reshape((-1,) + bias.shape[-1:])
                out = (jax.vmap(lambda wi: fn(wi))(w2) if b2 is None
                       else jax.vmap(lambda wi, bi: fn(wi, bi))(w2, b2))
            out = jax.tree.map(lambda a: a.reshape(stack + a.shape[1:]), out)
            new_leaves.append(out)

    frozen_new = jax.tree_util.tree_unflatten(treedef, new_leaves)
    # rebuild quant_state in the same structure init_params would produce
    _, _, qstate_like = jax.eval_shape(
        lambda k: M.init_params(k, _with_mode(cfg, target_mode)),
        jax.random.PRNGKey(0))
    if mode != QuantMode.QUAFF:
        return frozen_new, jax.tree.map(lambda x: None, qstate_like)
    qstate = _rebuild_qstate(qstate_like, qstate_flat)
    return frozen_new, qstate


def _with_mode(cfg: ModelConfig, mode: str) -> ModelConfig:
    import dataclasses
    return dataclasses.replace(cfg, quant=dataclasses.replace(cfg.quant,
                                                              mode=mode))


def _rebuild_qstate(qstate_like, qstate_flat: Dict[str, Any]):
    from repro.core.scaling import ScaleState
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(
        qstate_like, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    # group leaves back into ScaleStates by path prefix
    out_leaves = []
    for path, leaf in paths_leaves:
        ps = _path_str(path)
        # path ends with .../<lin>/<field> where field in {s, w_absmax}
        parts = ps.split("/")
        field = parts[-1]
        key = _norm("/".join(parts[:-1]))
        st = qstate_flat[key]
        out_leaves.append(getattr(st, field))
    return jax.tree_util.tree_unflatten(treedef, out_leaves)
