"""Step builders: train_step (loss + LoRA-only grads + AdamW + Quaff momentum
state update, with microbatch gradient accumulation), serve_prefill and
serve_decode. These are the functions the launcher lowers under pjit.

State layout (functional, donated between steps):
    TrainState = (adapters, opt_state, quant_state, step)
``frozen`` (the quantized base model) is a separate argument — it never
changes during fine-tuning, which is exactly Quaff's decoupling story.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import peft as PEFT
from repro.core.scaling import ScaleState, momentum_update
from repro.models import model as M
from repro.models.config import ModelConfig, TrainConfig
from repro.optim import adamw
from repro.train import losses


class TrainState(NamedTuple):
    adapters: Any
    opt: adamw.AdamWState
    quant: Any
    step: jnp.ndarray


def init_train_state(adapters, quant_state, tcfg: TrainConfig) -> TrainState:
    return TrainState(
        adapters=adapters,
        opt=adamw.init(adapters, use_error_feedback=tcfg.grad_compression),
        quant=quant_state,
        step=jnp.zeros((), jnp.int32),
    )


def update_quant_state(quant_state, stats, gamma: float):
    """Vectorized Eq. 7 across the whole model. ``stats`` leading dims (layer
    stacks) match the state's; max-reduces nothing — shapes already align."""
    def upd(st, m):
        return momentum_update(st, m, gamma)
    return jax.tree.map(
        upd, quant_state, stats,
        is_leaf=lambda x: isinstance(x, ScaleState))


def _has_scale_state(quant_state) -> bool:
    """True when the backend produced per-layer scale states (Quaff): the
    quant tree then has array leaves to momentum-update each step."""
    return len(jax.tree.leaves(quant_state)) > 0


def _split_microbatches(batch: Dict[str, jnp.ndarray], n: int):
    def resh(x):
        return x.reshape((n, x.shape[0] // n) + x.shape[1:])
    return jax.tree.map(resh, batch)


def build_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    """Returns train_step(frozen, state, batch) -> (state, metrics).

    batch: {"tokens": (B,S), "labels": (B,S)} (+ "embeds" for vlm/encdec).
    Microbatching: B is split into ``tcfg.microbatches`` chunks scanned
    sequentially with gradient accumulation (bounds activation memory)."""
    n_prefix = PEFT.n_prefix_tokens(cfg.peft)
    # stochastic LoRA dropout only when asked for AND configured > 0; the
    # rng is derived from (tcfg.seed, step, microbatch) so runs stay
    # reproducible and eval (which never passes an rng) stays deterministic.
    use_dropout = (not tcfg.deterministic
                   and cfg.peft.method == "lora"
                   and cfg.peft.lora_dropout > 0.0)

    def loss_fn(adapters, frozen, quant_state, mb, rng):
        remat = tcfg.remat_policy if tcfg.remat else False
        # named_scope: phase labels for device profiles (jax.profiler /
        # Obs.start_jax_profiler) — the fused jitted step has no host
        # boundaries to span, so this is where fwd/bwd/quant/optim
        # attribution comes from
        with jax.named_scope("fwd"):
            out = M.forward(
                frozen, adapters, quant_state, mb["tokens"], cfg,
                input_embeds=mb.get("embeds"), remat=remat, rng=rng)
        logits, stats, aux = out.logits, out.stats, out.aux_loss
        if n_prefix:
            logits = logits[:, n_prefix:, :]
        if cfg.family == "vlm" and cfg.n_image_tokens:
            logits = logits[:, cfg.n_image_tokens:, :]
        loss, n_tok = losses.cross_entropy(logits.astype(jnp.float32),
                                           mb["labels"])
        total = loss + cfg.moe_aux_weight * aux
        return total, (loss, aux, stats)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(frozen, state: TrainState, batch):
        nmb = tcfg.microbatches
        mbs = _split_microbatches(batch, nmb)
        if use_dropout:
            step_key = jax.random.fold_in(jax.random.PRNGKey(tcfg.seed),
                                          state.step)
            mb_keys = jax.random.split(step_key, nmb)
        else:
            mb_keys = None

        def micro(carry, xs):
            mb, key = xs
            g_acc, loss_acc, aux_acc = carry
            with jax.named_scope("bwd"):
                (_, (loss, aux, stats)), grads = grad_fn(
                    state.adapters, frozen, state.quant, mb, key)
            g_acc = jax.tree.map(lambda a, g: a + g, g_acc, grads)
            return (g_acc, loss_acc + loss, aux_acc + aux), stats

        g0 = jax.tree.map(jnp.zeros_like, state.adapters)
        (g_sum, loss_sum, aux_sum), stats_all = jax.lax.scan(
            micro, (g0, jnp.zeros(()), jnp.zeros(())), (mbs, mb_keys))
        grads = jax.tree.map(lambda g: g / nmb, g_sum)
        # momentum update uses the LAST microbatch's stats (freshest)
        stats = jax.tree.map(lambda s: s[-1], stats_all)

        with jax.named_scope("optim"):
            new_adapters, new_opt, opt_metrics = adamw.update(
                grads, state.opt, state.adapters,
                lr=tcfg.learning_rate, beta1=tcfg.beta1, beta2=tcfg.beta2,
                weight_decay=tcfg.weight_decay, grad_clip=tcfg.grad_clip,
                compress=tcfg.grad_compression)

        new_quant = state.quant
        if _has_scale_state(state.quant):
            with jax.named_scope("quant"):
                new_quant = update_quant_state(state.quant, stats,
                                               cfg.quant.gamma)

        metrics = {
            "loss": loss_sum / nmb,
            "aux_loss": aux_sum / nmb,
            "grad_norm": opt_metrics["grad_norm"],
        }
        new_state = TrainState(new_adapters, new_opt, new_quant, state.step + 1)
        return new_state, metrics

    return train_step


def build_eval_step(cfg: ModelConfig):
    n_prefix = PEFT.n_prefix_tokens(cfg.peft)

    def eval_step(frozen, adapters, quant_state, batch):
        # no rng: eval is always dropout-free / deterministic
        logits = M.forward(
            frozen, adapters, quant_state, batch["tokens"], cfg,
            input_embeds=batch.get("embeds")).logits
        if n_prefix:
            logits = logits[:, n_prefix:, :]
        if cfg.family == "vlm" and cfg.n_image_tokens:
            logits = logits[:, cfg.n_image_tokens:, :]
        logits = logits.astype(jnp.float32)
        loss, _ = losses.cross_entropy(logits, batch["labels"])
        acc = losses.token_accuracy(logits, batch["labels"])
        return {"loss": loss, "ppl": losses.perplexity(loss), "acc": acc}

    return eval_step


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------
def build_prefill(cfg: ModelConfig, extra_len: int = 0):
    """prefill(frozen, adapters, quant_state, batch) -> (last_logits, caches).

    Decode caches are sized total_seq + ``extra_len`` (generation budget);
    attention writes the whole block with one dynamic_update_slice. The
    total sequence includes VLM image tokens and PEFT virtual tokens."""
    n_prefix = PEFT.n_prefix_tokens(cfg.peft)

    def prefill(frozen, adapters, quant_state, batch):
        tokens = batch["tokens"]
        bsz, s_len = tokens.shape
        total = s_len + n_prefix
        if cfg.family == "vlm":
            total += cfg.n_image_tokens
        caches = M.init_caches(cfg, bsz, total + extra_len)
        out = M.forward(
            frozen, adapters, quant_state, tokens, cfg,
            input_embeds=batch.get("embeds"), caches=caches,
            positions=jnp.arange(total, dtype=jnp.int32))
        return out.logits[:, -1, :], out.caches

    return prefill


def build_decode(cfg: ModelConfig):
    """decode(frozen, adapters, quant_state, caches, token, pos) ->
    (logits, new_caches). ``caches`` carry seq_len-sized KV/SSM buffers."""
    def decode(frozen, adapters, quant_state, caches, token, pos):
        out = M.forward(
            frozen, adapters, quant_state, token, cfg,
            caches=caches, positions=pos.reshape((1,)))
        return out.logits[:, -1, :], out.caches

    return decode


# ---------------------------------------------------------------------------
# Continuous batching (repro.serving): prefill-into-slot + slot decode.
# One compiled decode step serves a CHANGING request mix: the KV pool carries
# per-slot write cursors ((L, n_slots) ``pos`` — see models.init_slot_caches)
# and attention masks each row by its own length, so requests admitted
# mid-decode or retired on EOS never block the other slots.
# ---------------------------------------------------------------------------
def build_prefill_slot(cfg: ModelConfig, cache_len: int):
    """prefill_slot(frozen, adapters, quant_state, tokens, embeds=None) ->
    (last-token logits, row caches) — FAMILY-AGNOSTIC.

    ``tokens`` is ONE request (1, prompt_len); the returned caches come
    from ``models.init_slot_caches(cfg, 1, cache_len)`` so the row is
    structurally a one-slot pool and splices straight into any pool column
    (serving.state.splice_slot) for every family: KV rows + cursor
    (dense/moe/vlm), final recurrent state (ssm/hybrid), self-KV + the
    request's cross-KV (encdec). ``embeds`` carries the per-request
    encoder frames (encdec) or prepended patch embeddings (vlm). Under
    jit, compilation specializes per prompt-length shape automatically."""
    n_prefix = PEFT.n_prefix_tokens(cfg.peft)

    def prefill_slot(frozen, adapters, quant_state, tokens, embeds=None):
        total = tokens.shape[1] + n_prefix
        if embeds is not None and cfg.family != "encdec":
            total += embeds.shape[1]      # vlm: patches prepend to the seq
        caches = M.init_slot_caches(cfg, tokens.shape[0], cache_len)
        out = M.forward(
            frozen, adapters, quant_state, tokens, cfg, caches=caches,
            input_embeds=embeds,
            positions=jnp.arange(total, dtype=jnp.int32))
        return out.logits[:, -1, :], out.caches

    return prefill_slot


def build_paged_step(cfg: ModelConfig):
    """paged_step(frozen, adapters, quant_state, caches, tokens, positions)
    -> (last-token logits (B, vocab), new caches).

    ONE builder serves every paged-KV call shape: decode (tokens (n_slots,
    1)) and chunked prefill (tokens (B_group, chunk)) — the block-pool
    caches carry per-row block tables + write cursors, so the same forward
    writes each row's tokens wherever its table says. ``positions`` is
    (B, S) absolute RoPE positions (chunk rows start mid-prompt). Under jit
    the function re-specializes per (B, S) — chunked admission groups
    same-length rows precisely so this stays a handful of shapes.

    Chunked prefill + prompt-PEFT: the engine passes adapters WITHOUT the
    "prompt" entry for continuation chunks, so the virtual-token prefix is
    prepended exactly once (on the first chunk)."""
    def paged_step(frozen, adapters, quant_state, caches, tokens, positions):
        out = M.forward(
            frozen, adapters, quant_state, tokens, cfg,
            caches=caches, positions=positions)
        return out.logits[:, -1, :], out.caches

    return paged_step


def build_unified_step(cfg: ModelConfig):
    """unified_step(frozen, adapters, quant_state, caches, tokens,
    positions, row_start, row_len, row_ids, n_tok) -> (per-row last-token
    logits (R, vocab), new caches).

    ONE dispatch for a MIXED batch: the engine flattens admitted prefill
    tails and live decode slots into a ragged token stream ``tokens``
    (1, T_cap) with absolute ``positions`` (1, T_cap); ``row_start`` /
    ``row_len`` (R,) locate each row's span, ``row_ids`` (T_cap,) maps
    stream tokens back to rows, and ``n_tok`` counts the live tokens (the
    tail is padding the ragged kernels skip). The row tables broadcast over
    the layer axis so the transformer's cache scan slices them per layer
    alongside the block tables; ``models.layers`` picks the ragged branch
    off the ``row_start`` cache key. The per-row sampled logits sit at each
    row's LAST span position — dead rows (row_len == 0) gather garbage the
    engine never samples."""
    def unified_step(frozen, adapters, quant_state, caches, tokens,
                     positions, row_start, row_len, row_ids, n_tok):
        nl = cfg.n_layers

        def per_layer(a):
            return jnp.broadcast_to(a, (nl,) + a.shape)

        merged = dict(caches)
        merged.update(row_start=per_layer(row_start),
                      row_len=per_layer(row_len),
                      row_ids=per_layer(row_ids),
                      n_tok=jnp.broadcast_to(n_tok, (nl,)))
        out = M.forward(frozen, adapters, quant_state, tokens, cfg,
                        caches=merged, positions=positions)
        new_caches = {key: out.caches[key] for key in caches}
        idx = jnp.maximum(row_start + row_len - 1, 0)
        return jnp.take(out.logits[0], idx, axis=0), new_caches

    return unified_step


def build_decode_slots(cfg: ModelConfig):
    """decode_slots(frozen, adapters, quant_state, caches, tokens,
    positions, live=None) -> (logits (n_slots, vocab), new_caches) —
    FAMILY-AGNOSTIC (every non-paged layout).

    ``tokens`` is (n_slots, 1) — each slot's previous token (free slots
    carry a pad token; their logits are ignored by the engine).
    ``positions`` is (n_slots,) — each slot's RoPE / sinusoidal position
    (prompt_len + n generated, the same convention the lockstep
    ``api.QuaffModel.generate`` used). KV write positions and length masks
    come from the caches' per-slot cursors; for the recurrent families
    ``live`` ((n_slots,) bool) masks the state carry so dead slots keep
    their stored state bit-exactly."""
    def decode_slots(frozen, adapters, quant_state, caches, tokens,
                     positions, live=None):
        out = M.forward(
            frozen, adapters, quant_state, tokens, cfg,
            caches=caches, positions=positions[:, None], live=live)
        return out.logits[:, -1, :], out.caches

    return decode_slots
