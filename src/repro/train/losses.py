"""Loss functions. Labels use -1 as the ignore index (padding / virtual
prompt positions); the data pipeline aligns labels[t] = tokens[t+1]."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray):
    """logits: (B, S, V) fp32; labels: (B, S) int32 with -1 ignored.
    Returns (mean_loss, n_tokens)."""
    mask = (labels >= 0)
    safe = jnp.where(mask, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask.astype(logits.dtype)
    n = jnp.maximum(jnp.sum(mask), 1)
    return jnp.sum(nll) / n.astype(logits.dtype), n


def perplexity(mean_loss: jnp.ndarray) -> jnp.ndarray:
    return jnp.exp(mean_loss)


def token_accuracy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    mask = (labels >= 0)
    pred = jnp.argmax(logits, axis=-1)
    correct = jnp.sum((pred == labels) & mask)
    return correct / jnp.maximum(jnp.sum(mask), 1)
