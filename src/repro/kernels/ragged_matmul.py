"""Pallas TPU kernel: ragged fused QKV projection over packed INT4 weights.

The unified mixed-batch step (serving) feeds the attention projections one
flattened ragged token stream padded to a static capacity — the tail past
``n_tok`` is dead weight a dense GEMM would still pay for. This kernel is
``kernels/int4_matmul.py`` (same split-half nibble unpack, group-wise
scales, f32 accumulator) with two additions:

  * ``n_tok`` rides in SMEM via scalar prefetch and gates every compute
    step with ``pl.when`` — token blocks that are entirely padding skip
    both integer dots AND the packed-byte unpack, writing zeros instead,
    so the quantized GEMM genuinely skips pad rows at block granularity;
  * ``ragged_qkv_matmul`` fuses the q/k/v projections into ONE kernel
    launch by concatenating their packed carriers along c_out (all three
    share c_in and the group grid), quantizing the activation stream once.

Rows at or past ``n_tok`` inside a live block are unspecified (they carry
whatever the padded activations produce); callers never read them. The
jnp oracle ``ragged_int4_matmul_ref`` computes the dense product for
parity checks on the live rows.
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import fit_block, interpret_mode


def _kernel(nt_ref, xlo_ref, xhi_ref, wp_ref, xd_ref, wdlo_ref, wdhi_ref,
            out_ref, acc_ref, *, k_steps: int, block_t: int):
    i, kk = pl.program_id(0), pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(i * block_t < nt_ref[0])
    def _compute():
        p = wp_ref[...].astype(jnp.int32) & 0xFF
        w_lo = (((p & 0xF) ^ 8) - 8).astype(jnp.int8)          # [0, K/2)
        w_hi = ((((p >> 4) & 0xF) ^ 8) - 8).astype(jnp.int8)   # [K/2, K)
        p_lo = jax.lax.dot_general(
            xlo_ref[...], w_lo, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        p_hi = jax.lax.dot_general(
            xhi_ref[...], w_hi, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        acc_ref[...] += (p_lo.astype(jnp.float32) * wdlo_ref[...]
                         + p_hi.astype(jnp.float32) * wdhi_ref[...])

    @pl.when(kk == k_steps - 1)
    def _epilogue():
        out_ref[...] = (acc_ref[...] * xd_ref[...]).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "block_n", "block_k",
                                             "interpret"))
def ragged_int4_matmul(
    x_int: jnp.ndarray,     # (T, K) int8 — ragged stream, pad past n_tok
    w_packed: jnp.ndarray,  # (K/2, N) int8 — two nibbles per byte
    x_delta: jnp.ndarray,   # (T, 1) f32 per-token step
    w_delta: jnp.ndarray,   # (G, N) f32 group steps (G == 1: per-OC)
    n_tok: jnp.ndarray,     # () or (1,) int32 — live rows in the stream
    *,
    block_t: int = 128,
    block_n: int = 128,
    block_k: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    interpret = interpret_mode(interpret)
    t, k = x_int.shape
    khalf, n = w_packed.shape
    assert k == 2 * khalf, (k, khalf)
    g = w_delta.shape[0]
    assert k % g == 0, (k, g)
    gs = k // g
    bt = fit_block(block_t, t)
    bn = fit_block(block_n, n)
    bk = fit_block(block_k, khalf, gs)  # one scale group per (lo|hi) block
    kh_steps = khalf // bk
    grid = (t // bt, n // bn, kh_steps)
    nt = jnp.reshape(n_tok, (1,)).astype(jnp.int32)

    return pl.pallas_call(
        functools.partial(_kernel, k_steps=kh_steps, block_t=bt),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bt, bk), lambda i, j, kk, nt: (i, kk)),
                pl.BlockSpec((bt, bk),
                             lambda i, j, kk, nt: (i, kk + kh_steps)),
                pl.BlockSpec((bk, bn), lambda i, j, kk, nt: (kk, j)),
                pl.BlockSpec((bt, 1), lambda i, j, kk, nt: (i, 0)),
                pl.BlockSpec((1, bn),
                             lambda i, j, kk, nt: ((kk * bk) // gs, j)),
                pl.BlockSpec(
                    (1, bn),
                    lambda i, j, kk, nt: ((khalf + kk * bk) // gs, j)),
            ],
            out_specs=pl.BlockSpec((bt, bn), lambda i, j, kk, nt: (i, j)),
            scratch_shapes=[pltpu.VMEM((bt, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((t, n), jnp.float32),
        interpret=interpret,
    )(nt, x_int, x_int, w_packed, x_delta, w_delta, w_delta)


def ragged_qkv_matmul(
    x_int: jnp.ndarray,
    x_delta: jnp.ndarray,
    w_packed: Sequence[jnp.ndarray],   # q/k/v carriers, each (K/2, N_i)
    w_delta: Sequence[jnp.ndarray],    # matching (G, N_i) group steps
    n_tok: jnp.ndarray,
    *,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, ...]:
    """One fused ragged GEMM for the q/k/v projections: the packed carriers
    concatenate along c_out (they share c_in and the scale-group grid), the
    stream is quantized once by the caller, pad blocks are skipped, and the
    output splits back into per-projection slabs."""
    gs = {d.shape[0] for d in w_delta}
    assert len(gs) == 1, f"q/k/v group grids differ: {gs}"
    wp = jnp.concatenate(list(w_packed), axis=1)
    wd = jnp.concatenate(list(w_delta), axis=1)
    y = ragged_int4_matmul(x_int, wp, x_delta, wd, n_tok,
                           interpret=interpret)
    sizes = [p.shape[1] for p in w_packed]
    splits = []
    off = 0
    for s in sizes[:-1]:
        off += s
        splits.append(off)
    return tuple(jnp.split(y, splits, axis=1))


def ragged_int4_matmul_ref(x_int, w_packed, x_delta, w_delta) -> jnp.ndarray:
    """Dense jnp oracle (no pad skipping): unpack both nibbles, group-wise
    dequant, per-token step. Compare live rows only."""
    k = x_int.shape[1]
    p = w_packed.astype(jnp.int32) & 0xFF
    lo = ((p & 0xF) ^ 8) - 8
    hi = (((p >> 4) & 0xF) ^ 8) - 8
    w_int = jnp.concatenate([lo, hi], axis=0).astype(jnp.float32)  # (K, N)
    g = w_delta.shape[0]
    w_fp = w_int * jnp.repeat(w_delta, k // g, axis=0)
    return (x_int.astype(jnp.float32) @ w_fp) * x_delta


def ragged_int4_matmul_auto(x_int, w_packed, x_delta, w_delta,
                            n_tok) -> jnp.ndarray:
    """Entry point for ``models.layers``: compiled on TPU, interpret
    elsewhere."""
    interpret = jax.default_backend() != "tpu"
    return ragged_int4_matmul(x_int, w_packed, x_delta, w_delta, n_tok,
                              interpret=interpret)
