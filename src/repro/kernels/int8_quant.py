"""Pallas TPU kernels for the activation-quantization pipeline:

  rowmax_kernel     : per-token absmax over channel chunks (two-pass per-token
                      quantization needs the full row max; a (BT, K) slab may
                      not fit VMEM for K up to 49152, so the grid iterates
                      channel chunks and max-accumulates into the output —
                      the TPU grid is sequential, revisiting an output block
                      is the standard reduction idiom).
  scale_quant_kernel: fused X * s_inv (Quaff outlier suppression) + round to
                      INT8 against the per-token step. Emitting the scaled
                      int8 activations in one pass over X is what replaces
                      the GPU paper's separate scale + quantize kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import interpret_mode

INT8_MAX = 127.0


def _rowmax_kernel(x_ref, out_ref):
    k = pl.program_id(1)
    blockmax = jnp.max(jnp.abs(x_ref[...].astype(jnp.float32)), axis=-1,
                       keepdims=True)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = blockmax

    @pl.when(k > 0)
    def _acc():
        out_ref[...] = jnp.maximum(out_ref[...], blockmax)


@functools.partial(jax.jit, static_argnames=("block_t", "block_k",
                                             "interpret"))
def rowmax(x: jnp.ndarray, *, block_t: int = 256, block_k: int = 2048,
           interpret: bool = False) -> jnp.ndarray:
    """x: (T, K) -> (T, 1) fp32 row absmax."""
    interpret = interpret_mode(interpret)
    t, k = x.shape
    bt, bk = min(block_t, t), min(block_k, k)
    assert t % bt == 0 and k % bk == 0
    return pl.pallas_call(
        _rowmax_kernel,
        grid=(t // bt, k // bk),
        in_specs=[pl.BlockSpec((bt, bk), lambda i, kk: (i, kk))],
        out_specs=pl.BlockSpec((bt, 1), lambda i, kk: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, 1), jnp.float32),
        interpret=interpret,
    )(x)


def _scale_quant_kernel(x_ref, sinv_ref, delta_ref, out_ref, *,
                        qmax: float = INT8_MAX):
    x = x_ref[...].astype(jnp.float32) * sinv_ref[...].astype(jnp.float32)
    q = jnp.round(x / delta_ref[...])
    out_ref[...] = jnp.clip(q, -qmax, qmax).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("block_t", "block_k",
                                             "qmax", "interpret"))
def scale_quant(x: jnp.ndarray, s_inv: jnp.ndarray, delta: jnp.ndarray, *,
                block_t: int = 256, block_k: int = 2048,
                qmax: float = INT8_MAX,
                interpret: bool = False) -> jnp.ndarray:
    """x: (T, K), s_inv: (K,), delta: (T, 1) -> int8 (T, K) clipped to
    ±``qmax`` (127 for int8 carriers, 7 for int4-range carriers)."""
    interpret = interpret_mode(interpret)
    t, k = x.shape
    bt, bk = min(block_t, t), min(block_k, k)
    assert t % bt == 0 and k % bk == 0
    return pl.pallas_call(
        functools.partial(_scale_quant_kernel, qmax=qmax),
        grid=(t // bt, k // bk),
        in_specs=[
            pl.BlockSpec((bt, bk), lambda i, kk: (i, kk)),
            pl.BlockSpec((1, bk), lambda i, kk: (0, kk)),
            pl.BlockSpec((bt, 1), lambda i, kk: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bt, bk), lambda i, kk: (i, kk)),
        out_shape=jax.ShapeDtypeStruct((t, k), jnp.int8),
        interpret=interpret,
    )(x, s_inv.reshape(1, -1), delta)
