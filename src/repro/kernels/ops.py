"""Jit'd wrappers orchestrating the Pallas kernels into full linear-layer
forwards (the kernel-level counterparts of the core/ jnp paths):

  quaff_forward_pallas : rowmax -> scale_quant -> quaff_matmul_fused
                         (W8A8 GEMM + dequant + outlier correction)
  naive_forward_pallas : same pipeline with zero outlier channels
  int4_forward_pallas  : rowmax -> scale_quant (at the activation qmax) ->
                         int4_matmul_fused (packed-nibble W4 GEMM with
                         group-wise scales; x_bits picks w4a4 vs w4a8)

On this CPU container the kernels run with interpret=True (Python
execution of the kernel body); on a real TPU the same code compiles to
Mosaic. Each wrapper is validated against the pure-jnp oracle (core path)
in tests/test_kernels.py / tests/test_int4.py across shape sweeps.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.core import quant
from repro.core.quaff_linear import QuaffWeights, _scatter_s_inv
from repro.kernels import int4_matmul, int8_quant, quaff_matmul

INT8_MAX = 127.0


def quaff_forward_pallas(
    x: jnp.ndarray,           # (T, K) float
    weights: QuaffWeights,
    s: jnp.ndarray,           # (n_o,) momentum scales
    *,
    interpret: bool = True,
    block_t: int = 128,
    block_n: int = 128,
    block_k: int = 512,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full kernel-path Quaff linear. Returns (y (T, N) f32, stats (n_o,))."""
    t, k = x.shape
    s = jnp.maximum(s, 1.0)
    s_inv = _scatter_s_inv(s, weights.outlier_idx, k, jnp.float32)

    # pass 1: per-token absmax of X*s_inv — fold s_inv into the max
    xmax = int8_quant.rowmax(x * s_inv[None, :].astype(x.dtype),
                             interpret=interpret)
    delta = jnp.maximum(xmax, 1e-8) / INT8_MAX

    # pass 2: fused scale + quantize
    x_int = int8_quant.scale_quant(x, s_inv, delta, interpret=interpret)

    # outlier slab (gather of already-quantized columns — Eq. 9 shares Dx)
    xo_int = jnp.take(x_int, weights.outlier_idx, axis=1)
    w_hat = (s - 1.0)[:, None] * weights.w_outlier
    wo_int, wo_delta = quant.quantize(w_hat, axis=0)

    # pass 3: fused dual-GEMM + epilogue
    o = xo_int.shape[1]
    o_pad = -o % 8  # MXU-friendly outlier slab
    if o_pad:
        xo_int = jnp.pad(xo_int, ((0, 0), (0, o_pad)))
        wo_int = jnp.pad(wo_int, ((0, o_pad), (0, 0)))
    y = quaff_matmul.quaff_matmul_fused(
        x_int, weights.w_int, delta, weights.w_delta.reshape(1, -1),
        xo_int, wo_int, wo_delta.reshape(1, -1),
        block_t=block_t, block_n=block_n, block_k=block_k,
        interpret=interpret)
    if weights.bias is not None:
        y = y + weights.bias[None, :]

    stats = jnp.max(jnp.abs(
        jnp.take(x, weights.outlier_idx, axis=1).astype(jnp.float32)), axis=0)
    return y, stats


def int4_forward_pallas(
    x: jnp.ndarray,            # (T, K) float
    weights,                   # core.int4.Int4Weights (packed + group deltas)
    *,
    x_bits: int = 4,           # 4 -> w4a4, 8 -> w4a8
    interpret: bool = True,
    block_t: int = 128,
    block_n: int = 128,
    block_k: int = 256,
) -> jnp.ndarray:
    """Full kernel-path packed-INT4 linear: per-token activation quantize at
    ``x_bits`` + fused unpack-dequant GEMM. Returns y (T, N) f32."""
    t, k = x.shape
    qm = quant.qmax_for_bits(x_bits)
    xmax = int8_quant.rowmax(x, interpret=interpret)
    delta = jnp.maximum(xmax, 1e-8) / qm
    x_int = int8_quant.scale_quant(x, jnp.ones((k,), jnp.float32), delta,
                                   qmax=qm, interpret=interpret)
    y = int4_matmul.int4_matmul_fused(
        x_int, weights.w_packed, delta, weights.w_delta,
        block_t=block_t, block_n=block_n, block_k=block_k,
        interpret=interpret)
    if weights.bias is not None:
        y = y + weights.bias[None, :]
    return y


def naive_forward_pallas(x, w_int, w_delta, *, interpret: bool = True):
    """Kernel-path naive WAQ (zero outlier channels)."""
    t, k = x.shape
    xmax = int8_quant.rowmax(x, interpret=interpret)
    delta = jnp.maximum(xmax, 1e-8) / INT8_MAX
    x_int = int8_quant.scale_quant(x, jnp.ones((k,), jnp.float32), delta,
                                   interpret=interpret)
    zero_o = jnp.zeros((t, 8), jnp.int8)
    zero_w = jnp.zeros((8, w_int.shape[1]), jnp.int8)
    zero_d = jnp.zeros((1, w_int.shape[1]), jnp.float32)
    return quaff_matmul.quaff_matmul_fused(
        x_int, w_int, delta, w_delta.reshape(1, -1), zero_o, zero_w, zero_d,
        interpret=interpret)
