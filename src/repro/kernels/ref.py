"""Pure-jnp oracles for the Pallas kernels. Every kernel test sweeps shapes
and dtypes against these references (integer math is exact, so comparisons
are tight)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

INT8_MAX = 127.0


def rowmax_ref(x: jnp.ndarray) -> jnp.ndarray:
    """x: (T, K) -> (T, 1) row absmax (fp32)."""
    return jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)


def scale_quant_ref(x: jnp.ndarray, s_inv: jnp.ndarray, delta: jnp.ndarray):
    """Fused scale-by-s_inv + per-token INT8 quantization.
    x: (T, K); s_inv: (K,); delta: (T, 1) fp32 (precomputed from the scaled
    row max). Returns x_int (T, K) int8."""
    x_hat = x.astype(jnp.float32) * s_inv.astype(jnp.float32)[None, :]
    q = jnp.round(x_hat / delta)
    return jnp.clip(q, -INT8_MAX, INT8_MAX).astype(jnp.int8)


def quaff_matmul_ref(
    x_int: jnp.ndarray,    # (T, K) int8 — quantized scaled activations
    w_int: jnp.ndarray,    # (K, N) int8 — frozen base weights
    x_delta: jnp.ndarray,  # (T, 1) fp32 — per-token step
    w_delta: jnp.ndarray,  # (1, N) fp32 — per-OC step
    xo_int: jnp.ndarray,   # (T, O) int8 — outlier columns of x_int
    wo_int: jnp.ndarray,   # (O, N) int8 — quantized (s-1)*W_O
    wo_delta: jnp.ndarray,  # (1, N) fp32
) -> jnp.ndarray:
    """Paper Eq. 9: Dx (X_int W_int Dw + xo_int wo_int Dwo)."""
    base = jax.lax.dot_general(
        x_int, w_int, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32).astype(jnp.float32)
    corr = jax.lax.dot_general(
        xo_int, wo_int, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32).astype(jnp.float32)
    return (base * w_delta + corr * wo_delta) * x_delta


def int8_matmul_ref(x_int, w_int, x_delta, w_delta):
    """Naive WAQ GEMM + dequant epilogue (no outlier term)."""
    acc = jax.lax.dot_general(
        x_int, w_int, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32).astype(jnp.float32)
    return acc * x_delta * w_delta


# ---------------------------------------------------------------------------
# Packed-nibble INT4 (split-half layout: byte r = row r | row r+K/2 << 4)
# ---------------------------------------------------------------------------
def int4_pack_ref(w_int: jnp.ndarray) -> jnp.ndarray:
    """(K, N) int4-valued int8 -> (K//2, N) packed bytes."""
    k = w_int.shape[0]
    lo = w_int[: k // 2].astype(jnp.int32)
    hi = w_int[k // 2:].astype(jnp.int32)
    return ((lo & 0xF) | ((hi & 0xF) << 4)).astype(jnp.int8)


def int4_unpack_ref(packed: jnp.ndarray) -> jnp.ndarray:
    """(K//2, N) packed bytes -> (K, N) int8 in [-8, 7]."""
    p = packed.astype(jnp.int32) & 0xFF
    lo = ((p & 0xF) ^ 8) - 8
    hi = (((p >> 4) & 0xF) ^ 8) - 8
    return jnp.concatenate([lo, hi], axis=0).astype(jnp.int8)


def int4_matmul_ref(x_int, w_packed, x_delta, w_delta):
    """Unpack + group-scaled integer GEMM + per-token dequant.

    x_int: (T, K) int8; w_packed: (K/2, N); x_delta: (T, 1) f32;
    w_delta: (G, N) f32 — group g scales c_in rows [g*K/G, (g+1)*K/G)."""
    w_int = int4_unpack_ref(w_packed)
    t = x_int.shape[0]
    k, n = w_int.shape
    g = w_delta.shape[0]
    xg = x_int.reshape((t, g, k // g)).transpose(1, 0, 2)     # (G, T, gs)
    wg = w_int.reshape((g, k // g, n))                        # (G, gs, N)
    acc = jax.lax.dot_general(
        xg, wg, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.int32).astype(jnp.float32)  # (G, T, N)
    return jnp.sum(acc * w_delta[:, None, :], axis=0) * x_delta
