"""Pallas TPU kernels for packed-nibble INT4 weight carriers.

Layout (must match ``core/quant.pack_int4``): split-half along c_in — byte
r of the packed (K/2, N) array holds original row r in the LOW nibble and
row r + K/2 in the HIGH nibble. The split (rather than the usual
even/odd interleave) is deliberate: unpack is a concatenation of two
contiguous row-blocks, so the GEMM kernel reads both activation halves as
ordinary contiguous blocks instead of a lane-strided gather the VPU would
have to emulate.

  pack_int4_pallas   : (K, N) int4-valued int8 -> (K/2, N) packed bytes.
                       Two input views of the same array (lo/hi halves via
                       two BlockSpec index maps) -> one byte store per pair.
  unpack_int4_pallas : (K/2, N) packed -> (K, N) sign-extended nibbles,
                       emitted as two outputs (lo, hi halves) the wrapper
                       concatenates — each grid step writes one block of
                       each half, no revisits.

Sign extension is branch-free 4-bit two's-complement: ((v & 0xF) ^ 8) - 8.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import fit_block, interpret_mode


def _pack_kernel(lo_ref, hi_ref, out_ref):
    lo = lo_ref[...].astype(jnp.int32)
    hi = hi_ref[...].astype(jnp.int32)
    out_ref[...] = ((lo & 0xF) | ((hi & 0xF) << 4)).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("block_k", "block_n",
                                             "interpret"))
def pack_int4_pallas(w_int: jnp.ndarray, *, block_k: int = 256,
                     block_n: int = 512, interpret: bool = False
                     ) -> jnp.ndarray:
    """w_int: (K, N) int8 with int4-range values -> (K//2, N) packed int8."""
    interpret = interpret_mode(interpret)
    k, n = w_int.shape
    assert k % 2 == 0, f"pack_int4_pallas needs an even c_in, got {k}"
    kh = k // 2
    bk, bn = fit_block(block_k, kh), fit_block(block_n, n)
    kh_steps = kh // bk
    return pl.pallas_call(
        _pack_kernel,
        grid=(kh_steps, n // bn),
        in_specs=[
            pl.BlockSpec((bk, bn), lambda i, j: (i, j)),             # rows r
            pl.BlockSpec((bk, bn),
                         lambda i, j: (i + kh_steps, j)),            # r + K/2
        ],
        out_specs=pl.BlockSpec((bk, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((kh, n), jnp.int8),
        interpret=interpret,
    )(w_int, w_int)


def _unpack_kernel(p_ref, lo_ref, hi_ref):
    p = p_ref[...].astype(jnp.int32) & 0xFF
    lo_ref[...] = (((p & 0xF) ^ 8) - 8).astype(jnp.int8)
    hi_ref[...] = ((((p >> 4) & 0xF) ^ 8) - 8).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("block_k", "block_n",
                                             "interpret"))
def unpack_int4_pallas(packed: jnp.ndarray, *, block_k: int = 256,
                       block_n: int = 512, interpret: bool = False
                       ) -> jnp.ndarray:
    """packed: (K//2, N) int8 -> (K, N) int8 in [-8, 7]."""
    interpret = interpret_mode(interpret)
    kh, n = packed.shape
    bk, bn = fit_block(block_k, kh), fit_block(block_n, n)
    lo, hi = pl.pallas_call(
        _unpack_kernel,
        grid=(kh // bk, n // bn),
        in_specs=[pl.BlockSpec((bk, bn), lambda i, j: (i, j))],
        out_specs=[pl.BlockSpec((bk, bn), lambda i, j: (i, j)),
                   pl.BlockSpec((bk, bn), lambda i, j: (i, j))],
        out_shape=[jax.ShapeDtypeStruct((kh, n), jnp.int8),
                   jax.ShapeDtypeStruct((kh, n), jnp.int8)],
        interpret=interpret,
    )(packed)
    return jnp.concatenate([lo, hi], axis=0)
