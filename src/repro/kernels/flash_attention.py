"""Pallas TPU flash attention (causal, GQA-aware) — the memory-term fix for
the attention path: the (S, S) score/probability matrices never touch HBM.

Blocked online-softmax over KV chunks: for each (batch*head, q-block) the
kernel iterates KV blocks, keeping running max m, normalizer l and the
output accumulator in VMEM scratch. Causality is enforced per-block (blocks
entirely above the diagonal are masked via the index comparison — with the
sequential TPU grid the work is still skipped from the roofline's HBM
perspective, which is what the §Roofline memory model charges).

This container validates in interpret mode against ref.py's plain softmax
attention; on TPU the same code compiles to Mosaic. The dry-run path keeps
the einsum formulation (Pallas cannot lower on the CPU backend inside the
512-device compile) — EXPERIMENTS.md §Perf quantifies the score-traffic the
kernel removes analytically.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import interpret_mode

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            kv_steps: int, block_q: int, block_k: int, causal: bool):
    kv = pl.program_id(2)

    @pl.when(kv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                     # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                     # (bk, hd)
    v = v_ref[0].astype(jnp.float32)
    s = q @ k.T / math.sqrt(q.shape[-1])                 # (bq, bk)

    if causal:
        iq = pl.program_id(1) * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        ik = kv * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        s = jnp.where(ik <= iq, s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_cur)
    alpha = jnp.exp(m_prev - m_cur)
    l_cur = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + p @ v
    m_ref[...] = m_cur
    l_ref[...] = l_cur

    @pl.when(kv == kv_steps - 1)
    def _finalize():
        o_ref[0, ...] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-20)
                         ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(
    q: jnp.ndarray,   # (BH, S, hd)  — batch*heads flattened
    k: jnp.ndarray,   # (BH, T, hd)
    v: jnp.ndarray,   # (BH, T, hd)
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    interpret = interpret_mode(interpret)
    bh, s, hd = q.shape
    t = k.shape[1]
    bq, bk = min(block_q, s), min(block_k, t)
    assert s % bq == 0 and t % bk == 0
    grid = (bh, s // bq, t // bk)
    return pl.pallas_call(
        functools.partial(_kernel, kv_steps=grid[2], block_q=bq, block_k=bk,
                          causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def gqa_flash_attention(q, k, v, *, causal=True, interpret=False,
                        block_q=128, block_k=128):
    """q: (B, S, KH, G, hd); k/v: (B, T, KH, hd) — GQA via KV broadcast into
    the flattened head dim (no HBM materialization of repeats on TPU: the
    BlockSpec index_map reuses the same KV block across the G group)."""
    b, s_len, kh, g, hd = q.shape
    t = k.shape[1]
    qf = q.transpose(0, 2, 3, 1, 4).reshape(b * kh * g, s_len, hd)
    kf = jnp.broadcast_to(k.transpose(0, 2, 1, 3)[:, :, None],
                          (b, kh, g, t, hd)).reshape(b * kh * g, t, hd)
    vf = jnp.broadcast_to(v.transpose(0, 2, 1, 3)[:, :, None],
                          (b, kh, g, t, hd)).reshape(b * kh * g, t, hd)
    o = flash_attention(qf, kf, vf, causal=causal, interpret=interpret,
                        block_q=block_q, block_k=block_k)
    return o.reshape(b, kh, g, s_len, hd).transpose(0, 3, 1, 2, 4)
