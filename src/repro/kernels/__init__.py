"""Pallas TPU kernels for the hot paths the paper optimizes, plus their
entry points:

  int8_quant    rowmax / scale_quant — two-pass per-token quantization
  quaff_matmul  quaff_matmul_fused — W8A8 GEMM + dequant + outlier GEMM
  int4_pack     pack_int4_pallas / unpack_int4_pallas — two signed nibbles
                per int8 byte (split-half layout, see core/quant.pack_int4)
  int4_matmul   int4_matmul_fused — fused unpack-dequant GEMM over packed
                INT4 weights with group-wise scales (w4a4 and w4a8)
  flash_attention  flash_attention / gqa_flash_attention
  ragged_attention ragged_attention — ONE flash dispatch over a flattened
                mixed prefill+decode token stream with per-row offset
                tables (paged or contiguous KV, in-kernel int8 dequant)
  ragged_matmul ragged_int4_matmul / ragged_qkv_matmul — the int4 fused
                GEMM with pad-block skipping + fused q/k/v projection
  ops           jnp-orchestrated full-layer forwards built from the above
  ref           pure-jnp oracles every kernel test compares against

Every wrapper takes ``interpret=`` and honors ``REPRO_PALLAS_INTERPRET=1``
(see ``common.interpret_mode``) so CPU-only runners — CI in particular —
execute the kernel bodies without Mosaic.
"""
from repro.kernels.common import FORCE_INTERPRET, interpret_mode
from repro.kernels.flash_attention import flash_attention, gqa_flash_attention
from repro.kernels.int4_matmul import int4_matmul_fused
from repro.kernels.int4_pack import pack_int4_pallas, unpack_int4_pallas
from repro.kernels.int8_quant import rowmax, scale_quant
from repro.kernels.quaff_matmul import quaff_matmul_fused
from repro.kernels.ragged_attention import ragged_attention
from repro.kernels.ragged_matmul import ragged_int4_matmul, ragged_qkv_matmul

__all__ = [
    "FORCE_INTERPRET",
    "interpret_mode",
    "flash_attention",
    "gqa_flash_attention",
    "int4_matmul_fused",
    "ragged_attention",
    "ragged_int4_matmul",
    "ragged_qkv_matmul",
    "pack_int4_pallas",
    "unpack_int4_pallas",
    "rowmax",
    "scale_quant",
    "quaff_matmul_fused",
]
