"""Pallas TPU kernel: ragged flash attention over a flattened token stream.

One dispatch serves PREFILL rows and DECODE rows together (POD-attention
style). Queries arrive as ONE ragged stream ``(total_tokens, KH, G, hd)``
with per-row offset tables — ``row_start`` / ``row_len`` locate each row's
span in the stream, ``cursor`` is how many positions the row already holds
in its KV pool. A row attends to

  * its pool prefix ``[0, cursor)``, read through the per-row block table
    (in-kernel int8 dequant under the paged pool's static per-channel K
    grid + per-token V scales — same layout as
    ``serving/paged/kernels/paged_attention.py``), and
  * its OWN span of the step's K/V stream (``k_self`` / ``v_self``),
    causally masked within the span.

A contiguous (non-paged) slot buffer is the degenerate pool: one page of
``max_seq_len`` positions per row with an identity block table, so the same
kernel serves both KV layouts. Decode rows are just ``row_len == 1`` spans;
dead rows (``row_len == 0``) produce finite don't-care output the caller
never gathers.

Grid ``(n_rows, KH, pages + 1)``: the first ``pages`` steps stream the pool
prefix through the online-softmax accumulator (fully-masked pages wash out
exactly — the first live score zeroes the provisional sums via
``alpha = exp(-inf - m) = 0``), the final step folds in the causal self
span and normalizes. The offset tables ride in SMEM via scalar prefetch so
the K/V BlockSpec index maps can chase the block tables, and the Q/self
streams are whole-array refs sliced at ``row_start`` with ``pl.ds``.

Routing: ``models.layers`` consults ``REPRO_RAGGED_PALLAS=1`` (read once at
import, like the paged sibling); the pure-jnp path below
(``ragged_attention_ref``) is the oracle and the default CPU math.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import interpret_mode

NEG_INF = -1e30


def _kernel(bt_ref, rs_ref, rl_ref, cur_ref, q_ref, ks_ref, vs_ref,
            kp_ref, vp_ref, ksc_ref, vsc_ref, o_ref, m_ref, l_ref, acc_ref,
            *, pages: int, page_size: int, bq: int, g: int):
    r, h, p = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    hd = q_ref.shape[-1]
    scale = 1.0 / math.sqrt(hd)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    start = rs_ref[r]
    q = q_ref[pl.ds(start, bq), h].astype(jnp.float32)       # (bq, g, hd)
    qf = q.reshape(bq * g, hd)

    def accumulate(s, v):
        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        probs = jnp.exp(s - m_cur)
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(probs, axis=1,
                                                  keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + probs @ v
        m_ref[...] = m_cur

    @pl.when(p < pages)
    def _pool_page():
        # pool prefix through the block table, dequantized in-register
        # (unit scales on fp pools make this the identity)
        k = kp_ref[0, :, 0, :].astype(jnp.float32) * ksc_ref[...]
        v = vp_ref[0, :, 0, :].astype(jnp.float32) * vsc_ref[0]
        s = jax.lax.dot_general(
            qf, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # (bq*g, page)
        pos = p * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < cur_ref[r], s, NEG_INF)
        accumulate(s, v)

    @pl.when(p == pages)
    def _self_span():
        ks = ks_ref[pl.ds(start, bq), h].astype(jnp.float32)  # (bq, hd)
        vs = vs_ref[pl.ds(start, bq), h].astype(jnp.float32)
        s = jax.lax.dot_general(
            qf, ks, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale       # (bq*g, bq)
        qi = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // g
        kj = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where((kj <= qi) & (kj < rl_ref[r]), s, NEG_INF)
        accumulate(s, vs)
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-20)
        o_ref[0, 0] = out.reshape(bq, g, hd).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("max_row_len", "interpret"))
def ragged_attention(
    q: jnp.ndarray,             # (total_tokens, KH, G, hd) ragged Q stream
    k_self: jnp.ndarray,        # (total_tokens, KH, hd) this step's keys
    v_self: jnp.ndarray,        # (total_tokens, KH, hd) this step's values
    k_pool: jnp.ndarray,        # (n_pages, page, KH, hd) f32 or int8
    v_pool: jnp.ndarray,        # (n_pages, page, KH, hd) f32 or int8
    block_tables: jnp.ndarray,  # (n_rows, pages) int32
    row_start: jnp.ndarray,     # (n_rows,) int32 span start in the stream
    row_len: jnp.ndarray,       # (n_rows,) int32 span length (0 = dead row)
    cursor: jnp.ndarray,        # (n_rows,) int32 pool positions already held
    k_scale=None,               # (KH, hd) f32 static per-channel K grid
    v_scale=None,               # (n_pages, page, KH) f32 per-token V scales
    *,
    max_row_len: int,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns per-row output ``(n_rows, max_row_len, KH, G, hd)`` f32; the
    caller gathers position ``i`` of row ``r`` back into its stream slot.
    Entries past ``row_len`` are don't-care."""
    interpret = interpret_mode(interpret)
    total, kh, g, hd = q.shape
    n_rows, pages = block_tables.shape
    page_size = k_pool.shape[1]
    bq = max_row_len
    # fp pools pass scale=None: resolved at trace time (None is a static
    # pytree leaf, not a tracer), so dequant becomes the identity
    if k_scale is None:  # repro: noqa[RPR002] None check is static
        k_scale = jnp.ones((kh, hd), jnp.float32)
    if v_scale is None:  # repro: noqa[RPR002] None check is static
        v_scale = jnp.ones(v_pool.shape[:3], jnp.float32)
    # pad the streams by one span so any (row_start, bq) slice is in bounds
    q = jnp.pad(q, ((0, bq), (0, 0), (0, 0), (0, 0)))
    k_self = jnp.pad(k_self, ((0, bq), (0, 0), (0, 0)))
    v_self = jnp.pad(v_self, ((0, bq), (0, 0), (0, 0)))
    # one trash column so the K/V index maps stay in bounds on the self step
    bt = jnp.concatenate(
        [block_tables.astype(jnp.int32),
         jnp.zeros((n_rows, 1), jnp.int32)], axis=1)

    grid = (n_rows, kh, pages + 1)
    out = pl.pallas_call(
        functools.partial(_kernel, pages=pages, page_size=page_size,
                          bq=bq, g=g),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=grid,
            in_specs=[
                pl.BlockSpec(q.shape,
                             lambda r, h, p, bt, rs, rl, cur: (0, 0, 0, 0)),
                pl.BlockSpec(k_self.shape,
                             lambda r, h, p, bt, rs, rl, cur: (0, 0, 0)),
                pl.BlockSpec(v_self.shape,
                             lambda r, h, p, bt, rs, rl, cur: (0, 0, 0)),
                pl.BlockSpec(
                    (1, page_size, 1, hd),
                    lambda r, h, p, bt, rs, rl, cur: (bt[r, p], 0, h, 0)),
                pl.BlockSpec(
                    (1, page_size, 1, hd),
                    lambda r, h, p, bt, rs, rl, cur: (bt[r, p], 0, h, 0)),
                pl.BlockSpec((1, hd),
                             lambda r, h, p, bt, rs, rl, cur: (h, 0)),
                pl.BlockSpec(
                    (1, page_size, 1),
                    lambda r, h, p, bt, rs, rl, cur: (bt[r, p], 0, h)),
            ],
            out_specs=pl.BlockSpec(
                (1, 1, bq, g, hd),
                lambda r, h, p, bt, rs, rl, cur: (r, h, 0, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((bq * g, 1), jnp.float32),
                pltpu.VMEM((bq * g, 1), jnp.float32),
                pltpu.VMEM((bq * g, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((n_rows, kh, bq, g, hd), jnp.float32),
        interpret=interpret,
    )(bt, row_start.astype(jnp.int32), row_len.astype(jnp.int32),
      cursor.astype(jnp.int32), q, k_self, v_self, k_pool, v_pool,
      k_scale, v_scale)
    return out.transpose(0, 2, 1, 3, 4)          # (n_rows, bq, KH, G, hd)


def ragged_attention_ref(q, k_self, v_self, k_pool, v_pool, block_tables,
                         row_start, row_len, cursor,
                         k_scale=None, v_scale=None, *,
                         max_row_len: int) -> jnp.ndarray:
    """Pure-jnp oracle, bit-compatible masking with the kernel (and the
    default CPU math ``models.layers`` runs without the env flag)."""
    bq = max_row_len
    n_rows, pages = block_tables.shape
    page = k_pool.shape[1]
    qp = jnp.pad(q, ((0, bq), (0, 0), (0, 0), (0, 0))).astype(jnp.float32)
    ksp = jnp.pad(k_self, ((0, bq), (0, 0), (0, 0))).astype(jnp.float32)
    vsp = jnp.pad(v_self, ((0, bq), (0, 0), (0, 0))).astype(jnp.float32)
    idx = row_start[:, None] + jnp.arange(bq, dtype=jnp.int32)[None, :]
    qr, ks, vs = qp[idx], ksp[idx], vsp[idx]     # (R, bq, ...)

    kg = k_pool[block_tables].astype(jnp.float32)  # (R, P, page, KH, hd)
    vg = v_pool[block_tables].astype(jnp.float32)
    if k_scale is not None:
        kg = kg * k_scale
    if v_scale is not None:
        vg = vg * v_scale[block_tables][..., None]
    t_ctx = pages * page
    kh, hd = kg.shape[-2], kg.shape[-1]
    kf = jnp.concatenate([kg.reshape(n_rows, t_ctx, kh, hd), ks], axis=1)
    vf = jnp.concatenate([vg.reshape(n_rows, t_ctx, kh, hd), vs], axis=1)

    kpos = jnp.arange(t_ctx + bq, dtype=jnp.int32)           # (Tk,)
    qi = jnp.arange(bq, dtype=jnp.int32)                     # (bq,)
    self_j = kpos - t_ctx
    key_ok = jnp.where(kpos[None, :] < t_ctx,
                       kpos[None, :] < cursor[:, None],
                       self_j[None, :] < row_len[:, None])   # (R, Tk)
    causal = (kpos[None, None, :] < t_ctx) \
        | (self_j[None, None, :] <= qi[None, :, None])       # (1, bq, Tk)
    mask = key_ok[:, None, :] & causal                       # (R, bq, Tk)

    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("rikgh,rjkh->rkgij", qr, kf) * scale
    s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
    probs = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("rkgij,rjkh->rikgh", probs, vf)


def ragged_attention_auto(q, k_self, v_self, k_pool, v_pool, block_tables,
                          row_start, row_len, cursor,
                          k_scale=None, v_scale=None, *,
                          max_row_len: int) -> jnp.ndarray:
    """Entry point for ``models.layers``: compiled on TPU, interpret
    elsewhere."""
    interpret = jax.default_backend() != "tpu"
    return ragged_attention(q, k_self, v_self, k_pool, v_pool, block_tables,
                            row_start, row_len, cursor, k_scale, v_scale,
                            max_row_len=max_row_len, interpret=interpret)
