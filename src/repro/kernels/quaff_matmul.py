"""Pallas TPU kernel: fused W8A8 GEMM with per-token x per-OC dequant
epilogue AND the Quaff outlier-correction GEMM in the same block loop.

TPU adaptation of the paper's bitsandbytes INT8 path (DESIGN.md §4):
  * both GEMMs hit the MXU as s8xs8->s32 (2x bf16 throughput);
  * the (T, O) outlier slab and (O, N) corrected weights are small
    (O <= 10% K by the paper's budget) and stay resident in VMEM across the
    K-loop, so the correction costs no extra HBM reads of X;
  * the dequant epilogue (x_delta * w_delta) and the correction are applied
    once per (BT, BN) output block on the final K step — on GPU the paper
    issues two cuBLAS calls plus a separate dequant kernel; here it is one
    fused pass.

Grid (T/BT, N/BN, K/BK), K innermost; int32 accumulator in VMEM scratch.
Block defaults (128, 128, 512) keep the working set
  BT*BK + BK*BN (int8) + BT*BN*4 (acc) + BT*O + O*BN
well under 16 MB VMEM for O <= 1024.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import interpret_mode


def _kernel(x_ref, w_ref, xd_ref, wd_ref, xo_ref, wo_ref, wod_ref,
            out_ref, acc_ref, *, k_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k == k_steps - 1)
    def _epilogue():
        corr = jax.lax.dot_general(
            xo_ref[...], wo_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32).astype(jnp.float32)
        base = acc_ref[...].astype(jnp.float32)
        y = (base * wd_ref[...] + corr * wod_ref[...]) * xd_ref[...]
        out_ref[...] = y.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "block_n", "block_k",
                                             "interpret"))
def quaff_matmul_fused(
    x_int: jnp.ndarray,    # (T, K) int8
    w_int: jnp.ndarray,    # (K, N) int8
    x_delta: jnp.ndarray,  # (T, 1) f32
    w_delta: jnp.ndarray,  # (1, N) f32
    xo_int: jnp.ndarray,   # (T, O) int8
    wo_int: jnp.ndarray,   # (O, N) int8
    wo_delta: jnp.ndarray,  # (1, N) f32
    *,
    block_t: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    interpret = interpret_mode(interpret)
    t, k = x_int.shape
    _, n = w_int.shape
    o = xo_int.shape[1]
    bt, bn, bk = min(block_t, t), min(block_n, n), min(block_k, k)
    assert t % bt == 0 and n % bn == 0 and k % bk == 0, (t, n, k, bt, bn, bk)
    grid = (t // bt, n // bn, k // bk)

    return pl.pallas_call(
        functools.partial(_kernel, k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, bk), lambda i, j, kk: (i, kk)),   # x
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),   # w
            pl.BlockSpec((bt, 1), lambda i, j, kk: (i, 0)),     # x_delta
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),     # w_delta
            pl.BlockSpec((bt, o), lambda i, j, kk: (i, 0)),     # xo (resident)
            pl.BlockSpec((o, bn), lambda i, j, kk: (0, j)),     # wo
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),     # wo_delta
        ],
        out_specs=pl.BlockSpec((bt, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bt, bn), jnp.int32)],
        interpret=interpret,
    )(x_int, w_int, x_delta, w_delta, xo_int, wo_int, wo_delta)
