"""Pallas TPU kernel: fused unpack-dequant-GEMM over packed-nibble INT4
weights with group-wise (or per-OC) scales.

Serves both int4 modes — the activation operand is whatever the quantizer
produced (int8 per-token at 8 bits for w4a8, int4-range int8 carriers for
w4a4); the MXU contraction is s8 x s8 -> s32 either way.

Why the weights never exist unpacked in HBM: the packed (K/2, N) byte block
is DMA'd to VMEM once per grid step and both nibbles are expanded in
registers right before the dot — HBM traffic for the weight stream is
HALVED vs an int8 GEMM of the same logical shape, which is the entire
memory win of ``bits=4``.

Why two dots per step: the split-half layout puts rows [0, K/2) in low
nibbles and [K/2, K) in high nibbles, so one packed block pairs with TWO
activation blocks (x[:, kb] and x[:, K/2 + kb]) — both contiguous, fed via
two BlockSpec views of the same x buffer. An even/odd interleaved layout
would need a lane-strided gather here instead.

Why the accumulator is f32 (not the usual s32): with G scale groups along
c_in the per-OC "dequant epilogue" factorization no longer exists — each
K-step's s32 partial product must be scaled by its group's (1, BN) delta
row before joining the accumulator. The two group rows per step are picked
by BlockSpec index maps ((k_off // group_size, j)), so block_k must divide
group_size; per-OC is just G == 1, where both maps collapse to row 0.
Grid (T/BT, N/BN, K/2/BK), K innermost; the per-token step is applied once
on the last K step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import fit_block, interpret_mode


def _kernel(xlo_ref, xhi_ref, wp_ref, xd_ref, wdlo_ref, wdhi_ref, out_ref,
            acc_ref, *, k_steps: int):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    p = wp_ref[...].astype(jnp.int32) & 0xFF
    w_lo = (((p & 0xF) ^ 8) - 8).astype(jnp.int8)          # rows [0, K/2)
    w_hi = ((((p >> 4) & 0xF) ^ 8) - 8).astype(jnp.int8)   # rows [K/2, K)
    p_lo = jax.lax.dot_general(
        xlo_ref[...], w_lo, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    p_hi = jax.lax.dot_general(
        xhi_ref[...], w_hi, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    acc_ref[...] += (p_lo.astype(jnp.float32) * wdlo_ref[...]
                     + p_hi.astype(jnp.float32) * wdhi_ref[...])

    @pl.when(kk == k_steps - 1)
    def _epilogue():
        out_ref[...] = (acc_ref[...] * xd_ref[...]).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "block_n", "block_k",
                                             "interpret"))
def int4_matmul_fused(
    x_int: jnp.ndarray,     # (T, K) int8 (int4-range carriers for w4a4)
    w_packed: jnp.ndarray,  # (K/2, N) int8 — two nibbles per byte
    x_delta: jnp.ndarray,   # (T, 1) f32 per-token step
    w_delta: jnp.ndarray,   # (G, N) f32 group steps (G == 1: per-OC)
    *,
    block_t: int = 128,
    block_n: int = 128,
    block_k: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    interpret = interpret_mode(interpret)
    t, k = x_int.shape
    kh, n = w_packed.shape
    assert k == 2 * kh, (k, kh)
    g = w_delta.shape[0]
    assert k % g == 0, (k, g)
    gs = k // g
    bt = fit_block(block_t, t)
    bn = fit_block(block_n, n)
    bk = fit_block(block_k, kh, gs)   # one scale group per (lo|hi) K-block
    kh_steps = kh // bk
    grid = (t // bt, n // bn, kh_steps)

    return pl.pallas_call(
        functools.partial(_kernel, k_steps=kh_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, bk), lambda i, j, kk: (i, kk)),          # x lo
            pl.BlockSpec((bt, bk),
                         lambda i, j, kk: (i, kk + kh_steps)),         # x hi
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),          # bytes
            pl.BlockSpec((bt, 1), lambda i, j, kk: (i, 0)),            # Dx
            pl.BlockSpec((1, bn),
                         lambda i, j, kk: ((kk * bk) // gs, j)),       # Dw lo
            pl.BlockSpec((1, bn),
                         lambda i, j, kk: ((kh + kk * bk) // gs, j)),  # Dw hi
        ],
        out_specs=pl.BlockSpec((bt, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bt, bn), jnp.float32)],
        interpret=interpret,
    )(x_int, x_int, w_packed, x_delta, w_delta, w_delta)


def int4_matmul_auto(x_int, w_packed, x_delta, w_delta) -> jnp.ndarray:
    """Backend entry point (core/int4*.py forwards land here when the
    Pallas route is enabled): compiled on TPU, interpret elsewhere."""
    interpret = jax.default_backend() != "tpu"
    return int4_matmul_fused(x_int, w_packed, x_delta, w_delta,
                             interpret=interpret)
