"""Shared kernel-layer knobs.

``REPRO_PALLAS_INTERPRET=1`` forces every Pallas wrapper in this package
into interpret mode regardless of what the caller requested — the switch CI
flips so the whole suite runs the kernel bodies on CPU-only runners. Read
once at import so jit cache keys stay consistent within a process.
"""
from __future__ import annotations

import math
import os

FORCE_INTERPRET = os.environ.get(
    "REPRO_PALLAS_INTERPRET", "").lower() in ("1", "true", "yes")


def interpret_mode(requested: bool) -> bool:
    """The interpret flag a wrapper should pass to ``pl.pallas_call``."""
    return True if FORCE_INTERPRET else bool(requested)


def fit_block(block: int, *dims: int) -> int:
    """Largest block size <= ``block`` dividing every dim in ``dims`` (the
    auto-shape rule for kernel entry points that cannot assert on their
    callers' shapes). gcd-based: exact for the power-of-two shapes the MXU
    wants, conservative otherwise."""
    g = 0
    for d in dims:
        g = math.gcd(g, d)
    return max(1, math.gcd(g, min(block, g)))
