"""Pytree path rendering shared by checkpointing and calibration."""
from __future__ import annotations


def key_str(p) -> str:
    """Render one path entry (DictKey / SequenceKey / GetAttrKey / FlattenedIndexKey)."""
    for attr in ("key", "idx", "name"):
        if hasattr(p, attr):
            return str(getattr(p, attr))
    return str(p).lstrip(".")


def path_str(path) -> str:
    return "/".join(key_str(p) for p in path)
