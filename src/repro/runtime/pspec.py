"""Sharding-hint plumbing: model code calls ``hint(x, kind)`` at layer
boundaries; the launcher installs a ``ShardingRules`` table mapping semantic
kinds -> PartitionSpec. With no rules installed (unit tests, single device)
hints are no-ops, so model code is mesh-agnostic.

Kinds:
  act_btd    : residual stream (batch, seq, d_model)
  act_btf    : FFN hidden      (batch, seq, d_ff)
  act_heads  : attention       (batch, seq, heads, head_dim)
  logits     : (batch, seq, vocab)
  kv_cache   : (batch, seq, kv_heads, head_dim)
  ssm_state  : (batch, heads, head_dim, state)
  moe_buffer : (experts, capacity, d)
  tokens     : (batch, seq)
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, Optional

import jax
from jax.sharding import PartitionSpec as P

_ACTIVE: Optional["ShardingRules"] = None


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Semantic-kind -> PartitionSpec. Built per (arch x shape x mesh) by
    repro.launch.shardings; see there for the actual policies."""

    table: Dict[str, P]

    def spec(self, kind: str) -> Optional[P]:
        return self.table.get(kind)


def active_rules() -> Optional[ShardingRules]:
    return _ACTIVE


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = rules
    try:
        yield
    finally:
        _ACTIVE = prev


def hint(x, kind: str):
    """Annotate x with the active spec for ``kind`` (no-op without rules)."""
    if _ACTIVE is None:
        return x
    spec = _ACTIVE.spec(kind)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)
