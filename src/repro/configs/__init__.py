"""Config registry: get_config("<arch-id>") for every assigned architecture
(+ phi3, the paper's own model). IDs match the assignment table."""
from importlib import import_module
from typing import Dict, List

from repro.models.config import ModelConfig, SHAPES, ShapeConfig  # noqa: F401

_MODULES: Dict[str, str] = {
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t_a32b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "qwen1.5-110b": "repro.configs.qwen15_110b",
    "qwen2-7b": "repro.configs.qwen2_7b",
    "tinyllama-1.1b": "repro.configs.tinyllama_1_1b",
    "gemma3-27b": "repro.configs.gemma3_27b",
    "pixtral-12b": "repro.configs.pixtral_12b",
    "zamba2-1.2b": "repro.configs.zamba2_1_2b",
    "xlstm-350m": "repro.configs.xlstm_350m",
    "whisper-large-v3": "repro.configs.whisper_large_v3",
    "phi3-3.8b": "repro.configs.phi3_3_8b",
}

ASSIGNED: List[str] = [k for k in _MODULES if k != "phi3-3.8b"]


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(_MODULES)}")
    return import_module(_MODULES[name]).get_config()


def list_archs() -> List[str]:
    return list(_MODULES)


# one representative arch per non-dense family — the per-family serving
# tests and the bench_serving --family CI gate must drive the SAME model
FAMILY_DEMO_ARCHS: Dict[str, str] = {
    "ssm": "xlstm-350m",
    "hybrid": "zamba2-1.2b",
    "encdec": "whisper-large-v3",
    "vlm": "pixtral-12b",
}


def reduced_family_demo(family: str, quant_mode: str = "quaff",
                        lora_rank: int = 4) -> ModelConfig:
    """The shared per-family demo recipe (reduced arch, placeholder-init
    quant mode, small LoRA) used by tests/test_serving_families and
    benchmarks/bench_serving so CI gates and tests validate one model."""
    import dataclasses

    from repro.core.peft import PEFTConfig
    from repro.models.config import QuantConfig

    cfg = get_config(FAMILY_DEMO_ARCHS[family]).reduced()
    return dataclasses.replace(
        cfg, quant=QuantConfig(mode=quant_mode),
        peft=PEFTConfig(method="lora", lora_rank=lora_rank))
