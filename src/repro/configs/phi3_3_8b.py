"""phi3-3.8b-mini — the paper's default model (Abdin et al., 2024):
32L d_model=3072 32H (GQA kv=32) d_ff=8192 vocab=32064. Used by the paper
benchmarks (Tables 1-4, Figs 3-7); not part of the assigned 10-arch pool."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="phi3-3.8b", family="dense",
        n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab_size=32064,
        act_dtype="bfloat16", param_dtype="bfloat16",
        source="arXiv:2404.14219",
    )
