"""whisper-large-v3 — enc-dec, conv frontend stub [arXiv:2212.04356;
unverified]: 32L(dec)+32L(enc) d_model=1280 20H d_ff=5120 vocab=51866.
Frames arrive as precomputed embeddings (B, 1500, D) per the assignment.
GELU FFN, sinusoidal positions (no RoPE). Decode shapes exercise the
decoder serve_step; 32k decoder positions exceed Whisper's 448 cap but the
backbone supports them architecturally (DESIGN.md)."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3", family="encdec",
        n_layers=32, n_encoder_layers=32, encoder_seq=1500,
        d_model=1280, n_heads=20, n_kv_heads=20,
        d_ff=5120, vocab_size=51866,
        use_rope=False, ffn_type="gelu",
        act_dtype="bfloat16", param_dtype="bfloat16",
        source="arXiv:2212.04356; unverified",
    )
