"""xlstm-350m — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified]:
24L d_model=1024 4H d_ff=0 vocab=50304. 7:1 mLSTM:sLSTM ratio
(slstm_every=8 -> 3 stages of 7 mLSTM + 1 sLSTM)."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m", family="ssm",
        n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab_size=50304, slstm_every=8,
        act_dtype="bfloat16", param_dtype="bfloat16",
        source="arXiv:2405.04517; unverified",
    )
