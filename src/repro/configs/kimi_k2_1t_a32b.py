"""kimi-k2-1t-a32b — Kimi K2 trillion-param MoE (paper-table)
[arXiv:2501.kimi2; unverified]: 61L d_model=7168 64H (GQA kv=8) d_ff=2048
vocab=163840, MoE 384 experts top-8.

Simplifications vs the real release (documented in DESIGN.md): no first
dense layer / shared expert; head_dim = d_model/heads = 112."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b", family="moe",
        n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
        d_ff=2048, vocab_size=163840,
        n_experts=384, top_k=8, capacity_factor=1.25,
        act_dtype="bfloat16", param_dtype="bfloat16",
        source="arXiv:2501.kimi2; unverified",
    )
