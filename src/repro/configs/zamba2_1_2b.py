"""zamba2-1.2b — Mamba2 + shared attention blocks [arXiv:2411.15242; hf]:
38L(blocks) d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000 ssm_state=64.
Layout: 5 stages x (6 mamba2 + 1 SHARED attn) + 3 trailing mamba = 38.
d_inner=4096, ssm head_dim 64 (64 SSM heads). The Zamba concat-reproject
after shared attn is simplified to a residual add (DESIGN.md)."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b", family="hybrid",
        n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab_size=32000,
        ssm_state=64, d_inner=4096, ssm_head_dim=64, attn_every=6,
        act_dtype="bfloat16", param_dtype="bfloat16",
        source="arXiv:2411.15242; hf",
    )
