"""gemma3-27b — 5:1 local:global sliding window, 128k context
[hf:google/gemma-3-1b-pt; unverified]: 62L d_model=5376 32H (GQA kv=16)
d_ff=21504 vocab=262144. head_dim=128 per the gemma3 release (q_dim 4096 !=
d_model; our attention supports rectangular projections)."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b", family="dense",
        n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, head_dim=128,
        d_ff=21504, vocab_size=262144,
        sliding_window=1024, global_every=6,
        act_dtype="bfloat16", param_dtype="bfloat16",
        source="hf:google/gemma-3-1b-pt; unverified",
    )
