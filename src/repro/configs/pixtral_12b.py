"""pixtral-12b — pixtral-ViT + mistral-nemo backbone
[hf:mistralai/Pixtral-12B-2409; unverified]: 40L d_model=5120 32H (GQA kv=8)
d_ff=14336 vocab=131072. The ViT frontend is a STUB per the assignment:
input_specs provides precomputed patch embeddings (B, n_image_tokens, D)
prepended to the text stream. head_dim=128 per the Nemo release."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b", family="vlm",
        n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab_size=131072, n_image_tokens=256,
        act_dtype="bfloat16", param_dtype="bfloat16",
        source="hf:mistralai/Pixtral-12B-2409; unverified",
    )
