"""olmoe-1b-7b — 64 experts top-8 [arXiv:2409.02060; hf]:
16L d_model=2048 16H (GQA kv=16) d_ff=1024 vocab=50304, MoE 64e top-8."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b", family="moe",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1024, vocab_size=50304,
        n_experts=64, top_k=8, capacity_factor=1.25,
        act_dtype="bfloat16", param_dtype="bfloat16",
        source="arXiv:2409.02060; hf",
    )
