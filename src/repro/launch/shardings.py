"""Sharding policy: PartitionSpec assignment for every leaf of the frozen
model, train state, batch and caches, plus the activation ShardingRules the
models consume via runtime.pspec hints.

Policy summary (mesh ("pod")×("data","model"); dp = non-model axes):
  * batch dims              -> dp axes (when divisible)
  * frozen dense weights    -> (c_in: "data"[FSDP], c_out: "model"[TP]);
    the INT8 payload makes the per-layer FSDP all-gather 4x cheaper than
    fp32 FSDP — a Quaff-specific distributed win (see EXPERIMENTS.md §Perf)
  * MoE expert weights      -> (E: "data"[EP], c_out: "model"[TP])
  * vocab/lm_head           -> "model"
  * adapters/opt/quant state-> replicated (tiny by construction: PEFT)
  * KV caches               -> heads over "model" when divisible, else
    sequence over "model" (+ dp when batch is unshardable, e.g. long_500k)
Every rule degrades to replication when a dim is not divisible — compile
success is never hostage to an odd vocab (whisper's 51866).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import axis_size, dp_axes
from repro.models.config import ModelConfig, ShapeConfig
from repro.runtime.pspec import ShardingRules
from repro.runtime.treepath import path_str


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def _dp_if(mesh, n: int):
    """dp axes tuple if the dim divides the full dp extent, else None."""
    dp = dp_axes(mesh)
    size = math.prod(axis_size(mesh, a) for a in dp)
    return dp if _div(n, size) else None


def _model_if(mesh, n: int):
    return "model" if _div(n, axis_size(mesh, "model")) else None


def _data_if(mesh, n: int):
    return "data" if _div(n, axis_size(mesh, "data")) else None


# ---------------------------------------------------------------------------
# Frozen parameter specs
# ---------------------------------------------------------------------------
def _frozen_leaf_spec(path_s: str, shape: Tuple[int, ...], cfg: ModelConfig,
                      mesh) -> P:
    nd = len(shape)
    lead = (None,) * max(0, nd - 2)
    last = shape[-1] if nd else 1

    if path_s.endswith("embed/tokens"):
        return P(_model_if(mesh, shape[0]), None)
    if path_s.endswith("lm_head/w"):
        return P(None, _model_if(mesh, shape[1]))
    if path_s.endswith("/router"):
        return P(*(None,) * (nd - 1), _model_if(mesh, last))

    is_expert = "/experts/" in path_s
    # Megatron pairing: o/down projections are ROW-parallel (c_in over
    # "model"); q/k/v/up/gate are COLUMN-parallel (c_out over "model").
    is_row = (any(t in path_s for t in ("/down/", "/wo/", "/out_proj/",
                                        "/w_out/"))
              and not is_expert)
    # w_packed: the int4 nibble carrier — (c_in/2, c_out), shards exactly
    # like its unpacked counterparts (halved c_in still divides the mesh
    # for pow-2 axes; _div falls back to replicated otherwise)
    if (path_s.endswith(("/w_int", "/w_fp", "/w_packed"))
            or path_s.endswith("/w/w")):
        c_in, c_out = shape[-2], shape[-1]
        if is_expert:
            # (L, E, c_in, c_out): EP over "data", TP over "model"
            e_axis = _data_if(mesh, shape[-3])
            if is_row:
                return P(*(None,) * (nd - 3), e_axis,
                         _model_if(mesh, c_in), None)
            return P(*(None,) * (nd - 3), e_axis, None,
                     _model_if(mesh, c_out))
        if is_row:
            return P(*lead, _model_if(mesh, c_in), _data_if(mesh, c_out))
        return P(*lead, _data_if(mesh, c_in), _model_if(mesh, c_out))
    if path_s.endswith(("/w_delta", "/w_outlier")):
        if is_expert:
            return P(*(None,) * (nd - 3), _data_if(mesh, shape[-3]), None,
                     _model_if(mesh, last))
        return P(*lead, None, _model_if(mesh, last))
    if path_s.endswith("/bias"):
        if is_expert and nd >= 2:
            return P(*(None,) * (nd - 2), _data_if(mesh, shape[-2]),
                     _model_if(mesh, last))
        return P(*(None,) * (nd - 1), _model_if(mesh, last))
    if path_s.endswith("/w_og") or path_s.endswith("/w_if"):
        return P(*lead, None, _model_if(mesh, last))
    # norms, conv, gates, s_inv, outlier_idx, a_log, ... : replicated
    return P(*(None,) * nd)


def frozen_shardings(frozen_abstract, cfg: ModelConfig, mesh):
    flat, treedef = jax.tree_util.tree_flatten_with_path(frozen_abstract)
    out = []
    for path, leaf in flat:
        spec = _frozen_leaf_spec(path_str(path), tuple(leaf.shape), cfg, mesh)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def replicated_shardings(tree_abstract, mesh):
    return jax.tree.map(
        lambda leaf: NamedSharding(mesh, P(*(None,) * len(leaf.shape))),
        tree_abstract)


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------
def batch_shardings(batch_abstract, mesh):
    def spec(leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return NamedSharding(mesh, P())
        b = leaf.shape[0]
        return NamedSharding(mesh, P(_dp_if(mesh, b), *(None,) * (nd - 1)))
    return jax.tree.map(spec, batch_abstract)


def _cache_leaf_spec(path_s: str, shape, cfg: ModelConfig, mesh,
                     kv_batch_only: bool = False) -> P:
    nd = len(shape)
    if path_s.endswith("/pos") or nd <= 1:
        return P(*(None,) * nd)
    model = axis_size(mesh, "model")
    if path_s.endswith(("/k", "/v")) and nd >= 4:
        # (stack..., B, S, KH, hd)
        lead = (None,) * (nd - 4)
        b, s, kh, hd = shape[-4], shape[-3], shape[-2], shape[-1]
        b_axis = _dp_if(mesh, b)
        if kv_batch_only:
            # SPerf variant: replicate over "model" so the decode-step
            # dynamic-update-slice is shard-local (no cache all-gather);
            # costs model-axis memory replication.
            return P(*lead, b_axis, None, None, None)
        if _div(kh, model):
            return P(*lead, b_axis, None, "model", None)
        # heads unshardable: shard sequence — over model, plus dp when the
        # batch is idle (long_500k batch=1)
        seq_axes: Tuple = ("model",)
        if b_axis is None:
            full = dp_axes(mesh) + ("model",)
            size = math.prod(axis_size(mesh, a) for a in full)
            if _div(s, size):
                seq_axes = full
        if _div(s, math.prod(axis_size(mesh, a) for a in seq_axes)):
            return P(*lead, b_axis, seq_axes, None, None)
        return P(*lead, b_axis, None, None, None)
    if path_s.endswith("/h") and nd >= 4:
        # mamba state (stack..., B, H, P, N)
        lead = (None,) * (nd - 4)
        b, h = shape[-4], shape[-3]
        return P(*lead, _dp_if(mesh, b), _model_if(mesh, h), None, None)
    if path_s.endswith("/conv") and nd >= 3:
        lead = (None,) * (nd - 3)
        return P(*lead, _dp_if(mesh, shape[-3]), None, None)
    if path_s.endswith("/C") and nd >= 4:  # mLSTM matrix memory
        lead = (None,) * (nd - 4)
        return P(*lead, _dp_if(mesh, shape[-4]),
                 _model_if(mesh, shape[-3]), None, None)
    if nd >= 3:  # mLSTM n / sLSTM states (stack..., B, H, P)
        lead = (None,) * (nd - 3)
        return P(*lead, _dp_if(mesh, shape[-3]), None, None)
    return P(*(None,) * nd)


def cache_shardings(cache_abstract, cfg: ModelConfig, mesh,
                    kv_batch_only: bool = False):
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_abstract)
    out = []
    for path, leaf in flat:
        spec = _cache_leaf_spec(path_str(path), tuple(leaf.shape), cfg, mesh,
                                kv_batch_only)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Activation rules (runtime.pspec hints)
# ---------------------------------------------------------------------------
def build_rules(cfg: ModelConfig, mesh, shape: ShapeConfig,
                *, seq_shard: bool = False,
                kv_batch_only: bool = False) -> ShardingRules:
    dp = _dp_if(mesh, shape.global_batch)
    model = axis_size(mesh, "model")
    seq = shape.seq_len
    kh_ax = _model_if(mesh, cfg.n_kv_heads)
    table = {
        # FSDP weight-use constraints (per-layer INT8 all-gather over "data"):
        "weight_use2": P(None, "model"),
        "weight_use2_row": P("model", None),
        "weight_use3": P("data", None, "model"),
        "weight_use3_row": P("data", "model", None),
        "act_btd": P(dp, ("model" if seq_shard and _div(seq, model) else None),
                     None),
        "act_btf": P(dp, None, _model_if(mesh, max(cfg.d_ff, 1))),
        "act_heads": P(dp, None, _model_if(mesh, cfg.n_heads), None),
        # attention tensors: shard KV heads over "model" when divisible,
        # otherwise REPLICATE over "model" (attention computed data-parallel
        # only) — prevents GSPMD partial-summing (S,S) score matrices when
        # the head split doesn't align with the mesh (EXPERIMENTS.md §Perf).
        "attn_q": P(dp, None, kh_ax, None, None),
        "attn_kv": P(dp, None, kh_ax, None),
        "logits": P(dp, None, _model_if(mesh, cfg.vocab_size)),
        "kv_cache": _cache_leaf_spec(
            "/k", (shape.global_batch, seq, cfg.n_kv_heads, cfg.head_dim),
            cfg, mesh, kv_batch_only),
    }
    if cfg.n_experts:
        e_ax = _data_if(mesh, cfg.n_experts)
        pod_ax = "pod" if "pod" in mesh.axis_names else None
        table["moe_tokens"] = P(dp, None, None)               # (G, Tg, D)
        table["moe_group_buf"] = P(dp, None, None, None)      # (G, E, cap, D)
        table["moe_expert_buf"] = P(e_ax, pod_ax, None, None)  # (E, G, cap, D)
        table["moe_buffer"] = P(e_ax, pod_ax, None)           # (E, G*cap, D)
        table["moe_buffer_f"] = P(e_ax, pod_ax,
                                  _model_if(mesh, max(cfg.d_ff, 1)))
    return ShardingRules(table=table)
