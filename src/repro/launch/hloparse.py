"""Post-partitioning HLO analysis for the roofline.

Why not compiled.cost_analysis()? It does NOT multiply while-loop bodies by
their trip counts (verified: a 4-iteration lax.scan of matmuls reports 1
matmul of flops), and every model here is scan-over-layers — the numbers
would be ~n_layers too small. This module parses ``compiled.as_text()``,
builds the computation call graph, detects scan trip counts from loop
conditions, and aggregates with execution multiplicity:

  * dot FLOPs, split int8 vs float (the MXU runs s8xs8->s32 at 2x bf16 rate
    — exactly Quaff's win — so the compute roofline uses different peaks);
  * HBM byte traffic ~ result bytes of non-fused ops + dot operand reads
    (fusion interiors are excluded: fused intermediates never hit HBM);
  * collective bytes by type (all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute), result-shape sized.

All numbers are PER DEVICE (the module is the SPMD-partitioned per-device
program). Verified against hand-computed shardings in
tests/test_roofline_terms.py.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(type_str: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return "", []
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str  # text after the opcode's opening paren


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    types: Dict[str, str]  # op name -> result type


_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")


def _parse_op_line(line: str) -> Optional[Op]:
    m = _DEF_RE.match(line)
    if not m:
        return None
    rest = line[m.end():]
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        type_str, rest2 = rest[: i + 1], rest[i + 1:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str, rest2 = rest[:sp], rest[sp + 1:]
    m2 = re.match(r"([\w\-]+)\(", rest2)
    if not m2:
        return None
    return Op(m.group(1), type_str, m2.group(1), rest2[m2.end():])


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_name = None
    for line in text.splitlines():
        if cur is None:
            mc = _COMP_RE.match(line.strip())
            if mc:
                cur = Computation(mc.group(1), [], {})
                if line.strip().startswith("ENTRY"):
                    entry_name = mc.group(1)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        op = _parse_op_line(line)
        if op is not None:
            cur.ops.append(op)
            cur.types[op.name] = op.type_str
    if entry_name is not None:
        comps["__entry__"] = comps[entry_name]
    return comps


def _operand_names(rest: str) -> List[str]:
    # operands are the %names inside the top-level parens of the op call
    depth = 1
    out = []
    token = ""
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        token += ch
    return re.findall(r"%([\w.\-]+)", token)


def _called_comps(op: Op) -> List[str]:
    tail = op.rest
    out = []
    for key in ("condition", "body", "calls", "to_apply"):
        for m in re.finditer(key + r"=%?([\w.\-]+)", tail):
            out.append((key, m.group(1)))
    m = re.search(r"branch_computations=\{([^}]*)\}", tail)
    if m:
        for name in re.findall(r"%?([\w.\-]+)", m.group(1)):
            out.append(("branch", name))
    return out


def _trip_count(cond: Computation) -> int:
    """Scan loops compare the induction var against a constant bound."""
    consts = []
    for op in cond.ops:
        if op.opcode == "constant":
            m = re.match(r"\s*(\d+)\s*\)", op.rest)
            if m:
                consts.append(int(m.group(1)))
    # heuristic: the largest integer constant in the condition is the bound
    return max(consts) if consts else 1


_INT_TYPES = ("s8", "u8", "s4", "u4")


def _src_type(comp: "Computation", name: str, op_by_name=None) -> str:
    """Operand type, looking THROUGH a convert (XLA-CPU upcasts bf16->f32
    before GEMMs; the TPU program keeps bf16 — counting the pre-convert type
    gives the TPU-accurate byte/dtype view)."""
    t = comp.types.get(name, "")
    if op_by_name is not None:
        src = op_by_name.get(name)
        if src is not None and src.opcode == "convert":
            inner = _operand_names(src.rest)
            if inner:
                return comp.types.get(inner[0], t)
    return t


def _dot_flops(op: Op, types: Dict[str, str], comp=None, op_by_name=None
               ) -> Tuple[float, bool]:
    """2 * prod(result dims) * prod(contracting dim sizes of lhs)."""
    operands = _operand_names(op.rest)
    rdtype, rdims = _shape_dims(op.type_str)
    n_out = 1
    for d in rdims:
        n_out *= d
    if comp is not None:
        lhs_type = _src_type(comp, operands[0], op_by_name) if operands else ""
    else:
        lhs_type = types.get(operands[0], "") if operands else ""
    ldtype, ldims = _shape_dims(lhs_type)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    contract = 1
    if m and m.group(1):
        for i in m.group(1).split(","):
            if int(i) < len(ldims):
                contract *= ldims[int(i)]
    # int dots emit s32 accumulators; classify by result OR src operand
    is_int = rdtype == "s32" or ldtype in _INT_TYPES
    return 2.0 * n_out * contract, is_int


@dataclasses.dataclass
class HloSummary:
    dot_flops_float: float = 0.0
    dot_flops_int8: float = 0.0
    # Two HBM-traffic estimates (see EXPERIMENTS.md §Roofline method):
    #   hbm_bytes       — upper bound: every non-fused op's result + GEMM
    #                     operand reads, at CPU-backend fusion boundaries.
    #                     A TPU fuses the elementwise chains this counts.
    #   hbm_bytes_model — TPU-fusion-aware model: GEMM operands+results,
    #                     gather/dynamic-slice results, scatter/DUS updates,
    #                     reduce inputs, collective payloads. This is the
    #                     traffic that CANNOT fuse away (our Pallas kernels
    #                     demonstrate the quantize prologue/epilogue fusion
    #                     that removes the rest).
    hbm_bytes: float = 0.0
    hbm_bytes_model: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    collective_count: Dict[str, int] = dataclasses.field(
        default_factory=lambda: defaultdict(int))

    @property
    def total_flops(self) -> float:
        return self.dot_flops_float + self.dot_flops_int8

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    def to_json(self) -> Dict:
        return {
            "dot_flops_float": self.dot_flops_float,
            "dot_flops_int8": self.dot_flops_int8,
            "hbm_bytes": self.hbm_bytes,
            "hbm_bytes_model": self.hbm_bytes_model,
            "collective_bytes": dict(self.collective_bytes),
            "collective_count": dict(self.collective_count),
        }


def analyze(text: str) -> HloSummary:
    comps = parse_hlo(text)
    entry = comps.get("__entry__")
    if entry is None:
        return HloSummary()
    summary = HloSummary()

    def visit(comp: Computation, mult: float, in_fusion: bool):
        op_by_name = {o.name: o for o in comp.ops}
        for op in comp.ops:
            oc = op.opcode
            operands = None
            if oc == "dot":
                flops, is_int = _dot_flops(op, comp.types, comp, op_by_name)
                if is_int:
                    summary.dot_flops_int8 += mult * flops
                else:
                    summary.dot_flops_float += mult * flops
                # GEMM operand reads + result write always hit HBM; types are
                # looked up THROUGH converts (TPU keeps bf16/int8 end-to-end
                # where XLA-CPU upcasts to f32)
                operands = _operand_names(op.rest)
                src_types = [_src_type(comp, n, op_by_name)
                             for n in operands[:2]]
                b = sum(mult * _type_bytes(t) for t in src_types)
                rdtype, rdims = _shape_dims(op.type_str)
                n_out = 1
                for d in rdims:
                    n_out *= d
                if rdtype == "f32" and all(
                        _shape_dims(t)[0] == "bf16" for t in src_types if t):
                    b += mult * n_out * 2  # TPU emits bf16 out of a bf16 GEMM
                else:
                    b += mult * _type_bytes(op.type_str)
                summary.hbm_bytes += b
                summary.hbm_bytes_model += b
            elif oc in ("gather", "dynamic-slice"):
                summary.hbm_bytes_model += mult * _type_bytes(op.type_str)
            elif oc in ("dynamic-update-slice", "scatter"):
                operands = _operand_names(op.rest)
                upd_idx = 1 if oc == "dynamic-update-slice" else 2
                if len(operands) > upd_idx:
                    summary.hbm_bytes_model += mult * _type_bytes(
                        comp.types.get(operands[upd_idx], ""))
            elif oc == "reduce":
                operands = _operand_names(op.rest)
                if operands:
                    summary.hbm_bytes_model += mult * _type_bytes(
                        comp.types.get(operands[0], ""))
            coll = next((c for c in _COLLECTIVES if oc == c or
                         oc == c + "-start"), None)
            if coll:
                b = mult * _type_bytes(op.type_str)
                summary.collective_bytes[coll] += b
                summary.collective_count[coll] += int(mult)
                summary.hbm_bytes_model += b
            if not in_fusion and oc not in ("parameter", "constant", "tuple",
                                            "get-tuple-element", "bitcast",
                                            "dot"):
                summary.hbm_bytes += mult * _type_bytes(op.type_str)

            for kind, cname in _called_comps(op):
                child = comps.get(cname)
                if child is None:
                    continue
                if oc == "while":
                    if kind == "body":
                        cond_name = dict(_called_comps(op)).get("condition")
                        # find trip from the condition computation
                        trip = 1
                        for k2, c2 in _called_comps(op):
                            if k2 == "condition" and c2 in comps:
                                trip = _trip_count(comps[c2])
                        visit(child, mult * trip, in_fusion)
                elif oc == "fusion":
                    visit(child, mult, True)
                elif kind in ("calls", "to_apply") and oc in ("call",
                                                              "custom-call"):
                    visit(child, mult, in_fusion)
                elif kind == "branch":
                    visit(child, mult, in_fusion)
                # reduce/scatter/sort to_apply bodies: negligible, skipped

    visit(entry, 1.0, False)
    return summary
