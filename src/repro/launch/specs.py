"""Abstract input specs for the dry-run: ShapeDtypeStruct stand-ins for
every (architecture x shape) cell — weak-type-correct, shardable, zero
allocation. Also the microbatch policy (gradient-accumulation depth per
cell, bounding per-device activation memory)."""
from __future__ import annotations

import functools
import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.launch.mesh import axis_size, dp_axes
from repro.models import model as M
from repro.models.config import ModelConfig, ShapeConfig, TrainConfig
from repro.train import steps as STEPS

SDS = jax.ShapeDtypeStruct

# hillclimbed overrides (arch, shape) -> microbatches; see EXPERIMENTS.md §Perf
MICROBATCH_OVERRIDES: Dict[Tuple[str, str], int] = {}


def default_microbatches(cfg: ModelConfig, shape: ShapeConfig, mesh) -> int:
    if shape.kind != "train":
        return 1
    if (cfg.name, shape.name) in MICROBATCH_OVERRIDES:
        return MICROBATCH_OVERRIDES[(cfg.name, shape.name)]
    dp = math.prod(axis_size(mesh, a) for a in dp_axes(mesh))
    b, s = shape.global_batch, shape.seq_len
    target = 8192 if cfg.d_model >= 4096 else 32768
    valid = [mb for mb in (1, 2, 4, 8, 16, 32, 64)
             if b % mb == 0 and (b // mb) % dp == 0]
    for mb in valid:
        if b * s / (mb * dp) <= target:
            return mb
    return valid[-1] if valid else 1


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, *, with_labels: bool):
    """Training/prefill batch ShapeDtypeStructs."""
    b, s = shape.global_batch, shape.seq_len
    act = jnp.dtype(cfg.act_dtype)
    if cfg.family == "vlm":
        n_text = s - cfg.n_image_tokens
        out = {"tokens": SDS((b, n_text), jnp.int32)}
        if with_labels:
            out["labels"] = SDS((b, n_text), jnp.int32)
        out["embeds"] = SDS((b, cfg.n_image_tokens, cfg.d_model), act)
        return out
    if cfg.family == "encdec":
        out = {"tokens": SDS((b, s), jnp.int32),
               "embeds": SDS((b, cfg.encoder_seq, cfg.d_model), act)}
        if with_labels:
            out["labels"] = SDS((b, s), jnp.int32)
        return out
    out = {"tokens": SDS((b, s), jnp.int32)}
    if with_labels:
        out["labels"] = SDS((b, s), jnp.int32)
    return out


def model_specs(cfg: ModelConfig):
    """Abstract (frozen, adapters, quant_state) via eval_shape — no alloc."""
    return jax.eval_shape(
        functools.partial(_init, cfg=cfg), jax.random.PRNGKey(0))


def _init(key, cfg: ModelConfig):
    return M.init_params(key, cfg)


def state_specs(adapters_a, qstate_a, tcfg: TrainConfig):
    return jax.eval_shape(
        lambda a, q: STEPS.init_train_state(a, q, tcfg), adapters_a, qstate_a)


def cache_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Decode-shape caches at seq_len occupancy (KV buffers of that size)."""
    return jax.eval_shape(
        lambda: M.init_caches(cfg, shape.global_batch, shape.seq_len))


def decode_specs(cfg: ModelConfig, shape: ShapeConfig):
    return {
        "caches": cache_specs(cfg, shape),
        "token": SDS((shape.global_batch, 1), jnp.int32),
        "pos": SDS((), jnp.int32),
    }


def param_bytes(tree) -> int:
    return sum(
        math.prod(l.shape) * jnp.dtype(l.dtype).itemsize
        for l in jax.tree.leaves(tree))


def model_flops_per_token(cfg: ModelConfig, train: bool) -> float:
    """6*N_active*D analog: per-token useful GEMM flops.
    2*N_active per forward token, x3 for fwd+bwd in training."""
    d, hd = cfg.d_model, cfg.head_dim
    per_layer = 0.0
    attn = d * (cfg.q_dim + 2 * cfg.kv_dim) + cfg.q_dim * d
    if cfg.family == "moe":
        ffn = cfg.top_k * 3 * d * cfg.d_ff
    elif cfg.family in ("hybrid", "ssm"):
        di = cfg.d_inner or 2 * d
        ffn = 0.0
        attn = 0.0  # counted per block type below
    else:
        n_mat = 3 if cfg.ffn_type == "swiglu" else 2
        ffn = n_mat * d * cfg.d_ff

    if cfg.family == "hybrid":
        from repro.models.hybrid import zamba_layout
        ns, per, trail = zamba_layout(cfg)
        di = cfg.d_inner
        n_state = cfg.ssm_state
        h = di // cfg.ssm_head_dim
        mamba = d * (2 * di + 2 * n_state + h) + di * d
        attn_blk = d * (cfg.q_dim + 2 * cfg.kv_dim) + cfg.q_dim * d
        total = (ns * per + trail) * mamba + ns * attn_blk
    elif cfg.family == "ssm":
        from repro.models.hybrid import xlstm_layout
        ns, per_m, trail = xlstm_layout(cfg)
        mlstm = 4 * d * d + d * 2 * cfg.n_heads + d * d
        slstm = d * 4 * d + d * d
        total = (ns * per_m + trail) * mlstm + ns * slstm
    elif cfg.family == "encdec":
        dec = attn * 2 + ffn  # self + cross attention
        total = cfg.n_layers * dec
    else:
        total = cfg.n_layers * (attn + ffn)
    total += d * cfg.vocab_size  # lm head
    flops_fwd = 2.0 * total
    return flops_fwd * (3.0 if train else 1.0)


def model_flops_per_step(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Whole-step useful GEMM flops (the 6*N_active*D analog).

    Adds the encoder pass for enc-dec (runs once per step; its backward is
    dead-code — no trainable params upstream of the decoder cross-attn) and
    the VLM image positions. Attention score/context flops (O(S^2)) are NOT
    counted, matching the 6ND convention — noted in EXPERIMENTS.md."""
    train = shape.kind == "train"
    tokens = (shape.global_batch * shape.seq_len
              if shape.kind != "decode" else shape.global_batch)
    total = model_flops_per_token(cfg, train) * tokens
    if cfg.family == "encdec" and shape.kind != "decode":
        d = cfg.d_model
        attn = d * (cfg.q_dim + 2 * cfg.kv_dim) + cfg.q_dim * d
        n_mat = 3 if cfg.ffn_type == "swiglu" else 2
        enc_layer = attn + n_mat * d * cfg.d_ff
        enc_tokens = shape.global_batch * cfg.encoder_seq
        total += 2.0 * cfg.n_encoder_layers * enc_layer * enc_tokens
    return total
