"""Quantized serving driver: continuous-batched prefill + decode with the
Quaff INT8 path, driven through the ``repro.api`` facade.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --reduced --requests 8 --max-new 32

The loop implements the small-but-real serving pattern: a request queue,
batched prefill (one compiled program), then lockstep batched decode with a
shared KV/state cache; per-request completion on EOS-or-budget. Throughput
(tokens/s) and per-phase latency are reported.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.configs import get_config
from repro.core.peft import PEFTConfig
from repro.data.pipeline import DataConfig, Loader
from repro.models.config import QuantConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--quant-mode", default="quaff")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = dataclasses.replace(cfg, quant=QuantConfig(mode=args.quant_mode),
                              peft=PEFTConfig(method="lora", lora_rank=8))
    model = api.prepare(cfg)

    # request queue: synthetic prompts
    loader = Loader(DataConfig(vocab_size=cfg.vocab_size,
                               seq_len=args.prompt_len,
                               batch_size=args.requests))
    prompts = jnp.asarray(loader.batch(0)["tokens"])

    t0 = time.perf_counter()
    logits, caches = model.prefill({"tokens": prompts}, extra_len=args.max_new)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    generated = [tok]
    t0 = time.perf_counter()
    for i in range(args.max_new - 1):
        logits, caches = model.decode_step(caches, tok, args.prompt_len + i)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        generated.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    out = jnp.concatenate(generated, axis=1)
    total_new = args.requests * args.max_new
    print(f"[serve] {args.requests} reqs x {args.prompt_len} prompt "
          f"+ {args.max_new} new tokens ({cfg.name}, {args.quant_mode})")
    print(f"prefill: {t_prefill*1e3:.1f} ms "
          f"({args.requests*args.prompt_len/t_prefill:.0f} tok/s)")
    print(f"decode : {t_decode*1e3:.1f} ms "
          f"({total_new/max(t_decode,1e-9):.0f} tok/s)")
    print(f"sample completion (req 0): {np.asarray(out[0])[:16].tolist()}")


if __name__ == "__main__":
    main()
