"""Quantized serving driver: continuous batching through
``repro.serving.Engine`` over the ``repro.api`` facade.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --reduced --requests 16 --slots 4 --max-new 32 --mixed

A fixed-capacity slot pool serves the request queue: prompts are prefilled
into free slots mid-decode, every live slot advances one token per compiled
decode step, and slots retire on EOS-or-budget — no request waits for the
batch's slowest. ``--mixed`` draws per-request budgets/prompt lengths to
show the continuous-batching win (EngineStats vs the lockstep equivalent);
``--temperature/--top-k/--top-p`` exercise the seeded sampling path.
``--load DIR`` serves a ``QuaffModel.save`` checkpoint instead of a fresh
random-init model.

EVERY family serves through the engine — dense/moe KV slots, ssm/hybrid
recurrent-state slots (``--state-dtype int8`` stores the conv/SSM/mLSTM
state quantized under OSSH-static channel scales), encdec self-KV +
cross-KV slots. KV-cache knobs (repro.serving.paged, KV families):
``--kv-layout paged`` swaps the per-slot contiguous rows for the
block-pool cache (``--block-size`` tokens per block), ``--kv-dtype int8``
stores it quantized (~4x fewer KV bytes), ``--prefill-chunk N`` admits
prompts N tokens at a time so long prompts never stall the decode batch,
and ``--lazy-blocks`` grows block tables at decode time instead of
reserving max_new up front; ``--prefix-share`` turns on radix/COW prefix
sharing (``--shared-prefix N`` gives every request the same N-token
opener so the reuse shows) with ``--radix-capacity`` bounding the blocks
the index may pin; pool telemetry prints after the run.

Dispatch amortization (repro.serving.spec): ``--decode-steps N`` runs N
decode iterations per engine step inside one compiled scan (in-graph
EOS/budget masking); ``--spec-decode --spec-backend quaff@4 --spec-k 4``
turns on self-speculative decoding — draft tokens under the cheaper
backend over the SAME weights, one batched verify pass, greedy output
token-identical — and prints acceptance telemetry after the run.

Every knob lands in one ``serving.EngineConfig`` — the same dataclass
``api.QuaffModel.engine`` takes.
"""
from __future__ import annotations

import argparse
import dataclasses

import numpy as np

from repro import api
from repro import obs as OBS
from repro.configs import get_config
from repro.core.peft import PEFTConfig, n_prefix_tokens
from repro.data.pipeline import DataConfig, Loader
from repro.models.config import QuantConfig
from repro.serving import EngineConfig, GenerationRequest, SamplingParams


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--quant-mode", default="quaff")
    ap.add_argument("--load", default=None, metavar="DIR",
                    help="serve a QuaffModel.save checkpoint")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--mixed", action="store_true",
                    help="mixed prompt lengths + budgets (continuous-"
                         "batching showcase)")
    ap.add_argument("--kv-layout", default="contiguous",
                    choices=["contiguous", "paged"])
    ap.add_argument("--kv-dtype", default="fp", choices=["fp", "int8"],
                    help="paged only: int8 KV (per-channel key scales, "
                         "per-token value scales)")
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="paged only: admit prompts in chunks of N tokens")
    ap.add_argument("--lazy-blocks", action="store_true",
                    help="paged only: grow block tables at decode time "
                         "instead of reserving max_new up front")
    ap.add_argument("--prefix-share", action="store_true",
                    help="paged only: radix/COW prefix sharing — repeated "
                         "prompt prefixes map cached KV blocks instead of "
                         "re-prefilling")
    ap.add_argument("--radix-capacity", type=int, default=0,
                    help="max blocks the prefix index may pin "
                         "(0 = unbounded)")
    ap.add_argument("--shared-prefix", type=int, default=0, metavar="N",
                    help="give every request the same N-token opener "
                         "(prefix-share showcase workload)")
    ap.add_argument("--decode-steps", type=int, default=1,
                    help="run N decode iterations per engine step inside "
                         "one compiled scan (in-graph EOS/budget masking)")
    ap.add_argument("--spec-decode", action="store_true",
                    help="self-speculative decoding: draft under "
                         "--spec-backend, verify with one batched target "
                         "pass (greedy output is token-identical)")
    ap.add_argument("--spec-backend", default="",
                    help="draft backend, 'mode' or 'mode@bits' (e.g. "
                         "quaff@4); must share the target's weight_carrier")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft tokens per speculation cycle")
    ap.add_argument("--state-dtype", default="fp", choices=["fp", "int8"],
                    help="ssm/hybrid only: int8 recurrent-state slots "
                         "(OSSH-static per-channel scales)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--stream", action="store_true",
                    help="print per-token stream events for request 0")
    ap.add_argument("--trace-out", default=None, metavar="FILE",
                    help="write a Chrome trace-event JSON of the run "
                         "(load in Perfetto / chrome://tracing)")
    ap.add_argument("--metrics-out", default=None, metavar="FILE",
                    help="write a metrics snapshot (TTFT/ITL/queue/e2e "
                         "histograms + engine counters)")
    ap.add_argument("--metrics-fmt", default="json",
                    choices=["json", "prometheus"])
    args = ap.parse_args()

    if args.load:
        model = api.QuaffModel.load(args.load)
        cfg = model.cfg
        print(f"[init] loaded checkpoint {args.load} ({cfg.name}, "
              f"{cfg.quant.mode})")
    else:
        cfg = get_config(args.arch)
        if args.reduced:
            cfg = cfg.reduced()
        cfg = dataclasses.replace(cfg,
                                  quant=QuantConfig(mode=args.quant_mode),
                                  peft=PEFTConfig(method="lora", lora_rank=8))
        model = api.prepare(cfg)
        print(f"[init] {cfg.name} ({cfg.family}) mode={args.quant_mode}")

    # request queue: synthetic prompts, optionally mixed lengths/budgets
    loader = Loader(DataConfig(vocab_size=cfg.vocab_size,
                               seq_len=args.prompt_len,
                               batch_size=max(args.requests, 1)))
    prompts = np.asarray(loader.batch(0)["tokens"])
    if args.shared_prefix:
        n = min(args.shared_prefix, prompts.shape[1])
        prompts[:, :n] = prompts[0, :n]     # every request opens identically
    rng = np.random.RandomState(args.seed)

    reqs = []
    for i in range(args.requests):
        plen = args.prompt_len
        max_new = args.max_new
        if args.mixed:
            plen = int(rng.randint(max(4, args.prompt_len // 4),
                                   args.prompt_len + 1))
            max_new = int(rng.choice([args.max_new // 4, args.max_new]))
        sp = SamplingParams(temperature=args.temperature, top_k=args.top_k,
                            top_p=args.top_p, seed=args.seed + i)
        on_token = None
        if args.stream and i == 0:
            def on_token(rid, tok):
                print(f"[stream] {rid} -> {tok}")
        reqs.append(GenerationRequest(prompts[i][:plen], max_new_tokens=max_new,
                                      sampling=sp, on_token=on_token))

    # pool must fit prompt + PEFT virtual-token prefix + budget per slot;
    # every family rides the engine (the lockstep fallback is gone)
    n_prefix = n_prefix_tokens(cfg.peft)
    ecfg = EngineConfig(max_slots=args.slots,
                        max_seq_len=args.prompt_len + n_prefix
                        + args.max_new,
                        kv_layout=args.kv_layout, kv_dtype=args.kv_dtype,
                        block_size=args.block_size,
                        prefill_chunk=args.prefill_chunk,
                        state_dtype=args.state_dtype,
                        lazy_blocks=args.lazy_blocks,
                        prefix_share=args.prefix_share,
                        radix_capacity=args.radix_capacity,
                        decode_steps=args.decode_steps,
                        spec_decode=args.spec_decode,
                        spec_backend=args.spec_backend,
                        spec_k=args.spec_k)
    obs = None
    if args.trace_out or args.metrics_out:
        # metrics ride along whenever tracing is on (and vice versa isn't
        # forced) — the latency summary below needs the histograms
        obs = OBS.Obs.from_config(OBS.ObsConfig(
            trace_path=args.trace_out, metrics=True,
            metrics_path=args.metrics_out, metrics_fmt=args.metrics_fmt))
    engine = model.engine(ecfg, fresh=True, obs=obs)
    outs = engine.run(reqs)

    st = engine.stats
    lockstep_slot_steps = args.requests * max(
        r.max_new_tokens for r in reqs)  # lockstep pays max budget everywhere
    print(f"[serve] {args.requests} reqs over {args.slots} slots "
          f"({cfg.family}, pool seq {ecfg.max_seq_len}, kv {args.kv_layout}/"
          f"{args.kv_dtype}, state {st.state_dtype}, {cfg.name}, "
          f"{cfg.quant.mode})")
    print(f"prefill: {st.prefills} reqs in {st.prefill_batches} batched "
          f"calls, {st.prefill_time_s*1e3:.1f} ms")
    print(f"decode : {st.decode_steps} steps in {st.decode_time_s*1e3:.1f} ms "
          f"({st.decode_tokens_per_s:.0f} tok/s, occupancy "
          f"{st.occupancy:.0%})")
    print(f"slot-steps: {st.slot_steps} continuous vs "
          f"{lockstep_slot_steps} lockstep-equivalent")
    if st.spec_decode or st.scheduled_steps > 1:
        print(f"dispatch: {st.decode_dispatches} dispatches for "
              f"{st.decode_steps} steps "
              f"({st.steps_per_dispatch:.2f} steps/dispatch)")
    if st.spec_decode:
        print(f"spec: {st.spec_backend} k={st.spec_k} — "
              f"{st.accepted_tokens}/{st.draft_tokens} drafts accepted "
              f"({st.acceptance_rate:.0%})")
    if args.kv_layout == "paged":
        print(f"kv-pool: {st.peak_blocks_in_use}/{st.n_blocks} blocks peak "
              f"(x{st.block_size} tok), fragmentation "
              f"{st.mean_fragmentation:.0%}, "
              f"{st.kv_bytes_per_request/1024:.1f} KiB/req vs "
              f"{st.contiguous_bytes_per_request/1024:.1f} KiB contiguous "
              f"(saves {st.kv_bytes_saved_vs_contiguous/1024:.1f} KiB/req)")
        if st.lazy_blocks:
            print(f"lazy-blocks: {st.block_grows} grows, "
                  f"{st.block_stalls} stalls, {st.preemptions} preemptions, "
                  f"reserved-vs-used delta "
                  f"{st.lazy_blocks_saved_per_request:.1f} blocks/req")
        if st.prefix_share:
            print(f"prefix-share: {st.prefix_hits}/{st.prefix_queries} hits "
                  f"({st.prefix_hit_rate:.0%}), {st.prefix_tokens_saved} "
                  f"prefill tokens + {st.prefill_chunks_saved} chunk calls "
                  f"saved, {st.radix_blocks} blocks indexed "
                  f"({st.radix_evictions} evicted), {st.cow_copies} COW "
                  f"copies")
    elif cfg.family in ("ssm", "hybrid"):
        print(f"state-pool: {st.state_bytes_per_slot/1024:.1f} KiB/slot "
              f"({st.state_dtype}; fp equivalent "
              f"{st.fp_state_bytes_per_slot/1024:.1f} KiB)")
    if obs is not None and obs.metrics is not None:
        def pct(name, p):
            return obs.metrics.histogram(name).percentile(p) * 1e3
        print(f"latency : ttft p50 {pct('ttft_s', 50):.1f}ms / "
              f"p95 {pct('ttft_s', 95):.1f}ms — itl p50 "
              f"{pct('itl_s', 50):.1f}ms / p95 {pct('itl_s', 95):.1f}ms — "
              f"queue p95 {pct('queue_s', 95):.1f}ms — "
              f"e2e p95 {pct('e2e_s', 95):.1f}ms")
    for o in outs[:3]:
        print(f"  {o.request_id}: prompt {o.prompt_len} -> "
              f"{o.n_generated} tokens ({o.finish_reason}) "
              f"queue {o.queue_s*1e3:.1f}ms ttft {o.ttft_s*1e3:.1f}ms "
              f"e2e {o.e2e_s*1e3:.1f}ms "
              f"{o.token_ids[:8]}{'...' if o.n_generated > 8 else ''}")
    if obs is not None:
        for kind, path in obs.export().items():
            print(f"[obs] {kind} written to {path}")


if __name__ == "__main__":
    main()
