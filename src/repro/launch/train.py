"""Fault-tolerant fine-tuning launcher.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --steps 200 --reduced --quant-mode quaff --peft lora

Production behaviors (exercised at micro scale on CPU; identical code path
on a real cluster):
  * resume-from-latest checkpoint on startup (crash ⇒ relaunch ⇒ continue);
  * periodic + terminal checkpoints (atomic, keep-k, async writer);
  * heartbeat file (external watchdogs/monitors poll it — a missing beat is
    the node-failure signal that triggers relaunch);
  * straggler watchdog: steps slower than ``tolerance x`` the running median
    are logged with their step index (on a cluster this feeds the scheduler's
    hot-spare logic — here it surfaces contention);
  * elastic re-scaling: checkpoints are shard-agnostic (gathered host-side),
    so a restart may use a different mesh/batch — the state re-shards on
    device_put. ``--dp-only`` runs the shard_map data-parallel path with
    INT8-compressed gradient all-reduce (optim/compress.py).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro import obs as OBS
from repro.checkpoint.manager import CheckpointManager, config_fingerprint
from repro.configs import get_config
from repro.core.peft import PEFTConfig
from repro.data.pipeline import DataConfig, Loader, calibration_batches
from repro.models.config import QuantConfig, TrainConfig
from repro.train import steps as S


class StragglerWatchdog:
    def __init__(self, tolerance: float = 3.0, warmup: int = 3):
        self.tolerance = tolerance
        self.warmup = warmup
        self.times = []
        self.flagged = []

    def observe(self, step: int, dt: float):
        self.times.append(dt)
        if len(self.times) <= self.warmup:
            return False
        med = float(np.median(self.times[self.warmup:]))
        if dt > self.tolerance * med:
            self.flagged.append((step, dt, med))
            print(f"[watchdog] straggler step {step}: {dt*1e3:.1f}ms "
                  f"(median {med*1e3:.1f}ms)")
            return True
        return False


def heartbeat(path: str, step: int):
    with open(path, "w") as f:
        json.dump({"step": step, "time": time.time()}, f)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-scale reduced config of the same family")
    ap.add_argument("--quant-mode", default="quaff")
    ap.add_argument("--peft", default="lora")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=2e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--ckpt-dir", default="checkpoints/run")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--calib-batches", type=int, default=4)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--crash-at", type=int, default=0,
                    help="fault-injection: raise at this step (testing)")
    ap.add_argument("--ossh-monitor-every", type=int, default=0,
                    metavar="N",
                    help="every N steps, recompute the top-k outlier "
                         "channel sets and report Jaccard overlap vs the "
                         "calibration sets (OSSH drift; quantized modes "
                         "only)")
    ap.add_argument("--trace-out", default=None, metavar="FILE",
                    help="write a Chrome trace-event JSON of the run")
    ap.add_argument("--metrics-out", default=None, metavar="FILE",
                    help="write a metrics snapshot (step timing + OSSH "
                         "drift gauges)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = dataclasses.replace(
        cfg,
        quant=QuantConfig(mode=args.quant_mode),
        peft=PEFTConfig(method=args.peft, lora_rank=16),
    )
    tcfg = TrainConfig(microbatches=args.microbatches, remat=False,
                       learning_rate=args.lr,
                       grad_compression=args.grad_compression)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      batch_size=args.batch)

    # ---- weights preprocessing (paper §3.3): calibrate on fp32, convert
    print(f"[init] {cfg.name} ({cfg.family}) mode={args.quant_mode}")
    cfg_fp = dataclasses.replace(cfg, quant=dataclasses.replace(
        cfg.quant, mode="fp32"))
    model = api.prepare(cfg_fp, seed=tcfg.seed)
    if args.quant_mode != "fp32":
        model.calibrate(calibration_batches(dcfg, args.calib_batches))
        model.convert(args.quant_mode)
    frozen, adapters, qstate = model.frozen, model.adapters, model.quant_state

    state = S.init_train_state(adapters, qstate, tcfg)
    mgr = CheckpointManager(args.ckpt_dir, keep=3)
    # fingerprint the post-convert config: resume refuses a checkpoint
    # written by a run with a different arch/quant setup
    fp = config_fingerprint(api._cfg_to_dict(model.cfg))
    start = 0
    if mgr.latest_step() is not None:
        state, meta = mgr.restore(state, expect_fingerprint=fp)
        start = meta["step"]
        print(f"[resume] restored step {start} from {args.ckpt_dir}")

    step_fn = jax.jit(S.build_train_step(cfg, tcfg))
    loader = Loader(dcfg)
    watchdog = StragglerWatchdog()
    hb_path = os.path.join(args.ckpt_dir, "heartbeat.json")
    os.makedirs(args.ckpt_dir, exist_ok=True)

    obs = OBS.NULL_OBS
    if args.trace_out or args.metrics_out:
        obs = OBS.Obs.from_config(OBS.ObsConfig(
            trace_path=args.trace_out, metrics=True,
            metrics_path=args.metrics_out))
    monitor = None
    if args.ossh_monitor_every:
        if model.stats is None:
            print("[obs] --ossh-monitor-every ignored: no calibration "
                  "stats (fp32 mode has no outlier sets to drift)")
        else:
            monitor = OBS.DriftMonitor(
                frozen, cfg, model.stats,
                tokens=loader.batch(0)["tokens"],
                ratio=cfg.quant.outlier_ratio, obs=obs)

    for i in range(start, args.steps):
        if args.crash_at and i == args.crash_at:
            raise RuntimeError(f"fault injection at step {i}")
        t0 = obs.phase_begin("train_step", cat="train",
                             tid=OBS.TID_TRAIN, step=i)
        batch = jax.tree.map(jnp.asarray, loader.batch(i))
        state, metrics = step_fn(frozen, state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = obs.phase_end("train_step", t0, cat="train",
                           tid=OBS.TID_TRAIN, hist="train_step_s")
        watchdog.observe(i, dt)
        heartbeat(hb_path, i)
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"{dt*1e3:.0f}ms")
        if monitor is not None and (i + 1) % args.ossh_monitor_every == 0:
            with obs.span("ossh_monitor", cat="train", tid=OBS.TID_TRAIN,
                          step=i):
                drifts = monitor.observe(state.adapters, state.quant,
                                         step=i)
            print(OBS.format_report(drifts, step=i))
        if (i + 1) % args.ckpt_every == 0:
            mgr.save(i + 1, state, {"arch": cfg.name,
                                    "config_fingerprint": fp})
    mgr.save(args.steps, state, {"arch": cfg.name, "final": True,
                                 "config_fingerprint": fp})
    mgr.wait()
    for kind, path in obs.export().items():
        print(f"[obs] {kind} written to {path}")
    print(f"[done] {args.steps} steps; stragglers flagged: "
          f"{len(watchdog.flagged)}; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
