import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count on first init). Everything below is the multi-pod dry-run driver:
# lower + compile every (architecture x input-shape x mesh) cell, print
# memory_analysis/cost_analysis, and record roofline inputs to JSON.
#
# Usage:
#   python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
#   python -m repro.launch.dryrun --arch ... --shape ... --multi-pod
#   python -m repro.launch.dryrun --all [--multi-pod] [--jobs 1]
#   python -m repro.launch.dryrun --all --both   # 1-pod and 2-pod passes
#
# --all re-execs itself one subprocess per cell so each compile starts from
# a clean XLA state (and a crash in one cell cannot take down the sweep —
# the sweep is restartable: finished cells are skipped via their JSON).
import argparse
import dataclasses
import json
import subprocess
import sys
import time
import warnings

warnings.filterwarnings("ignore")

import jax

from repro.configs import ASSIGNED, get_config
from repro.launch import hloparse, shardings, specs
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.models.config import SHAPES, TrainConfig
from repro.runtime.pspec import use_rules
from repro.train import steps as STEPS

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def cell_list(multi_pod: bool):
    cells = []
    for arch in ASSIGNED:
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            if sname == "long_500k" and not M.supports_long_context(cfg):
                continue  # full-attention archs skip long-context decode
            cells.append((arch, sname))
    return cells


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             seq_shard: bool = False, microbatches: int = 0,
             bwd_bf16: bool = False, logits_bf16: bool = False,
             remat_policy: str = "nothing", int8_dispatch: bool = False,
             kv_batch_only: bool = False, tag: str = "") -> dict:
    import math

    from repro.launch.mesh import axis_size, dp_axes

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    if cfg.n_experts:
        # GShard grouping: one routing group per data shard
        dp_total = math.prod(axis_size(mesh, a) for a in dp_axes(mesh))
        cfg = dataclasses.replace(cfg, moe_groups=dp_total)
    if bwd_bf16:
        cfg = dataclasses.replace(cfg, quant=dataclasses.replace(
            cfg.quant, bwd_int8=False))
    if logits_bf16:
        cfg = dataclasses.replace(cfg, logits_fp32=False)
    if int8_dispatch:
        cfg = dataclasses.replace(cfg, moe_int8_dispatch=True)
    t0 = time.time()

    mb = microbatches or specs.default_microbatches(cfg, shape, mesh)
    tcfg = TrainConfig(microbatches=mb, remat=True, remat_policy=remat_policy)
    rules = shardings.build_rules(cfg, mesh, shape, seq_shard=seq_shard,
                                  kv_batch_only=kv_batch_only)

    frozen_a, adapters_a, qstate_a = specs.model_specs(cfg)
    frozen_sh = shardings.frozen_shardings(frozen_a, cfg, mesh)

    with jax.set_mesh(mesh), use_rules(rules):
        if shape.kind == "train":
            state_a = specs.state_specs(adapters_a, qstate_a, tcfg)
            state_sh = shardings.replicated_shardings(state_a, mesh)
            batch_a = specs.batch_specs(cfg, shape, with_labels=True)
            batch_sh = shardings.batch_shardings(batch_a, mesh)
            step = STEPS.build_train_step(cfg, tcfg)
            jitted = jax.jit(step, in_shardings=(frozen_sh, state_sh, batch_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(frozen_a, state_a, batch_a)
        elif shape.kind == "prefill":
            batch_a = specs.batch_specs(cfg, shape, with_labels=False)
            batch_sh = shardings.batch_shardings(batch_a, mesh)
            repl = shardings.replicated_shardings
            step = STEPS.build_prefill(cfg)
            jitted = jax.jit(step, in_shardings=(
                frozen_sh, repl(adapters_a, mesh), repl(qstate_a, mesh),
                batch_sh))
            lowered = jitted.lower(frozen_a, adapters_a, qstate_a, batch_a)
        else:  # decode
            d = specs.decode_specs(cfg, shape)
            cache_sh = shardings.cache_shardings(d["caches"], cfg, mesh,
                                                 kv_batch_only)
            repl = shardings.replicated_shardings
            step = STEPS.build_decode(cfg)
            jitted = jax.jit(step, in_shardings=(
                frozen_sh, repl(adapters_a, mesh), repl(qstate_a, mesh),
                cache_sh,
                shardings.batch_shardings(d["token"], mesh),
                jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())),
                donate_argnums=(3,))
            lowered = jitted.lower(frozen_a, adapters_a, qstate_a,
                                   d["caches"], d["token"], d["pos"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    print(f"memory_analysis: args={mem.argument_size_in_bytes/1e9:.3f}GB "
          f"out={mem.output_size_in_bytes/1e9:.3f}GB "
          f"temp={mem.temp_size_in_bytes/1e9:.3f}GB "
          f"alias={mem.alias_size_in_bytes/1e9:.3f}GB  (per device)")
    ca = compiled.cost_analysis() or {}
    print(f"cost_analysis: flops={ca.get('flops', 0):.3e} "
          f"bytes={ca.get('bytes accessed', 0):.3e} (per device, no trip counts)")

    hlo_text = compiled.as_text()
    summary = hloparse.analyze(hlo_text)
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": 512 if multi_pod else 256,
        "kind": shape.kind,
        "microbatches": mb,
        "seq_shard": seq_shard,
        "variant": tag or "baseline",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "cost_analysis": {k: ca.get(k) for k in ("flops", "bytes accessed")},
        "hlo": summary.to_json(),
        "param_bytes_total": specs.param_bytes(frozen_a),
        "model_flops_per_token": specs.model_flops_per_token(
            cfg, shape.kind == "train"),
        "model_flops_per_step": specs.model_flops_per_step(cfg, shape),
        "tokens_per_step": (shape.global_batch * shape.seq_len
                            if shape.kind != "decode" else shape.global_batch),
    }
    os.makedirs(out_dir, exist_ok=True)
    mesh_tag = "2pod" if multi_pod else "1pod"
    suffix = f"__{tag}" if tag else ("__ss" if seq_shard else "")
    path = os.path.join(out_dir,
                        f"{arch}__{shape_name}__{mesh_tag}{suffix}.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    try:
        import zstandard
        with open(path.replace(".json", ".hlo.zst"), "wb") as f:
            f.write(zstandard.ZstdCompressor(level=9).compress(
                hlo_text.encode()))
    except Exception:
        pass
    print(f"wrote {path}")
    print(f"collectives: { {k: f'{v/1e9:.3f}GB' for k, v in summary.collective_bytes.items()} }")
    print(f"dot flops int8={summary.dot_flops_int8:.3e} "
          f"float={summary.dot_flops_float:.3e} hbm={summary.hbm_bytes:.3e}B")
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both", action="store_true",
                    help="with --all: run 1-pod and 2-pod passes")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--bwd-bf16", action="store_true")
    ap.add_argument("--logits-bf16", action="store_true")
    ap.add_argument("--int8-dispatch", action="store_true")
    ap.add_argument("--kv-batch-only", action="store_true")
    ap.add_argument("--remat-policy", default="nothing")
    ap.add_argument("--tag", default="")
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--out", default=os.path.abspath(OUT_DIR))
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=int, default=3000)
    args = ap.parse_args()

    if args.all:
        pods = [False, True] if args.both else [args.multi_pod]
        failures = []
        for mp in pods:
            for arch, sname in cell_list(mp):
                tag = "2pod" if mp else "1pod"
                path = os.path.join(args.out, f"{arch}__{sname}__{tag}.json")
                if os.path.exists(path) and not args.force:
                    print(f"skip {arch} {sname} {tag} (done)")
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", sname, "--out", args.out]
                if mp:
                    cmd.append("--multi-pod")
                print(f"=== {arch} x {sname} [{tag}] ===", flush=True)
                try:
                    r = subprocess.run(cmd, timeout=args.timeout)
                    if r.returncode != 0:
                        failures.append((arch, sname, tag, r.returncode))
                except subprocess.TimeoutExpired:
                    failures.append((arch, sname, tag, "timeout"))
        print(f"\nDONE. failures: {failures}")
        sys.exit(1 if failures else 0)

    run_cell(args.arch, args.shape, args.multi_pod, args.out,
             seq_shard=args.seq_shard, microbatches=args.microbatches,
             bwd_bf16=args.bwd_bf16, logits_bf16=args.logits_bf16,
             remat_policy=args.remat_policy, int8_dispatch=args.int8_dispatch,
             kv_batch_only=args.kv_batch_only, tag=args.tag)


if __name__ == "__main__":
    main()
