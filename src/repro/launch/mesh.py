"""Production mesh builders. A FUNCTION, not a module-level constant, so
importing this module never touches jax device state (required for smoke
tests that must see 1 device)."""
from __future__ import annotations

import math
from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod ("data","model"); 2 pods = 512 chips with a
    leading "pod" axis. Requires XLA_FLAGS=--xla_force_host_platform_device_count=512
    to be set before jax initializes (dryrun.py does this on lines 1-2)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)}; "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax")
    return jax.make_mesh(
        shape, axes, devices=devices[:n],
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_test_mesh(shape: Tuple[int, ...] = (2, 2),
                   axes: Tuple[str, ...] = ("data", "model")):
    """Small mesh for in-process sharding tests (8 forced host devices)."""
    n = math.prod(shape)
    return jax.make_mesh(
        shape, axes, devices=jax.devices()[:n],
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def dp_axes(mesh) -> Tuple[str, ...]:
    """Batch-parallel axes: everything except "model"."""
    return tuple(a for a in mesh.axis_names if a != "model")


def axis_size(mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape))[name]
