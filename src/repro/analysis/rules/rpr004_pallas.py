"""RPR004 — Pallas kernel-wrapper contracts.

Every ``pl.pallas_call`` wrapper in this repo must uphold three local
contracts that only explode on real TPUs (CPU CI runs interpret mode):

  1. the ``interpret=`` flag must be routed through
     ``kernels.common.interpret_mode`` so ``REPRO_PALLAS_INTERPRET=1``
     (the switch CI flips) reaches every kernel — a missing or ad-hoc
     flag silently compiles Mosaic on runners that can't;
  2. a wrapper that derives its grid with floor division must guard
     divisibility (``fit_block`` or a ``%``-based assert/raise) — a
     truncated grid silently drops tail blocks;
  3. matmul kernels must not accumulate in a narrow float: VMEM scratch
     accumulators feeding a dot must be f32 (or i32 for integer GEMMs).
"""

from __future__ import annotations

import ast
from typing import Optional

from repro.analysis.context import ModuleContext
from repro.analysis.registry import Rule, register
from repro.analysis.rules.common import PALLAS_CALL

INTERPRET_MODE_SUFFIX = ".interpret_mode"
NARROW_FLOATS = ("bfloat16", "float16")


def _is_interpret_mode_call(ctx: ModuleContext, node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    qn = ctx.call_qualname(node)
    return qn is not None and (
        qn == "interpret_mode" or qn.endswith(INTERPRET_MODE_SUFFIX)
    )


def _interpret_routed(ctx: ModuleContext, call: ast.Call, kw: ast.keyword) -> bool:
    """True when ``interpret=`` is fed by ``interpret_mode(...)`` — directly
    or through a name assigned from it in the enclosing function."""
    if _is_interpret_mode_call(ctx, kw.value):
        return True
    if not isinstance(kw.value, ast.Name):
        return False
    fn = ctx.enclosing_function(call)
    scope: ast.AST = fn if fn is not None else ctx.tree
    for node in ast.walk(scope):
        if not isinstance(node, ast.Assign):
            continue
        if any(
            isinstance(t, ast.Name) and t.id == kw.value.id for t in node.targets
        ) and _is_interpret_mode_call(ctx, node.value):
            return True
    return False


def _has_divisibility_guard(ctx: ModuleContext, scope: ast.AST) -> bool:
    """``fit_block(...)`` anywhere, or a ``%`` inside an assert / raise-y
    if-test, counts as guarding the grid arithmetic."""
    def has_mod(expr: ast.AST) -> bool:
        return any(
            isinstance(sub, ast.BinOp) and isinstance(sub.op, ast.Mod)
            for sub in ast.walk(expr)
        )

    for node in ast.walk(scope):
        if isinstance(node, ast.Call):
            qn = ctx.call_qualname(node)
            if qn is not None and qn.split(".")[-1] == "fit_block":
                return True
        if isinstance(node, ast.Assert) and has_mod(node.test):
            return True
        if (
            isinstance(node, ast.If)
            and any(isinstance(s, ast.Raise) for s in node.body)
            and has_mod(node.test)
        ):
            return True
    return False


def _grid_uses_floordiv(call: ast.Call, scope: ast.AST) -> Optional[ast.AST]:
    """The offending node when the wrapper computes grid-ish values with
    ``//`` — either inline in the grid keyword or anywhere in the scope
    feeding a grid/BlockSpec expression (approximated as: any ``//`` in the
    wrapper scope when a grid kwarg is present)."""
    has_grid = any(kw.arg in ("grid", "grid_spec") for kw in call.keywords)
    if not has_grid:
        return None
    for node in ast.walk(scope):
        if isinstance(node, ast.BinOp) and isinstance(node.op, ast.FloorDiv):
            return node
    return None


def _kernel_body(ctx: ModuleContext, call: ast.Call):
    """The FunctionDef of the kernel passed as first argument (possibly
    through functools.partial), when it lives in this module."""
    if not call.args:
        return None
    inner, _ = ctx.unwrap_partial(call.args[0])
    if isinstance(inner, ast.Name):
        for fn in ctx.functions():
            if fn.name == inner.id:
                return fn
    if isinstance(inner, ast.Lambda):
        return inner
    return None


def _has_dot(body: ast.AST, ctx: ModuleContext) -> bool:
    for node in ast.walk(body):
        if isinstance(node, ast.BinOp) and isinstance(node.op, ast.MatMult):
            return True
        if isinstance(node, ast.Call):
            qn = ctx.call_qualname(node)
            if qn is not None and qn.split(".")[-1] in ("dot_general", "dot", "matmul"):
                return True
    return False


@register
class PallasKernelContracts(Rule):
    rule_id = "RPR004"
    severity = "error"
    description = (
        "pallas_call contracts: interpret routed via kernels.common."
        "interpret_mode, grid floor-division guarded, matmul accumulators "
        "not narrow-float"
    )

    def check_module(self, ctx: ModuleContext):
        for call in ctx.calls():
            qn = ctx.call_qualname(call)
            if qn != PALLAS_CALL:
                continue
            yield from self._check_interpret(ctx, call)
            yield from self._check_grid(ctx, call)
            yield from self._check_accumulators(ctx, call)

    def _check_interpret(self, ctx, call):
        kw = next((k for k in call.keywords if k.arg == "interpret"), None)
        if kw is None:
            yield self.finding(
                ctx,
                call,
                "pallas_call without interpret=: pass interpret="
                "interpret_mode(requested) (kernels/common.py) so "
                "REPRO_PALLAS_INTERPRET=1 reaches this kernel on CPU CI",
            )
        elif not _interpret_routed(ctx, call, kw):
            yield self.finding(
                ctx,
                kw.value,
                "interpret= must be routed through kernels.common."
                "interpret_mode(...) — an ad-hoc flag ignores the "
                "REPRO_PALLAS_INTERPRET CI override",
            )

    def _check_grid(self, ctx, call):
        fn = ctx.enclosing_function(call)
        scope = fn if fn is not None else ctx.tree
        offender = _grid_uses_floordiv(call, scope)
        if offender is not None and not _has_divisibility_guard(ctx, scope):
            yield self.finding(
                ctx,
                offender,
                "grid computed with // but no divisibility guard in the "
                "wrapper: a non-dividing block size silently drops tail "
                "elements — use fit_block() or assert dim % block == 0",
            )

    def _check_accumulators(self, ctx, call):
        body = _kernel_body(ctx, call)
        if body is None or not _has_dot(body, ctx):
            return
        for kw in call.keywords:
            if kw.arg != "scratch_shapes":
                continue
            for node in ast.walk(kw.value):
                if not isinstance(node, ast.Call):
                    continue
                qn = ctx.call_qualname(node)
                if qn is None or qn.split(".")[-1] not in ("VMEM", "SMEM"):
                    continue
                if len(node.args) < 2:
                    continue
                dt = ctx.qualname(node.args[1])
                if dt is not None and dt.split(".")[-1] in NARROW_FLOATS:
                    yield self.finding(
                        ctx,
                        node.args[1],
                        f"matmul kernel accumulates in {dt.split('.')[-1]}: "
                        "VMEM accumulator scratch must be f32 (or i32 for "
                        "integer GEMMs) — narrow-float accumulation loses "
                        "the epilogue's precision",
                    )
