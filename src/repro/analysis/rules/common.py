"""Shared trace-discovery helpers used by the jit/tracer/Pallas rules.

"Traced" means the function body runs under a JAX trace: either the
function is decorated with a transform (``@jax.jit``,
``@functools.partial(jax.jit, ...)``) or it is passed by name/lambda/
partial into a transform or control-flow combinator (``jax.lax.scan``,
``pl.pallas_call``, ...). Keyword-only parameters are treated as static:
every in-tree idiom binds them at trace time (jit ``static_argnames``,
``functools.partial`` closure for kernel bodies).
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.context import FunctionNode, ModuleContext

JIT_QUALNAMES = frozenset({"jax.jit", "jax.pjit", "jax.experimental.pjit.pjit"})

#: transforms whose function argument(s) get traced
TRACE_WRAPPER_QUALNAMES = frozenset(
    {
        "jax.jit",
        "jax.pjit",
        "jax.vmap",
        "jax.pmap",
        "jax.grad",
        "jax.value_and_grad",
        "jax.checkpoint",
        "jax.remat",
        "jax.custom_vjp",
        "jax.custom_jvp",
        "jax.lax.scan",
        "jax.lax.cond",
        "jax.lax.switch",
        "jax.lax.while_loop",
        "jax.lax.fori_loop",
        "jax.lax.map",
        "jax.lax.associative_scan",
        "jax.experimental.pallas.pallas_call",
    }
)

PALLAS_CALL = "jax.experimental.pallas.pallas_call"


def is_jit_call(ctx: ModuleContext, node: ast.AST) -> bool:
    return isinstance(node, ast.Call) and ctx.call_qualname(node) in JIT_QUALNAMES


def static_argnames_from_keywords(kws: List[ast.keyword]) -> Set[str]:
    """String literals named by a ``static_argnames=`` keyword."""
    names: Set[str] = set()
    for kw in kws:
        if kw.arg != "static_argnames":
            continue
        v = kw.value
        if isinstance(v, ast.Constant) and isinstance(v.value, str):
            names.add(v.value)
        elif isinstance(v, (ast.Tuple, ast.List, ast.Set)):
            for elt in v.elts:
                if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                    names.add(elt.value)
    return names


def jit_decoration(
    ctx: ModuleContext, fn: FunctionNode
) -> Optional[Tuple[ast.AST, Set[str]]]:
    """(decorator_node, static_argnames) when ``fn`` is jit-decorated
    directly or through ``functools.partial(jax.jit, ...)``; else None."""
    for deco in fn.decorator_list:
        if ctx.qualname(deco) in JIT_QUALNAMES:
            return deco, set()
        if isinstance(deco, ast.Call):
            inner, kws = ctx.unwrap_partial(deco)
            if ctx.qualname(inner) in JIT_QUALNAMES:
                return deco, static_argnames_from_keywords(kws + deco.keywords)
            if ctx.call_qualname(deco) in JIT_QUALNAMES:
                return deco, static_argnames_from_keywords(deco.keywords)
    return None


def _functions_by_name(ctx: ModuleContext) -> Dict[str, List[FunctionNode]]:
    by_name: Dict[str, List[FunctionNode]] = {}
    for fn in ctx.functions():
        by_name.setdefault(fn.name, []).append(fn)
    return by_name


def resolve_function_arg(
    ctx: ModuleContext, node: ast.AST, by_name: Dict[str, List[FunctionNode]]
) -> List[ast.AST]:
    """Function bodies named by an argument expression: a bare Name
    resolving to a local def, a lambda, or either wrapped in partial."""
    node, _ = ctx.unwrap_partial(node)
    if isinstance(node, ast.Lambda):
        return [node]
    if isinstance(node, ast.Name):
        return list(by_name.get(node.id, ()))
    return []


def traced_functions(ctx: ModuleContext) -> Dict[ast.AST, Set[str]]:
    """All function/lambda nodes whose body runs under a trace, mapped to
    the set of parameter names that are static at trace time."""
    traced: Dict[ast.AST, Set[str]] = {}
    by_name = _functions_by_name(ctx)

    def add(fn: ast.AST, static: Set[str]):
        prev = traced.setdefault(fn, set(static))
        prev.update(static)

    for fn in ctx.functions():
        deco = jit_decoration(ctx, fn)
        if deco is not None:
            add(fn, deco[1])

    for call in ctx.calls():
        qn = ctx.call_qualname(call)
        if qn not in TRACE_WRAPPER_QUALNAMES:
            continue
        static = static_argnames_from_keywords(call.keywords)
        for arg in call.args:
            for fn in resolve_function_arg(ctx, arg, by_name):
                add(fn, static)

    # keyword-only params are bound at trace time in every in-tree idiom
    for fn, static in traced.items():
        args = fn.args
        static.update(a.arg for a in args.kwonlyargs)
    return traced


def positional_param_names(fn: ast.AST) -> List[str]:
    args = fn.args
    names = [a.arg for a in args.posonlyargs + args.args]
    if names and names[0] in ("self", "cls"):
        names = names[1:]
    return names
