"""RPR005 — quant-scale flow.

A quantized integer carrier is meaningless without its step size: a value
produced by ``quantize``/``quantize_grouped`` (which return an
``(int_carrier, delta)`` pair) or ``pack_int4``/``unpack_int4`` must not
reach a matmul-like consumer in a scope that never applies a scale. The
classic silent failure: unpack nibbles, feed the raw int carrier to a
GEMM, forget ``w_delta`` — numerically plausible garbage at int magnitude.

Module-convention type-flow pass, per function scope:

  * carriers = names bound from a producer call (tuple unpacking tracked,
    so the companion delta name is known), propagated through
    ``.reshape``/``.astype``/``.transpose`` chains and plain aliasing;
  * consumers = ``dot_general``/``dot``/``matmul``/``einsum``/
    ``int_matmul``/anything named ``*matmul*``, and the ``@`` operator;
  * a carrier reaching a consumer is flagged when its companion delta is
    never referenced again in the scope (it "escaped without its scale"),
    or — for companion-less carriers from pack/unpack — when no scale-ish
    name (``*delta*``/``*scale*``) appears anywhere in the scope.
"""

from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.context import ModuleContext
from repro.analysis.registry import Rule, register

PRODUCER_PAIR = frozenset({"quantize", "quantize_grouped"})
PRODUCER_SINGLE = frozenset(
    {"pack_int4", "pack_int4_pallas", "unpack_int4", "unpack_int4_pallas"}
)
CONSUMER_NAMES = frozenset({"dot_general", "dot", "matmul", "einsum", "int_matmul"})
PASSTHROUGH_METHODS = frozenset({"reshape", "astype", "transpose", "swapaxes"})
SCALEISH = re.compile(r"delta|scale", re.IGNORECASE)


def _last_seg(qn: Optional[str]) -> str:
    return qn.split(".")[-1] if qn else ""


def _is_consumer(ctx: ModuleContext, call: ast.Call) -> bool:
    name = _last_seg(ctx.call_qualname(call))
    return name in CONSUMER_NAMES or "matmul" in name


def _scopes(ctx: ModuleContext):
    yield ctx.tree
    yield from ctx.functions()


def _own_statements(ctx: ModuleContext, scope: ast.AST) -> List[ast.stmt]:
    """Statements of this scope only (nested defs are their own scopes)."""
    out: List[ast.stmt] = []

    def visit(stmts):
        for s in stmts:
            if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
                continue
            out.append(s)
            for field in ("body", "orelse", "finalbody"):
                visit(getattr(s, field, []) or [])
            for h in getattr(s, "handlers", []) or []:
                visit(h.body)

    visit(scope.body)
    return out


@register
class QuantScaleFlow(Rule):
    rule_id = "RPR005"
    severity = "error"
    description = (
        "an int carrier from quantize*/pack_int4 reaches a matmul-like "
        "consumer in a scope that never applies its scale"
    )

    def check_module(self, ctx: ModuleContext):
        for scope in _scopes(ctx):
            yield from self._check_scope(ctx, scope)

    def _check_scope(self, ctx: ModuleContext, scope: ast.AST):
        stmts = _own_statements(ctx, scope)
        carriers: Dict[str, Optional[str]] = {}  # carrier name -> delta name
        produced_at: Dict[str, int] = {}

        # pass 1: producer assignments + carrier propagation
        for stmt in stmts:
            if not isinstance(stmt, ast.Assign) or not isinstance(stmt.value, ast.Call):
                continue
            call = stmt.value
            name = _last_seg(ctx.call_qualname(call))
            tgt = stmt.targets[0]
            if name in PRODUCER_PAIR and isinstance(tgt, (ast.Tuple, ast.List)):
                elts = tgt.elts
                if (
                    len(elts) >= 2
                    and isinstance(elts[0], ast.Name)
                    and isinstance(elts[1], ast.Name)
                ):
                    carriers[elts[0].id] = elts[1].id
                    produced_at[elts[0].id] = stmt.lineno
            elif name in PRODUCER_SINGLE and isinstance(tgt, ast.Name):
                carriers[tgt.id] = None
                produced_at[tgt.id] = stmt.lineno
            elif isinstance(tgt, ast.Name):
                src = self._passthrough_source(call)
                if src is not None and src in carriers:
                    carriers[tgt.id] = carriers[src]
                    produced_at[tgt.id] = stmt.lineno

        if not carriers:
            return

        # pass 2: name loads (for "is the scale ever applied?")
        loads: Dict[str, int] = {}
        scaleish_seen = False
        for stmt in stmts:
            for node in ast.walk(stmt):
                if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
                    loads[node.id] = loads.get(node.id, 0) + 1
                    if SCALEISH.search(node.id):
                        scaleish_seen = True

        # pass 3: consumers
        for stmt in stmts:
            for node in ast.walk(stmt):
                hits: List[Tuple[str, ast.AST]] = []
                if isinstance(node, ast.Call) and _is_consumer(ctx, node):
                    for arg in node.args:
                        c = self._carrier_of(arg, carriers)
                        if c is not None:
                            hits.append((c, node))
                elif isinstance(node, ast.BinOp) and isinstance(node.op, ast.MatMult):
                    for side in (node.left, node.right):
                        c = self._carrier_of(side, carriers)
                        if c is not None:
                            hits.append((c, node))
                for carrier, site in hits:
                    yield from self._judge(
                        ctx, scope, carrier, carriers[carrier], site, loads, scaleish_seen
                    )

    @staticmethod
    def _passthrough_source(call: ast.Call) -> Optional[str]:
        """``x.reshape(...)`` / ``x.astype(...)`` chains keep carrier-ness."""
        func = call.func
        while isinstance(func, ast.Attribute):
            if func.attr in PASSTHROUGH_METHODS:
                base = func.value
                while isinstance(base, ast.Call):  # x.reshape(..).astype(..)
                    if not isinstance(base.func, ast.Attribute):
                        return None
                    base = base.func.value
                if isinstance(base, ast.Name):
                    return base.id
            return None
        return None

    @staticmethod
    def _carrier_of(expr: ast.AST, carriers: Dict[str, Optional[str]]) -> Optional[str]:
        """Carrier name when ``expr`` is a carrier or a passthrough-method
        chain rooted at one."""
        node = expr
        while True:
            if isinstance(node, ast.Name):
                return node.id if node.id in carriers else None
            if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
                if node.func.attr in PASSTHROUGH_METHODS:
                    node = node.func.value
                    continue
                return None
            if isinstance(node, ast.Attribute):
                node = node.value
                continue
            return None

    def _judge(self, ctx, scope, carrier, delta, site, loads, scaleish_seen):
        if delta is not None:
            # companion known: the delta must be referenced somewhere beyond
            # its own unpacking, else the carrier escaped scale-less
            if loads.get(delta, 0) == 0:
                yield self.finding(
                    ctx,
                    site,
                    f"int carrier {carrier!r} feeds a matmul but its scale "
                    f"{delta!r} is never applied in this scope — the result "
                    "is at raw integer magnitude",
                )
        elif not scaleish_seen:
            yield self.finding(
                ctx,
                site,
                f"int carrier {carrier!r} (pack/unpack product) feeds a "
                "matmul in a scope with no *delta*/*scale* name in sight — "
                "quantized values must travel with their scales",
            )
