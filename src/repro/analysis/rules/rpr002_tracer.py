"""RPR002 — tracer safety.

Inside a traced function, Python-level control flow on traced values
(``if``/``while``/``assert`` on an array argument) raises a
``TracerBoolConversionError`` at best and silently bakes in a branch at
worst; ``print`` executes at trace time (once), not at run time; and
mutating a closed-over Python container is a side effect the trace replays
never see. Static parameters (``static_argnames``, keyword-only params
bound via ``functools.partial``) are concrete Python values and are fine
to branch on — the rule exempts them.

Flags, inside any traced function (see ``rules.common.traced_functions``):
  * ``print(...)`` — use ``jax.debug.print`` / ``pl.debug_print``;
  * ``if``/``while``/``assert`` whose test references a non-static
    positional parameter directly by name;
  * ``.append``/``.extend``/``.add``/``.update``/``.insert``/``.pop``
    on a name not local to the traced function (closure mutation).
"""

from __future__ import annotations

import ast
from typing import Set

from repro.analysis.context import ModuleContext
from repro.analysis.registry import Rule, register
from repro.analysis.rules.common import positional_param_names, traced_functions

MUTATORS = frozenset({"append", "extend", "insert", "add", "update", "pop", "remove"})


def _local_names(fn: ast.AST) -> Set[str]:
    """Parameter names plus every name assigned anywhere in the body."""
    args = fn.args
    names = {a.arg for a in args.posonlyargs + args.args + args.kwonlyargs}
    if args.vararg:
        names.add(args.vararg.arg)
    if args.kwarg:
        names.add(args.kwarg.arg)
    for node in ast.walk(fn):
        if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            targets = node.targets if isinstance(node, ast.Assign) else [node.target]
            for tgt in targets:
                for sub in ast.walk(tgt):
                    if isinstance(sub, ast.Name):
                        names.add(sub.id)
        elif isinstance(node, (ast.For, ast.AsyncFor)):
            for sub in ast.walk(node.target):
                if isinstance(sub, ast.Name):
                    names.add(sub.id)
        elif isinstance(node, ast.withitem) and node.optional_vars is not None:
            for sub in ast.walk(node.optional_vars):
                if isinstance(sub, ast.Name):
                    names.add(sub.id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            names.add(node.name)
    return names


def _own_nodes(ctx: ModuleContext, fn: ast.AST):
    """Nodes of ``fn``'s body excluding nested function/class bodies —
    nested defs are separate (possibly untraced) scopes."""
    for node in ast.walk(fn):
        if node is fn:
            continue
        skip = False
        for anc in ctx.ancestors(node):
            if anc is fn:
                break
            if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda, ast.ClassDef)):
                skip = True
                break
        if not skip:
            yield node


@register
class TracerSafety(Rule):
    rule_id = "RPR002"
    severity = "error"
    description = (
        "Python control flow / print / closure mutation on traced values "
        "inside a jitted, scanned, or Pallas-called function"
    )

    def check_module(self, ctx: ModuleContext):
        for fn, static in traced_functions(ctx).items():
            suspect = {p for p in positional_param_names(fn) if p not in static}
            locals_ = _local_names(fn)
            for node in _own_nodes(ctx, fn):
                if isinstance(node, ast.Call):
                    yield from self._check_call(ctx, node, locals_)
                elif isinstance(node, (ast.If, ast.While, ast.Assert)):
                    yield from self._check_branch(ctx, node, suspect)

    def _check_call(self, ctx, call: ast.Call, locals_: Set[str]):
        if isinstance(call.func, ast.Name) and call.func.id == "print":
            if "print" not in locals_:
                yield self.finding(
                    ctx,
                    call,
                    "print() inside a traced function runs once at trace time, "
                    "not per step — use jax.debug.print / pl.debug_print",
                )
            return
        if (
            isinstance(call.func, ast.Attribute)
            and call.func.attr in MUTATORS
            and isinstance(call.func.value, ast.Name)
            and call.func.value.id not in locals_
        ):
            yield self.finding(
                ctx,
                call,
                f"mutating closed-over {call.func.value.id!r} with "
                f".{call.func.attr}() inside a traced function is a Python "
                "side effect: it runs at trace time only and is invisible to "
                "replayed executions",
            )

    def _check_branch(self, ctx, node, suspect: Set[str]):
        kind = {ast.If: "if", ast.While: "while", ast.Assert: "assert"}[type(node)]
        test = node.test
        for sub in ast.walk(test):
            if isinstance(sub, ast.Name) and sub.id in suspect:
                # x.shape / x.ndim / x.dtype are concrete under tracing
                parent = ctx.parent(sub)
                if isinstance(parent, ast.Attribute) and parent.attr in (
                    "shape",
                    "ndim",
                    "dtype",
                    "size",
                ):
                    continue
                # `key in pytree_param` is membership over static dict
                # structure (e.g. state.py's scale dicts), not a tracer read
                if (
                    isinstance(parent, ast.Compare)
                    and sub in parent.comparators
                    and any(isinstance(op, (ast.In, ast.NotIn)) for op in parent.ops)
                ):
                    continue
                yield self.finding(
                    ctx,
                    node,
                    f"`{kind}` on traced parameter {sub.id!r}: concretization "
                    "of a tracer — use jax.lax.cond/select (or mark the "
                    "argument static) instead of Python control flow",
                )
                break
