"""RPR009 — every public kernel wrapper must have an interpret-mode test.

Pallas kernels only execute on an accelerator (or under ``interpret=True``
on CPU), so a kernel wrapper without an interpret-mode test is code CI
never runs: grid math, BlockSpec index maps, and scratch sizing can all be
wrong and the suite stays green until someone lands on real hardware. The
repo's convention is that each public wrapper takes an ``interpret``
flag and at least one test calls it with ``interpret=True`` so the full
kernel body runs in CI's CPU job.

Project pass:

  * kernel modules = any analyzed file with a ``kernels`` directory
    segment in its path (``src/repro/kernels/``,
    ``src/repro/serving/paged/kernels/``);
  * targets = public module-level functions there that accept a
    parameter literally named ``interpret`` (private ``_helpers``,
    ``*_auto`` dispatchers without the flag, and pure-jnp references
    are naturally excluded);
  * coverage = a call in any test module (``test_*.py`` basename or a
    ``tests`` path segment) passing the literal keyword
    ``interpret=True`` whose resolved callee name matches the wrapper —
    by final segment, with the dotted prefix (when present) required to
    be import-path-compatible with the kernel module so a same-named
    function elsewhere cannot vouch for it.

If the analyzed set contains no test modules at all (e.g. a src-only
invocation) the rule stays silent — coverage cannot be assessed.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Set, Tuple

from repro.analysis.context import ModuleContext, ProjectContext
from repro.analysis.registry import Rule, register

FLAG = "interpret"


def _path_segments(relpath: str) -> List[str]:
    return relpath.replace("\\", "/").split("/")


def _is_kernel_module(ctx: ModuleContext) -> bool:
    return "kernels" in _path_segments(ctx.relpath)[:-1]


def _is_test_module(ctx: ModuleContext) -> bool:
    segs = _path_segments(ctx.relpath)
    return segs[-1].startswith("test_") or "tests" in segs[:-1]


def _params(fn: ast.FunctionDef) -> Set[str]:
    a = fn.args
    return {p.arg for p in a.posonlyargs + a.args + a.kwonlyargs}


def _wrappers(ctx: ModuleContext) -> Iterator[ast.FunctionDef]:
    """Public module-level functions taking an ``interpret`` parameter."""
    for node in ctx.tree.body:
        if not isinstance(node, ast.FunctionDef):
            continue
        if node.name.startswith("_"):
            continue
        if FLAG in _params(node):
            yield node


def _prefix_compatible(prefix: str, module_name: str) -> bool:
    """Does a call spelled ``prefix.fn(...)`` plausibly import from
    ``module_name``? Accepts exact/suffix-rooted matches and ancestor
    packages re-exporting the wrapper (``from repro.kernels import f``)."""
    if not prefix or not module_name:
        return True  # bare local name / unnamed module: lenient
    if prefix == module_name:
        return True
    if module_name.endswith("." + prefix) or prefix.endswith("." + module_name):
        return True
    return module_name.startswith(prefix + ".")


def _interpret_true_calls(ctx: ModuleContext) -> Iterator[Tuple[str, str]]:
    """(final callee segment, dotted prefix) for every ``interpret=True``
    literal-keyword call in a test module."""
    for call in ctx.calls():
        hit = any(
            kw.arg == FLAG
            and isinstance(kw.value, ast.Constant)
            and kw.value.value is True
            for kw in call.keywords
        )
        if not hit:
            continue
        qn = ctx.call_qualname(call)
        if qn is None:
            continue
        parts = qn.split(".")
        yield parts[-1], ".".join(parts[:-1])


@register
class KernelInterpretCoverage(Rule):
    rule_id = "RPR009"
    severity = "error"
    description = (
        "public kernels/ wrappers taking an `interpret` flag must be "
        "exercised by at least one test with interpret=True"
    )

    def check_project(self, project: ProjectContext):
        kernel_mods = [m for m in project.modules if _is_kernel_module(m)]
        test_mods = [m for m in project.modules if _is_test_module(m)]
        if not kernel_mods or not test_mods:
            return

        # name -> set of dotted prefixes seen at interpret=True call sites
        covered: Dict[str, Set[str]] = {}
        for tm in test_mods:
            for name, prefix in _interpret_true_calls(tm):
                covered.setdefault(name, set()).add(prefix)

        for ctx in kernel_mods:
            for fn in _wrappers(ctx):
                prefixes = covered.get(fn.name)
                if prefixes is not None and any(
                    _prefix_compatible(p, ctx.module_name) for p in prefixes
                ):
                    continue
                yield self.finding(
                    ctx,
                    fn,
                    f"kernel wrapper {fn.name!r} is never called with "
                    "interpret=True from any test — the Pallas body never "
                    "runs in CI's CPU job; add an interpret-mode test "
                    "(see tests/test_kernels.py for the idiom)",
                )
