"""RPR011 — runtime timing in ``src/repro/`` must go through ``repro.obs``.

The observability layer (``repro/obs/``) owns the monotonic clock:
``obs.clock.now()`` is the one sanctioned ``time.perf_counter`` site, and
``Obs.phase_begin``/``phase_end`` share a single clock read between the
``EngineStats`` accumulators, the Chrome-trace span, and the latency
histograms. A stray ``time.perf_counter()`` (or ``time.monotonic()``)
elsewhere in the library splinters that contract three ways:

  * the measurement is invisible to traces and metrics (a phantom cost
    no exported artifact accounts for);
  * tests cannot fake it — ``obs.clock.set_source`` swaps the clock for
    deterministic fakes, but only for call sites that use it;
  * disabled-mode guarantees break silently: ``Obs`` promises that a
    null observer adds *zero* extra timer calls, which is only auditable
    when every timer call is routed through the one module.

Flagged: calls resolving to ``time.perf_counter``, ``time.monotonic``
(and their ``_ns`` variants) in modules under a ``repro`` package
directory, excluding ``repro/obs/`` itself. Tests, benchmarks, and
examples are outside the library and exempt. Wall-clock calls
(``time.time``) are not flagged — they mean calendar time (heartbeats,
artifact stamps), not durations.

Fix: use ``repro.obs.clock.now()`` for raw timestamps, or an
``Obs.phase_begin``/``phase_end`` pair when the duration should also
feed a trace span and a histogram. Suppress a deliberate exception with
``# repro: noqa[RPR011]`` and a justifying comment.
"""

from __future__ import annotations

from typing import List

from repro.analysis.context import ModuleContext
from repro.analysis.registry import Rule, register

CLOCK_CALLS = frozenset(
    {
        "time.perf_counter",
        "time.perf_counter_ns",
        "time.monotonic",
        "time.monotonic_ns",
    }
)


def _path_segments(relpath: str) -> List[str]:
    return relpath.replace("\\", "/").split("/")


def _in_scope(ctx: ModuleContext) -> bool:
    """Library modules only: under a ``repro`` dir but not ``repro/obs/``."""
    dirs = _path_segments(ctx.relpath)[:-1]
    return "repro" in dirs and "obs" not in dirs


@register
class MonotonicClockOutsideObs(Rule):
    rule_id = "RPR011"
    severity = "error"
    description = (
        "time.perf_counter/time.monotonic in src/repro/ outside obs/ — "
        "route timing through repro.obs.clock (or Obs.phase_begin/end)"
    )

    def check_module(self, ctx: ModuleContext):
        if not _in_scope(ctx):
            return
        for call in ctx.calls():
            qn = ctx.call_qualname(call)
            if qn in CLOCK_CALLS:
                yield self.finding(
                    ctx,
                    call,
                    f"direct {qn}() call in library code — timing must go "
                    "through repro.obs.clock.now() (testable via "
                    "set_source) or an Obs.phase_begin/phase_end pair so "
                    "the same clock read feeds stats, trace, and metrics",
                )
