"""RPR003 — PRNG-key discipline.

A JAX PRNG key consumed by two random ops yields *identical* (or, via
``split`` twice, correlated) streams — the silent-correlation bug class.
Every key must be split or folded before a second consumption.

Per function scope, the rule tracks variables that hold keys (assigned
from ``jax.random.PRNGKey``/``key``/``split``/``fold_in``, or parameters
named ``key``/``rng``/``keys``/...) and counts *consumptions*: the key
appearing as the first argument of any ``jax.random.*`` call (``split``
and ``fold_in`` consume their operand too — splitting the same parent
twice is exactly the correlated-stream bug). Distinct constant subscripts
(``ks[0]`` vs ``ks[1]``) and distinct ``fold_in`` constants are distinct
streams. Control flow is honored: ``if``/``elif`` branches don't see each
other's uses, and loop bodies are evaluated twice so a key consumed per
iteration without an in-loop re-split is caught.
"""

from __future__ import annotations

import ast
import copy
import re
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.context import ModuleContext
from repro.analysis.registry import Rule, register

KEYISH_PARAM = re.compile(r"(^|_)(key|rng|prng)s?\d*$", re.IGNORECASE)

PRODUCERS = frozenset(
    {"jax.random.PRNGKey", "jax.random.key", "jax.random.split", "jax.random.fold_in"}
)
#: jax.random functions that do NOT consume a key operand
NON_CONSUMING = frozenset(
    {"jax.random.PRNGKey", "jax.random.key", "jax.random.key_data", "jax.random.wrap_key_data"}
)

# stream id: (var name, subscript const or None, fold_in const or None)
StreamId = Tuple[str, Optional[object], Optional[object]]


class _ScopeState:
    __slots__ = ("keyvars", "counts")

    def __init__(self, keyvars: Set[str]):
        self.keyvars = keyvars
        self.counts: Dict[StreamId, int] = {}

    def clone(self) -> "_ScopeState":
        st = _ScopeState(set(self.keyvars))
        st.counts = copy.copy(self.counts)
        return st

    def merge(self, other: "_ScopeState"):
        self.keyvars |= other.keyvars
        for k, v in other.counts.items():
            self.counts[k] = max(self.counts.get(k, 0), v)

    def reset_name(self, name: str, is_key: bool):
        for sid in [s for s in self.counts if s[0] == name]:
            del self.counts[sid]
        if is_key:
            self.keyvars.add(name)
        else:
            self.keyvars.discard(name)


class _KeyFlow:
    def __init__(self, rule: "RngKeyDiscipline", ctx: ModuleContext):
        self.rule = rule
        self.ctx = ctx
        self.findings: List = []
        self._seen: Set[Tuple[int, int, StreamId]] = set()

    # ---- expression side ------------------------------------------------
    def _stream_of(self, node: ast.AST, st: _ScopeState) -> Optional[StreamId]:
        if isinstance(node, ast.Name) and node.id in st.keyvars:
            return (node.id, None, None)
        if isinstance(node, ast.Subscript) and isinstance(node.value, ast.Name):
            if node.value.id not in st.keyvars:
                return None
            idx = node.slice
            if isinstance(idx, ast.Constant):
                return (node.value.id, idx.value, None)
            return None  # data-dependent index: can't reason statically
        return None

    def scan_expr(self, expr: ast.AST, st: _ScopeState):
        for node in ast.walk(expr):
            if not isinstance(node, ast.Call):
                continue
            qn = self.ctx.call_qualname(node)
            if qn is None or not qn.startswith("jax.random.") or qn in NON_CONSUMING:
                continue
            if not node.args:
                continue
            sid = self._stream_of(node.args[0], st)
            if sid is None:
                continue
            if qn == "jax.random.fold_in":
                fold = node.args[1] if len(node.args) > 1 else None
                if not isinstance(fold, ast.Constant):
                    continue  # varying fold value -> distinct streams
                sid = (sid[0], sid[1], ("fold", fold.value))
            st.counts[sid] = st.counts.get(sid, 0) + 1
            if st.counts[sid] == 2:
                mark = (node.lineno, node.col_offset, sid)
                if mark not in self._seen:
                    self._seen.add(mark)
                    what = sid[0] if sid[1] is None else f"{sid[0]}[{sid[1]!r}]"
                    self.findings.append(
                        self.rule.finding(
                            self.ctx,
                            node,
                            f"PRNG key {what} consumed again without an "
                            "interposing jax.random.split/fold_in — identical "
                            "or correlated random streams",
                        )
                    )

    # ---- statement side -------------------------------------------------
    def _assign(self, targets: List[ast.AST], value: ast.AST, st: _ScopeState):
        produced = (
            isinstance(value, ast.Call) and self.ctx.call_qualname(value) in PRODUCERS
        )
        for tgt in targets:
            if isinstance(tgt, ast.Name):
                st.reset_name(tgt.id, produced)
            elif isinstance(tgt, (ast.Tuple, ast.List)):
                for elt in tgt.elts:
                    if isinstance(elt, ast.Name):
                        st.reset_name(elt.id, produced)

    def visit_block(self, stmts: List[ast.stmt], st: _ScopeState):
        for stmt in stmts:
            self.visit_stmt(stmt, st)

    def visit_stmt(self, stmt: ast.stmt, st: _ScopeState):
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            return  # separate scope, analyzed on its own
        if isinstance(stmt, ast.Assign):
            self.scan_expr(stmt.value, st)
            self._assign(stmt.targets, stmt.value, st)
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            self.scan_expr(stmt.value, st)
            self._assign([stmt.target], stmt.value, st)
        elif isinstance(stmt, ast.AugAssign):
            self.scan_expr(stmt.value, st)
        elif isinstance(stmt, ast.If):
            self.scan_expr(stmt.test, st)
            body_st = st.clone()
            self.visit_block(stmt.body, body_st)
            else_st = st.clone()
            self.visit_block(stmt.orelse, else_st)
            st.keyvars.clear()
            st.counts.clear()
            body_st.merge(else_st)
            st.merge(body_st)
        elif isinstance(stmt, (ast.For, ast.AsyncFor)):
            self.scan_expr(stmt.iter, st)
            # two symbolic iterations: catches per-iteration reuse while
            # accepting the `key, sub = split(key)`-at-top idiom
            self.visit_block(stmt.body, st)
            self.visit_block(stmt.body, st)
            self.visit_block(stmt.orelse, st)
        elif isinstance(stmt, ast.While):
            self.scan_expr(stmt.test, st)
            self.visit_block(stmt.body, st)
            self.visit_block(stmt.body, st)
            self.visit_block(stmt.orelse, st)
        elif isinstance(stmt, ast.With) or isinstance(stmt, ast.AsyncWith):
            for item in stmt.items:
                self.scan_expr(item.context_expr, st)
            self.visit_block(stmt.body, st)
        elif isinstance(stmt, ast.Try):
            self.visit_block(stmt.body, st)
            for handler in stmt.handlers:
                self.visit_block(handler.body, st)
            self.visit_block(stmt.orelse, st)
            self.visit_block(stmt.finalbody, st)
        elif isinstance(stmt, (ast.Return, ast.Expr)) and stmt.value is not None:
            self.scan_expr(stmt.value, st)
        elif isinstance(stmt, (ast.Raise, ast.Assert, ast.Delete)):
            for sub in ast.iter_child_nodes(stmt):
                self.scan_expr(sub, st)


@register
class RngKeyDiscipline(Rule):
    rule_id = "RPR003"
    severity = "error"
    description = (
        "a PRNG key consumed by >=2 random ops without an interposing "
        "jax.random.split/fold_in"
    )

    def check_module(self, ctx: ModuleContext):
        scopes: List[Tuple[List[ast.stmt], Set[str]]] = [(ctx.tree.body, set())]
        for fn in ctx.functions():
            params = {
                a.arg
                for a in fn.args.posonlyargs + fn.args.args + fn.args.kwonlyargs
                if KEYISH_PARAM.search(a.arg)
            }
            scopes.append((fn.body, params))
        for body, seed in scopes:
            flow = _KeyFlow(self, ctx)
            flow.visit_block(body, _ScopeState(seed))
            yield from flow.findings
