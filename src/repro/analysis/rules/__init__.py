"""Builtin rule modules — importing this package registers every rule.

One file per rule, mirroring ``repro/core``'s backend layout: each module
defines a ``Rule`` subclass and calls ``register()`` at import time, so
``registry.get_rules()`` sees the full catalogue no matter which entry
point was imported first. See RULES.md (one directory up) for the
human-readable catalogue.
"""

from repro.analysis.rules import (
    rpr001_jit_cache,
    rpr002_tracer,
    rpr003_rng,
    rpr004_pallas,
    rpr005_scales,
    rpr006_backend,
    rpr007_sharding,
    rpr009_interpret,
    rpr010_facade,
    rpr011_timing,
)

__all__ = [
    "rpr001_jit_cache",
    "rpr002_tracer",
    "rpr003_rng",
    "rpr004_pallas",
    "rpr005_scales",
    "rpr006_backend",
    "rpr007_sharding",
    "rpr009_interpret",
    "rpr010_facade",
    "rpr011_timing",
]
