"""RPR007 — sharding specs must name real mesh axes (and match arity).

``PartitionSpec`` axis names are stringly typed: ``P("modle", None)`` or
``P("tensor", None)`` parses, jits, and — because unknown axes only fail
at ``NamedSharding`` construction against a concrete mesh — can survive
until a multi-host launch that no unit test exercises. The mesh axis
vocabulary for this repo is defined once, in ``repro.launch.mesh``
(``("pod", "data", "model")`` for pod-scale, ``("data", "model")``
otherwise); every sharding spec anywhere in the tree must draw from it.

Two checks, both project-level (the mesh module is read cross-file):

  * every string-literal axis passed to a ``PartitionSpec(...)`` call
    (directly or inside a tuple/list entry) must be one of the mesh axis
    names harvested from ``repro.launch.mesh`` — all-string tuples
    assigned to (or defaulted into a parameter named) ``axes``. When the
    mesh module is not part of the analyzed file set, the canonical
    ``{"pod", "data", "model"}`` vocabulary applies.
  * ``jax.jit(fn, in_shardings=(...))`` where ``fn`` is a module-local
    ``def`` must pass exactly one spec per positional parameter — an
    arity mismatch silently replicates (or crashes at lower time,
    far from the typo). Skipped when the function takes ``*args``, has
    parameter defaults, or the jit call sets ``static_argnums`` /
    ``static_argnames`` (those change the mapping legitimately).

Dynamic spellings — ``P(*axes)``, axis names held in variables, specs
built by ``runtime.pspec`` rules — are out of lexical reach and pass.
Suppress a deliberate exception with ``# repro: noqa[RPR007]``.
"""

from __future__ import annotations

import ast
from typing import Iterator, Set

from repro.analysis.context import ModuleContext, ProjectContext
from repro.analysis.registry import Rule, register

MESH_MODULE = "repro.launch.mesh"

#: fallback vocabulary when ``repro.launch.mesh`` is outside the analyzed
#: file set (single-file runs, unit-test snippets)
DEFAULT_AXES = frozenset({"pod", "data", "model"})


def _harvest_axes(value: ast.AST, axes: Set[str]) -> None:
    """Collect every all-string tuple under ``value`` (handles the
    ``(...) if multi_pod else (...)`` IfExp in make_production_mesh)."""
    for node in ast.walk(value):
        if (
            isinstance(node, ast.Tuple)
            and node.elts
            and all(
                isinstance(e, ast.Constant) and isinstance(e.value, str)
                for e in node.elts
            )
        ):
            axes.update(e.value for e in node.elts)


def _mesh_axes(project: ProjectContext) -> Set[str]:
    """Axis vocabulary from ``repro.launch.mesh``: all-string tuples bound
    to a name (or parameter) called ``axes``."""
    mod = project.module(MESH_MODULE)
    if mod is None:
        return set(DEFAULT_AXES)
    axes: Set[str] = set()
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Assign):
            if any(
                isinstance(t, ast.Name) and t.id == "axes"
                for t in node.targets
            ):
                _harvest_axes(node.value, axes)
        elif isinstance(node, ast.AnnAssign):
            if (
                isinstance(node.target, ast.Name)
                and node.target.id == "axes"
                and node.value is not None
            ):
                _harvest_axes(node.value, axes)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            a = node.args
            params = a.posonlyargs + a.args
            for param, default in zip(params[len(params) - len(a.defaults):],
                                      a.defaults):
                if param.arg == "axes":
                    _harvest_axes(default, axes)
            for param, default in zip(a.kwonlyargs, a.kw_defaults):
                if param.arg == "axes" and default is not None:
                    _harvest_axes(default, axes)
    return axes or set(DEFAULT_AXES)


def _literal_axis_names(arg: ast.AST) -> Iterator[ast.Constant]:
    """String constants used as axis entries of one PartitionSpec arg:
    the arg itself, or the elements of a tuple/list entry (PartitionSpec
    accepts ``("data", "model")`` to shard one dim over two axes)."""
    if isinstance(arg, ast.Constant):
        if isinstance(arg.value, str):
            yield arg
    elif isinstance(arg, (ast.Tuple, ast.List)):
        for elt in arg.elts:
            if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                yield elt


def _local_functions(ctx: ModuleContext):
    return {
        f.name: f
        for f in ctx.tree.body
        if isinstance(f, (ast.FunctionDef, ast.AsyncFunctionDef))
    }


@register
class ShardingSpecConsistency(Rule):
    rule_id = "RPR007"
    severity = "error"
    description = (
        "PartitionSpec axis names must exist on the repro.launch.mesh "
        "mesh; jit in_shardings literals must match the function arity"
    )

    def check_project(self, project: ProjectContext):
        axes = _mesh_axes(project)
        shown = ", ".join(sorted(axes))
        for ctx in project.modules:
            yield from self._check_axis_names(ctx, axes, shown)
            yield from self._check_jit_arity(ctx)

    # ---- axis vocabulary -------------------------------------------------
    def _check_axis_names(self, ctx: ModuleContext, axes, shown):
        for call in ctx.calls():
            qn = ctx.call_qualname(call)
            if qn is None or qn.split(".")[-1] != "PartitionSpec":
                continue
            for arg in call.args:
                for const in _literal_axis_names(arg):
                    if const.value not in axes:
                        yield self.finding(
                            ctx,
                            const,
                            f"PartitionSpec axis {const.value!r} is not a "
                            f"mesh axis (repro.launch.mesh defines: "
                            f"{shown}) — typo'd axes replicate silently "
                            "or fail only at multi-host launch",
                        )

    # ---- in_shardings arity ----------------------------------------------
    def _check_jit_arity(self, ctx: ModuleContext):
        local_fns = _local_functions(ctx)
        for call in ctx.calls():
            if ctx.call_qualname(call) != "jax.jit" or not call.args:
                continue
            kw = next(
                (k for k in call.keywords if k.arg == "in_shardings"), None)
            if kw is None or not isinstance(kw.value, (ast.Tuple, ast.List)):
                continue
            if any(
                k.arg in ("static_argnums", "static_argnames")
                for k in call.keywords
            ):
                continue
            fn_ref = call.args[0]
            if not isinstance(fn_ref, ast.Name):
                continue
            fn = local_fns.get(fn_ref.id)
            if fn is None:
                continue
            a = fn.args
            if a.vararg is not None or a.defaults:
                continue
            n_params = len(a.posonlyargs) + len(a.args)
            n_specs = len(kw.value.elts)
            if n_specs != n_params:
                yield self.finding(
                    ctx,
                    kw.value,
                    f"in_shardings has {n_specs} spec(s) but "
                    f"{fn_ref.id}() takes {n_params} positional "
                    "argument(s) — jax pads/truncates nothing; this "
                    "fails at lower time or silently mis-shards",
                )
