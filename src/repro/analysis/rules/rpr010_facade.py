"""RPR010 — facade drift: README examples vs the ``repro.api`` AST.

The README's code fences are the first thing a user copies, and nothing
executes them: a facade method renamed, a parameter dropped, or a keyword
added in ``api.py`` leaves the documented calls silently broken until a
user hits the TypeError. This rule closes that gap statically.

Project pass: parse ``repro.api`` into a signature table (module-level
functions, ``QuaffModel`` methods and classmethods, the constructor), find
the README.md that documents it (walking up from ``api.py``'s directory),
parse every fenced code block that is valid Python, and check each call
against the table:

  * ``api.X(...)`` / ``QuaffModel.X(...)`` must name a real export;
  * facade-bound names (assigned from ``api.prepare`` /
    ``api.QuaffModel.load`` / ``QuaffModel(...)``, plus the conventional
    name ``model``) must call real ``QuaffModel`` methods;
  * calls must bind: no more positionals than the signature takes, no
    unknown keywords (unless the signature has ``**kwargs``), every
    default-less parameter supplied.

Blocks that do not parse as Python (shell commands, output transcripts)
are skipped, as is any call using ``*args``/``**kwargs`` splats — the rule
only flags what it can prove lexically.
"""

from __future__ import annotations

import ast
import os
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.analysis.context import ModuleContext, ProjectContext
from repro.analysis.registry import Finding, Rule, register

API_MODULE = "repro.api"
FACADE_CLASS = "QuaffModel"
#: README convention: examples call the facade instance ``model`` even in
#: fences that elide the assignment that produced it
CONVENTIONAL_INSTANCE = "model"


class _Sig:
    """Callable signature lexically extracted from a def."""

    __slots__ = ("name", "pos", "required_pos", "kwonly", "required_kwonly",
                 "has_vararg", "has_kwargs")

    def __init__(self, fn: ast.FunctionDef, skip_self: bool):
        a = fn.args
        pos = [x.arg for x in a.posonlyargs + a.args]
        if skip_self and pos and pos[0] in ("self", "cls"):
            pos = pos[1:]
        self.name = fn.name
        self.pos = pos
        self.required_pos = pos[:len(pos) - len(a.defaults)]
        self.kwonly = {x.arg for x in a.kwonlyargs}
        self.required_kwonly = {x.arg for d, x in
                                zip(a.kw_defaults, a.kwonlyargs) if d is None}
        self.has_vararg = a.vararg is not None
        self.has_kwargs = a.kwarg is not None


def _is_property(fn: ast.FunctionDef) -> bool:
    return any(isinstance(d, ast.Name) and d.id == "property"
               for d in fn.decorator_list)


def _facade_tables(api_mod: ModuleContext
                   ) -> Tuple[Dict[str, _Sig], Dict[str, _Sig], Set[str]]:
    """(module functions, QuaffModel methods, non-callable attrs)."""
    functions: Dict[str, _Sig] = {}
    methods: Dict[str, _Sig] = {}
    attrs: Set[str] = set()
    for node in api_mod.tree.body:
        if isinstance(node, ast.FunctionDef) and not node.name.startswith("_"):
            functions[node.name] = _Sig(node, skip_self=False)
        elif isinstance(node, ast.ClassDef) and node.name == FACADE_CLASS:
            for item in node.body:
                if not isinstance(item, ast.FunctionDef):
                    continue
                if _is_property(item):
                    attrs.add(item.name)
                elif item.name == "__init__" or not item.name.startswith("_"):
                    methods[item.name] = _Sig(item, skip_self=True)
    return functions, methods, attrs


def _find_readme(api_path: str) -> Optional[str]:
    """Walk up from ``api.py``'s directory to the README that documents the
    facade (repo root in the shipped tree, ``tmp_path`` in test fixtures)."""
    d = os.path.dirname(os.path.abspath(api_path))
    for _ in range(8):
        candidate = os.path.join(d, "README.md")
        if os.path.isfile(candidate):
            return candidate
        parent = os.path.dirname(d)
        if parent == d:
            break
        d = parent
    return None


def _code_fences(text: str) -> Iterator[Tuple[int, str]]:
    """(1-based line of the opening fence, block source) for each fenced
    block whose tag could be Python (python/py/untagged)."""
    lines = text.splitlines()
    open_line, tag, buf = 0, "", []
    for i, line in enumerate(lines, start=1):
        stripped = line.strip()
        if stripped.startswith("```"):
            if open_line:
                if tag in ("", "python", "py"):
                    yield open_line, "\n".join(buf)
                open_line, buf = 0, []
            else:
                open_line, tag = i, stripped[3:].strip().lower()
        elif open_line:
            buf.append(line)


def _has_splat(call: ast.Call) -> bool:
    return (any(isinstance(a, ast.Starred) for a in call.args)
            or any(kw.arg is None for kw in call.keywords))


def _check_binding(call: ast.Call, sig: _Sig, label: str) -> List[str]:
    """Messages for ways ``call`` cannot bind against ``sig``."""
    if _has_splat(call):
        return []
    out = []
    n_pos = len(call.args)
    if not sig.has_vararg and n_pos > len(sig.pos):
        out.append(f"{label} takes {len(sig.pos)} positional argument(s) "
                   f"but the README call passes {n_pos}")
    kwnames = {kw.arg for kw in call.keywords}
    if not sig.has_kwargs:
        unknown = sorted(kwnames - set(sig.pos) - sig.kwonly)
        if unknown:
            out.append(f"{label} has no parameter(s) "
                       f"{', '.join(repr(k) for k in unknown)}")
    bound = set(sig.pos[:n_pos]) | kwnames
    missing = sorted((set(sig.required_pos) | sig.required_kwonly) - bound)
    if missing:
        out.append(f"README call leaves required {label} parameter(s) "
                   f"unbound: {', '.join(missing)}")
    return out


def _bound_instances(ctx: ModuleContext, functions: Dict[str, _Sig]) -> Set[str]:
    """Names a fence binds to a facade instance (plus the conventional
    ``model``): assigned from ``api.prepare`` / ``api.QuaffModel.load`` /
    ``QuaffModel(...)``."""
    bound = {CONVENTIONAL_INSTANCE}
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and isinstance(node.value, ast.Call)):
            continue
        qn = ctx.call_qualname(node.value) or ""
        parts = qn.split(".")
        if parts[-1] == FACADE_CLASS or (
                len(parts) >= 2 and parts[-2] == FACADE_CLASS) or (
                parts[-1] == "prepare" and "api" in parts):
            bound.add(node.targets[0].id)
    return bound


def _facade_target(ctx: ModuleContext, call: ast.Call, bound: Set[str]
                   ) -> Optional[Tuple[str, str]]:
    """Classify a call against the facade surface. Returns one of
    ``("function", name)`` for ``api.X(...)``, ``("method", name)`` for
    ``api.QuaffModel.X(...)`` / ``<instance>.X(...)`` /
    ``QuaffModel(...)`` (name ``__init__``), else None."""
    qn = ctx.call_qualname(call)
    if qn is not None:
        parts = qn.split(".")
        if parts[-1] == FACADE_CLASS:
            return "method", "__init__"
        if len(parts) >= 2 and parts[-2] == FACADE_CLASS:
            return "method", parts[-1]
        if len(parts) >= 2 and parts[-2] == "api":
            return "function", parts[-1]
    func = call.func
    if (isinstance(func, ast.Attribute) and isinstance(func.value, ast.Name)
            and func.value.id in bound):
        return "method", func.attr
    return None


@register
class FacadeDrift(Rule):
    rule_id = "RPR010"
    severity = "error"
    description = (
        "README code fences must call repro.api exports that exist, with "
        "arguments their signatures accept"
    )

    def check_project(self, project: ProjectContext):
        api_mod = project.module(API_MODULE)
        if api_mod is None:
            return
        functions, methods, attrs = _facade_tables(api_mod)
        readme = _find_readme(api_mod.path)
        if readme is None:
            return
        with open(readme, "r", encoding="utf-8") as f:
            text = f.read()
        rel = os.path.relpath(readme)
        for fence_line, block in _code_fences(text):
            try:
                ctx = ModuleContext(readme, block, relpath=rel)
            except SyntaxError:
                continue        # shell commands / output transcripts
            yield from self._check_fence(ctx, fence_line, rel,
                                         functions, methods, attrs)

    def _check_fence(self, ctx, fence_line, rel, functions, methods, attrs):
        bound = _bound_instances(ctx, functions)
        for call in ctx.calls():
            target = _facade_target(ctx, call, bound)
            if target is None:
                continue
            kind, name = target
            if kind == "function":
                sig = functions.get(name)
                label = f"api.{name}"
                known = name in functions
            else:
                sig = methods.get(name)
                label = (FACADE_CLASS if name == "__init__"
                         else f"{FACADE_CLASS}.{name}")
                known = name in methods or name in attrs
            if not known:
                yield self._finding(rel, fence_line, call,
                                    f"README documents {label} but repro.api "
                                    f"defines no such "
                                    f"{'function' if kind == 'function' else 'method'}")
                continue
            if sig is None:     # property accessed as a call elsewhere
                continue
            for msg in _check_binding(call, sig, label):
                yield self._finding(rel, fence_line, call, msg)

    def _finding(self, rel: str, fence_line: int, node: ast.AST,
                 message: str) -> Finding:
        return Finding(rule_id=self.rule_id, severity=self.severity,
                       path=rel, line=fence_line + getattr(node, "lineno", 1),
                       col=getattr(node, "col_offset", 0) + 1,
                       message=message)
