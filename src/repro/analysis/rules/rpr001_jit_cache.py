"""RPR001 — jit-cache-busting.

``jax.jit`` keeps its trace cache on the wrapper object, so a wrapper
constructed per loop iteration (or constructed-and-immediately-called)
retraces every execution — the classic silent recompile storm in a serving
hot loop. Hot paths must build steps once (module level, ``@functools.
lru_cache`` builders as in ``serving/engine.py``, or an ``is None`` memo
guard). Separately, arguments declared in ``static_argnames`` become cache
*keys*: passing an unhashable literal (list/dict/set) raises at best and,
for freshly-constructed objects, busts the cache at every call.

Flags:
  * a ``jax.jit(...)`` call lexically inside a ``for``/``while`` loop,
    unless memoized under an ``x is None`` guard;
  * ``jax.jit(f)(...)`` — a fresh wrapper invoked immediately;
  * a call to a known jit-wrapped function passing a list/dict/set
    literal (or comprehension) for a ``static_argnames`` parameter.
"""

from __future__ import annotations

import ast
from typing import Dict, Set

from repro.analysis.context import ModuleContext, ProjectContext
from repro.analysis.registry import Rule, register
from repro.analysis.rules.common import (
    is_jit_call,
    jit_decoration,
    static_argnames_from_keywords,
)

UNHASHABLE_NODES = (
    ast.List,
    ast.Dict,
    ast.Set,
    ast.ListComp,
    ast.DictComp,
    ast.SetComp,
    ast.GeneratorExp,
)


def _memo_guarded(ctx: ModuleContext, call: ast.Call, loop: ast.AST) -> bool:
    """True when the jit call sits under an ``if x is None:`` (or
    ``if not x:``) guard between itself and the loop — the build-once
    pattern ``train/calibrate.py`` uses."""
    for anc in ctx.ancestors(call):
        if anc is loop:
            return False
        if not isinstance(anc, ast.If):
            continue
        test = anc.test
        if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
            return True
        if isinstance(test, ast.Compare) and any(
            isinstance(op, (ast.Is, ast.Eq)) for op in test.ops
        ):
            comparators = [test.left] + list(test.comparators)
            if any(
                isinstance(c, ast.Constant) and c.value is None for c in comparators
            ):
                return True
    return False


def _enclosing_loop(ctx: ModuleContext, call: ast.Call):
    """Nearest For/While ancestor, stopping at a function boundary (a jit
    built inside a def that merely *sits* in a loop runs when the def is
    called, not per iteration)."""
    for anc in ctx.ancestors(call):
        if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            return None
        if isinstance(anc, (ast.For, ast.AsyncFor, ast.While)):
            return anc
    return None


def _jit_static_table(project: ProjectContext) -> Dict[str, Set[str]]:
    """bare function name -> static_argnames, for every jit-wrapped
    function in the analyzed set (decorated defs and ``f = jax.jit(g,
    static_argnames=...)`` assignments)."""
    table: Dict[str, Set[str]] = {}

    def add(name: str, static: Set[str]):
        if static:
            table.setdefault(name, set()).update(static)

    for ctx in project.modules:
        for fn in ctx.functions():
            deco = jit_decoration(ctx, fn)
            if deco is not None:
                add(fn.name, deco[1])
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Assign) or not is_jit_call(ctx, node.value):
                continue
            static = static_argnames_from_keywords(node.value.keywords)
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    add(tgt.id, static)
    return table


@register
class JitCacheBusting(Rule):
    rule_id = "RPR001"
    severity = "error"
    description = (
        "jax.jit constructed per loop iteration / invoked immediately, or a "
        "static_argnames parameter passed an unhashable literal"
    )

    def check_module(self, ctx: ModuleContext):
        for call in ctx.calls():
            # jax.jit(f)(...): fresh wrapper, traced on every execution
            if isinstance(call.func, ast.Call) and is_jit_call(ctx, call.func):
                yield self.finding(
                    ctx,
                    call,
                    "jax.jit(...) constructed and called in one expression: the "
                    "wrapper (and its trace cache) dies immediately, so every "
                    "execution retraces — bind the jitted function once",
                )
            if not is_jit_call(ctx, call):
                continue
            loop = _enclosing_loop(ctx, call)
            if loop is not None and not _memo_guarded(ctx, call, loop):
                yield self.finding(
                    ctx,
                    call,
                    "jax.jit(...) inside a loop builds a fresh wrapper (fresh "
                    "trace cache) per iteration — hoist it, memoize under an "
                    "`is None` guard, or use an lru_cache'd builder as in "
                    "serving/engine.py",
                )

    def check_project(self, project: ProjectContext):
        table = _jit_static_table(project)
        if not table:
            return
        for ctx in project.modules:
            for call in ctx.calls():
                qn = ctx.call_qualname(call)
                if qn is None:
                    continue
                static = table.get(qn.split(".")[-1])
                if not static:
                    continue
                for kw in call.keywords:
                    if kw.arg in static and isinstance(kw.value, UNHASHABLE_NODES):
                        yield self.finding(
                            ctx,
                            kw.value,
                            f"static_argnames parameter {kw.arg!r} receives an "
                            "unhashable literal — static args are jit cache keys "
                            "and must be hashable (use a tuple)",
                        )
