"""RPR006 — QuantBackend protocol completeness.

The model layer resolves quant modes through ``core/backend.py``'s
registry and calls the protocol blind — a backend missing a required
method or accepting a different signature fails at apply time, deep inside
a jitted forward, for whichever user first selects that mode. The protocol
is easy to state and easy to silently violate (OWQ/OutlierTune-style
schemes each hinge on exactly this kind of per-channel invariant surface).

Project pass: the protocol is parsed out of ``repro.core.backend`` itself
(required = methods whose body raises NotImplementedError; optional = the
rest), then every ``QuantBackend`` subclass in the analyzed set is checked:

  * defines every required method;
  * sets a non-empty ``name`` class attribute;
  * each overriding method matches the protocol arity: same positional
    parameter count, and accepts every protocol keyword-only parameter
    (by name, or via ``**kwargs``);
  * is actually registered (``@register`` or a ``register(Cls)`` call) —
    a complete-but-unregistered backend is dead code the registry will
    never resolve.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.context import ModuleContext, ProjectContext
from repro.analysis.registry import Rule, register

BACKEND_MODULE = "repro.core.backend"
BASE_CLASS = "QuantBackend"


class _MethodSig:
    __slots__ = ("name", "n_positional", "kwonly", "has_kwargs")

    def __init__(self, fn: ast.FunctionDef):
        self.name = fn.name
        pos = [a.arg for a in fn.args.posonlyargs + fn.args.args]
        if pos and pos[0] in ("self", "cls"):
            pos = pos[1:]
        self.n_positional = len(pos)
        self.kwonly = {a.arg for a in fn.args.kwonlyargs}
        self.has_kwargs = fn.args.kwarg is not None


def _raises_not_implemented(fn: ast.FunctionDef) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Raise):
            exc = node.exc
            if isinstance(exc, ast.Call):
                exc = exc.func
            if isinstance(exc, ast.Name) and exc.id == "NotImplementedError":
                return True
    return False


def _protocol_from(
    backend_mod: ModuleContext,
) -> Optional[Tuple[Dict[str, _MethodSig], Set[str]]]:
    """(all protocol method signatures, required method names)."""
    for node in ast.walk(backend_mod.tree):
        if isinstance(node, ast.ClassDef) and node.name == BASE_CLASS:
            sigs: Dict[str, _MethodSig] = {}
            required: Set[str] = set()
            for item in node.body:
                if not isinstance(item, ast.FunctionDef):
                    continue
                if item.name.startswith("__"):
                    continue
                sigs[item.name] = _MethodSig(item)
                if _raises_not_implemented(item):
                    required.add(item.name)
            return sigs, required
    return None


def _subclasses(ctx: ModuleContext) -> List[ast.ClassDef]:
    out = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.ClassDef):
            continue
        for base in node.bases:
            qn = ctx.qualname(base)
            if qn is not None and qn.split(".")[-1] == BASE_CLASS:
                out.append(node)
                break
    return out


def _class_name_attr(cls: ast.ClassDef) -> Optional[str]:
    """Value of a literal ``name = "..."`` class attribute, if present."""
    for item in cls.body:
        targets = []
        if isinstance(item, ast.Assign):
            targets = item.targets
            value = item.value
        elif isinstance(item, ast.AnnAssign) and item.value is not None:
            targets = [item.target]
            value = item.value
        else:
            continue
        for tgt in targets:
            if isinstance(tgt, ast.Name) and tgt.id == "name":
                if isinstance(value, ast.Constant) and isinstance(value.value, str):
                    return value.value
                return ""  # non-literal: treated as unknown/empty
    return None


def _is_registered(ctx: ModuleContext, cls: ast.ClassDef) -> bool:
    for deco in cls.decorator_list:
        node = deco.func if isinstance(deco, ast.Call) else deco
        qn = ctx.qualname(node)
        if qn is not None and qn.split(".")[-1] == "register":
            return True
    for call in ctx.calls():
        qn = ctx.call_qualname(call)
        if qn is None or qn.split(".")[-1] != "register":
            continue
        for arg in call.args:
            if isinstance(arg, ast.Name) and arg.id == cls.name:
                return True
            if isinstance(arg, ast.Call) and isinstance(arg.func, ast.Name):
                if arg.func.id == cls.name:
                    return True
    return False


@register
class BackendProtocolCompleteness(Rule):
    rule_id = "RPR006"
    severity = "error"
    description = (
        "QuantBackend subclasses must register, set .name, implement every "
        "required protocol method, and match protocol signatures"
    )

    def check_project(self, project: ProjectContext):
        backend_mod = project.module(BACKEND_MODULE)
        if backend_mod is None:
            return  # protocol source not in the analyzed set
        proto = _protocol_from(backend_mod)
        if proto is None:
            return
        sigs, required = proto

        for ctx in project.modules:
            for cls in _subclasses(ctx):
                if ctx is backend_mod and cls.name == BASE_CLASS:
                    continue
                yield from self._check_class(ctx, cls, sigs, required)

    def _check_class(self, ctx, cls, sigs, required):
        methods = {
            item.name: item for item in cls.body if isinstance(item, ast.FunctionDef)
        }

        missing = sorted(required - set(methods))
        if missing:
            yield self.finding(
                ctx,
                cls,
                f"QuantBackend subclass {cls.name!r} does not implement "
                f"required protocol method(s): {', '.join(missing)}",
            )

        name_value = _class_name_attr(cls)
        if name_value is None or name_value == "":
            yield self.finding(
                ctx,
                cls,
                f"QuantBackend subclass {cls.name!r} must set a non-empty "
                "literal `name` class attribute (the registry key)",
            )

        for mname, fn in methods.items():
            proto_sig = sigs.get(mname)
            if proto_sig is None:
                continue
            impl = _MethodSig(fn)
            if impl.n_positional != proto_sig.n_positional:
                yield self.finding(
                    ctx,
                    fn,
                    f"{cls.name}.{mname} takes {impl.n_positional} positional "
                    f"parameter(s) but the protocol defines "
                    f"{proto_sig.n_positional} — model code calls the "
                    "protocol blind",
                )
            if not impl.has_kwargs:
                dropped = sorted(proto_sig.kwonly - impl.kwonly)
                if dropped:
                    yield self.finding(
                        ctx,
                        fn,
                        f"{cls.name}.{mname} does not accept protocol "
                        f"keyword-only parameter(s): {', '.join(dropped)}",
                    )

        if not _is_registered(ctx, cls) and not missing:
            yield self.finding(
                ctx,
                cls,
                f"QuantBackend subclass {cls.name!r} is never registered — "
                "call register() (or decorate with @register) at import "
                "time, or the registry cannot resolve it",
            )
