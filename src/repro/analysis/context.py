"""Parsed-file contexts shared by every rule.

``ModuleContext`` wraps one parsed file: the AST plus the derived maps the
rules keep needing — parent links, import-alias resolution (so ``pl`` in a
file that did ``from jax.experimental import pallas as pl`` resolves to
``jax.experimental.pallas``), inline ``# repro: noqa[...]`` suppressions,
and function enumeration. ``ProjectContext`` is the whole analyzed file set
with dotted-module lookup for the cross-file rules.

Resolution is purely lexical — no imports are executed; the analyzed files
are never run.
"""

from __future__ import annotations

import ast
import re
from typing import Dict, Iterator, List, Optional, Set, Tuple, Union

FunctionNode = Union[ast.FunctionDef, ast.AsyncFunctionDef]

#: ``# repro: noqa`` (all rules) or ``# repro: noqa[RPR001,RPR002] why...``
NOQA_RE = re.compile(r"#\s*repro:\s*noqa(?:\[([A-Za-z0-9_,\s-]+)\])?")


def _collect_aliases(tree: ast.AST) -> Dict[str, str]:
    """Map local names to canonical dotted import paths.

    ``import jax.numpy as jnp``                    -> {"jnp": "jax.numpy"}
    ``import jax``                                 -> {"jax": "jax"}
    ``from jax.experimental import pallas as pl``  -> {"pl": "jax...pallas"}
    ``from functools import partial``              -> {"partial": "functools.partial"}
    """
    aliases: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.asname:
                    aliases[a.asname] = a.name
                else:
                    # ``import jax.numpy`` binds the top-level name ``jax``
                    top = a.name.split(".")[0]
                    aliases[top] = top
        elif isinstance(node, ast.ImportFrom) and node.module and node.level == 0:
            for a in node.names:
                if a.name == "*":
                    continue
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


def _collect_noqa(lines: List[str]) -> Dict[int, Optional[Set[str]]]:
    """1-based line -> suppressed rule-id set, or None meaning all rules."""
    out: Dict[int, Optional[Set[str]]] = {}
    for i, line in enumerate(lines, start=1):
        m = NOQA_RE.search(line)
        if not m:
            continue
        if m.group(1) is None:
            out[i] = None
        else:
            out[i] = {p.strip() for p in m.group(1).split(",") if p.strip()}
    return out


class ModuleContext:
    """One parsed file plus the lexical maps rules operate on."""

    def __init__(self, path: str, source: str, relpath: Optional[str] = None):
        self.path = path
        self.relpath = relpath or path
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        self.aliases = _collect_aliases(self.tree)
        self.noqa = _collect_noqa(self.lines)
        self._parents: Dict[int, ast.AST] = {}
        for parent in ast.walk(self.tree):
            for child in ast.iter_child_nodes(parent):
                self._parents[id(child)] = parent
        #: dotted module name ("repro.kernels.common"); set by the runner
        self.module_name = ""

    # ---- tree navigation ------------------------------------------------
    def parent(self, node: ast.AST) -> Optional[ast.AST]:
        return self._parents.get(id(node))

    def ancestors(self, node: ast.AST) -> Iterator[ast.AST]:
        cur = self.parent(node)
        while cur is not None:
            yield cur
            cur = self.parent(cur)

    def enclosing_function(self, node: ast.AST) -> Optional[FunctionNode]:
        for anc in self.ancestors(node):
            if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return anc
        return None

    def functions(self) -> Iterator[FunctionNode]:
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield node

    def calls(self) -> Iterator[ast.Call]:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Call):
                yield node

    def statement_of(self, node: ast.AST) -> ast.AST:
        """The enclosing ``ast.stmt`` (the node itself if already one)."""
        cur: Optional[ast.AST] = node
        while cur is not None and not isinstance(cur, ast.stmt):
            cur = self.parent(cur)
        return cur if cur is not None else node

    # ---- name resolution ------------------------------------------------
    def qualname(self, node: ast.AST) -> Optional[str]:
        """Canonical dotted name of a Name/Attribute chain, resolved
        through this file's imports; None for non-name expressions."""
        parts: List[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        base = self.aliases.get(node.id, node.id)
        return ".".join([base] + parts[::-1])

    def call_qualname(self, call: ast.Call) -> Optional[str]:
        return self.qualname(call.func)

    def is_call_to(self, node: ast.AST, *names: str) -> bool:
        """True if ``node`` is a Call whose resolved function name equals
        one of ``names`` exactly or by last-segment suffix (``a.b.c``
        matches ``"c"`` only when ``"c"`` itself is passed undotted)."""
        if not isinstance(node, ast.Call):
            return False
        qn = self.call_qualname(node)
        if qn is None:
            return False
        return any(qn == n or ("." not in n and qn.split(".")[-1] == n) for n in names)

    def unwrap_partial(self, node: ast.AST) -> Tuple[ast.AST, List[ast.keyword]]:
        """Peel ``functools.partial(f, ...)`` wrappers: returns the innermost
        callee expression plus every keyword bound along the way."""
        kws: List[ast.keyword] = []
        while (
            isinstance(node, ast.Call)
            and self.call_qualname(node) == "functools.partial"
            and node.args
        ):
            kws.extend(node.keywords)
            node = node.args[0]
        return node, kws

    # ---- suppression ----------------------------------------------------
    def suppressed(self, rule_id: str, line: int) -> bool:
        if line not in self.noqa:
            return False
        ids = self.noqa[line]
        return ids is None or rule_id in ids


class ProjectContext:
    """The whole analyzed file set (cross-module rules read this)."""

    def __init__(self, modules: List[ModuleContext]):
        self.modules = modules
        self._by_name = {m.module_name: m for m in modules if m.module_name}

    def module(self, dotted: str) -> Optional[ModuleContext]:
        """Lookup by dotted name, exact or by suffix (so ``repro.core.
        backend`` is found whether the tree was rooted at src/ or not)."""
        if dotted in self._by_name:
            return self._by_name[dotted]
        for name, mod in self._by_name.items():
            if name.endswith("." + dotted) or dotted.endswith("." + name):
                return mod
        return None
