"""Rule registry: the one extension point for static-analysis checks.

Same shape as ``core/backend.py``'s ``QuantBackend`` registry: a rule is a
self-registering one-file module under ``repro/analysis/rules/`` that
subclasses ``Rule`` and calls ``register()`` at import time. The runner
resolves rules through ``get_rules()`` and never branches on rule ids.

A rule implements one (or both) of two passes:

    check_module(ctx)      -> findings for one parsed file (most rules)
    check_project(project) -> findings needing the whole file set
                              (cross-module tables: jit static-arg
                              signatures, the QuantBackend protocol)
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List

SEVERITIES = ("error", "warning")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One diagnostic: a rule id anchored to a file position."""

    rule_id: str
    severity: str
    path: str
    line: int
    col: int
    message: str

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def render(self) -> str:
        return (
            f"{self.path}:{self.line}:{self.col}: "
            f"{self.rule_id} [{self.severity}] {self.message}"
        )


class Rule:
    """Protocol base class. Subclass, set ``rule_id``, implement a pass."""

    rule_id: str = ""
    severity: str = "error"
    description: str = ""

    # ---- passes (implement at least one) --------------------------------
    def check_module(self, ctx) -> Iterable[Finding]:
        """Per-file pass over one ``ModuleContext``."""
        return ()

    def check_project(self, project) -> Iterable[Finding]:
        """Whole-file-set pass over a ``ProjectContext``."""
        return ()

    # ---- helpers --------------------------------------------------------
    def finding(self, ctx, node, message: str) -> Finding:
        """Build a Finding anchored at an AST node of ``ctx``'s file."""
        return Finding(
            rule_id=self.rule_id,
            severity=self.severity,
            path=ctx.relpath,
            line=getattr(node, "lineno", 1),
            col=getattr(node, "col_offset", 0) + 1,
            message=message,
        )


_REGISTRY: Dict[str, Rule] = {}


def register(rule):
    """Register a rule under its ``.rule_id`` (last wins). Accepts an
    instance or a Rule subclass (usable as a class decorator)."""
    instance = rule() if isinstance(rule, type) else rule
    if not instance.rule_id:
        raise ValueError(f"{type(instance).__name__} has an empty .rule_id")
    if instance.severity not in SEVERITIES:
        raise ValueError(
            f"{instance.rule_id}: severity {instance.severity!r} not in {SEVERITIES}"
        )
    _REGISTRY[instance.rule_id] = instance
    return rule


def _ensure_builtins():
    # Lazy so importing the registry alone never pulls the rule modules,
    # and so the builtin rules register no matter which entry point was
    # imported first — exactly core/backend.py's _ensure_builtins dance.
    from repro.analysis import rules  # noqa: F401


def get_rules(select=None) -> List[Rule]:
    """All registered rules sorted by id; ``select`` filters to those ids."""
    _ensure_builtins()
    rules = [_REGISTRY[k] for k in sorted(_REGISTRY)]
    if select:
        wanted = set(select)
        unknown = wanted - {r.rule_id for r in rules}
        if unknown:
            raise ValueError(
                f"unknown rule ids {sorted(unknown)}; registered: "
                f"{', '.join(sorted(_REGISTRY))}"
            )
        rules = [r for r in rules if r.rule_id in wanted]
    return rules
