"""File collection and rule execution.

``analyze_paths`` walks the given files/directories, parses every ``.py``
file into a ``ModuleContext``, runs each registered rule's module pass and
project pass, and filters ``# repro: noqa`` suppressions. Files that fail
to parse produce an ``RPR000`` parse-error finding instead of crashing the
run (the analyzer must never be the thing that breaks CI opaquely).

Directories named in ``DEFAULT_EXCLUDE_DIRS`` (caches, checked-in bad
fixtures) are skipped unless the caller opts out.
"""

from __future__ import annotations

import os
from typing import Iterable, List, Optional, Sequence, Tuple

from repro.analysis.context import ModuleContext, ProjectContext
from repro.analysis.registry import Finding, get_rules

DEFAULT_EXCLUDE_DIRS = frozenset(
    {
        ".git",
        "__pycache__",
        ".ruff_cache",
        ".pytest_cache",
        "build",
        "dist",
        # intentionally-violating rule fixtures live under a fixtures/ dir
        "fixtures",
    }
)


def collect_files(
    paths: Sequence[str], exclude_dirs: Iterable[str] = DEFAULT_EXCLUDE_DIRS
) -> List[str]:
    """Expand files/dirs into a sorted list of ``.py`` file paths."""
    exclude = set(exclude_dirs)
    out = []
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                out.append(p)
        elif os.path.isdir(p):
            for root, dirnames, filenames in os.walk(p):
                dirnames[:] = sorted(d for d in dirnames if d not in exclude)
                for f in sorted(filenames):
                    if f.endswith(".py"):
                        out.append(os.path.join(root, f))
        else:
            raise FileNotFoundError(f"no such file or directory: {p}")
    return sorted(dict.fromkeys(out))


def _module_name(path: str) -> str:
    """Dotted module name from a path; ``src/`` roots are stripped so
    ``src/repro/core/quant.py`` -> ``repro.core.quant``."""
    parts = os.path.normpath(path).split(os.sep)
    if parts and parts[-1].endswith(".py"):
        parts[-1] = parts[-1][: -len(".py")]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    if "src" in parts:
        parts = parts[parts.index("src") + 1 :]
    parts = [p for p in parts if p not in ("", ".", "..")]
    return ".".join(parts)


def build_project(files: Sequence[str]) -> Tuple[ProjectContext, List[Finding]]:
    """Parse every file; unparseable ones become RPR000 findings."""
    modules, errors = [], []
    for path in files:
        with open(path, "r", encoding="utf-8") as f:
            source = f.read()
        try:
            ctx = ModuleContext(path, source, relpath=os.path.relpath(path))
        except SyntaxError as e:
            errors.append(
                Finding(
                    rule_id="RPR000",
                    severity="error",
                    path=os.path.relpath(path),
                    line=e.lineno or 1,
                    col=(e.offset or 0) + 1,
                    message=f"file does not parse: {e.msg}",
                )
            )
            continue
        ctx.module_name = _module_name(path)
        modules.append(ctx)
    return ProjectContext(modules), errors


def _apply_noqa(project: ProjectContext, findings: Iterable[Finding]) -> List[Finding]:
    by_path = {m.relpath: m for m in project.modules}
    kept = []
    for f in findings:
        ctx = by_path.get(f.path)
        if ctx is not None and ctx.suppressed(f.rule_id, f.line):
            continue
        kept.append(f)
    return kept


def analyze_project(
    project: ProjectContext, select: Optional[Sequence[str]] = None
) -> List[Finding]:
    findings: List[Finding] = []
    rules = get_rules(select)
    for rule in rules:
        for ctx in project.modules:
            findings.extend(rule.check_module(ctx))
        findings.extend(rule.check_project(project))
    findings = _apply_noqa(project, findings)
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule_id))
    return findings


def analyze_paths(
    paths: Sequence[str],
    select: Optional[Sequence[str]] = None,
    exclude_dirs: Iterable[str] = DEFAULT_EXCLUDE_DIRS,
) -> Tuple[List[Finding], int]:
    """Run all (or ``select``-ed) rules over ``paths``.

    Returns (findings, files_analyzed). Parse failures surface as RPR000
    findings so a broken file fails the gate visibly.
    """
    files = collect_files(paths, exclude_dirs)
    project, parse_errors = build_project(files)
    findings = parse_errors + analyze_project(project, select)
    return findings, len(files)


def analyze_source(
    source: str,
    select: Optional[Sequence[str]] = None,
    path: str = "<string>",
) -> List[Finding]:
    """Analyze one in-memory snippet (the unit-test entry point)."""
    ctx = ModuleContext(path, source, relpath=path)
    ctx.module_name = _module_name(path) if path.endswith(".py") else ""
    project = ProjectContext([ctx])
    return analyze_project(project, select)
