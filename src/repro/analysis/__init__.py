"""repro.analysis: JAX/Pallas-aware static analysis for this repository.

An AST-based (stdlib ``ast``, zero new dependencies) rule framework that
checks the conventions the rest of the codebase relies on but pytest cannot
see on CPU interpret mode: jit recompile hazards in serving hot paths,
tracer leaks inside traced functions, PRNG-key reuse, Pallas kernel-wrapper
contracts (interpret routing, grid divisibility, accumulator dtypes),
quantized-value/scale companionship, and ``QuantBackend`` protocol
completeness.

Mirrors ``core/backend.py``'s one-file-per-rule self-registration pattern:
each rule lives in ``repro/analysis/rules/<rule>.py``, subclasses ``Rule``,
and calls ``register()`` at import time. Run it as::

    python -m repro.analysis src tests benchmarks

Findings can be suppressed inline with a justifying comment::

    step = jax.jit(build(cfg))  # repro: noqa[RPR001] fresh cfg per iteration

See ``src/repro/analysis/RULES.md`` for the rule catalogue.
"""

from repro.analysis.context import ModuleContext, ProjectContext
from repro.analysis.registry import Finding, Rule, get_rules, register
from repro.analysis.runner import analyze_paths, analyze_source

__all__ = [
    "Finding",
    "ModuleContext",
    "ProjectContext",
    "Rule",
    "analyze_paths",
    "analyze_source",
    "get_rules",
    "register",
]
