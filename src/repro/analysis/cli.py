"""``python -m repro.analysis`` / ``repro-analyze`` command line.

Text findings go to stdout (one per line, ``path:line:col RPRnnn ...``);
``--json-out`` additionally writes the machine-readable report CI uploads
as an artifact (mirroring the bench-smoke JSON convention). Exit status is
1 when any error-severity finding survives suppression, 2 on usage errors,
0 otherwise — warnings print but never fail the gate.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Optional, Sequence

from repro.analysis.registry import get_rules
from repro.analysis.runner import DEFAULT_EXCLUDE_DIRS, analyze_paths


def _build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="repro-analyze",
        description="JAX/Pallas-aware static analysis for the repro tree.",
    )
    p.add_argument(
        "paths",
        nargs="*",
        default=["src", "tests", "benchmarks"],
        help="files or directories to analyze (default: src tests benchmarks)",
    )
    p.add_argument(
        "--select",
        help="comma-separated rule ids to run (default: all registered rules)",
    )
    p.add_argument(
        "--format",
        choices=("text", "json"),
        default="text",
        help="stdout format (default: text)",
    )
    p.add_argument(
        "--json-out",
        metavar="FILE",
        help="also write the JSON report to FILE (CI artifact)",
    )
    p.add_argument(
        "--list-rules",
        action="store_true",
        help="print the rule catalogue and exit",
    )
    p.add_argument(
        "--no-default-excludes",
        action="store_true",
        help=f"also analyze {sorted(DEFAULT_EXCLUDE_DIRS)} directories",
    )
    return p


def _report(findings, n_files) -> dict:
    return {
        "tool": "repro.analysis",
        "files_analyzed": n_files,
        "errors": sum(1 for f in findings if f.severity == "error"),
        "warnings": sum(1 for f in findings if f.severity == "warning"),
        "findings": [f.to_dict() for f in findings],
    }


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = _build_parser().parse_args(argv)

    if args.list_rules:
        for rule in get_rules():
            print(f"{rule.rule_id} [{rule.severity}] {rule.description}")
        return 0

    select = [s.strip() for s in args.select.split(",")] if args.select else None
    exclude = () if args.no_default_excludes else DEFAULT_EXCLUDE_DIRS
    try:
        findings, n_files = analyze_paths(args.paths, select=select, exclude_dirs=exclude)
    except (FileNotFoundError, ValueError) as e:
        print(f"repro-analyze: {e}", file=sys.stderr)
        return 2

    report = _report(findings, n_files)
    if args.format == "json":
        print(json.dumps(report, indent=2))
    else:
        for f in findings:
            print(f.render())
        print(
            f"{n_files} files analyzed: {report['errors']} error(s), "
            f"{report['warnings']} warning(s)"
        )
    if args.json_out:
        with open(args.json_out, "w", encoding="utf-8") as fh:
            json.dump(report, fh, indent=2)
            fh.write("\n")

    return 1 if report["errors"] else 0


if __name__ == "__main__":
    sys.exit(main())
