"""Quaff decoupled quantized linear (paper Eq. 4/5/9).

    Y = X_hat @ W  +  x_hat @ w_hat
      X_hat = X * s_inv          (s_inv == 1 outside outlier channels O)
      x_hat = X_hat[:, O]
      w_hat = (s_O - 1) * W[O, :]

Quantized (Eq. 9):

    Y ~= Dx * (X_hat_int @ W_int) * Dw  +  Dx * (x_hat_int @ w_hat_int) * Dw_hat

where Dx is the shared per-token step of X_hat and x_hat_int is a column
gather of X_hat_int (no second quantization). W_int / Dw are computed ONCE
before fine-tuning and never touched again — this is the decoupling that
removes SmoothQuant-dynamic's per-step weight requantization.

The forward also emits max|X_:,O| — the statistic the momentum update (Eq. 7)
consumes — for free (the column slab is already materialized).

Gradients: W is frozen (PEFT), s is a state (non-diff). Only dX flows:
    dX = (dY @ W_eff^T) * s_inv,   W_eff = W + scatter_O(w_hat)
computed with one more INT8 GEMM (per-OC scale folded into dY) plus the tiny
fp outlier-correction GEMM.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import outliers as OUT
from repro.core import quant
from repro.core.backend import LinearOut, QuantBackend, register
from repro.core.scaling import ScaleState


class QuaffWeights(NamedTuple):
    """Preprocessed frozen weights for one linear layer (pytree).

    May carry a leading stack dim (L, ...) for scan-over-layers and/or an
    expert dim (E, ...) for MoE — the math is vmapped over leading dims.
    """

    w_int: jnp.ndarray       # (c_in, c_out) int8
    w_delta: jnp.ndarray     # (1, c_out) fp32, per output channel
    w_outlier: jnp.ndarray   # (n_o, c_out) fp32 — full-precision W_O rows
    outlier_idx: jnp.ndarray  # (n_o,) int32 — static channel indices
    bias: Optional[jnp.ndarray] = None  # (c_out,) fp32 or None

    @property
    def c_in(self) -> int:
        return self.w_int.shape[-2]

    @property
    def c_out(self) -> int:
        return self.w_int.shape[-1]


def prepare_quaff_weights(
    w: jnp.ndarray,
    outlier_idx: jnp.ndarray,
    bias: Optional[jnp.ndarray] = None,
    bits: int = 8,
) -> Tuple[QuaffWeights, ScaleState]:
    """One-time preprocessing (paper §3.3 'weights preprocessing'):
    quantize W per-OC, stash fp rows W_O, init momentum state from max|W_O|."""
    w_int, w_delta = quant.quantize(w, axis=0, bits=bits)
    w_outlier = jnp.take(w, outlier_idx, axis=0)
    weights = QuaffWeights(
        w_int=w_int,
        w_delta=w_delta.astype(jnp.float32),
        w_outlier=w_outlier.astype(jnp.float32),
        outlier_idx=outlier_idx.astype(jnp.int32),
        bias=None if bias is None else bias.astype(jnp.float32),
    )
    return weights, ScaleState.init(w_outlier)


def _scatter_s_inv(s: jnp.ndarray, idx: jnp.ndarray, c_in: int, dtype) -> jnp.ndarray:
    """Full (c_in,) vector of 1/s with ones off the outlier set."""
    s_inv = jnp.ones((c_in,), dtype=dtype)
    return s_inv.at[idx].set((1.0 / s).astype(dtype))


def _quaff_forward_impl(x2d, weights: QuaffWeights, s, bits: int):
    c_in = weights.w_int.shape[0]
    s = jnp.maximum(s, 1.0)
    s_inv = _scatter_s_inv(s, weights.outlier_idx, c_in, x2d.dtype)

    x_hat = x2d * s_inv[None, :]
    x_int, x_delta = quant.quantize(x_hat, axis=-1, bits=bits)

    # main INT8 GEMM against the never-rescaled W_int
    base = quant.int_matmul(x_int, weights.w_int).astype(jnp.float32)
    base = base * x_delta.astype(jnp.float32) * weights.w_delta

    # outlier correction: x_hat_int gather (Eq. 9: shares Dx, no requant)
    x_o_int = jnp.take(x_int, weights.outlier_idx, axis=1)  # (t, n_o) int8
    w_hat = (s - 1.0)[:, None] * weights.w_outlier          # (n_o, c_out)
    w_hat_int, w_hat_delta = quant.quantize(w_hat, axis=0, bits=bits)
    corr = quant.int_matmul(x_o_int, w_hat_int).astype(jnp.float32)
    corr = corr * x_delta.astype(jnp.float32) * w_hat_delta

    y = (base + corr).astype(x2d.dtype)
    if weights.bias is not None:
        y = y + weights.bias.astype(x2d.dtype)

    # OSSH statistic: max|X| on outlier channels of the *unscaled* input
    x_o = jnp.take(x2d, weights.outlier_idx, axis=1)
    stats = jnp.max(jnp.abs(x_o.astype(jnp.float32)), axis=0)  # (n_o,)
    return y, stats


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _quaff_matmul_2d(
    x2d: jnp.ndarray, weights: QuaffWeights, s: jnp.ndarray, bits: int = 8,
    bwd_int8: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    return _quaff_forward_impl(x2d, weights, s, bits)


def _quaff_fwd(x2d, weights, s, bits, bwd_int8):
    out = _quaff_matmul_2d(x2d, weights, s, bits, bwd_int8)
    return out, (weights, jnp.maximum(s, 1.0))


def _quaff_bwd(bits, bwd_int8, res, cts):
    weights, s = res
    g, _ = cts  # gradient w.r.t. stats is discarded (state, not loss path)

    if bwd_int8:
        # dX_hat = g @ W^T (INT8: fold per-OC w_delta into g, transpose GEMM)
        g2d = g.astype(jnp.float32)
        g_scaled = g2d * weights.w_delta
        g_int, g_delta = quant.quantize(g_scaled, axis=-1, bits=bits)
        dx_hat = (quant.int_matmul(g_int, weights.w_int.T).astype(jnp.float32)
                  * g_delta)
    else:
        # bf16 backward: dequantized transposed GEMM — the TP all-reduce of
        # dx moves bf16 instead of s32 (EXPERIMENTS.md SPerf iteration)
        g2d = g
        w_fp = quant.dequantize(weights.w_int, weights.w_delta, g.dtype)
        dx_hat = g @ w_fp.T

    # + outlier-correction backward (tiny fp GEMM, n_o columns)
    w_hat = ((s - 1.0)[:, None] * weights.w_outlier).astype(g2d.dtype)
    dx_o = g2d @ w_hat.T  # (t, n_o)
    dx_hat = dx_hat.at[:, weights.outlier_idx].add(dx_o.astype(dx_hat.dtype))

    c_in = weights.w_int.shape[0]
    s_inv = _scatter_s_inv(s, weights.outlier_idx, c_in, jnp.float32)
    dx = (dx_hat * s_inv[None, :].astype(dx_hat.dtype)).astype(g.dtype)
    return dx, None, None


_quaff_matmul_2d.defvjp(_quaff_fwd, _quaff_bwd)


def quaff_matmul(
    x: jnp.ndarray, weights: QuaffWeights, s: jnp.ndarray, bits: int = 8,
    bwd_int8: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (..., c_in) -> (y: (..., c_out), stats: (n_o,) max|X_:,O|)."""
    x2d = x.reshape((-1, x.shape[-1]))
    y, stats = _quaff_matmul_2d(x2d, weights, s, bits, bwd_int8)
    return y.reshape(x.shape[:-1] + (y.shape[-1],)), stats


# ---------------------------------------------------------------------------
# MoE variant: weights carry a leading expert dim (E, ...). The activation
# batch arrives pre-dispatched as (E, cap, c_in); s / outlier set are shared
# across experts of a layer (activation statistics are a property of the
# hidden stream, not of the expert — validated in tests/test_moe.py).
# ---------------------------------------------------------------------------
def quaff_matmul_experts(
    x: jnp.ndarray, weights: QuaffWeights, s: jnp.ndarray, bits: int = 8,
    bwd_int8: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (E, cap, c_in), weights.*: (E, ...) except outlier_idx (n_o,).

    Returns (y: (E, cap, c_out), stats: (n_o,) max over experts)."""
    def per_expert(xe, w_int, w_delta, w_outlier, bias):
        we = QuaffWeights(w_int, w_delta, w_outlier, weights.outlier_idx, bias)
        return quaff_matmul(xe, we, s, bits, bwd_int8)

    y, stats = jax.vmap(per_expert)(
        x, weights.w_int, weights.w_delta, weights.w_outlier,
        weights.bias if weights.bias is not None else jnp.zeros(
            (weights.w_int.shape[0], weights.w_int.shape[-1]), jnp.float32),
    )
    return y, jnp.max(stats, axis=0)


# ---------------------------------------------------------------------------
# Registry backend
# ---------------------------------------------------------------------------
def spread_indices(c_in: int, count: int) -> jnp.ndarray:
    """Deterministic placeholder outlier set used at init time; real runs
    overwrite it via calibration (see repro/train/calibrate.py)."""
    count = max(1, min(count, c_in))
    idx = (jnp.arange(count, dtype=jnp.int32) * (c_in // count)) % c_in
    # de-dup by construction: stride >= 1 and count <= c_in
    return jnp.sort(idx)


@register
class _QuaffBackend(QuantBackend):
    name = "quaff"
    wants_outliers = True

    def prepare(self, w, bias=None, *, calib=None, bits=8):
        idx = calib.outlier_idx if calib is not None else None
        if idx is None:
            if calib is None or not calib.init_placeholder:
                raise ValueError(
                    "quaff needs a calibrated outlier set "
                    "(Calibration.outlier_idx); pass init_placeholder=True "
                    "for the data-free spread-indices init")
            c_in = w.shape[-2]
            idx = spread_indices(
                c_in, OUT.outlier_count(c_in, calib.layer_type, calib.budgets))
        weights, _ = prepare_quaff_weights(w, jnp.asarray(idx), bias, bits)
        return weights

    def init_state(self, weights: QuaffWeights) -> ScaleState:
        return ScaleState.init(weights.w_outlier)

    @staticmethod
    def _s(state) -> jnp.ndarray:
        # fail loudly: a dropped ScaleState would otherwise freeze every
        # outlier scale at 1 and silently disable the paper's mechanism
        if state is None:
            raise ValueError(
                "quaff apply() needs its ScaleState (momentum scales); got "
                "None — thread the quant_state entry for this layer")
        return state.s

    def apply(self, x, weights, *, state=None, bits=8, bwd_int8=True):
        y, stats = quaff_matmul(x, weights, self._s(state), bits, bwd_int8)
        return LinearOut(y, stats)

    def apply_experts(self, x, weights, *, state=None, bits=8, bwd_int8=True):
        # per-expert W_int / W_O, layer-shared outlier set + scale state
        y, stats = quaff_matmul_experts(x, weights, self._s(state), bits,
                                        bwd_int8)
        return LinearOut(y, stats)

    def merge_expert_init(self, params_e, states_e):
        # collapse the expert dim of the scale state (shared across experts;
        # max|W| over experts is a safe normalizer upper bound); outlier_idx
        # must be expert-invariant, so drop the vmapped copies.
        states = jax.tree.map(lambda a: jnp.max(a, axis=0), states_e)

        def fix_idx(w):
            if isinstance(w, QuaffWeights):
                return w._replace(outlier_idx=w.outlier_idx[0])
            return w

        params_e = jax.tree.map(
            fix_idx, params_e,
            is_leaf=lambda v: isinstance(v, QuaffWeights))
        return params_e, states

    def collapse_expert_state(self, weights, state):
        # stacked (L, E, ...) conversion output -> expert dim (axis 1) shared
        state = jax.tree.map(lambda a: jnp.max(a, axis=1), state)
        weights = weights._replace(outlier_idx=weights.outlier_idx[:, 0])
        return weights, state
