"""PEFT methods evaluated in the paper (§4.1): LoRA, IA3, Prompt tuning,
P-tuning. Adapters are the ONLY trainable parameters — base weights are the
frozen quantized pytrees from core/baselines.py / core/quaff_linear.py.

Everything is functional: `init_*` builds a param pytree, `apply` combines
with the base layer output. Model code owns placement (which projections get
LoRA, where virtual tokens are injected).
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PEFTConfig:
    method: str = "lora"         # lora | ia3 | prompt | ptuning | none
    lora_rank: int = 16          # paper App. E
    lora_alpha: float = 16.0
    lora_dropout: float = 0.1    # applied only when deterministic=False
    n_virtual_tokens: int = 20   # paper App. E (prompt / p-tuning)
    ptuning_hidden: int = 128    # prompt-encoder MLP width


# ----------------------------- LoRA ---------------------------------------
class LoRAParams(NamedTuple):
    a: jnp.ndarray  # (c_in, r)
    b: jnp.ndarray  # (r, c_out)


def init_lora(key, c_in: int, c_out: int, rank: int, dtype=jnp.float32) -> LoRAParams:
    # Kaiming-uniform A, zero B (standard LoRA init: adapter starts as no-op)
    bound = 1.0 / math.sqrt(c_in)
    a = jax.random.uniform(key, (c_in, rank), dtype, -bound, bound)
    b = jnp.zeros((rank, c_out), dtype)
    return LoRAParams(a, b)


def apply_lora(x: jnp.ndarray, p: LoRAParams, alpha: float, rank: int,
               dropout: float = 0.0, key=None) -> jnp.ndarray:
    h = x
    if dropout > 0.0 and key is not None:
        keep = jax.random.bernoulli(key, 1.0 - dropout, x.shape)
        h = jnp.where(keep, x / (1.0 - dropout), 0.0).astype(x.dtype)
    return (h @ p.a.astype(x.dtype)) @ p.b.astype(x.dtype) * (alpha / rank)


# ----------------------------- IA3 ----------------------------------------
class IA3Params(NamedTuple):
    """Learned rescaling vectors: l_k, l_v on attention keys/values and l_ff
    on the FFN intermediate activation (Liu et al., 2022)."""
    l_k: jnp.ndarray   # (kv_dim,)
    l_v: jnp.ndarray   # (kv_dim,)
    l_ff: jnp.ndarray  # (d_ff,)


def init_ia3(kv_dim: int, d_ff: int, dtype=jnp.float32) -> IA3Params:
    return IA3Params(jnp.ones((kv_dim,), dtype), jnp.ones((kv_dim,), dtype),
                     jnp.ones((max(d_ff, 1),), dtype))


def apply_ia3(x: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return x * scale.astype(x.dtype)


# ------------------------- Prompt tuning -----------------------------------
class PromptParams(NamedTuple):
    embeddings: jnp.ndarray  # (n_virtual, d_model)


def init_prompt(key, n_virtual: int, d_model: int, dtype=jnp.float32) -> PromptParams:
    return PromptParams(jax.random.normal(key, (n_virtual, d_model), dtype) * 0.02)


def apply_prompt(input_embeds: jnp.ndarray, p: PromptParams) -> jnp.ndarray:
    """Prepend virtual tokens: (B, S, D) -> (B, S + n_virtual, D)."""
    b = input_embeds.shape[0]
    virt = jnp.broadcast_to(
        p.embeddings.astype(input_embeds.dtype)[None],
        (b,) + p.embeddings.shape,
    )
    return jnp.concatenate([virt, input_embeds], axis=1)


# --------------------------- P-tuning --------------------------------------
class PTuningParams(NamedTuple):
    """Continuous prompts produced by a small MLP prompt-encoder (Liu et al.,
    2021). The encoder is trainable; raw embeddings are its input."""
    raw: jnp.ndarray   # (n_virtual, d_model)
    w1: jnp.ndarray    # (d_model, hidden)
    b1: jnp.ndarray
    w2: jnp.ndarray    # (hidden, d_model)
    b2: jnp.ndarray


def init_ptuning(key, n_virtual: int, d_model: int, hidden: int,
                 dtype=jnp.float32) -> PTuningParams:
    k1, k2, k3 = jax.random.split(key, 3)
    return PTuningParams(
        raw=jax.random.normal(k1, (n_virtual, d_model), dtype) * 0.02,
        w1=jax.random.normal(k2, (d_model, hidden), dtype) / math.sqrt(d_model),
        b1=jnp.zeros((hidden,), dtype),
        w2=jax.random.normal(k3, (hidden, d_model), dtype) / math.sqrt(hidden),
        b2=jnp.zeros((d_model,), dtype),
    )


def apply_ptuning(input_embeds: jnp.ndarray, p: PTuningParams) -> jnp.ndarray:
    h = jnp.tanh(p.raw @ p.w1 + p.b1)
    virt = (h @ p.w2 + p.b2).astype(input_embeds.dtype)
    b = input_embeds.shape[0]
    virt = jnp.broadcast_to(virt[None], (b,) + virt.shape)
    return jnp.concatenate([virt, input_embeds], axis=1)


def n_prefix_tokens(cfg: PEFTConfig) -> int:
    return cfg.n_virtual_tokens if cfg.method in ("prompt", "ptuning") else 0
