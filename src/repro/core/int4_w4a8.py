"""INT4-weight / INT8-activation mixed backend (OWQ-style fine-tuning mode).

Weights: packed-nibble 4-bit with group-wise (or per-OC) scales — exactly
``core/int4.py``'s carrier, so the frozen tree is byte-identical in size.
Activations: per-token INT8 (the 16x finer grid is what makes 4-bit weights
usable for fine-tuning on outlier-heavy activations; weight error dominates,
activation error stays at W8A8 levels).

Shares ``prepare_int4_weights`` / the packed GEMM with the w4a4 backend —
the two modes differ in ONE number (``x_bits``), which is the point of the
packed-matmul primitive taking activation bits as an argument.
"""
from __future__ import annotations

from repro.core import int4 as _int4
from repro.core.backend import QuantBackend, register

X_BITS = 8


@register
class _Int4W4A8Backend(QuantBackend):
    name = "int4_w4a8"
    weight_carrier = "int4"

    def prepare(self, w, bias=None, *, calib=None, bits=8):
        group_size = calib.group_size if calib is not None else 0
        return _int4.prepare_int4_weights(w, bias, group_size)

    def apply(self, x, weights, *, state=None, bits=8, bwd_int8=True):
        return _int4._apply_packed(x, weights, X_BITS, bwd_int8,
                                   _int4.USE_PALLAS_KERNEL)
