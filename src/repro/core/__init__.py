"""Quaff core: quantization primitives, outlier identification, momentum
scaling, the decoupled Quaff linear, WAQ baselines, and PEFT adapters."""
from repro.core.baselines import QuantMode, qlinear, prepare  # noqa: F401
from repro.core.quaff_linear import (  # noqa: F401
    QuaffWeights,
    prepare_quaff_weights,
    quaff_matmul,
)
from repro.core.scaling import ScaleState, momentum_update  # noqa: F401
