"""Quaff core: quantization primitives, outlier identification, momentum
scaling, the decoupled Quaff linear, WAQ baselines, the QuantBackend
registry, and PEFT adapters."""
from repro.core.backend import (  # noqa: F401
    CAPTURE,
    Calibration,
    LinearOut,
    QuantBackend,
    StatsScope,
    get_backend,
    register,
    registered_modes,
)
from repro.core.baselines import QuantMode, prepare, qlinear  # noqa: F401
from repro.core.int4 import Int4Weights  # noqa: F401
from repro.core.quaff_linear import (  # noqa: F401
    QuaffWeights,
    prepare_quaff_weights,
    quaff_matmul,
)
from repro.core.scaling import ScaleState, momentum_update  # noqa: F401
