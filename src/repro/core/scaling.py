"""Targeted momentum scaling (paper Eq. 7/8).

The per-layer scale state lives in a pytree (``ScaleState``) threaded through
``train_step`` functionally:

    s_t = gamma * s_{t-1} + (1 - gamma) * beta_t                     (Eq. 7)
    beta_i = max(1, sqrt(max|X_:,i| / max|W_i|))   for i in O        (Eq. 8)

Only the |O| outlier channels carry state — non-outlier channels are
implicitly s == 1 (never stored), which is what makes the mechanism cheap.
``w_absmax`` (max|W_i| over the outlier rows) is precomputed at quantization
time and folded into the state so the runtime update touches activations only.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

DEFAULT_GAMMA = 0.2  # paper App. E


class ScaleState(NamedTuple):
    """Momentum scale state for one Quaff linear layer."""

    s: jnp.ndarray          # (n_outliers,) current scale factors, >= 1
    w_absmax: jnp.ndarray   # (n_outliers,) max|W_i| over outlier rows (static)

    @classmethod
    def init(cls, w_outlier_rows: jnp.ndarray) -> "ScaleState":
        """w_outlier_rows: (n_outliers, c_out) fp rows of W at O."""
        w_absmax = jnp.maximum(jnp.max(jnp.abs(w_outlier_rows), axis=-1), 1e-8)
        return cls(s=jnp.ones_like(w_absmax), w_absmax=w_absmax)


def beta_from_stats(x_absmax_outlier: jnp.ndarray, w_absmax: jnp.ndarray) -> jnp.ndarray:
    """Eq. 8 on the outlier channels only (non-outliers are identically 1)."""
    return jnp.maximum(1.0, jnp.sqrt(x_absmax_outlier / jnp.maximum(w_absmax, 1e-8)))


def momentum_update(
    state: ScaleState, x_absmax_outlier: jnp.ndarray, gamma: float = DEFAULT_GAMMA
) -> ScaleState:
    """One Eq. 7 step. ``x_absmax_outlier``: (n_outliers,) max|X_:,O| observed
    in the current step's forward (emitted as a side output of the matmul)."""
    beta = beta_from_stats(x_absmax_outlier, state.w_absmax)
    s_new = gamma * state.s + (1.0 - gamma) * beta
    return state._replace(s=s_new)
