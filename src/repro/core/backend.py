"""QuantBackend registry: the one extension point for quantized-linear modes.

Every weight-activation-quantization scheme (fp32 reference, naive WAQ,
LLM.int8, SmoothQuant static/dynamic, Quaff, int4, ...) is a ``QuantBackend``
registered under its mode name. Model code (``models/layers.py``) never
branches on the mode — it resolves the backend once and calls the protocol:

    prepare(w, bias, *, calib, bits)  -> frozen weights pytree (one-time)
    apply(x, weights, *, state, bits, bwd_int8) -> LinearOut(y, stats)
    init_state(weights)               -> optional per-layer scale state

Adding a mode is one self-registering file (see ``core/int4.py`` for the
canonical example): define the weights NamedTuple, subclass ``QuantBackend``,
call ``register()`` at import time. MoE and calibration hooks have default
implementations so simple backends need only the three methods above.

``StatsScope`` replaces the old module-global capture flag: stats
capture is an explicit, trace-safe argument threaded through
``apply_qlinear`` and every model forward. Because the captured statistic
changes shape ((c_in,) full absmax vs the backend's own stats), the scope is
static Python data baked in at trace time — exactly like the old flag, but
visible in the call signature and safe under nested/concurrent traces.
"""
from __future__ import annotations

from typing import Dict, Mapping, NamedTuple, Optional

import jax
import jax.numpy as jnp


class LinearOut(NamedTuple):
    """Typed output of one quantized linear application."""

    y: jnp.ndarray
    stats: Optional[jnp.ndarray] = None  # backend-defined (Quaff: max|X_:,O|)


class Calibration(NamedTuple):
    """Calibration artifacts handed to ``prepare``.

    ``init_placeholder=True`` marks an init-time call (random weights, no
    data seen yet): backends substitute documented placeholders for missing
    artifacts (smooth_static: unit absmax; quaff: spread outlier set) that
    real runs overwrite via train/calibrate. Without the flag, a backend
    that requires an artifact must raise rather than silently degrade."""

    absmax: Optional[jnp.ndarray] = None       # (c_in,) activation absmax
    outlier_idx: Optional[jnp.ndarray] = None  # (n_o,) selected channels
    layer_type: str = ""                       # q_proj / down_proj / ...
    budgets: Optional[Mapping[str, float]] = None  # per-layer-type fractions
    init_placeholder: bool = False             # init-time defaults allowed
    group_size: int = 0                        # group-wise weight scales
                                               # (0 = per-OC; int4 backends)


class StatsScope(NamedTuple):
    """Explicit stats-capture request threaded through ``apply_qlinear``.

    capture=True makes every qlinear emit the FULL per-channel absmax
    (c_in,) of its input instead of the backend's own stats. Used by
    calibration (outlier identification) and the OSSH hit-rate benchmark.
    Never combined with momentum updates."""

    capture: bool = False


#: Convenience scope for calibration / hit-rate capture passes.
CAPTURE = StatsScope(capture=True)


class QuantBackend:
    """Protocol base class. Subclass, set ``name``, implement prepare/apply."""

    name: str = ""
    #: frozen-weights format this backend consumes; backends sharing a
    #: carrier accept each other's prepared trees byte-for-byte (int4 and
    #: int4_w4a8 both read Int4Weights). "" means the carrier is the mode
    #: itself. Self-speculative decoding pairs draft/target by carrier.
    weight_carrier: str = ""
    #: convert() supplies calibration-time activation absmax to prepare()
    wants_absmax: bool = False
    #: convert() supplies selected outlier channel indices to prepare()
    wants_outliers: bool = False

    # ---- required -------------------------------------------------------
    def prepare(self, w, bias=None, *, calib: Optional[Calibration] = None,
                bits: int = 8):
        """Build the frozen per-layer weights pytree from fp W (c_in, c_out)."""
        raise NotImplementedError

    def apply(self, x, weights, *, state=None, bits: int = 8,
              bwd_int8: bool = True) -> LinearOut:
        """x: (..., c_in) -> LinearOut(y: (..., c_out), stats-or-None)."""
        raise NotImplementedError

    # ---- optional -------------------------------------------------------
    def init_state(self, weights):
        """Per-layer mutable scale state (threaded through train steps)."""
        return None

    def apply_experts(self, x, weights, *, state=None, bits: int = 8,
                      bwd_int8: bool = True) -> LinearOut:
        """MoE expert-batched apply. x: (E, cap, c_in); ``weights`` leaves
        carry a leading expert dim. Default: vmap ``apply`` over experts."""
        def one(xe, we):
            return self.apply(xe, we, state=state, bits=bits,
                              bwd_int8=bwd_int8)
        return jax.vmap(one)(x, weights)

    def merge_expert_init(self, params_e, states_e):
        """Post-init hook for per-expert stacked weights/states of one MoE
        layer ((E, ...) leading dim). Backends with layer-shared state (Quaff:
        outlier set + momentum scale are properties of the hidden stream, not
        the expert) collapse the expert dim here. Default: no-op."""
        return params_e, states_e

    def collapse_expert_state(self, weights, state):
        """Conversion-time analogue of ``merge_expert_init`` for stacked
        (L, E, ...) trees produced by ``train/calibrate.convert``; the expert
        dim is axis 1. Default: no-op."""
        return weights, state


_REGISTRY: Dict[str, QuantBackend] = {}


def register(backend) -> QuantBackend:
    """Register a backend under its ``.name`` (last wins). Accepts an
    instance or a QuantBackend subclass (usable as a class decorator)."""
    instance = backend() if isinstance(backend, type) else backend
    if not instance.name:
        raise ValueError(f"{type(instance).__name__} has an empty .name")
    _REGISTRY[instance.name] = instance
    return backend


def _ensure_builtins():
    # Lazy so `import repro.core.backend` alone never pulls jax-heavy math,
    # and so the builtin modules (which import this one) register themselves
    # no matter which entry point was imported first.
    from repro.core import (  # noqa: F401
        baselines, int4, int4_w4a8, quaff_linear)


def get_backend(mode) -> QuantBackend:
    """Resolve a mode (str or enum with .value) to its backend."""
    key = getattr(mode, "value", mode)
    _ensure_builtins()
    try:
        return _REGISTRY[key]
    except KeyError:
        raise ValueError(
            f"unknown quant mode {key!r}; registered modes: "
            f"{', '.join(registered_modes())}"
        ) from None


def registered_modes():
    _ensure_builtins()
    return sorted(_REGISTRY)
