"""INT4 weight-activation quantization backend — the proof that a new mode
is ONE self-registering file under the ``QuantBackend`` registry.

Per-OC symmetric 4-bit weights + per-token 4-bit activations (paper Eq. 1/2
granularities at bits=4). The int values still ride in int8 containers
(`quant.quantize` clips to ±7), so the same integer GEMM path applies; a
packed-nibble layout is a kernel-level concern, not a protocol one.

No calibration artifacts, no scale state: ``prepare`` + ``apply`` is the
whole contract. Everything else (init_qlinear, apply_qlinear, MoE experts,
calibration conversion, the repro.api facade, serving) picks it up from the
registry with zero edits elsewhere — `QuantConfig(mode="int4")` just works.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp

from repro.core import quant
from repro.core.backend import LinearOut, QuantBackend, register

BITS = 4


class Int4Weights(NamedTuple):
    w_int: jnp.ndarray       # (c_in, c_out), values in [-7, 7] (int8 carrier)
    w_delta: jnp.ndarray     # (1, c_out) per-OC step
    bias: Optional[jnp.ndarray] = None


@register
class _Int4Backend(QuantBackend):
    name = "int4"

    def prepare(self, w, bias=None, *, calib=None, bits=8):
        # bits is the config-wide knob; this backend is 4-bit by definition
        w_int, w_delta = quant.quantize(w, axis=0, bits=BITS)
        return Int4Weights(w_int, w_delta, bias)

    def apply(self, x, weights, *, state=None, bits=8, bwd_int8=True):
        y = quant.quantized_matmul(x, weights.w_int, weights.w_delta, BITS,
                                   bwd_int8)
        if weights.bias is not None:
            y = y + weights.bias.astype(y.dtype)
        return LinearOut(y)
