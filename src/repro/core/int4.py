"""INT4 weight-activation quantization backend, packed-nibble edition.

Per-group (or per-OC when ``QuantConfig.group_size`` is 0) symmetric 4-bit
weights stored as TWO SIGNED NIBBLES PER INT8 BYTE (``quant.pack_int4``
split-half layout), plus per-token 4-bit activations. Packing halves the
frozen weight bytes for real — ``bits=4`` stops being a protocol fiction
carried in int8 containers.

The integer GEMM runs against the unpacked nibbles
(``quant.quantized_matmul_packed``); setting ``USE_PALLAS_KERNEL`` (or the
``REPRO_INT4_PALLAS=1`` environment knob) routes the forward through the
fused unpack-dequant-GEMM Pallas kernel in ``kernels/int4_matmul.py`` —
identical integer math, one pass over the packed bytes.

No calibration artifacts, no scale state: ``prepare`` + ``apply`` is the
whole contract. Everything else (init_qlinear, apply_qlinear, MoE experts,
calibration conversion, the repro.api facade, serving) picks it up from the
registry with zero edits elsewhere — `QuantConfig(mode="int4")` just works.
"""
from __future__ import annotations

import os
from typing import NamedTuple, Optional

import jax.numpy as jnp

from repro.core import quant
from repro.core.backend import LinearOut, QuantBackend, register

BITS = 4

#: Route backend forwards through the Pallas fused kernel (interpret-mode on
#: CPU). Off by default: the pure-jnp path is the oracle and compiles leaner
#: at CPU test scale; tests flip this to prove the wiring.
USE_PALLAS_KERNEL = os.environ.get(
    "REPRO_INT4_PALLAS", "").lower() in ("1", "true", "yes")


class Int4Weights(NamedTuple):
    w_packed: jnp.ndarray    # (c_in // 2, c_out) int8 — two nibbles per byte
    w_delta: jnp.ndarray     # (G, c_out) group steps (G == 1: per-OC)
    bias: Optional[jnp.ndarray] = None


def prepare_int4_weights(w, bias=None, group_size: int = 0) -> Int4Weights:
    """Group-quantize at 4 bits and pack two nibbles per byte (shared by the
    w4a4 and w4a8 backends)."""
    if w.shape[-2] % 2:
        raise ValueError(
            f"int4 packing needs an even c_in, got {w.shape[-2]}")
    w_int, w_delta = quant.quantize_grouped(w, group_size, bits=BITS)
    return Int4Weights(quant.pack_int4(w_int), w_delta, bias)


def _apply_packed(x, weights: Int4Weights, x_bits: int, bwd_int8: bool,
                  use_kernel: bool) -> LinearOut:
    y = quant.quantized_matmul_packed(
        x, weights.w_packed, weights.w_delta, x_bits, bwd_int8, use_kernel)
    if weights.bias is not None:
        y = y + weights.bias.astype(y.dtype)
    return LinearOut(y)


@register
class _Int4Backend(QuantBackend):
    """w4a4: packed 4-bit weights x per-token 4-bit activations."""

    name = "int4"
    weight_carrier = "int4"

    def prepare(self, w, bias=None, *, calib=None, bits=8):
        # bits is the config-wide knob; this backend is 4-bit by definition
        group_size = calib.group_size if calib is not None else 0
        return prepare_int4_weights(w, bias, group_size)

    def apply(self, x, weights, *, state=None, bits=8, bwd_int8=True):
        return _apply_packed(x, weights, BITS, bwd_int8, USE_PALLAS_KERNEL)
