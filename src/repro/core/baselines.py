"""WAQ baselines the paper compares against (§4.1, App. A), each packaged as
a registered ``QuantBackend`` so every model in the zoo can run every mode
without a single mode branch outside the registry.

  fp32            : plain fp GEMM (paper's FP32 row).
  naive           : per-token / per-OC INT8 WAQ, Eq. 2.
  llm_int8        : LLM.int8 mixed-precision decomposition (Eq. 10). Runtime
                    outlier columns (|x| > threshold) are computed in fp
                    against the RETAINED fp weights; the rest in INT8. The fp
                    weight residency is the point — it is the memory cost the
                    paper measures. XLA needs static shapes, so the split is a
                    mask, not a gather (faithful cost, identical math).
  smooth_static   : SmoothQuant with calibration-fixed s on ALL channels; W is
                    pre-scaled+quantized once. Cheap but drifts (Fig. 11).
  smooth_dynamic  : s recomputed from live activations each call; forces a
                    per-step rescale + requantize of the FP weights (Eq. 3) —
                    the coupling bottleneck Quaff removes.

Quaff itself registers from ``core/quaff_linear.py``; the int4 proof-of-
extension backend from ``core/int4.py``.
"""
from __future__ import annotations

import enum
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.backend import (
    Calibration,
    LinearOut,
    QuantBackend,
    get_backend,
    register,
)


class QuantMode(str, enum.Enum):
    """Canonical mode names. The registry accepts any registered string —
    this enum just enumerates the paper's baseline set for configs/docs."""

    FP32 = "fp32"
    NAIVE = "naive"
    LLM_INT8 = "llm_int8"
    SMOOTH_STATIC = "smooth_static"
    SMOOTH_DYNAMIC = "smooth_dynamic"
    QUAFF = "quaff"
    INT4 = "int4"


class FPWeights(NamedTuple):
    w: jnp.ndarray
    bias: Optional[jnp.ndarray] = None


class NaiveWeights(NamedTuple):
    w_int: jnp.ndarray
    w_delta: jnp.ndarray
    bias: Optional[jnp.ndarray] = None


class LLMInt8Weights(NamedTuple):
    w_int: jnp.ndarray
    w_delta: jnp.ndarray
    w_fp: jnp.ndarray              # full fp weights retained (the memory cost)
    bias: Optional[jnp.ndarray] = None


class SmoothStaticWeights(NamedTuple):
    w_int: jnp.ndarray             # Q(s * W), pre-scaled at calibration
    w_delta: jnp.ndarray
    s_inv: jnp.ndarray             # (c_in,) 1/s from calibration
    bias: Optional[jnp.ndarray] = None


class SmoothDynamicWeights(NamedTuple):
    w_fp: jnp.ndarray              # fp weights retained for per-step rescale
    w_absmax: jnp.ndarray          # (c_in,) max|W_i| (precomputed)
    bias: Optional[jnp.ndarray] = None


LLM_INT8_THRESHOLD = 6.0  # paper App. A sigma
SMOOTH_ALPHA = 0.5        # SmoothQuant migration strength


def _add_bias(y, bias, dtype):
    return y if bias is None else y + bias.astype(dtype)


def fp32_linear(x, wts: FPWeights):
    y = x @ wts.w.astype(x.dtype)
    return _add_bias(y, wts.bias, x.dtype)


def naive_linear(x, wts: NaiveWeights, bits: int = 8, bwd_int8: bool = True):
    y = quant.quantized_matmul(x, wts.w_int, wts.w_delta, bits, bwd_int8)
    return _add_bias(y, wts.bias, x.dtype)


def llm_int8_linear(x, wts: LLMInt8Weights, bits: int = 8,
                    threshold: float = LLM_INT8_THRESHOLD,
                    bwd_int8: bool = True):
    x2d = x.reshape((-1, x.shape[-1]))
    col_max = jnp.max(jnp.abs(jax.lax.stop_gradient(x2d)), axis=0)  # (c_in,)
    is_out = (col_max > threshold).astype(x.dtype)                  # dynamic O
    x_in = x2d * (1.0 - is_out)[None, :]
    x_out = x2d * is_out[None, :]
    y_q = quant.quantized_matmul(x_in, wts.w_int, wts.w_delta, bits, bwd_int8)
    y_fp = x_out @ wts.w_fp.astype(x.dtype)   # fp path, needs resident fp W
    y = (y_q + y_fp).reshape(x.shape[:-1] + (wts.w_int.shape[-1],))
    return _add_bias(y, wts.bias, x.dtype)


def smooth_static_linear(x, wts: SmoothStaticWeights, bits: int = 8,
                         bwd_int8: bool = True):
    x_hat = x * wts.s_inv.astype(x.dtype)[None, :]
    y = quant.quantized_matmul(x_hat, wts.w_int, wts.w_delta, bits, bwd_int8)
    return _add_bias(y, wts.bias, x.dtype)


def smooth_dynamic_linear(x, wts: SmoothDynamicWeights, bits: int = 8,
                          bwd_int8: bool = True):
    """Per-call: s from live stats, rescale + requantize W (the cost), then
    INT8 GEMM. Requantization is inside the step = the paper's Smooth_D row."""
    x2d = x.reshape((-1, x.shape[-1]))
    x_absmax = jnp.maximum(
        jnp.max(jnp.abs(jax.lax.stop_gradient(x2d)), axis=0), 1e-8
    )
    s = jnp.maximum(
        (x_absmax ** SMOOTH_ALPHA) / (wts.w_absmax ** (1 - SMOOTH_ALPHA)), 1e-4
    )
    w_int, w_delta = quant.quantize(s[:, None] * wts.w_fp, axis=0, bits=bits)
    x_hat = x2d * (1.0 / s).astype(x.dtype)[None, :]
    y = quant.quantized_matmul(x_hat, w_int, w_delta, bits, bwd_int8)
    y = y.reshape(x.shape[:-1] + (wts.w_fp.shape[-1],))
    return _add_bias(y, wts.bias, x.dtype)


# ---------------------------------------------------------------------------
# Registered backends
# ---------------------------------------------------------------------------
@register
class _FP32Backend(QuantBackend):
    name = "fp32"

    def prepare(self, w, bias=None, *, calib=None, bits=8):
        return FPWeights(w, bias)

    def apply(self, x, weights, *, state=None, bits=8, bwd_int8=True):
        return LinearOut(fp32_linear(x, weights))


@register
class _NaiveBackend(QuantBackend):
    name = "naive"

    def prepare(self, w, bias=None, *, calib=None, bits=8):
        w_int, w_delta = quant.quantize(w, axis=0, bits=bits)
        return NaiveWeights(w_int, w_delta, bias)

    def apply(self, x, weights, *, state=None, bits=8, bwd_int8=True):
        return LinearOut(naive_linear(x, weights, bits, bwd_int8))


@register
class _LLMInt8Backend(QuantBackend):
    name = "llm_int8"

    def prepare(self, w, bias=None, *, calib=None, bits=8):
        w_int, w_delta = quant.quantize(w, axis=0, bits=bits)
        return LLMInt8Weights(w_int, w_delta, w, bias)

    def apply(self, x, weights, *, state=None, bits=8, bwd_int8=True):
        return LinearOut(llm_int8_linear(x, weights, bits, bwd_int8=bwd_int8))


@register
class _SmoothStaticBackend(QuantBackend):
    name = "smooth_static"
    wants_absmax = True

    def prepare(self, w, bias=None, *, calib=None, bits=8):
        if calib is not None and calib.absmax is not None:
            absmax = calib.absmax
        elif calib is not None and calib.init_placeholder:
            absmax = jnp.ones((w.shape[-2],), jnp.float32)
        else:
            raise ValueError(
                "smooth_static needs calibration stats (Calibration.absmax); "
                "pass init_placeholder=True for data-free init")
        w_absmax = jnp.maximum(jnp.max(jnp.abs(w), axis=1), 1e-8)
        s = jnp.maximum(
            (absmax ** SMOOTH_ALPHA) / (w_absmax ** (1 - SMOOTH_ALPHA)), 1e-4
        )
        w_int, w_delta = quant.quantize(s[:, None] * w, axis=0, bits=bits)
        return SmoothStaticWeights(w_int, w_delta, 1.0 / s, bias)

    def apply(self, x, weights, *, state=None, bits=8, bwd_int8=True):
        return LinearOut(smooth_static_linear(x, weights, bits, bwd_int8))


@register
class _SmoothDynamicBackend(QuantBackend):
    name = "smooth_dynamic"

    def prepare(self, w, bias=None, *, calib=None, bits=8):
        w_absmax = jnp.maximum(jnp.max(jnp.abs(w), axis=1), 1e-8)
        return SmoothDynamicWeights(w, w_absmax, bias)

    def apply(self, x, weights, *, state=None, bits=8, bwd_int8=True):
        return LinearOut(smooth_dynamic_linear(x, weights, bits, bwd_int8))


# ---------------------------------------------------------------------------
# Thin compatibility wrappers (registry-backed, no mode branching)
# ---------------------------------------------------------------------------
def prepare(mode, w, bias=None, *, calib_absmax=None, bits: int = 8):
    """Build the per-mode frozen weight pytree from fp W (c_in, c_out)."""
    calib = Calibration(absmax=calib_absmax)
    return get_backend(mode).prepare(w, bias, calib=calib, bits=bits)


def qlinear(x, wts, mode, s: Optional[jnp.ndarray] = None, bits: int = 8,
            bwd_int8: bool = True
            ) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """Registry dispatch. Returns (y, stats-or-None). ``s`` only for Quaff."""
    state = None
    if s is not None:
        from repro.core.scaling import ScaleState
        state = ScaleState(s=s, w_absmax=jnp.ones_like(s))
    out = get_backend(mode).apply(x, wts, state=state, bits=bits,
                                  bwd_int8=bwd_int8)
    return out.y, out.stats
