"""Outlier channel identification (paper §3.3, Eq. 6) with non-uniform
per-layer-type budgets (§4.1, App. B).

The paper's criterion counts, over calibration samples, how often a channel's
max magnitude exceeds ``ratio`` x the typical magnitude of the sample:

    xi_o = sum_i 1[ max|X^i_{:,o}| > ratio * typical(|X^i|) ]        (Eq. 6)

(The paper writes ``100 * max(|X^i|)`` which is a typo — a channel max can
never exceed the global max; the cited outlier literature (LLM.int8,
SmoothQuant) defines outliers as ~100x the *typical* magnitude. We use the
per-sample mean absolute value as "typical" and keep ``ratio`` configurable.)

Budgets are per layer *type* (q/k/v/up: 0.03%, o_proj: 4%, down_proj: 10%)
with reallocation so the model-wide overhead stays < ``total_budget`` (5%).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# paper §4.1 budgets, fraction of c_in per layer type
DEFAULT_BUDGETS: Dict[str, float] = {
    "q_proj": 0.0003,
    "k_proj": 0.0003,
    "v_proj": 0.0003,
    "up_proj": 0.0003,
    "gate_proj": 0.0003,
    "o_proj": 0.04,
    "down_proj": 0.10,
}
DEFAULT_BUDGET_FALLBACK = 0.01  # layer types the paper does not name
TOTAL_BUDGET = 0.05


@dataclasses.dataclass(frozen=True)
class OutlierSpec:
    """Static outlier-channel set for one linear layer (fixed before FT)."""

    indices: Tuple[int, ...]  # sorted channel indices, len == n_outliers

    @property
    def count(self) -> int:
        return len(self.indices)


def budget_for(layer_type: str, budgets: Optional[Mapping[str, float]] = None) -> float:
    budgets = budgets or DEFAULT_BUDGETS
    for key, frac in budgets.items():
        if key in layer_type:
            return frac
    return DEFAULT_BUDGET_FALLBACK


def outlier_count(c_in: int, layer_type: str,
                  budgets: Optional[Mapping[str, float]] = None) -> int:
    """Channel count for one layer under the per-type budget (>= 1, <= c_in).
    The single source of truth shared by init-time placeholder selection and
    calibration-time top-k conversion."""
    return max(1, min(c_in, int(round(budget_for(layer_type, budgets) * c_in))))


def outlier_scores(acts: jnp.ndarray, ratio: float = 20.0) -> jnp.ndarray:
    """xi per channel from calibration activations (n_samples, tokens, c_in).

    Counts samples whose channel max exceeds ratio x the sample's mean |X|.
    Ties broken by mean channel magnitude so top-k selection is stable.
    """
    a = jnp.abs(acts)
    chan_max = jnp.max(a, axis=1)  # (n, c_in)
    typical = jnp.mean(a, axis=(1, 2), keepdims=False)[:, None]  # (n, 1)
    hits = (chan_max > ratio * typical).astype(jnp.float32)
    xi = jnp.sum(hits, axis=0)
    # small tiebreaker keeps argsort deterministic and favours hot channels
    mag = jnp.mean(chan_max, axis=0)
    return xi + mag / (jnp.max(mag) + 1e-9)


def identify_outliers(
    acts: jnp.ndarray,
    layer_type: str,
    *,
    ratio: float = 20.0,
    budgets: Optional[Mapping[str, float]] = None,
    min_count: int = 1,
) -> OutlierSpec:
    """Pick the top-``budget * c_in`` channels by xi score for one layer."""
    c_in = acts.shape[-1]
    frac = budget_for(layer_type, budgets)
    k = max(min_count, int(round(frac * c_in)))
    k = min(k, c_in)
    xi = np.asarray(outlier_scores(acts, ratio))
    idx = np.argsort(-xi)[:k]
    return OutlierSpec(indices=tuple(sorted(int(i) for i in idx)))


def reallocate_budgets(
    layer_dims: Mapping[str, int],
    budgets: Optional[Mapping[str, float]] = None,
    total_budget: float = TOTAL_BUDGET,
) -> Dict[str, int]:
    """Global budget check (paper: reallocate from outlier-poor layers like
    q_proj to outlier-rich ones like down_proj, keeping sum < 5% of all c_in).

    layer_dims: layer_name -> c_in. Returns layer_name -> channel count.
    If the per-type budgets already satisfy the total, they are returned
    as-is; otherwise counts are scaled down proportionally (largest first).
    """
    counts = {
        name: max(1, int(round(budget_for(name, budgets) * c_in)))
        for name, c_in in layer_dims.items()
    }
    cap = int(total_budget * sum(layer_dims.values()))
    excess = sum(counts.values()) - cap
    if excess > 0:
        # shave proportionally from the biggest consumers
        order = sorted(counts, key=lambda n: -counts[n])
        total = sum(counts.values())
        for name in order:
            take = min(counts[name] - 1, int(np.ceil(excess * counts[name] / total)))
            counts[name] -= take
            excess -= take
            if excess <= 0:
                break
    return counts


def hit_rate(
    predefined: Sequence[int], acts: jnp.ndarray, ratio: float = 20.0
) -> float:
    """Fraction of *runtime* outlier channels covered by the predefined set
    (paper Fig. 3 metric). acts: (tokens, c_in) from one step."""
    a = jnp.abs(acts)
    chan_max = jnp.max(a, axis=0)
    typical = jnp.mean(a)
    runtime = np.nonzero(np.asarray(chan_max > ratio * typical))[0]
    if runtime.size == 0:
        return 1.0
    pre = set(int(i) for i in predefined)
    return float(sum(1 for i in runtime if int(i) in pre)) / float(runtime.size)
