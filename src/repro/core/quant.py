"""Symmetric round-to-nearest quantization primitives (paper Eq. 1, App. F).

Granularities:
  per-tensor : one Delta for the whole matrix.
  per-token  : Delta per row of an activation matrix  (axis=-1 reduced).
  per-oc     : Delta per output channel of a weight matrix (axis=0 reduced
               for a (c_in, c_out) weight).

All quantizers are differentiable via a straight-through estimator (STE):
the backward pass treats quantize->dequantize as identity, which is the
standard QAT treatment and what the paper's fine-tuning relies on.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

INT8_MAX = 127.0
INT4_MAX = 7.0

_EPS = 1e-8


def qmax_for_bits(bits: int) -> float:
    return float(2 ** (bits - 1) - 1)


def _absmax(x: jnp.ndarray, axis: Optional[int]) -> jnp.ndarray:
    """max(|x|) with keepdims over the reduction axis (None = full tensor)."""
    if axis is None:
        return jnp.max(jnp.abs(x))
    return jnp.max(jnp.abs(x), axis=axis, keepdims=True)


def compute_delta(x: jnp.ndarray, axis: Optional[int], bits: int = 8) -> jnp.ndarray:
    """Quantization step size Delta = max|X| / (2^{N-1}-1)  (Eq. 1)."""
    return jnp.maximum(_absmax(x, axis), _EPS) / qmax_for_bits(bits)


def quantize(
    x: jnp.ndarray, axis: Optional[int], bits: int = 8
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Quantize x -> (x_int, delta) so that x ~= x_int * delta.

    x_int is int8 for bits<=8. delta keeps reduced dims (keepdims=True) so
    x_int * delta broadcasts back to x's shape.
    """
    delta = compute_delta(x, axis, bits)
    qm = qmax_for_bits(bits)
    x_int = jnp.clip(jnp.round(x / delta), -qm, qm).astype(jnp.int8)
    return x_int, delta


def dequantize(x_int: jnp.ndarray, delta: jnp.ndarray, dtype=jnp.float32) -> jnp.ndarray:
    return x_int.astype(dtype) * delta.astype(dtype)


# ---------------------------------------------------------------------------
# Differentiable fake-quant (STE) — used when a quantized value sits on the
# autodiff path (activations). Forward computes the real rounded value;
# backward passes gradients straight through (clipped to the representable
# range so saturated entries get zero gradient, the standard LSQ/STE rule).
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def fake_quant(x: jnp.ndarray, axis: Optional[int], bits: int = 8) -> jnp.ndarray:
    x_int, delta = quantize(x, axis, bits)
    return dequantize(x_int, delta, x.dtype)


def _fake_quant_fwd(x, axis, bits):
    delta = compute_delta(x, axis, bits)
    qm = qmax_for_bits(bits)
    scaled = x / delta
    y = jnp.clip(jnp.round(scaled), -qm, qm) * delta
    mask = (jnp.abs(scaled) <= qm).astype(x.dtype)
    return y.astype(x.dtype), mask


def _fake_quant_bwd(axis, bits, mask, g):
    return (g * mask,)


fake_quant.defvjp(_fake_quant_fwd, _fake_quant_bwd)


def int_matmul(x_int: jnp.ndarray, w_int: jnp.ndarray) -> jnp.ndarray:
    """int8 x int8 -> int32 matmul. On TPU this hits the MXU at 2x bf16 rate;
    the CPU backend upcasts but keeps integer semantics (exact)."""
    return jax.lax.dot_general(
        x_int,
        w_int,
        dimension_numbers=(((x_int.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _quantized_matmul_2d(
    x2d: jnp.ndarray,
    w_int: jnp.ndarray,
    w_delta: jnp.ndarray,
    bits: int = 8,
    bwd_int8: bool = True,
) -> jnp.ndarray:
    x_int, x_delta = quantize(x2d, axis=-1, bits=bits)
    return (
        int_matmul(x_int, w_int).astype(x2d.dtype)
        * x_delta.astype(x2d.dtype)
        * w_delta.reshape((1, -1)).astype(x2d.dtype)
    )


def _qmm_fwd(x2d, w_int, w_delta, bits, bwd_int8):
    return (_quantized_matmul_2d(x2d, w_int, w_delta, bits, bwd_int8),
            (w_int, w_delta))


def _qmm_bwd(bits, bwd_int8, res, g):
    w_int, w_delta = res
    if not bwd_int8:
        # bf16 backward: dequantized transposed GEMM. Half the MXU rate of
        # int8 but the TP all-reduce of dx moves bf16 instead of s32 (4x
        # fewer wire bytes) — see EXPERIMENTS.md SPerf.
        w_fp = dequantize(w_int, w_delta, g.dtype)
        return g @ w_fp.T, None, None
    # Fold the per-OC weight scale into g so the contraction over c_out is
    # scale-free, then run the transposed GEMM in INT8 as well.
    g_scaled = g.astype(jnp.float32) * w_delta.reshape((1, -1))
    g_int, g_delta = quantize(g_scaled, axis=-1, bits=bits)
    dx = int_matmul(g_int, w_int.T).astype(g.dtype) * g_delta.astype(g.dtype)
    return dx, None, None


_quantized_matmul_2d.defvjp(_qmm_fwd, _qmm_bwd)


def quantized_matmul(
    x: jnp.ndarray,
    w_int: jnp.ndarray,
    w_delta: jnp.ndarray,
    bits: int = 8,
    bwd_int8: bool = True,
) -> jnp.ndarray:
    """Naive WAQ forward (paper Eq. 2): per-token quantize x, int GEMM, dequant.

    ``w_delta`` has shape (1, c_out) (per-OC keepdims) or scalar. One INT8 GEMM
    forward, one INT8 GEMM backward (gradient w.r.t. x; W is frozen):

        dx = quant_per_token(g * w_delta) @ W_int^T * g_delta

    which is exact in the same sense as the forward (STE through the rounding).
    """
    x2d = x.reshape((-1, x.shape[-1]))
    y = _quantized_matmul_2d(x2d, w_int, w_delta, bits, bwd_int8)
    return y.reshape(x.shape[:-1] + (w_int.shape[-1],))
