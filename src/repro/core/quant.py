"""Symmetric round-to-nearest quantization primitives (paper Eq. 1, App. F).

Granularities:
  per-tensor : one Delta for the whole matrix.
  per-token  : Delta per row of an activation matrix  (axis=-1 reduced).
  per-oc     : Delta per output channel of a weight matrix (axis=0 reduced
               for a (c_in, c_out) weight).

All quantizers are differentiable via a straight-through estimator (STE):
the backward pass treats quantize->dequantize as identity, which is the
standard QAT treatment and what the paper's fine-tuning relies on.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

INT8_MAX = 127.0
INT4_MAX = 7.0

_EPS = 1e-8


def qmax_for_bits(bits: int) -> float:
    return float(2 ** (bits - 1) - 1)


def _absmax(x: jnp.ndarray, axis: Optional[int]) -> jnp.ndarray:
    """max(|x|) with keepdims over the reduction axis (None = full tensor)."""
    if axis is None:
        return jnp.max(jnp.abs(x))
    return jnp.max(jnp.abs(x), axis=axis, keepdims=True)


def compute_delta(x: jnp.ndarray, axis: Optional[int], bits: int = 8) -> jnp.ndarray:
    """Quantization step size Delta = max|X| / (2^{N-1}-1)  (Eq. 1)."""
    return jnp.maximum(_absmax(x, axis), _EPS) / qmax_for_bits(bits)


def quantize(
    x: jnp.ndarray, axis: Optional[int], bits: int = 8
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Quantize x -> (x_int, delta) so that x ~= x_int * delta.

    x_int is int8 for bits<=8. delta keeps reduced dims (keepdims=True) so
    x_int * delta broadcasts back to x's shape.
    """
    delta = compute_delta(x, axis, bits)
    qm = qmax_for_bits(bits)
    x_int = jnp.clip(jnp.round(x / delta), -qm, qm).astype(jnp.int8)
    return x_int, delta


def dequantize(x_int: jnp.ndarray, delta: jnp.ndarray, dtype=jnp.float32) -> jnp.ndarray:
    return x_int.astype(dtype) * delta.astype(dtype)


# ---------------------------------------------------------------------------
# Differentiable fake-quant (STE) — used when a quantized value sits on the
# autodiff path (activations). Forward computes the real rounded value;
# backward passes gradients straight through (clipped to the representable
# range so saturated entries get zero gradient, the standard LSQ/STE rule).
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def fake_quant(x: jnp.ndarray, axis: Optional[int], bits: int = 8) -> jnp.ndarray:
    x_int, delta = quantize(x, axis, bits)
    return dequantize(x_int, delta, x.dtype)


def _fake_quant_fwd(x, axis, bits):
    delta = compute_delta(x, axis, bits)
    qm = qmax_for_bits(bits)
    scaled = x / delta
    y = jnp.clip(jnp.round(scaled), -qm, qm) * delta
    mask = (jnp.abs(scaled) <= qm).astype(x.dtype)
    return y.astype(x.dtype), mask


def _fake_quant_bwd(axis, bits, mask, g):
    return (g * mask,)


fake_quant.defvjp(_fake_quant_fwd, _fake_quant_bwd)


def int_matmul(x_int: jnp.ndarray, w_int: jnp.ndarray) -> jnp.ndarray:
    """int8 x int8 -> int32 matmul. On TPU this hits the MXU at 2x bf16 rate;
    the CPU backend upcasts but keeps integer semantics (exact)."""
    return jax.lax.dot_general(
        x_int,
        w_int,
        dimension_numbers=(((x_int.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _quantized_matmul_2d(
    x2d: jnp.ndarray,
    w_int: jnp.ndarray,
    w_delta: jnp.ndarray,
    bits: int = 8,
    bwd_int8: bool = True,
) -> jnp.ndarray:
    x_int, x_delta = quantize(x2d, axis=-1, bits=bits)
    return (
        int_matmul(x_int, w_int).astype(x2d.dtype)
        * x_delta.astype(x2d.dtype)
        * w_delta.reshape((1, -1)).astype(x2d.dtype)
    )


def _qmm_fwd(x2d, w_int, w_delta, bits, bwd_int8):
    return (_quantized_matmul_2d(x2d, w_int, w_delta, bits, bwd_int8),
            (w_int, w_delta))


def _qmm_bwd(bits, bwd_int8, res, g):
    w_int, w_delta = res
    if not bwd_int8:
        # bf16 backward: dequantized transposed GEMM. Half the MXU rate of
        # int8 but the TP all-reduce of dx moves bf16 instead of s32 (4x
        # fewer wire bytes) — see EXPERIMENTS.md SPerf.
        w_fp = dequantize(w_int, w_delta, g.dtype)
        return g @ w_fp.T, None, None
    # Fold the per-OC weight scale into g so the contraction over c_out is
    # scale-free, then run the transposed GEMM in INT8 as well.
    g_scaled = g.astype(jnp.float32) * w_delta.reshape((1, -1))
    g_int, g_delta = quantize(g_scaled, axis=-1, bits=bits)
    dx = int_matmul(g_int, w_int.T).astype(g.dtype) * g_delta.astype(g.dtype)
    return dx, None, None


_quantized_matmul_2d.defvjp(_qmm_fwd, _qmm_bwd)


def quantized_matmul(
    x: jnp.ndarray,
    w_int: jnp.ndarray,
    w_delta: jnp.ndarray,
    bits: int = 8,
    bwd_int8: bool = True,
) -> jnp.ndarray:
    """Naive WAQ forward (paper Eq. 2): per-token quantize x, int GEMM, dequant.

    ``w_delta`` has shape (1, c_out) (per-OC keepdims) or scalar. One INT8 GEMM
    forward, one INT8 GEMM backward (gradient w.r.t. x; W is frozen):

        dx = quant_per_token(g * w_delta) @ W_int^T * g_delta

    which is exact in the same sense as the forward (STE through the rounding).
    """
    x2d = x.reshape((-1, x.shape[-1]))
    y = _quantized_matmul_2d(x2d, w_int, w_delta, bits, bwd_int8)
    return y.reshape(x.shape[:-1] + (w_int.shape[-1],))


# ---------------------------------------------------------------------------
# Packed-nibble INT4 carriers + group-wise scales.
#
# Layout ("split-half"): a (c_in, c_out) int4 weight packs two signed nibbles
# per int8 byte along c_in — byte r holds row r in the LOW nibble and row
# r + c_in/2 in the HIGH nibble. Unpack is therefore a concatenation (no
# sublane interleave), which is what lets the Pallas GEMM kernel
# (kernels/int4_matmul.py) feed both halves to the MXU as two contiguous
# x-blocks instead of a strided gather.
#
# Scales: ``w_delta`` is (G, c_out) — G == 1 is plain per-OC; G > 1 splits
# c_in into G contiguous groups of ``c_in / G`` channels, each with its own
# step (OWQ / OutlierTune-style group-wise granularity).
# ---------------------------------------------------------------------------
def pack_int4(w_int: jnp.ndarray) -> jnp.ndarray:
    """(..., K, N) int4-valued int8 -> (..., K//2, N) packed int8 (K even)."""
    k = w_int.shape[-2]
    if k % 2:
        raise ValueError(f"pack_int4 needs an even c_in, got {k}")
    lo = w_int[..., : k // 2, :].astype(jnp.int32)
    hi = w_int[..., k // 2:, :].astype(jnp.int32)
    return ((lo & 0xF) | ((hi & 0xF) << 4)).astype(jnp.int8)


def unpack_int4(packed: jnp.ndarray) -> jnp.ndarray:
    """(..., K//2, N) packed int8 -> (..., K, N) int8 in [-8, 7] (exact
    inverse of ``pack_int4`` for values in [-8, 7])."""
    p = packed.astype(jnp.int32) & 0xFF
    lo = ((p & 0xF) ^ 8) - 8          # 4-bit sign extension
    hi = (((p >> 4) & 0xF) ^ 8) - 8
    return jnp.concatenate([lo, hi], axis=-2).astype(jnp.int8)


def quantize_grouped(
    w: jnp.ndarray, group_size: int, bits: int = 4
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Group-wise symmetric quantization of a (K, N) weight along c_in.

    Returns (w_int (K, N) int8-carried, delta (G, N)) with
    G = K / group_size. ``group_size`` that is <= 0 or does not divide K
    degrades to one group (per-OC) — the safe granularity for any layer
    shape, matching how group-wise schemes handle ragged layers.
    """
    k, n = w.shape[-2:]
    if group_size <= 0 or k % group_size:
        group_size = k
    g = k // group_size
    wg = w.reshape(w.shape[:-2] + (g, group_size, n))
    delta = compute_delta(wg, axis=-2, bits=bits)            # (..., G, 1, N)
    qm = qmax_for_bits(bits)
    w_int = jnp.clip(jnp.round(wg / delta), -qm, qm).astype(jnp.int8)
    return (w_int.reshape(w.shape),
            delta.reshape(w.shape[:-2] + (g, n)).astype(jnp.float32))


def dequantize_grouped(w_int: jnp.ndarray, w_delta: jnp.ndarray,
                       dtype=jnp.float32) -> jnp.ndarray:
    """(K, N) int carrier x (G, N) group steps -> (K, N) float."""
    k = w_int.shape[-2]
    g = w_delta.shape[-2]
    scale = jnp.repeat(w_delta, k // g, axis=-2)
    return w_int.astype(dtype) * scale.astype(dtype)


def _grouped_int_matmul(x_int: jnp.ndarray, w_int: jnp.ndarray,
                        w_delta: jnp.ndarray) -> jnp.ndarray:
    """sum_g (X_:,g @ W_g) * delta_g  — (T, K) x (K, N) x (G, N) -> (T, N)
    f32. The int32 partial products are exact; group scales are applied
    before the cross-group sum (a group-wise GEMM cannot fold its scales
    into a pure epilogue the way per-OC can)."""
    t = x_int.shape[0]
    k, n = w_int.shape
    g = w_delta.shape[0]
    if g == 1:
        acc = int_matmul(x_int, w_int).astype(jnp.float32)
        return acc * w_delta.reshape((1, n))
    xg = x_int.reshape((t, g, k // g)).transpose(1, 0, 2)    # (G, T, gs)
    wg = w_int.reshape((g, k // g, n))                       # (G, gs, N)
    acc = jax.lax.dot_general(
        xg, wg, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.int32).astype(jnp.float32)  # (G, T, N)
    return jnp.sum(acc * w_delta[:, None, :], axis=0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _packed_matmul_2d(
    x2d: jnp.ndarray,
    w_packed: jnp.ndarray,
    w_delta: jnp.ndarray,
    x_bits: int = 8,
    bwd_int8: bool = True,
    use_kernel: bool = False,
) -> jnp.ndarray:
    x_int, x_delta = quantize(x2d, axis=-1, bits=x_bits)
    if use_kernel:
        # Pallas fused unpack-dequant-GEMM (interpret-mode on CPU). Lazy
        # import: the kernels layer depends on core, never the reverse at
        # import time.
        from repro.kernels import int4_matmul as _k
        y = _k.int4_matmul_auto(x_int, w_packed, x_delta, w_delta)
        return y.astype(x2d.dtype)
    w_int = unpack_int4(w_packed)
    y = _grouped_int_matmul(x_int, w_int, w_delta)
    return (y * x_delta.astype(jnp.float32)).astype(x2d.dtype)


def _pmm_fwd(x2d, w_packed, w_delta, x_bits, bwd_int8, use_kernel):
    return (_packed_matmul_2d(x2d, w_packed, w_delta, x_bits, bwd_int8,
                              use_kernel),
            (w_packed, w_delta))


def _pmm_bwd(x_bits, bwd_int8, use_kernel, res, g):
    w_packed, w_delta = res
    w_int = unpack_int4(w_packed)
    if not bwd_int8:
        # bf16 backward: dequantized transposed GEMM (collective-lean mode)
        w_fp = dequantize_grouped(w_int, w_delta, g.dtype)
        return g @ w_fp.T, None, None
    n_groups = w_delta.shape[0]
    k, n = w_int.shape
    if n_groups == 1:
        # per-OC: fold the weight scale into g, one integer transposed GEMM
        g_scaled = g.astype(jnp.float32) * w_delta.reshape((1, n))
        g_int, g_delta = quantize(g_scaled, axis=-1, bits=x_bits)
        dx = int_matmul(g_int, w_int.T).astype(g.dtype) * g_delta.astype(
            g.dtype)
        return dx, None, None
    # group-wise: the scale depends on (group(k), n), so fold it per group
    # and run one batched integer GEMM over groups:
    #   dx[:, g] = quant_per_token(dY * delta_g) @ W_g^T
    gs_all = g.astype(jnp.float32)[None] * w_delta[:, None, :]  # (G, T, N)
    g_int, g_delta = quantize(gs_all, axis=-1, bits=x_bits)     # (G, T, 1)
    wg = w_int.reshape((n_groups, k // n_groups, n))
    dxg = jax.lax.dot_general(
        g_int, wg, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.int32).astype(jnp.float32)   # (G, T, gs)
    dxg = dxg * g_delta
    dx = dxg.transpose(1, 0, 2).reshape((g.shape[0], k))
    return dx.astype(g.dtype), None, None


_packed_matmul_2d.defvjp(_pmm_fwd, _pmm_bwd)


def quantized_matmul_packed(
    x: jnp.ndarray,
    w_packed: jnp.ndarray,
    w_delta: jnp.ndarray,
    x_bits: int = 8,
    bwd_int8: bool = True,
    use_kernel: bool = False,
) -> jnp.ndarray:
    """Packed-nibble INT4-weight GEMM: per-token quantize x at ``x_bits``
    (8 -> w4a8, 4 -> w4a4), integer GEMM against the unpacked nibbles,
    group-wise dequant. ``w_delta``: (G, c_out), G == 1 meaning per-OC.

    Backward (frozen W, STE through the rounding) mirrors
    ``quantized_matmul``: one integer transposed GEMM per-OC, or one
    group-batched integer GEMM when G > 1. ``use_kernel=True`` routes the
    forward through the fused Pallas kernel (same integer math)."""
    x2d = x.reshape((-1, x.shape[-1]))
    y = _packed_matmul_2d(x2d, w_packed, w_delta, x_bits, bwd_int8,
                          use_kernel)
    return y.reshape(x.shape[:-1] + (w_packed.shape[-1],))
