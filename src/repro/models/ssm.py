"""SSM blocks: Mamba2 (SSD, chunked) for zamba2 and mLSTM/sLSTM for xLSTM.

Quaff coverage: the in/out projections (the FLOP-dominant GEMMs) are
quantized; the recurrence itself is activation-only (no weight GEMM), so
there is nothing to quantize there — see DESIGN.md §Arch-applicability.

Mamba2 uses the chunked SSD form for train/prefill (intra-chunk quadratic +
inter-chunk scan; memory O(S·c) not O(S²)) and the O(1) recurrence for
decode. mLSTM uses the stabilized parallel form for train/prefill and the
matrix-memory recurrence for decode (tested against each other). sLSTM is
sequential by construction (recurrent gate mixing) and runs under lax.scan.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig, QuantConfig
from repro.runtime.pspec import hint

CHUNK = 128


def _carry(live, new, old):
    """Masked state carry for slot-pooled decode (repro.serving.state
    .RecurrentPool): rows whose slot is dead (free / mid-admission) keep
    their stored state bit-exactly instead of advancing on a don't-care
    token. ``live`` is (B,) bool; None (single-request decode, train,
    prefill) passes ``new`` through untouched."""
    if live is None or old is None or new is None:
        return new
    lm = live.reshape(live.shape + (1,) * (new.ndim - 1))
    return jnp.where(lm, new, old.astype(new.dtype))


# ===========================================================================
# Mamba2 (SSD)
# ===========================================================================
def mamba_dims(cfg: ModelConfig):
    di = cfg.d_inner
    p = cfg.ssm_head_dim
    h = di // p
    n = cfg.ssm_state
    conv_dim = di + 2 * n
    return di, p, h, n, conv_dim


def init_mamba_block(key, cfg: ModelConfig, qcfg: QuantConfig, param_dtype):
    di, p, h, n, conv_dim = mamba_dims(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    in_dim = 2 * di + 2 * n + h  # z, x, B, C, dt
    w_in, s_in = L.init_qlinear(k1, cfg.d_model, in_dim, "up_proj", qcfg,
                                param_dtype=param_dtype)
    w_out, s_out = L.init_qlinear(k2, di, cfg.d_model, "down_proj", qcfg,
                                  param_dtype=param_dtype)
    params = {
        "in_proj": w_in,
        "out_proj": w_out,
        "conv_w": jax.random.normal(k3, (cfg.conv_kernel, conv_dim), param_dtype)
        * (1.0 / math.sqrt(cfg.conv_kernel)),
        "conv_b": jnp.zeros((conv_dim,), param_dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h).astype(jnp.float32)),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": L.init_rmsnorm(di),
    }
    return params, {"in_proj": s_in, "out_proj": s_out}


def _causal_depthwise_conv(x, w, b, state=None):
    """x: (B,S,C); w: (K,C). Returns (y, new_state) with new_state the last
    K-1 inputs (for decode). Train path pads with zeros on the left."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+K-1, C)
    y = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    y = y + b[None, None, :]
    new_state = xp[:, -(k - 1):, :]
    return jax.nn.silu(y.astype(jnp.float32)).astype(x.dtype), new_state


def _ssd_chunked(xh, bc, cc, dt, a_log):
    """Chunked SSD scan.
    xh: (B,S,H,P)  bc/cc: (B,S,N)  dt: (B,S,H) (post-softplus)  a_log: (H,)
    Returns y: (B,S,H,P).
    """
    bsz, s, h, p = xh.shape
    n = bc.shape[-1]
    c = min(CHUNK, s)
    nc = s // c
    assert nc * c == s, f"seq {s} not divisible by chunk {c}"

    f32 = jnp.float32
    xh = xh.astype(f32).reshape(bsz, nc, c, h, p)
    bc = bc.astype(f32).reshape(bsz, nc, c, n)
    cc = cc.astype(f32).reshape(bsz, nc, c, n)
    dt = dt.astype(f32).reshape(bsz, nc, c, h)
    a = -jnp.exp(a_log.astype(f32))                      # (H,) negative
    la = dt * a[None, None, None, :]                     # log decay per step
    cum = jnp.cumsum(la, axis=2)                         # (B,nc,c,H)

    # intra-chunk: scores[t,s'] = (C_t . B_s') * exp(cum_t - cum_s') * dt_s'
    # NOTE: the mask is applied to the EXPONENT (not post-exp) — above the
    # diagonal cum_t - cum_s' > 0 and exp() overflows, which poisons the
    # backward pass through jnp.where (NaN * 0 = NaN).
    cb = jnp.einsum("bztn,bzsn->bzts", cc, bc)           # (B,nc,c,c)
    causal = jnp.tril(jnp.ones((c, c), bool))
    dcum = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,t,s,H)
    dcum = jnp.where(causal[None, None, :, :, None], dcum, -1e30)
    scores = cb[..., None] * jnp.exp(dcum) * dt[:, :, None, :, :]
    y_intra = jnp.einsum("bztsh,bzshp->bzthp", scores, xh)

    # chunk states: S_z = sum_s exp(cum_end - cum_s) dt_s B_s (x) x_s
    end_decay = jnp.exp(cum[:, :, -1:, :] - cum)          # (B,nc,c,H)
    sbx = jnp.einsum("bzsh,bzsn,bzshp->bzhpn", end_decay * dt, bc, xh)

    # inter-chunk recurrence over nc
    chunk_la = cum[:, :, -1, :]                           # (B,nc,H)

    def scan_fn(hprev, inp):
        s_z, la_z = inp                                   # (B,H,P,N), (B,H)
        h_new = hprev * jnp.exp(la_z)[:, :, None, None] + s_z
        return h_new, hprev

    h0 = jnp.zeros((bsz, h, p, n), f32)
    h_last, h_before = jax.lax.scan(
        scan_fn, h0, (jnp.moveaxis(sbx, 1, 0), jnp.moveaxis(chunk_la, 1, 0)))
    h_before = jnp.moveaxis(h_before, 0, 1)               # (B,nc,H,P,N)

    y_inter = jnp.einsum("bztn,bzhpn->bzthp", cc, h_before) * jnp.exp(cum)[
        :, :, :, :, None]
    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    return y, h_last


def mamba_block(x, params, states, cfg: ModelConfig, cache=None, scope=None,
                live=None):
    """x: (B,S,D) -> (y, new_cache, stats). cache: {"conv": (B,K-1,C),
    "h": (B,H,P,N)} for decode (S==1). ``live`` (B,) bool masks the state
    carry per slot (continuous batching); a capture ``scope`` additionally
    records per-channel state absmax — the OSSH-static grid that seeds
    int8 recurrent-state storage (serving.state.RecurrentPool)."""
    qcfg = cfg.quant
    di, p, h, n, conv_dim = mamba_dims(cfg)
    bsz, s, _ = x.shape

    zxbcdt, st_in = L.apply_qlinear(x, params["in_proj"], qcfg,
                                    states.get("in_proj"), scope=scope)
    z, xin, bc, cc, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    conv_in = jnp.concatenate([xin, bc, cc], axis=-1)
    conv_state = None if cache is None else cache["conv"]
    conv_out, new_conv = _causal_depthwise_conv(
        conv_in, params["conv_w"], params["conv_b"], conv_state)
    xin, bc, cc = jnp.split(conv_out, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"][None, None, :])
    xh = xin.reshape(bsz, s, h, p)

    if cache is None:
        y, state_h = _ssd_chunked(xh, bc, cc, dt, params["a_log"])
        new_h = None
    elif s > 1:
        # prefill: parallel form from a FRESH state + emit the final state
        y, new_h = _ssd_chunked(xh, bc, cc, dt, params["a_log"])
        state_h = new_h
    else:
        # decode: O(1) recurrence h' = h*exp(dt*A) + dt * B (x) x ; y = C.h
        a = -jnp.exp(params["a_log"].astype(jnp.float32))
        la = dt[:, 0, :] * a[None, :]                     # (B,H)
        hprev = cache["h"].astype(jnp.float32)
        upd = jnp.einsum("bh,bn,bhp->bhpn", dt[:, 0, :], bc[:, 0].astype(jnp.float32),
                         xh[:, 0].astype(jnp.float32))
        hnew = hprev * jnp.exp(la)[:, :, None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", cc[:, 0].astype(jnp.float32), hnew)[:, None]
        new_h = hnew
        state_h = hnew
    y = y + params["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(bsz, s, di).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = L.rmsnorm(y, params["norm"], cfg.norm_eps)
    y = hint(y, "act_btf")
    out, st_out = L.apply_qlinear(y, params["out_proj"], qcfg,
                                  states.get("out_proj"), use_kind="row",
                                  scope=scope)
    stats = {"in_proj": st_in, "out_proj": st_out}
    if scope is not None and scope.capture:
        # per-channel absmax of the to-be-cached recurrent state: conv rows
        # (last K-1 raw conv inputs) per conv channel, SSM state per state
        # channel N. Seeds the int8 RecurrentPool's static grid from the
        # same calibration set that fixes the activation outlier channels.
        stats["state"] = {
            "conv": jnp.max(jnp.abs(conv_in.astype(jnp.float32)), axis=(0, 1)),
            "h": jnp.max(jnp.abs(state_h), axis=(0, 1, 2)),
        }
    new_cache = None if cache is None else {
        "conv": _carry(live, new_conv, cache["conv"]),
        "h": _carry(live, new_h, cache["h"])}
    return out, new_cache, stats


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype):
    di, p, h, n, conv_dim = mamba_dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, conv_dim), dtype),
        "h": jnp.zeros((batch, h, p, n), jnp.float32),
    }


# ===========================================================================
# mLSTM (xLSTM) — stabilized parallel + recurrent forms
# ===========================================================================
def init_mlstm_block(key, cfg: ModelConfig, qcfg: QuantConfig, param_dtype):
    d, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 6)
    wq, sq = L.init_qlinear(ks[0], d, d, "q_proj", qcfg, param_dtype=param_dtype)
    wk, sk = L.init_qlinear(ks[1], d, d, "k_proj", qcfg, param_dtype=param_dtype)
    wv, sv = L.init_qlinear(ks[2], d, d, "v_proj", qcfg, param_dtype=param_dtype)
    wo, so = L.init_qlinear(ks[3], d, d, "o_proj", qcfg, param_dtype=param_dtype)
    params = {
        "wq": wq, "wk": wk, "wv": wv, "wo": wo,
        "w_if": jax.random.normal(ks[4], (d, 2 * h), jnp.float32) * 0.02,
        "b_if": jnp.concatenate([jnp.zeros((h,)), 3.0 * jnp.ones((h,))]),
        "w_og": jax.random.normal(ks[5], (d, d), jnp.float32) * 0.02,
        "norm": L.init_rmsnorm(d),
    }
    return params, {"wq": sq, "wk": sk, "wv": sv, "wo": so}


def mlstm_block(x, params, states, cfg: ModelConfig, cache=None, scope=None,
                live=None):
    """x: (B,S,D). cache: {"C": (B,H,P,P), "n": (B,H,P), "m": (B,H)}.
    ``live`` masks the state carry per slot; a capture ``scope`` records the
    matrix memory's per-channel absmax (int8 RecurrentPool seeding)."""
    qcfg = cfg.quant
    bsz, s, d = x.shape
    h = cfg.n_heads
    p = d // h
    xn = L.rmsnorm(x, params["norm"], cfg.norm_eps)

    q, st_q = L.apply_qlinear(xn, params["wq"], qcfg, states.get("wq"),
                              scope=scope)
    k, st_k = L.apply_qlinear(xn, params["wk"], qcfg, states.get("wk"),
                              scope=scope)
    v, st_v = L.apply_qlinear(xn, params["wv"], qcfg, states.get("wv"),
                              scope=scope)
    q = q.reshape(bsz, s, h, p).astype(jnp.float32)
    k = k.reshape(bsz, s, h, p).astype(jnp.float32) / math.sqrt(p)
    v = v.reshape(bsz, s, h, p).astype(jnp.float32)

    gates = xn.astype(jnp.float32) @ params["w_if"] + params["b_if"][None, None, :]
    log_i, log_f_raw = jnp.split(gates, 2, axis=-1)       # (B,S,H)
    log_f = jax.nn.log_sigmoid(log_f_raw)

    if cache is None or s > 1:
        # parallel stabilized form: D[t,s] = sum_{j=s+1..t} log_f_j + log_i_s
        cum_f = jnp.cumsum(log_f, axis=1)                 # (B,S,H)
        dmat = (cum_f[:, :, None, :] - cum_f[:, None, :, :]
                + log_i[:, None, :, :])                   # (B,t,s,H)
        causal = jnp.tril(jnp.ones((s, s), bool))
        dmat = jnp.where(causal[None, :, :, None], dmat, -jnp.inf)
        m = jnp.max(dmat, axis=2, keepdims=True)          # (B,t,1,H)
        dexp = jnp.exp(dmat - m)
        scores = jnp.einsum("bthp,bshp->btsh", q, k) * dexp
        norm = jnp.maximum(
            jnp.abs(jnp.sum(scores, axis=2)), jnp.exp(-m[:, :, 0, :]))  # (B,t,H)
        y = jnp.einsum("btsh,bshp->bthp", scores, v) / norm[..., None]
        new_cache = None
        if cache is not None:
            # prefill from a FRESH state: emit the final (C, n, m) so decode
            # can continue. rel[s] = sum_{j>s} log_f_j + log_i_s.
            rel = cum_f[:, -1:, :] - cum_f + log_i        # (B,S,H)
            m_end = jnp.max(rel, axis=1)                  # (B,H)
            w_s = jnp.exp(rel - m_end[:, None, :])        # (B,S,H)
            c_end = jnp.einsum("bsh,bshp,bshr->bhpr", w_s, v, k)
            n_end = jnp.einsum("bsh,bshp->bhp", w_s, k)
            new_cache = {"C": c_end, "n": n_end, "m": m_end}
    else:
        cmat, n_s, m_s = (cache["C"].astype(jnp.float32),
                          cache["n"].astype(jnp.float32),
                          cache["m"].astype(jnp.float32))
        li, lf = log_i[:, 0], log_f[:, 0]                 # (B,H)
        m_new = jnp.maximum(lf + m_s, li)
        f_act = jnp.exp(lf + m_s - m_new)[:, :, None]
        i_act = jnp.exp(li - m_new)[:, :, None]
        kt, vt, qt = k[:, 0], v[:, 0], q[:, 0]            # (B,H,P)
        cmat = cmat * f_act[..., None] + i_act[..., None] * jnp.einsum(
            "bhp,bhr->bhpr", vt, kt)
        n_s = n_s * f_act + i_act * kt
        hnum = jnp.einsum("bhpr,bhr->bhp", cmat, qt)
        hden = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", n_s, qt)),
                           jnp.exp(-m_new))[..., None]
        y = (hnum / hden)[:, None]                        # (B,1,H,P)
        new_cache = {"C": cmat, "n": n_s, "m": m_new}

    o = jax.nn.sigmoid(xn.astype(jnp.float32) @ params["w_og"])
    y = (y.reshape(bsz, s, d) * o).astype(x.dtype)
    out, st_o = L.apply_qlinear(y, params["wo"], qcfg,
                                states.get("wo"), use_kind="row", scope=scope)
    stats = {"wq": st_q, "wk": st_k, "wv": st_v, "wo": st_o}
    if scope is not None and scope.capture:
        if new_cache is not None:
            c_cap = new_cache["C"]
        else:
            # calibration runs cache-less: emit the end-of-sequence matrix
            # memory the prefill branch would produce, for its absmax only
            rel = jnp.cumsum(log_f, axis=1)
            rel = rel[:, -1:, :] - rel + log_i           # (B,S,H)
            m_end = jnp.max(rel, axis=1)
            w_s = jnp.exp(rel - m_end[:, None, :])
            c_cap = jnp.einsum("bsh,bshp,bshr->bhpr", w_s, v, k)
        stats["state"] = {"C": jnp.max(jnp.abs(c_cap), axis=(0, 1, 2))}
    if new_cache is not None and cache is not None:
        new_cache = {k2: _carry(live, new_cache[k2], cache[k2])
                     for k2 in new_cache}
    return out, new_cache, stats


def init_mlstm_cache(cfg: ModelConfig, batch: int):
    h, p = cfg.n_heads, cfg.d_model // cfg.n_heads
    return {
        "C": jnp.zeros((batch, h, p, p), jnp.float32),
        "n": jnp.zeros((batch, h, p), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
    }


# ===========================================================================
# sLSTM — sequential scan (recurrent gate mixing is not associative)
# ===========================================================================
def init_slstm_block(key, cfg: ModelConfig, qcfg: QuantConfig, param_dtype):
    d, h = cfg.d_model, cfg.n_heads
    p = d // h
    ks = jax.random.split(key, 3)
    w_in, s_in = L.init_qlinear(ks[0], d, 4 * d, "up_proj", qcfg,
                                param_dtype=param_dtype)
    params = {
        "w_in": w_in,
        # per-head block-diagonal recurrent weights
        "r": jax.random.normal(ks[1], (4, h, p, p), jnp.float32) / math.sqrt(p),
        "b": jnp.zeros((4, d), jnp.float32),
        "norm": L.init_rmsnorm(d),
        "w_out": None,
    }
    w_out, s_out = L.init_qlinear(ks[2], d, d, "o_proj", qcfg,
                                  param_dtype=param_dtype)
    params["w_out"] = w_out
    return params, {"w_in": s_in, "w_out": s_out}


def slstm_block(x, params, states, cfg: ModelConfig, cache=None, scope=None,
                live=None):
    """Stabilized sLSTM (xLSTM Eq. 15-24), per-head recurrence via lax.scan.
    ``live`` masks the state carry per slot (continuous batching)."""
    qcfg = cfg.quant
    bsz, s, d = x.shape
    h = cfg.n_heads
    p = d // h
    xn = L.rmsnorm(x, params["norm"], cfg.norm_eps)
    pre, st_in = L.apply_qlinear(xn, params["w_in"], qcfg,
                                 states.get("w_in"), scope=scope)
    pre = pre.astype(jnp.float32).reshape(bsz, s, 4, h, p)

    r = params["r"]
    b = params["b"].reshape(4, h, p)

    if cache is None:
        c0 = jnp.zeros((bsz, h, p), jnp.float32)
        n0 = jnp.full((bsz, h, p), 1e-6, jnp.float32)
        h0 = jnp.zeros((bsz, h, p), jnp.float32)
        m0 = jnp.zeros((bsz, h, p), jnp.float32)
    else:
        c0, n0, h0, m0 = cache["c"], cache["n"], cache["h"], cache["m"]

    def step(carry, x_t):
        c, n, hp, m = carry
        rec = jnp.einsum("ghpr,bhr->bghp", r, hp)         # (B,4,H,P)
        z_t = jnp.tanh(x_t[:, 0] + rec[:, 0] + b[0])
        i_t = x_t[:, 1] + rec[:, 1] + b[1]                # log-space input gate
        f_t = jax.nn.log_sigmoid(x_t[:, 2] + rec[:, 2] + b[2])
        o_t = jax.nn.sigmoid(x_t[:, 3] + rec[:, 3] + b[3])
        m_new = jnp.maximum(f_t + m, i_t)
        i_act = jnp.exp(i_t - m_new)
        f_act = jnp.exp(f_t + m - m_new)
        c = f_act * c + i_act * z_t
        n = f_act * n + i_act
        hp = o_t * c / jnp.maximum(n, 1e-6)
        return (c, n, hp, m_new), hp

    xs = jnp.moveaxis(pre, 1, 0)                          # (S,B,4,H,P)
    (c, n, hp, m), ys = jax.lax.scan(step, (c0, n0, h0, m0), xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, d).astype(x.dtype)
    out, st_out = L.apply_qlinear(y, params["w_out"], qcfg,
                                  states.get("w_out"), use_kind="row",
                                  scope=scope)
    new_cache = None if cache is None else {
        "c": _carry(live, c, cache["c"]), "n": _carry(live, n, cache["n"]),
        "h": _carry(live, hp, cache["h"]), "m": _carry(live, m, cache["m"])}
    return out, new_cache, {"w_in": st_in, "w_out": st_out}


def init_slstm_cache(cfg: ModelConfig, batch: int):
    h, p = cfg.n_heads, cfg.d_model // cfg.n_heads
    z = jnp.zeros((batch, h, p), jnp.float32)
    return {"c": z, "n": z + 1e-6, "h": z, "m": z}
