"""Family dispatch: one uniform functional interface over the model zoo.

    init_params(key, cfg)  -> (frozen, adapters, quant_state)
    forward(...)           -> ModelOut(logits, stats, caches, aux_loss)
    init_caches(cfg, B, S) -> decode caches

Families: dense | moe | vlm (transformer.py), hybrid (zamba2), ssm (xlstm),
encdec (whisper). VLM/audio frontends are stubs: ``input_embeds`` carries
precomputed patch/frame embeddings per the assignment.

``scope`` (core.backend.StatsScope) requests full-absmax stats capture for
calibration; ``rng`` enables train-time LoRA dropout (eval passes None).
"""
from __future__ import annotations



from repro.models import encdec, hybrid, transformer
from repro.models.config import ModelConfig
from repro.models.outputs import ModelOut


def init_params(key, cfg: ModelConfig):
    if cfg.family in ("dense", "moe", "vlm"):
        return transformer.init_params(key, cfg)
    if cfg.family == "hybrid":
        return hybrid.init_params_zamba(key, cfg)
    if cfg.family == "ssm":
        return hybrid.init_params_xlstm(key, cfg)
    if cfg.family == "encdec":
        return encdec.init_params(key, cfg)
    raise ValueError(cfg.family)


def forward(frozen, adapters, quant_state, tokens, cfg: ModelConfig, *,
            input_embeds=None, caches=None, positions=None, remat=False,
            enc_out=None, scope=None, rng=None, live=None,
            exact_kv_reads=False) -> ModelOut:
    """``live`` ((B,) bool, slot-pooled decode only) masks the RECURRENT
    state carry per row for the ssm/hybrid families — KV caches need no
    mask (their per-slot cursors already isolate rows).

    ``exact_kv_reads`` (int8 paged KV only) makes a multi-token chunk read
    its OWN positions back quantized from the pool instead of the prefill
    path's within-call fp override — speculative verification needs its
    K+1-wide chunk to see byte-identical KV to sequential decode."""
    if cfg.family in ("dense", "moe", "vlm"):
        return transformer.forward(frozen, adapters, quant_state, tokens, cfg,
                                   input_embeds=input_embeds, caches=caches,
                                   positions=positions, remat=remat,
                                   exact_kv_reads=exact_kv_reads,
                                   scope=scope, rng=rng)
    if cfg.family == "hybrid":
        return hybrid.forward_zamba(frozen, adapters, quant_state, tokens, cfg,
                                    input_embeds=input_embeds, caches=caches,
                                    positions=positions, remat=remat,
                                    scope=scope, rng=rng, live=live)
    if cfg.family == "ssm":
        return hybrid.forward_xlstm(frozen, adapters, quant_state, tokens, cfg,
                                    input_embeds=input_embeds, caches=caches,
                                    positions=positions, remat=remat,
                                    scope=scope, rng=rng, live=live)
    if cfg.family == "encdec":
        return encdec.forward(frozen, adapters, quant_state, tokens, cfg,
                              input_embeds=input_embeds, caches=caches,
                              positions=positions, remat=remat,
                              enc_out=enc_out, scope=scope, rng=rng)
    raise ValueError(cfg.family)


def init_caches(cfg: ModelConfig, batch: int, max_len: int):
    if cfg.family in ("dense", "moe", "vlm"):
        return transformer.init_caches(cfg, batch, max_len)
    if cfg.family == "hybrid":
        return hybrid.init_caches_zamba(cfg, batch, max_len)
    if cfg.family == "ssm":
        return hybrid.init_caches_xlstm(cfg, batch, max_len)
    if cfg.family == "encdec":
        return encdec.init_caches(cfg, batch, max_len)
    raise ValueError(cfg.family)


def supports_slot_decode(cfg: ModelConfig) -> bool:
    """True for every family in the zoo: decode state — KV cache
    (dense/moe/vlm), recurrent conv/SSM/mLSTM/sLSTM state (ssm/hybrid), or
    self-KV + per-request cross-KV (encdec) — pools into per-request slots
    behind the ``serving.state.DecodeState`` protocol, so
    ``repro.serving.Engine`` serves all of them with mid-decode admission.

    The one batch-composition caveat (moe): expert-capacity routing pools
    all batch rows, so under TIGHT capacity a request's logits can shift
    with pool composition — exactly the semantics lockstep decode already
    has (see tests/test_decode_consistency.py). Dense/recurrent/enc-dec
    per-request parity is exact; MoE parity holds when capacity is ample."""
    return cfg.family in ("dense", "moe", "vlm", "ssm", "hybrid", "encdec")


def init_slot_caches(cfg: ModelConfig, n_slots: int, max_len: int):
    """Slot-pooled decode state for serving: per-slot KV write cursors for
    the attention-bearing families, per-row recurrent state for ssm/hybrid,
    self-KV cursors + per-request cross-KV rows for encdec."""
    if cfg.family in ("dense", "moe", "vlm"):
        return transformer.init_slot_caches(cfg, n_slots, max_len)
    if cfg.family == "hybrid":
        return hybrid.init_slot_caches_zamba(cfg, n_slots, max_len)
    if cfg.family == "ssm":
        return hybrid.init_slot_caches_xlstm(cfg, n_slots, max_len)
    if cfg.family == "encdec":
        return encdec.init_slot_caches(cfg, n_slots, max_len)
    raise ValueError(cfg.family)


def has_decode(cfg: ModelConfig) -> bool:
    """Encoder-only archs would return False; all assigned archs decode."""
    return True


def supports_long_context(cfg: ModelConfig) -> bool:
    """long_500k applicability: SSM/hybrid (O(1)-state decode) and the
    5:1 local:global sliding-window arch. Pure full-attention archs are
    skipped per the assignment rule (see DESIGN.md)."""
    return cfg.family in ("hybrid", "ssm") or bool(cfg.sliding_window)
