"""Model / quantization / training configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig``; the reduced
smoke variants use ``ModelConfig.reduced()``. Field semantics follow the
assignment table (arch id comments in repro/configs/<id>.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Mapping, Optional

from repro.core.peft import PEFTConfig


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    mode: str = "quaff"          # QuantMode value
    bits: int = 8
    gamma: float = 0.2           # momentum (paper App. E)
    outlier_ratio: float = 20.0  # xi criterion threshold
    bwd_int8: bool = True        # INT8 backward GEMMs (paper-faithful); False
                                 # = bf16 backward (collective-lean, SPerf)
    group_size: int = 0          # group-wise weight-scale granularity for
                                 # the int4 backends: channels per scale
                                 # group along c_in (0 = per-OC; layers it
                                 # does not divide fall back to per-OC)
    total_budget: float = 0.05   # < 5% overall overhead
    # per-layer-type budget fractions of c_in (paper §4.1)
    budgets: Optional[Mapping[str, float]] = None


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    qkv_bias: bool = False
    use_rope: bool = True
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    ffn_type: str = "swiglu"      # swiglu | gelu

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_aux_weight: float = 0.01
    # GShard grouping: tokens are routed within ``moe_groups`` independent
    # groups (= data shards) so dispatch scatters stay shard-local and the
    # group->expert transpose lowers to one all-to-all. The launcher sets
    # this to the dp extent; 1 (default) is fine on a single device.
    moe_groups: int = 1

    # sliding-window attention (gemma3: 5 local : 1 global)
    sliding_window: int = 0     # 0 = all layers full attention
    global_every: int = 0       # every Nth layer is global

    # SSM / hybrid
    ssm_state: int = 0
    d_inner: int = 0            # mamba inner width (0 -> 2*d_model)
    ssm_head_dim: int = 64
    conv_kernel: int = 4
    attn_every: int = 0         # zamba2: shared attn after every N mamba blocks
    slstm_every: int = 0        # xlstm: every Nth block is sLSTM

    # encoder-decoder (whisper)
    n_encoder_layers: int = 0
    encoder_seq: int = 1500     # precomputed frame embeddings (stub frontend)

    # VLM (pixtral): prepended precomputed patch embeddings (stub frontend)
    n_image_tokens: int = 0

    # dtypes as strings so configs stay hashable/serializable
    act_dtype: str = "float32"
    param_dtype: str = "float32"
    logits_fp32: bool = True     # False: unembed in act_dtype (SPerf knob)
    moe_int8_dispatch: bool = False  # INT8-compressed EP all-to-all (SPerf)

    quant: QuantConfig = QuantConfig()
    peft: PEFTConfig = PEFTConfig()

    # metadata
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))
        if self.family in ("hybrid", "ssm") and self.d_inner == 0:
            object.__setattr__(self, "d_inner", 2 * self.d_model)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def reduced(self, **overrides) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        small: Dict[str, Any] = dict(
            n_layers=min(self.n_layers, 4),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=32,
            d_ff=256,
            vocab_size=512,
        )
        if self.n_experts:
            small.update(n_experts=8, top_k=2)
        if self.sliding_window:
            small.update(sliding_window=16, global_every=self.global_every)
        if self.family in ("hybrid", "ssm"):
            small.update(ssm_state=16, d_inner=256, ssm_head_dim=32,
                         attn_every=2 if self.attn_every else 0,
                         slstm_every=2 if self.slstm_every else 0)
        if self.n_encoder_layers:
            small.update(n_encoder_layers=2, encoder_seq=32)
        if self.n_image_tokens:
            small.update(n_image_tokens=8)
        small.update(act_dtype="float32", param_dtype="float32")
        small.update(overrides)
        return dataclasses.replace(self, **small)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str                   # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Engine pool sizing (repro.serving): ``max_slots`` concurrent
    requests over a shared KV pool of ``max_seq_len`` positions per slot.
    A request needs prompt + PEFT-prefix + max_new positions to fit.

    KV layout/precision (repro.serving.paged):
      kv_layout     "contiguous" = one max_seq_len row per slot (PR 3);
                    "paged" = block-pool cache — a request holds
                    ceil(need / block_size) fixed-size blocks through a
                    per-request block table, so short requests stop
                    stranding worst-case rows.
      kv_dtype      "fp" = activation-dtype passthrough; "int8" = quantized
                    KV (per-channel key scales held static under OSSH,
                    per-token value scales) at ~4x fewer KV bytes.
      block_size    tokens per KV block (paged only).
      n_blocks      pool capacity in blocks; 0 = worst case
                    (max_slots * ceil(max_seq_len / block_size)).
      prefill_chunk admit prompts in chunks of this many tokens so long
                    prompts never stall the decode batch; 0 = whole-prompt
                    admission. Chunked prefill is paged-only.
      lazy_blocks   paged-only: admit with the PROMPT block footprint and
                    grow tables at decode time (stall/preempt
                    backpressure) instead of reserving max_new up front.
      prefix_share  paged-only: radix/COW prefix sharing — index full KV
                    blocks by token content and map the longest indexed
                    prefix read-only into new requests.
      radix_capacity  max blocks the prefix index may pin (0 = unbounded;
                    leaves still shed LRU-first under pool pressure).

    Dispatch amortization (repro.serving.spec):
      decode_steps  run N decode iterations per engine step inside one
                    compiled scan (in-graph EOS/budget masking); 1 = the
                    classic one-token-per-dispatch loop.
      spec_decode   self-speculative decoding: draft spec_k tokens under
                    the cheaper spec_backend (same frozen weights via the
                    QuantBackend registry), verify with one batched
                    target pass. Mutually exclusive with decode_steps>1.
      spec_backend  draft backend, "mode" or "mode@bits" (e.g.
                    "quaff@4"); must share the target's weight_carrier.
      spec_k        draft tokens per speculation cycle.

    Recurrent-state precision (ssm/hybrid, repro.serving.state):
      state_dtype   "fp" = float state; "int8" = quantized conv/SSM/mLSTM
                    state under OSSH-static per-channel scales (seeded
                    from the Quaff calibration capture or probed from the
                    first admitted prompt).

    This is the training-side mirror of ``repro.serving.EngineConfig``
    (kept import-light for configs); ``to_engine_config()`` converts, and
    the serving engine validates there.
    """

    max_slots: int = 4
    max_seq_len: int = 256
    kv_layout: str = "contiguous"   # contiguous | paged
    kv_dtype: str = "fp"            # fp | int8
    block_size: int = 16
    n_blocks: int = 0
    prefill_chunk: int = 0
    state_dtype: str = "fp"         # fp | int8 (ssm/hybrid recurrent state)
    lazy_blocks: bool = False
    prefix_share: bool = False
    radix_capacity: int = 0
    decode_steps: int = 1
    spec_decode: bool = False
    spec_backend: str = ""
    spec_k: int = 4

    def to_engine_config(self):
        """The serving-side ``EngineConfig`` with these knobs (local import:
        ``models.config`` must stay importable without ``repro.serving``)."""
        from repro.serving.config import EngineConfig
        return EngineConfig(
            max_slots=self.max_slots, max_seq_len=self.max_seq_len,
            kv_layout=self.kv_layout, kv_dtype=self.kv_dtype,
            block_size=self.block_size, n_blocks=self.n_blocks,
            prefill_chunk=self.prefill_chunk, lazy_blocks=self.lazy_blocks,
            prefix_share=self.prefix_share,
            radix_capacity=self.radix_capacity,
            state_dtype=self.state_dtype,
            decode_steps=self.decode_steps, spec_decode=self.spec_decode,
            spec_backend=self.spec_backend, spec_k=self.spec_k)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 2e-4   # paper App. E
    beta1: float = 0.9
    beta2: float = 0.999
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    microbatches: int = 1         # gradient-accumulation steps inside train_step
    remat: bool = True
    remat_policy: str = "nothing"  # nothing | dots (checkpoint_dots)
    grad_compression: bool = False  # INT8 all-reduce of LoRA grads w/ error feedback
    seed: int = 0
    # deterministic=False enables stochastic regularization in train steps
    # (PEFTConfig.lora_dropout, keyed from ``seed`` + step). Eval paths are
    # always deterministic regardless of this flag.
    deterministic: bool = True
