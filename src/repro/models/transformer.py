"""Decoder-only transformer LM (dense / MoE / VLM-backbone) — quant-aware,
scan-over-layers so HLO size is O(1) in depth (61-layer 1T MoE compiles).

Parameter trees:
  frozen     : embed, stacked blocks (attn + ffn|moe + norms), final_norm, lm_head
  adapters   : trainable PEFT params (stacked LoRA / IA3 per layer, prompt at top)
  quant_state: stacked ScaleState per Quaff projection (None otherwise)

forward() returns a typed ``ModelOut``; its stats tree feeds the momentum
update in repro/train/steps.py.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import peft as PEFT
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models.config import ModelConfig
from repro.models.outputs import ModelOut
from repro.runtime.pspec import hint


def _is_global_pattern(cfg: ModelConfig) -> jnp.ndarray:
    """(L,) bool — gemma3-style: every ``global_every``-th layer is global."""
    idx = jnp.arange(cfg.n_layers)
    if cfg.sliding_window and cfg.global_every:
        return (idx % cfg.global_every) == (cfg.global_every - 1)
    return jnp.ones((cfg.n_layers,), bool)


def init_block(key, cfg: ModelConfig, param_dtype):
    k1, k2 = jax.random.split(key)
    attn_p, attn_s = L.init_attention(k1, cfg, cfg.quant, param_dtype)
    if cfg.n_experts:
        ffn_p, ffn_s = MOE.init_moe(k2, cfg, cfg.quant, param_dtype)
    else:
        ffn_p, ffn_s = L.init_ffn(k2, cfg, cfg.quant, param_dtype)
    params = {
        "attn": attn_p,
        "ffn": ffn_p,
        "norm1": L.init_rmsnorm(cfg.d_model),
        "norm2": L.init_rmsnorm(cfg.d_model),
    }
    return params, {"attn": attn_s, "ffn": ffn_s}


def init_adapters_block(key, cfg: ModelConfig):
    p = cfg.peft
    out: Dict[str, Any] = {}
    if p.method == "lora":
        k1, k2 = jax.random.split(key)
        out["lora_q"] = PEFT.init_lora(k1, cfg.d_model, cfg.q_dim, p.lora_rank)
        out["lora_v"] = PEFT.init_lora(k2, cfg.d_model, cfg.kv_dim, p.lora_rank)
    elif p.method == "ia3":
        out["ia3"] = PEFT.init_ia3(cfg.kv_dim, cfg.d_ff if not cfg.n_experts else 1)
    return out


def init_params(key, cfg: ModelConfig):
    """-> (frozen, adapters, quant_state). Usable under jax.eval_shape."""
    param_dtype = L.dt(cfg.param_dtype)
    keys = jax.random.split(key, 4)
    frozen: Dict[str, Any] = {
        "embed": L.init_embedding(keys[0], cfg.vocab_size, cfg.d_model, param_dtype)
    }
    block_keys = jax.random.split(keys[1], cfg.n_layers)
    frozen["blocks"], qstate = jax.vmap(
        lambda k: init_block(k, cfg, param_dtype)
    )(block_keys)
    frozen["final_norm"] = L.init_rmsnorm(cfg.d_model)
    if not cfg.tie_embeddings:
        frozen["lm_head"] = {
            "w": jax.random.normal(keys[2], (cfg.d_model, cfg.vocab_size),
                                   param_dtype) * 0.02
        }

    adapters: Dict[str, Any] = {}
    p = cfg.peft
    if p.method in ("lora", "ia3"):
        adapters["blocks"] = jax.vmap(
            lambda k: init_adapters_block(k, cfg)
        )(jax.random.split(keys[3], cfg.n_layers))
    elif p.method == "prompt":
        adapters["prompt"] = PEFT.init_prompt(keys[3], p.n_virtual_tokens, cfg.d_model)
    elif p.method == "ptuning":
        adapters["prompt"] = PEFT.init_ptuning(
            keys[3], p.n_virtual_tokens, cfg.d_model, p.ptuning_hidden)
    return frozen, adapters, qstate


def _block_apply(x, block, qstate, adapters, cfg: ModelConfig, *,
                 positions, is_global, cache, exact_kv_reads=False,
                 scope=None, rng=None):
    attn_in = L.rmsnorm(x, block["norm1"], cfg.norm_eps)
    attn_out, new_cache, attn_stats = L.attention(
        attn_in, block["attn"], qstate["attn"], cfg,
        positions=positions, is_global=is_global, cache=cache,
        adapters=adapters, exact_kv_reads=exact_kv_reads,
        scope=scope, rng=rng)
    x = hint(x + attn_out, "act_btd")
    ffn_in = L.rmsnorm(x, block["norm2"], cfg.norm_eps)
    if cfg.n_experts:
        ffn_out, aux, ffn_stats = MOE.moe_ffn(ffn_in, block["ffn"],
                                              qstate["ffn"], cfg, scope=scope)
    else:
        ffn_out, ffn_stats = L.ffn(ffn_in, block["ffn"], qstate["ffn"], cfg,
                                   adapters=adapters, scope=scope)
        aux = jnp.zeros((), jnp.float32)
    x = hint(x + ffn_out, "act_btd")
    return x, new_cache, {"attn": attn_stats, "ffn": ffn_stats}, aux


def forward(
    frozen: Dict[str, Any],
    adapters: Dict[str, Any],
    quant_state: Any,
    tokens: Optional[jnp.ndarray],
    cfg: ModelConfig,
    *,
    input_embeds: Optional[jnp.ndarray] = None,   # VLM: (B, n_img, D) prepended
    caches: Optional[Any] = None,                 # stacked (L, ...) KV caches
    positions: Optional[jnp.ndarray] = None,      # decode: (S,) absolute pos
    remat: bool = False,
    exact_kv_reads: bool = False,      # int8 KV: skip within-call fp override
    scope=None,                                   # StatsScope (calibration)
    rng: Optional[jnp.ndarray] = None,            # train-time dropout key
) -> ModelOut:
    act_dtype = L.dt(cfg.act_dtype)
    parts = []
    if input_embeds is not None:
        parts.append(input_embeds.astype(act_dtype))
    if tokens is not None:
        parts.append(L.embed(tokens, frozen["embed"], act_dtype))
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)

    if "prompt" in adapters:
        if isinstance(adapters["prompt"], PEFT.PromptParams):
            x = PEFT.apply_prompt(x, adapters["prompt"])
        else:
            x = PEFT.apply_ptuning(x, adapters["prompt"])

    x = hint(x, "act_btd")
    s_len = x.shape[1]
    if positions is None:
        positions = jnp.arange(s_len, dtype=jnp.int32)

    is_global = _is_global_pattern(cfg)
    block_adapters = adapters.get("blocks")

    def body(carry, xs):
        h, key = carry
        block, qs, bad, glob, cache = xs
        sub = None
        if key is not None:
            key, sub = jax.random.split(key)
        h, new_cache, stats, aux = _block_apply(
            h, block, qs, bad, cfg,
            positions=positions, is_global=glob, cache=cache,
            exact_kv_reads=exact_kv_reads, scope=scope, rng=sub)
        return (h, key), (stats, aux, new_cache)

    body = L.remat_wrap(body, remat)

    xs = (frozen["blocks"], quant_state, block_adapters, is_global, caches)
    (x, _), (stats, aux, new_caches) = jax.lax.scan(body, (x, rng), xs)

    x = L.rmsnorm(x, frozen["final_norm"], cfg.norm_eps)
    head = frozen["embed"] if cfg.tie_embeddings else frozen["lm_head"]
    logits = L.unembed(x, head, act_dtype, cfg.logits_fp32)
    return ModelOut(logits, stats, new_caches, jnp.mean(aux))


def init_caches(cfg: ModelConfig, batch: int, max_len: int):
    act_dtype = L.dt(cfg.act_dtype)
    one = L.init_kv_cache(cfg, batch, max_len, act_dtype)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape).copy(), one)


def init_slot_caches(cfg: ModelConfig, n_slots: int, max_len: int):
    """KV pool for continuous batching: like ``init_caches`` but the write
    cursor is PER SLOT ((L, n_slots) instead of (L,)), which routes
    ``layers.attention`` through its per-row write/mask branch."""
    caches = init_caches(cfg, n_slots, max_len)
    caches["pos"] = jnp.zeros((cfg.n_layers, n_slots), jnp.int32)
    return caches
