"""Mixture-of-Experts FFN with capacity-based dispatch (GShard-style
positions, scatter into a dense (E, C, d) buffer so expert GEMMs are batched
and EP-shardable over the mesh "model"/"data" axes via sharding hints).

Quaff on experts: the outlier channel set O and the momentum scale s are
per-layer (shared across experts — outliers are a property of the hidden
stream feeding the experts, not of the expert; tests/test_moe.py checks
dispatch exactness and tests/test_smoke_archs.py exercises the quant path),
while W_int / W_O are per-expert.

No dropless guarantees: tokens over capacity are dropped (standard GShard);
``capacity_factor`` controls the drop rate. An aux load-balancing loss
(Switch-style) is returned for the train loss.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.backend import StatsScope, get_backend
from repro.core.scaling import ScaleState
from repro.models.config import ModelConfig, QuantConfig
from repro.models.layers import init_qlinear
from repro.runtime.pspec import hint


def init_moe(key, cfg: ModelConfig, qcfg: QuantConfig, param_dtype):
    """Router (fp32, small) + per-expert SwiGLU weights, expert dim leading."""
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    router = jax.random.normal(ks[0], (d, e), jnp.float32) * 0.02

    def init_expert(k):
        k1, k2, k3 = jax.random.split(k, 3)
        gate, s_g = init_qlinear(k1, d, f, "gate_proj", qcfg, param_dtype=param_dtype)
        up, s_u = init_qlinear(k2, d, f, "up_proj", qcfg, param_dtype=param_dtype)
        down, s_d = init_qlinear(k3, f, d, "down_proj", qcfg, param_dtype=param_dtype)
        return {"gate": gate, "up": up, "down": down}, {"gate": s_g, "up": s_u,
                                                        "down": s_d}

    params_e, states_e = jax.vmap(init_expert)(jax.random.split(ks[1], e))
    # backend hook: backends with layer-shared state (Quaff) collapse the
    # expert dim here; stateless backends pass through (all-None states).
    params_e, states = get_backend(qcfg.mode).merge_expert_init(
        params_e, states_e)
    return {"router": router, "experts": params_e}, states


def _expert_linear(xe, wts, qcfg: QuantConfig, state: Optional[ScaleState],
                   use_kind: str = "col",
                   scope: Optional[StatsScope] = None):
    """xe: (E, C, c_in); wts: per-expert stacked weights pytree."""
    from repro.models.layers import _hint_weight_use, capture_absmax

    backend = get_backend(qcfg.mode)
    out = backend.apply_experts(xe, _hint_weight_use(wts["w"], use_kind),
                                state=state, bits=qcfg.bits,
                                bwd_int8=qcfg.bwd_int8)
    y, stats = out.y, out.stats
    if scope is not None and scope.capture:
        stats = capture_absmax(xe)
    return y, stats


def _ct_impl(x: jnp.ndarray, kind: str) -> jnp.ndarray:
    """Quantize per token -> transpose dims 0/1 with the sharding hint on the
    INT8 payload (so the all-to-all moves int8) -> dequantize locally."""
    from repro.core import quant as Q
    from repro.runtime.pspec import hint as H

    x_int, delta = Q.quantize(x, axis=-1)
    x_int = H(jnp.swapaxes(x_int, 0, 1), kind)
    delta = H(jnp.swapaxes(delta, 0, 1), kind)
    return (x_int.astype(x.dtype) * delta.astype(x.dtype))


def _compressed_transpose(x: jnp.ndarray, kind: str) -> jnp.ndarray:
    """INT8-compressed (G,E,c,d)<->(E,G,c,d) transpose. Both directions of
    autodiff compress: the backward cotangent crosses the mesh quantized
    too (custom_vjp — int8 arrays have no JAX tangents otherwise)."""
    rev_kind = ("moe_group_buf" if kind == "moe_expert_buf"
                else "moe_expert_buf")

    @jax.custom_vjp
    def ct(v):
        return _ct_impl(v, kind)

    def ct_fwd(v):
        return _ct_impl(v, kind), None

    def ct_bwd(_, g):
        return (_ct_impl(g, rev_kind),)

    ct.defvjp(ct_fwd, ct_bwd)
    return ct(x)


def moe_ffn(
    x: jnp.ndarray,
    params: Dict[str, Any],
    states: Dict[str, Optional[ScaleState]],
    cfg: ModelConfig,
    scope: Optional[StatsScope] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, Dict[str, Any]]:
    """x: (B, S, D) -> (y, aux_loss, stats).

    GShard grouped dispatch: tokens are split into ``moe_groups`` independent
    routing groups aligned with the data shards. All cumsums/scatters are
    group-local (shard-local on the mesh); the only cross-shard movement is
    the (g, e, c, d) -> (e, g, c, d) transpose, which GSPMD lowers to ONE
    all-to-all over the "data" axis — the canonical EP collective."""
    qcfg = cfg.quant
    bsz, s_len, d = x.shape
    t = bsz * s_len
    e, k = cfg.n_experts, cfg.top_k
    g = max(1, min(cfg.moe_groups, t))
    while t % g:
        g //= 2
    tg = t // g
    cap = max(1, int(math.ceil(cfg.capacity_factor * tg * k / e)))

    xt = hint(x.reshape(g, tg, d), "moe_tokens")
    logits = xt.astype(jnp.float32) @ params["router"]          # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)               # (G, Tg, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)       # renormalize

    # Switch-style aux loss: E * sum_e (frac_tokens_e * frac_probs_e)
    assign_onehot = jax.nn.one_hot(gate_idx[..., 0], e, dtype=jnp.float32)
    frac_tokens = jnp.mean(assign_onehot, axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * frac_probs)

    # group-local GShard positions: (G, Tg, E) cumsums only
    pos_list, keep_list = [], []
    base = jnp.zeros((g, 1, e), jnp.int32)
    for j in range(k):
        onehot_j = jax.nn.one_hot(gate_idx[..., j], e, dtype=jnp.int32)
        pos_in_e = jnp.cumsum(onehot_j, axis=1) - 1 + base
        pos_j = jnp.sum(pos_in_e * onehot_j, axis=-1)           # (G, Tg)
        keep_j = pos_j < cap
        pos_list.append(jnp.where(keep_j, pos_j, cap))
        keep_list.append(keep_j)
        base = base + jnp.sum(onehot_j, axis=1, keepdims=True)

    pos = jnp.stack(pos_list, axis=-1)       # (G, Tg, k)
    keep = jnp.stack(keep_list, axis=-1)     # (G, Tg, k)
    flat_slot = gate_idx * (cap + 1) + pos   # (G, Tg, k)

    # group-local dispatch: k batched scatters of (G, Tg, D)
    def scatter_group(buf_g, slot_g, x_g):
        return buf_g.at[slot_g].set(x_g, mode="drop")

    buf = jnp.zeros((g, e * (cap + 1), d), x.dtype)
    for j in range(k):
        buf = jax.vmap(scatter_group)(buf, flat_slot[..., j], xt)
    buf = buf.reshape(g, e, cap + 1, d)[:, :, :cap, :]
    buf = hint(buf, "moe_group_buf")

    # group -> expert transpose: THE all-to-all. Optional INT8 compression
    # (per-token quantized payload, fp deltas ride along) cuts the wire
    # bytes 2x vs bf16 / 4x vs fp32 — the Quaff idea applied to the EP
    # collective itself (EXPERIMENTS.md §Perf, beyond-paper).
    if cfg.moe_int8_dispatch:
        buf = _compressed_transpose(buf, "moe_expert_buf")      # (E, G, cap, D)
    else:
        buf = hint(jnp.swapaxes(buf, 0, 1), "moe_expert_buf")
    buf = buf.reshape(e, g * cap, d)
    buf = hint(buf, "moe_buffer")

    # expert SwiGLU
    stats: Dict[str, Any] = {}
    gate_h, stats["gate"] = _expert_linear(buf, params["experts"]["gate"], qcfg,
                                           states.get("gate"), scope=scope)
    up_h, stats["up"] = _expert_linear(buf, params["experts"]["up"], qcfg,
                                       states.get("up"), scope=scope)
    h = jax.nn.silu(gate_h.astype(jnp.float32)).astype(x.dtype) * up_h
    h = hint(h, "moe_buffer_f")
    # NOTE: expert down stays COLUMN-parallel: with top-k token duplication
    # a row-parallel fwd all-reduce moves k x more bytes than the dense case
    # — measured worse (EXPERIMENTS.md §Perf, kimi iteration 3).
    out, stats["down"] = _expert_linear(h, params["experts"]["down"], qcfg,
                                        states.get("down"), scope=scope)
    out = hint(out.reshape(e, g, cap, d), "moe_expert_buf")

    # expert -> group transpose (all-to-all back) + local combine
    if cfg.moe_int8_dispatch:
        out = _compressed_transpose(out, "moe_group_buf")       # (G, E, cap, D)
    else:
        out = hint(jnp.swapaxes(out, 0, 1), "moe_group_buf")
    pad = jnp.zeros((g, e, 1, d), out.dtype)
    out_p = jnp.concatenate([out, pad], axis=2).reshape(g, e * (cap + 1), d)
    w = (gate_vals * keep.astype(jnp.float32)).astype(x.dtype)
    y = jnp.zeros((g, tg, d), x.dtype)
    for j in range(k):
        gathered = jax.vmap(lambda o_g, s_g: o_g[s_g])(out_p, flat_slot[..., j])
        y = y + gathered * w[..., j:j + 1]
    y = hint(y, "moe_tokens")
    return y.reshape(bsz, s_len, d), aux, stats
