"""Hybrid (zamba2: Mamba2 + shared attention) and xLSTM (mLSTM + sLSTM)
model wrappers. Same interface as models/transformer.py.

zamba2 layer layout (total n_layers blocks):
    n_stages x [ attn_every mamba blocks -> ONE SHARED attention block ]
    + trailing mamba blocks
    n_stages = n_layers // (attn_every + 1)
The attention block's parameters are shared across all applications (the
Zamba trick); its Quaff scale state is also shared — per-application stats
are max-reduced before the momentum update.

xLSTM layout: n_stages x [ (slstm_every - 1) mLSTM -> 1 sLSTM ] + trailing
mLSTM, n_stages = n_layers // slstm_every (0 => pure mLSTM stack).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import peft as PEFT
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.config import ModelConfig
from repro.models.outputs import ModelOut
from repro.runtime.pspec import hint


def zamba_layout(cfg: ModelConfig) -> Tuple[int, int, int]:
    per = cfg.attn_every
    if per <= 0:
        return 0, 0, cfg.n_layers
    n_stages = cfg.n_layers // (per + 1)
    trailing = cfg.n_layers - n_stages * (per + 1)
    return n_stages, per, trailing


def xlstm_layout(cfg: ModelConfig) -> Tuple[int, int, int]:
    per = cfg.slstm_every
    if per <= 0:
        return 0, 0, cfg.n_layers
    n_stages = cfg.n_layers // per
    trailing = cfg.n_layers - n_stages * per
    return n_stages, per - 1, trailing


# ===========================================================================
# zamba2
# ===========================================================================
def init_params_zamba(key, cfg: ModelConfig):
    param_dtype = L.dt(cfg.param_dtype)
    n_stages, per, trailing = zamba_layout(cfg)
    keys = jax.random.split(key, 6)
    frozen: Dict[str, Any] = {
        "embed": L.init_embedding(keys[0], cfg.vocab_size, cfg.d_model, param_dtype)
    }
    qstate: Dict[str, Any] = {}

    def init_m(k):
        return S.init_mamba_block(k, cfg, cfg.quant, param_dtype)

    if n_stages:
        ks = jax.random.split(keys[1], n_stages * per).reshape(n_stages, per, 2)
        frozen["stage_mamba"], qstate["stage_mamba"] = jax.vmap(jax.vmap(init_m))(ks)
        attn_p, attn_s = L.init_attention(keys[2], cfg, cfg.quant, param_dtype)
        frozen["shared_attn"] = {"attn": attn_p,
                                 "norm": L.init_rmsnorm(cfg.d_model)}
        qstate["shared_attn"] = attn_s
    if trailing:
        frozen["trail_mamba"], qstate["trail_mamba"] = jax.vmap(init_m)(
            jax.random.split(keys[3], trailing))
    frozen["final_norm"] = L.init_rmsnorm(cfg.d_model)
    frozen["lm_head"] = {
        "w": jax.random.normal(keys[4], (cfg.d_model, cfg.vocab_size),
                               param_dtype) * 0.02}

    adapters: Dict[str, Any] = {}
    p = cfg.peft
    if p.method == "lora" and n_stages:
        k1, k2 = jax.random.split(keys[5])
        adapters["attn"] = {
            "lora_q": PEFT.init_lora(k1, cfg.d_model, cfg.q_dim, p.lora_rank),
            "lora_v": PEFT.init_lora(k2, cfg.d_model, cfg.kv_dim, p.lora_rank),
        }
    elif p.method == "ia3" and n_stages:
        adapters["attn"] = {"ia3": PEFT.init_ia3(cfg.kv_dim, 1)}
    elif p.method in ("prompt", "ptuning"):
        adapters["prompt"] = (
            PEFT.init_prompt(keys[5], p.n_virtual_tokens, cfg.d_model)
            if p.method == "prompt"
            else PEFT.init_ptuning(keys[5], p.n_virtual_tokens, cfg.d_model,
                                   p.ptuning_hidden))
    return frozen, adapters, qstate


def forward_zamba(frozen, adapters, quant_state, tokens, cfg: ModelConfig, *,
                  input_embeds=None, caches=None, positions=None, remat=False,
                  scope=None, rng=None, live=None):
    act_dtype = L.dt(cfg.act_dtype)
    n_stages, per, trailing = zamba_layout(cfg)
    x = L.embed(tokens, frozen["embed"], act_dtype)
    if "prompt" in adapters:
        x = (PEFT.apply_prompt(x, adapters["prompt"])
             if isinstance(adapters["prompt"], PEFT.PromptParams)
             else PEFT.apply_ptuning(x, adapters["prompt"]))
    x = hint(x, "act_btd")
    s_len = x.shape[1]
    if positions is None:
        positions = jnp.arange(s_len, dtype=jnp.int32)

    stats: Dict[str, Any] = {}
    new_caches: Dict[str, Any] = {}

    def mamba_body(carry, xs):
        h = carry
        params, qs, cache = xs
        h2, new_cache, st = S.mamba_block(h, params, qs, cfg, cache,
                                          scope=scope, live=live)
        return h + h2, (st, new_cache)

    mamba_body = L.remat_wrap(mamba_body, remat)

    if n_stages:
        attn_params = frozen["shared_attn"]
        attn_qs = quant_state["shared_attn"]
        attn_ad = adapters.get("attn")

        def stage_body(carry, xs):
            h, key = carry
            stage_params, stage_qs, stage_mcache, stage_kvcache = xs
            sub = None
            if key is not None:
                key, sub = jax.random.split(key)
            h, (m_stats, m_caches) = jax.lax.scan(
                mamba_body, h, (stage_params, stage_qs, stage_mcache))
            attn_in = L.rmsnorm(h, attn_params["norm"], cfg.norm_eps)
            a_out, new_kv, a_stats = L.attention(
                attn_in, attn_params["attn"], attn_qs, cfg,
                positions=positions, cache=stage_kvcache, adapters=attn_ad,
                scope=scope, rng=sub)
            h = hint(h + a_out, "act_btd")
            return (h, key), (m_stats, a_stats, m_caches, new_kv)

        stage_mc = None if caches is None else caches["stage_mamba"]
        stage_kv = None if caches is None else caches["stage_kv"]
        xs = (frozen["stage_mamba"], quant_state["stage_mamba"], stage_mc, stage_kv)
        (x, _), (m_stats, a_stats, m_caches, kv_caches) = jax.lax.scan(
            stage_body, (x, rng), xs)
        stats["stage_mamba"] = m_stats
        # shared attention: reduce per-application stats (state is shared)
        stats["shared_attn"] = jax.tree.map(
            lambda a: None if a is None else jnp.max(a, axis=0), a_stats)
        new_caches["stage_mamba"] = m_caches
        new_caches["stage_kv"] = kv_caches

    if trailing:
        trail_mc = None if caches is None else caches["trail_mamba"]
        x, (t_stats, t_caches) = jax.lax.scan(
            mamba_body, x, (frozen["trail_mamba"], quant_state["trail_mamba"],
                            trail_mc))
        stats["trail_mamba"] = t_stats
        new_caches["trail_mamba"] = t_caches

    x = L.rmsnorm(x, frozen["final_norm"], cfg.norm_eps)
    logits = L.unembed(x, frozen["lm_head"], act_dtype, cfg.logits_fp32)
    out_caches = new_caches if caches is not None else None
    return ModelOut(logits, stats, out_caches, jnp.zeros((), jnp.float32))


def init_caches_zamba(cfg: ModelConfig, batch: int, max_len: int):
    act_dtype = L.dt(cfg.act_dtype)
    n_stages, per, trailing = zamba_layout(cfg)
    mc = S.init_mamba_cache(cfg, batch, act_dtype)
    caches: Dict[str, Any] = {}
    if n_stages:
        caches["stage_mamba"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None, None],
                                       (n_stages, per) + a.shape).copy(), mc)
        kv = L.init_kv_cache(cfg, batch, max_len, act_dtype)
        caches["stage_kv"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_stages,) + a.shape).copy(), kv)
    if trailing:
        caches["trail_mamba"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (trailing,) + a.shape).copy(), mc)
    return caches


def init_slot_caches_zamba(cfg: ModelConfig, n_slots: int, max_len: int):
    """Slot-pooled decode state: the conv/SSM leaves are already per-row
    (no seq axis — admission overwrites a slot's column wholesale), and the
    shared-attention KV cache gets a PER-SLOT write cursor ((n_stages,
    n_slots) instead of (n_stages,)), routing ``layers.attention`` through
    its per-row cursor branch exactly like the transformer slot pool."""
    caches = init_caches_zamba(cfg, n_slots, max_len)
    if "stage_kv" in caches:
        n_stages, _, _ = zamba_layout(cfg)
        caches["stage_kv"]["pos"] = jnp.zeros((n_stages, n_slots), jnp.int32)
    return caches


# ===========================================================================
# xLSTM
# ===========================================================================
def init_params_xlstm(key, cfg: ModelConfig):
    param_dtype = L.dt(cfg.param_dtype)
    n_stages, per_m, trailing = xlstm_layout(cfg)
    keys = jax.random.split(key, 6)
    frozen: Dict[str, Any] = {
        "embed": L.init_embedding(keys[0], cfg.vocab_size, cfg.d_model, param_dtype)
    }
    qstate: Dict[str, Any] = {}

    def init_m(k):
        return S.init_mlstm_block(k, cfg, cfg.quant, param_dtype)

    def init_s(k):
        return S.init_slstm_block(k, cfg, cfg.quant, param_dtype)

    if n_stages and per_m:
        ks = jax.random.split(keys[1], n_stages * per_m).reshape(n_stages, per_m, 2)
        frozen["stage_mlstm"], qstate["stage_mlstm"] = jax.vmap(jax.vmap(init_m))(ks)
    if n_stages:
        frozen["stage_slstm"], qstate["stage_slstm"] = jax.vmap(init_s)(
            jax.random.split(keys[2], n_stages))
    if trailing:
        frozen["trail_mlstm"], qstate["trail_mlstm"] = jax.vmap(init_m)(
            jax.random.split(keys[3], trailing))
    frozen["final_norm"] = L.init_rmsnorm(cfg.d_model)
    frozen["lm_head"] = {
        "w": jax.random.normal(keys[4], (cfg.d_model, cfg.vocab_size),
                               param_dtype) * 0.02}

    adapters: Dict[str, Any] = {}
    p = cfg.peft
    if p.method == "lora":
        def init_ad(k):
            return {"lora": PEFT.init_lora(k, cfg.d_model, cfg.d_model,
                                           p.lora_rank)}
        k_stage, k_trail = jax.random.split(keys[5])
        if n_stages and per_m:
            ks = jax.random.split(k_stage, n_stages * per_m).reshape(
                n_stages, per_m, 2)
            adapters["stage_mlstm"] = jax.vmap(jax.vmap(init_ad))(ks)
        if trailing:
            adapters["trail_mlstm"] = jax.vmap(init_ad)(
                jax.random.split(k_trail, trailing))
    elif p.method in ("prompt", "ptuning"):
        adapters["prompt"] = (
            PEFT.init_prompt(keys[5], p.n_virtual_tokens, cfg.d_model)
            if p.method == "prompt"
            else PEFT.init_ptuning(keys[5], p.n_virtual_tokens, cfg.d_model,
                                   p.ptuning_hidden))
    return frozen, adapters, qstate


def forward_xlstm(frozen, adapters, quant_state, tokens, cfg: ModelConfig, *,
                  input_embeds=None, caches=None, positions=None, remat=False,
                  scope=None, rng=None, live=None):
    act_dtype = L.dt(cfg.act_dtype)
    n_stages, per_m, trailing = xlstm_layout(cfg)
    x = L.embed(tokens, frozen["embed"], act_dtype)
    if "prompt" in adapters:
        x = (PEFT.apply_prompt(x, adapters["prompt"])
             if isinstance(adapters["prompt"], PEFT.PromptParams)
             else PEFT.apply_ptuning(x, adapters["prompt"]))
    x = hint(x, "act_btd")

    stats: Dict[str, Any] = {}
    new_caches: Dict[str, Any] = {}

    def ml_body(carry, xs):
        h, key = carry
        params, qs, ad, cache = xs
        sub = None
        if key is not None:
            key, sub = jax.random.split(key)
        h2, new_cache, st = S.mlstm_block(h, params, qs, cfg, cache,
                                          scope=scope, live=live)
        if ad is not None:
            p = cfg.peft
            xn = L.rmsnorm(h, params["norm"], cfg.norm_eps)
            dropout = p.lora_dropout if sub is not None else 0.0
            h2 = h2 + PEFT.apply_lora(xn, ad["lora"], p.lora_alpha,
                                      p.lora_rank, dropout, sub)
        return (h + h2, key), (st, new_cache)

    ml_body = L.remat_wrap(ml_body, remat)

    ml_ad_stage = adapters.get("stage_mlstm")
    ml_ad_trail = adapters.get("trail_mlstm")

    if n_stages:
        def stage_body(carry, xs):
            h, key = carry
            (m_params, m_qs, m_ad, m_cache, s_params, s_qs, s_cache) = xs
            if per_m:
                (h, key), (m_stats, m_caches) = jax.lax.scan(
                    ml_body, (h, key), (m_params, m_qs, m_ad, m_cache))
            else:
                m_stats, m_caches = None, None
            h2, new_scache, s_stats = S.slstm_block(h, s_params, s_qs, cfg,
                                                    s_cache, scope=scope,
                                                    live=live)
            h = hint(h + h2, "act_btd")
            return (h, key), (m_stats, s_stats, m_caches, new_scache)

        mc = None if caches is None else caches.get("stage_mlstm")
        sc = None if caches is None else caches.get("stage_slstm")
        xs = (frozen.get("stage_mlstm"), quant_state.get("stage_mlstm"),
              ml_ad_stage, mc, frozen["stage_slstm"],
              quant_state["stage_slstm"], sc)
        (x, rng), (m_stats, s_stats, m_caches, s_caches) = jax.lax.scan(
            stage_body, (x, rng), xs)
        if per_m:
            stats["stage_mlstm"] = m_stats
            new_caches["stage_mlstm"] = m_caches
        stats["stage_slstm"] = s_stats
        new_caches["stage_slstm"] = s_caches

    if trailing:
        tc = None if caches is None else caches.get("trail_mlstm")
        (x, rng), (t_stats, t_caches) = jax.lax.scan(
            ml_body, (x, rng), (frozen["trail_mlstm"],
                                quant_state["trail_mlstm"], ml_ad_trail, tc))
        stats["trail_mlstm"] = t_stats
        new_caches["trail_mlstm"] = t_caches

    x = L.rmsnorm(x, frozen["final_norm"], cfg.norm_eps)
    logits = L.unembed(x, frozen["lm_head"], act_dtype, cfg.logits_fp32)
    out_caches = new_caches if caches is not None else None
    return ModelOut(logits, stats, out_caches, jnp.zeros((), jnp.float32))


def init_caches_xlstm(cfg: ModelConfig, batch: int, max_len: int):
    n_stages, per_m, trailing = xlstm_layout(cfg)
    mc = S.init_mlstm_cache(cfg, batch)
    sc = S.init_slstm_cache(cfg, batch)
    caches: Dict[str, Any] = {}
    if n_stages and per_m:
        caches["stage_mlstm"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None, None],
                                       (n_stages, per_m) + a.shape).copy(), mc)
    if n_stages:
        caches["stage_slstm"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_stages,) + a.shape).copy(), sc)
    if trailing:
        caches["trail_mlstm"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (trailing,) + a.shape).copy(), mc)
    return caches


def init_slot_caches_xlstm(cfg: ModelConfig, n_slots: int, max_len: int):
    """Slot-pooled decode state for xLSTM. Purely recurrent (no KV cache,
    no seq axis): every leaf is per-row already, so the slot pool IS the
    batched cache — ``max_len`` is accepted for interface uniformity but
    does not size anything (O(1) state per slot)."""
    del max_len
    return init_caches_xlstm(cfg, n_slots, 0)
