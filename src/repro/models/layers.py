"""Shared quant-aware layers: linear init/apply, norms, RoPE, GQA attention
(with KV cache + sliding window), FFN, embeddings.

Parameter layout convention: every linear is a dict
    {"w": <backend-specific weights pytree>}
and, for backends with per-layer state (Quaff's momentum scale), a parallel
state lives in the model-level ``quant_state`` tree (same key path).

Mode dispatch lives entirely in the ``QuantBackend`` registry
(core/backend.py): this module resolves ``qcfg.mode`` to a backend and calls
the protocol. Stats capture is requested with an explicit trace-safe
``StatsScope`` argument (threaded through every forward), not a global flag.
"""
from __future__ import annotations

import math
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import peft as P
from repro.core.backend import Calibration, StatsScope, get_backend
from repro.models.config import ModelConfig, QuantConfig
from repro.runtime.pspec import hint


def dt(name: str):
    return jnp.dtype(name)


def capture_absmax(x: jnp.ndarray) -> jnp.ndarray:
    """Full per-channel absmax (c_in,) of a qlinear input — the calibration
    statistic a ``StatsScope(capture=True)`` pass collects."""
    x2d = jax.lax.stop_gradient(x).reshape((-1, x.shape[-1]))
    return jnp.max(jnp.abs(x2d.astype(jnp.float32)), axis=0)


def remat_wrap(body, remat):
    """remat: False | True/"nothing" | "dots" (checkpoint_dots_with_no_batch
    -dims saves GEMM outputs: ~1/3 less recompute, more activation memory)."""
    if not remat:
        return body
    if remat == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    else:
        pol = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(body, policy=pol)


# ---------------------------------------------------------------------------
# Quantized linear init / apply
# ---------------------------------------------------------------------------
def init_qlinear(
    key,
    c_in: int,
    c_out: int,
    layer_type: str,
    qcfg: QuantConfig,
    *,
    bias: bool = False,
    param_dtype=jnp.float32,
) -> Tuple[Dict[str, Any], Optional[Any]]:
    """Random fp init -> backend-prepared frozen weights (+ optional state).
    Real runs overwrite calibration-dependent pieces via train/calibrate."""
    w = jax.random.normal(key, (c_in, c_out), param_dtype) / math.sqrt(c_in)
    b = jnp.zeros((c_out,), param_dtype) if bias else None
    backend = get_backend(qcfg.mode)
    calib = Calibration(layer_type=layer_type, budgets=qcfg.budgets,
                        init_placeholder=True, group_size=qcfg.group_size)
    wts = backend.prepare(w, b, calib=calib, bits=qcfg.bits)
    return {"w": wts}, backend.init_state(wts)


def _hint_weight_use(wts, use_kind: str = "col"):
    """FSDP storage -> gathered-INT8 use constraint, with the Megatron
    pairing: "col" (column-parallel: c_out over "model", no fwd collective)
    for q/k/v/up/gate, "row" (row-parallel: c_in over "model", one fwd
    all-reduce of the small (tokens, d) output) for o/down projections.
    The row choice replaces a (tokens, d_ff) backward partial-sum all-reduce
    + fwd activation gather with one (tokens, d) fwd all-reduce — measured in
    EXPERIMENTS.md §Perf."""
    def one(arr, ndim_kind):
        if arr is None:
            return None
        return hint(arr, ndim_kind)

    d = wts._asdict() if hasattr(wts, "_asdict") else None
    if d is None:
        return wts
    suffix = "_row" if use_kind == "row" else ""
    # w_packed: the int4 nibble carrier — (c_in/2, c_out), same col/row
    # Megatron pairing as its unpacked counterparts
    for f in ("w", "w_int", "w_fp", "w_packed"):
        if f in d and d[f] is not None:
            kind = ("weight_use2" if d[f].ndim == 2 else
                    "weight_use3" if d[f].ndim == 3 else None)
            if kind:
                d[f] = one(d[f], kind + suffix)
    return type(wts)(**d)


def apply_qlinear(
    x: jnp.ndarray,
    lin: Dict[str, Any],
    qcfg: QuantConfig,
    state: Optional[Any] = None,
    lora: Optional[P.LoRAParams] = None,
    peft_cfg: Optional[P.PEFTConfig] = None,
    use_kind: str = "col",
    scope: Optional[StatsScope] = None,
    rng: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """One quantized linear. ``scope`` requests full-absmax stats capture;
    ``rng`` (train path only) enables LoRA dropout — eval passes None and
    stays deterministic."""
    backend = get_backend(qcfg.mode)
    out = backend.apply(x, _hint_weight_use(lin["w"], use_kind), state=state,
                        bits=qcfg.bits, bwd_int8=qcfg.bwd_int8)
    y, stats = out.y, out.stats
    if scope is not None and scope.capture:
        stats = capture_absmax(x)  # (c_in,)
    if lora is not None:
        dropout = peft_cfg.lora_dropout if rng is not None else 0.0
        y = y + P.apply_lora(x, lora, peft_cfg.lora_alpha, peft_cfg.lora_rank,
                             dropout, rng)
    return y, stats


# ---------------------------------------------------------------------------
# Norms / embeddings / positions
# ---------------------------------------------------------------------------
def init_rmsnorm(d: int, dtype=jnp.float32) -> Dict[str, jnp.ndarray]:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(x: jnp.ndarray, p: Dict[str, jnp.ndarray], eps: float) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def init_embedding(key, vocab: int, d: int, dtype=jnp.float32) -> Dict[str, jnp.ndarray]:
    return {"tokens": jax.random.normal(key, (vocab, d), dtype) * 0.02}


def embed(tokens: jnp.ndarray, emb: Dict[str, jnp.ndarray], dtype) -> jnp.ndarray:
    return jnp.take(emb["tokens"], tokens, axis=0).astype(dtype)


def unembed(x: jnp.ndarray, emb_or_head, dtype, fp32: bool = True) -> jnp.ndarray:
    """Project to vocab. Tied: x @ E^T; untied: fp linear (lm_head stays fp —
    the paper quantizes interior linears; the head feeds the softmax).
    ``fp32=False`` computes the projection in act dtype (bf16 on TPU) —
    halves the biggest fp GEMM + the logits residency (SPerf knob); the loss
    still reduces in fp32."""
    w = emb_or_head["tokens"].T if "tokens" in emb_or_head else emb_or_head["w"]
    cdt = jnp.float32 if fp32 else dtype
    logits = x.astype(cdt) @ w.astype(cdt)
    return hint(logits, "logits")


def sinusoidal_positions(n_pos: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(n_pos, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) * (-math.log(10000.0) / d))
    pe = jnp.zeros((n_pos, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, hd); positions: (B, S) or (S,) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # (hd/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, optional sliding window, optional KV cache)
# ---------------------------------------------------------------------------
def init_attention(key, cfg: ModelConfig, qcfg: QuantConfig, param_dtype):
    ks = jax.random.split(key, 4)
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    wq, sq = init_qlinear(ks[0], d, qd, "q_proj", qcfg, bias=cfg.qkv_bias,
                          param_dtype=param_dtype)
    wk, sk = init_qlinear(ks[1], d, kvd, "k_proj", qcfg, bias=cfg.qkv_bias,
                          param_dtype=param_dtype)
    wv, sv = init_qlinear(ks[2], d, kvd, "v_proj", qcfg, bias=cfg.qkv_bias,
                          param_dtype=param_dtype)
    wo, so = init_qlinear(ks[3], qd, d, "o_proj", qcfg, param_dtype=param_dtype)
    params = {"wq": wq, "wk": wk, "wv": wv, "wo": wo}
    states = {"wq": sq, "wk": sk, "wv": sv, "wo": so}
    return params, states


# Route paged DECODE attention through the Pallas block-table kernel
# (serving/paged/kernels) instead of the jnp gather path — the paged
# sibling of REPRO_INT4_PALLAS, read once so jit cache keys stay stable.
_PAGED_PALLAS = os.environ.get(
    "REPRO_PAGED_PALLAS", "").lower() in ("1", "true", "yes")

# Route ragged mixed-batch attention (the unified prefill+decode step) and
# uniform multi-token paged chunks — spec-decode verify included — through
# the Pallas ragged flash kernel (kernels/ragged_attention.py), plus the
# fused ragged QKV GEMM on int4 carriers (kernels/ragged_matmul.py). Same
# read-once convention as the flags above.
_RAGGED_PALLAS = os.environ.get(
    "REPRO_RAGGED_PALLAS", "").lower() in ("1", "true", "yes")


def _gqa_scores_softmax_out(q, k, v, mask):
    """q: (B,S,KH,G,hd); k,v: (B,T,KH,hd); mask: broadcastable (B,1,1,S,T)."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bskgh,btkh->bkgst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v.astype(jnp.float32))
    return out


def _ragged_qkv_proj(x, params, qcfg, ad, pcfg, n_tok, scope, rng_q, rng_v):
    """Fused ragged QKV for the unified mixed-batch step: when all three
    projections carry packed-int4 weights, quantize the flattened stream
    once and run ONE pad-block-skipping GEMM (kernels/ragged_matmul.py) —
    the same integer math as three ``apply_qlinear`` calls. Returns None to
    fall back onto the per-projection path (non-int4 carriers)."""
    from repro.core.int4 import Int4Weights
    wts = [params[n]["w"] for n in ("wq", "wk", "wv")]
    if not all(isinstance(w, Int4Weights) for w in wts):
        return None
    from repro.core import quant as Q
    from repro.kernels.ragged_matmul import ragged_qkv_matmul
    x_bits = 4 if qcfg.mode == "int4" else 8
    x2d = x.reshape((-1, x.shape[-1]))
    x_int, x_delta = Q.quantize(x2d, axis=-1, bits=x_bits)
    ys = ragged_qkv_matmul(
        x_int, x_delta, [w.w_packed for w in wts],
        [w.w_delta for w in wts], n_tok,
        interpret=jax.default_backend() != "tpu")
    outs = []
    for y, w in zip(ys, wts):
        if w.bias is not None:
            y = y + w.bias.astype(y.dtype)
        outs.append(y.astype(x.dtype).reshape(x.shape[:-1] + (y.shape[-1],)))
    q, k, v = outs
    if ad.get("lora_q") is not None:
        dropout = pcfg.lora_dropout if rng_q is not None else 0.0
        q = q + P.apply_lora(x, ad["lora_q"], pcfg.lora_alpha,
                             pcfg.lora_rank, dropout, rng_q)
    if ad.get("lora_v") is not None:
        dropout = pcfg.lora_dropout if rng_v is not None else 0.0
        v = v + P.apply_lora(x, ad["lora_v"], pcfg.lora_alpha,
                             pcfg.lora_rank, dropout, rng_v)
    st = capture_absmax(x) if scope is not None and scope.capture else None
    return q, k, v, st, st, st


def _ragged_mixed_step(q, k, v, cache, positions, cfg, exact_kv_reads):
    """Unified mixed-batch attention over a flattened ragged stream: rows
    are located by ``row_start``/``row_len``/``row_ids`` (serving's unified
    step packs prefill tails and decode slots into one batch), each row
    attends to its pool prefix ``[0, cursor)`` plus its own causally-masked
    span. Serves BOTH KV layouts — a contiguous slot buffer is a one-page
    pool with an identity block table. Pad tokens (past ``n_tok``) scatter
    out of bounds with ``mode="drop"`` and gather don't-care rows.

    Per-row read-after-write fidelity matches the two-dispatch baseline on
    int8 pools: prefill spans attend to themselves in fp straight from
    registers, decode rows read their single token through the quantizer
    round trip, and ``exact_kv_reads`` (spec verify) round-trips everything.

    Returns (out (1, T, KH, G, hd) f32, new_cache)."""
    from repro.serving.paged import kvquant as KVQ
    rs, rl = cache["row_start"], cache["row_len"]            # (R,)
    rid = cache["row_ids"]                                   # (T,)
    cur = cache["pos"]                                       # (R,)
    n_tok = cache["n_tok"]                                   # () int32
    t_len = q.shape[1]
    qs, ks, vs = q[0], k[0], v[0]                 # streams (T, ...)
    tpos = positions[0] if positions.ndim == 2 else positions
    valid = jnp.arange(t_len, dtype=jnp.int32) < n_tok
    new_cache = dict(cache)
    new_cache["pos"] = cur + rl
    k_scale = v_scale = None
    if "k_pool" in cache:
        k_pool, v_pool = cache["k_pool"], cache["v_pool"]
        bt = cache["block_tables"]                           # (R, P)
        blk = k_pool.shape[1]
        page = jnp.where(valid, bt[rid, tpos // blk], k_pool.shape[0])
        off = tpos % blk
        quantized = k_pool.dtype == jnp.int8
        if quantized:
            qk = KVQ.quantize_k(ks, cache["k_scale"])
            qv, vsc = KVQ.quantize_v(vs)
            k_pool = k_pool.at[page, off].set(qk, mode="drop")
            v_pool = v_pool.at[page, off].set(qv, mode="drop")
            new_cache["v_scale"] = cache["v_scale"].at[page, off].set(
                vsc, mode="drop")
            rt_k = KVQ.dequant_k(qk, cache["k_scale"])
            rt_v = KVQ.dequant_v(qv, vsc)
            if exact_kv_reads:
                ks_eff, vs_eff = rt_k, rt_v
            else:
                rt = (rl[rid] == 1)[:, None, None]
                ks_eff = jnp.where(rt, rt_k, ks)
                vs_eff = jnp.where(rt, rt_v, vs)
        else:
            k_pool = k_pool.at[page, off].set(ks.astype(k_pool.dtype),
                                              mode="drop")
            v_pool = v_pool.at[page, off].set(vs.astype(v_pool.dtype),
                                              mode="drop")
            ks_eff, vs_eff = ks, vs
        new_cache.update(k_pool=k_pool, v_pool=v_pool)
        k_ctx, v_ctx, tables = k_pool, v_pool, bt
        k_scale = cache.get("k_scale")
        v_scale = new_cache.get("v_scale")
    else:
        buf_k, buf_v = cache["k"], cache["v"]      # (R, S, kh, hd) slots
        n_rows = buf_k.shape[0]
        slot = jnp.where(valid, rid, n_rows)
        buf_k = buf_k.at[slot, tpos].set(ks.astype(buf_k.dtype),
                                         mode="drop")
        buf_v = buf_v.at[slot, tpos].set(vs.astype(buf_v.dtype),
                                         mode="drop")
        new_cache.update(k=buf_k, v=buf_v)
        k_ctx, v_ctx = buf_k, buf_v
        tables = jnp.arange(n_rows, dtype=jnp.int32)[:, None]
        ks_eff, vs_eff = ks, vs
    if _RAGGED_PALLAS and not cfg.sliding_window:
        from repro.kernels.ragged_attention import ragged_attention_auto
        out_rows = ragged_attention_auto(
            qs, ks_eff, vs_eff, k_ctx, v_ctx, tables, rs, rl, cur,
            k_scale, v_scale, max_row_len=t_len)
    else:
        from repro.kernels.ragged_attention import ragged_attention_ref
        out_rows = ragged_attention_ref(
            qs, ks_eff, vs_eff, k_ctx, v_ctx, tables, rs, rl, cur,
            k_scale, v_scale, max_row_len=t_len)
    local = jnp.clip(tpos - cur[rid], 0, t_len - 1)
    return out_rows[rid, local][None], new_cache


def attention(
    x: jnp.ndarray,
    params: Dict[str, Any],
    states: Dict[str, Optional[ScaleState]],
    cfg: ModelConfig,
    *,
    positions: jnp.ndarray,            # (S,) or (B,S) query positions
    is_global: bool = True,            # False -> sliding window layer
    causal: bool = True,
    cache: Optional[Dict[str, jnp.ndarray]] = None,   # decode KV cache
    adapters: Optional[Dict[str, Any]] = None,
    kv_override: Optional[jnp.ndarray] = None,        # cross-attention input
    cross_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,  # cached (k,v)
    exact_kv_reads: bool = False,      # int8 pools: no within-call fp override
    scope: Optional[StatsScope] = None,
    rng: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]], Dict[str, Any]]:
    """Returns (y, new_cache, stats). Shapes: x (B,S,D)."""
    qcfg, pcfg = cfg.quant, cfg.peft
    bsz, s_len, _ = x.shape
    kh, h, hd = cfg.n_kv_heads, cfg.n_heads, cfg.head_dim
    g = h // kh
    ad = adapters or {}
    rng_q = rng_v = None
    if rng is not None:
        rng_q, rng_v = jax.random.split(rng)

    fused_qkv = None
    if (_RAGGED_PALLAS and cache is not None and kv_override is None
            and cross_kv is None and "row_start" in cache):
        fused_qkv = _ragged_qkv_proj(x, params, qcfg, ad, pcfg,
                                     cache["n_tok"], scope, rng_q, rng_v)
    if fused_qkv is not None:
        q, k, v, st_q, st_k, st_v = fused_qkv
    else:
        q, st_q = apply_qlinear(x, params["wq"], qcfg, states.get("wq"),
                                ad.get("lora_q"), pcfg, scope=scope,
                                rng=rng_q)
    if cross_kv is not None:
        # precomputed cross-attention K/V (enc-dec decode path)
        k, v = cross_kv
        q = q.reshape(bsz, s_len, kh, g, hd)
        mask = jnp.ones((1, 1, 1, s_len, k.shape[1]), dtype=bool)
        out = _gqa_scores_softmax_out(q, k, v, mask)
        out = out.reshape(bsz, s_len, h * hd).astype(x.dtype)
        y, st_o = apply_qlinear(out, params["wo"], qcfg, states.get("wo"),
                                use_kind="row", scope=scope)
        return y, None, {"wq": st_q, "wk": None, "wv": None, "wo": st_o}
    kv_in = kv_override if kv_override is not None else x
    if fused_qkv is None:
        k, st_k = apply_qlinear(kv_in, params["wk"], qcfg, states.get("wk"),
                                scope=scope)
        v, st_v = apply_qlinear(kv_in, params["wv"], qcfg, states.get("wv"),
                                ad.get("lora_v"), pcfg, scope=scope,
                                rng=rng_v)

    q = hint(q.reshape(bsz, s_len, kh, g, hd), "attn_q")
    k = hint(k.reshape(bsz, kv_in.shape[1], kh, hd), "attn_kv")
    v = hint(v.reshape(bsz, kv_in.shape[1], kh, hd), "attn_kv")
    if "ia3" in ad:
        k = k * ad["ia3"].l_k.reshape(1, 1, kh, hd).astype(k.dtype)
        v = v * ad["ia3"].l_v.reshape(1, 1, kh, hd).astype(v.dtype)

    if cfg.use_rope and kv_override is None:
        q4 = q.reshape(bsz, s_len, kh * g, hd)
        q = apply_rope(q4, positions, cfg.rope_theta).reshape(bsz, s_len, kh, g, hd)
        k = apply_rope(k, positions, cfg.rope_theta)

    kv_stats = None
    if scope is not None and scope.capture:
        # per-channel absmax of the to-be-cached (rotated) K/V: seeds the
        # paged int8 pool's static key-channel grid (serving.paged.kvquant)
        # from the same calibration set that fixes the outlier channels
        def kv_abs(a):
            a32 = jax.lax.stop_gradient(a).astype(jnp.float32)
            return jnp.max(jnp.abs(a32), axis=(0, 1))        # (kh, hd)
        kv_stats = {"k": kv_abs(k), "v": kv_abs(v)}

    new_cache = None
    if cache is not None and kv_override is None and "row_start" in cache:
        # unified ragged mixed batch (serving's one-dispatch step): prefill
        # tails and decode slots share this call; _ragged_mixed_step writes
        # each row's span through its block table (or slot buffer) and
        # attends pool-prefix + causal self span per row
        out, new_cache = _ragged_mixed_step(q, k, v, cache, positions, cfg,
                                            exact_kv_reads)
        out = out.reshape(bsz, s_len, h * hd).astype(x.dtype)
        y, st_o = apply_qlinear(out, params["wo"], qcfg, states.get("wo"),
                                use_kind="row", scope=scope)
        stats = {"wq": st_q, "wk": st_k, "wv": st_v, "wo": st_o}
        if kv_stats is not None:
            stats["kv"] = kv_stats
        return y, new_cache, stats
    if cache is not None and kv_override is None and "k_pool" in cache:
        # paged (block-pool) path: each of the row's s_len tokens lands at
        # cache position pos+i, which the per-request block table maps to
        # (page, offset) — pool writes are scatters, reads are block-table
        # gathers, and int8 pools quantize on write / dequantize on read
        # (per-channel K grid, per-token V scales; serving.paged.kvquant).
        from repro.serving.paged import kvquant as KVQ
        pos = cache["pos"]                                           # (B,)
        bt = cache["block_tables"]                                   # (B,P)
        k_pool, v_pool = cache["k_pool"], cache["v_pool"]
        blk = k_pool.shape[1]
        tpos = pos[:, None] + jnp.arange(s_len, dtype=jnp.int32)[None, :]
        page = jnp.take_along_axis(bt, tpos // blk, axis=1)          # (B,S)
        off = tpos % blk
        quantized = k_pool.dtype == jnp.int8
        new_cache = dict(cache)
        if quantized:
            qk = KVQ.quantize_k(k, cache["k_scale"])
            qv, vsc = KVQ.quantize_v(v)
            k_pool = k_pool.at[page, off].set(qk)
            v_pool = v_pool.at[page, off].set(qv)
            new_cache["v_scale"] = cache["v_scale"].at[page, off].set(vsc)
        else:
            k_pool = k_pool.at[page, off].set(k.astype(k_pool.dtype))
            v_pool = v_pool.at[page, off].set(v.astype(v_pool.dtype))
        new_cache.update(k_pool=k_pool, v_pool=v_pool, pos=pos + s_len)
        if s_len == 1 and _PAGED_PALLAS and not cfg.sliding_window:
            # decode hot path: fused gather-dequant-attention kernel. The
            # kernel reads every position — the current token included —
            # from the pool; the jnp s_len==1 branch below reads the same
            # way, so the two decode paths are numerically aligned on int8
            # pools (fp pools are exact either way).
            from repro.serving.paged.kernels.paged_attention import (
                paged_attention_auto)
            out = paged_attention_auto(
                q[:, 0], k_pool, v_pool, bt, pos + 1,
                new_cache.get("k_scale"), new_cache.get("v_scale"))
            out = out[:, None]                           # (B,1,KH,G,hd)
        elif _RAGGED_PALLAS and not cfg.sliding_window:
            # uniform (B, S) paged chunks — prefill groups, decode, and
            # spec-decode's K+1-row verify batch — are ragged batches with
            # equal spans: flatten and reuse the unified kernel. The
            # effective self-stream reproduces the read-after-write rules
            # below (fp for non-exact prefill, round trip otherwise).
            from repro.kernels.ragged_attention import ragged_attention_auto
            if quantized and not (s_len > 1 and not exact_kv_reads):
                ks_eff = KVQ.dequant_k(qk, cache["k_scale"])
                vs_eff = KVQ.dequant_v(qv, vsc)
            else:
                ks_eff, vs_eff = k, v
            rs = jnp.arange(bsz, dtype=jnp.int32) * s_len
            rl = jnp.full((bsz,), s_len, jnp.int32)

            def flat(a):
                return a.reshape((bsz * s_len,) + a.shape[2:])

            out = ragged_attention_auto(
                flat(q), flat(ks_eff), flat(vs_eff), k_pool, v_pool, bt,
                rs, rl, pos, cache.get("k_scale"),
                new_cache.get("v_scale"), max_row_len=s_len)
        else:
            kg, vg = k_pool[bt], v_pool[bt]              # (B,P,blk,kh,hd)
            if quantized:
                kf = KVQ.dequant_k(kg, cache["k_scale"])
                vf = KVQ.dequant_v(vg, new_cache["v_scale"][bt])
            else:
                kf, vf = kg, vg
            t_len = bt.shape[1] * blk
            kf = kf.reshape(bsz, t_len, kh, hd)
            vf = vf.reshape(bsz, t_len, kh, hd)
            if quantized and s_len > 1 and not exact_kv_reads:
                # PREFILL read-after-write fidelity: this chunk's own
                # tokens attend in fp straight from registers — the pool's
                # int8 copy is for FUTURE steps. Makes whole-prompt prefill
                # exact vs the contiguous fp path; only already-retired
                # positions carry quantization error. Single-token DECODE
                # skips it (reads its own position quantized, matching the
                # fused kernel), and speculative verification passes
                # ``exact_kv_reads=True`` so its K+1-wide chunk sees
                # byte-identical KV to the sequential decode it must
                # reproduce token-for-token.
                row = jnp.arange(bsz, dtype=jnp.int32)[:, None]
                kf = kf.at[row, tpos].set(k.astype(kf.dtype))
                vf = vf.at[row, tpos].set(v.astype(vf.dtype))
            kf = hint(kf, "kv_cache")
            vf = hint(vf, "kv_cache")
            k_pos = jnp.arange(t_len, dtype=jnp.int32)               # (T,)
            mask = k_pos[None, None, :] <= tpos[:, :, None]          # (B,S,T)
            if cfg.sliding_window:
                win = (tpos[:, :, None] - k_pos[None, None, :]) \
                    < cfg.sliding_window
                mask = jnp.logical_and(mask, jnp.logical_or(win, is_global))
            out = _gqa_scores_softmax_out(q, kf, vf, mask[:, None, None])
        out = out.reshape(bsz, s_len, h * hd).astype(x.dtype)
        y, st_o = apply_qlinear(out, params["wo"], qcfg, states.get("wo"),
                                use_kind="row", scope=scope)
        stats = {"wq": st_q, "wk": st_k, "wv": st_v, "wo": st_o}
        if kv_stats is not None:
            stats["kv"] = kv_stats
        return y, new_cache, stats
    if cache is not None and kv_override is None and cache["pos"].ndim == 1:
        # slot decode (continuous batching): per-row write cursors (B,).
        # Each slot writes this step's k/v at its OWN position and masks by
        # its OWN length — rows never block each other, so one compiled
        # step serves a changing request mix (repro.serving.Engine).
        pos = cache["pos"]                                           # (B,)
        def _row_write(buf, new):
            return jax.vmap(
                lambda b, n, p: jax.lax.dynamic_update_slice(b, n, (p, 0, 0))
            )(buf, new.astype(buf.dtype), pos)
        ck = _row_write(cache["k"], k)
        cv = _row_write(cache["v"], v)
        new_cache = {"k": ck, "v": cv, "pos": pos + s_len}
        k, v = hint(ck, "kv_cache"), hint(cv, "kv_cache")
        t_len = k.shape[1]
        k_pos = jnp.arange(t_len, dtype=jnp.int32)                   # (T,)
        q_pos = pos[:, None] + jnp.arange(s_len, dtype=jnp.int32)[None, :]
        mask = k_pos[None, None, :] <= q_pos[:, :, None]             # (B,S,T)
        if cfg.sliding_window:
            win = (q_pos[:, :, None] - k_pos[None, None, :]) < cfg.sliding_window
            mask = jnp.logical_and(mask, jnp.logical_or(win, is_global))
        mask = mask[:, None, None, :, :]
    elif cache is not None and kv_override is None:
        # decode: write this step's k/v at cache["pos"], attend over buffer
        pos = cache["pos"]
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, pos, 0, 0))
        new_cache = {"k": ck, "v": cv, "pos": pos + s_len}
        k, v = hint(ck, "kv_cache"), hint(cv, "kv_cache")
        t_len = k.shape[1]
        k_pos = jnp.arange(t_len, dtype=jnp.int32)[None, :]          # (1,T)
        q_pos = (pos + jnp.arange(s_len, dtype=jnp.int32))[:, None]  # (S,1)
        mask = k_pos <= q_pos                                        # (S,T)
        if cfg.sliding_window:
            # is_global may be a traced bool (scanned local/global pattern)
            win = (q_pos - k_pos) < cfg.sliding_window
            mask = jnp.logical_and(mask, jnp.logical_or(win, is_global))
        mask = mask[None, None, None, :, :]
    else:
        t_len = k.shape[1]
        if causal and kv_override is None:
            q_pos = jnp.arange(s_len, dtype=jnp.int32)[:, None]
            k_pos = jnp.arange(t_len, dtype=jnp.int32)[None, :]
            mask = k_pos <= q_pos
            if cfg.sliding_window:
                win = (q_pos - k_pos) < cfg.sliding_window
                mask = jnp.logical_and(mask, jnp.logical_or(win, is_global))
            mask = mask[None, None, None, :, :]
        else:
            mask = jnp.ones((1, 1, 1, s_len, t_len), dtype=bool)

    out = _gqa_scores_softmax_out(q, k, v, mask)
    out = out.reshape(bsz, s_len, h * hd).astype(x.dtype)
    y, st_o = apply_qlinear(out, params["wo"], qcfg, states.get("wo"),
                            use_kind="row", scope=scope)
    stats = {"wq": st_q, "wk": st_k, "wv": st_v, "wo": st_o}
    if kv_stats is not None:
        stats["kv"] = kv_stats
    return y, new_cache, stats


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Dict[str, jnp.ndarray]:
    shape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "pos": jnp.zeros((), jnp.int32)}


# ---------------------------------------------------------------------------
# Dense FFN (SwiGLU or GELU)
# ---------------------------------------------------------------------------
def init_ffn(key, cfg: ModelConfig, qcfg: QuantConfig, param_dtype):
    ks = jax.random.split(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    params, states = {}, {}
    if cfg.ffn_type == "swiglu":
        params["gate"], states["gate"] = init_qlinear(
            ks[0], d, f, "gate_proj", qcfg, param_dtype=param_dtype)
    params["up"], states["up"] = init_qlinear(
        ks[1], d, f, "up_proj", qcfg, param_dtype=param_dtype)
    params["down"], states["down"] = init_qlinear(
        ks[2], f, d, "down_proj", qcfg, param_dtype=param_dtype)
    return params, states


def ffn(x, params, states, cfg: ModelConfig, adapters=None, scope=None):
    qcfg = cfg.quant
    ad = adapters or {}
    stats = {}
    if cfg.ffn_type == "swiglu":
        gate, stats["gate"] = apply_qlinear(x, params["gate"], qcfg,
                                            states.get("gate"), scope=scope)
        up, stats["up"] = apply_qlinear(x, params["up"], qcfg,
                                        states.get("up"), scope=scope)
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    else:
        up, stats["up"] = apply_qlinear(x, params["up"], qcfg,
                                        states.get("up"), scope=scope)
        h = jax.nn.gelu(up.astype(jnp.float32)).astype(x.dtype)
    if "ia3" in ad:
        h = h * ad["ia3"].l_ff.astype(h.dtype)
    h = hint(h, "act_btf")
    y, stats["down"] = apply_qlinear(h, params["down"], qcfg,
                                     states.get("down"), use_kind="row",
                                     scope=scope)
    return y, stats
