"""Whisper-style encoder-decoder backbone (audio frontend is a STUB per the
assignment: ``input_specs`` provides precomputed frame embeddings of shape
(B, encoder_seq, d_model), standing in for the conv1d+mel frontend).

Encoder: bidirectional self-attn + GELU FFN, sinusoidal positions.
Decoder: causal self-attn + cross-attn to encoder output + GELU FFN.
Decode-time caches: per-layer self-attn KV (growing) + cross-attn KV
(precomputed at prefill, static afterwards).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core import peft as PEFT
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.outputs import ModelOut
from repro.runtime.pspec import hint


def _init_encdec_block(key, cfg: ModelConfig, param_dtype, *, cross: bool):
    ks = jax.random.split(key, 3)
    attn_p, attn_s = L.init_attention(ks[0], cfg, cfg.quant, param_dtype)
    params = {
        "attn": attn_p,
        "norm1": L.init_rmsnorm(cfg.d_model),
        "norm2": L.init_rmsnorm(cfg.d_model),
    }
    states = {"attn": attn_s}
    if cross:
        xattn_p, xattn_s = L.init_attention(ks[1], cfg, cfg.quant, param_dtype)
        params["xattn"] = xattn_p
        params["norm_x"] = L.init_rmsnorm(cfg.d_model)
        states["xattn"] = xattn_s
    ffn_p, ffn_s = L.init_ffn(ks[2], cfg, cfg.quant, param_dtype)
    params["ffn"] = ffn_p
    states["ffn"] = ffn_s
    return params, states


def init_params(key, cfg: ModelConfig):
    param_dtype = L.dt(cfg.param_dtype)
    keys = jax.random.split(key, 5)
    frozen: Dict[str, Any] = {
        "embed": L.init_embedding(keys[0], cfg.vocab_size, cfg.d_model, param_dtype)
    }
    qstate: Dict[str, Any] = {}
    frozen["enc_blocks"], qstate["enc_blocks"] = jax.vmap(
        lambda k: _init_encdec_block(k, cfg, param_dtype, cross=False)
    )(jax.random.split(keys[1], cfg.n_encoder_layers))
    frozen["dec_blocks"], qstate["dec_blocks"] = jax.vmap(
        lambda k: _init_encdec_block(k, cfg, param_dtype, cross=True)
    )(jax.random.split(keys[2], cfg.n_layers))
    frozen["enc_norm"] = L.init_rmsnorm(cfg.d_model)
    frozen["final_norm"] = L.init_rmsnorm(cfg.d_model)
    frozen["lm_head"] = {
        "w": jax.random.normal(keys[3], (cfg.d_model, cfg.vocab_size),
                               param_dtype) * 0.02}

    adapters: Dict[str, Any] = {}
    p = cfg.peft
    if p.method == "lora":
        def init_ad(k):
            k1, k2 = jax.random.split(k)
            return {"lora_q": PEFT.init_lora(k1, cfg.d_model, cfg.q_dim, p.lora_rank),
                    "lora_v": PEFT.init_lora(k2, cfg.d_model, cfg.kv_dim, p.lora_rank)}
        adapters["dec_blocks"] = jax.vmap(init_ad)(
            jax.random.split(keys[4], cfg.n_layers))
    elif p.method == "ia3":
        adapters["dec_blocks"] = jax.vmap(
            lambda k: {"ia3": PEFT.init_ia3(cfg.kv_dim, cfg.d_ff)}
        )(jax.random.split(keys[4], cfg.n_layers))
    elif p.method in ("prompt", "ptuning"):
        adapters["prompt"] = (
            PEFT.init_prompt(keys[4], p.n_virtual_tokens, cfg.d_model)
            if p.method == "prompt"
            else PEFT.init_ptuning(keys[4], p.n_virtual_tokens, cfg.d_model,
                                   p.ptuning_hidden))
    return frozen, adapters, qstate


def encode(frozen, quant_state, frames: jnp.ndarray, cfg: ModelConfig,
           remat: bool = False, scope=None):
    """frames: (B, encoder_seq, D) precomputed embeddings (stub frontend)."""
    act_dtype = L.dt(cfg.act_dtype)
    x = frames.astype(act_dtype)
    x = x + L.sinusoidal_positions(x.shape[1], cfg.d_model).astype(act_dtype)[None]
    x = hint(x, "act_btd")
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)

    def body(carry, xs):
        h = carry
        block, qs = xs
        a_in = L.rmsnorm(h, block["norm1"], cfg.norm_eps)
        a_out, _, a_st = L.attention(a_in, block["attn"], qs["attn"], cfg,
                                     positions=positions, causal=False,
                                     scope=scope)
        h = hint(h + a_out, "act_btd")
        f_in = L.rmsnorm(h, block["norm2"], cfg.norm_eps)
        f_out, f_st = L.ffn(f_in, block["ffn"], qs["ffn"], cfg, scope=scope)
        h = hint(h + f_out, "act_btd")
        return h, {"attn": a_st, "ffn": f_st}

    body = L.remat_wrap(body, remat)
    x, enc_stats = jax.lax.scan(body, x, (frozen["enc_blocks"],
                                          quant_state["enc_blocks"]))
    return L.rmsnorm(x, frozen["enc_norm"], cfg.norm_eps), enc_stats


def forward(frozen, adapters, quant_state, tokens, cfg: ModelConfig, *,
            input_embeds=None, caches=None, positions=None, remat=False,
            enc_out=None, scope=None, rng=None):
    """Decoder forward. ``input_embeds`` is the encoder frame input (stub);
    pass ``enc_out`` directly to skip re-encoding (decode steps), or
    ``caches`` with precomputed cross-KV."""
    act_dtype = L.dt(cfg.act_dtype)
    stats: Dict[str, Any] = {}
    if enc_out is None and input_embeds is not None:
        enc_out, stats["enc_blocks"] = encode(frozen, quant_state, input_embeds,
                                              cfg, remat, scope=scope)

    x = L.embed(tokens, frozen["embed"], act_dtype)
    if positions is None:
        x = x + L.sinusoidal_positions(x.shape[1], cfg.d_model).astype(
            act_dtype)[None]
    else:
        # decode: absolute sinusoidal position looked up from a static
        # table. (S,) positions are shared across the batch (lockstep);
        # (B,S) positions are PER ROW — the slot-decode branch, where each
        # slot of a continuous batch sits at its own absolute position.
        pe = L.sinusoidal_positions(65536, cfg.d_model)
        pos_emb = jnp.take(pe, positions, axis=0).astype(act_dtype)
        x = x + (pos_emb[None] if positions.ndim == 1 else pos_emb)
    if "prompt" in adapters:
        x = (PEFT.apply_prompt(x, adapters["prompt"])
             if isinstance(adapters["prompt"], PEFT.PromptParams)
             else PEFT.apply_ptuning(x, adapters["prompt"]))
    x = hint(x, "act_btd")
    s_len = x.shape[1]
    if positions is None:
        positions = jnp.arange(s_len, dtype=jnp.int32)

    dec_ad = adapters.get("dec_blocks")

    def body(carry, xs):
        h, key = carry
        block, qs, ad, cache = xs
        sub = None
        if key is not None:
            key, sub = jax.random.split(key)
        self_cache = None if cache is None else cache["self"]
        a_in = L.rmsnorm(h, block["norm1"], cfg.norm_eps)
        a_out, new_self, a_st = L.attention(
            a_in, block["attn"], qs["attn"], cfg, positions=positions,
            cache=self_cache, adapters=ad, scope=scope, rng=sub)
        h = hint(h + a_out, "act_btd")
        x_in = L.rmsnorm(h, block["norm_x"], cfg.norm_eps)
        new_cross = None
        if cache is not None and enc_out is None:
            # decode: cross K/V were cached at prefill
            x_out, _, x_st = L.attention(
                x_in, block["xattn"], qs["xattn"], cfg, positions=positions,
                causal=False, cross_kv=(cache["cross"]["k"],
                                        cache["cross"]["v"]))
            new_cross = cache["cross"]
        else:
            x_out, _, x_st = L.attention(
                x_in, block["xattn"], qs["xattn"], cfg, positions=positions,
                causal=False, kv_override=enc_out, scope=scope)
            if cache is not None:
                # prefill: populate the cross-KV cache for later decode steps
                kh, hd = cfg.n_kv_heads, cfg.head_dim
                xk, _ = L.apply_qlinear(enc_out, block["xattn"]["wk"],
                                        cfg.quant, qs["xattn"].get("wk"))
                xv, _ = L.apply_qlinear(enc_out, block["xattn"]["wv"],
                                        cfg.quant, qs["xattn"].get("wv"))
                new_cross = {
                    "k": xk.reshape(xk.shape[0], xk.shape[1], kh, hd),
                    "v": xv.reshape(xv.shape[0], xv.shape[1], kh, hd),
                }
        h = hint(h + x_out, "act_btd")
        f_in = L.rmsnorm(h, block["norm2"], cfg.norm_eps)
        f_out, f_st = L.ffn(f_in, block["ffn"], qs["ffn"], cfg, scope=scope)
        h = hint(h + f_out, "act_btd")
        new_cache = None if cache is None else {"self": new_self,
                                                "cross": new_cross}
        return (h, key), ({"attn": a_st, "xattn": x_st, "ffn": f_st},
                          new_cache)

    body = L.remat_wrap(body, remat)
    xs = (frozen["dec_blocks"], quant_state["dec_blocks"], dec_ad, caches)
    (x, _), (dec_stats, new_caches) = jax.lax.scan(body, (x, rng), xs)
    stats["dec_blocks"] = dec_stats

    x = L.rmsnorm(x, frozen["final_norm"], cfg.norm_eps)
    logits = L.unembed(x, frozen["lm_head"], act_dtype, cfg.logits_fp32)
    return ModelOut(logits, stats, new_caches, jnp.zeros((), jnp.float32))


def init_caches(cfg: ModelConfig, batch: int, max_len: int):
    act_dtype = L.dt(cfg.act_dtype)
    kv = L.init_kv_cache(cfg, batch, max_len, act_dtype)
    cross_shape = (batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.head_dim)
    one = {"self": kv,
           "cross": {"k": jnp.zeros(cross_shape, act_dtype),
                     "v": jnp.zeros(cross_shape, act_dtype)}}
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape).copy(), one)


def init_slot_caches(cfg: ModelConfig, n_slots: int, max_len: int):
    """Slot-pooled decode state (serving.state.CrossAttnPool): self-attn
    KV with a PER-SLOT write cursor ((L, n_slots) — routes
    ``layers.attention`` through its per-row cursor branch) plus each
    request's cross-KV rows (the projected encoder output, written once at
    admission and static afterwards; zero rows for text-only requests,
    matching the lockstep no-frames decode)."""
    caches = init_caches(cfg, n_slots, max_len)
    caches["self"]["pos"] = jnp.zeros((cfg.n_layers, n_slots), jnp.int32)
    return caches
