"""Typed forward output shared by every model family.

Replaces the positional ``(logits, stats, caches, aux)`` 4-tuple. It is a
NamedTuple, so legacy positional unpacking still works, but call sites
should read fields by name — adding a field later then stays non-breaking.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax.numpy as jnp


class ModelOut(NamedTuple):
    """Output of one model forward pass (any family)."""

    logits: jnp.ndarray     # (B, S, vocab)
    stats: Any = None       # per-qlinear stats tree (backend-defined)
    caches: Any = None      # updated decode caches (None outside decode)
    aux_loss: Any = None    # scalar auxiliary loss (MoE load balancing)
