"""Batched per-slot token sampling: greedy / temperature / top-k / top-p,
seeded per request.

One jitted function samples for the WHOLE pool at once — each slot carries
its own (temperature, top_k, top_p, key) row, so a greedy request and a
nucleus-sampled request share the same compiled step. Free slots ride along
with don't-care rows; the engine ignores their output.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.serving.params import SamplingParams


def request_key(params: SamplingParams, token_index: int) -> jnp.ndarray:
    """Key for token ``token_index`` of a request: depends only on the
    request's seed and the token position — NOT on slot assignment or batch
    composition — so seeded streams are reproducible under any admission
    order."""
    return jax.random.fold_in(jax.random.PRNGKey(params.seed), token_index)


def sample_tokens(logits: jnp.ndarray, temperature: jnp.ndarray,
                  top_k: jnp.ndarray, top_p: jnp.ndarray,
                  keys: jnp.ndarray) -> jnp.ndarray:
    """logits (B, V) f32; temperature/top_p (B,) f32; top_k (B,) i32;
    keys (B, 2) PRNG keys. Returns (B,) int32 token ids.

    Rows with ``temperature <= 0`` take the argmax (exactly the lockstep
    greedy path). Others: scale by temperature, keep the top-k logits, then
    the smallest prefix of the remaining distribution with cumulative
    probability >= top_p (the max-probability token always survives), and
    draw categorically with the row's key."""
    v = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    lg = logits / jnp.maximum(temperature, 1e-6)[:, None]
    desc = jnp.sort(lg, axis=-1)[:, ::-1]                       # (B, V) desc
    # top-k: threshold at the k-th largest logit (k<=0 keeps everything)
    k_idx = jnp.clip(jnp.where(top_k > 0, top_k, v) - 1, 0, v - 1)
    kth = jnp.take_along_axis(desc, k_idx[:, None], axis=-1)    # (B, 1)
    lg = jnp.where(lg < kth, -jnp.inf, lg)
    # top-p over the top-k-truncated distribution
    desc = jnp.sort(lg, axis=-1)[:, ::-1]
    probs = jax.nn.softmax(desc, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    keep = (cum - probs) < top_p[:, None]        # prefix up to mass >= top_p
    cutoff = jnp.min(jnp.where(keep, desc, jnp.inf), axis=-1, keepdims=True)
    lg = jnp.where(lg < cutoff, -jnp.inf, lg)

    drawn = jax.vmap(jax.random.categorical)(keys, lg).astype(jnp.int32)
    return jnp.where(temperature > 0.0, drawn, greedy)


def make_sampler():
    return jax.jit(sample_tokens)
