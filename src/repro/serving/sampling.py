"""Batched per-slot token sampling: greedy / temperature / top-k / top-p,
seeded per request.

One jitted function samples for the WHOLE pool at once — each slot carries
its own (temperature, top_k, top_p, key) row, so a greedy request and a
nucleus-sampled request share the same compiled step. Free slots ride along
with don't-care rows; the engine ignores their output.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.serving.params import SamplingParams


def request_key(params: SamplingParams, token_index: int) -> jnp.ndarray:
    """Key for token ``token_index`` of a request: depends only on the
    request's seed and the token position — NOT on slot assignment or batch
    composition — so seeded streams are reproducible under any admission
    order."""
    return jax.random.fold_in(jax.random.PRNGKey(params.seed), token_index)


def _filter_logits(lg: jnp.ndarray, top_k: jnp.ndarray,
                   top_p: jnp.ndarray) -> jnp.ndarray:
    """Top-k + top-p truncation over already-temperature-scaled logits
    (B, V): keep the top-k logits, then the smallest prefix of the
    remaining distribution with cumulative probability >= top_p (the
    max-probability token always survives). Dropped entries go to -inf.

    Shared between ``sample_tokens`` and ``speculative_verify`` — rejection
    sampling must score draft proposals against EXACTLY the distribution
    sequential decode would have sampled from."""
    v = lg.shape[-1]
    desc = jnp.sort(lg, axis=-1)[:, ::-1]                       # (B, V) desc
    # top-k: threshold at the k-th largest logit (k<=0 keeps everything)
    k_idx = jnp.clip(jnp.where(top_k > 0, top_k, v) - 1, 0, v - 1)
    kth = jnp.take_along_axis(desc, k_idx[:, None], axis=-1)    # (B, 1)
    lg = jnp.where(lg < kth, -jnp.inf, lg)
    # top-p over the top-k-truncated distribution
    desc = jnp.sort(lg, axis=-1)[:, ::-1]
    probs = jax.nn.softmax(desc, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    keep = (cum - probs) < top_p[:, None]        # prefix up to mass >= top_p
    cutoff = jnp.min(jnp.where(keep, desc, jnp.inf), axis=-1, keepdims=True)
    return jnp.where(lg < cutoff, -jnp.inf, lg)


def sample_tokens(logits: jnp.ndarray, temperature: jnp.ndarray,
                  top_k: jnp.ndarray, top_p: jnp.ndarray,
                  keys: jnp.ndarray) -> jnp.ndarray:
    """logits (B, V) f32; temperature/top_p (B,) f32; top_k (B,) i32;
    keys (B, 2) PRNG keys. Returns (B,) int32 token ids.

    Rows with ``temperature <= 0`` take the argmax (exactly the lockstep
    greedy path). Others: scale by temperature, truncate with
    ``_filter_logits`` and draw categorically with the row's key."""
    logits = logits.astype(jnp.float32)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lg = _filter_logits(logits / jnp.maximum(temperature, 1e-6)[:, None],
                        top_k, top_p)
    drawn = jax.vmap(jax.random.categorical)(keys, lg).astype(jnp.int32)
    return jnp.where(temperature > 0.0, drawn, greedy)


def make_sampler():
    return jax.jit(sample_tokens)


def _filtered_probs(logits: jnp.ndarray, temperature: jnp.ndarray,
                    top_k: jnp.ndarray, top_p: jnp.ndarray) -> jnp.ndarray:
    """(B, N, V) logits -> (B, N, V) per-position sampling distributions
    under each row's (temperature, top_k, top_p)."""
    b, n, v = logits.shape
    lg = logits / jnp.maximum(temperature, 1e-6)[:, None, None]
    fl = _filter_logits(lg.reshape(b * n, v),
                        jnp.repeat(top_k, n), jnp.repeat(top_p, n))
    return jax.nn.softmax(fl, axis=-1).reshape(b, n, v)


def speculative_verify(target_logits: jnp.ndarray,
                       draft_tokens: jnp.ndarray,
                       draft_logits: jnp.ndarray,
                       temperature: jnp.ndarray, top_k: jnp.ndarray,
                       top_p: jnp.ndarray, keys: jnp.ndarray):
    """Score K draft tokens against one batched target pass.

    ``target_logits`` (B, K+1, V): position j holds the target logits for
    the token AFTER ``[t0, d_1..d_j]`` (the verify chunk feeds the last
    committed token followed by the K proposals, so the forward's causal
    read-after-write yields every conditional at once). ``draft_tokens``
    (B, K) and ``draft_logits`` (B, K, V) are the drafter's proposals and
    raw logits; ``temperature``/``top_p`` (B,) f32, ``top_k`` (B,) i32;
    ``keys`` (B, K+1, 2) one PRNG key per position.

    Returns ``(counts, out_tokens)``: row i commits
    ``out_tokens[i, :counts[i]]`` (1 <= counts <= K+1).

    Greedy rows (temperature <= 0): ``out_tokens`` is the target argmax at
    every position and ``counts - 1`` is the length of the leading run of
    draft tokens matching it — the committed stream is the target argmax
    prefix, token-identical to sequential greedy decode by construction.

    Sampled rows: standard rejection sampling (Leviathan et al.) over the
    SAME top-k/top-p-filtered distributions sequential decode samples
    from. Proposal d_{j+1} is accepted with probability
    min(1, p_j(d)/q_j(d)); the first rejection resamples from
    norm(max(p_j - q_j, 0)); accepting all K earns a bonus token from
    p_K. The committed marginals match sequential sampling exactly (the
    drawn stream differs — speculation consumes randomness differently)."""
    b, kp1, v = target_logits.shape
    k = kp1 - 1
    tl = target_logits.astype(jnp.float32)
    greedy_toks = jnp.argmax(tl, axis=-1).astype(jnp.int32)     # (B, K+1)

    # per-position subkeys: one stream for accept draws, one for resamples
    sub = jax.vmap(lambda kk: jax.random.split(kk, 2))(keys.reshape(-1, 2))
    u_keys = sub[:, 0].reshape(b, kp1, 2)
    r_keys = sub[:, 1].reshape(b, kp1, 2)

    p = _filtered_probs(tl, temperature, top_k, top_p)          # (B, K+1, V)
    if k:
        g_match = greedy_toks[:, :k] == draft_tokens            # (B, K)
        g_m = jnp.sum(jnp.cumprod(g_match.astype(jnp.int32), axis=-1),
                      axis=-1)                                  # leading run
        q = _filtered_probs(draft_logits.astype(jnp.float32),
                            temperature, top_k, top_p)          # (B, K, V)
        d_idx = draft_tokens[..., None]
        p_d = jnp.take_along_axis(p[:, :k], d_idx, axis=-1)[..., 0]
        q_d = jnp.take_along_axis(q, d_idx, axis=-1)[..., 0]
        u = jax.vmap(jax.random.uniform)(
            u_keys[:, :k].reshape(b * k, 2)).reshape(b, k)
        accept = u * jnp.maximum(q_d, 1e-20) < p_d              # (B, K)
        s_m = jnp.sum(jnp.cumprod(accept.astype(jnp.int32), axis=-1),
                      axis=-1)
        # residual distribution at each position (used only at the first
        # rejection); all-zero residual (p == q) falls back to p
        res = jnp.maximum(p[:, :k] - q, 0.0)
        res_sum = jnp.sum(res, axis=-1, keepdims=True)
        res = jnp.where(res_sum > 0, res / jnp.maximum(res_sum, 1e-20),
                        p[:, :k])
        corr = jax.vmap(jax.random.categorical)(
            r_keys[:, :k].reshape(b * k, 2),
            jnp.log(res.reshape(b * k, v) + 1e-30)
        ).reshape(b, k).astype(jnp.int32)
    else:
        g_m = jnp.zeros((b,), jnp.int32)
        s_m = jnp.zeros((b,), jnp.int32)
        corr = jnp.zeros((b, 0), jnp.int32)
    bonus = jax.vmap(jax.random.categorical)(
        r_keys[:, k], jnp.log(p[:, k] + 1e-30)).astype(jnp.int32)

    repl = jnp.concatenate([corr, bonus[:, None]], axis=1)      # (B, K+1)
    d_pad = jnp.concatenate(
        [draft_tokens, jnp.zeros((b, 1), jnp.int32)], axis=1)
    idx = jnp.arange(kp1, dtype=jnp.int32)[None, :]
    out_s = jnp.where(idx < s_m[:, None], d_pad, repl)

    sampled = temperature > 0.0
    counts = jnp.where(sampled, s_m, g_m).astype(jnp.int32) + 1
    out = jnp.where(sampled[:, None], out_s, greedy_toks)
    return counts, out.astype(jnp.int32)
