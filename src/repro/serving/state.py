"""Family-agnostic decode-state pools: the ``DecodeState`` protocol.

The serving engine no longer knows what a family's decode state IS — it
talks to a pool through a small verb set:

    acquire / release          slot (+ capacity) bookkeeping
    write_prefill              splice one prefilled request row into a slot
    advance                    host-side cursor bookkeeping after a step
    mask_dead                  per-row liveness for the compiled step
    live_assemble              the cache pytree one compiled call consumes
    update_from                take the written state back from the step
    byte_stats                 telemetry (state bytes per slot, ...)

Three state shapes implement it:

  * KV pools (``pool.SlotPool`` contiguous / ``pool.PagedPool`` blocks) —
    dense/moe/vlm. Dead rows are masked by their per-slot cursors, so
    ``mask_dead`` is a no-op there.
  * ``RecurrentPool`` — ssm/hybrid conv+SSM/mLSTM/sLSTM state. No seq
    axis: admission overwrites a slot's whole column (slot reset), decode
    carries state under a per-row ``live`` mask (dead slots stay
    bit-exact), and ``state_dtype="int8"`` stores the big state leaves
    quantized under OSSH-STATIC per-channel scales — the same spatial-
    stability bet Quaff makes for activations: the hot state channels the
    calibration set (or the first admitted prompt) exposes are the hot
    channels every later token hits. Scales are seeded once and never
    rescaled.
  * ``CrossAttnPool`` — encdec: per-slot self-KV (cursor-masked) plus each
    request's cross-KV rows (projected encoder output), written once at
    admission and static afterwards.

The generic machinery (slot-axis inference + column splice) works for any
pytree a family's ``models.init_slot_caches`` produces: a prefill row is
structurally a ONE-slot pool, so the axis where its shape differs from the
pool's is the slot axis — no per-family write code.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.runtime.treepath import path_str

INT8_MAX = 127.0
STATE_DTYPES = ("fp", "int8")


def check_state_dtype(state_dtype: str) -> str:
    if state_dtype not in STATE_DTYPES:
        raise ValueError(f"state_dtype must be one of {STATE_DTYPES}, "
                         f"got {state_dtype!r}")
    return state_dtype


@runtime_checkable
class DecodeState(Protocol):
    """What ``serving.Engine`` needs from a pool of per-request decode
    state — nothing in the engine loop mentions KV caches, block tables or
    recurrent leaves; it speaks only these verbs."""

    n_slots: int
    max_seq_len: int

    @property
    def n_free(self) -> int: ...

    @property
    def n_active(self) -> int: ...

    def acquire(self, need: int) -> Optional[int]:
        """A free slot (and, where state is capacity-bounded, the footprint
        for ``need`` cache positions) — or None to defer admission."""
        ...

    def release(self, slot: int) -> None: ...

    def advance(self, slot: int, n: int) -> None:
        """Record ``n`` more positions written for ``slot`` (host cursors;
        pools whose cursors live on-device make this a no-op)."""
        ...

    def cursor(self, slot: int) -> int: ...

    def write_prefill(self, row_state: Any, slot: int) -> None: ...

    def mask_dead(self, live: List[bool]) -> Optional[jnp.ndarray]: ...

    def live_assemble(self, live: List[bool]) -> Any: ...

    def update_from(self, new_caches: Any) -> None: ...

    def byte_stats(self) -> Dict[str, Any]: ...


# ---------------------------------------------------------------------------
# Generic slot-pytree machinery
# ---------------------------------------------------------------------------
def slot_axes(cfg: ModelConfig, max_seq_len: int) -> Dict[str, Optional[int]]:
    """Per-leaf slot axis of a family's slot-cache pytree, inferred by
    abstract-evaluating ``init_slot_caches`` at n_slots=1 vs 2 and diffing
    shapes — no per-family layout table to maintain."""
    s1 = jax.eval_shape(lambda: M.init_slot_caches(cfg, 1, max_seq_len))
    s2 = jax.eval_shape(lambda: M.init_slot_caches(cfg, 2, max_seq_len))
    axes: Dict[str, Optional[int]] = {}
    for (p, a), (_, b) in zip(jax.tree_util.tree_flatten_with_path(s1)[0],
                              jax.tree_util.tree_flatten_with_path(s2)[0]):
        diffs = [i for i, (x, y) in enumerate(zip(a.shape, b.shape))
                 if x != y]
        if len(diffs) > 1:
            raise ValueError(f"leaf {path_str(p)} varies in more than one "
                             f"axis with n_slots: {a.shape} vs {b.shape}")
        axes[path_str(p)] = diffs[0] if diffs else None
    return axes


def splice_slot(pool, row, slot, axes: Dict[str, Optional[int]]):
    """Write a batch-1 prefill row into column ``slot`` of the pool,
    leaf-wise along each leaf's slot axis. Slot-invariant leaves (axis
    None) are replaced wholesale."""
    flat_p, treedef = jax.tree_util.tree_flatten_with_path(pool)
    flat_r = jax.tree_util.tree_flatten_with_path(row)[0]
    out = []
    for (path, p), (_, r) in zip(flat_p, flat_r):
        ax = axes[path_str(path)]
        if ax is None or p.shape == r.shape:
            out.append(r.astype(p.dtype))
            continue
        start = [0] * p.ndim
        start[ax] = slot
        out.append(jax.lax.dynamic_update_slice(p, r.astype(p.dtype),
                                                tuple(start)))
    return jax.tree_util.tree_unflatten(treedef, out)


def _flat_by_path(tree) -> Dict[str, Any]:
    return {path_str(p): leaf
            for p, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]}


class SlotStatePool:
    """Whole-pytree slot pool: the shared base of every non-paged
    ``DecodeState``. Device caches come from the family's
    ``models.init_slot_caches``; admission is one compiled generic column
    splice; retirement is host-side bookkeeping (the next admission
    overwrites the slot's entire column — slot reset, no leakage)."""

    def __init__(self, cfg: ModelConfig, n_slots: int, max_seq_len: int):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_seq_len = max_seq_len
        self.caches = M.init_slot_caches(cfg, n_slots, max_seq_len)
        self._axes = slot_axes(cfg, max_seq_len)
        axes = self._axes
        self._splice = jax.jit(
            lambda pool, row, slot: splice_slot(pool, row, slot, axes))
        self._free: List[int] = list(range(n_slots))

    # ---- host bookkeeping ------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_active(self) -> int:
        return self.n_slots - len(self._free)

    def acquire(self, need: int) -> Optional[int]:
        """Slot-only admission (state here is not capacity-bounded beyond
        the pool's sizing, which ``Engine.submit`` validates)."""
        del need
        return self._free.pop(0) if self._free else None

    def release(self, slot: int):
        if slot in self._free:
            raise ValueError(f"slot {slot} is already free")
        self._free.append(slot)
        self._free.sort()

    def advance(self, slot: int, n: int):
        """No-op: write cursors advance on-device inside the step."""

    def cursor(self, slot: int) -> int:
        return 0

    # ---- device ----------------------------------------------------------
    def write_prefill(self, row_state, slot: int):
        self.caches = self._splice(self.caches, row_state,
                                   jnp.asarray(slot, jnp.int32))

    def mask_dead(self, live: List[bool]) -> Optional[jnp.ndarray]:
        """KV cursors already isolate dead rows — no mask needed."""
        return None

    def live_assemble(self, live: List[bool]):
        return self.caches

    def update_from(self, new_caches):
        self.caches = new_caches

    # ---- telemetry -------------------------------------------------------
    def _fp_bytes_per_slot(self) -> int:
        total = 0
        for path, leaf in _flat_by_path(self.caches).items():
            ax = self._axes[path]
            per = leaf.size // (leaf.shape[ax] if ax is not None else 1)
            total += per * jnp.dtype(leaf.dtype).itemsize
        return total

    def byte_stats(self) -> Dict[str, Any]:
        return {"state_bytes_per_slot": self._fp_bytes_per_slot()}


# ---------------------------------------------------------------------------
# Recurrent state (ssm / hybrid), optional int8 storage
# ---------------------------------------------------------------------------
def _is_quantized_path(path: str) -> bool:
    """The big recurrent leaves worth quantizing: Mamba conv rows + SSD
    state, mLSTM matrix memory. Small trackers (gate maxima ``m``,
    normalizers ``n``, sLSTM vectors) and the hybrid's KV part stay fp."""
    name = path.split("/")[-1]
    return (("mamba" in path and name in ("conv", "h"))
            or ("mlstm" in path and name == "C"))


def _quantize_state(caches, scales: Dict[str, jnp.ndarray]):
    flat, treedef = jax.tree_util.tree_flatten_with_path(caches)
    out = []
    for path, leaf in flat:
        p = path_str(path)
        if p in scales:
            q = jnp.round(leaf.astype(jnp.float32) / scales[p])
            out.append(jnp.clip(q, -INT8_MAX, INT8_MAX).astype(jnp.int8))
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def _dequantize_state(caches, scales: Dict[str, jnp.ndarray]):
    flat, treedef = jax.tree_util.tree_flatten_with_path(caches)
    out = []
    for path, leaf in flat:
        p = path_str(path)
        out.append(leaf.astype(jnp.float32) * scales[p] if p in scales
                   else leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


class RecurrentPool(SlotStatePool):
    """Per-slot conv+SSM/mLSTM/sLSTM state for the ssm/hybrid families
    (the hybrid's shared-attention KV rides in the same pytree with its
    per-slot cursors). No seq axis: a slot's state is O(1), admission
    resets it wholesale, and decode advances it under the engine's
    ``live`` mask (``models.ssm._carry``) so dead slots never drift.

    ``state_dtype="int8"`` stores the big leaves (Mamba conv rows + SSD
    state, mLSTM matrix memory) quantized under per-channel scales that
    are STATIC for the pool's lifetime (OSSH): seeded from the Quaff
    calibration capture (``stats[...]["state"]`` absmax recorded by the
    ssm blocks) or, absent calibration, probed from the first admitted
    prompt's prefill state. The compiled step always sees fp state —
    ``live_assemble`` dequantizes, ``update_from`` requantizes — and the
    static grid makes the dead-row round trip exact (q(dq(x)) == x), so
    masked-out slots still hold their state bit-for-bit."""

    def __init__(self, cfg: ModelConfig, n_slots: int, max_seq_len: int, *,
                 state_dtype: str = "fp"):
        check_state_dtype(state_dtype)
        super().__init__(cfg, n_slots, max_seq_len)
        self.state_dtype = state_dtype
        self._qpaths = [p for p in self._axes if _is_quantized_path(p)] \
            if state_dtype == "int8" else []
        self.scales: Optional[Dict[str, jnp.ndarray]] = None
        self.seeded_source: Optional[str] = None
        self._fp_itemsize = {p: jnp.dtype(leaf.dtype).itemsize
                             for p, leaf in _flat_by_path(self.caches).items()}
        if self._qpaths:
            flat, treedef = jax.tree_util.tree_flatten_with_path(self.caches)
            self.caches = jax.tree_util.tree_unflatten(treedef, [
                jnp.zeros(leaf.shape, jnp.int8)
                if path_str(p) in self._qpaths else leaf
                for p, leaf in flat])
            self._quant = jax.jit(_quantize_state)
            self._dequant = jax.jit(_dequantize_state)

    # ---- OSSH-static scale seeding ---------------------------------------
    @property
    def needs_seed(self) -> bool:
        return bool(self._qpaths) and self.scales is None

    def seed_from_stats(self, stats) -> bool:
        """Seed the static grid from the Quaff calibration capture
        (``QuaffModel.stats``): the ssm blocks record per-channel state
        absmax next to the per-linear input absmax. Returns False when the
        capture predates the state entry (or no calibration ran)."""
        if not self.needs_seed or stats is None:
            return False
        tree = stats[0] if isinstance(stats, tuple) else stats
        flat = _flat_by_path(self.caches)
        scales: Dict[str, jnp.ndarray] = {}
        for p in self._qpaths:
            leaf, ax = flat[p], self._axes[p]
            top, name = p.split("/")[0], p.split("/")[-1]
            try:
                a = np.asarray(tree[top]["state"][name], np.float32)
            except (KeyError, TypeError, IndexError):
                return False
            if a.shape != leaf.shape[:ax] + (leaf.shape[-1],):
                return False
            a = a.reshape(leaf.shape[:ax]
                          + (1,) * (leaf.ndim - ax - 1) + (leaf.shape[-1],))
            scales[p] = jnp.asarray(np.maximum(a, 1e-8) / INT8_MAX)
        self.scales = scales
        self.seeded_source = "calibration"
        return True

    def seed_from_row(self, row_state):
        """Probe fallback: per-channel absmax of the first admitted
        prompt's fp prefill state. OSSH makes one prompt a usable seed —
        the hot state channels it exposes are the hot channels every later
        token hits."""
        flat = _flat_by_path(row_state)
        scales: Dict[str, jnp.ndarray] = {}
        for p in self._qpaths:
            leaf, ax = flat[p], self._axes[p]
            red = tuple(range(ax, leaf.ndim - 1))
            a = jnp.max(jnp.abs(leaf.astype(jnp.float32)), axis=red,
                        keepdims=True)
            scales[p] = jnp.maximum(a, 1e-8) / INT8_MAX
        self.scales = scales
        self.seeded_source = "probe"

    # ---- device ----------------------------------------------------------
    def write_prefill(self, row_state, slot: int):
        if self._qpaths:
            if self.needs_seed:          # engine seeds from calib first
                self.seed_from_row(row_state)
            row_state = self._quant(row_state, self.scales)
        super().write_prefill(row_state, slot)

    def mask_dead(self, live: List[bool]) -> Optional[jnp.ndarray]:
        return jnp.asarray(np.asarray(live, bool))

    def live_assemble(self, live: List[bool]):
        if self._qpaths:
            return self._dequant(self.caches, self.scales)
        return self.caches

    def update_from(self, new_caches):
        self.caches = (self._quant(new_caches, self.scales)
                       if self._qpaths else new_caches)

    # ---- telemetry -------------------------------------------------------
    def byte_stats(self) -> Dict[str, Any]:
        fp_total, total = 0, 0
        for path, leaf in _flat_by_path(self.caches).items():
            ax = self._axes[path]
            per = leaf.size // (leaf.shape[ax] if ax is not None else 1)
            fp_total += per * self._fp_itemsize[path]
            total += per * jnp.dtype(leaf.dtype).itemsize
        if self.scales is not None:      # static grids amortize over slots
            total += sum(s.size * 4 for s in self.scales.values()) \
                // self.n_slots
        return {"state_bytes_per_slot": total,
                "fp_state_bytes_per_slot": fp_total,
                "state_dtype": self.state_dtype}


class CrossAttnPool(SlotStatePool):
    """Enc-dec (whisper) decode state: per-slot self-attention KV rows with
    per-slot write cursors PLUS each request's cross-attention K/V (the
    projected encoder output), spliced once at admission and static for
    the request's lifetime. Requests without encoder frames keep zero
    cross rows — identical to the lockstep no-frames decode."""

    def byte_stats(self) -> Dict[str, Any]:
        kh, hd, nl = (self.cfg.n_kv_heads, self.cfg.head_dim,
                      self.cfg.n_layers)
        itemsize = jnp.dtype(self.cfg.act_dtype).itemsize
        cross = nl * 2 * self.cfg.encoder_seq * kh * hd * itemsize
        return {"state_bytes_per_slot": self._fp_bytes_per_slot(),
                "cross_kv_bytes_per_slot": cross}
