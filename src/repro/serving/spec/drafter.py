"""Self-speculative drafting: the SAME weights under cheaper activations.

Classic speculative decoding needs a second, smaller draft model. Quaff's
registry makes the draft free: every ``QuantBackend`` is an execution
mode over one frozen weight tree, so the draft pass is simply the target
model run under a lower-precision-activation backend — ``int4`` drafts
for an ``int4_w4a8`` target read the identical packed nibbles with 4-bit
instead of 8-bit activations, and ``quaff@4`` drafts for a ``quaff``
target coarsen only the runtime activation quantization (``QuantConfig.
bits`` is apply-time; the stored ``w_int`` never changes). No second
checkpoint, no extra weight memory, no KV duplication: the drafter runs
against the live pools and its cache writes are thrown away (verification
re-reads the pre-draft state).

Backend pairing is validated through ``QuantBackend.weight_carrier``:
draft and target must consume the same frozen-weights format, otherwise
the draft forward would misread the tree.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.backend import get_backend
from repro.models.config import ModelConfig
from repro.serving.spec import schedule

#: fold_in offset separating the drafter's PRNG stream from the request's
#: sequential sampling stream (token indices never reach 2**30; reusing
#: the sequential keys for proposals would correlate draft and verify
#: draws and bias rejection sampling)
DRAFT_FOLD = 1 << 30


def parse_spec_backend(spec: str) -> Tuple[str, Optional[int]]:
    """Split a ``spec_backend`` string ``"mode"`` / ``"mode@bits"``
    (e.g. ``"int4"``, ``"quaff@4"``) into (mode, bits-or-None)."""
    mode, _, bits = spec.partition("@")
    if not mode:
        raise ValueError(f"empty mode in spec_backend {spec!r}")
    if not bits:
        return mode, None
    try:
        b = int(bits)
    except ValueError:
        raise ValueError(
            f"spec_backend {spec!r}: bits suffix must be an integer"
        ) from None
    if b < 1:
        raise ValueError(f"spec_backend {spec!r}: bits must be >= 1")
    return mode, b


def draft_model_config(cfg: ModelConfig, spec_backend: str) -> ModelConfig:
    """The draft-pass ``ModelConfig``: ``cfg`` with its quant mode (and
    optionally apply-time activation bits) swapped for the draft backend.

    Raises when the draft backend's ``weight_carrier`` differs from the
    target's — the two passes share one frozen tree, so they must agree
    on its format. Per-layer quant STATE (Quaff momentum scales) rides
    along unchanged for the same reason: same carrier, same state shape.
    """
    mode, bits = parse_spec_backend(spec_backend)
    target = get_backend(cfg.quant.mode)
    draft = get_backend(mode)          # raises on an unregistered mode
    t_carrier = target.weight_carrier or target.name
    d_carrier = draft.weight_carrier or draft.name
    if t_carrier != d_carrier:
        raise ValueError(
            f"spec_backend {spec_backend!r} (weight carrier {d_carrier!r}) "
            f"cannot draft for target mode {cfg.quant.mode!r} (carrier "
            f"{t_carrier!r}): draft and target read the same frozen "
            "weights, so their backends must share a weight_carrier")
    quant = dataclasses.replace(
        cfg.quant, mode=mode,
        **({"bits": bits} if bits is not None else {}))
    return dataclasses.replace(cfg, quant=quant)


class Drafter:
    """K-token draft proposer for one engine.

    Holds the draft ``ModelConfig`` and the jitted draft scan; stateless
    with respect to the pools — the engine hands it the assembled caches
    and discards everything but the proposals and their logits."""

    def __init__(self, cfg: ModelConfig, spec_backend: str, k: int):
        if k < 1:
            raise ValueError(f"spec_k must be >= 1, got {k}")
        self.target_cfg = cfg
        self.cfg = draft_model_config(cfg, spec_backend)
        self.spec_backend = spec_backend
        self.k = k
        self._fn = schedule.jit_draft_scan(self.cfg, k)

    def propose(self, frozen, adapters, quant_state, caches, tokens,
                positions, keys, temps, top_ks, top_ps):
        """(d_toks (K, B) int32, d_logits (K, B, V) f32). ``keys`` must be
        the DRAFT_FOLD-offset stream, not the request's sequential keys."""
        return self._fn(frozen, adapters, quant_state, caches, tokens,
                        positions, keys, temps, top_ks, top_ps)
