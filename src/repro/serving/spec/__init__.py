"""Scheduled multi-step decode + Quaff self-speculative decoding.

Two ways to spend fewer host dispatches per generated token, both knobs
on ``serving.EngineConfig``:

  * ``decode_steps=N`` — run N decode iterations inside one compiled
    scan with in-graph EOS/budget masking (``schedule``);
  * ``spec_decode=True, spec_backend="mode[@bits]", spec_k=K`` — draft K
    tokens under a cheap-activation backend over the same frozen weights
    (``drafter``), then score all K in one batched target pass
    (``verify``); greedy output is token-identical to non-speculative
    decode by construction.
"""
from repro.serving.spec.drafter import (DRAFT_FOLD, Drafter,
                                        draft_model_config,
                                        parse_spec_backend)
from repro.serving.spec.schedule import (build_draft_scan,
                                         build_multistep_decode,
                                         jit_draft_scan,
                                         jit_multistep_decode)
from repro.serving.spec.verify import build_spec_verify, jit_spec_verify

__all__ = [
    "DRAFT_FOLD",
    "Drafter",
    "build_draft_scan",
    "build_multistep_decode",
    "build_spec_verify",
    "draft_model_config",
    "jit_draft_scan",
    "jit_multistep_decode",
    "jit_spec_verify",
    "parse_spec_backend",
]
