"""Multi-step scheduled decode: N decode iterations inside ONE compiled call.

The classic engine loop pays one host round-trip per generated token:
assemble the batch, dispatch the jitted step, pull logits back, sample,
re-dispatch. ``build_multistep_decode`` folds ``num_steps`` of that loop
into a single ``lax.scan`` — the sampled token feeds straight back into the
next forward on-device, and EOS / token-budget death is handled IN-GRAPH by
masking: a dead row keeps riding the scan as a no-op (it re-feeds its last
token and its writes land past its committed cursor, exactly where the
one-step engine's free slots already scribble), so the batch never
re-shapes mid-window and host scheduling cost is amortized N-fold.

The in-graph death condition is byte-for-byte the engine's retirement rule
(``Engine._emit_token``): a row dies after emitting its EOS token or its
``budget``-th token of the window. The host replays the emit mask after
the window, so streaming callbacks, retirement bookkeeping and paged
cursor advances all see exactly the tokens the graph committed.

``build_draft_scan`` is the same scan specialized for speculative
drafting (``serving.spec.drafter``): no death masking — proposals are
provisional by definition — and it returns the per-step logits so
verification can rejection-sample against the draft distribution. The
draft caches are DISCARDED by the caller: verification re-reads the
pre-draft pools, so draft writes never pollute committed KV state.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.serving import sampling


def build_multistep_decode(cfg: ModelConfig, num_steps: int):
    """multistep(frozen, adapters, quant_state, caches, tokens, positions,
    keys, temps, top_ks, top_ps, eos_ids, budgets, alive, live=None)
    -> (toks (N, B) int32, emits (N, B) bool, final caches).

    ``tokens`` (B, 1) fed-back last tokens; ``positions`` (B,) the fed-back
    token's RoPE position (step s uses ``positions + s``); ``keys``
    (N, B, 2) per-(step, row) sampling keys — precomputed host-side from
    ``sampling.request_key`` so seeded streams are bit-identical to the
    one-step loop; ``eos_ids`` (B,) int32 with -1 for "no EOS"; ``budgets``
    (B,) int32 tokens each row may still emit; ``alive`` (B,) bool rows
    decoding at window start. ``emits[s, i]`` marks a token the host must
    emit; dead and free rows produce emits=False no-op steps.
    """
    if num_steps < 1:
        raise ValueError(f"num_steps must be >= 1, got {num_steps}")

    def multistep(frozen, adapters, quant_state, caches, tokens, positions,
                  keys, temps, top_ks, top_ps, eos_ids, budgets, alive,
                  live=None):
        def step(carry, xs):
            caches, tok, alive_c, emitted = carry
            s, keys_s = xs
            out = M.forward(frozen, adapters, quant_state, tok, cfg,
                            caches=caches, positions=(positions + s)[:, None],
                            live=live)
            nxt = sampling.sample_tokens(
                out.logits[:, -1, :], temps, top_ks, top_ps, keys_s)
            emit = alive_c
            emitted = emitted + emit.astype(jnp.int32)
            alive_n = alive_c & (nxt != eos_ids) & (emitted < budgets)
            tok = jnp.where(emit, nxt, tok[:, 0])[:, None]
            return (out.caches, tok, alive_n, emitted), (nxt, emit)

        carry0 = (caches, tokens, alive, jnp.zeros_like(positions))
        xs = (jnp.arange(num_steps, dtype=jnp.int32), keys)
        (caches, _, _, _), (toks, emits) = jax.lax.scan(step, carry0, xs)
        return toks, emits, caches

    return multistep


def build_draft_scan(cfg: ModelConfig, num_steps: int):
    """draft(frozen, adapters, quant_state, caches, tokens, positions,
    keys, temps, top_ks, top_ps) -> (toks (K, B) int32, logits (K, B, V)).

    ``cfg`` is the DRAFT model config (cheap-activation backend over the
    target's frozen weights — ``serving.spec.drafter``). No death masking:
    every proposal is provisional until verification. The final draft
    caches are intentionally not returned — the caller verifies against
    the pre-draft pools and commits only accepted positions.
    """
    if num_steps < 1:
        raise ValueError(f"num_steps must be >= 1, got {num_steps}")

    def draft(frozen, adapters, quant_state, caches, tokens, positions,
              keys, temps, top_ks, top_ps):
        def step(carry, xs):
            caches, tok = carry
            s, keys_s = xs
            out = M.forward(frozen, adapters, quant_state, tok, cfg,
                            caches=caches, positions=(positions + s)[:, None])
            lg = out.logits[:, -1, :].astype(jnp.float32)
            nxt = sampling.sample_tokens(lg, temps, top_ks, top_ps, keys_s)
            return (out.caches, nxt[:, None]), (nxt, lg)

        xs = (jnp.arange(num_steps, dtype=jnp.int32), keys)
        _, (toks, logits) = jax.lax.scan(step, (caches, tokens), xs)
        return toks, logits

    return draft


@functools.lru_cache(maxsize=64)
def jit_multistep_decode(cfg: ModelConfig, num_steps: int):
    return jax.jit(build_multistep_decode(cfg, num_steps))


@functools.lru_cache(maxsize=64)
def jit_draft_scan(cfg: ModelConfig, num_steps: int):
    return jax.jit(build_draft_scan(cfg, num_steps))
