"""Speculative verification: one batched target pass scores K drafts.

The verify chunk for a row is ``[t0, d_1 .. d_K]`` — the last COMMITTED
token followed by the K draft proposals — fed at absolute positions
``p .. p+K`` against the PRE-draft caches. The attention paths already
handle multi-token rows (chunked prefill uses the same math): each
position's KV is written at its cursor slot and the causal within-chunk
mask gives position j logits conditioned on everything up to and
including d_j. One dispatch therefore yields every conditional
p(. | prefix, t0, d_1..d_j) for j = 0..K at once, and
``sampling.speculative_verify`` turns those into per-row commit counts —
greedy rows commit the target-argmax prefix (token-identical to
sequential decode by construction), sampled rows run standard rejection
sampling over the same filtered distributions.

Rollback is free by cursor arithmetic: rejected positions' KV stays in
the row's private blocks (COW already fenced shared prefixes) but the
committed cursor stops at ``counts``, so attention's length mask hides
them and the next cycle's chunk overwrites them. On the paged pool the
host truncates the block table (``advance(i, counts)``); on the
contiguous pool ``_shift_cursors`` rewrites the in-cache per-slot
cursors in-graph before they leave the jitted call.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.serving import sampling


def _shift_cursors(new_caches, chunk_len: int, counts, commit):
    """Rewrite every per-slot write cursor from ``pre + chunk_len`` (the
    forward advanced all rows by the full chunk) to ``pre + counts`` for
    committing rows and ``pre`` for riders. Cursor leaves are the dict
    entries named "pos" ((L, B) contiguous slot caches, (L, B) paged —
    the paged copy is advisory: ``PagedPool.update_from`` only takes the
    pool leaves back and the host block table is the real cursor)."""
    shift = jnp.where(commit, counts, 0) - chunk_len            # (B,)

    def fix(tree):
        if isinstance(tree, dict):
            return {k: (v + shift if k == "pos" else fix(v))
                    for k, v in tree.items()}
        return tree

    return fix(new_caches)


def build_spec_verify(cfg: ModelConfig, k: int):
    """verify(frozen, adapters, quant_state, caches, chunk, positions,
    draft_tokens, draft_logits, temps, top_ks, top_ps, keys, commit)
    -> (counts (B,) int32, out_tokens (B, K+1) int32, new caches).

    ``chunk`` (B, K+1) = [t0, d_1..d_K]; ``positions`` (B, K+1) absolute;
    ``keys`` (B, K+1, 2) the row's sequential sampling keys for token
    indices n_generated .. n_generated+K; ``commit`` (B,) bool marks rows
    actually speculating (riders keep cursor and commit nothing —
    ``counts`` is forced to 0 for them).
    """
    if k < 1:
        raise ValueError(f"spec_k must be >= 1, got {k}")

    def verify(frozen, adapters, quant_state, caches, chunk, positions,
               draft_tokens, draft_logits, temps, top_ks, top_ps, keys,
               commit):
        # exact_kv_reads: the chunk must score each draft against the SAME
        # (quantized, on int8 pools) KV bytes sequential decode would have
        # read — greedy token-identity is only "by construction" when the
        # two paths see identical inputs.
        out = M.forward(frozen, adapters, quant_state, chunk, cfg,
                        caches=caches, positions=positions,
                        exact_kv_reads=True)
        counts, out_toks = sampling.speculative_verify(
            out.logits.astype(jnp.float32), draft_tokens, draft_logits,
            temps, top_ks, top_ps, keys)
        counts = jnp.where(commit, counts, 0)
        new_caches = _shift_cursors(out.caches, k + 1, counts, commit)
        return counts, out_toks, new_caches

    return verify


@functools.lru_cache(maxsize=64)
def jit_spec_verify(cfg: ModelConfig, k: int):
    return jax.jit(build_spec_verify(cfg, k))
