"""Public request/response surface of the serving engine.

Everything here is plain host-side data: requests go in, per-token streams
and ``RequestOutput``s come out, and ``EngineStats`` summarizes a run. The
device-side machinery (slot pool, compiled steps, samplers) lives in
``pool.py`` / ``engine.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding policy.

    ``temperature <= 0`` is greedy (argmax); otherwise tokens are drawn from
    the temperature-scaled distribution after top-k / top-p truncation.
    Sampling is SEEDED per request: token ``i`` of a request uses
    ``fold_in(PRNGKey(seed), i)``, so a request's stream is reproducible
    regardless of which slot it lands in or what else shares the batch."""

    temperature: float = 0.0
    top_k: int = 0          # 0 = no top-k truncation
    top_p: float = 1.0      # 1.0 = no nucleus truncation
    seed: int = 0

    def __post_init__(self):
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")


@dataclasses.dataclass
class GenerationRequest:
    """One generation job. ``prompt`` is a token-id sequence (list/array).

    ``on_token(request_id, token_id)`` — optional streaming callback, called
    from the engine loop the moment each token is sampled (before the
    request completes).

    ``input_embeds`` — per-request precomputed embeddings for the families
    that take them: (encoder_seq, d_model) encoder frames (encdec) or
    (n_image_tokens, d_model) patch embeddings (vlm). None = text-only
    (encdec then decodes against zero cross-KV, exactly like the lockstep
    no-frames path)."""

    prompt: Sequence[int]
    max_new_tokens: int = 32
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    eos_id: Optional[int] = None
    request_id: Optional[str] = None      # assigned by the engine if None
    on_token: Optional[Callable[[str, int], None]] = None
    input_embeds: Optional[object] = None


@dataclasses.dataclass
class RequestOutput:
    """Completed request: generated ids + why generation stopped.

    The latency fields are measured on the obs clock (``repro.obs.clock``)
    from the caller's ``submit`` call: ``queue_s`` until first admission,
    ``ttft_s`` until the first sampled token, ``e2e_s`` until retirement.
    They are always populated — no observability config needed — so
    callers get per-request latency without scraping aggregate stats. A
    preempted-and-resumed request keeps its original submit mark (its
    queue/ttft reflect the first admission; the preemption shows up in
    ``e2e_s``)."""

    request_id: str
    prompt_len: int
    token_ids: List[int]
    finish_reason: str          # "eos" | "length"
    queue_s: float = 0.0        # submit -> admitted into a slot
    ttft_s: float = 0.0         # submit -> first token sampled
    e2e_s: float = 0.0          # submit -> retired

    @property
    def n_generated(self) -> int:
        return len(self.token_ids)


@dataclasses.dataclass
class EngineStats:
    """Aggregate counters for one engine lifetime.

    ``slot_steps`` (decode steps x pool width) is the cost a LOCKSTEP decoder
    of the same width would also pay — continuous batching wins by finishing
    the same workload in fewer of them. ``occupancy`` is the fraction of
    those slot-steps that decoded a live request.

    Block-pool telemetry (``kv_layout="paged"`` engines): ``prefills``
    counts requests fully admitted, ``prefill_batches`` compiled prefill
    calls (batched same-length admission makes batches < prefills), and
    ``prefill_chunks`` per-request chunk advances; ``fragmentation`` is the
    allocated-but-unwritten fraction of in-use blocks; the ``kv_bytes_*``
    fields compare against what the contiguous layout (one fp max_seq_len
    row per request) would pin.

    The ``*_time_s`` fields are backed by the obs layer: every value
    accumulated here is the return of an ``Obs.phase_begin``/``phase_end``
    pair on ``repro.obs.clock``, which simultaneously emits the trace
    span and feeds the metrics histograms (``prefill_s`` /
    ``decode_dispatch_s``) when those layers are enabled — one clock
    read, three consumers. No code in the engine reads
    ``time.perf_counter`` directly (rule RPR011)."""

    n_slots: int = 0
    family: str = ""
    requests_submitted: int = 0
    requests_completed: int = 0
    tokens_generated: int = 0
    prefills: int = 0
    decode_steps: int = 0
    busy_slot_steps: int = 0
    prefill_time_s: float = 0.0
    decode_time_s: float = 0.0

    # decode-state telemetry (DecodeState.byte_stats; every pool kind)
    state_dtype: str = "fp"                 # recurrent pools: fp | int8
    state_bytes_per_slot: int = 0
    fp_state_bytes_per_slot: int = 0        # int8 pools: the fp equivalent

    # KV layout + block-pool telemetry (paged engines)
    kv_layout: str = "contiguous"
    kv_dtype: str = "fp"
    block_size: int = 0
    n_blocks: int = 0
    blocks_in_use: int = 0
    peak_blocks_in_use: int = 0
    fragmentation: float = 0.0              # current gauge (0 when drained)
    fragmentation_sum: float = 0.0          # sampled before each decode step
    fragmentation_samples: int = 0
    kv_bytes_in_use: int = 0
    kv_bytes_per_request_sum: int = 0       # allocated bytes, completed reqs
    contiguous_bytes_per_request: int = 0   # fp max_seq_len row equivalent
    prefill_batches: int = 0
    prefill_chunks: int = 0
    admission_deferrals: int = 0

    # lazy block allocation (paged engines with ``lazy_blocks=True``):
    # tables grow at decode time instead of reserving max_new up front
    lazy_blocks: bool = False
    block_grows: int = 0                    # blocks added mid-decode
    block_stalls: int = 0                   # slot-steps skipped, pool full
    preemptions: int = 0                    # victims requeued to unwedge
    blocks_reserved_eager_sum: int = 0      # what eager would have pinned
    blocks_used_sum: int = 0                # blocks actually held at retire

    # multi-step scheduled decode + speculative decoding (serving.spec):
    # ``decode_steps`` keeps its logical meaning (one count per generated-
    # token opportunity) — ``decode_dispatches`` counts compiled decode
    # calls, so steps_per_dispatch measures the host-scheduling
    # amortization (N for decode_steps=N windows, the mean committed run
    # for speculation)
    scheduled_steps: int = 1                # configured decode_steps
    spec_decode: bool = False
    spec_backend: str = ""
    spec_k: int = 0
    decode_dispatches: int = 0              # compiled decode calls issued
    draft_tokens: int = 0                   # proposals the drafter made
    accepted_tokens: int = 0                # proposals verification kept

    # dispatch-geometry padding: the LEGACY two-dispatch paths pay one
    # full-width token row per slot on every decode call (dead slots ride
    # as pads) and split prefill into same-length groups (pad-free, but
    # one compiled call per distinct length). ``unified_step=True``
    # replaces both with ONE ragged dispatch per iteration whose stream
    # packs only live tokens — ``pad_tokens_saved`` counts the decode pad
    # rows that packing removed, ``mixed_batches`` the dispatches that
    # carried prefill AND decode rows together.
    prefill_pad_tokens: int = 0             # legacy prefill geometry - real
    decode_pad_tokens: int = 0              # legacy decode geometry - real
    unified_step: bool = False
    unified_dispatches: int = 0             # ragged mixed-batch calls issued
    mixed_batches: int = 0                  # dispatches with both row kinds
    pad_tokens_saved: int = 0               # decode pads packing removed
    unified_time_s: float = 0.0

    # radix/COW prefix sharing (paged engines with ``prefix_share=True``)
    prefix_share: bool = False
    prefix_queries: int = 0                 # admissions that probed the index
    prefix_hits: int = 0                    # admissions that mapped blocks
    shared_blocks: int = 0                  # gauge: blocks mapped > once now
    prefix_tokens_saved: int = 0            # cache positions not re-prefilled
    prefill_chunks_saved: int = 0           # chunk calls sharing avoided
    cow_copies: int = 0                     # private copies of shared blocks
    radix_blocks: int = 0                   # gauge: blocks the index pins
    radix_evictions: int = 0                # leaves dropped under pressure

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of prefix-index probes that mapped at least one shared
        block (repeated-prefix workloads should sit near 1 after warmup)."""
        return self.prefix_hits / max(self.prefix_queries, 1)

    @property
    def lazy_blocks_saved_per_request(self) -> float:
        """Mean reserved-vs-used block delta per completed request: blocks
        the eager policy would have pinned up front minus blocks the lazy
        table actually grew to."""
        return ((self.blocks_reserved_eager_sum - self.blocks_used_sum)
                / max(self.requests_completed, 1))

    @property
    def mean_fragmentation(self) -> float:
        """Mean allocated-but-unwritten fraction over decode steps (the
        ``fragmentation`` gauge reads 0 once a run drains — this is the
        number to report for a completed workload)."""
        return self.fragmentation_sum / max(self.fragmentation_samples, 1)

    @property
    def kv_bytes_per_request(self) -> float:
        """Mean KV bytes one completed request pinned (paged: its block
        footprint; meaningful after at least one retirement)."""
        return self.kv_bytes_per_request_sum / max(self.requests_completed, 1)

    @property
    def kv_bytes_saved_vs_contiguous(self) -> float:
        """Per-request bytes the paged layout saved vs a contiguous fp row."""
        return self.contiguous_bytes_per_request - self.kv_bytes_per_request

    @property
    def steps_per_dispatch(self) -> float:
        """Logical decode steps amortized per compiled decode call: N for
        a drained ``decode_steps=N`` engine, mean committed tokens per
        cycle under speculation, 1.0 for the classic loop."""
        return self.decode_steps / max(self.decode_dispatches, 1)

    @property
    def acceptance_rate(self) -> float:
        """Fraction of draft proposals verification committed (correction
        and bonus tokens excluded — this measures the DRAFTER)."""
        return self.accepted_tokens / max(self.draft_tokens, 1)

    @property
    def slot_steps(self) -> int:
        return self.decode_steps * self.n_slots

    @property
    def occupancy(self) -> float:
        return self.busy_slot_steps / max(self.slot_steps, 1)

    @property
    def decode_tokens_per_s(self) -> float:
        # each admission samples one token inside the prefill-timed block;
        # only the rest were produced by decode steps
        decode_tokens = self.tokens_generated - self.prefills
        return decode_tokens / max(self.decode_time_s, 1e-9)

    @property
    def tokens_per_s(self) -> float:
        total = (self.prefill_time_s + self.decode_time_s
                 + self.unified_time_s)
        return self.tokens_generated / max(total, 1e-9)

    def as_dict(self) -> dict:
        out = {
            "n_slots": self.n_slots,
            "family": self.family,
            "state_dtype": self.state_dtype,
            "state_bytes_per_slot": self.state_bytes_per_slot,
            "fp_state_bytes_per_slot": self.fp_state_bytes_per_slot,
            "requests_submitted": self.requests_submitted,
            "requests_completed": self.requests_completed,
            "tokens_generated": self.tokens_generated,
            "prefills": self.prefills,
            "prefill_batches": self.prefill_batches,
            "decode_steps": self.decode_steps,
            "slot_steps": self.slot_steps,
            "busy_slot_steps": self.busy_slot_steps,
            "occupancy": round(self.occupancy, 4),
            "prefill_time_s": self.prefill_time_s,
            "decode_time_s": self.decode_time_s,
            "decode_tokens_per_s": self.decode_tokens_per_s,
            "tokens_per_s": self.tokens_per_s,
            "kv_layout": self.kv_layout,
            "kv_dtype": self.kv_dtype,
            "prefill_pad_tokens": self.prefill_pad_tokens,
            "decode_pad_tokens": self.decode_pad_tokens,
        }
        if self.unified_step:
            out.update({
                "unified_step": self.unified_step,
                "unified_dispatches": self.unified_dispatches,
                "mixed_batches": self.mixed_batches,
                "pad_tokens_saved": self.pad_tokens_saved,
                "unified_time_s": self.unified_time_s,
            })
        # telemetry sections key off which pool FEATURES are active (a
        # block pool exists, the prefix index exists), not off layout
        # strings — a spelling drift in ``kv_layout`` can't silently drop
        # a whole section
        if self.n_blocks:
            out.update({
                "block_size": self.block_size,
                "n_blocks": self.n_blocks,
                "blocks_in_use": self.blocks_in_use,
                "peak_blocks_in_use": self.peak_blocks_in_use,
                "fragmentation": round(self.fragmentation, 4),
                "mean_fragmentation": round(self.mean_fragmentation, 4),
                "kv_bytes_in_use": self.kv_bytes_in_use,
                "kv_bytes_per_request": self.kv_bytes_per_request,
                "contiguous_bytes_per_request":
                    self.contiguous_bytes_per_request,
                "kv_bytes_saved_vs_contiguous":
                    self.kv_bytes_saved_vs_contiguous,
                "prefill_chunks": self.prefill_chunks,
                "admission_deferrals": self.admission_deferrals,
                "lazy_blocks": self.lazy_blocks,
                "block_grows": self.block_grows,
                "block_stalls": self.block_stalls,
                "preemptions": self.preemptions,
                "lazy_blocks_saved_per_request":
                    round(self.lazy_blocks_saved_per_request, 2),
            })
        if self.spec_decode or self.scheduled_steps > 1:
            out.update({
                "scheduled_steps": self.scheduled_steps,
                "decode_dispatches": self.decode_dispatches,
                "steps_per_dispatch": round(self.steps_per_dispatch, 4),
            })
        if self.spec_decode:
            out.update({
                "spec_decode": self.spec_decode,
                "spec_backend": self.spec_backend,
                "spec_k": self.spec_k,
                "draft_tokens": self.draft_tokens,
                "accepted_tokens": self.accepted_tokens,
                "acceptance_rate": round(self.acceptance_rate, 4),
            })
        if self.prefix_share:
            out.update({
                "prefix_share": self.prefix_share,
                "prefix_queries": self.prefix_queries,
                "prefix_hits": self.prefix_hits,
                "prefix_hit_rate": round(self.prefix_hit_rate, 4),
                "shared_blocks": self.shared_blocks,
                "prefix_tokens_saved": self.prefix_tokens_saved,
                "prefill_chunks_saved": self.prefill_chunks_saved,
                "cow_copies": self.cow_copies,
                "radix_blocks": self.radix_blocks,
                "radix_evictions": self.radix_evictions,
            })
        return out
