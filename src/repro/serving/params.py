"""Public request/response surface of the serving engine.

Everything here is plain host-side data: requests go in, per-token streams
and ``RequestOutput``s come out, and ``EngineStats`` summarizes a run. The
device-side machinery (slot pool, compiled steps, samplers) lives in
``pool.py`` / ``engine.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding policy.

    ``temperature <= 0`` is greedy (argmax); otherwise tokens are drawn from
    the temperature-scaled distribution after top-k / top-p truncation.
    Sampling is SEEDED per request: token ``i`` of a request uses
    ``fold_in(PRNGKey(seed), i)``, so a request's stream is reproducible
    regardless of which slot it lands in or what else shares the batch."""

    temperature: float = 0.0
    top_k: int = 0          # 0 = no top-k truncation
    top_p: float = 1.0      # 1.0 = no nucleus truncation
    seed: int = 0

    def __post_init__(self):
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")


@dataclasses.dataclass
class GenerationRequest:
    """One generation job. ``prompt`` is a token-id sequence (list/array).

    ``on_token(request_id, token_id)`` — optional streaming callback, called
    from the engine loop the moment each token is sampled (before the
    request completes)."""

    prompt: Sequence[int]
    max_new_tokens: int = 32
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    eos_id: Optional[int] = None
    request_id: Optional[str] = None      # assigned by the engine if None
    on_token: Optional[Callable[[str, int], None]] = None


@dataclasses.dataclass
class RequestOutput:
    """Completed request: generated ids + why generation stopped."""

    request_id: str
    prompt_len: int
    token_ids: List[int]
    finish_reason: str          # "eos" | "length"

    @property
    def n_generated(self) -> int:
        return len(self.token_ids)


@dataclasses.dataclass
class EngineStats:
    """Aggregate counters for one engine lifetime.

    ``slot_steps`` (decode steps x pool width) is the cost a LOCKSTEP decoder
    of the same width would also pay — continuous batching wins by finishing
    the same workload in fewer of them. ``occupancy`` is the fraction of
    those slot-steps that decoded a live request."""

    n_slots: int = 0
    requests_submitted: int = 0
    requests_completed: int = 0
    tokens_generated: int = 0
    prefills: int = 0
    decode_steps: int = 0
    busy_slot_steps: int = 0
    prefill_time_s: float = 0.0
    decode_time_s: float = 0.0

    @property
    def slot_steps(self) -> int:
        return self.decode_steps * self.n_slots

    @property
    def occupancy(self) -> float:
        return self.busy_slot_steps / max(self.slot_steps, 1)

    @property
    def decode_tokens_per_s(self) -> float:
        # each admission samples one token inside the prefill-timed block;
        # only the rest were produced by decode steps
        decode_tokens = self.tokens_generated - self.prefills
        return decode_tokens / max(self.decode_time_s, 1e-9)

    @property
    def tokens_per_s(self) -> float:
        total = self.prefill_time_s + self.decode_time_s
        return self.tokens_generated / max(total, 1e-9)

    def as_dict(self) -> dict:
        return {
            "n_slots": self.n_slots,
            "requests_submitted": self.requests_submitted,
            "requests_completed": self.requests_completed,
            "tokens_generated": self.tokens_generated,
            "prefills": self.prefills,
            "decode_steps": self.decode_steps,
            "slot_steps": self.slot_steps,
            "busy_slot_steps": self.busy_slot_steps,
            "occupancy": round(self.occupancy, 4),
            "prefill_time_s": self.prefill_time_s,
            "decode_time_s": self.decode_time_s,
            "decode_tokens_per_s": self.decode_tokens_per_s,
            "tokens_per_s": self.tokens_per_s,
        }
