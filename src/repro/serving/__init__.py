"""repro.serving — continuous-batching inference over the facade model.

Public surface:

    Engine             slot-pooled continuous-batching engine
    GenerationRequest  prompt + budget + SamplingParams (+ streaming cb)
    SamplingParams     greedy / temperature / top-k / top-p, seeded
    RequestOutput      generated ids + finish reason
    EngineStats        tokens/s, per-phase latency, slot occupancy
"""
from repro.models.config import ServingConfig
from repro.serving.engine import Engine
from repro.serving.params import (EngineStats, GenerationRequest,
                                  RequestOutput, SamplingParams)

__all__ = ["Engine", "GenerationRequest", "SamplingParams", "RequestOutput",
           "EngineStats", "ServingConfig"]
