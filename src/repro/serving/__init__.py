"""repro.serving — continuous-batching inference over the facade model,
for EVERY family in the zoo (dense/moe/vlm/ssm/hybrid/encdec).

Public surface:

    Engine             slot-pooled continuous-batching engine
    EngineConfig       THE engine knob surface (frozen dataclass):
                       max_slots / max_seq_len, kv_layout="contiguous"|
                       "paged", kv_dtype="fp"|"int8", block_size / n_blocks /
                       prefill_chunk / lazy_blocks, prefix_share /
                       radix_capacity, state_dtype="fp"|"int8",
                       decode_steps=N (N decode iterations per compiled
                       dispatch), spec_decode / spec_backend / spec_k
                       (self-speculative decoding); loose-kwarg
                       spellings keep working via a warn-once shim
    GenerationRequest  prompt + budget + SamplingParams (+ streaming cb,
                       + per-request encoder frames / patch embeddings)
    SamplingParams     greedy / temperature / top-k / top-p, seeded
    RequestOutput      generated ids + finish reason
    EngineStats        tokens/s, per-phase latency, slot occupancy,
                       decode-state bytes, block-pool + prefix-share
                       telemetry

Decode state is family-agnostic behind the ``DecodeState`` protocol
(``serving.state``): contiguous ``SlotPool`` rows or the ``PagedPool``
block cache for KV families, ``RecurrentPool`` conv+SSM/mLSTM/sLSTM state
for ssm/hybrid (optionally int8 under OSSH-static channel scales), and
``CrossAttnPool`` self-KV + per-request cross-KV for encdec. The
block-pool machinery (allocator, int8 KV storage, Pallas block-table
attention) lives in ``repro.serving.paged``; multi-step scheduled decode
and Quaff self-speculative decoding (draft and target as two quant
backends over ONE frozen weight tree) live in ``repro.serving.spec``.
"""
from repro.models.config import ServingConfig
from repro.serving.config import EngineConfig
from repro.serving.engine import Engine
from repro.serving.params import (EngineStats, GenerationRequest,
                                  RequestOutput, SamplingParams)
from repro.serving.pool import PagedPool, SlotPool, make_decode_state
from repro.serving.state import CrossAttnPool, DecodeState, RecurrentPool

__all__ = ["Engine", "EngineConfig", "GenerationRequest", "SamplingParams",
           "RequestOutput", "EngineStats", "ServingConfig", "SlotPool",
           "PagedPool", "RecurrentPool", "CrossAttnPool", "DecodeState",
           "make_decode_state"]
