"""repro.serving — continuous-batching inference over the facade model.

Public surface:

    Engine             slot-pooled continuous-batching engine; KV knobs
                       kv_layout="contiguous"|"paged", kv_dtype="fp"|"int8",
                       block_size / n_blocks / prefill_chunk
    GenerationRequest  prompt + budget + SamplingParams (+ streaming cb)
    SamplingParams     greedy / temperature / top-k / top-p, seeded
    RequestOutput      generated ids + finish reason
    EngineStats        tokens/s, per-phase latency, slot occupancy,
                       block-pool telemetry (paged engines)

The block-pool machinery (allocator, int8 KV storage, Pallas block-table
attention) lives in ``repro.serving.paged``.
"""
from repro.models.config import ServingConfig
from repro.serving.engine import Engine
from repro.serving.params import (EngineStats, GenerationRequest,
                                  RequestOutput, SamplingParams)
from repro.serving.pool import PagedPool, SlotPool

__all__ = ["Engine", "GenerationRequest", "SamplingParams", "RequestOutput",
           "EngineStats", "ServingConfig", "SlotPool", "PagedPool"]
