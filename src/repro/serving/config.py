"""``EngineConfig`` — the one knob surface of the serving engine.

The engine's options grew one keyword at a time (PR 3 slots, PR 4 paged +
quantized KV + chunked prefill, PR 5 recurrent state + lazy blocks, now
prefix sharing), leaving every caller to thread eight loose kwargs through
``api.QuaffModel.engine`` / ``Engine`` / ``ServingConfig`` / the serve
launcher. This module collapses that sprawl into one frozen dataclass:

    from repro.serving import EngineConfig
    engine = model.engine(EngineConfig(max_slots=8, max_seq_len=512,
                                       kv_layout="paged", kv_dtype="int8",
                                       prefix_share=True))

Validation lives in ``__post_init__`` so a bad combination fails at
construction, not deep inside the engine; the dataclass is frozen (and
therefore hashable), so it doubles as the engine cache key in
``api.QuaffModel.engine`` — equivalent spellings (defaults written out or
omitted, legacy kwargs or the dataclass) land on the same compiled engine.

Legacy keyword spellings (``engine(max_slots=8, kv_layout="paged")``)
keep working through ``from_legacy_kwargs``, which warns once per process
and builds the identical dataclass.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict

from repro.serving.paged.kvquant import check_kv_dtype
from repro.serving.state import check_state_dtype

KV_LAYOUTS = ("contiguous", "paged")

#: process-wide warn-once latch for the legacy kwarg shim (tests reset it
#: via ``_reset_legacy_warning`` to assert the warning fires)
_legacy_warned = False


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Every serving-engine knob, validated and frozen.

    Pool sizing:
      max_slots      concurrent requests sharing the decode-state pool.
      max_seq_len    cache positions per request (prompt + PEFT prefix +
                     max_new must fit).

    KV layout / precision (attention-cache families, ``serving.paged``):
      kv_layout      "contiguous" = one max_seq_len row per slot;
                     "paged" = block-pool cache behind per-request block
                     tables.
      kv_dtype       "fp" passthrough or "int8" quantized KV (OSSH-static
                     per-channel key scales, per-token value scales).
      block_size     tokens per KV block (paged only).
      n_blocks       pool capacity in blocks; 0 = worst case
                     (max_slots * ceil(max_seq_len / block_size)).
      prefill_chunk  admit prompts in chunks of N tokens (paged only);
                     0 = whole-prompt admission.
      lazy_blocks    paged only: admit with the PROMPT footprint and grow
                     tables at decode time (stall/preempt backpressure).

    Prefix sharing (paged only, ``serving.paged.radix``):
      prefix_share   index full KV blocks by their token content and map
                     the longest indexed prefix copy-on-write into new
                     requests, so repeated system prompts / few-shot
                     prefixes prefill once.
      radix_capacity max blocks the radix index may pin (LRU-leaf
                     eviction beyond it); 0 = unbounded — the index still
                     sheds leaves under block-pool pressure.

    Recurrent-state precision (ssm/hybrid, ``serving.state``):
      state_dtype    "fp" or "int8" quantized conv/SSM/mLSTM state under
                     OSSH-static per-channel scales.

    Scheduled / speculative decode (``serving.spec``):
      decode_steps   decode iterations per compiled dispatch: the engine
                     runs N steps inside one jitted scan with in-graph
                     EOS/budget masking (dead rows advance as no-ops),
                     amortizing host scheduling N-fold. 1 = classic
                     one-step loop.
      spec_decode    self-speculative decoding: draft K tokens per cycle
                     under a cheap-activation backend over the SAME frozen
                     weights, verify all K in one batched target pass.
                     Greedy output is token-identical to non-speculative
                     decode by construction. Mutually exclusive with
                     decode_steps > 1.
      spec_backend   draft execution mode, "mode" or "mode@bits"
                     (e.g. "int4_w4a8", "quaff@4"); must share the
                     target's weight carrier so both passes read one
                     frozen tree. Required when spec_decode=True.
      spec_k         draft tokens per speculation cycle (>= 1).

    Unified mixed-batch step (``train.steps.build_unified_step``):
      unified_step   ONE ragged dispatch per engine iteration: admitted
                     prefill tails and live decode slots flatten into a
                     single packed token stream with per-row offset
                     tables, so decode rows stop paying a full dispatch
                     of pad tokens while prefills run. KV-pool families
                     only (dense/moe/vlm); greedy output is
                     token-identical to the two-dispatch path. Composes
                     with both layouts, int8 KV, prefix sharing, and —
                     prefill side only — decode_steps/spec_decode.
                     ``prefill_chunk`` bounds each row's tokens per
                     dispatch (default min(32, max_seq_len)); the stream
                     is capped at max_slots * chunk tokens.
    """

    max_slots: int = 4
    max_seq_len: int = 256
    kv_layout: str = "contiguous"
    kv_dtype: str = "fp"
    block_size: int = 16
    n_blocks: int = 0
    prefill_chunk: int = 0
    lazy_blocks: bool = False
    prefix_share: bool = False
    radix_capacity: int = 0
    state_dtype: str = "fp"
    decode_steps: int = 1
    spec_decode: bool = False
    spec_backend: str = ""
    spec_k: int = 4
    unified_step: bool = False

    def __post_init__(self):
        if self.max_slots < 1:
            raise ValueError(f"max_slots must be >= 1, got {self.max_slots}")
        if self.max_seq_len < 1:
            raise ValueError(
                f"max_seq_len must be >= 1, got {self.max_seq_len}")
        if self.kv_layout not in KV_LAYOUTS:
            raise ValueError(f"kv_layout must be 'contiguous' or 'paged', "
                             f"got {self.kv_layout!r}")
        check_kv_dtype(self.kv_dtype)
        check_state_dtype(self.state_dtype)
        if self.block_size < 1:
            raise ValueError(
                f"block_size must be >= 1, got {self.block_size}")
        if self.n_blocks < 0:
            raise ValueError(f"n_blocks must be >= 0, got {self.n_blocks}")
        if self.prefill_chunk < 0:
            raise ValueError(
                f"prefill_chunk must be >= 0, got {self.prefill_chunk}")
        if self.radix_capacity < 0:
            raise ValueError(
                f"radix_capacity must be >= 0, got {self.radix_capacity}")
        if self.kv_layout != "paged":
            if self.kv_dtype != "fp":
                raise ValueError("kv_dtype='int8' needs kv_layout='paged'")
            if self.prefill_chunk and not self.unified_step:
                raise ValueError("chunked prefill (prefill_chunk > 0) needs "
                                 "kv_layout='paged' or unified_step=True "
                                 "(the unified step chunks both layouts)")
            if self.lazy_blocks:
                raise ValueError("lazy_blocks needs kv_layout='paged'")
            if self.prefix_share:
                raise ValueError("prefix_share needs kv_layout='paged' "
                                 "(sharing is block-granular)")
            if self.radix_capacity:
                raise ValueError("radix_capacity needs kv_layout='paged' "
                                 "and prefix_share=True")
        elif self.radix_capacity and not self.prefix_share:
            raise ValueError("radix_capacity needs prefix_share=True")
        if self.decode_steps < 1:
            raise ValueError(
                f"decode_steps must be >= 1, got {self.decode_steps}")
        if self.spec_k < 1:
            raise ValueError(f"spec_k must be >= 1, got {self.spec_k}")
        if self.spec_decode:
            if not self.spec_backend:
                raise ValueError("spec_decode=True needs a spec_backend "
                                 "('mode' or 'mode@bits', e.g. 'int4_w4a8')")
            if self.decode_steps != 1:
                raise ValueError(
                    "spec_decode and decode_steps > 1 are mutually "
                    "exclusive (a speculation cycle already batches "
                    "spec_k + 1 positions per dispatch)")
        elif self.spec_backend:
            raise ValueError("spec_backend is set but spec_decode=False")


def from_legacy_kwargs(kwargs: Dict[str, Any]) -> EngineConfig:
    """Deprecation shim: build an ``EngineConfig`` from the historical
    loose-kwarg spelling (``max_slots=8, kv_layout="paged", ...``).

    Unknown names raise ``TypeError`` exactly like the old signature did;
    a non-empty legacy spelling emits one ``DeprecationWarning`` per
    process. The returned dataclass is identical to writing
    ``EngineConfig(**kwargs)`` directly, so both spellings share engine
    caches keyed on the config."""
    valid = {f.name for f in dataclasses.fields(EngineConfig)}
    unknown = set(kwargs) - valid
    if unknown:
        raise TypeError(
            f"unknown engine option(s) {sorted(unknown)}; "
            f"EngineConfig fields are {sorted(valid)}")
    if kwargs:
        global _legacy_warned
        if not _legacy_warned:
            _legacy_warned = True
            warnings.warn(
                "passing loose engine knobs "
                f"({', '.join(sorted(kwargs))}) is deprecated; build an "
                "EngineConfig and pass it as the single config argument "
                "(engine(EngineConfig(...)) / Engine(model, "
                "EngineConfig(...)))",
                DeprecationWarning, stacklevel=3)
    return EngineConfig(**kwargs)


def _reset_legacy_warning():
    """Test hook: re-arm the warn-once latch."""
    global _legacy_warned
    _legacy_warned = False
