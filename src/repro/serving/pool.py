"""KV-family ``DecodeState`` pools behind the serving engine.

``SlotPool`` (contiguous layout) is ONE device pytree shaped like
``models.init_slot_caches``: k/v buffers (L, n_slots, max_seq_len,
kv_heads, head_dim) plus per-slot write cursors (L, n_slots). It is the
generic ``serving.state.SlotStatePool`` specialized only in its byte
telemetry — admission splices a freshly prefilled row with the shared
column splice (``state.splice_slot``); retirement is pure host-side
bookkeeping (the slot's buffer is fully overwritten by the next
admission, and its cursor keeps masking it consistently meanwhile).

``PagedPool`` (block layout, ``repro.serving.paged``) replaces the
per-slot rows with a shared pool of fixed-size blocks: a request holds
ceil(need / block_size) blocks through a per-request block table, so
short requests stop paying for worst-case rows, and ``kv_dtype="int8"``
stores the pool quantized (~4x fewer KV bytes on top of the paging win).
With lazy allocation (``Engine(lazy_blocks=True)``) a request is admitted
with its PROMPT footprint only and ``ensure_capacity`` grows its table
one block at a time as decode fills it.

``make_decode_state`` is the single family -> pool dispatch point: the
engine never branches on ``cfg.family`` itself.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.serving.paged import blocks as PB
from repro.serving.paged import kvquant as KVQ
from repro.serving.state import (CrossAttnPool, RecurrentPool, SlotStatePool,
                                 check_state_dtype)


class SlotPool(SlotStatePool):
    """Contiguous per-slot KV rows (dense/moe/vlm). Admission goes through
    the generic slot-axis splice (``serving.state.splice_slot``)."""

    def byte_stats(self) -> Dict[str, Any]:
        return {"state_bytes_per_slot":
                self.max_seq_len * KVQ.kv_bytes_per_token(self.cfg, "fp")}


class PagedPool:
    """Block-pool KV cache: device pools + host-side block allocator and
    per-request ``BlockTable``s.

    A slot admission acquires the slot AND its block footprint atomically
    (``acquire`` returns None on either shortage — the engine defers,
    never crashes); retirement returns both. The device side is
    slot-agnostic — pools are indexed by block id only — so any subset of
    slots can ride one compiled call: ``gather_caches(rows)`` assembles the
    cache pytree for those rows (tables + cursors broadcast over layers, the
    per-layer leading axis ``lax.scan`` slices), and ``update_from`` takes
    the written pools back. Rows without a live table read/write the trash
    page (block 0) and are masked by cursor 0. ``live_assemble`` is the
    ``DecodeState``-protocol view: all slots, dead ones trash-paged."""

    def __init__(self, cfg: ModelConfig, n_slots: int, max_seq_len: int, *,
                 block_size: int = 16, kv_dtype: str = "fp",
                 n_blocks: int = 0):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        KVQ.check_kv_dtype(kv_dtype)
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_seq_len = max_seq_len
        self.kv_dtype = kv_dtype
        self.max_pages = max(1, math.ceil(max_seq_len / block_size))
        n_blocks = n_blocks or n_slots * self.max_pages
        self.alloc = PB.BlockAllocator(n_blocks, block_size)
        self.pools = KVQ.init_paged_pools(cfg, n_blocks, block_size, kv_dtype)
        self.tables: List[Optional[PB.BlockTable]] = [None] * n_slots
        self._free_slots: List[int] = list(range(n_slots))
        self._k_seeded = kv_dtype != "int8"
        self.peak_blocks_in_use = 0
        self.n_grows = 0

    # ---- host bookkeeping ------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free_slots)

    @property
    def n_active(self) -> int:
        return self.n_slots - len(self._free_slots)

    def blocks_for(self, n_tokens: int) -> int:
        return self.alloc.blocks_for(n_tokens)

    def can_admit(self, n_tokens: int) -> bool:
        return bool(self._free_slots) and self.alloc.can_acquire(
            self.blocks_for(n_tokens))

    def acquire(self, n_tokens: int) -> Optional[int]:
        """Slot + block footprint for ``n_tokens`` cache positions, or
        None (defer). Under lazy allocation the engine passes the PROMPT
        footprint here and grows the table at decode time."""
        if not self._free_slots:
            return None
        blocks = self.alloc.acquire(self.blocks_for(n_tokens))
        if blocks is None:
            return None
        slot = self._free_slots.pop(0)
        self.tables[slot] = PB.BlockTable(blocks, self.alloc.block_size)
        self.peak_blocks_in_use = max(self.peak_blocks_in_use,
                                      self.alloc.n_used)
        return slot

    def ensure_capacity(self, slot: int, n_tokens: int) -> bool:
        """Grow ``slot``'s table so it can take ``n_tokens`` more cache
        positions (lazy allocation). True when capacity is already there
        or the growth succeeded; False = the pool is out of blocks RIGHT
        NOW (the engine stalls the slot or preempts a victim)."""
        t = self.tables[slot]
        if t.n_tokens + n_tokens <= t.capacity:
            return True
        need = self.blocks_for(t.n_tokens + n_tokens) - len(t.blocks)
        got = self.alloc.acquire(need)
        if got is None:
            return False
        t.blocks.extend(got)
        self.n_grows += need
        self.peak_blocks_in_use = max(self.peak_blocks_in_use,
                                      self.alloc.n_used)
        return True

    def release(self, slot: int):
        table = self.tables[slot]
        if table is None:
            raise ValueError(f"slot {slot} is already free")
        self.alloc.release(table.blocks)
        self.tables[slot] = None
        self._free_slots.append(slot)
        self._free_slots.sort()

    def advance(self, slot: int, n_tokens: int):
        """Record ``n_tokens`` more cache positions written for ``slot``."""
        self.tables[slot].n_tokens += n_tokens

    def cursor(self, slot: int) -> int:
        t = self.tables[slot]
        return 0 if t is None else t.n_tokens

    # ---- k-scale seeding (int8) ------------------------------------------
    @property
    def needs_k_seed(self) -> bool:
        return not self._k_seeded

    def seed_k_scales(self, scales: jnp.ndarray):
        self.pools["k_scale"] = jnp.asarray(scales, jnp.float32)
        self._k_seeded = True

    # ---- device call assembly --------------------------------------------
    def gather_caches(self, rows: List[int],
                      live: Optional[List[bool]] = None
                      ) -> Dict[str, jnp.ndarray]:
        """Cache pytree for one compiled call over ``rows``. ``live[i]``
        False masks row i onto the trash page at cursor 0 (free or
        mid-prefill slots riding a decode batch must not touch their
        blocks)."""
        nl = self.cfg.n_layers
        if live is None:
            live = [True] * len(rows)
        bt = np.stack([
            self.tables[s].as_row(self.max_pages)
            if live[j] and self.tables[s] is not None
            else np.full((self.max_pages,), PB.TRASH_BLOCK, np.int32)
            for j, s in enumerate(rows)])
        pos = np.asarray([self.cursor(s) if live[j] else 0
                          for j, s in enumerate(rows)], np.int32)
        caches = dict(self.pools)
        caches["block_tables"] = jnp.asarray(
            np.broadcast_to(bt, (nl,) + bt.shape))
        caches["pos"] = jnp.asarray(np.broadcast_to(pos, (nl, len(rows))))
        return caches

    # ---- DecodeState protocol views --------------------------------------
    def write_prefill(self, row_state, slot: int):
        raise NotImplementedError(
            "paged admission writes through block tables inside the "
            "compiled step (chunked prefill), not via a row splice")

    def mask_dead(self, live: List[bool]) -> Optional[jnp.ndarray]:
        return None                    # trash page + cursor 0 mask dead rows

    def live_assemble(self, live: List[bool]) -> Dict[str, jnp.ndarray]:
        return self.gather_caches(list(range(self.n_slots)), live=live)

    def update_from(self, new_caches: Dict[str, jnp.ndarray]):
        """Take the written pool leaves back (tables/cursors stay host-side;
        the static k_scale rides along unchanged)."""
        for key in self.pools:
            self.pools[key] = new_caches[key]

    # ---- telemetry -------------------------------------------------------
    def bytes_per_token(self) -> int:
        return KVQ.kv_bytes_per_token(self.cfg, self.kv_dtype)

    def bytes_in_use(self) -> int:
        per_blk = self.alloc.block_size * self.bytes_per_token()
        return sum(len(t.blocks) * per_blk
                   for t in self.tables if t is not None)

    def contiguous_bytes_equiv(self, n_requests: int) -> int:
        """What the PR 3 layout (one fp max_seq_len row each) would hold."""
        fp_tok = KVQ.kv_bytes_per_token(self.cfg, "fp")
        return n_requests * self.max_seq_len * fp_tok

    def fragmentation(self) -> float:
        """Allocated-but-unwritten fraction of the in-use blocks (internal
        fragmentation: the tail of each request's last block)."""
        active = [t for t in self.tables if t is not None]
        cap = sum(t.capacity for t in active)
        return sum(t.waste for t in active) / cap if cap else 0.0

    def byte_stats(self) -> Dict[str, Any]:
        return {"blocks_in_use": self.alloc.n_used,
                "peak_blocks_in_use": self.peak_blocks_in_use,
                "fragmentation": self.fragmentation(),
                "kv_bytes_in_use": self.bytes_in_use(),
                "block_grows": self.n_grows}


def make_decode_state(cfg: ModelConfig, max_slots: int, max_seq_len: int, *,
                      kv_layout: str = "contiguous", kv_dtype: str = "fp",
                      block_size: int = 16, n_blocks: int = 0,
                      state_dtype: str = "fp"):
    """THE family -> ``DecodeState`` dispatch (the engine holds no family
    if-chains): paged/contiguous KV pools for the attention-cache families,
    ``RecurrentPool`` for ssm/hybrid, ``CrossAttnPool`` for encdec."""
    check_state_dtype(state_dtype)
    if not M.supports_slot_decode(cfg):
        raise NotImplementedError(
            f"family={cfg.family!r} has no slot-pooled decode state")
    fam = cfg.family
    if kv_layout == "paged" and fam not in ("dense", "moe", "vlm"):
        raise ValueError(
            f"kv_layout='paged' pools a KV cache; family={fam!r} "
            f"decode state is not a paged KV cache")
    if fam in ("ssm", "hybrid"):
        return RecurrentPool(cfg, max_slots, max_seq_len,
                             state_dtype=state_dtype)
    if state_dtype != "fp":
        raise ValueError("state_dtype='int8' quantizes recurrent state; "
                         f"family={fam!r} has none (use kv_dtype for KV)")
    if kv_layout == "paged":
        return PagedPool(cfg, max_slots, max_seq_len, block_size=block_size,
                         kv_dtype=kv_dtype, n_blocks=n_blocks)
    if fam == "encdec":
        return CrossAttnPool(cfg, max_slots, max_seq_len)
    return SlotPool(cfg, max_slots, max_seq_len)
