"""Fixed-capacity slot-based KV pool.

The pool is ONE device pytree shaped like ``models.init_slot_caches``:
k/v buffers (L, n_slots, max_seq_len, kv_heads, head_dim) plus per-slot
write cursors (L, n_slots). Admission splices a freshly prefilled row into a
free slot with one compiled ``write_slot``; retirement is pure host-side
bookkeeping (the slot's buffer is fully overwritten by the next admission,
and its cursor keeps masking it consistently meanwhile).
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig


def write_slot(pool, row, slot):
    """Splice single-request caches (leading batch dim 1, from
    ``train.steps.build_prefill_slot``) into column ``slot`` of the pool.

    Works leaf-wise: k/v buffers share the pool's rank (row batch dim == 1);
    the row's write cursor is (L,) scalar-per-layer and lands in one column
    of the pool's (L, n_slots) cursor plane."""
    slot = jnp.asarray(slot, jnp.int32)

    def wr(p, r):
        if r.ndim == p.ndim:
            start = (0, slot) + (0,) * (p.ndim - 2)
            return jax.lax.dynamic_update_slice(p, r.astype(p.dtype), start)
        return jax.lax.dynamic_update_slice(
            p, r[:, None].astype(p.dtype), (0, slot))

    return jax.tree.map(wr, pool, row)


class SlotPool:
    """Device caches + host-side free-list for ``n_slots`` concurrent rows."""

    def __init__(self, cfg: ModelConfig, n_slots: int, max_seq_len: int):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_seq_len = max_seq_len
        self.caches = M.init_slot_caches(cfg, n_slots, max_seq_len)
        self._free: List[int] = list(range(n_slots))
        self._write = jax.jit(write_slot)

    # ---- host bookkeeping ------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_active(self) -> int:
        return self.n_slots - len(self._free)

    def acquire(self) -> Optional[int]:
        return self._free.pop(0) if self._free else None

    def release(self, slot: int):
        if slot in self._free:
            raise ValueError(f"slot {slot} is already free")
        self._free.append(slot)
        self._free.sort()

    # ---- device ----------------------------------------------------------
    def admit(self, row_caches, slot: int):
        """Write a prefilled request row into ``slot`` (one compiled call)."""
        self.caches = self._write(self.caches, row_caches, slot)
