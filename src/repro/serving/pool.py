"""KV-family ``DecodeState`` pools behind the serving engine.

``SlotPool`` (contiguous layout) is ONE device pytree shaped like
``models.init_slot_caches``: k/v buffers (L, n_slots, max_seq_len,
kv_heads, head_dim) plus per-slot write cursors (L, n_slots). It is the
generic ``serving.state.SlotStatePool`` specialized only in its byte
telemetry — admission splices a freshly prefilled row with the shared
column splice (``state.splice_slot``); retirement is pure host-side
bookkeeping (the slot's buffer is fully overwritten by the next
admission, and its cursor keeps masking it consistently meanwhile).

``PagedPool`` (block layout, ``repro.serving.paged``) replaces the
per-slot rows with a shared pool of fixed-size blocks: a request holds
ceil(need / block_size) blocks through a per-request block table, so
short requests stop paying for worst-case rows, and ``kv_dtype="int8"``
stores the pool quantized (~4x fewer KV bytes on top of the paging win).
With lazy allocation (``Engine(lazy_blocks=True)``) a request is admitted
with its PROMPT footprint only and ``ensure_capacity`` grows its table
one block at a time as decode fills it.

``make_decode_state`` is the single family -> pool dispatch point: the
engine never branches on ``cfg.family`` itself.
"""
from __future__ import annotations

import hashlib
import math
from typing import Any, Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.serving.paged import blocks as PB
from repro.serving.paged import kvquant as KVQ
from repro.serving.paged.radix import RadixIndex
from repro.serving.state import (CrossAttnPool, RecurrentPool, SlotStatePool,
                                 check_state_dtype)


class SlotPool(SlotStatePool):
    """Contiguous per-slot KV rows (dense/moe/vlm). Admission goes through
    the generic slot-axis splice (``serving.state.splice_slot``)."""

    def byte_stats(self) -> Dict[str, Any]:
        return {"state_bytes_per_slot":
                self.max_seq_len * KVQ.kv_bytes_per_token(self.cfg, "fp")}


class PagedPool:
    """Block-pool KV cache: device pools + host-side block allocator and
    per-request ``BlockTable``s.

    A slot admission acquires the slot AND its block footprint atomically
    (``acquire`` returns None on either shortage — the engine defers,
    never crashes); retirement returns both. The device side is
    slot-agnostic — pools are indexed by block id only — so any subset of
    slots can ride one compiled call: ``gather_caches(rows)`` assembles the
    cache pytree for those rows (tables + cursors broadcast over layers, the
    per-layer leading axis ``lax.scan`` slices), and ``update_from`` takes
    the written pools back. Rows without a live table read/write the trash
    page (block 0) and are masked by cursor 0. ``live_assemble`` is the
    ``DecodeState``-protocol view: all slots, dead ones trash-paged."""

    def __init__(self, cfg: ModelConfig, n_slots: int, max_seq_len: int, *,
                 block_size: int = 16, kv_dtype: str = "fp",
                 n_blocks: int = 0, prefix_share: bool = False,
                 radix_capacity: int = 0):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        KVQ.check_kv_dtype(kv_dtype)
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_seq_len = max_seq_len
        self.kv_dtype = kv_dtype
        self.max_pages = max(1, math.ceil(max_seq_len / block_size))
        n_blocks = n_blocks or n_slots * self.max_pages
        self.alloc = PB.BlockAllocator(n_blocks, block_size)
        self.pools = KVQ.init_paged_pools(cfg, n_blocks, block_size, kv_dtype)
        self.tables: List[Optional[PB.BlockTable]] = [None] * n_slots
        self._free_slots: List[int] = list(range(n_slots))
        self._k_seeded = kv_dtype != "int8"
        self.peak_blocks_in_use = 0
        self.n_grows = 0
        # prefix sharing: the radix index pins one reference per indexed
        # block; its scope ties cached blocks to THIS pool's quantization
        # grid, model shape AND served-weights version (an fp and an int8
        # pool of the same model must never cross-share block content, and
        # KV cached under pre-finetune weights must never map into
        # requests served by the new adapters)
        self.radix: Optional[RadixIndex] = None
        self._radix_capacity = radix_capacity
        self._weights_version = 0
        if prefix_share:
            self.radix = RadixIndex(block_size, scope=self._radix_scope(),
                                    capacity=radix_capacity)
        self.prefix_queries = 0
        self.prefix_hits = 0
        self.shared_blocks_mapped = 0
        self.prefix_tokens_saved = 0
        self.cow_copies = 0
        self.radix_evictions = 0

    # ---- host bookkeeping ------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free_slots)

    @property
    def n_active(self) -> int:
        return self.n_slots - len(self._free_slots)

    def blocks_for(self, n_tokens: int) -> int:
        return self.alloc.blocks_for(n_tokens)

    def can_admit(self, n_tokens: int) -> bool:
        return bool(self._free_slots) and self.alloc.can_acquire(
            self.blocks_for(n_tokens))

    def _acquire_with_evict(self, n: int) -> Optional[List[int]]:
        """``alloc.acquire`` that sheds radix leaves under pressure: an
        index-pinned block whose LAST reference is the index frees the
        moment its leaf drops, so cached-but-unmapped prefixes yield to
        live requests. Blocks still mapped by a table only lose the index
        reference (they stay resident — unevictable while refcount > 1)."""
        while True:
            got = self.alloc.acquire(n)
            if got is not None or self.radix is None:
                return got
            dropped = self.radix.evict(1)
            if not dropped:
                return None
            self.radix_evictions += len(dropped)
            self.alloc.release(dropped)

    def acquire(self, n_tokens: int) -> Optional[int]:
        """Slot + block footprint for ``n_tokens`` cache positions, or
        None (defer). Under lazy allocation the engine passes the PROMPT
        footprint here and grows the table at decode time."""
        if not self._free_slots:
            return None
        blocks = self._acquire_with_evict(self.blocks_for(n_tokens))
        if blocks is None:
            return None
        slot = self._free_slots.pop(0)
        self.tables[slot] = PB.BlockTable(blocks, self.alloc.block_size)
        self.peak_blocks_in_use = max(self.peak_blocks_in_use,
                                      self.alloc.n_used)
        return slot

    def ensure_capacity(self, slot: int, n_tokens: int) -> bool:
        """Grow ``slot``'s table so it can take ``n_tokens`` more cache
        positions (lazy allocation). True when capacity is already there
        or the growth succeeded; False = the pool is out of blocks RIGHT
        NOW (the engine stalls the slot or preempts a victim)."""
        t = self.tables[slot]
        if t.n_tokens + n_tokens <= t.capacity:
            return True
        need = self.blocks_for(t.n_tokens + n_tokens) - len(t.blocks)
        got = self._acquire_with_evict(need)
        if got is None:
            return False
        t.blocks.extend(got)
        self.n_grows += need
        self.peak_blocks_in_use = max(self.peak_blocks_in_use,
                                      self.alloc.n_used)
        return True

    # ---- prefix sharing (radix + COW) ------------------------------------
    def acquire_prefix(self, key: Sequence[int], n_tokens: int,
                       min_share: int = 0) -> Optional[int]:
        """Prefix-aware ``acquire``: walk the longest indexed prefix of
        ``key`` (the request's prefill token stream), map those blocks
        read-only into the new table, and allocate private blocks for the
        rest of the ``n_tokens`` footprint. The returned slot's cursor
        already sits at the shared length — the engine prefills only the
        tail.

        Shares are capped at ``len(key) - 1`` positions (at least one tail
        token is always re-prefilled: the first sampled token needs its
        logits) and dropped entirely below ``min_share`` positions (a share
        that does not cover the whole PEFT prefix is useless — continuation
        chunks cannot write prefix positions). Matched blocks are forked
        BEFORE the private allocation so the eviction loop it may trigger
        can never free them."""
        if self.radix is None:
            return self.acquire(n_tokens)
        if not self._free_slots:
            return None
        self.prefix_queries += 1
        bs = self.alloc.block_size
        shared = self.radix.match(key)[:max(len(key) - 1, 0) // bs]
        if len(shared) * bs < max(min_share, 1):
            shared = []
        if not shared:
            return self.acquire(n_tokens)
        self.alloc.fork(shared)
        got = self._acquire_with_evict(
            self.blocks_for(n_tokens) - len(shared))
        if got is None:
            self.alloc.release(shared)
            return None
        slot = self._free_slots.pop(0)
        self.tables[slot] = PB.BlockTable(
            list(shared) + got, bs, n_tokens=len(shared) * bs)
        self.peak_blocks_in_use = max(self.peak_blocks_in_use,
                                      self.alloc.n_used)
        self.prefix_hits += 1
        self.shared_blocks_mapped += len(shared)
        self.prefix_tokens_saved += len(shared) * bs
        return slot

    def index_insert(self, slot: int, key: Sequence[int]):
        """Index ``slot``'s FULL, already-written blocks under ``key``
        (called when the request's prefill completes — ``key`` spans
        exactly the prefilled positions). The index forks each block it
        newly pins; capacity evictions release AFTER the forks, so a block
        inserted and immediately LRU-evicted never dips to refcount 0
        while mapped."""
        if self.radix is None:
            return
        t = self.tables[slot]
        bs = self.alloc.block_size
        n_full = min(len(t.blocks), t.n_tokens // bs, len(key) // bs)
        new_refs, evicted = self.radix.insert(key, t.blocks[:n_full])
        self.alloc.fork(new_refs)
        if evicted:
            self.radix_evictions += len(evicted)
            self.alloc.release(evicted)

    def prepare_write(self, slot: int, n_tokens: int) -> bool:
        """Copy-on-write barrier: before the compiled step writes
        ``n_tokens`` positions at ``slot``'s cursor, replace any block in
        the write range that is mapped elsewhere (refcount > 1) with a
        private copy. In the monotonic engine flow writes start past the
        shared region, so this never fires — it is the safety net that
        makes sharing an invariant rather than a convention. False = the
        pool cannot supply a copy target right now (caller stalls)."""
        t = self.tables[slot]
        if t is None or self.radix is None:
            return True
        bs = self.alloc.block_size
        lo, hi = t.n_tokens // bs, (t.n_tokens + n_tokens - 1) // bs
        for idx in range(lo, min(hi, len(t.blocks) - 1) + 1):
            src = t.blocks[idx]
            if self.alloc.refcount(src) <= 1:
                continue
            got = self._acquire_with_evict(1)
            if got is None:
                return False
            dst = got[0]
            self._copy_block(src, dst)
            t.blocks[idx] = dst
            self.alloc.release([src])
            self.cow_copies += 1
        return True

    def _copy_block(self, src: int, dst: int):
        """Device-side block copy: every pool leaf with a block axis
        (k/v pools and the per-token v_scale; the static k_scale grid has
        no block axis and is shared by construction)."""
        for key, arr in self.pools.items():
            if key == "k_scale":
                continue
            self.pools[key] = arr.at[:, dst].set(arr[:, src])

    def _radix_scope(self) -> str:
        return (f"{self.kv_dtype}:v{self._weights_version}:"
                + hashlib.sha1(repr(self.cfg).encode("utf-8")).hexdigest())

    def set_weights_version(self, version: int):
        """Pin the prefix index to served-weights ``version``. A version
        change (``api.QuaffModel`` bumps it on every ``finetune()`` /
        ``convert()``) flushes the index and rebuilds it under a re-salted
        scope, so stale prefix KV can never be mapped into requests served
        by the new weights — the engine calls this automatically; no
        manual ``reset_prefix_cache()`` needed."""
        if version == self._weights_version:
            return
        self._weights_version = version
        if self.radix is None:
            return
        self.drop_radix()
        self.radix = RadixIndex(self.alloc.block_size,
                                scope=self._radix_scope(),
                                capacity=self._radix_capacity)

    def drop_radix(self):
        """Flush the prefix index and release every block it pinned (the
        serve launcher calls this when the adapters change mid-flight —
        cached KV from the old weights must not leak into new requests)."""
        if self.radix is None:
            return
        dropped = self.radix.drop_all()
        if dropped:
            self.alloc.release(dropped)

    def release(self, slot: int):
        table = self.tables[slot]
        if table is None:
            raise ValueError(f"slot {slot} is already free")
        self.alloc.release(table.blocks)
        self.tables[slot] = None
        self._free_slots.append(slot)
        self._free_slots.sort()

    def advance(self, slot: int, n_tokens: int):
        """Record ``n_tokens`` more cache positions written for ``slot``."""
        self.tables[slot].n_tokens += n_tokens

    def cursor(self, slot: int) -> int:
        t = self.tables[slot]
        return 0 if t is None else t.n_tokens

    # ---- k-scale seeding (int8) ------------------------------------------
    @property
    def needs_k_seed(self) -> bool:
        return not self._k_seeded

    def seed_k_scales(self, scales: jnp.ndarray):
        self.pools["k_scale"] = jnp.asarray(scales, jnp.float32)
        self._k_seeded = True

    # ---- device call assembly --------------------------------------------
    def gather_caches(self, rows: List[int],
                      live: Optional[List[bool]] = None
                      ) -> Dict[str, jnp.ndarray]:
        """Cache pytree for one compiled call over ``rows``. ``live[i]``
        False masks row i onto the trash page at cursor 0 (free or
        mid-prefill slots riding a decode batch must not touch their
        blocks)."""
        nl = self.cfg.n_layers
        if live is None:
            live = [True] * len(rows)
        bt = np.stack([
            self.tables[s].as_row(self.max_pages)
            if live[j] and self.tables[s] is not None
            else np.full((self.max_pages,), PB.TRASH_BLOCK, np.int32)
            for j, s in enumerate(rows)])
        pos = np.asarray([self.cursor(s) if live[j] else 0
                          for j, s in enumerate(rows)], np.int32)
        caches = dict(self.pools)
        caches["block_tables"] = jnp.asarray(
            np.broadcast_to(bt, (nl,) + bt.shape))
        caches["pos"] = jnp.asarray(np.broadcast_to(pos, (nl, len(rows))))
        return caches

    # ---- DecodeState protocol views --------------------------------------
    def write_prefill(self, row_state, slot: int):
        raise NotImplementedError(
            "paged admission writes through block tables inside the "
            "compiled step (chunked prefill), not via a row splice")

    def mask_dead(self, live: List[bool]) -> Optional[jnp.ndarray]:
        return None                    # trash page + cursor 0 mask dead rows

    def live_assemble(self, live: List[bool]) -> Dict[str, jnp.ndarray]:
        return self.gather_caches(list(range(self.n_slots)), live=live)

    def update_from(self, new_caches: Dict[str, jnp.ndarray]):
        """Take the written pool leaves back (tables/cursors stay host-side;
        the static k_scale rides along unchanged)."""
        for key in self.pools:
            self.pools[key] = new_caches[key]

    # ---- telemetry -------------------------------------------------------
    def bytes_per_token(self) -> int:
        return KVQ.kv_bytes_per_token(self.cfg, self.kv_dtype)

    def bytes_in_use(self) -> int:
        per_blk = self.alloc.block_size * self.bytes_per_token()
        return sum(len(t.blocks) * per_blk
                   for t in self.tables if t is not None)

    def contiguous_bytes_equiv(self, n_requests: int) -> int:
        """What the PR 3 layout (one fp max_seq_len row each) would hold."""
        fp_tok = KVQ.kv_bytes_per_token(self.cfg, "fp")
        return n_requests * self.max_seq_len * fp_tok

    def fragmentation(self) -> float:
        """Allocated-but-unwritten fraction of the in-use blocks (internal
        fragmentation: the tail of each request's last block)."""
        active = [t for t in self.tables if t is not None]
        cap = sum(t.capacity for t in active)
        return sum(t.waste for t in active) / cap if cap else 0.0

    def byte_stats(self) -> Dict[str, Any]:
        out = {"blocks_in_use": self.alloc.n_used,
               "peak_blocks_in_use": self.peak_blocks_in_use,
               "fragmentation": self.fragmentation(),
               "kv_bytes_in_use": self.bytes_in_use(),
               "block_grows": self.n_grows}
        if self.radix is not None:
            out.update({"radix_blocks": self.radix.n_blocks,
                        "shared_blocks": self.alloc.n_shared,
                        "prefix_hits": self.prefix_hits,
                        "cow_copies": self.cow_copies})
        return out


def make_decode_state(cfg: ModelConfig, max_slots: int, max_seq_len: int, *,
                      kv_layout: str = "contiguous", kv_dtype: str = "fp",
                      block_size: int = 16, n_blocks: int = 0,
                      state_dtype: str = "fp", prefix_share: bool = False,
                      radix_capacity: int = 0):
    """THE family -> ``DecodeState`` dispatch (the engine holds no family
    if-chains): paged/contiguous KV pools for the attention-cache families,
    ``RecurrentPool`` for ssm/hybrid, ``CrossAttnPool`` for encdec."""
    check_state_dtype(state_dtype)
    if not M.supports_slot_decode(cfg):
        raise NotImplementedError(
            f"family={cfg.family!r} has no slot-pooled decode state")
    fam = cfg.family
    if kv_layout == "paged" and fam not in ("dense", "moe", "vlm"):
        raise ValueError(
            f"kv_layout='paged' pools a KV cache; family={fam!r} "
            f"decode state is not a paged KV cache")
    if fam in ("ssm", "hybrid"):
        return RecurrentPool(cfg, max_slots, max_seq_len,
                             state_dtype=state_dtype)
    if state_dtype != "fp":
        raise ValueError("state_dtype='int8' quantizes recurrent state; "
                         f"family={fam!r} has none (use kv_dtype for KV)")
    if kv_layout == "paged":
        return PagedPool(cfg, max_slots, max_seq_len, block_size=block_size,
                         kv_dtype=kv_dtype, n_blocks=n_blocks,
                         prefix_share=prefix_share,
                         radix_capacity=radix_capacity)
    if fam == "encdec":
        return CrossAttnPool(cfg, max_slots, max_seq_len)
    return SlotPool(cfg, max_slots, max_seq_len)
