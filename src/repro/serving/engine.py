"""Continuous-batching serving engine — family-agnostic.

ONE compiled decode step (``train.steps.build_decode_slots`` /
``build_paged_step``) serves a continuously changing request mix over a
fixed-capacity decode-state pool:

  * admission — a waiting request is prefilled into any free slot between
    decode steps, while other slots are mid-generation; under
    ``kv_layout="paged"`` admission acquires the request's BLOCK footprint
    and, with ``prefill_chunk`` set, feeds the prompt in fixed-size chunks
    so a long prompt never stalls the decode batch — pending prompts whose
    next chunk has the same length are prefilled as ONE batched call;
  * decode — every live slot advances one token per step, each writing at
    its own cursor (KV) or carrying its own recurrent state, masked by its
    own liveness;
  * retirement — a slot frees on EOS or token budget (plus its blocks in
    paged mode), with no barrier on the rest of the batch.

The engine speaks to decode state ONLY through the ``DecodeState``
protocol (``serving.state``); ``pool.make_decode_state`` picks the
implementation per family:

  dense/moe/vlm   contiguous ``SlotPool`` rows or the ``PagedPool`` block
                  cache (``kv_layout="paged"``, optionally int8 KV w/
                  OSSH-static key-channel scales, chunked prefill, and
                  ``lazy_blocks=True`` decode-time table growth with
                  stall/preempt backpressure);
  ssm/hybrid      ``RecurrentPool`` conv+SSM/mLSTM/sLSTM state (slot reset
                  on admit, live-masked carry on advance, optional
                  ``state_dtype="int8"`` storage under OSSH-static channel
                  scales seeded from the Quaff calibration capture);
  encdec          ``CrossAttnPool`` self-KV + per-request cross-KV rows
                  (``GenerationRequest.input_embeds`` carries the frames).

The engine holds no model state of its own: it reads ``cfg`` / ``frozen`` /
``adapters`` / ``quant_state`` off the wrapped model object (duck-typed —
``repro.api.QuaffModel`` in practice) at every call, so serving a model that
is later fine-tuned further picks up the new adapters automatically.
"""
from __future__ import annotations

import collections
import functools
import itertools
from typing import Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import peft as PEFT
from repro.models.config import ServingConfig
from repro.obs import NULL_OBS, clock
from repro.serving import sampling
from repro.serving.config import EngineConfig, from_legacy_kwargs
from repro.serving.paged import kvquant as KVQ
from repro.serving.params import (EngineStats, GenerationRequest,
                                  RequestOutput, SamplingParams)
from repro.serving.pool import PagedPool, SlotPool, make_decode_state
from repro.serving.spec import drafter as SPEC
from repro.serving.spec import schedule as SCHED
from repro.serving.spec import verify as SVER
from repro.train import steps as S


# ---------------------------------------------------------------------------
# Compiled-step cache: ModelConfig is a frozen (hashable) dataclass, so the
# jitted step builders memoize per cfg — every engine over the same config
# (short-lived benchmark/test engines included) shares one trace cache
# instead of recompiling its own.
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=64)
def _jit_paged_step(cfg):
    return jax.jit(S.build_paged_step(cfg))


@functools.lru_cache(maxsize=64)
def _jit_decode_slots(cfg):
    return jax.jit(S.build_decode_slots(cfg))


@functools.lru_cache(maxsize=64)
def _jit_unified_step(cfg):
    return jax.jit(S.build_unified_step(cfg))


@functools.lru_cache(maxsize=64)
def _jit_prefill_slot(cfg, max_seq_len: int):
    return jax.jit(S.build_prefill_slot(cfg, max_seq_len))


class _SlotState:
    """Host-side bookkeeping for one request (queued or occupying a slot).
    ``remaining`` is the not-yet-prefilled prompt tail (paged chunked
    admission) — None once the request is decoding. After a lazy-block
    preemption the request re-queues with its generated tokens appended to
    the pending prompt, so greedy continuation is deterministic."""

    __slots__ = ("req", "request_id", "prompt", "embeds", "pos_offset",
                 "token_ids", "last_token", "remaining", "n_shared",
                 "prefix_key", "t_submit", "t_admit", "t_first", "t_last")

    def __init__(self, req: GenerationRequest, request_id: str,
                 prompt: np.ndarray, embeds: Optional[np.ndarray],
                 pos_offset: int = 0):
        self.req = req
        self.request_id = request_id
        self.prompt = prompt
        self.embeds = embeds
        # decoder positions the request's prepended embeddings occupy
        # BEFORE the token stream (vlm patches; 0 for encdec — frames
        # live on the encoder side and take no decoder positions)
        self.pos_offset = pos_offset
        self.token_ids: List[int] = []
        self.last_token = 0
        self.remaining: Optional[np.ndarray] = None
        self.n_shared = 0                    # cache positions prefix-shared
        self.prefix_key: Optional[Tuple[int, ...]] = None
        # lifecycle marks on the obs clock; feed RequestOutput.queue_s /
        # ttft_s / e2e_s. A preempted request keeps its original marks —
        # latency is measured from the caller's submit, not the re-admit.
        self.t_submit = 0.0
        self.t_admit = 0.0
        self.t_first = 0.0
        self.t_last = 0.0

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.size)

    @property
    def n_generated(self) -> int:
        return len(self.token_ids)

    @property
    def decoding(self) -> bool:
        return self.remaining is None

    def pending_tokens(self) -> np.ndarray:
        """Tokens still to prefill: the prompt, plus (after a preemption)
        everything generated so far."""
        if not self.token_ids:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.token_ids, np.int32)])


class Engine:
    """Slot-pooled continuous-batching engine over a facade model — every
    family in the zoo (dense/moe/vlm/ssm/hybrid/encdec).

        engine = Engine(model, EngineConfig(max_slots=4, max_seq_len=128))
        outs = engine.run([GenerationRequest(prompt, max_new_tokens=16),
                           GenerationRequest(prompt2, max_new_tokens=64,
                                             sampling=SamplingParams(
                                                 temperature=0.8, top_k=50,
                                                 seed=7))])

    ``submit``/``step`` expose the loop for callers that interleave their own
    work (the serve launcher); ``run`` drains to completion. Per-token
    streaming: set ``GenerationRequest.on_token``. Every knob lives on
    ``EngineConfig``: paged / quantized KV, chunked prefill and lazy block
    growth (``kv_layout="paged"``, ``kv_dtype="int8"``, ``prefill_chunk=N``,
    ``lazy_blocks=True``), radix/COW prefix sharing (``prefix_share=True``,
    ``radix_capacity=N``), quantized recurrent state for ssm/hybrid
    (``state_dtype="int8"``). The historical loose-kwarg spelling
    (``Engine(model, max_slots=4, kv_layout="paged")``) still works through
    a warn-once deprecation shim. Encoder frames / patch embeddings ride
    per request (``GenerationRequest.input_embeds``).
    """

    @classmethod
    def from_config(cls, model, serving, obs=None) -> "Engine":
        """Build from an ``EngineConfig`` (or the training-side
        ``models.config.ServingConfig``, which converts)."""
        if isinstance(serving, ServingConfig):
            serving = serving.to_engine_config()
        return cls(model, serving, obs=obs)

    def __init__(self, model, config: Optional[EngineConfig] = None,
                 max_seq_len: Optional[int] = None, obs=None, **legacy):
        if isinstance(config, EngineConfig):
            if max_seq_len is not None or legacy:
                raise TypeError(
                    "pass either an EngineConfig or legacy engine knobs, "
                    "not both")
        else:
            # legacy spelling: Engine(model, max_slots, max_seq_len,
            # kv_layout=..., ...) — warn-once shim, identical validation
            if config is not None:
                legacy["max_slots"] = config
            if max_seq_len is not None:
                legacy["max_seq_len"] = max_seq_len
            config = from_legacy_kwargs(legacy)
        cfg = model.cfg
        self.config = config
        self.cfg = cfg
        # observability handle — NOT part of EngineConfig (and so not part
        # of the api-level engine cache key); rebind with set_obs()
        self._obs = obs if obs is not None else NULL_OBS
        self.max_slots = config.max_slots
        self.max_seq_len = config.max_seq_len
        self.kv_layout = config.kv_layout
        self.kv_dtype = config.kv_dtype
        self.prefill_chunk = config.prefill_chunk
        self.lazy_blocks = config.lazy_blocks
        self.prefix_share = config.prefix_share
        self._model = model
        self._sample = sampling.make_sampler()
        self._n_prefix = PEFT.n_prefix_tokens(cfg.peft)
        self._waiting: collections.deque = collections.deque()
        self._slots: List[Optional[_SlotState]] = [None] * config.max_slots
        self._finished: Dict[str, RequestOutput] = {}
        self._pending: List[str] = []               # submitted, not returned
        self._auto_id = itertools.count()
        self._probe_fn = None                       # int8 k-scale probe
        # family -> DecodeState dispatch lives in pool.make_decode_state;
        # NOTHING below branches on cfg.family.
        self._pool = make_decode_state(
            cfg, config.max_slots, config.max_seq_len,
            kv_layout=config.kv_layout, kv_dtype=config.kv_dtype,
            block_size=config.block_size, n_blocks=config.n_blocks,
            state_dtype=config.state_dtype,
            prefix_share=config.prefix_share,
            radix_capacity=config.radix_capacity)
        self._paged: Optional[PagedPool] = (
            self._pool if isinstance(self._pool, PagedPool) else None)
        self._step_fn = (_jit_paged_step(cfg) if self._paged is not None
                         else _jit_decode_slots(cfg))
        self._prefill_fn = _jit_prefill_slot(cfg, config.max_seq_len)
        # unified mixed-batch step: ONE ragged dispatch per iteration over
        # prefill tails + decode slots (train.steps.build_unified_step).
        # Family-dependent validation lives here (EngineConfig cannot see
        # the model): ragged rows are KV-cache rows, causal-global only,
        # with no prepended virtual-prefix positions.
        if config.unified_step:
            if not isinstance(self._pool, (SlotPool, PagedPool)):
                raise ValueError(
                    f"unified_step batches ragged KV rows (families "
                    f"dense/moe/vlm); family={cfg.family!r} decode state "
                    "is not a KV pool")
            if cfg.sliding_window:
                raise ValueError(
                    "unified_step needs global causal attention; "
                    "sliding_window layers have no ragged kernel")
            if self._n_prefix:
                raise ValueError(
                    "unified_step does not compose with prompt-PEFT "
                    "virtual prefix tokens (ragged rows are token-stream "
                    "positions only)")
        self._unified_fn = (_jit_unified_step(cfg) if config.unified_step
                            else None)
        self._unified_chunk = (config.prefill_chunk
                               or min(32, config.max_seq_len))
        # contiguous-layout write cursors for the unified step: SlotPool
        # keeps cursors on-device and admission normally splices them via
        # write_prefill — unified admission skips that splice, so the
        # engine tracks cursors host-side and overrides caches["pos"]
        # (a freshly acquired slot would otherwise read its previous
        # occupant's stale cursor)
        self._cursors = [0] * config.max_slots
        # multi-step scheduled decode / self-speculative decoding
        # (serving.spec): both fold several logical decode steps into one
        # compiled dispatch; speculation additionally needs a KV pool whose
        # provisional writes roll back by cursor arithmetic
        self._multistep_fn = (
            SCHED.jit_multistep_decode(cfg, config.decode_steps)
            if config.decode_steps > 1 else None)
        self._drafter: Optional[SPEC.Drafter] = None
        self._verify_fn = None
        if config.spec_decode:
            if not isinstance(self._pool, (SlotPool, PagedPool)):
                raise ValueError(
                    f"spec_decode needs a KV pool (families dense/moe/vlm); "
                    f"family={cfg.family!r} decode state cannot roll back "
                    "provisional writes")
            self._drafter = SPEC.Drafter(cfg, config.spec_backend,
                                         config.spec_k)
            self._verify_fn = SVER.jit_spec_verify(cfg, config.spec_k)
        # served-weights version: a finetune()/convert() on the wrapped
        # model bumps it, and the engine auto-flushes the prefix index —
        # cached KV from the old weights must never map into new requests
        self._weights_version = getattr(model, "weights_version", 0)
        if self._paged is not None:
            self._paged.set_weights_version(self._weights_version)
        self.stats = EngineStats(
            n_slots=config.max_slots, family=cfg.family,
            kv_layout=config.kv_layout, kv_dtype=config.kv_dtype,
            state_dtype=config.state_dtype, lazy_blocks=config.lazy_blocks,
            prefix_share=config.prefix_share,
            scheduled_steps=config.decode_steps,
            spec_decode=config.spec_decode, spec_backend=config.spec_backend,
            spec_k=config.spec_k if config.spec_decode else 0,
            unified_step=config.unified_step,
            block_size=self._paged.alloc.block_size if self._paged else 0,
            n_blocks=self._paged.alloc.n_blocks if self._paged else 0,
            contiguous_bytes_per_request=(
                self._paged.contiguous_bytes_equiv(1) if self._paged
                else config.max_seq_len * KVQ.kv_bytes_per_token(cfg, "fp")))
        self._snapshot_state_bytes()

    def set_obs(self, obs):
        """Rebind the observability handle (``None`` disables). The
        api-level engine cache reuses compiled engines across calls with
        different obs configs, so the handle must be swappable without a
        rebuild."""
        self._obs = obs if obs is not None else NULL_OBS

    @property
    def obs(self):
        return self._obs

    # ------------------------------------------------------------------
    # submission
    # ------------------------------------------------------------------
    def submit(self, req: GenerationRequest) -> str:
        """Validate + enqueue; returns the request id. Admission happens on
        the next ``step``/``run`` — possibly mid-decode of other requests
        (and possibly DEFERRED under paged layout until enough blocks
        free up; only a request that could NEVER fit is rejected here)."""
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if req.max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got "
                             f"{req.max_new_tokens}")
        embeds = None
        if req.input_embeds is not None:
            if self.cfg.family not in ("encdec", "vlm"):
                raise ValueError(
                    f"input_embeds is for the encdec/vlm families; "
                    f"family={self.cfg.family!r} takes token prompts only")
            if self._paged is not None:
                raise ValueError(
                    "input_embeds requests need kv_layout='contiguous' "
                    "(paged chunked admission feeds token chunks only)")
            if self.config.unified_step:
                raise ValueError(
                    "input_embeds requests cannot ride the unified ragged "
                    "step (prepended embeddings occupy cache positions "
                    "outside the token stream)")
            embeds = np.asarray(req.input_embeds, np.float32)
            if embeds.ndim != 2 or embeds.shape[-1] != self.cfg.d_model:
                raise ValueError(
                    f"input_embeds must be (seq, d_model={self.cfg.d_model}),"
                    f" got {embeds.shape}")
            if self.cfg.family == "encdec" and \
                    embeds.shape[0] != self.cfg.encoder_seq:
                raise ValueError(
                    f"encoder frames must span encoder_seq="
                    f"{self.cfg.encoder_seq} positions, got {embeds.shape[0]}")
        # vlm patches prepend to the decoder sequence and occupy cache
        # positions; encoder frames (encdec) do not
        pos_offset = (embeds.shape[0]
                      if embeds is not None and self.cfg.family != "encdec"
                      else 0)
        need = prompt.size + self._n_prefix + pos_offset + req.max_new_tokens
        if need > self.max_seq_len:
            raise ValueError(
                f"request needs {need} cache positions (prompt {prompt.size} "
                f"+ prefix {self._n_prefix} + embeds {pos_offset} + max_new "
                f"{req.max_new_tokens}) but the pool is sized "
                f"max_seq_len={self.max_seq_len}")
        if self._paged is not None and \
                self._paged.blocks_for(need) > self._paged.alloc.n_blocks:
            raise ValueError(
                f"request needs {self._paged.blocks_for(need)} KV blocks but "
                f"the pool only has {self._paged.alloc.n_blocks}")
        rid = req.request_id or f"req-{next(self._auto_id)}"
        if rid in self._finished or any(
                w.request_id == rid for w in self._waiting) or any(
                s is not None and s.request_id == rid for s in self._slots):
            raise ValueError(f"duplicate request_id {rid!r}")
        st = _SlotState(req, rid, prompt, embeds, pos_offset)
        st.t_submit = clock.now()
        self._waiting.append(st)
        self._pending.append(rid)
        self.stats.requests_submitted += 1
        self._obs.inc("requests_submitted")
        self._obs.async_begin("request", rid, prompt_len=int(prompt.size),
                              max_new=req.max_new_tokens)
        return rid

    # ------------------------------------------------------------------
    # engine loop
    # ------------------------------------------------------------------
    @property
    def _n_active(self) -> int:
        return self._pool.n_active

    @property
    def has_work(self) -> bool:
        return bool(self._waiting) or self._n_active > 0

    def step(self) -> bool:
        """One engine iteration: admit into free slots, advance prefill
        chunks (paged), then one batched decode dispatch (a single step, a
        ``decode_steps``-long compiled window, or a draft+verify
        speculation cycle). Returns ``has_work``."""
        self._check_weights_version()
        if self._unified_fn is not None:
            self._step_unified()
            return self.has_work
        if self._paged is not None:
            self._admit_paged()
            self._prefill_paged_chunks()
            self._decode_dispatch()
            self._snapshot_pool_stats()
        else:
            while self._waiting and self._pool.n_free:
                self._admit_one()
            if self._pool.n_active:
                self._decode_dispatch()
        return self.has_work

    def _decode_dispatch(self):
        if self._drafter is not None:
            self._decode_spec()
        elif self._multistep_fn is not None:
            self._decode_multistep()
        elif self._paged is not None:
            self._decode_once_paged()
        else:
            self._decode_once()

    def _check_weights_version(self):
        """Auto-invalidate stale prefix KV: ``api.QuaffModel`` bumps
        ``weights_version`` on every ``finetune()``/``convert()``, and a
        version change re-scopes the radix index (dropping every cached
        block) — no manual ``reset_prefix_cache()`` call needed."""
        v = getattr(self._model, "weights_version", 0)
        if v == self._weights_version:
            return
        self._weights_version = v
        if self._paged is not None:
            self._paged.set_weights_version(v)
            self._snapshot_pool_stats()

    def run(self, requests: Iterable[GenerationRequest] = ()
            ) -> List[RequestOutput]:
        """Submit ``requests``, drain until idle, and return outputs for all
        not-yet-returned requests in submission order. Returned outputs are
        released from the engine (a long-lived engine holds no per-request
        state once its outputs are handed out)."""
        for req in requests:
            self.submit(req)
        while self.has_work:
            self.step()
        out = [self._finished.pop(rid) for rid in self._pending]
        self._pending = []
        return out

    def output(self, request_id: str, pop: bool = True
               ) -> Optional[RequestOutput]:
        """Fetch a completed request's output (step-driven callers).
        ``pop=True`` (default) releases it from the engine so completed
        requests do not accumulate over a long-lived engine's lifetime."""
        if pop:
            out = self._finished.pop(request_id, None)
            if out is not None and request_id in self._pending:
                self._pending.remove(request_id)
            return out
        return self._finished.get(request_id)

    # ------------------------------------------------------------------
    # shared internals
    # ------------------------------------------------------------------
    def _need_full(self, st: _SlotState) -> int:
        return (st.prompt_len + self._n_prefix + st.pos_offset
                + st.req.max_new_tokens)

    def _sample_one(self, logits_row, sp: SamplingParams, token_index: int):
        tok = self._sample(
            logits_row,
            jnp.asarray([sp.temperature], jnp.float32),
            jnp.asarray([sp.top_k], jnp.int32),
            jnp.asarray([sp.top_p], jnp.float32),
            sampling.request_key(sp, token_index)[None],
        )
        return int(tok[0])

    def _emit_token(self, st: _SlotState, slot: int, tok: int):
        st.token_ids.append(tok)
        st.last_token = tok
        self.stats.tokens_generated += 1
        t = clock.now()
        if st.t_first == 0.0:
            st.t_first = t
            self._obs.observe("ttft_s", t - st.t_submit)
            self._obs.async_instant("first_token", st.request_id)
        else:
            # inter-token latency between consecutive emissions; tokens
            # committed by one multi-step/spec dispatch emit back-to-back
            # and so record near-zero gaps — that IS the caller-visible
            # arrival pattern, not an artifact
            self._obs.observe("itl_s", t - st.t_last)
        st.t_last = t
        if st.req.on_token is not None:
            st.req.on_token(st.request_id, tok)
        hit_eos = st.req.eos_id is not None and tok == st.req.eos_id
        if hit_eos or st.n_generated >= st.req.max_new_tokens:
            self._retire(st, slot, "eos" if hit_eos else "length")

    def _retire(self, st: _SlotState, slot: int, reason: str):
        self._finished[st.request_id] = RequestOutput(
            request_id=st.request_id, prompt_len=st.prompt_len,
            token_ids=st.token_ids, finish_reason=reason,
            queue_s=st.t_admit - st.t_submit,
            ttft_s=st.t_first - st.t_submit,
            e2e_s=st.t_last - st.t_submit)
        self._obs.observe("e2e_s", st.t_last - st.t_submit)
        self._obs.inc("requests_completed")
        self._obs.async_end("request", st.request_id, reason=reason,
                            n_tokens=st.n_generated)
        self._slots[slot] = None
        if self._paged is not None:
            table = self._paged.tables[slot]
            self.stats.kv_bytes_per_request_sum += (
                table.capacity * self._paged.bytes_per_token())
            self.stats.blocks_used_sum += len(table.blocks)
            self.stats.blocks_reserved_eager_sum += \
                self._paged.blocks_for(self._need_full(st))
        self._pool.release(slot)
        self.stats.requests_completed += 1

    def _preempt(self, slot: int):
        """Lazy-block backpressure: evict the request in ``slot`` back to
        the FRONT of the queue (its blocks free immediately), carrying its
        generated tokens so the re-prefill continues the same greedy
        stream. Only reached when every runnable slot is out of blocks —
        forward progress beats holding a wedged pool.

        Caveat (prompt-PEFT): re-prefill assigns positions cursor-wise
        (prefix included), while the decode convention places generated
        token g at prompt_len + g (prefix excluded, the legacy lockstep
        convention) — so with ``n_prefix > 0`` a preempted request's
        already-generated tokens are re-rotated ``n_prefix`` positions
        later and the continuation can drift from the un-preempted
        stream. Without prompt-PEFT (n_prefix == 0, every test/CI
        config) the continuation is exactly deterministic."""
        st = self._slots[slot]
        self._slots[slot] = None
        self._pool.release(slot)
        st.remaining = None
        self._waiting.appendleft(st)
        self.stats.preemptions += 1
        self._obs.inc("preemptions")
        self._obs.async_instant("preempt", st.request_id)

    def _adapters_no_prefix(self):
        """Adapters with the prompt-PEFT virtual tokens stripped: decode
        steps (all layouts) and continuation chunks must not re-prepend
        the prefix — it is already in the cache from the prefill, and a
        re-prepended prefix would also write n_prefix extra cache positions
        per step, corrupting the slot cursor."""
        ad = self._model.adapters
        if isinstance(ad, dict) and "prompt" in ad:
            return {k: v for k, v in ad.items() if k != "prompt"}
        return ad

    def _decode_batch_arrays(self, decoding: List[int]):
        """Per-slot host arrays for one batched decode call: fed-back
        tokens, RoPE positions and sampling-parameter rows (free and
        mid-prefill slots ride along with don't-care rows).

        The fed-back token is generated token #n_generated (1-based): its
        RoPE position is prompt_len + n_generated - 1, matching the
        pre-engine lockstep loop's ``prompt_len + i`` — plus the request's
        ``pos_offset`` when prepended vlm patches occupy the positions
        before the token stream."""
        b = self.max_slots
        tokens = np.zeros((b, 1), np.int32)
        positions = np.zeros((b,), np.int32)
        temps = np.zeros((b,), np.float32)
        top_ks = np.zeros((b,), np.int32)
        top_ps = np.ones((b,), np.float32)
        keys = [jax.random.PRNGKey(0)] * b
        for i in decoding:
            st = self._slots[i]
            sp = st.req.sampling
            tokens[i, 0] = st.last_token
            positions[i] = st.prompt_len + st.pos_offset + st.n_generated - 1
            temps[i] = sp.temperature
            top_ks[i] = sp.top_k
            top_ps[i] = sp.top_p
            keys[i] = sampling.request_key(sp, st.n_generated)
        return tokens, positions, temps, top_ks, top_ps, keys

    def _snapshot_state_bytes(self):
        bs = self._pool.byte_stats()
        self.stats.state_bytes_per_slot = bs.get("state_bytes_per_slot", 0)
        self.stats.fp_state_bytes_per_slot = bs.get(
            "fp_state_bytes_per_slot", self.stats.state_bytes_per_slot)

    # ------------------------------------------------------------------
    # direct (non-paged) admission + decode — every family
    # ------------------------------------------------------------------
    def _admit_one(self):
        st = self._waiting.popleft()
        slot = self._pool.acquire(self._need_full(st))
        m = self._model
        t0 = self._obs.phase_begin("prefill", req=st.request_id,
                                   prompt_len=st.prompt_len)
        if st.t_admit == 0.0:
            st.t_admit = t0
            self._obs.observe("queue_s", t0 - st.t_submit)
            self._obs.async_instant("admit", st.request_id)
        pool = self._pool
        if getattr(pool, "needs_seed", False):
            # int8 recurrent state: OSSH-static scales from the Quaff
            # calibration capture; write_prefill probes from this first
            # row if the capture predates the state entry
            pool.seed_from_stats(getattr(m, "stats", None))
        tokens = jnp.asarray(st.pending_tokens()[None, :])
        if st.embeds is not None:
            logits, row_caches = self._prefill_fn(
                m.frozen, m.adapters, m.quant_state, tokens,
                jnp.asarray(st.embeds[None]))
        else:
            logits, row_caches = self._prefill_fn(
                m.frozen, m.adapters, m.quant_state, tokens)
        pool.write_prefill(row_caches, slot)
        tok = self._sample_one(logits, st.req.sampling, st.n_generated)
        self.stats.prefill_time_s += self._obs.phase_end(
            "prefill", t0, hist="prefill_s")
        self.stats.prefills += 1
        self.stats.prefill_batches += 1
        self._snapshot_state_bytes()

        self._slots[slot] = st
        self._emit_token(st, slot, tok)

    def _decode_once(self):
        m = self._model
        active = [i for i, st in enumerate(self._slots) if st is not None]
        live = [st is not None for st in self._slots]
        tokens, positions, temps, top_ks, top_ps, keys = \
            self._decode_batch_arrays(active)

        t0 = self._obs.phase_begin("decode", n_slots=len(active))
        caches = self._pool.live_assemble(live)
        logits, new_caches = self._step_fn(
            m.frozen, self._adapters_no_prefix(), m.quant_state,
            caches, jnp.asarray(tokens), jnp.asarray(positions),
            self._pool.mask_dead(live))
        self._pool.update_from(new_caches)
        toks = np.asarray(self._sample(
            logits, jnp.asarray(temps), jnp.asarray(top_ks),
            jnp.asarray(top_ps), jnp.stack(keys)))
        self.stats.decode_time_s += self._obs.phase_end(
            "decode", t0, hist="decode_dispatch_s")
        self.stats.decode_steps += 1
        self.stats.decode_dispatches += 1
        self.stats.busy_slot_steps += len(active)
        self.stats.decode_pad_tokens += self.max_slots - len(active)

        for i in active:
            self._pool.advance(i, 1)
            self._emit_token(self._slots[i], i, int(toks[i]))

    # ------------------------------------------------------------------
    # paged layout (KV families)
    # ------------------------------------------------------------------
    def _prefix_key(self, pending: np.ndarray) -> Tuple[int, ...]:
        """Radix key for a request's prefill stream: the PEFT prefix
        positions as negative sentinels (every request of this engine
        prepends the same virtual tokens — they share by construction but
        must occupy key positions so block boundaries line up), then the
        pending prompt tokens."""
        return tuple(range(-self._n_prefix, 0)) + tuple(
            int(t) for t in pending)

    def _admit_paged(self):
        """FIFO admission into (slot + block footprint); stops at the first
        request the pool cannot hold RIGHT NOW — it stays queued and admits
        once retirements free enough blocks (refusal, never a crash).
        Lazy mode acquires the PROMPT footprint only; decode grows it.
        With ``prefix_share`` the pool maps the longest indexed prefix
        into the table read-only and only the tail stays in ``remaining``
        — prefill work already cached is never redone."""
        while self._waiting:
            st = self._waiting[0]
            pending = st.pending_tokens()
            need = (pending.size + self._n_prefix if self.lazy_blocks
                    else pending.size + self._n_prefix
                    + st.req.max_new_tokens - st.n_generated)
            if self.prefix_share:
                key = self._prefix_key(pending)
                slot = self._paged.acquire_prefix(
                    key, need, min_share=self._n_prefix)
            else:
                key, slot = None, self._pool.acquire(need)
            if slot is None:
                self.stats.admission_deferrals += 1
                break
            self._waiting.popleft()
            if st.t_admit == 0.0:
                st.t_admit = clock.now()
                self._obs.observe("queue_s", st.t_admit - st.t_submit)
                self._obs.async_instant("admit", st.request_id)
            st.prefix_key = key
            st.n_shared = self._paged.cursor(slot)
            if st.n_shared:
                # the shared region covers the PEFT prefix plus the first
                # n_shared - n_prefix prompt tokens; prefill only the tail
                st.remaining = pending[st.n_shared - self._n_prefix:]
                chunk = self.prefill_chunk
                if chunk:
                    self.stats.prefill_chunks_saved += (
                        -(-(pending.size) // chunk)
                        - -(-(st.remaining.size) // chunk))
            else:
                st.remaining = pending
            self._slots[slot] = st

    def _ensure_k_scales(self, prompt: np.ndarray):
        """Seed the int8 pool's static key-channel grid: from the Quaff
        calibration capture when the model carries one, else from a one-off
        contiguous fp prefill of the first admitted prompt (OSSH: the hot
        key channels it exposes are the hot channels every token hits)."""
        scales = KVQ.k_scales_from_stats(
            getattr(self._model, "stats", None), self.cfg)
        if scales is None:
            m = self._model
            if self._probe_fn is None:
                self._probe_fn = _jit_prefill_slot(self.cfg, self.max_seq_len)
            _, row_caches = self._probe_fn(
                m.frozen, m.adapters, m.quant_state,
                jnp.asarray(prompt[None, :]))
            scales = KVQ.k_scales_from_row_caches(jax.device_get(row_caches))
        self._paged.seed_k_scales(scales)

    def _prefill_paged_chunks(self):
        """Advance every mid-prefill slot by one chunk. Slots whose next
        chunk has the SAME length ride one batched call (same-length
        admission); jit re-specializes only per distinct (batch, chunk).
        Lazy mode: a slot whose chunk cannot get blocks stalls this round
        (and a victim is preempted if nothing at all can move)."""
        pending = [i for i, st in enumerate(self._slots)
                   if st is not None and not st.decoding]
        if not pending:
            return
        if self._paged.needs_k_seed:
            self._ensure_k_scales(self._slots[pending[0]].remaining)
        groups: Dict[Tuple[int, bool], List[int]] = {}
        stalled: List[int] = []
        for i in pending:
            st = self._slots[i]
            clen = st.remaining.size if not self.prefill_chunk else \
                min(self.prefill_chunk, st.remaining.size)
            first = self._paged.cursor(i) == 0
            sx = clen + (self._n_prefix if first else 0)
            if self.lazy_blocks and not self._paged.ensure_capacity(i, sx):
                self.stats.block_stalls += 1
                stalled.append(i)
                continue
            if not self._paged.prepare_write(i, sx):
                # COW target unavailable: treat like a block stall
                self.stats.block_stalls += 1
                stalled.append(i)
                continue
            groups.setdefault((clen, first), []).append(i)
        if not groups:
            decoding = any(st is not None and st.decoding
                           for st in self._slots)
            if stalled and not decoding:
                # nothing can move: evict the least-progressed prefill
                victim = min(stalled, key=lambda i: self._paged.cursor(i))
                self._preempt(victim)
            return
        m = self._model
        for (clen, first), rows in sorted(groups.items()):
            t0 = self._obs.phase_begin("prefill", chunk=clen,
                                       rows=len(rows))
            tokens = np.stack(
                [self._slots[s].remaining[:clen] for s in rows])
            # the first chunk prepends the PEFT prefix inside the forward,
            # so it spans clen + n_prefix cache positions
            sx = clen + (self._n_prefix if first else 0)
            pos0 = np.asarray([self._paged.cursor(s) for s in rows], np.int32)
            positions = pos0[:, None] + np.arange(sx, dtype=np.int32)[None, :]
            adapters = m.adapters if first else self._adapters_no_prefix()
            caches = self._paged.gather_caches(rows)
            logits, new_caches = self._step_fn(
                m.frozen, adapters, m.quant_state, caches,
                jnp.asarray(tokens), jnp.asarray(positions))
            self._paged.update_from(new_caches)
            self.stats.prefill_time_s += self._obs.phase_end(
                "prefill", t0, hist="prefill_s")
            self.stats.prefill_batches += 1
            self.stats.prefill_chunks += len(rows)
            # same-length grouping keeps the geometry exactly full (each
            # row takes precisely clen tokens), so this counter stays 0 —
            # the grouped path's cost is EXTRA DISPATCHES per distinct
            # length, which the unified step's single ragged call removes
            self.stats.prefill_pad_tokens += sum(
                clen - min(clen, self._slots[s].remaining.size)
                for s in rows)
            for r, slot in enumerate(rows):
                st = self._slots[slot]
                self._paged.advance(slot, sx)
                st.remaining = st.remaining[clen:]
                if st.remaining.size == 0:
                    st.remaining = None
                    self.stats.prefills += 1
                    if self.prefix_share and st.prefix_key is not None:
                        # prefill complete: the cursor spans exactly the
                        # keyed region — index its full blocks for reuse
                        self._paged.index_insert(slot, st.prefix_key)
                    tok = self._sample_one(logits[r:r + 1], st.req.sampling,
                                           st.n_generated)
                    self._emit_token(st, slot, tok)

    def _ready_paged(self, window: int) -> List[int]:
        """Decoding slots whose next ``window`` cache positions are backed
        by blocks: lazy tables grow (``ensure_capacity``) and shared blocks
        in the write range get private copies (``prepare_write``) — a slot
        failing either stalls this round. When nothing at all can move,
        the youngest stream (fewest sunk tokens) is preempted. A slot only
        needs capacity for the positions it can still COMMIT (its budget);
        window writes past that land on the trash page and die with the
        row."""
        decoding = [i for i, st in enumerate(self._slots)
                    if st is not None and st.decoding]
        if not decoding or not (self.lazy_blocks or self.prefix_share):
            return decoding
        ready = []
        for i in decoding:
            st = self._slots[i]
            w = min(window, st.req.max_new_tokens - st.n_generated)
            if self.lazy_blocks and not self._paged.ensure_capacity(i, w):
                self.stats.block_stalls += 1
            elif not self._paged.prepare_write(i, w):
                # write would land in a shared block and no COW target
                # is available — stall this stream for the round
                self.stats.block_stalls += 1
            else:
                ready.append(i)
        if not ready:
            victim = min(decoding,
                         key=lambda i: (self._slots[i].n_generated, -i))
            self._preempt(victim)
        return ready

    def _decode_once_paged(self):
        decoding = self._ready_paged(1)
        if not decoding:
            return
        m = self._model
        in_step = set(decoding)
        live = [i in in_step for i in range(self.max_slots)]
        tokens, positions, temps, top_ks, top_ps, keys = \
            self._decode_batch_arrays(decoding)

        t0 = self._obs.phase_begin("decode", n_slots=len(decoding))
        frag = self._paged.fragmentation()      # pool state THIS step uses
        self.stats.fragmentation_sum += frag
        self.stats.fragmentation_samples += 1
        caches = self._pool.live_assemble(live)
        logits, new_caches = self._step_fn(
            m.frozen, self._adapters_no_prefix(), m.quant_state, caches,
            jnp.asarray(tokens), jnp.asarray(positions[:, None]))
        self._pool.update_from(new_caches)
        toks = np.asarray(self._sample(
            logits, jnp.asarray(temps), jnp.asarray(top_ks),
            jnp.asarray(top_ps), jnp.stack(keys)))
        self.stats.decode_time_s += self._obs.phase_end(
            "decode", t0, hist="decode_dispatch_s")
        self.stats.decode_steps += 1
        self.stats.decode_dispatches += 1
        self.stats.busy_slot_steps += len(decoding)
        self.stats.decode_pad_tokens += self.max_slots - len(decoding)

        for i in decoding:
            self._pool.advance(i, 1)
            self._emit_token(self._slots[i], i, int(toks[i]))

    # ------------------------------------------------------------------
    # unified mixed-batch step: ONE ragged dispatch per iteration
    # ------------------------------------------------------------------
    def _step_unified(self):
        """One engine iteration under ``unified_step=True``: admit, then
        flatten every runnable row — mid-prefill slots contribute their
        next chunk, decoding slots their fed-back token — into ONE packed
        ragged forward (``train.steps.build_unified_step``). Greedy output
        is token-identical to the two-dispatch path: each request's tokens
        depend only on its own prefix, and the ragged kernel reproduces
        the per-row causal masking and int8 read-after-write rules of the
        separate prefill/decode calls. With spec/multistep decode the
        unified dispatch carries the PREFILL rows only and the compiled
        decode window follows — its verify chunks route through the same
        ragged kernel inside the model."""
        if self._paged is not None:
            self._admit_paged()
        else:
            self._admit_unified()
        if self._drafter is not None or self._multistep_fn is not None:
            self._unified_dispatch(include_decode=False)
            self._decode_dispatch()
        else:
            self._unified_dispatch()
        if self._paged is not None:
            self._snapshot_pool_stats()

    def _admit_unified(self):
        """Contiguous-layout admission WITHOUT the eager whole-prompt
        prefill: the slot row is reserved and the prompt parks in
        ``remaining`` — the unified dispatch feeds it chunk by chunk
        exactly like paged chunked admission."""
        while self._waiting and self._pool.n_free:
            st = self._waiting.popleft()
            slot = self._pool.acquire(self._need_full(st))
            if st.t_admit == 0.0:
                st.t_admit = clock.now()
                self._obs.observe("queue_s", st.t_admit - st.t_submit)
                self._obs.async_instant("admit", st.request_id)
            st.remaining = st.pending_tokens()
            self._cursors[slot] = 0
            self._slots[slot] = st

    def _row_writable(self, slot: int, n: int) -> bool:
        """Per-row backpressure for one unified dispatch: lazy tables
        grow and COW-shared blocks in the write range get private copies,
        exactly as the legacy paths do per phase — a row failing either
        sits this dispatch out (row_len 0)."""
        if self._paged is None:
            return True
        if self.lazy_blocks and not self._paged.ensure_capacity(slot, n):
            self.stats.block_stalls += 1
            return False
        if not self._paged.prepare_write(slot, n):
            self.stats.block_stalls += 1
            return False
        return True

    def _advance_row(self, slot: int, n: int):
        self._pool.advance(slot, n)
        if self._paged is None:
            self._cursors[slot] += n

    def _unified_dispatch(self, include_decode: bool = True):
        """Build and run one packed ragged batch. The stream is
        token-budget-bounded at ``max_slots * chunk`` positions (chunk =
        ``prefill_chunk`` or min(32, max_seq_len)) — a static shape, so
        jit compiles the step ONCE per engine config regardless of the
        request mix. Rows pack in slot order; row r of the offset tables
        IS slot r of the gathered caches."""
        b = self.max_slots
        chunk = self._unified_chunk
        prefill_rows: Dict[int, int] = {}
        decode_rows: List[int] = []
        stalled: List[int] = []
        for i, st in enumerate(self._slots):
            if st is None:
                continue
            if not st.decoding:
                clen = min(chunk, st.remaining.size)
                if self._row_writable(i, clen):
                    prefill_rows[i] = clen
                else:
                    stalled.append(i)
            elif include_decode:
                if self._row_writable(i, 1):
                    decode_rows.append(i)
                else:
                    stalled.append(i)
        if not prefill_rows and not decode_rows:
            if stalled and include_decode:
                # nothing at all can move: free the least-progressed
                # stream's blocks so the rest unwedge (legacy preemption)
                self._preempt(min(stalled,
                                  key=lambda i: self._paged.cursor(i)))
            return
        if (self._paged is not None and self._paged.needs_k_seed
                and prefill_rows):
            first = min(prefill_rows)
            self._ensure_k_scales(self._slots[first].remaining)

        decode_set = set(decode_rows)
        t_cap = b * chunk
        tokens = np.zeros((1, t_cap), np.int32)
        positions = np.zeros((1, t_cap), np.int32)
        row_start = np.zeros((b,), np.int32)
        row_len = np.zeros((b,), np.int32)
        row_ids = np.zeros((t_cap,), np.int32)
        cursors = np.zeros((b,), np.int32)
        live = [False] * b
        off = 0
        for i in range(b):
            row_start[i] = off
            n = prefill_rows.get(i, 1 if i in decode_set else 0)
            if not n:
                continue
            st = self._slots[i]
            cur = (self._paged.cursor(i) if self._paged is not None
                   else self._cursors[i])
            cursors[i] = cur
            live[i] = True
            row_len[i] = n
            row_ids[off:off + n] = i
            # every span writes at its cursor, so RoPE positions are
            # cursor + local index — for a decode row that equals the
            # legacy prompt_len + n_generated - 1 feedback position
            positions[0, off:off + n] = cur + np.arange(n, dtype=np.int32)
            if i in prefill_rows:
                tokens[0, off:off + n] = st.remaining[:n]
            else:
                tokens[0, off] = st.last_token
            off += n
        n_tok = off

        m = self._model
        t0 = self._obs.phase_begin(
            "unified", n_prefill=len(prefill_rows),
            n_decode=len(decode_rows), n_tok=n_tok)
        if self._paged is not None:
            self.stats.fragmentation_sum += self._paged.fragmentation()
            self.stats.fragmentation_samples += 1
            caches = self._paged.gather_caches(list(range(b)), live=live)
        else:
            caches = dict(self._pool.live_assemble(live))
            caches["pos"] = jnp.asarray(np.broadcast_to(
                cursors, (self.cfg.n_layers, b)))
        logits, new_caches = self._unified_fn(
            m.frozen, self._adapters_no_prefix(), m.quant_state, caches,
            jnp.asarray(tokens), jnp.asarray(positions),
            jnp.asarray(row_start), jnp.asarray(row_len),
            jnp.asarray(row_ids), jnp.int32(n_tok))
        self._pool.update_from(new_caches)
        self.stats.unified_time_s += self._obs.phase_end(
            "unified", t0, hist="unified_step_s")
        self.stats.unified_dispatches += 1
        self.stats.prefill_chunks += len(prefill_rows)
        if decode_rows:
            self.stats.decode_steps += 1
            self.stats.decode_dispatches += 1
            self.stats.busy_slot_steps += len(decode_rows)
            # the legacy decode dispatch is max_slots token-rows wide with
            # dead/mid-prefill slots riding as pads; the packed stream
            # carries only the live ones
            self.stats.pad_tokens_saved += b - len(decode_rows)
        if prefill_rows and decode_rows:
            self.stats.mixed_batches += 1

        for i in range(b):
            st = self._slots[i]
            if i in prefill_rows:
                n = prefill_rows[i]
                self._advance_row(i, n)
                st.remaining = st.remaining[n:]
                if st.remaining.size == 0:
                    st.remaining = None
                    self.stats.prefills += 1
                    if self.prefix_share and st.prefix_key is not None:
                        self._paged.index_insert(i, st.prefix_key)
                    tok = self._sample_one(logits[i:i + 1], st.req.sampling,
                                           st.n_generated)
                    self._emit_token(st, i, tok)
            elif i in decode_set:
                self._advance_row(i, 1)
                tok = self._sample_one(logits[i:i + 1], st.req.sampling,
                                       st.n_generated)
                self._emit_token(st, i, tok)

    # ------------------------------------------------------------------
    # multi-step scheduled decode / speculative decoding (serving.spec)
    # ------------------------------------------------------------------
    def _decode_rows(self, window: int) -> Tuple[List[int], List[bool]]:
        """(decoding slots, per-slot live mask) for one spec/multi-step
        dispatch — paged slots additionally pass the ``window``-wide block
        backpressure check; stalled rows sit the window out entirely (they
        are dead in the gather, so the graph neither reads nor writes
        them)."""
        if self._paged is not None:
            decoding = self._ready_paged(window)
        else:
            decoding = [i for i, st in enumerate(self._slots)
                        if st is not None]
        in_step = set(decoding)
        return decoding, [i in in_step for i in range(self.max_slots)]

    def _decode_multistep(self):
        """One ``decode_steps``-long compiled window: sampling, feedback
        and EOS/budget death all happen in-graph (``spec.schedule``); the
        host replays the emit mask afterwards so streaming callbacks,
        retirement and paged cursors see exactly the committed tokens."""
        n = self.config.decode_steps
        decoding, live = self._decode_rows(n)
        if not decoding:
            return
        m = self._model
        tokens, positions, temps, top_ks, top_ps, _ = \
            self._decode_batch_arrays(decoding)
        b = self.max_slots
        eos_ids = np.full((b,), -1, np.int32)
        budgets = np.ones((b,), np.int32)
        keys = [[jax.random.PRNGKey(0)] * b for _ in range(n)]
        for i in decoding:
            st = self._slots[i]
            sp = st.req.sampling
            if st.req.eos_id is not None:
                eos_ids[i] = st.req.eos_id
            budgets[i] = st.req.max_new_tokens - st.n_generated
            for s in range(n):
                # the one-step loop's exact key stream: seeded sampling is
                # bit-identical whichever window size emitted the token
                keys[s][i] = sampling.request_key(sp, st.n_generated + s)

        t0 = self._obs.phase_begin("decode", n_slots=len(decoding),
                                   steps=n)
        if self._paged is not None:
            self.stats.fragmentation_sum += self._paged.fragmentation()
            self.stats.fragmentation_samples += 1
        caches = self._pool.live_assemble(live)
        toks, emits, new_caches = self._multistep_fn(
            m.frozen, self._adapters_no_prefix(), m.quant_state, caches,
            jnp.asarray(tokens), jnp.asarray(positions),
            jnp.stack([jnp.stack(row) for row in keys]),
            jnp.asarray(temps), jnp.asarray(top_ks), jnp.asarray(top_ps),
            jnp.asarray(eos_ids), jnp.asarray(budgets),
            jnp.asarray(np.asarray(live)), self._pool.mask_dead(live))
        self._pool.update_from(new_caches)
        toks, emits = np.asarray(toks), np.asarray(emits)
        self.stats.decode_time_s += self._obs.phase_end(
            "decode", t0, hist="decode_dispatch_s")
        self.stats.decode_steps += n
        self.stats.decode_dispatches += 1
        self.stats.busy_slot_steps += int(emits.sum())
        self.stats.decode_pad_tokens += n * (self.max_slots - len(decoding))

        for i in decoding:
            st = self._slots[i]
            # advance BEFORE the emit walk: retirement snapshots the block
            # table. emits[:, i] is a prefix of Trues, so the walk breaks
            # at the row's in-graph death — which is byte-for-byte the
            # _emit_token retirement rule, so the two always agree.
            self._pool.advance(i, int(emits[:, i].sum()))
            for s in range(n):
                if not emits[s, i]:
                    break
                self._emit_token(st, i, int(toks[s, i]))

    def _decode_spec(self):
        """One speculation cycle = TWO dispatches for up to ``spec_k + 1``
        tokens per row: a K-step draft scan under the cheap-activation
        backend (``spec.drafter`` — its cache writes are discarded), then
        one batched target pass scoring all K+1 positions against the
        PRE-draft caches (``spec.verify``). Rollback of rejected positions
        is cursor arithmetic: in-graph for contiguous slots, a host
        ``advance(i, counts)`` short of the chunk for block tables."""
        k = self.config.spec_k
        decoding, live = self._decode_rows(k + 1)
        if not decoding:
            return
        m = self._model
        tokens, positions, temps, top_ks, top_ps, _ = \
            self._decode_batch_arrays(decoding)
        b = self.max_slots
        zero = jax.random.PRNGKey(0)
        draft_keys = [[zero] * b for _ in range(k)]
        seq_keys = [[zero] * (k + 1) for _ in range(b)]
        for i in decoding:
            st = self._slots[i]
            sp = st.req.sampling
            for j in range(k):
                # proposals draw from a DISJOINT fold_in stream; reusing
                # the sequential keys would correlate draft and verify
                # draws and bias rejection sampling
                draft_keys[j][i] = sampling.request_key(
                    sp, SPEC.DRAFT_FOLD + st.n_generated + j)
            for j in range(k + 1):
                seq_keys[i][j] = sampling.request_key(sp, st.n_generated + j)
        temps, top_ks, top_ps = (jnp.asarray(temps), jnp.asarray(top_ks),
                                 jnp.asarray(top_ps))

        t0 = self._obs.phase_begin("decode", n_slots=len(decoding),
                                   kind="spec", k=k)
        if self._paged is not None:
            self.stats.fragmentation_sum += self._paged.fragmentation()
            self.stats.fragmentation_samples += 1
        caches = self._pool.live_assemble(live)
        tok0 = jnp.asarray(tokens)
        td = self._obs.phase_begin("draft")
        d_toks, d_logits = self._drafter.propose(
            m.frozen, self._adapters_no_prefix(), m.quant_state, caches,
            tok0, jnp.asarray(positions),
            jnp.stack([jnp.stack(row) for row in draft_keys]),
            temps, top_ks, top_ps)
        self._obs.phase_end("draft", td, hist="spec_draft_s")
        chunk = jnp.concatenate([tok0, jnp.transpose(d_toks)], axis=1)
        vpos = (jnp.asarray(positions)[:, None]
                + jnp.arange(k + 1, dtype=jnp.int32)[None, :])
        tv = self._obs.phase_begin("verify")
        counts, out_toks, new_caches = self._verify_fn(
            m.frozen, self._adapters_no_prefix(), m.quant_state, caches,
            chunk, vpos, jnp.transpose(d_toks),
            jnp.transpose(d_logits, (1, 0, 2)), temps, top_ks, top_ps,
            jnp.stack([jnp.stack(row) for row in seq_keys]),
            jnp.asarray(np.asarray(live)))
        self._pool.update_from(new_caches)
        counts, out_toks = np.asarray(counts), np.asarray(out_toks)
        self._obs.phase_end("verify", tv, hist="spec_verify_s")
        self.stats.decode_time_s += self._obs.phase_end(
            "decode", t0, hist="decode_dispatch_s")
        rows = counts[decoding]
        self.stats.decode_steps += int(rows.max())
        self.stats.decode_dispatches += 2
        self.stats.busy_slot_steps += int(rows.sum())
        self.stats.decode_pad_tokens += \
            (k + 1) * (self.max_slots - len(decoding))
        self.stats.draft_tokens += k * len(decoding)
        self.stats.accepted_tokens += int((rows - 1).sum())

        for i in decoding:
            st = self._slots[i]
            c = int(counts[i])
            # verification is blind to EOS/budget, so clamp the cursor to
            # the row's budget (its _ready_paged-ensured window); the emit
            # walk retires the row at EOS or budget and stops emitting —
            # over-committed trailing tokens die with the slot.
            self._pool.advance(
                i, min(c, st.req.max_new_tokens - st.n_generated))
            for j in range(c):
                self._emit_token(st, i, int(out_toks[i, j]))
                if self._slots[i] is not st:
                    break

    def _snapshot_pool_stats(self):
        st, pool = self.stats, self._paged
        st.blocks_in_use = pool.alloc.n_used
        st.peak_blocks_in_use = pool.peak_blocks_in_use
        st.fragmentation = pool.fragmentation()
        st.kv_bytes_in_use = pool.bytes_in_use()
        st.block_grows = pool.n_grows
        if pool.radix is not None:
            st.prefix_queries = pool.prefix_queries
            st.prefix_hits = pool.prefix_hits
            st.shared_blocks = pool.alloc.n_shared
            st.prefix_tokens_saved = pool.prefix_tokens_saved
            st.cow_copies = pool.cow_copies
            st.radix_blocks = pool.radix.n_blocks
            st.radix_evictions = pool.radix_evictions

    def reset_prefix_cache(self):
        """Flush the radix index and release its pinned blocks. Call after
        swapping / further fine-tuning the served adapters: cached KV was
        computed under the OLD weights and must not be mapped into new
        requests. No-op without ``prefix_share``."""
        if self._paged is not None:
            self._paged.drop_radix()
            self._snapshot_pool_stats()
