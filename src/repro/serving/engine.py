"""Continuous-batching serving engine.

ONE compiled decode step (``train.steps.build_decode_slots``) serves a
continuously changing request mix over a fixed-capacity slot pool:

  * admission — a waiting request is prefilled into any free slot
    (``build_prefill_slot`` + ``pool.write_slot``) between decode steps,
    while other slots are mid-generation;
  * decode — every live slot advances one token per step, each writing at
    its own cursor and masked by its own length;
  * retirement — a slot frees on EOS or token budget, with no barrier on
    the rest of the batch (the lockstep loop this replaces made the whole
    batch wait for its slowest request).

The engine holds no model state of its own: it reads ``cfg`` / ``frozen`` /
``adapters`` / ``quant_state`` off the wrapped model object (duck-typed —
``repro.api.QuaffModel`` in practice) at every call, so serving a model that
is later fine-tuned further picks up the new adapters automatically.
"""
from __future__ import annotations

import collections
import itertools
import time
from typing import Dict, Iterable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import peft as PEFT
from repro.models import model as M
from repro.models.config import ServingConfig
from repro.serving import sampling
from repro.serving.params import (EngineStats, GenerationRequest,
                                  RequestOutput, SamplingParams)
from repro.serving.pool import SlotPool
from repro.train import steps as S


class _SlotState:
    """Host-side bookkeeping for one occupied slot."""

    __slots__ = ("req", "request_id", "token_ids", "prompt_len", "last_token")

    def __init__(self, req: GenerationRequest, request_id: str, prompt_len: int):
        self.req = req
        self.request_id = request_id
        self.token_ids: List[int] = []
        self.prompt_len = prompt_len
        self.last_token = 0

    @property
    def n_generated(self) -> int:
        return len(self.token_ids)


class Engine:
    """Slot-pooled continuous-batching engine over a facade model.

        engine = Engine(model, max_slots=4, max_seq_len=128)
        outs = engine.run([GenerationRequest(prompt, max_new_tokens=16),
                           GenerationRequest(prompt2, max_new_tokens=64,
                                             sampling=SamplingParams(
                                                 temperature=0.8, top_k=50,
                                                 seed=7))])

    ``submit``/``step`` expose the loop for callers that interleave their own
    work (the serve launcher); ``run`` drains to completion. Per-token
    streaming: set ``GenerationRequest.on_token``.
    """

    @classmethod
    def from_config(cls, model, serving: ServingConfig) -> "Engine":
        """Build from a ``models.config.ServingConfig``."""
        return cls(model, max_slots=serving.max_slots,
                   max_seq_len=serving.max_seq_len)

    def __init__(self, model, max_slots: int = 4, max_seq_len: int = 256):
        cfg = model.cfg
        if not M.supports_slot_decode(cfg):
            raise NotImplementedError(
                f"Engine needs a KV-cache family (dense/moe); "
                f"family={cfg.family!r} is not slot-poolable yet")
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_seq_len = max_seq_len
        self._model = model
        self._pool = SlotPool(cfg, max_slots, max_seq_len)
        self._decode_fn = jax.jit(S.build_decode_slots(cfg))
        # one jitted prefill; jit re-specializes per prompt-length shape
        self._prefill_fn = jax.jit(S.build_prefill_slot(cfg, max_seq_len))
        self._sample = sampling.make_sampler()
        self._n_prefix = PEFT.n_prefix_tokens(cfg.peft)
        self._waiting: collections.deque = collections.deque()
        self._slots: List[Optional[_SlotState]] = [None] * max_slots
        self._finished: Dict[str, RequestOutput] = {}
        self._pending: List[str] = []               # submitted, not returned
        self._auto_id = itertools.count()
        self.stats = EngineStats(n_slots=max_slots)

    # ------------------------------------------------------------------
    # submission
    # ------------------------------------------------------------------
    def submit(self, req: GenerationRequest) -> str:
        """Validate + enqueue; returns the request id. Admission happens on
        the next ``step``/``run`` — possibly mid-decode of other requests."""
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if req.max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got "
                             f"{req.max_new_tokens}")
        need = prompt.size + self._n_prefix + req.max_new_tokens
        if need > self.max_seq_len:
            raise ValueError(
                f"request needs {need} cache positions (prompt {prompt.size} "
                f"+ prefix {self._n_prefix} + max_new {req.max_new_tokens}) "
                f"but the pool is sized max_seq_len={self.max_seq_len}")
        rid = req.request_id or f"req-{next(self._auto_id)}"
        if rid in self._finished or any(
                r is not None and r[0] == rid for r in self._waiting) or any(
                s is not None and s.request_id == rid for s in self._slots):
            raise ValueError(f"duplicate request_id {rid!r}")
        self._waiting.append((rid, req, prompt))
        self._pending.append(rid)
        self.stats.requests_submitted += 1
        return rid

    # ------------------------------------------------------------------
    # engine loop
    # ------------------------------------------------------------------
    @property
    def has_work(self) -> bool:
        return bool(self._waiting) or self._pool.n_active > 0

    def step(self) -> bool:
        """One engine iteration: admit into free slots, then one batched
        decode step. Returns ``has_work``."""
        while self._waiting and self._pool.n_free:
            self._admit_one()
        if self._pool.n_active:
            self._decode_once()
        return self.has_work

    def run(self, requests: Iterable[GenerationRequest] = ()
            ) -> List[RequestOutput]:
        """Submit ``requests``, drain until idle, and return outputs for all
        not-yet-returned requests in submission order. Returned outputs are
        released from the engine (a long-lived engine holds no per-request
        state once its outputs are handed out)."""
        for req in requests:
            self.submit(req)
        while self.has_work:
            self.step()
        out = [self._finished.pop(rid) for rid in self._pending]
        self._pending = []
        return out

    def output(self, request_id: str, pop: bool = True
               ) -> Optional[RequestOutput]:
        """Fetch a completed request's output (step-driven callers).
        ``pop=True`` (default) releases it from the engine so completed
        requests do not accumulate over a long-lived engine's lifetime."""
        if pop:
            out = self._finished.pop(request_id, None)
            if out is not None and request_id in self._pending:
                self._pending.remove(request_id)
            return out
        return self._finished.get(request_id)

    # ------------------------------------------------------------------
    # internals
    # ------------------------------------------------------------------
    def _sample_one(self, logits_row, sp: SamplingParams, token_index: int):
        tok = self._sample(
            logits_row,
            jnp.asarray([sp.temperature], jnp.float32),
            jnp.asarray([sp.top_k], jnp.int32),
            jnp.asarray([sp.top_p], jnp.float32),
            sampling.request_key(sp, token_index)[None],
        )
        return int(tok[0])

    def _admit_one(self):
        rid, req, prompt = self._waiting.popleft()
        slot = self._pool.acquire()
        m = self._model
        t0 = time.perf_counter()
        logits, row_caches = self._prefill_fn(
            m.frozen, m.adapters, m.quant_state, jnp.asarray(prompt[None, :]))
        self._pool.admit(row_caches, slot)
        tok = self._sample_one(logits, req.sampling, 0)
        self.stats.prefill_time_s += time.perf_counter() - t0
        self.stats.prefills += 1

        st = _SlotState(req, rid, prompt.size)
        self._slots[slot] = st
        self._emit_token(st, slot, tok)

    def _decode_once(self):
        m = self._model
        b = self.max_slots
        tokens = np.zeros((b, 1), np.int32)
        positions = np.zeros((b,), np.int32)
        temps = np.zeros((b,), np.float32)
        top_ks = np.zeros((b,), np.int32)
        top_ps = np.ones((b,), np.float32)
        keys = [None] * b
        active = []
        for i, st in enumerate(self._slots):
            if st is None:
                keys[i] = jax.random.PRNGKey(0)
                continue
            active.append(i)
            sp = st.req.sampling
            tokens[i, 0] = st.last_token
            # the fed-back token is generated token #n_generated (1-based):
            # its RoPE position is prompt_len + n_generated - 1, matching the
            # lockstep generate loop's ``prompt_len + i``
            positions[i] = st.prompt_len + st.n_generated - 1
            temps[i] = sp.temperature
            top_ks[i] = sp.top_k
            top_ps[i] = sp.top_p
            keys[i] = sampling.request_key(sp, st.n_generated)

        t0 = time.perf_counter()
        logits, self._pool.caches = self._decode_fn(
            m.frozen, m.adapters, m.quant_state, self._pool.caches,
            jnp.asarray(tokens), jnp.asarray(positions))
        toks = np.asarray(self._sample(
            logits, jnp.asarray(temps), jnp.asarray(top_ks),
            jnp.asarray(top_ps), jnp.stack(keys)))
        self.stats.decode_time_s += time.perf_counter() - t0
        self.stats.decode_steps += 1
        self.stats.busy_slot_steps += len(active)

        for i in active:
            self._emit_token(self._slots[i], i, int(toks[i]))

    def _emit_token(self, st: _SlotState, slot: int, tok: int):
        st.token_ids.append(tok)
        st.last_token = tok
        self.stats.tokens_generated += 1
        if st.req.on_token is not None:
            st.req.on_token(st.request_id, tok)
        hit_eos = st.req.eos_id is not None and tok == st.req.eos_id
        if hit_eos or st.n_generated >= st.req.max_new_tokens:
            self._retire(st, slot, "eos" if hit_eos else "length")

    def _retire(self, st: _SlotState, slot: int, reason: str):
        self._finished[st.request_id] = RequestOutput(
            request_id=st.request_id, prompt_len=st.prompt_len,
            token_ids=st.token_ids, finish_reason=reason)
        self._slots[slot] = None
        self._pool.release(slot)
        self.stats.requests_completed += 1
