"""Hash-keyed radix index over full KV blocks (prefix sharing).

Serving millions of users means millions of requests opening with the
same system prompt / few-shot prefix. The paged cache already describes a
request as a list of fixed-size blocks, and (for ``kv_dtype="int8"``)
OSSH-static key-channel scales make the quantized blocks bitwise
request-independent — so a block whose ``block_size`` positions hold a
known token chunk can be mapped read-only into ANY later request whose
stream opens with the same chunks. This module is the host-side lookup
structure for that reuse:

  * a node per FULL block, keyed by the hash chain of its token chunk and
    every chunk before it — a radix tree flattened into a dict, where the
    chain key encodes the whole path so lookup is one dict probe per
    block;
  * the chain is rooted in a ``scope`` string (kv_dtype + model
    fingerprint), so an fp pool and an int8 pool — or two different
    models — can never cross-share a block id;
  * the index OWNS one reference per indexed block (``BlockAllocator.
    fork``): a block stays resident after its writing request retires,
    which is the whole point — and is unevictable from the pool while any
    table still maps it;
  * leaves evict LRU-first: under ``capacity`` pressure at insert time,
    or on demand (``evict``) when the block pool itself runs dry.

Partial blocks are never indexed: a request's tail block keeps being
written by decode, while an indexed block must be immutable. The pool
enforces that side with COW (``PagedPool.prepare_write``).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Sequence, Tuple


def _chain_key(parent_key: str, chunk: Sequence[int]) -> str:
    h = hashlib.sha1(parent_key.encode("utf-8"))
    h.update(b"|")
    h.update(",".join(str(int(t)) for t in chunk).encode("utf-8"))
    return h.hexdigest()


@dataclasses.dataclass
class _Node:
    key: str
    parent_key: str
    block: int
    tick: int               # last match/insert touch (LRU eviction order)
    n_children: int = 0


class RadixIndex:
    """Longest-indexed-prefix lookup over token streams, block-granular.

    The caller (``PagedPool``) owns all refcount bookkeeping: ``insert``
    reports which blocks the index newly took over (fork these), and
    ``evict``/``drop_all`` report which blocks it let go (release these).
    The index itself never touches the allocator or device pools.
    """

    def __init__(self, block_size: int, scope: str = "",
                 capacity: int = 0):
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        if capacity < 0:
            raise ValueError(f"capacity must be >= 0, got {capacity}")
        self.block_size = block_size
        self.scope = scope
        self.capacity = capacity
        self._root = hashlib.sha1(
            ("radix:" + scope).encode("utf-8")).hexdigest()
        self._nodes: Dict[str, _Node] = {}
        self._tick = 0

    # ---- introspection ---------------------------------------------------
    @property
    def n_blocks(self) -> int:
        return len(self._nodes)

    def blocks(self) -> List[int]:
        return [n.block for n in self._nodes.values()]

    # ---- lookup ----------------------------------------------------------
    def match(self, tokens: Sequence[int]) -> List[int]:
        """Block ids of the longest indexed prefix of ``tokens`` (full
        chunks only). Touches the matched path for LRU."""
        self._tick += 1
        blocks: List[int] = []
        key = self._root
        bs = self.block_size
        for i in range(len(tokens) // bs):
            key = _chain_key(key, tokens[i * bs:(i + 1) * bs])
            node = self._nodes.get(key)
            if node is None:
                break
            node.tick = self._tick
            blocks.append(node.block)
        return blocks

    # ---- insertion -------------------------------------------------------
    def insert(self, tokens: Sequence[int], blocks: Sequence[int]
               ) -> Tuple[List[int], List[int]]:
        """Index ``blocks[i]`` as holding chunk ``i`` of ``tokens`` (only
        ``len(blocks)`` full chunks are considered; ``tokens`` may run
        longer). Chunks already indexed keep their existing block — the
        caller's duplicate stays private to its request.

        Returns ``(newly_owned, evicted)``: blocks the index just took a
        mapping on (caller must ``fork``) and blocks it dropped to honor
        ``capacity`` (caller must ``release``). Fork before releasing, so
        a block both inserted and immediately evicted stays refcount-
        consistent."""
        self._tick += 1
        new_owned: List[int] = []
        key = self._root
        bs = self.block_size
        n = min(len(blocks), len(tokens) // bs)
        for i in range(n):
            child = _chain_key(key, tokens[i * bs:(i + 1) * bs])
            node = self._nodes.get(child)
            if node is None:
                node = _Node(child, key, int(blocks[i]), self._tick)
                self._nodes[child] = node
                parent = self._nodes.get(key)
                if parent is not None:
                    parent.n_children += 1
                new_owned.append(node.block)
            else:
                node.tick = self._tick
            key = child
        evicted = []
        if self.capacity:
            evicted = self.evict(len(self._nodes) - self.capacity)
        return new_owned, evicted

    # ---- eviction --------------------------------------------------------
    def _pop(self, node: _Node) -> int:
        del self._nodes[node.key]
        parent = self._nodes.get(node.parent_key)
        if parent is not None:
            parent.n_children -= 1
        return node.block

    def evict(self, n: int) -> List[int]:
        """Drop up to ``n`` leaves, least-recently-touched first (an inner
        node becomes evictable once its children go). Returns the dropped
        block ids for the caller to release."""
        out: List[int] = []
        while len(out) < n:
            leaves = [nd for nd in self._nodes.values()
                      if nd.n_children == 0]
            if not leaves:
                break
            victim = min(leaves, key=lambda nd: (nd.tick, nd.key))
            out.append(self._pop(victim))
        return out

    def drop_all(self) -> List[int]:
        """Clear the index (e.g. after the served adapters change — the
        cached KV no longer matches the model). Returns every owned block
        id for the caller to release."""
        out = self.blocks()
        self._nodes.clear()
        return out
