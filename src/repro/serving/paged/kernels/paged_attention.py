"""Pallas TPU decode attention over the paged KV pool: K/V are GATHERED
through the per-request block table and dequantized in registers — the
packed int8 pool is the only thing that ever leaves HBM, so decode's KV
traffic drops ~4x vs an fp32 contiguous row on top of the paging win.

How the gather works: the grid is (request, kv_head, page) and the K/V/
v-scale BlockSpec index maps read the scalar-prefetched block table —
``lambda b, h, p, bt, cl: (bt[b, p], 0, h, 0)`` — so the DMA engine walks
each request's (possibly non-contiguous) block list directly; the kernel
body never sees a block id. Pages run innermost and sequential, carrying a
flash-style online softmax (running max / normalizer / accumulator in VMEM
scratch); positions at or past the request's context length — including
every slot of a trash page — are masked before the max.

All operands inside the body are 2D (g x hd queries, block_size x hd keys)
so the dots lower cleanly to the MXU; dequant is one VPU multiply by the
(1, hd) static key-scale row / (block_size, 1) per-token value-scale
column, with fp passthrough just feeding ones.

Wrappers follow the int4 kernels' CPU story: ``interpret=`` plus the
``REPRO_PALLAS_INTERPRET=1`` override (kernels.common.interpret_mode), and
``paged_attention_auto`` interprets off-TPU. ``paged_attention_ref`` is the
plain-jnp oracle the tests compare against — the same gather/dequant math
``models.layers.attention``'s paged branch inlines.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import interpret_mode

NEG_INF = -1e30


def _kernel(bt_ref, cl_ref, q_ref, k_ref, v_ref, ksc_ref, vsc_ref, o_ref,
            m_ref, l_ref, acc_ref, *, pages: int, block_size: int):
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                     # (g, hd)
    k = k_ref[0, :, 0].astype(jnp.float32) * ksc_ref[...]   # (bs, hd) dequant
    v = v_ref[0, :, 0].astype(jnp.float32) * vsc_ref[0]     # (bs, hd)

    s = q @ k.T / math.sqrt(q.shape[-1])                    # (g, bs)
    pos = p * block_size + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)
    s = jnp.where(pos < cl_ref[b], s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    probs = jnp.exp(s - m_cur)
    alpha = jnp.exp(m_prev - m_cur)
    l_ref[...] = l_prev * alpha + jnp.sum(probs, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + probs @ v
    m_ref[...] = m_cur

    @pl.when(p == pages - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-20)
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(
    q: jnp.ndarray,             # (B, kv_heads, group, head_dim) f32
    k_pool: jnp.ndarray,        # (n_pool, block_size, kv_heads, hd) int8|fp
    v_pool: jnp.ndarray,
    block_tables: jnp.ndarray,  # (B, pages) int32 into the pool's row axis
    context_lens: jnp.ndarray,  # (B,) int32 valid KV positions per request
    k_scale: jnp.ndarray,       # (kv_heads, hd) f32 — ones for fp pools
    v_scale: jnp.ndarray,       # (n_pool, block_size, kv_heads) f32
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    """One decode step of block-table attention -> (B, kv_heads, group, hd)
    f32. Free rows (context_len 0) produce finite don't-care output."""
    interpret = interpret_mode(interpret)
    bsz, kh, g, hd = q.shape
    bs = k_pool.shape[1]
    pages = block_tables.shape[1]
    grid = (bsz, kh, pages)
    spec_kv = pl.BlockSpec((1, bs, 1, hd),
                           lambda b, h, p, bt, cl: (bt[b, p], 0, h, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,              # block_tables, context_lens
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda b, h, p, bt, cl: (b, h, 0, 0)),
            spec_kv,                                                    # k
            spec_kv,                                                    # v
            pl.BlockSpec((1, hd), lambda b, h, p, bt, cl: (h, 0)),      # Dk
            pl.BlockSpec((1, bs, 1),
                         lambda b, h, p, bt, cl: (bt[b, p], 0, h)),     # Dv
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda b, h, p, bt, cl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),        # running max
            pltpu.VMEM((g, 1), jnp.float32),        # normalizer
            pltpu.VMEM((g, hd), jnp.float32),       # output accumulator
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, pages=pages, block_size=bs),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, kh, g, hd), jnp.float32),
        interpret=interpret,
    )(block_tables, context_lens, q, k_pool, v_pool, k_scale, v_scale)


def paged_attention_ref(q, k_pool, v_pool, block_tables, context_lens,
                        k_scale=None, v_scale=None) -> jnp.ndarray:
    """Plain-jnp oracle: gather pages, dequantize, full masked softmax."""
    bsz, kh, g, hd = q.shape
    bs = k_pool.shape[1]
    t = block_tables.shape[1] * bs
    k = k_pool[block_tables].astype(jnp.float32)    # (B, P, bs, kh, hd)
    v = v_pool[block_tables].astype(jnp.float32)
    if k_scale is not None:
        k = k * k_scale
    if v_scale is not None:
        v = v * v_scale[block_tables][..., None]
    k = k.reshape(bsz, t, kh, hd)
    v = v.reshape(bsz, t, kh, hd)
    s = jnp.einsum("bkgh,btkh->bkgt", q.astype(jnp.float32), k)
    s = s / math.sqrt(hd)
    valid = jnp.arange(t)[None, :] < context_lens[:, None]  # (B, T)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    probs = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgt,btkh->bkgh", probs, v)


def paged_attention_auto(q, k_pool, v_pool, block_tables, context_lens,
                         k_scale=None, v_scale=None) -> jnp.ndarray:
    """Entry point for the decode hot path (models.layers routes here under
    ``REPRO_PAGED_PALLAS=1``): compiled on TPU, interpret elsewhere. Fills
    unit scales for fp pools so the kernel signature stays uniform."""
    kh, hd = q.shape[1], q.shape[3]
    if k_scale is None:
        k_scale = jnp.ones((kh, hd), jnp.float32)
    if v_scale is None:
        v_scale = jnp.ones(k_pool.shape[:3], jnp.float32)
    interpret = jax.default_backend() != "tpu"
    return paged_attention(q, k_pool, v_pool, block_tables, context_lens,
                           k_scale, v_scale, interpret=interpret)
