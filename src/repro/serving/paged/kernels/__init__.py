"""Pallas kernels owned by the paged-KV subsystem."""
from repro.serving.paged.kernels.paged_attention import (
    paged_attention, paged_attention_auto, paged_attention_ref)

__all__ = ["paged_attention", "paged_attention_auto", "paged_attention_ref"]
