"""Fixed-size KV blocks: free-list allocator + per-request block tables.

A "block" is ``block_size`` cache positions across ALL layers (one block id
indexes every layer's pool at once), so a request's whole KV footprint is
described by one table of block ids. Block id 0 is reserved as the trash
page: free/mid-admission slot rows point every table entry at it, so the
batched decode step can scatter its don't-care K/V without corrupting live
requests.

Everything here is host-side bookkeeping — device pools live in
``kvquant.init_paged_pools`` and are written through the block table by the
paged branch of ``models.layers.attention``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

TRASH_BLOCK = 0   # block id 0 is never allocated; free rows write/read it


class BlockAllocator:
    """Refcounted free-list over block ids ``1..n_blocks`` (0 is trash).

    ``acquire(n)`` hands out ``n`` ids (each at refcount 1) or ``None``
    when the pool cannot satisfy the request right now — the engine turns
    that into admission deferral, never a crash. ``fork(blocks)`` takes an
    extra reference on already-allocated ids (copy-on-write prefix
    sharing: a block mapped into several block tables — or pinned by the
    radix index — carries one reference per mapping). ``release``
    decrements; an id returns to the free list only when its LAST
    reference drops, so a shared block can never be freed while any table
    or index still maps it. Freed ids are reused lowest-id-first (keeps
    tables dense and tests deterministic).
    """

    def __init__(self, n_blocks: int, block_size: int):
        if n_blocks < 1:
            raise ValueError(f"n_blocks must be >= 1, got {n_blocks}")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.n_blocks = n_blocks
        self.block_size = block_size
        self._free: List[int] = list(range(1, n_blocks + 1))
        self._ref: Dict[int, int] = {}      # allocated id -> refcount

    # ---- sizing ----------------------------------------------------------
    def blocks_for(self, n_tokens: int) -> int:
        """ceil(n_tokens / block_size) — the footprint of one request."""
        return -(-max(n_tokens, 0) // self.block_size)

    # ---- free-list -------------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return self.n_blocks - len(self._free)

    @property
    def n_shared(self) -> int:
        """Blocks currently mapped more than once (refcount > 1)."""
        return sum(1 for r in self._ref.values() if r > 1)

    def can_acquire(self, n: int) -> bool:
        return n <= len(self._free)

    def acquire(self, n: int) -> Optional[List[int]]:
        if n > len(self._free):
            return None
        out, self._free = self._free[:n], self._free[n:]
        for b in out:
            self._ref[b] = 1
        return out

    def refcount(self, block: int) -> int:
        """Current reference count (0 = free/never allocated)."""
        return self._ref.get(block, 0)

    def fork(self, blocks: List[int]):
        """Take one extra reference on each of ``blocks`` (all must be
        allocated): the COW half of prefix sharing — a forked block is
        read-only until its refcount drops back to 1."""
        for b in blocks:
            if self._ref.get(b, 0) < 1:
                raise ValueError(f"cannot fork unallocated block {b}")
        for b in blocks:
            self._ref[b] += 1

    def release(self, blocks: List[int]):
        for b in blocks:
            if not 1 <= b <= self.n_blocks:
                raise ValueError(f"block id {b} outside pool 1..{self.n_blocks}")
            if self._ref.get(b, 0) < 1:
                raise ValueError(f"block {b} is already free")
        freed = []
        for b in blocks:
            self._ref[b] -= 1
            if self._ref[b] == 0:
                del self._ref[b]
                freed.append(b)
        self._free.extend(freed)
        self._free.sort()

    # ---- occupancy -------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        return {"n_blocks": self.n_blocks, "block_size": self.block_size,
                "blocks_in_use": self.n_used, "blocks_free": self.n_free,
                "shared_blocks": self.n_shared,
                "utilization": self.n_used / self.n_blocks}


@dataclasses.dataclass
class BlockTable:
    """One request's view of the pool: its block ids in sequence order plus
    the number of cache positions actually written so far (for internal-
    fragmentation accounting: the tail of the last block is allocated but
    unused until decode fills it)."""

    blocks: List[int]
    block_size: int
    n_tokens: int = 0           # cache positions written so far

    @property
    def capacity(self) -> int:
        return len(self.blocks) * self.block_size

    @property
    def waste(self) -> int:
        """Allocated-but-unwritten positions (internal fragmentation)."""
        return self.capacity - self.n_tokens

    def as_row(self, max_pages: int) -> np.ndarray:
        """(max_pages,) int32 row for the device-side table; entries past
        this request's footprint point at the trash page."""
        row = np.full((max_pages,), TRASH_BLOCK, np.int32)
        row[:len(self.blocks)] = self.blocks
        return row
