"""repro.serving.paged — block-pool KV cache with optional int8 storage.

    BlockAllocator / BlockTable   free-list blocks + per-request tables
    init_paged_pools              device pools (int8 w/ scales, or fp)
    kv_bytes_per_token            telemetry unit for paged-vs-contiguous
    kernels.paged_attention       Pallas block-table decode attention

The engine-facing pool object (``PagedPool``) lives in
``repro.serving.pool`` next to its contiguous sibling.
"""
from repro.serving.paged.blocks import TRASH_BLOCK, BlockAllocator, BlockTable
from repro.serving.paged.kvquant import (init_paged_pools, k_scales_from_stats,
                                         kv_bytes_per_token)

__all__ = ["BlockAllocator", "BlockTable", "TRASH_BLOCK", "init_paged_pools",
           "kv_bytes_per_token", "k_scales_from_stats"]
