"""Quantized (or fp-passthrough) storage for the paged KV pool.

INT8 layout (KIVI-style, justified by the paper's OSSH):

  * K — per-CHANNEL scales, one per (kv_head, head_dim) channel, held
    STATIC for the pool's lifetime. Key outliers live in fixed channels
    (the same spatial stability Quaff exploits for activations), so a
    static per-channel grid absorbs them without per-token rescaling —
    and a static grid is what makes in-kernel dequant free: the scale row
    rides next to the block in VMEM. Scales are seeded from the Quaff
    calibration capture (``StatsScope`` absmax of the rotated K, rides in
    ``model.stats``) or, absent calibration, probed from the first
    admitted prompt's fp prefill.
  * V — per-TOKEN scales (one per (position, kv_head)), computed at write
    time from the token itself and stored alongside the pool; no seeding
    needed, and value outliers (which are token-local, not channel-local)
    are captured exactly.

``kv_dtype="fp"`` skips all of it: pools are activation-dtype and the
scale leaves are absent, which statically routes ``models.layers`` and the
Pallas kernel onto the passthrough path.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig

INT8_MAX = 127.0
KV_DTYPES = ("fp", "int8")


def check_kv_dtype(kv_dtype: str) -> str:
    if kv_dtype not in KV_DTYPES:
        raise ValueError(f"kv_dtype must be one of {KV_DTYPES}, "
                         f"got {kv_dtype!r}")
    return kv_dtype


# ---------------------------------------------------------------------------
# Pool construction
# ---------------------------------------------------------------------------
def init_paged_pools(cfg: ModelConfig, n_blocks: int, block_size: int,
                     kv_dtype: str) -> Dict[str, jnp.ndarray]:
    """Device arrays of the paged cache, stacked over layers. Row 0 of every
    pool is the trash page (blocks.TRASH_BLOCK) — allocatable ids are
    1..n_blocks, so pools carry ``n_blocks + 1`` rows.

    int8: k_scale (L, kv_heads, head_dim) starts at 1.0 (placeholder until
    seeded); v_scale (L, n_blocks+1, block_size, kv_heads) is written
    per-token next to the values."""
    check_kv_dtype(kv_dtype)
    kh, hd, nl = cfg.n_kv_heads, cfg.head_dim, cfg.n_layers
    shape = (nl, n_blocks + 1, block_size, kh, hd)
    if kv_dtype == "int8":
        return {
            "k_pool": jnp.zeros(shape, jnp.int8),
            "v_pool": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.ones((nl, kh, hd), jnp.float32),
            "v_scale": jnp.ones(shape[:-1], jnp.float32),
        }
    act = jnp.dtype(cfg.act_dtype)
    return {"k_pool": jnp.zeros(shape, act), "v_pool": jnp.zeros(shape, act)}


def kv_bytes_per_token(cfg: ModelConfig, kv_dtype: str) -> int:
    """KV bytes one cache position costs across all layers (k + v + any
    per-token scale rows) — the unit of the paged-vs-contiguous telemetry."""
    kh, hd, nl = cfg.n_kv_heads, cfg.head_dim, cfg.n_layers
    if kv_dtype == "int8":
        return nl * (2 * kh * hd * 1 + kh * 4)      # int8 k+v, f32 v scale
    return nl * 2 * kh * hd * jnp.dtype(cfg.act_dtype).itemsize


# ---------------------------------------------------------------------------
# Quantize / dequantize (shared by models.layers and the Pallas kernel ref)
# ---------------------------------------------------------------------------
def quantize_k(k: jnp.ndarray, k_scale: jnp.ndarray) -> jnp.ndarray:
    """k (..., kv_heads, head_dim) f32 -> int8 under the static per-channel
    grid; values past the seeded absmax clip (OSSH: rare by construction)."""
    q = jnp.round(k.astype(jnp.float32) / k_scale)
    return jnp.clip(q, -INT8_MAX, INT8_MAX).astype(jnp.int8)


def quantize_v(v: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """v (..., kv_heads, head_dim) -> (int8 values, (..., kv_heads) f32
    per-token scales)."""
    absmax = jnp.max(jnp.abs(v.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(absmax, 1e-8) / INT8_MAX
    q = jnp.round(v.astype(jnp.float32) / scale[..., None])
    return jnp.clip(q, -INT8_MAX, INT8_MAX).astype(jnp.int8), scale


def dequant_k(qk: jnp.ndarray, k_scale: jnp.ndarray) -> jnp.ndarray:
    return qk.astype(jnp.float32) * k_scale


def dequant_v(qv: jnp.ndarray, v_scale: jnp.ndarray) -> jnp.ndarray:
    return qv.astype(jnp.float32) * v_scale[..., None]


# ---------------------------------------------------------------------------
# Key-channel scale seeding
# ---------------------------------------------------------------------------
def k_scales_from_stats(stats: Any, cfg: ModelConfig
                        ) -> Optional[jnp.ndarray]:
    """(L, kv_heads, head_dim) scales from the Quaff calibration artifacts
    (``QuaffModel.stats``): the ``StatsScope`` capture pass records the
    rotated K's per-channel absmax next to the per-linear input absmax the
    outlier criterion uses, so the KV grid is pinned by the SAME calibration
    set that fixes the outlier channels. Returns None when the capture
    predates the kv entry (or no calibration ran)."""
    if stats is None:
        return None
    absmax_tree = stats[0] if isinstance(stats, tuple) else stats
    try:
        k_absmax = absmax_tree["attn"]["kv"]["k"]
    except (KeyError, TypeError, IndexError):
        return None
    k_absmax = np.asarray(k_absmax, np.float32)
    if k_absmax.shape != (cfg.n_layers, cfg.n_kv_heads, cfg.head_dim):
        return None
    return jnp.asarray(np.maximum(k_absmax, 1e-8) / INT8_MAX)


def k_scales_from_row_caches(row_caches: Dict[str, jnp.ndarray]
                             ) -> jnp.ndarray:
    """Probe fallback: per-channel absmax of a contiguous fp prefill's K
    buffers ((L, 1, T, kh, hd), zero-padded past the prompt — zeros never
    win an absmax). OSSH makes one prompt a usable seed: the hot channels
    it exposes are the hot channels every later token hits."""
    k = np.asarray(row_caches["k"], np.float32)
    absmax = np.max(np.abs(k), axis=(1, 2))                 # (L, kh, hd)
    return jnp.asarray(np.maximum(absmax, 1e-8) / INT8_MAX)
