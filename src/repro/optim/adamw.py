"""AdamW (from scratch — only adapter params are optimized, so state is tiny
even for the 1T MoE) plus INT8 gradient compression with error feedback.

Two compression paths:
  * ``ef_compress`` — quantize->dequantize the accumulated gradient with a
    persistent error-feedback buffer (numerical path, works under pjit).
  * ``compressed_psum`` (optim/compress.py) — true INT8 all-reduce over the
    data axes via shard_map (collective-bytes reduction, used by the DP-only
    launcher path and tests/test_grad_compression.py).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import quant


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any
    err: Optional[Any] = None  # error-feedback buffers (grad compression)


def init(params, use_error_feedback: bool = False) -> AdamWState:
    zeros = lambda: jax.tree.map(jnp.zeros_like, params)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=zeros(),
        v=zeros(),
        err=zeros() if use_error_feedback else None,
    )


def ef_compress(grads, err, bits: int = 8):
    """Quantize grads (per-tensor INT8) with error feedback:
        g_hat = Q(g + err);  err' = (g + err) - g_hat.
    Returns (g_hat, err'). Unbiased in the EF limit (residual never lost)."""
    def one(g, e):
        tot = g + e
        g_int, delta = quant.quantize(tot, axis=None, bits=bits)
        g_hat = quant.dequantize(g_int, delta, g.dtype)
        return g_hat, tot - g_hat
    flat = jax.tree.map(one, grads, err)
    g_hat = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    return g_hat, new_err


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gnorm


def update(
    grads,
    state: AdamWState,
    params,
    *,
    lr: float,
    beta1: float = 0.9,
    beta2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    grad_clip: float = 0.0,
    compress: bool = False,
):
    """-> (new_params, new_state, metrics)."""
    gnorm = jnp.zeros((), jnp.float32)
    if grad_clip:
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
    err = state.err
    if compress and err is not None:
        grads, err = ef_compress(grads, err)

    step = state.step + 1
    b1c = 1.0 - beta1 ** step.astype(jnp.float32)
    b2c = 1.0 - beta2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: beta1 * m + (1 - beta1) * g, state.m, grads)
    new_v = jax.tree.map(lambda v, g: beta2 * v + (1 - beta2) * jnp.square(g),
                         state.v, grads)

    def upd(p, m, v):
        mh = m / b1c
        vh = v / b2c
        return (p - lr * (mh / (jnp.sqrt(vh) + eps) + weight_decay * p)).astype(
            p.dtype)

    new_params = jax.tree.map(upd, params, new_m, new_v)
    return new_params, AdamWState(step, new_m, new_v, err), {"grad_norm": gnorm}
