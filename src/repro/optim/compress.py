"""True INT8 gradient all-reduce over data axes with a shared scale: each
device quantizes its local gradient against the global absmax (one scalar
pmax), then psums the INT8 payload (cast int32 for accumulation) — ~4x fewer
bytes on the wire than an fp32 ring all-reduce.

These helpers are meant to be called INSIDE a shard_map region (they use
named-axis collectives). The DP-only fine-tuning path (repro/launch/train.py)
wraps its per-device grad computation in shard_map and reduces with
``compressed_psum_tree``; tests/test_grad_compression.py verifies the mean
against an exact fp32 psum.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp


def compressed_psum_leaf(g: jnp.ndarray, axis_names: Sequence[str],
                         bits: int = 8) -> jnp.ndarray:
    """Mean of ``g`` across ``axis_names`` with an INT8 payload."""
    qmax = float(2 ** (bits - 1) - 1)
    axis_names = tuple(axis_names)
    local_max = jnp.max(jnp.abs(g.astype(jnp.float32)))
    global_max = jax.lax.pmax(local_max, axis_names)   # scalar collective
    delta = jnp.maximum(global_max, 1e-8) / qmax
    g_int = jnp.clip(jnp.round(g.astype(jnp.float32) / delta), -qmax, qmax
                     ).astype(jnp.int32)
    g_sum = jax.lax.psum(g_int, axis_names)
    n = 1
    for name in axis_names:
        n *= jax.lax.axis_size(name)
    return (g_sum.astype(jnp.float32) * delta / n).astype(g.dtype)


def compressed_psum_tree(grads: Any, axis_names: Sequence[str],
                         bits: int = 8) -> Any:
    return jax.tree.map(lambda g: compressed_psum_leaf(g, axis_names, bits),
                        grads)


def exact_psum_tree(grads: Any, axis_names: Sequence[str]) -> Any:
    axis_names = tuple(axis_names)
    n = 1
    # resolved inside shard_map; sizes are static there

    def mean(g):
        s = jax.lax.psum(g, axis_names)
        size = 1
        for name in axis_names:
            size *= jax.lax.axis_size(name)
        return s / size

    return jax.tree.map(mean, grads)
