"""Fault-tolerant checkpoint manager.

Design (scaled-down from the multi-host production pattern):
  * step-numbered directories ``step_%08d`` written under a ``.tmp`` name and
    atomically renamed — a crash mid-write never corrupts the latest ckpt;
  * arrays stored shard-agnostically (gathered host-side here; per-host shard
    files in a true multi-host run) so restore can re-shard onto ANY mesh —
    this is what makes elastic re-scaling work;
  * metadata.json carries step, wall-time, mesh shape and a config fingerprint
    (restore refuses a mismatched model config);
  * keep-last-k retention + async writer thread (save returns immediately,
    the next save joins the previous writer — bounded staleness of 1).

``latest_step``/``restore`` are what launch/train.py uses to resume after a
simulated crash (tests/test_checkpoint.py kills mid-run and resumes).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def config_fingerprint(obj: Any) -> str:
    """Deterministic fingerprint of a JSON-serializable config payload.
    Stored in metadata.json at save time; restore/load refuse a checkpoint
    whose fingerprint does not match the expected model config."""
    blob = json.dumps(obj, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode("utf-8")).hexdigest()[:16]


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    from repro.runtime.treepath import path_str
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[path_str(path)] = np.asarray(leaf)
    return flat


def _unflatten(like: Any, flat: Dict[str, np.ndarray]) -> Any:
    from repro.runtime.treepath import path_str
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in paths_leaves:
        key = path_str(path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs model {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._writer: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}")

    def all_steps(self):
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------------
    def _write(self, step: int, flat: Dict[str, np.ndarray],
               metadata: Dict[str, Any]):
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        with open(os.path.join(tmp, "metadata.json"), "w") as f:
            json.dump(metadata, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)          # atomic publish
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    def save(self, step: int, tree: Any, metadata: Optional[Dict] = None):
        # gather to host (device_get) BEFORE handing to the writer thread
        flat = _flatten(jax.device_get(tree))
        meta = dict(metadata or {})
        meta.update({"step": step, "time": time.time()})
        if self._writer is not None:
            self._writer.join()
        if self.async_save:
            self._writer = threading.Thread(
                target=self._write, args=(step, flat, meta), daemon=True)
            self._writer.start()
        else:
            self._write(step, flat, meta)

    def wait(self):
        if self._writer is not None:
            self._writer.join()
            self._writer = None

    # ------------------------------------------------------------------
    def read_metadata(self, step: Optional[int] = None) -> Dict[str, Any]:
        """Load metadata.json alone (no arrays) — lets a loader reconstruct
        the model config BEFORE it can build the ``like`` tree ``restore``
        needs."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        with open(os.path.join(self._step_dir(step), "metadata.json")) as f:
            return json.load(f)

    def restore(self, like: Any, step: Optional[int] = None, *,
                expect_fingerprint: Optional[str] = None
                ) -> Tuple[Any, Dict[str, Any]]:
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = self._step_dir(step)
        with open(os.path.join(d, "metadata.json")) as f:
            meta = json.load(f)
        if expect_fingerprint is not None:
            got = meta.get("config_fingerprint")
            if got is None:
                # pre-fingerprint checkpoint: can't verify — proceed (the
                # shape checks in _unflatten still catch gross mismatches)
                print(f"[ckpt] warning: {d} has no config fingerprint; "
                      f"skipping config verification")
            elif got != expect_fingerprint:
                raise ValueError(
                    f"checkpoint config fingerprint mismatch in {d}: "
                    f"checkpoint has {got!r}, caller expects "
                    f"{expect_fingerprint!r} — refusing to restore a "
                    f"different model config")
        with np.load(os.path.join(d, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        return _unflatten(like, flat), meta
