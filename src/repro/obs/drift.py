"""OSSH drift monitor: live telemetry for the paper's central hypothesis.

Quaff's calibration picks top-k outlier channel *positions* per layer and
then assumes those positions stay put across fine-tuning (the Outlier
Spatial Stability Hypothesis, paper §3.2 / Figure 2). This monitor turns
that assumption into a measurement: every N train steps it reruns a
``StatsScope(capture=True)`` forward on a fixed monitor batch (the same
mechanism calibration used, so scores are commensurable), re-ranks the
top-k channels per layer under the same per-layer-type budgets, and
compares against the calibration-time sets:

  * **jaccard** — |base ∩ cur| / |base ∪ cur| per stacked layer row,
    reported as mean/min across the stack;
  * **stable / entered / exited** — channel counts (both sets have size
    k, so entered == exited == k - stable per row).

Jaccard near 1.0 means OSSH is holding and the frozen outlier sets (and
any int8 decode-state scales derived from them) remain valid; a falling
curve is the earliest possible warning that re-calibration is due.

Results flow three ways: returned as ``LayerDrift`` rows, set as gauges
on the obs metrics registry (``ossh_jaccard{layer=...}``), and emitted as
Chrome-trace counter events so Perfetto renders the drift as a time
series alongside the train-step spans.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core import outliers as OUT


@dataclass
class LayerDrift:
    """Drift of one instrumented linear layer vs its calibration set."""
    layer: str            # normalized layer path, e.g. "ffn/down"
    k: int                # outlier budget for this layer type
    n_rows: int           # stacked rows compared (depth x experts)
    jaccard: float        # mean Jaccard overlap across rows
    jaccard_min: float    # worst row
    stable: int           # total channels present in both sets
    entered: int          # total channels new in the live set
    exited: int           # total channels that left the calibration set


def _single_batch_scores(st: np.ndarray, ratio: float) -> np.ndarray:
    """xi hit + magnitude tiebreak for ONE capture batch — the same
    ranking capture_stats builds, collapsed to a single forward."""
    med = np.median(st, axis=-1, keepdims=True)
    hit = (st > ratio * np.maximum(med, 1e-8)).astype(np.float32)
    return hit + st / (np.max(st, axis=-1, keepdims=True) + 1e-9)


class DriftMonitor:
    """Periodic OSSH checker bound to one converted model.

    ``calib_stats`` is the ``(absmax_tree, score_tree)`` pair produced by
    ``calibrate.capture_stats`` (what ``QuaffModel.calibrate`` stores as
    ``model.stats``); the baseline top-k sets are recomputed from it with
    the model's own budgets so they match what ``convert`` froze into the
    weights. ``observe`` is cheap relative to a train step (one jitted
    forward on the monitor batch) but not free — call it every N steps,
    not every step.
    """

    def __init__(self, frozen, cfg, calib_stats, tokens,
                 embeds: Optional[Any] = None, ratio: float = 20.0,
                 obs: Optional[Any] = None):
        import jax
        import jax.numpy as jnp

        from repro.core import backend as BK
        from repro.models import model as M
        from repro.train import calibrate as C

        self._ratio = ratio
        self._obs = obs
        self._tokens = jnp.asarray(tokens)
        self._embeds = None if embeds is None else jnp.asarray(embeds)

        budgets = cfg.quant.budgets

        def run(adapters, quant_state):
            return M.forward(frozen, adapters, quant_state, self._tokens,
                             cfg, input_embeds=self._embeds,
                             scope=BK.CAPTURE).stats
        self._fwd = jax.jit(run)

        # baseline: calibration-time top-k channel sets per stacked row
        self._base: Dict[str, List[set]] = {}
        self._k: Dict[str, int] = {}
        for key, score in C._stats_lookup(calib_stats[1]).items():
            lname = key.split("/")[-1]
            ltype = C.LAYER_TYPE_MAP.get(lname, lname)
            c_in = score.shape[-1]
            k = OUT.outlier_count(c_in, ltype, budgets)
            idx = C._topk_indices(score, k).reshape((-1, k))
            self._base[key] = [set(row.tolist()) for row in idx]
            self._k[key] = k

    def observe(self, adapters, quant_state,
                step: Optional[int] = None) -> Dict[str, LayerDrift]:
        """Recompute live top-k sets and diff against calibration."""
        import jax

        from repro.train import calibrate as C

        live = C._stats_lookup(jax.device_get(
            self._fwd(adapters, quant_state)))
        out: Dict[str, LayerDrift] = {}
        for key, base_rows in self._base.items():
            st = live.get(key)
            if st is None:
                continue
            k = self._k[key]
            score = _single_batch_scores(st.reshape((-1, st.shape[-1])),
                                         self._ratio)
            cur = C._topk_indices(score, k)
            # stats stack can be shorter than the calib stack (MoE share)
            n = min(len(base_rows), cur.shape[0])
            jac, stable = [], 0
            for row in range(n):
                b, c = base_rows[row], set(cur[row].tolist())
                inter = len(b & c)
                union = len(b | c)
                jac.append(inter / union if union else 1.0)
                stable += inter
            entered = n * k - stable
            out[key] = LayerDrift(
                layer=key, k=k, n_rows=n,
                jaccard=float(np.mean(jac)) if jac else 1.0,
                jaccard_min=float(np.min(jac)) if jac else 1.0,
                stable=stable, entered=entered, exited=entered)
        self._emit(out, step)
        return out

    # ---- obs fan-out -----------------------------------------------------
    def _emit(self, drifts: Dict[str, LayerDrift], step: Optional[int]):
        obs = self._obs
        if obs is None or not drifts:
            return
        if obs.metrics is not None:
            for d in drifts.values():
                labels = {"layer": d.layer}
                obs.metrics.set_gauge("ossh_jaccard", d.jaccard, labels)
                obs.metrics.set_gauge("ossh_jaccard_min", d.jaccard_min,
                                      labels)
                obs.metrics.inc("ossh_channels_entered", d.entered, labels)
                obs.metrics.inc("ossh_channels_exited", d.exited, labels)
            mean = float(np.mean([d.jaccard for d in drifts.values()]))
            obs.metrics.set_gauge("ossh_jaccard_mean", mean)
            if step is not None:
                obs.metrics.set_gauge("ossh_monitor_step", float(step))
        if obs.tracer is not None:
            obs.tracer.counter(
                "ossh_jaccard",
                {d.layer: d.jaccard for d in drifts.values()})


def format_report(drifts: Dict[str, LayerDrift],
                  step: Optional[int] = None) -> str:
    """One log line per observation, densest-info-first."""
    if not drifts:
        return "ossh-drift: no instrumented layers"
    mean = np.mean([d.jaccard for d in drifts.values()])
    worst = min(drifts.values(), key=lambda d: d.jaccard_min)
    head = f"ossh-drift step={step} " if step is not None else "ossh-drift "
    per = " ".join(f"{d.layer}={d.jaccard:.3f}"
                   for d in sorted(drifts.values(), key=lambda d: d.layer))
    return (f"{head}mean_jaccard={mean:.3f} "
            f"worst={worst.layer}:{worst.jaccard_min:.3f} {per}")
