"""The instrumented monotonic clock — the ONE place in ``src/repro`` that
may read a performance timer.

Every wall-time measurement in the tree (engine phase timing, span
begin/end stamps, per-request latency marks, the train launcher's
straggler watchdog) routes through :func:`now`, so traces, metrics and
``EngineStats`` all share a single timebase and the static-analysis gate
RPR011 can enforce that no ad-hoc ``time.perf_counter()`` deltas creep
back into the hot paths.

Tests monkeypatch :data:`_source` (via :func:`set_source`) to drive a fake
clock; production code never touches it.
"""
from __future__ import annotations

import time
from typing import Callable

#: the underlying timer — ``time.perf_counter`` is monotonic, unaffected by
#: wall-clock adjustments, and the highest-resolution timer CPython offers
_source: Callable[[], float] = time.perf_counter


def now() -> float:
    """Seconds on the process-wide monotonic timebase (float, ns-ish
    resolution). Differences are meaningful; absolute values are not."""
    return _source()


def set_source(fn: Callable[[], float]) -> Callable[[], float]:
    """Swap the timer (tests: deterministic fake clocks). Returns the
    previous source so callers can restore it."""
    global _source
    prev = _source
    _source = fn
    return prev
