"""Metrics registry: counters / gauges / histograms with label sets.

Everything is plain host-side Python — a metric mutation is a dict lookup
plus a float add, cheap enough for per-token call sites. Histograms use
fixed buckets (log-spaced latency buckets by default, ~0.1 ms .. 60 s)
so recording is O(log n_buckets) and percentile queries are
linear-interpolated from cumulative counts, the same estimate Prometheus'
``histogram_quantile`` computes server-side.

Export paths:

  * :meth:`MetricsRegistry.snapshot` — plain-dict JSON (counters as
    values, histograms with bucket counts + derived p50/p95/p99);
  * :meth:`MetricsRegistry.to_prometheus` — text exposition format
    (``# TYPE`` headers, ``_bucket{le=...}`` / ``_sum`` / ``_count``
    series) for scrape-style consumption.

A module-level :func:`mutation_count` tallies every registry write; the
disabled-mode test pins it to prove obs-off leaves the registry
untouched.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Sequence, Tuple

#: default latency buckets (seconds): log-spaced 100 µs .. 60 s, plus +inf.
#: Wide enough for queue waits on a loaded engine, fine enough near the
#: bottom to resolve interp-mode decode steps.
DEFAULT_LATENCY_BUCKETS: Tuple[float, ...] = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0,
)

_mutations = 0


def mutation_count() -> int:
    """Total registry writes since import (all registries). The
    disabled-mode no-op test snapshots this before/after a run."""
    return _mutations


def _labels_key(labels: Optional[Dict[str, str]]) -> Tuple[Tuple[str, str], ...]:
    if not labels:
        return ()
    return tuple(sorted(labels.items()))


class Counter:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def add(self, amount: float = 1.0):
        global _mutations
        _mutations += 1
        self.value += amount


class Gauge:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, value: float):
        global _mutations
        _mutations += 1
        self.value = value


class Histogram:
    """Fixed-bucket histogram; ``buckets`` are inclusive upper bounds,
    an implicit +inf bucket catches the rest."""

    __slots__ = ("name", "buckets", "counts", "total", "sum", "min", "max")

    def __init__(self, name: str,
                 buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS):
        self.name = name
        self.buckets = tuple(buckets)
        self.counts = [0] * (len(self.buckets) + 1)
        self.total = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, value: float):
        global _mutations
        _mutations += 1
        i = 0
        for i, ub in enumerate(self.buckets):
            if value <= ub:
                break
        else:
            i = len(self.buckets)
        self.counts[i] += 1
        self.total += 1
        self.sum += value
        if value < self.min:
            self.min = value
        if value > self.max:
            self.max = value

    def percentile(self, p: float) -> float:
        """Linear interpolation within the bucket holding the p-quantile
        (Prometheus ``histogram_quantile`` semantics). Accurate to a
        bucket width; the obs test checks it against numpy quantiles with
        exactly that tolerance. Returns nan when empty."""
        if self.total == 0:
            return float("nan")
        rank = (p / 100.0) * self.total
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum >= rank and c > 0:
                lo = self.buckets[i - 1] if i > 0 else 0.0
                hi = self.buckets[i] if i < len(self.buckets) else self.max
                frac = (rank - (cum - c)) / c
                return lo + (hi - lo) * frac
        return self.max

    def as_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {
            "count": self.total,
            "sum": self.sum,
            "buckets": {("%g" % ub): c
                        for ub, c in zip(self.buckets, self.counts)},
            "overflow": self.counts[-1],
        }
        if self.total:
            d.update(min=self.min, max=self.max,
                     mean=self.sum / self.total,
                     p50=self.percentile(50), p95=self.percentile(95),
                     p99=self.percentile(99))
        return d


class MetricsRegistry:
    """Name+labels -> metric. ``counter/gauge/histogram`` get-or-create;
    the convenience ``inc/set_gauge/observe`` wrappers are what hot paths
    call (one line, no instance juggling)."""

    def __init__(self):
        self._counters: Dict[Tuple[str, tuple], Counter] = {}
        self._gauges: Dict[Tuple[str, tuple], Gauge] = {}
        self._hists: Dict[Tuple[str, tuple], Histogram] = {}

    # ---- get-or-create ---------------------------------------------------
    def counter(self, name: str, labels: Optional[Dict[str, str]] = None
                ) -> Counter:
        key = (name, _labels_key(labels))
        c = self._counters.get(key)
        if c is None:
            c = self._counters[key] = Counter(name)
        return c

    def gauge(self, name: str, labels: Optional[Dict[str, str]] = None
              ) -> Gauge:
        key = (name, _labels_key(labels))
        g = self._gauges.get(key)
        if g is None:
            g = self._gauges[key] = Gauge(name)
        return g

    def histogram(self, name: str, labels: Optional[Dict[str, str]] = None,
                  buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS
                  ) -> Histogram:
        key = (name, _labels_key(labels))
        h = self._hists.get(key)
        if h is None:
            h = self._hists[key] = Histogram(name, buckets)
        return h

    # ---- hot-path wrappers -----------------------------------------------
    def inc(self, name: str, amount: float = 1.0,
            labels: Optional[Dict[str, str]] = None):
        self.counter(name, labels).add(amount)

    def set_gauge(self, name: str, value: float,
                  labels: Optional[Dict[str, str]] = None):
        self.gauge(name, labels).set(value)

    def observe(self, name: str, value: float,
                labels: Optional[Dict[str, str]] = None):
        self.histogram(name, labels).observe(value)

    # ---- export ----------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        def render(items, fn):
            out: Dict[str, Any] = {}
            for (name, labels), metric in sorted(items):
                key = name if not labels else (
                    name + "{" + ",".join(f"{k}={v}" for k, v in labels)
                    + "}")
                out[key] = fn(metric)
            return out
        return {
            "counters": render(self._counters.items(), lambda c: c.value),
            "gauges": render(self._gauges.items(), lambda g: g.value),
            "histograms": render(self._hists.items(),
                                 lambda h: h.as_dict()),
        }

    def to_prometheus(self) -> str:
        lines: List[str] = []

        def fmt_labels(labels: tuple, extra: str = "") -> str:
            parts = [f'{k}="{v}"' for k, v in labels]
            if extra:
                parts.append(extra)
            return "{" + ",".join(parts) + "}" if parts else ""

        for (name, labels), c in sorted(self._counters.items()):
            lines.append(f"# TYPE {name} counter")
            lines.append(f"{name}{fmt_labels(labels)} {c.value:g}")
        for (name, labels), g in sorted(self._gauges.items()):
            lines.append(f"# TYPE {name} gauge")
            lines.append(f"{name}{fmt_labels(labels)} {g.value:g}")
        for (name, labels), h in sorted(self._hists.items()):
            lines.append(f"# TYPE {name} histogram")
            cum = 0
            for ub, cnt in zip(h.buckets, h.counts):
                cum += cnt
                le = 'le="%g"' % ub
                lines.append(f"{name}_bucket{fmt_labels(labels, le)} {cum}")
            cum += h.counts[-1]
            inf_lbl = fmt_labels(labels, 'le="+Inf"')
            lines.append(f"{name}_bucket{inf_lbl} {cum}")
            lines.append(f"{name}_sum{fmt_labels(labels)} {h.sum:g}")
            lines.append(f"{name}_count{fmt_labels(labels)} {h.total}")
        return "\n".join(lines) + "\n"

    def write(self, path: str, fmt: str = "json") -> str:
        with open(path, "w") as f:
            if fmt == "prometheus":
                f.write(self.to_prometheus())
            else:
                json.dump(self.snapshot(), f, indent=1)
        return path
