"""Trace layer: nestable wall-time spans exported as Chrome trace-event
JSON (the ``{"traceEvents": [...]}`` format Perfetto / ``chrome://tracing``
load directly).

Span discipline is strict B/E bracketing per track (``tid``): entering a
span appends a ``"B"`` event, exiting appends the matching ``"E"``, so
nested spans render as a flame graph and the export is schema-valid by
construction (the CI serve-smoke gate re-checks balance anyway). Three
more event kinds cover the serving lifecycle:

  * ``instant`` — zero-duration marks (request enqueue/admit/retire);
  * async ``b``/``n``/``e`` — per-request lanes keyed by request id, so
    one request's enqueue -> admit -> first-token -> retire story reads
    as a single horizontal track across the engine's batched dispatches;
  * ``C`` counters — time series (the OSSH drift monitor emits per-layer
    Jaccard overlap this way, turning Figure-2-style offline analysis
    into a live Perfetto track).

Timestamps come from ``obs.clock`` (microseconds relative to the
tracer's construction). Optional ``jax.profiler`` coupling: when a span
is created with ``annotate=True`` the region is additionally wrapped in a
``jax.profiler.TraceAnnotation`` so device traces started through
``Obs.jax_profile()`` carry the same region names.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional

from repro.obs import clock

#: default tracks; anything else can pass an explicit tid
TID_ENGINE = 0
TID_TRAIN = 1


class Span:
    """One live span: a context manager appending B on enter / E on exit.

    ``elapsed_s`` is valid after exit (0.0 before). Spans are cheap but
    not free (two clock reads + two dict appends); the disabled path
    never constructs one — see ``obs.NULL_SPAN``.
    """

    __slots__ = ("_tracer", "name", "cat", "args", "tid", "_annotation",
                 "t0", "elapsed_s")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 args: Dict[str, Any], tid: int, annotate: bool):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args
        self.tid = tid
        self.t0 = 0.0
        self.elapsed_s = 0.0
        self._annotation = None
        if annotate:                      # couple to an active jax profile
            import jax.profiler
            self._annotation = jax.profiler.TraceAnnotation(name)

    def __enter__(self) -> "Span":
        self.t0 = clock.now()
        self._tracer._begin(self.name, self.cat, self.t0, self.args,
                            self.tid)
        if self._annotation is not None:
            self._annotation.__enter__()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if self._annotation is not None:
            self._annotation.__exit__(exc_type, exc, tb)
        t1 = clock.now()
        self.elapsed_s = t1 - self.t0
        self._tracer._end(self.name, self.cat, t1, self.tid)
        return False


class Tracer:
    """Append-only trace-event buffer with per-track span stacks."""

    def __init__(self, process_name: str = "repro"):
        self._epoch = clock.now()
        self._events: List[Dict[str, Any]] = []
        self._stacks: Dict[int, List[str]] = {}
        self._event({"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
                     "args": {"name": process_name}})
        for tid, name in ((TID_ENGINE, "engine"), (TID_TRAIN, "train")):
            self._event({"name": "thread_name", "ph": "M", "pid": 0,
                         "tid": tid, "args": {"name": name}})

    # ---- raw event plumbing ---------------------------------------------
    def _ts(self, t: float) -> float:
        return (t - self._epoch) * 1e6        # trace-event ts is in µs

    def _event(self, ev: Dict[str, Any]):
        self._events.append(ev)

    def _begin(self, name: str, cat: str, t: float, args: Dict[str, Any],
               tid: int):
        self._stacks.setdefault(tid, []).append(name)
        self._event({"name": name, "cat": cat, "ph": "B", "pid": 0,
                     "tid": tid, "ts": self._ts(t), "args": args})

    def _end(self, name: str, cat: str, t: float, tid: int):
        stack = self._stacks.get(tid, [])
        if stack and stack[-1] == name:
            stack.pop()
        self._event({"name": name, "cat": cat, "ph": "E", "pid": 0,
                     "tid": tid, "ts": self._ts(t)})

    # ---- public event kinds ---------------------------------------------
    def span(self, name: str, cat: str = "serve", tid: int = TID_ENGINE,
             annotate: bool = False, **args) -> Span:
        return Span(self, name, cat, args, tid, annotate)

    def instant(self, name: str, cat: str = "serve", tid: int = TID_ENGINE,
                **args):
        """Zero-duration mark (enqueue/admit/retire and friends)."""
        self._event({"name": name, "cat": cat, "ph": "i", "s": "t",
                     "pid": 0, "tid": tid, "ts": self._ts(clock.now()),
                     "args": args})

    def async_begin(self, name: str, async_id: str, cat: str = "request",
                    **args):
        """Open a per-request lane; ``async_id`` (the request id) keys the
        matching instants/end so Perfetto draws one track per request."""
        self._event({"name": name, "cat": cat, "ph": "b", "id": async_id,
                     "pid": 0, "tid": TID_ENGINE,
                     "ts": self._ts(clock.now()), "args": args})

    def async_instant(self, name: str, async_id: str, cat: str = "request",
                      **args):
        self._event({"name": name, "cat": cat, "ph": "n", "id": async_id,
                     "pid": 0, "tid": TID_ENGINE,
                     "ts": self._ts(clock.now()), "args": args})

    def async_end(self, name: str, async_id: str, cat: str = "request",
                  **args):
        self._event({"name": name, "cat": cat, "ph": "e", "id": async_id,
                     "pid": 0, "tid": TID_ENGINE,
                     "ts": self._ts(clock.now()), "args": args})

    def counter(self, name: str, values: Dict[str, float],
                cat: str = "metrics", tid: int = TID_TRAIN):
        """Counter track sample (``ph: "C"``): ``values`` maps series name
        to value; repeated calls build the time series."""
        self._event({"name": name, "cat": cat, "ph": "C", "pid": 0,
                     "tid": tid, "ts": self._ts(clock.now()),
                     "args": dict(values)})

    # ---- export ----------------------------------------------------------
    @property
    def n_events(self) -> int:
        return len(self._events)

    def events(self) -> List[Dict[str, Any]]:
        """The raw event list (shared, do not mutate)."""
        return self._events

    def open_spans(self) -> Dict[int, List[str]]:
        """tid -> names of spans entered but not yet exited (should be
        empty at export time; exported anyway — Perfetto tolerates it)."""
        return {tid: list(stack)
                for tid, stack in self._stacks.items() if stack}

    def to_chrome_trace(self) -> Dict[str, Any]:
        return {"traceEvents": list(self._events),
                "displayTimeUnit": "ms"}

    def write(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)
        return path


def validate_chrome_trace(payload: Any) -> Optional[str]:
    """Schema sanity for an exported trace: returns an error string or
    None. Checks what Perfetto actually needs — a ``traceEvents`` list,
    per-event ``ph``/``name``, numeric ``ts`` where required, and B/E
    balance per (pid, tid) with LIFO nesting. Shared by the obs tests and
    the CI serve-smoke gate."""
    if not isinstance(payload, dict) or "traceEvents" not in payload:
        return "missing traceEvents"
    stacks: Dict[Any, List[str]] = {}
    for i, ev in enumerate(payload["traceEvents"]):
        if not isinstance(ev, dict):
            return f"event {i} is not an object"
        ph = ev.get("ph")
        if not isinstance(ev.get("name"), str) or not isinstance(ph, str):
            return f"event {i} lacks name/ph"
        if ph != "M" and not isinstance(ev.get("ts"), (int, float)):
            return f"event {i} ({ev['name']!r}) lacks a numeric ts"
        if ph in ("b", "n", "e") and "id" not in ev:
            return f"async event {i} ({ev['name']!r}) lacks an id"
        if ph in ("B", "E"):
            key = (ev.get("pid", 0), ev.get("tid", 0))
            stack = stacks.setdefault(key, [])
            if ph == "B":
                stack.append(ev["name"])
            elif not stack:
                return f"event {i}: E {ev['name']!r} with no open B"
            elif stack[-1] != ev["name"]:
                return (f"event {i}: E {ev['name']!r} closes "
                        f"{stack[-1]!r} (interleaved spans)")
            else:
                stack.pop()
    for key, stack in stacks.items():
        if stack:
            return f"unclosed span(s) {stack} on track {key}"
    return None
