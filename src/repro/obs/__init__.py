"""repro.obs — unified tracing + metrics + OSSH drift telemetry.

One object (:class:`Obs`) carries the whole observability surface through
the stack: the engine, the train loops and the launchers all take an
``obs=`` handle and never construct their own timers. Three layers:

  * ``obs.clock`` — THE monotonic timebase (sole sanctioned
    ``time.perf_counter`` call site in ``src/repro``; rule RPR011
    enforces this);
  * ``obs.trace`` — nestable spans + per-request async lanes exported as
    Chrome trace-event JSON (Perfetto-loadable), with optional
    ``jax.profiler`` start/stop hooks;
  * ``obs.metrics`` — counters/gauges/fixed-bucket histograms (TTFT,
    inter-token latency, queue wait, e2e) with JSON snapshot and
    Prometheus text exposition;
  * ``obs.drift`` — the OSSH drift monitor (live Jaccard overlap of
    outlier channel sets vs calibration).

Disabled mode is a true no-op: :data:`NULL_OBS` hands out the module
singleton :data:`NULL_SPAN` (no clock reads, no allocations) and every
metric call returns before touching a registry. Code that needs a
timestamp *regardless* of observability (``EngineStats`` throughput
accounting pre-dates this package and CI gates on it) reads
``clock.now()`` through the :meth:`Obs.phase_begin` /
:meth:`Obs.phase_end` pair — those share ONE clock read between the
stats field and the trace event, so enabling tracing adds no extra timer
calls to the hot path.

Typical wiring::

    obs = Obs.from_config(ObsConfig(trace=True, metrics=True,
                                    trace_path="trace.json"))
    eng = model.engine(EngineConfig(max_slots=4), obs=obs)
    ... run requests ...
    obs.export()          # writes trace.json (+ metrics if configured)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional

from repro.obs import clock
from repro.obs.drift import DriftMonitor, LayerDrift, format_report
from repro.obs.metrics import (DEFAULT_LATENCY_BUCKETS, Counter, Gauge,
                               Histogram, MetricsRegistry, mutation_count)
from repro.obs.trace import (TID_ENGINE, TID_TRAIN, Span, Tracer,
                             validate_chrome_trace)

__all__ = [
    "Obs", "ObsConfig", "NULL_OBS", "NULL_SPAN", "NullSpan",
    "Tracer", "Span", "validate_chrome_trace", "TID_ENGINE", "TID_TRAIN",
    "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "DEFAULT_LATENCY_BUCKETS", "mutation_count",
    "DriftMonitor", "LayerDrift", "format_report", "clock",
]


@dataclass(frozen=True)
class ObsConfig:
    """What to collect and where to put it. ``trace_path`` /
    ``metrics_path`` imply enabling their layer, so CLI flags map 1:1."""
    trace: bool = False
    metrics: bool = False
    trace_path: Optional[str] = None
    metrics_path: Optional[str] = None
    metrics_fmt: str = "json"             # "json" | "prometheus"
    jax_profiler_dir: Optional[str] = None

    @property
    def trace_enabled(self) -> bool:
        return self.trace or self.trace_path is not None

    @property
    def metrics_enabled(self) -> bool:
        return self.metrics or self.metrics_path is not None


class NullSpan:
    """The disabled span: enter/exit touch nothing — not even the clock.
    ``elapsed_s`` stays 0.0; callers that need real elapsed time use
    ``Obs.phase_begin``/``phase_end`` instead of reading it."""

    __slots__ = ()
    elapsed_s = 0.0

    def __enter__(self) -> "NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False


#: module-level singleton — ``obs.span(...)`` when disabled returns THIS
#: object, so the disabled path allocates nothing per call.
NULL_SPAN = NullSpan()


class Obs:
    """Live observability handle: ``tracer`` and/or ``metrics`` are None
    when that layer is off, and every delegating method checks exactly
    one attribute before doing work."""

    def __init__(self, config: Optional[ObsConfig] = None,
                 tracer: Optional[Tracer] = None,
                 metrics: Optional[MetricsRegistry] = None):
        self.config = config or ObsConfig()
        self.tracer = tracer
        self.metrics = metrics
        self._profiling = False

    @classmethod
    def from_config(cls, config: Optional[ObsConfig]) -> "Obs":
        if config is None:
            return NULL_OBS
        return cls(config,
                   tracer=Tracer() if config.trace_enabled else None,
                   metrics=(MetricsRegistry()
                            if config.metrics_enabled else None))

    @property
    def enabled(self) -> bool:
        return self.tracer is not None or self.metrics is not None

    # ---- trace delegation ------------------------------------------------
    def span(self, name: str, cat: str = "serve", tid: int = TID_ENGINE,
             annotate: bool = False, **args):
        if self.tracer is None:
            return NULL_SPAN
        return self.tracer.span(name, cat=cat, tid=tid, annotate=annotate,
                                **args)

    def instant(self, name: str, cat: str = "serve",
                tid: int = TID_ENGINE, **args):
        if self.tracer is not None:
            self.tracer.instant(name, cat=cat, tid=tid, **args)

    def async_begin(self, name: str, async_id: str, **args):
        if self.tracer is not None:
            self.tracer.async_begin(name, async_id, **args)

    def async_instant(self, name: str, async_id: str, **args):
        if self.tracer is not None:
            self.tracer.async_instant(name, async_id, **args)

    def async_end(self, name: str, async_id: str, **args):
        if self.tracer is not None:
            self.tracer.async_end(name, async_id, **args)

    def counter(self, name: str, values: Dict[str, float],
                tid: int = TID_TRAIN):
        if self.tracer is not None:
            self.tracer.counter(name, values, tid=tid)

    # ---- shared-timestamp phase timing -----------------------------------
    # EngineStats accounting needs wall time whether or not obs is on;
    # these share the single clock read with the trace event so tracing
    # adds zero extra timer calls.
    def phase_begin(self, name: str, cat: str = "serve",
                    tid: int = TID_ENGINE, **args) -> float:
        t0 = clock.now()
        if self.tracer is not None:
            self.tracer._begin(name, cat, t0, args, tid)
        return t0

    def phase_end(self, name: str, t0: float, cat: str = "serve",
                  tid: int = TID_ENGINE, hist: Optional[str] = None,
                  labels: Optional[Dict[str, str]] = None) -> float:
        t1 = clock.now()
        if self.tracer is not None:
            self.tracer._end(name, cat, t1, tid)
        dt = t1 - t0
        if self.metrics is not None and hist is not None:
            self.metrics.observe(hist, dt, labels)
        return dt

    # ---- metrics delegation ----------------------------------------------
    def inc(self, name: str, amount: float = 1.0,
            labels: Optional[Dict[str, str]] = None):
        if self.metrics is not None:
            self.metrics.inc(name, amount, labels)

    def set_gauge(self, name: str, value: float,
                  labels: Optional[Dict[str, str]] = None):
        if self.metrics is not None:
            self.metrics.set_gauge(name, value, labels)

    def observe(self, name: str, value: float,
                labels: Optional[Dict[str, str]] = None):
        if self.metrics is not None:
            self.metrics.observe(name, value, labels)

    # ---- jax.profiler hooks ----------------------------------------------
    def start_jax_profiler(self):
        """Start a device trace when ``jax_profiler_dir`` is configured;
        spans created with ``annotate=True`` show up inside it."""
        if self.config.jax_profiler_dir and not self._profiling:
            import jax.profiler
            jax.profiler.start_trace(self.config.jax_profiler_dir)
            self._profiling = True

    def stop_jax_profiler(self):
        if self._profiling:
            import jax.profiler
            jax.profiler.stop_trace()
            self._profiling = False

    # ---- export ----------------------------------------------------------
    def export(self) -> Dict[str, str]:
        """Write whatever was configured; returns {kind: path}."""
        self.stop_jax_profiler()
        out: Dict[str, str] = {}
        if self.tracer is not None and self.config.trace_path:
            out["trace"] = self.tracer.write(self.config.trace_path)
        if self.metrics is not None and self.config.metrics_path:
            out["metrics"] = self.metrics.write(self.config.metrics_path,
                                                self.config.metrics_fmt)
        return out


#: the disabled singleton — a plain Obs with both layers off. Safe to
#: share: it holds no state and mutates nothing.
NULL_OBS = Obs()
