"""High-level facade over the Quaff reproduction: the paper's whole
prepare -> calibrate -> convert -> fine-tune -> serve pipeline in one object,
so examples, benchmarks and serving stop hand-wiring the plumbing.

    from repro import api

    model = api.prepare(cfg)                 # fp32 init (base stays frozen)
    model.calibrate(batches)                 # §3.3: capture outlier stats
    model.convert("quaff")                   # one-time weights preprocessing
    model.finetune(tcfg, loader, steps=100)  # PEFT adapters + Eq. 7 updates
    model.evaluate(batch)                    # loss / ppl / acc
    model.generate(prompts, max_new=32)      # batched greedy decode

Every quant mode in the ``QuantBackend`` registry (including modes
registered by downstream code) works through the same five calls.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, List, Optional

import jax
import jax.numpy as jnp

from repro.core import backend as BK
from repro.models import model as M
from repro.models.config import ModelConfig, TrainConfig
from repro.train import calibrate as C
from repro.train import steps as S


def prepare(cfg: ModelConfig, seed: int = 0) -> "QuaffModel":
    """Initialize a model in ``cfg``'s quant mode (typically "fp32" so it
    can be calibrated and converted) and wrap it in the facade."""
    frozen, adapters, quant_state = M.init_params(jax.random.PRNGKey(seed), cfg)
    return QuaffModel(cfg, frozen, adapters, quant_state)


class QuaffModel:
    """Stateful facade. ``frozen`` never changes after ``convert`` — that is
    Quaff's decoupling story; ``adapters``/``quant_state`` advance with
    ``finetune``. All heavy functions are jitted once per (cfg, shape)."""

    def __init__(self, cfg: ModelConfig, frozen, adapters, quant_state):
        self.cfg = cfg
        self.frozen = frozen
        self.adapters = adapters
        self.quant_state = quant_state
        self.stats = None           # calibration artifacts (absmax, scores)
        self._eval_fn = None
        self._eval_cfg = None
        self._decode_fn = None
        self._prefill_fns: Dict[int, Any] = {}
        self._train_state = None
        self._train_tcfg = None
        self._step_fn = None

    # ---- calibration / conversion --------------------------------------
    def calibrate(self, batches: Iterable[Dict[str, Any]],
                  ratio: Optional[float] = None) -> "QuaffModel":
        """Capture per-channel activation stats (paper §3.3, Eq. 6)."""
        ratio = self.cfg.quant.outlier_ratio if ratio is None else ratio
        self.stats = C.capture_stats(self.frozen, self.adapters,
                                     self.quant_state, self.cfg,
                                     list(batches), ratio=ratio)
        return self

    def convert(self, mode: str) -> "QuaffModel":
        """One-time weights preprocessing into ``mode`` via the registry."""
        backend = BK.get_backend(mode)  # fail fast on unknown modes
        if self.cfg.quant.mode != "fp32":
            raise ValueError(
                f"convert() preprocesses the fp32 weight tree exactly once; "
                f"this model is already {self.cfg.quant.mode!r} — api.prepare "
                f"a fresh fp32 model to target {mode!r}")
        if self.stats is None and (backend.wants_absmax
                                   or backend.wants_outliers):
            raise ValueError(
                f"mode {mode!r} needs calibration artifacts; call "
                f".calibrate(batches) before .convert({mode!r})")
        self.frozen, self.quant_state = C.convert(
            self.frozen, self.stats, self.cfg, mode)
        self.cfg = dataclasses.replace(
            self.cfg, quant=dataclasses.replace(self.cfg.quant, mode=mode))
        self._eval_fn = None
        self._decode_fn = None
        self._prefill_fns = {}
        self._train_state = None
        self._step_fn = None
        return self

    # ---- training -------------------------------------------------------
    def finetune(self, tcfg: TrainConfig, loader, steps: int,
                 start_step: Optional[int] = None,
                 log_every: int = 0) -> List[float]:
        """Run ``steps`` train steps (adapters + quant state advance in
        place); returns the per-step loss history.

        Repeated calls with the same ``tcfg`` CONTINUE training: optimizer
        moments, the step counter (which also keys dropout), and the data
        position carry over. A different ``tcfg`` re-initializes the
        optimizer. ``start_step`` only overrides the loader batch index."""
        if self._train_state is None or tcfg != self._train_tcfg:
            self._train_state = S.init_train_state(self.adapters,
                                                   self.quant_state, tcfg)
            self._step_fn = jax.jit(S.build_train_step(self.cfg, tcfg))
            self._train_tcfg = tcfg
        state = self._train_state
        begin = int(state.step) if start_step is None else start_step
        losses = []  # device arrays; host sync deferred to the end
        for i in range(begin, begin + steps):
            batch = jax.tree.map(jnp.asarray, loader.batch(i))
            state, metrics = self._step_fn(self.frozen, state, batch)
            losses.append(metrics["loss"])
            if log_every and i % log_every == 0:
                print(f"step {i:5d}  loss {float(metrics['loss']):.4f}  "
                      f"gnorm {float(metrics['grad_norm']):.3f}")
        self._train_state = state
        self.adapters = state.adapters
        self.quant_state = state.quant
        return [float(l) for l in losses]

    # ---- evaluation / inference -----------------------------------------
    def evaluate(self, batch: Dict[str, Any]) -> Dict[str, float]:
        if self._eval_fn is None or self._eval_cfg is not self.cfg:
            self._eval_fn = jax.jit(S.build_eval_step(self.cfg))
            self._eval_cfg = self.cfg
        m = self._eval_fn(self.frozen, self.adapters, self.quant_state,
                          jax.tree.map(jnp.asarray, batch))
        return {k: float(v) for k, v in m.items()}

    def forward(self, tokens, **kw):
        """Raw typed forward (ModelOut) for power users."""
        return M.forward(self.frozen, self.adapters, self.quant_state,
                         jnp.asarray(tokens), self.cfg, **kw)

    def prefill(self, batch: Dict[str, Any], extra_len: int = 0):
        """Batched prefill -> (last-token logits, decode caches)."""
        fn = self._prefill_fns.get(extra_len)
        if fn is None:
            fn = jax.jit(S.build_prefill(self.cfg, extra_len=extra_len))
            self._prefill_fns[extra_len] = fn
        return fn(self.frozen, self.adapters, self.quant_state,
                  jax.tree.map(jnp.asarray, batch))

    def decode_step(self, caches, token, pos):
        """One decode step -> (logits, new caches)."""
        if self._decode_fn is None:
            self._decode_fn = jax.jit(S.build_decode(self.cfg))
        return self._decode_fn(self.frozen, self.adapters, self.quant_state,
                               caches, token, jnp.asarray(pos, jnp.int32))

    def generate(self, tokens, max_new: int = 32) -> jnp.ndarray:
        """Greedy batched generation: (B, S) prompts -> (B, max_new)."""
        tokens = jnp.asarray(tokens)
        if max_new <= 0:
            return jnp.zeros((tokens.shape[0], 0), jnp.int32)
        prompt_len = tokens.shape[1]
        logits, caches = self.prefill({"tokens": tokens}, extra_len=max_new)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out = [tok]
        for i in range(max_new - 1):
            logits, caches = self.decode_step(caches, tok, prompt_len + i)
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            out.append(tok)
        return jnp.concatenate(out, axis=1)
