"""High-level facade over the Quaff reproduction: the paper's whole
prepare -> calibrate -> convert -> fine-tune -> save/load -> serve lifecycle
in one object, so examples, benchmarks and serving stop hand-wiring the
plumbing.

    from repro import api

    model = api.prepare(cfg)                 # fp32 init (base stays frozen)
    model.calibrate(batches)                 # §3.3: capture outlier stats
    model.convert("quaff")                   # one-time weights preprocessing
    model.finetune(tcfg, loader, steps=100)  # PEFT adapters + Eq. 7 updates
    model.evaluate(batch)                    # loss / ppl / acc
    model.save("ckpts/run")                  # frozen + adapters + quant
                                             #  (+ optimizer) w/ fingerprint
    model = api.QuaffModel.load("ckpts/run")  # bit-identical round-trip
    model.generate(prompts, max_new=32, eos_id=2)   # one-shot engine decode
    engine = model.engine(EngineConfig(max_slots=8, max_seq_len=512))
    outs = engine.run([GenerationRequest(...), ...])   # continuous batching

Every quant mode in the ``QuantBackend`` registry (including modes
registered by downstream code) works through the same calls. Inference is
backed by ``repro.serving.Engine`` for EVERY family — a fixed-capacity
slot pool of family-appropriate decode state (KV rows, recurrent
conv/SSM/mLSTM state, or self-KV + cross-KV) where one compiled decode
step serves a changing request mix (greedy / temperature / top-k / top-p /
seeded sampling, per-token streaming, EOS-or-budget retirement). The old
lockstep loop is gone; ``generate`` is engine-backed everywhere.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as OBS
from repro.core import backend as BK
from repro.models import model as M
from repro.models.config import ModelConfig, QuantConfig, TrainConfig
from repro.train import calibrate as C
from repro.train import steps as S


def prepare(cfg: ModelConfig, seed: int = 0) -> "QuaffModel":
    """Initialize a model in ``cfg``'s quant mode (typically "fp32" so it
    can be calibrated and converted) and wrap it in the facade."""
    frozen, adapters, quant_state = M.init_params(jax.random.PRNGKey(seed), cfg)
    return QuaffModel(cfg, frozen, adapters, quant_state)


def _cfg_to_dict(cfg: ModelConfig) -> Dict[str, Any]:
    d = dataclasses.asdict(cfg)
    if d["quant"].get("budgets") is not None:
        d["quant"]["budgets"] = dict(d["quant"]["budgets"])
    return d


def _cfg_from_dict(d: Dict[str, Any]) -> ModelConfig:
    from repro.core.peft import PEFTConfig
    d = dict(d)
    d["quant"] = QuantConfig(**d["quant"])
    d["peft"] = PEFTConfig(**d["peft"])
    return ModelConfig(**d)


class QuaffModel:
    """Stateful facade. ``frozen`` never changes after ``convert`` — that is
    Quaff's decoupling story; ``adapters``/``quant_state`` advance with
    ``finetune``. All heavy functions are jitted once per (cfg, shape)."""

    #: each cached engine pins a (L, slots, seq, kv_heads, hd) device KV
    #: pool; bound the cache so varied generate() shapes can't accumulate
    _MAX_CACHED_ENGINES = 4

    def __init__(self, cfg: ModelConfig, frozen, adapters, quant_state):
        self.cfg = cfg
        self.frozen = frozen
        self.adapters = adapters
        self.quant_state = quant_state
        self.stats = None           # calibration artifacts (absmax, scores)
        #: OSSH drift observations from finetune(ossh_monitor_every=N):
        #: list of (step, {layer: obs.LayerDrift}) in observation order
        self.ossh_drift: List[Any] = []
        #: monotonic counter over served-weight changes: finetune()/convert()
        #: bump it, and a serving Engine watching this model re-scopes its
        #: prefix cache on the next step (stale KV auto-invalidation)
        self.weights_version = 0
        self._eval_fn = None
        self._eval_cfg = None
        self._decode_fn = None
        self._prefill_fns: Dict[int, Any] = {}
        self._engines: Dict[Any, Any] = {}   # EngineConfig -> Engine
        self._train_state = None
        self._train_tcfg = None
        self._step_fn = None

    def _invalidate_compiled(self):
        """Drop every compiled function keyed on ``self.cfg``. Call whenever
        ``self.cfg`` (or the tree structures it implies) is replaced."""
        self._eval_fn = None
        self._eval_cfg = None
        self._decode_fn = None
        self._prefill_fns = {}
        self._engines = {}
        self._step_fn = None

    # ---- calibration / conversion --------------------------------------
    def calibrate(self, batches: Iterable[Dict[str, Any]],
                  ratio: Optional[float] = None) -> "QuaffModel":
        """Capture per-channel activation stats (paper §3.3, Eq. 6)."""
        ratio = self.cfg.quant.outlier_ratio if ratio is None else ratio
        self.stats = C.capture_stats(self.frozen, self.adapters,
                                     self.quant_state, self.cfg,
                                     list(batches), ratio=ratio)
        return self

    def convert(self, mode: str) -> "QuaffModel":
        """One-time weights preprocessing into ``mode`` via the registry."""
        backend = BK.get_backend(mode)  # fail fast on unknown modes
        if self.cfg.quant.mode != "fp32":
            raise ValueError(
                f"convert() preprocesses the fp32 weight tree exactly once; "
                f"this model is already {self.cfg.quant.mode!r} — api.prepare "
                f"a fresh fp32 model to target {mode!r}")
        if self.stats is None and (backend.wants_absmax
                                   or backend.wants_outliers):
            raise ValueError(
                f"mode {mode!r} needs calibration artifacts; call "
                f".calibrate(batches) before .convert({mode!r})")
        self.frozen, self.quant_state = C.convert(
            self.frozen, self.stats, self.cfg, mode)
        self.cfg = dataclasses.replace(
            self.cfg, quant=dataclasses.replace(self.cfg.quant, mode=mode))
        self._invalidate_compiled()
        self._train_state = None
        self.weights_version += 1
        return self

    # ---- training -------------------------------------------------------
    def finetune(self, tcfg: TrainConfig, loader, steps: int,
                 start_step: Optional[int] = None,
                 log_every: int = 0, obs=None,
                 ossh_monitor_every: int = 0) -> List[float]:
        """Run ``steps`` train steps (adapters + quant state advance in
        place); returns the per-step loss history.

        Repeated calls with the same ``tcfg`` CONTINUE training: optimizer
        moments, the step counter (which also keys dropout), and the data
        position carry over — including across a ``save``/``load`` pair. A
        different ``tcfg`` re-initializes the optimizer. ``start_step`` only
        overrides the loader batch index.

        ``obs`` (a ``repro.obs.Obs``) wraps each step in a ``train_step``
        span and receives the drift telemetry. ``ossh_monitor_every=N``
        turns on the OSSH drift monitor: every N steps the outlier channel
        sets are recomputed on a fixed monitor batch and diffed against
        the calibration sets (requires ``calibrate()`` to have run on this
        model); observations accumulate on ``self.ossh_drift`` as
        ``(step, {layer: LayerDrift})`` pairs."""
        if self._train_state is None or tcfg != self._train_tcfg:
            self._train_state = S.init_train_state(self.adapters,
                                                   self.quant_state, tcfg)
            self._step_fn = jax.jit(S.build_train_step(self.cfg, tcfg))
            self._train_tcfg = tcfg
        elif self._step_fn is None:     # restored state (load) — re-jit only
            self._step_fn = jax.jit(S.build_train_step(self.cfg, tcfg))
        obs = obs if obs is not None else OBS.NULL_OBS
        state = self._train_state
        begin = int(state.step) if start_step is None else start_step
        monitor = None
        if ossh_monitor_every:
            if self.stats is None:
                raise ValueError(
                    "ossh_monitor_every needs the calibration outlier sets "
                    "as the drift baseline; call .calibrate(batches) (before "
                    ".convert) so model.stats is populated")
            monitor = OBS.DriftMonitor(
                self.frozen, self.cfg, self.stats,
                tokens=loader.batch(begin)["tokens"],
                ratio=self.cfg.quant.outlier_ratio, obs=obs)
        losses = []  # device arrays; host sync deferred to the end
        for i in range(begin, begin + steps):
            batch = jax.tree.map(jnp.asarray, loader.batch(i))
            with obs.span("train_step", cat="train", tid=OBS.TID_TRAIN,
                          step=i):
                state, metrics = self._step_fn(self.frozen, state, batch)
            losses.append(metrics["loss"])
            if log_every and i % log_every == 0:
                print(f"step {i:5d}  loss {float(metrics['loss']):.4f}  "
                      f"gnorm {float(metrics['grad_norm']):.3f}")
            if monitor is not None and (i - begin + 1) % ossh_monitor_every == 0:
                with obs.span("ossh_monitor", cat="train",
                              tid=OBS.TID_TRAIN, step=i):
                    drifts = monitor.observe(state.adapters, state.quant,
                                             step=i)
                self.ossh_drift.append((i, drifts))
                if log_every:
                    print(OBS.format_report(drifts, step=i))
        self._train_state = state
        self.adapters = state.adapters
        self.quant_state = state.quant
        self.weights_version += 1
        return [float(l) for l in losses]

    # ---- checkpoint lifecycle -------------------------------------------
    def save(self, directory: str) -> str:
        """Checkpoint the full model state into ``directory``:
        frozen (quantized base) + adapters + quant state, plus — when the
        model has been fine-tuned — the optimizer moments and step counter,
        so training continues where it left off after ``load``. The model
        config rides in metadata.json with a fingerprint that ``load``
        verifies."""
        from repro.checkpoint.manager import (CheckpointManager,
                                              config_fingerprint)
        cfg_dict = _cfg_to_dict(self.cfg)
        tree: Dict[str, Any] = {"frozen": self.frozen,
                                "adapters": self.adapters,
                                "quant": self.quant_state}
        meta: Dict[str, Any] = {
            "config": cfg_dict,
            "config_fingerprint": config_fingerprint(cfg_dict),
            "arch": self.cfg.name,
        }
        step = 0
        if self._train_state is not None:
            tree["opt"] = self._train_state.opt
            meta["train_config"] = dataclasses.asdict(self._train_tcfg)
            step = int(self._train_state.step)
        mgr = CheckpointManager(directory, async_save=False)
        mgr.save(step, tree, meta)
        return directory

    @classmethod
    def load(cls, directory: str, step: Optional[int] = None) -> "QuaffModel":
        """Rebuild a facade model from a ``save`` checkpoint: reconstructs
        the config from metadata (refusing a fingerprint mismatch), uses a
        same-config init as the structural template, and restores every
        array bit-exactly — eval metrics round-trip identically, and a
        fine-tuned model keeps its optimizer state."""
        from repro.checkpoint.manager import (CheckpointManager,
                                              config_fingerprint)
        mgr = CheckpointManager(directory, async_save=False)
        meta = mgr.read_metadata(step)
        if "config" not in meta:
            raise ValueError(
                f"checkpoint in {directory} has no model config metadata — "
                f"was it written by QuaffModel.save()?")
        cfg = _cfg_from_dict(meta["config"])
        expect = config_fingerprint(_cfg_to_dict(cfg))
        # template with the right pytree structure/shapes for this config
        frozen, adapters, qstate = M.init_params(jax.random.PRNGKey(0), cfg)
        like: Dict[str, Any] = {"frozen": frozen, "adapters": adapters,
                                "quant": qstate}
        tcfg = None
        if meta.get("train_config") is not None:
            tcfg = TrainConfig(**meta["train_config"])
            like["opt"] = S.init_train_state(adapters, qstate, tcfg).opt
        tree, meta = mgr.restore(like, step, expect_fingerprint=expect)
        model = cls(cfg, tree["frozen"], tree["adapters"], tree["quant"])
        if tcfg is not None:
            model._train_state = S.TrainState(
                adapters=tree["adapters"], opt=tree["opt"],
                quant=tree["quant"],
                step=jnp.asarray(meta["step"], jnp.int32))
            model._train_tcfg = tcfg
        return model

    # ---- evaluation / inference -----------------------------------------
    def evaluate(self, batch: Dict[str, Any]) -> Dict[str, float]:
        if self._eval_fn is None or self._eval_cfg != self.cfg:
            self._eval_fn = jax.jit(S.build_eval_step(self.cfg))
            self._eval_cfg = self.cfg
        m = self._eval_fn(self.frozen, self.adapters, self.quant_state,
                          jax.tree.map(jnp.asarray, batch))
        return {k: float(v) for k, v in m.items()}

    def forward(self, tokens, **kw):
        """Raw typed forward (ModelOut) for power users."""
        return M.forward(self.frozen, self.adapters, self.quant_state,
                         jnp.asarray(tokens), self.cfg, **kw)

    def prefill(self, batch: Dict[str, Any], extra_len: int = 0):
        """Batched prefill -> (last-token logits, decode caches)."""
        fn = self._prefill_fns.get(extra_len)
        if fn is None:
            fn = jax.jit(S.build_prefill(self.cfg, extra_len=extra_len))
            self._prefill_fns[extra_len] = fn
        return fn(self.frozen, self.adapters, self.quant_state,
                  jax.tree.map(jnp.asarray, batch))

    def decode_step(self, caches, token, pos):
        """One decode step -> (logits, new caches)."""
        if self._decode_fn is None:
            self._decode_fn = jax.jit(S.build_decode(self.cfg))
        return self._decode_fn(self.frozen, self.adapters, self.quant_state,
                               caches, token, jnp.asarray(pos, jnp.int32))

    # ---- serving ---------------------------------------------------------
    def engine(self, cfg=None, fresh: bool = False, obs=None, **legacy):
        """A ``repro.serving.Engine`` over this model (continuous batching:
        slot-pooled decode state for every family, mid-decode admission,
        per-request sampling). ``cfg`` is a ``serving.EngineConfig`` — THE
        knob surface (``max_slots`` / ``max_seq_len``, ``kv_layout="paged"``
        / ``kv_dtype="int8"`` / ``block_size`` / ``n_blocks`` /
        ``prefill_chunk`` / ``lazy_blocks``, ``prefix_share`` /
        ``radix_capacity``, ``state_dtype="int8"``); the historical loose
        spelling ``engine(max_slots=8, kv_layout="paged")`` still works via
        a warn-once deprecation shim and builds the identical config.

        Engines are cached per config — the frozen dataclass IS the cache
        key, so equivalent spellings (defaults written out or omitted,
        legacy kwargs or the dataclass) share one compiled engine.
        Oldest-evicted beyond ``_MAX_CACHED_ENGINES``, since each engine
        pins a device KV pool; ``fresh=True`` bypasses the cache (e.g. for
        independent ``EngineStats``).

        ``obs`` (a ``repro.obs.Obs``) attaches tracing/metrics. It is NOT
        part of the cache key — a cache hit rebinds the cached engine's
        handle when ``obs`` is given and leaves it untouched when omitted,
        so observability never forces a pool rebuild."""
        from repro.serving import Engine, EngineConfig
        from repro.serving.config import from_legacy_kwargs
        if cfg is None:
            cfg = from_legacy_kwargs(legacy)
        elif not isinstance(cfg, EngineConfig):
            raise TypeError(f"cfg must be an EngineConfig, got {type(cfg)}")
        elif legacy:
            raise TypeError(
                "pass either an EngineConfig or legacy engine knobs, "
                "not both")
        eng = None if fresh else self._engines.get(cfg)
        if eng is None:
            eng = Engine(self, cfg, obs=obs)
            if not fresh:
                while len(self._engines) >= self._MAX_CACHED_ENGINES:
                    self._engines.pop(next(iter(self._engines)))
                self._engines[cfg] = eng
        elif obs is not None:
            eng.set_obs(obs)
        return eng

    def generate(self, tokens, max_new: int = 32,
                 eos_id: Optional[int] = None, pad_id: int = 0,
                 input_embeds=None) -> jnp.ndarray:
        """Batched generation: (B, S) prompts -> (B, max_new) greedy tokens.

        A thin wrapper over a one-shot serving engine (every prompt gets a
        slot; rows retire independently) — EVERY family routes through
        ``serving.Engine``; the old lockstep loop is gone. With ``eos_id``
        set, a row stops at its EOS token and the remainder is
        ``pad_id``-padded; with ``eos_id=None`` every row spends the exact
        budget. ``input_embeds`` ((B, seq, d_model), optional) carries
        per-row encoder frames (encdec) or patch embeddings (vlm)."""
        tokens = np.asarray(tokens)
        bsz = tokens.shape[0]
        if max_new <= 0:
            return jnp.zeros((bsz, 0), jnp.int32)
        from repro.core.peft import n_prefix_tokens
        from repro.serving import EngineConfig, GenerationRequest
        embeds = None if input_embeds is None else np.asarray(input_embeds)
        max_seq = tokens.shape[1] + n_prefix_tokens(self.cfg.peft) + max_new
        if embeds is not None and self.cfg.family != "encdec":
            max_seq += embeds.shape[1]      # vlm patches take cache rows
        eng = self.engine(EngineConfig(max_slots=bsz, max_seq_len=max_seq))
        outs = eng.run([GenerationRequest(
            tokens[i], max_new_tokens=max_new, eos_id=eos_id,
            input_embeds=None if embeds is None else embeds[i])
            for i in range(bsz)])
        rows = [o.token_ids + [pad_id] * (max_new - o.n_generated)
                for o in outs]
        return jnp.asarray(np.asarray(rows, np.int32))
