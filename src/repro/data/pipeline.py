"""Deterministic synthetic data pipeline (offline container — no datasets).

The task is a noisy Markov language: a fixed random permutation pi over the
vocab generates next = pi[cur] with probability (1-eps), uniform otherwise.
The entropy floor is known analytically, LoRA-sized adapters learn it
quickly, and runs are bit-reproducible from the seed — so convergence
comparisons between quant modes (paper Fig. 6) are meaningful.

Host sharding: ``Loader`` takes (host_index, host_count) and yields only its
slice of each global batch, matching the multi-host pattern where each
process feeds its addressable shard of a globally-sharded array.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int            # global batch
    noise: float = 0.1         # eps: P(next != pi[cur])
    seed: int = 1234
    pad_id: int = 0
    with_embeds: int = 0       # vlm/encdec: also emit (B, n, d) embeddings
    embed_dim: int = 0


class SyntheticLM:
    """Markov chain over the vocab with a planted permutation."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.RandomState(cfg.seed)
        self.perm = rng.permutation(cfg.vocab_size)

    def sample(self, rng: np.random.RandomState, batch: int) -> np.ndarray:
        v, s = self.cfg.vocab_size, self.cfg.seq_len
        out = np.empty((batch, s + 1), np.int32)
        out[:, 0] = rng.randint(0, v, size=batch)
        for t in range(1, s + 1):
            nxt = self.perm[out[:, t - 1]]
            noise_mask = rng.rand(batch) < self.cfg.noise
            nxt = np.where(noise_mask, rng.randint(0, v, size=batch), nxt)
            out[:, t] = nxt
        return out

    def entropy_floor(self) -> float:
        """Per-token CE floor of the generating process (nats)."""
        v, eps = self.cfg.vocab_size, self.cfg.noise
        p_correct = (1 - eps) + eps / v
        p_other = eps / v
        return float(-(p_correct * np.log(p_correct)
                       + (v - 1) * p_other * np.log(max(p_other, 1e-12))))


class Loader:
    """Deterministic epoch-less loader, host-shardable."""

    def __init__(self, cfg: DataConfig, host_index: int = 0, host_count: int = 1):
        assert cfg.batch_size % host_count == 0
        self.cfg = cfg
        self.lm = SyntheticLM(cfg)
        self.host_index = host_index
        self.host_count = host_count
        self.local_batch = cfg.batch_size // host_count

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        # one RNG per (step, host) so every host draws a disjoint slice
        rng = np.random.RandomState(
            (self.cfg.seed * 1_000_003 + step) % (2 ** 31) + self.host_index)
        seqs = self.lm.sample(rng, self.local_batch)
        tokens = seqs[:, :-1]
        labels = seqs[:, 1:].astype(np.int32)
        out = {"tokens": tokens, "labels": labels}
        if self.cfg.with_embeds:
            out["embeds"] = rng.randn(
                self.local_batch, self.cfg.with_embeds, self.cfg.embed_dim
            ).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def calibration_batches(cfg: DataConfig, n_batches: int):
    """Paper §4.1: 512 calibration samples. Returns a list of batches drawn
    from a DISJOINT seed stream (calibration data != training data)."""
    calib_cfg = dataclasses.replace(cfg, seed=cfg.seed + 777_777)
    loader = Loader(calib_cfg)
    return [loader.batch(i) for i in range(n_batches)]
