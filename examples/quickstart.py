"""Quickstart: Quaff-quantized LoRA fine-tuning of a tiny LM through the
``repro.api`` facade — the whole paper pipeline (prepare -> calibrate ->
convert -> finetune -> evaluate -> save/load -> generate) in a screenful.

    PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import jax.numpy as jnp

from repro import api
from repro.core.peft import PEFTConfig
from repro.data.pipeline import DataConfig, Loader, calibration_batches
from repro.models.config import ModelConfig, QuantConfig, TrainConfig

cfg = ModelConfig(
    name="quickstart", family="dense", n_layers=4, d_model=128, n_heads=8,
    n_kv_heads=4, d_ff=256, vocab_size=512, head_dim=16,
    quant=QuantConfig(mode="fp32"),   # fp32 init; .convert() quantizes
    peft=PEFTConfig(method="lora", lora_rank=16))
data = DataConfig(vocab_size=512, seq_len=64, batch_size=8, noise=0.05)

# fp32 init -> calibrate outliers (paper §3.3, Eq. 6) -> one-time Quaff
# preprocessing (INT8 W, fp W_O rows, momentum state)
model = api.prepare(cfg)
model.calibrate(calibration_batches(data, 4))
model.convert("quaff")

# fine-tune: only the LoRA adapters train; s_t updates via Eq. 7
losses = model.finetune(TrainConfig(learning_rate=5e-3, microbatches=1),
                        Loader(data), steps=40, log_every=10)
s_mean = float(jnp.mean(model.quant_state["ffn"]["down"].s))
print(f"trained 40 steps: loss {losses[0]:.4f} -> {losses[-1]:.4f}  "
      f"mean s(down_proj) {s_mean:.3f}")

# evaluate
m = model.evaluate(Loader(data).batch(999))
print(f"final: loss {m['loss']:.4f}  ppl {m['ppl']:.2f}  acc {m['acc']:.3f}")

# checkpoint lifecycle: save -> load round-trips to bit-identical metrics
with tempfile.TemporaryDirectory() as ckpt_dir:
    model.save(ckpt_dir)
    restored = api.QuaffModel.load(ckpt_dir)
    m2 = restored.evaluate(Loader(data).batch(999))
    print(f"save->load round-trip bit-identical: {m == m2}")

# engine-backed greedy generation (see examples/serve_quantized.py for the
# full continuous-batching surface)
tokens = model.generate(Loader(data).batch(0)["tokens"][:, :16], max_new=8)
print(f"generated: {tokens[0].tolist()}")
