"""Quickstart: Quaff-quantized LoRA fine-tuning of a tiny LM in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Shows the whole public API surface: config -> fp32 init -> calibration ->
Quaff conversion -> train loop with momentum-scale updates -> eval.
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.core.peft import PEFTConfig
from repro.data.pipeline import DataConfig, Loader, calibration_batches
from repro.models import model as M
from repro.models.config import ModelConfig, QuantConfig, TrainConfig
from repro.train import calibrate, steps

cfg = ModelConfig(
    name="quickstart", family="dense", n_layers=4, d_model=128, n_heads=8,
    n_kv_heads=4, d_ff=256, vocab_size=512, head_dim=16,
    quant=QuantConfig(mode="fp32"),
    peft=PEFTConfig(method="lora", lora_rank=16))
data = DataConfig(vocab_size=512, seq_len=64, batch_size=8, noise=0.05)

# 1. initialize the full-precision model (base weights will be frozen)
frozen, adapters, qstate = M.init_params(jax.random.PRNGKey(0), cfg)

# 2. calibrate outlier channels on held-out data (paper §3.3, Eq. 6)
stats = calibrate.capture_stats(frozen, adapters, qstate, cfg,
                                calibration_batches(data, 4))

# 3. one-time Quaff preprocessing: INT8 W, fp W_O rows, momentum state
frozen_q, qstate = calibrate.convert(frozen, stats, cfg, "quaff")
cfg = dataclasses.replace(cfg, quant=dataclasses.replace(cfg.quant,
                                                         mode="quaff"))

# 4. fine-tune: only the LoRA adapters train; s_t updates via Eq. 7
tcfg = TrainConfig(learning_rate=5e-3, microbatches=1)
state = steps.init_train_state(adapters, qstate, tcfg)
train_step = jax.jit(steps.build_train_step(cfg, tcfg))
loader = Loader(data)
for i in range(40):
    state, metrics = train_step(frozen_q, state, jax.tree.map(
        jnp.asarray, loader.batch(i)))
    if i % 10 == 0:
        s_mean = float(jnp.mean(state.quant["ffn"]["down"].s))
        print(f"step {i:3d}  loss {float(metrics['loss']):.4f}  "
              f"mean s(down_proj) {s_mean:.3f}")

# 5. evaluate
ev = jax.jit(steps.build_eval_step(cfg))
m = ev(frozen_q, state.adapters, state.quant,
       jax.tree.map(jnp.asarray, loader.batch(999)))
print(f"final: loss {float(m['loss']):.4f}  ppl {float(m['ppl']):.2f}  "
      f"acc {float(m['acc']):.3f}")
