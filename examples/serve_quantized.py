"""Serving example: continuous batching with the Quaff INT8 path through
``repro.serving.Engine`` — a mixed-length request queue over a small slot
pool, quaff vs fp32, with greedy-token agreement and engine stats.

    PYTHONPATH=src python examples/serve_quantized.py
"""
import numpy as np

from repro import api
from repro.core.peft import PEFTConfig
from repro.data.pipeline import DataConfig, Loader
from repro.models.config import ModelConfig, QuantConfig
from repro.serving import EngineConfig, GenerationRequest, SamplingParams

N_REQ, SLOTS, PROMPT, MAX_NEW = 6, 2, 32, 24


def serve(mode: str):
    cfg = ModelConfig(
        name="serve-demo", family="dense", n_layers=6, d_model=256,
        n_heads=8, n_kv_heads=4, d_ff=512, vocab_size=1024, head_dim=32,
        quant=QuantConfig(mode=mode),
        peft=PEFTConfig(method="lora", lora_rank=8))
    model = api.prepare(cfg)
    prompts = np.asarray(Loader(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=PROMPT,
        batch_size=N_REQ)).batch(0)["tokens"])

    # mixed budgets: even requests use the full budget, odd ones a quarter —
    # the slot pool backfills retired slots instead of waiting lockstep
    engine = model.engine(EngineConfig(max_slots=SLOTS,
                                       max_seq_len=PROMPT + MAX_NEW),
                          fresh=True)
    outs = engine.run([
        GenerationRequest(prompts[i],
                          max_new_tokens=MAX_NEW if i % 2 == 0 else MAX_NEW // 4,
                          sampling=SamplingParams())        # greedy
        for i in range(N_REQ)])

    st = engine.stats
    print(f"[{mode:6s}] prefill {st.prefill_time_s*1e3:7.1f} ms | "
          f"decode {st.decode_steps} steps {st.decode_time_s*1e3:7.1f} ms "
          f"({st.decode_tokens_per_s:6.0f} tok/s, occ {st.occupancy:.0%}) | "
          f"slot-steps {st.slot_steps} vs {N_REQ*MAX_NEW} lockstep")
    return outs


if __name__ == "__main__":
    print(f"{N_REQ} requests over {SLOTS} slots, prompt {PROMPT}, "
          f"budget {MAX_NEW} (even) / {MAX_NEW//4} (odd)")
    out_q = serve("quaff")
    out_f = serve("fp32")
    toks_q = np.concatenate([o.token_ids for o in out_q])
    toks_f = np.concatenate([o.token_ids for o in out_f])
    agree = float(np.mean(toks_q == toks_f))
    print(f"greedy-token agreement quaff vs fp32: {agree:.2%}")
