"""Serving example: batched request handling with the Quaff INT8 path
through the ``repro.api`` facade — prefill a batch of prompts, then decode
with a shared KV cache, measuring per-phase throughput for quaff vs fp32.

    PYTHONPATH=src python examples/serve_quantized.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core.peft import PEFTConfig
from repro.data.pipeline import DataConfig, Loader
from repro.models.config import ModelConfig, QuantConfig

N_REQ, PROMPT, MAX_NEW = 4, 32, 24


def serve(mode: str):
    cfg = ModelConfig(
        name="serve-demo", family="dense", n_layers=6, d_model=256,
        n_heads=8, n_kv_heads=4, d_ff=512, vocab_size=1024, head_dim=32,
        quant=QuantConfig(mode=mode),
        peft=PEFTConfig(method="lora", lora_rank=8))
    model = api.prepare(cfg)
    prompts = jnp.asarray(Loader(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=PROMPT,
        batch_size=N_REQ)).batch(0)["tokens"])

    logits, caches = model.prefill({"tokens": prompts}, extra_len=MAX_NEW)
    jax.block_until_ready(logits)  # includes compile
    t0 = time.perf_counter()
    logits, caches = model.prefill({"tokens": prompts}, extra_len=MAX_NEW)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    toks = [tok]
    t0 = time.perf_counter()
    for i in range(MAX_NEW - 1):
        logits, caches = model.decode_step(caches, tok, PROMPT + i)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        toks.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0
    out = np.asarray(jnp.concatenate(toks, axis=1))
    print(f"[{mode:6s}] prefill {t_prefill*1e3:7.1f} ms | "
          f"decode {t_decode*1e3:7.1f} ms "
          f"({N_REQ*MAX_NEW/t_decode:6.0f} tok/s) | req0: {out[0][:8].tolist()}")
    return out


if __name__ == "__main__":
    print(f"{N_REQ} requests, prompt {PROMPT}, {MAX_NEW} new tokens")
    out_q = serve("quaff")
    out_f = serve("fp32")
    agree = float(np.mean(out_q == out_f))
    print(f"greedy-token agreement quaff vs fp32: {agree:.2%}")
