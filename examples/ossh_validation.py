"""OSSH validation experiment (paper Fig. 3): measure the hit rate of
calibration-predefined outlier channels against runtime outliers across
fine-tuning iterations, with the paper's non-uniform budget allocation.

    PYTHONPATH=src python examples/ossh_validation.py
"""
from benchmarks import bench_hitrate

print("OSSH hit-rate during fine-tuning (non-uniform per-layer budgets)")
for name, _, val in bench_hitrate.run(steps=12, uniform=False):
    print(f"  {name}: {val}")
print("uniform budgets (paper Fig. 9 — expected to be worse on volatile layers)")
for name, _, val in bench_hitrate.run(steps=12, uniform=True):
    print(f"  {name}: {val}")
