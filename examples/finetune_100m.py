"""End-to-end driver: Quaff LoRA fine-tuning of a ~100M-parameter dense LM
for a few hundred steps, with calibration, checkpointing, crash-resume and
a baseline comparison (quaff vs naive WAQ) at the end.

    PYTHONPATH=src python examples/finetune_100m.py [--steps 200]

~100M params: 12L x d_model 768 x d_ff 2048, vocab 8192 -> 98.7M.
On the CPU container this takes a few minutes; the identical code drives
the production configs via repro.launch.train.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import api
from repro.checkpoint.manager import CheckpointManager
from repro.core.peft import PEFTConfig
from repro.data.pipeline import DataConfig, Loader, SyntheticLM, calibration_batches
from repro.models.config import ModelConfig, QuantConfig, TrainConfig
from repro.train import steps


def build(mode: str):
    cfg = ModelConfig(
        name="lm-100m", family="dense", n_layers=12, d_model=768, n_heads=12,
        n_kv_heads=4, d_ff=2048, vocab_size=8192, head_dim=64,
        quant=QuantConfig(mode="fp32"),
        peft=PEFTConfig(method="lora", lora_rank=16))
    data = DataConfig(vocab_size=8192, seq_len=128, batch_size=8, noise=0.05)
    model = api.prepare(cfg)
    n_params = sum(x.size for x in jax.tree.leaves(model.frozen))
    print(f"[{mode}] base model: {n_params/1e6:.1f}M params (frozen)")
    if mode != "fp32":
        model.calibrate(calibration_batches(data, 2))
        model.convert(mode)
    return model, data


def train(mode: str, n_steps: int, ckpt_dir: str):
    model, data = build(mode)
    cfg, frozen = model.cfg, model.frozen
    tcfg = TrainConfig(learning_rate=2e-3, microbatches=2, remat=True)
    state = steps.init_train_state(model.adapters, model.quant_state, tcfg)
    mgr = CheckpointManager(f"{ckpt_dir}/{mode}", keep=2)
    start = 0
    if mgr.latest_step() is not None:
        state, meta = mgr.restore(state)
        start = meta["step"]
        print(f"[{mode}] resumed from step {start}")
    step_fn = jax.jit(steps.build_train_step(cfg, tcfg))
    loader = Loader(data)
    t0 = time.perf_counter()
    losses = []
    for i in range(start, n_steps):
        state, metrics = step_fn(frozen, state,
                                 jax.tree.map(jnp.asarray, loader.batch(i)))
        losses.append(float(metrics["loss"]))
        if i % 20 == 0:
            print(f"[{mode}] step {i:4d} loss {losses[-1]:.4f} "
                  f"({(time.perf_counter()-t0)/(i-start+1)*1e3:.0f} ms/step)")
        if (i + 1) % 50 == 0:
            mgr.save(i + 1, state)
    mgr.save(n_steps, state)
    mgr.wait()
    ev = jax.jit(steps.build_eval_step(cfg))
    m = ev(frozen, state.adapters, state.quant,
           jax.tree.map(jnp.asarray, loader.batch(10_000)))
    floor = SyntheticLM(data).entropy_floor()
    print(f"[{mode}] final loss {float(m['loss']):.4f} "
          f"(entropy floor {floor:.4f})  ppl {float(m['ppl']):.2f}  "
          f"acc {float(m['acc']):.3f}")
    return float(m["loss"])


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="checkpoints/finetune_100m")
    ap.add_argument("--modes", default="quaff,naive")
    args = ap.parse_args()
    results = {}
    for mode in args.modes.split(","):
        results[mode] = train(mode, args.steps, args.ckpt_dir)
    print("\nsummary:", {k: round(v, 4) for k, v in results.items()})
