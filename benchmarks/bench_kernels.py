"""Kernel-path timing + accuracy: Pallas (interpret) vs jnp oracle vs XLA
fp32 GEMM, for the Quaff W8A8 path and the packed-nibble INT4 path. On CPU
the interpret-mode timing is NOT a perf claim (the TPU roofline lives in
EXPERIMENTS.md); accuracy parity is the deliverable.

CLI (the CI bench-smoke job runs ``--tiny --json bench_kernels.json``):
  --tiny         shrink shapes so interpret-mode Pallas stays in seconds
  --json PATH    also dump rows + shape metadata as a JSON artifact
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.int4 import prepare_int4_weights
from repro.core.quaff_linear import prepare_quaff_weights, quaff_matmul
from repro.kernels import int4_matmul_fused, ops, ref


def _time(fn, *args, reps=3):
    fn(*args)  # warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def _quaff_rows(t, k, n, bt, bn, bk) -> list:
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (t, k)).at[:, 7].mul(90.0)
    w = jax.random.normal(k2, (k, n)) * 0.05
    idx = jnp.array([7, k // 4, (3 * k) // 4], jnp.int32)
    qw, st = prepare_quaff_weights(w, idx)
    s = jnp.array([8.0, 1.0, 1.0])

    us_core = _time(lambda: quaff_matmul(x, qw, s)[0])
    us_kernel = _time(lambda: ops.quaff_forward_pallas(
        x, qw, s, interpret=True, block_t=bt, block_n=bn, block_k=bk)[0])
    us_fp = _time(lambda: x @ w)

    y_k, _ = ops.quaff_forward_pallas(x, qw, s, interpret=True,
                                      block_t=bt, block_n=bn, block_k=bk)
    y_c, _ = quaff_matmul(x, qw, s)
    max_diff = float(jnp.max(jnp.abs(y_k - y_c)))
    return [
        ("kernel_quaff_core_jnp", us_core, "oracle"),
        ("kernel_quaff_pallas_interpret", us_kernel,
         f"max_diff_vs_core={max_diff:.2e}"),
        ("kernel_fp32_gemm", us_fp, "reference"),
    ]


def _int4_rows(t, k, n, bt, bn, bk, group_size) -> list:
    """Packed fused kernel vs the UNPACKED int8-carrier reference — the
    acceptance gate: the packed path must at least match the unpacked one
    (exact integer math, ULP-level fp epilogue noise only)."""
    key = jax.random.PRNGKey(1)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (t, k))
    w = jax.random.normal(k2, (k, n)) * 0.05
    wts = prepare_int4_weights(w, group_size=group_size)
    x_int, x_delta = quant.quantize(x, axis=-1, bits=8)

    # unpacked reference: same nibble values riding in full int8 bytes;
    # timed GEMM-to-GEMM against the fused kernel (both start from x_int)
    def unpacked_ref():
        return ref.int4_matmul_ref(x_int, wts.w_packed, x_delta,
                                   wts.w_delta)

    us_packed = _time(lambda: int4_matmul_fused(
        x_int, wts.w_packed, x_delta, wts.w_delta, block_t=bt, block_n=bn,
        block_k=bk, interpret=True))
    us_unpacked = _time(unpacked_ref)
    us_pipeline = _time(lambda: ops.int4_forward_pallas(
        x, wts, x_bits=8, interpret=True, block_t=bt, block_n=bn,
        block_k=bk))
    us_core = _time(lambda: quant.quantized_matmul_packed(
        x, wts.w_packed, wts.w_delta, x_bits=8))

    y_p = int4_matmul_fused(x_int, wts.w_packed, x_delta, wts.w_delta,
                            block_t=bt, block_n=bn, block_k=bk,
                            interpret=True)
    y_u = unpacked_ref()
    max_diff = float(jnp.max(jnp.abs(y_p - y_u)))
    scale = float(jnp.max(jnp.abs(y_u))) + 1e-12
    matches = max_diff <= 1e-4 * scale
    # vs the INDEPENDENT int8 carrier (not our own unpack) so a packing
    # regression to full bytes would show up as 1.00 here
    pack_ratio = (wts.w_packed.nbytes
                  / quant.quantize(w, axis=0, bits=4)[0].nbytes)
    return [
        ("kernel_int4_fused_pallas_interpret", us_packed,
         f"max_diff_vs_unpacked_ref={max_diff:.2e},matches_unpacked="
         f"{matches}"),
        ("kernel_int4_unpacked_ref_jnp", us_unpacked, "oracle"),
        ("kernel_int4_pipeline_pallas_interpret", us_pipeline,
         "rowmax+scale_quant+fused_gemm"),
        ("kernel_int4_packed_core_jnp", us_core,
         f"groups={wts.w_delta.shape[0]}"),
        ("kernel_int4_weight_bytes_ratio", 0.0, f"{pack_ratio:.2f}"),
    ]


def run(tiny: bool = False) -> list:
    if tiny:
        t, k, n, bt, bn, bk = 32, 128, 64, 16, 32, 32
    else:
        t, k, n, bt, bn, bk = 128, 512, 256, 64, 128, 128
    rows = _quaff_rows(t, k, n, bt, bn, bk)
    rows += _int4_rows(t, k, n, bt, bn, bk, group_size=k // 4)
    return rows


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--tiny", action="store_true",
                   help="CI smoke shapes (seconds in interpret mode)")
    p.add_argument("--json", metavar="PATH", default=None,
                   help="also write rows as a JSON artifact")
    args = p.parse_args(argv)
    rows = run(tiny=args.tiny)
    for r in rows:
        print(f"{r[0]},{r[1]:.1f},{r[2]}")
    if args.json:
        payload = {
            "benchmark": "bench_kernels",
            "tiny": args.tiny,
            "backend": jax.default_backend(),
            "rows": [{"name": r[0], "us_per_call": r[1], "derived": r[2]}
                     for r in rows],
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)


if __name__ == "__main__":
    main()
