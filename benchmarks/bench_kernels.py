"""Kernel-path timing + accuracy: Pallas (interpret) vs jnp oracle vs XLA
fp32 GEMM. On CPU the interpret-mode timing is NOT a perf claim (the TPU
roofline lives in EXPERIMENTS.md); accuracy parity is the deliverable."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.quaff_linear import prepare_quaff_weights, quaff_matmul
from repro.kernels import ops


def _time(fn, *args, reps=3):
    fn(*args)  # warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run() -> list:
    key = jax.random.PRNGKey(0)
    t, k, n = 128, 512, 256
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (t, k)).at[:, 7].mul(90.0)
    w = jax.random.normal(k2, (k, n)) * 0.05
    idx = jnp.array([7, 100, 300], jnp.int32)
    qw, st = prepare_quaff_weights(w, idx)
    s = jnp.array([8.0, 1.0, 1.0])

    us_core = _time(lambda: quaff_matmul(x, qw, s)[0])
    us_kernel = _time(lambda: ops.quaff_forward_pallas(
        x, qw, s, interpret=True, block_t=64, block_n=128, block_k=128)[0])
    us_fp = _time(lambda: x @ w)

    y_k, _ = ops.quaff_forward_pallas(x, qw, s, interpret=True,
                                      block_t=64, block_n=128, block_k=128)
    y_c, _ = quaff_matmul(x, qw, s)
    max_diff = float(jnp.max(jnp.abs(y_k - y_c)))
    return [
        ("kernel_quaff_core_jnp", us_core, "oracle"),
        ("kernel_quaff_pallas_interpret", us_kernel,
         f"max_diff_vs_core={max_diff:.2e}"),
        ("kernel_fp32_gemm", us_fp, "reference"),
    ]


def main():
    for r in run():
        print(f"{r[0]},{r[1]:.1f},{r[2]}")


if __name__ == "__main__":
    main()
