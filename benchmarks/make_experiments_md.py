"""Regenerates the data tables in EXPERIMENTS.md from experiments/dryrun/*
artifacts. The prose sections (§Perf narrative) live in EXPERIMENTS.md and
are not touched — this emits markdown to stdout for the table sections.

    PYTHONPATH=src python -m benchmarks.make_experiments_md > experiments/tables.md
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.roofline import DRYRUN_DIR, terms


def load(pattern):
    out = []
    for p in sorted(glob.glob(os.path.join(DRYRUN_DIR, pattern))):
        with open(p) as f:
            out.append(json.load(f))
    return out


def fmt_bytes(b):
    if b >= 1e12:
        return f"{b/1e12:.2f}TB"
    if b >= 1e9:
        return f"{b/1e9:.2f}GB"
    return f"{b/1e6:.1f}MB"


def dryrun_table(cells):
    print("| arch | shape | mesh | mb | args/dev | temp/dev | int8 GEMM FLOPs "
          "| fp GEMM FLOPs | collectives (AG/AR/RS/A2A/CP) | compile s |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in cells:
        h = r["hlo"]
        cb = h["collective_bytes"]
        coll = "/".join(fmt_bytes(cb.get(k, 0)) for k in
                        ("all-gather", "all-reduce", "reduce-scatter",
                         "all-to-all", "collective-permute"))
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
              f"| {r['microbatches']} "
              f"| {fmt_bytes(r['memory']['argument_bytes'])} "
              f"| {fmt_bytes(r['memory']['temp_bytes'])} "
              f"| {h['dot_flops_int8']:.2e} | {h['dot_flops_float']:.2e} "
              f"| {coll} | {r['compile_s']:.0f} |")


def roofline_table(cells):
    print("| arch | shape | compute s | memory s (model/upper) | collective s "
          "| dominant | MODEL/HLO flops | roofline frac | bottleneck lever |")
    print("|---|---|---|---|---|---|---|---|---|")
    levers = {
        ("compute",): "int8-ify remaining fp GEMMs (logits), remat=dots",
        ("memory",): "larger microbatches / fused epilogues / bf16 logits",
        ("collective",): "int8 payloads (FSDP gather, EP a2a, bwd dx)",
    }
    for r in cells:
        t = terms(r)
        lever = levers.get((t["dominant"],), "")
        print(f"| {t['arch']} | {t['shape']} | {t['compute_s']:.3f} "
              f"| {t['memory_s']:.3f} / {t['memory_upper_s']:.3f} "
              f"| {t['collective_s']:.3f} | **{t['dominant']}** "
              f"| {t['useful_ratio']:.3f} | {t['roofline_frac']:.3f} "
              f"| {lever} |")


def variant_table(arch, shape):
    cells = [r for r in load(f"{arch}__{shape}__1pod*.json")]
    if not cells:
        return
    print(f"\n#### {arch} x {shape} variants\n")
    print("| variant | compute s | memory s | collective s | dominant | "
          "frac | Δ dominant vs baseline |")
    print("|---|---|---|---|---|---|---|")
    base = None
    for r in cells:
        t = terms(r)
        v = r.get("variant", "baseline")
        dom_val = {"compute": t["compute_s"], "memory": t["memory_s"],
                   "collective": t["collective_s"]}[t["dominant"]]
        if v == "baseline":
            base = max(t["compute_s"], t["memory_s"], t["collective_s"])
        bound = max(t["compute_s"], t["memory_s"], t["collective_s"])
        delta = f"{(1 - bound/base)*100:+.1f}%" if base else "—"
        print(f"| {v} | {t['compute_s']:.3f} | {t['memory_s']:.3f} "
              f"| {t['collective_s']:.3f} | {t['dominant']} "
              f"| {t['roofline_frac']:.3f} | {delta} |")


def main():
    base_1pod = [r for r in load("*__1pod.json")]
    base_2pod = [r for r in load("*__2pod.json")]
    print(f"## §Dry-run ({len(base_1pod)} cells x 16x16, "
          f"{len(base_2pod)} cells x 2x16x16 — all compiled)\n")
    print("### Single-pod (16x16 = 256 chips)\n")
    dryrun_table(base_1pod)
    print("\n### Multi-pod (2x16x16 = 512 chips)\n")
    dryrun_table(base_2pod)
    print("\n## §Roofline (single-pod, per-device terms)\n")
    roofline_table(base_1pod)
    print("\n## §Perf variant measurements\n")
    for arch, shape in (("qwen2-7b", "train_4k"),
                        ("kimi-k2-1t-a32b", "train_4k"),
                        ("qwen2-7b", "decode_32k")):
        variant_table(arch, shape)


if __name__ == "__main__":
    main()
