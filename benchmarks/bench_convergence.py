"""Paper Fig. 6: convergence trajectories (loss vs step) for Quaff vs the
efficient baselines on the synthetic task — reports steps-to-threshold and
final loss."""
from __future__ import annotations

import numpy as np

from benchmarks import common


def run(steps: int = 30) -> list:
    dcfg = common.data_cfg(noise=0.05)
    rows = []
    for mode in ("fp32", "naive", "smooth_static", "quaff"):
        cfg, frozen, adapters, qstate = common.build_mode_model(mode, "lora",
                                                                dcfg)
        us, losses, _ = common.timed_train(cfg, frozen, adapters, qstate,
                                           dcfg, steps=steps, lr=5e-3)
        threshold = losses[0] - 0.5 * (losses[0] - min(losses))
        steps_to = next((i for i, l in enumerate(losses) if l < threshold),
                        steps)
        rows.append((f"fig6_convergence_{mode}", us,
                     f"final={np.mean(losses[-3:]):.4f};steps_to_half={steps_to}"))
    return rows


def main():
    for r in run():
        print(f"{r[0]},{r[1]:.1f},{r[2]}")


if __name__ == "__main__":
    main()
