"""Paper Fig. 2(c): quantization error of static scaling vs Quaff's targeted
momentum scaling on outlier-heavy activations whose outlier magnitudes SHIFT
over iterations (the distribution-shift failure mode of Smooth_S), plus the
packed-INT4 modes (per-OC and group-wise) on the same drift schedule."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import backend as BK
from repro.core import baselines as B
from repro.core.quaff_linear import prepare_quaff_weights, quaff_matmul
from repro.core.scaling import momentum_update


def run() -> list:
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    t, c_in, c_out = 128, 256, 128
    idx = jnp.array([11, 63, 200], jnp.int32)
    w = jax.random.normal(k2, (c_in, c_out)) * 0.05

    # calibration-time activations: outliers at 40x
    x_cal = jax.random.normal(k1, (t, c_in)).at[:, idx].mul(40.0)
    calib_absmax = jnp.max(jnp.abs(x_cal), axis=0)

    naive_w = B.prepare(B.QuantMode.NAIVE, w)
    smooth_w = B.prepare(B.QuantMode.SMOOTH_STATIC, w, calib_absmax=calib_absmax)
    quaff_w, qstate = prepare_quaff_weights(w, idx)
    w4a8 = BK.get_backend("int4_w4a8")
    w4a8_poc = w4a8.prepare(w, calib=BK.Calibration(init_placeholder=True))
    w4a8_g64 = w4a8.prepare(w, calib=BK.Calibration(init_placeholder=True,
                                                    group_size=64))

    rows = []
    # fine-tuning drift: outlier magnitude grows 40x -> 160x (Fig. 2b)
    for step, scale in enumerate([40.0, 80.0, 120.0, 160.0]):
        xk = jax.random.normal(jax.random.PRNGKey(10 + step), (t, c_in))
        xk = xk.at[:, idx].mul(scale)
        y_fp = xk @ w
        denom = float(jnp.mean(jnp.abs(y_fp)))

        y_n = B.naive_linear(xk, naive_w)
        y_s = B.smooth_static_linear(xk, smooth_w)
        y_q, stats = quaff_matmul(xk, quaff_w, qstate.s)
        qstate = momentum_update(qstate, stats, gamma=0.2)

        for name, y in (("naive", y_n), ("smooth_static", y_s),
                        ("quaff", y_q),
                        ("int4_w4a8", w4a8.apply(xk, w4a8_poc).y),
                        ("int4_w4a8_g64", w4a8.apply(xk, w4a8_g64).y)):
            rel = float(jnp.mean(jnp.abs(y - y_fp))) / denom
            rows.append((f"fig2c_err_{name}_scale{int(scale)}", 0.0,
                         f"{rel:.5f}"))
    return rows


def main():
    for r in run():
        print(f"{r[0]},{r[1]:.1f},{r[2]}")


if __name__ == "__main__":
    main()
