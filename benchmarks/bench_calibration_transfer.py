"""Paper Tab. 5: cross-dataset calibration — calibrate outlier channels on
corpus A, fine-tune/evaluate on corpus B (different seed streams = different
synthetic 'domains'), vs matched calibration."""
from __future__ import annotations

import dataclasses

import jax

from benchmarks import common
from repro.data.pipeline import calibration_batches
from repro.models import model as M
from repro.train import calibrate as C


def run(steps: int = 10) -> list:
    rows = []
    domains = {"domA": 111, "domB": 999}
    for calib_name, calib_seed in domains.items():
        for task_name, task_seed in domains.items():
            dcfg_task = common.data_cfg(seed=task_seed)
            dcfg_cal = common.data_cfg(seed=calib_seed)
            cfg0 = common.micro_phi3("fp32")
            frozen, adapters, qstate = M.init_params(jax.random.PRNGKey(0),
                                                     cfg0)
            stats = C.capture_stats(frozen, adapters, qstate, cfg0,
                                    calibration_batches(dcfg_cal, 4))
            fz, qs = C.convert(frozen, stats, cfg0, "quaff")
            cfg = dataclasses.replace(cfg0, quant=dataclasses.replace(
                cfg0.quant, mode="quaff"))
            us, losses, state = common.timed_train(
                cfg, fz, adapters, qs, dcfg_task, steps=steps, lr=2e-3)
            m = common.eval_model(cfg, fz, state.adapters, state.quant,
                                  dcfg_task)
            rows.append((f"tab5_calib_{calib_name}_task_{task_name}", us,
                         f"loss={m['loss']:.4f};acc={m['acc']:.4f}"))
    return rows


def main():
    for r in run():
        print(f"{r[0]},{r[1]:.1f},{r[2]}")


if __name__ == "__main__":
    main()
