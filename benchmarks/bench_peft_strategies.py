"""Paper Fig. 5: accuracy + fine-tuning cost across PEFT strategies
(LoRA / Prompt / P-tuning / IA3) x quant modes on the synthetic task."""
from __future__ import annotations

from benchmarks import common


def run(steps: int = 10) -> list:
    dcfg = common.data_cfg()
    rows = []
    for peft in ("lora", "prompt", "ptuning", "ia3"):
        for mode in ("fp32", "naive", "smooth_static", "quaff"):
            cfg, frozen, adapters, qstate = common.build_mode_model(
                mode, peft, dcfg)
            us, losses, state = common.timed_train(
                cfg, frozen, adapters, qstate, dcfg, steps=steps, lr=2e-3)
            m = common.eval_model(cfg, frozen, state.adapters, state.quant,
                                  dcfg)
            rows.append((f"fig5_{peft}_{mode}", us,
                         f"loss={m['loss']:.4f};acc={m['acc']:.4f}"))
    return rows


def main():
    for r in run():
        print(f"{r[0]},{r[1]:.1f},{r[2]}")


if __name__ == "__main__":
    main()
