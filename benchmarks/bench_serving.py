"""Continuous-batching serving benchmark: Engine throughput/latency vs the
lockstep decode loop on the same workload, via the real calibration +
conversion pipeline (micro Phi3 stand-in).

CLI (the CI serve-smoke job runs ``--tiny --json bench_serving.json``):

  --tiny         CI smoke shapes (seconds on CPU)
  --json PATH    dump rows + engine stats as a JSON artifact
  --mode MODE    quant mode to serve (default quaff)

Rows follow the bench_kernels convention: (name, us_per_call, derived).
``serving_engine_greedy_parity`` carries ``parity=True/False`` (engine
tokens vs lockstep on a shared batch) and ``serving_engine_mixed`` carries
``slot_steps=A<B=lockstep`` — the two gates CI checks.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

import common
from repro import api
from repro.data.pipeline import DataConfig, Loader
from repro.serving import GenerationRequest, SamplingParams


def _lockstep_tokens(model, prompts, max_new):
    import jax.numpy as jnp
    tokens = jnp.asarray(prompts)
    logits, caches = model.prefill({"tokens": tokens}, extra_len=max_new)
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    for i in range(max_new - 1):
        logits, caches = model.decode_step(caches, tok, tokens.shape[1] + i)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    return np.asarray(jnp.concatenate(out, axis=1))


def run(mode: str = "quaff", tiny: bool = False):
    if tiny:
        n_req, slots, plen, max_new = 4, 2, 8, 8
    else:
        n_req, slots, plen, max_new = 16, 4, 32, 32
    cfg, frozen, adapters, qstate = common.build_mode_model(
        mode, dcfg=common.data_cfg(batch=max(n_req, 4), seq=plen,
                                   vocab=512))
    model = api.QuaffModel(cfg, frozen, adapters, qstate)
    prompts = np.asarray(Loader(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=plen,
        batch_size=n_req)).batch(0)["tokens"])

    rows, extra = [], {}

    # ---- greedy parity gate: engine vs lockstep on a shared batch --------
    t0 = time.perf_counter()
    ref = _lockstep_tokens(model, prompts, max_new)
    t_lockstep = time.perf_counter() - t0
    eng = model.engine(max_slots=n_req, max_seq_len=plen + max_new,
                       fresh=True)
    outs = eng.run([GenerationRequest(p, max_new_tokens=max_new)
                    for p in prompts])
    got = np.asarray([o.token_ids for o in outs])
    parity = bool(np.array_equal(ref, got))
    rows.append(("serving_engine_greedy_parity",
                 (eng.stats.prefill_time_s + eng.stats.decode_time_s) * 1e6,
                 f"parity={parity}"))
    rows.append(("serving_lockstep_reference", t_lockstep * 1e6,
                 f"reqs={n_req} max_new={max_new}"))

    # ---- mixed-budget workload: the continuous-batching win --------------
    short = max(1, max_new // 4)
    eng2 = model.engine(max_slots=slots, max_seq_len=plen + max_new,
                        fresh=True)
    reqs = [GenerationRequest(prompts[i],
                              max_new_tokens=short if i % 2 else max_new)
            for i in range(n_req)]
    outs2 = eng2.run(reqs)
    st = eng2.stats
    lockstep_slot_steps = n_req * max_new
    rows.append((
        "serving_engine_mixed",
        (st.prefill_time_s + st.decode_time_s) * 1e6,
        f"slot_steps={st.slot_steps}<{lockstep_slot_steps}=lockstep "
        f"occupancy={st.occupancy:.2f} tok_s={st.decode_tokens_per_s:.1f}"))
    extra["mixed_stats"] = st.as_dict()
    extra["mixed_completed"] = sum(o.n_generated for o in outs2)

    # ---- seeded sampling path (throughput only) --------------------------
    eng3 = model.engine(max_slots=slots, max_seq_len=plen + max_new,
                        fresh=True)
    eng3.run([GenerationRequest(
        prompts[i], max_new_tokens=short,
        sampling=SamplingParams(temperature=0.8, top_k=50, top_p=0.95,
                                seed=i)) for i in range(slots)])
    rows.append(("serving_engine_sampled",
                 (eng3.stats.prefill_time_s + eng3.stats.decode_time_s) * 1e6,
                 f"tok_s={eng3.stats.decode_tokens_per_s:.1f}"))
    return rows, extra


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--tiny", action="store_true",
                   help="CI smoke shapes (seconds on CPU)")
    p.add_argument("--mode", default="quaff")
    p.add_argument("--json", metavar="PATH", default=None)
    args = p.parse_args(argv)
    rows, extra = run(mode=args.mode, tiny=args.tiny)
    for r in rows:
        print(f"{r[0]},{r[1]:.1f},{r[2]}")
    if args.json:
        payload = {
            "benchmark": "bench_serving",
            "tiny": args.tiny,
            "mode": args.mode,
            "backend": jax.default_backend(),
            "rows": [{"name": r[0], "us_per_call": r[1], "derived": r[2]}
                     for r in rows],
            **extra,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)


if __name__ == "__main__":
    main()
