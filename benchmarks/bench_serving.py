"""Continuous-batching serving benchmark: Engine throughput/latency vs the
lockstep decode loop on the same workload, via the real calibration +
conversion pipeline (micro Phi3 stand-in).

CLI (the CI serve-smoke job runs ``--tiny --json bench_serving.json``, a
paged sibling ``--tiny --kv-layout paged --json bench_serving_paged.json``
and per-family siblings ``--tiny --family ssm|hybrid`` gated on lockstep
parity):

  --tiny             CI smoke shapes (seconds on CPU)
  --json PATH        dump rows + engine stats as a JSON artifact
  --mode MODE        quant mode to serve (default quaff; dense only)
  --family F         dense (default) | ssm | hybrid | encdec — serve that
                     family's reduced arch through the engine and emit
                     tokens/s + state-bytes rows (incl. an int8
                     recurrent-state sibling for ssm/hybrid)
  --kv-layout L      contiguous (default) | paged — block-pool KV cache
  --kv-dtype D       fp (default) | int8 — paged-only quantized KV
  --prefill-chunk N  paged-only chunked admission (default plen/2 when paged)
  --prefix-share     radix/COW prefix-sharing rows instead: a shared-prefix
                     workload served with and without sharing, gated on
                     token-identical output + hit rate + chunks saved, for
                     BOTH fp and int8 KV
  --spec-decode      multi-step + self-speculative decode rows instead:
                     decode_steps=4 scheduled decode (token parity + a
                     tokens/s win over the decode_steps=1 baseline) and
                     quaff@8 self-speculation (greedy identity for fp AND
                     int8 KV, acceptance rate, steps/dispatch)
  --unified-step     unified mixed-batch step rows instead: a staggered
                     workload (ragged prompt lengths + budgets, so
                     admissions land mid-decode) served with
                     unified_step=True vs the two-dispatch baseline on
                     all four KV layouts (contiguous / paged / paged-int8
                     / paged-prefix), gated on greedy token identity,
                     pad_tokens_saved > 0, and a tokens/s win

Rows follow the bench_kernels convention: (name, us_per_call, derived).
``serving_engine_greedy_parity`` carries ``parity=True/False`` (engine
tokens vs lockstep on a shared batch) and ``serving_engine_mixed`` carries
``slot_steps=A<B=lockstep`` — the CI gates. A paged run adds
``serving_paged_kv_bytes`` (``bytes_per_req=A<B=contiguous``) and an int8
sibling of the mixed workload (``serving_paged_int8_kv_bytes``) gated on a
further bytes reduction. The JSON payload records the workload geometry
(n_requests / slots / prompt_len / max_new / max_seq_len) so
paged-vs-contiguous memory comparisons are reproducible from the artifact.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

import common
from repro import api
from repro import obs as OBS
from repro.data.pipeline import DataConfig, Loader
from repro.serving import EngineConfig, GenerationRequest, SamplingParams


def _metrics_obs():
    return OBS.Obs.from_config(OBS.ObsConfig(metrics=True))


def _latency_rows(obs, suffix):
    """p50/p95 TTFT + inter-token latency rows off the engine's obs
    histograms (us_per_call column = p95 in µs, the tail the row gates)."""
    rows = []
    for kind, hist in (("ttft", "ttft_s"), ("itl", "itl_s")):
        h = obs.metrics.histogram(hist)
        p50, p95 = h.percentile(50.0), h.percentile(95.0)
        rows.append((f"serving_{kind}_{suffix}", p95 * 1e6,
                     f"p50={p50 * 1e3:.2f}ms p95={p95 * 1e3:.2f}ms "
                     f"n={h.as_dict()['count']}"))
    return rows


def _lockstep_tokens(model, prompts, max_new):
    import jax.numpy as jnp
    tokens = jnp.asarray(prompts)
    logits, caches = model.prefill({"tokens": tokens}, extra_len=max_new)
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    for i in range(max_new - 1):
        logits, caches = model.decode_step(caches, tok, tokens.shape[1] + i)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    return np.asarray(jnp.concatenate(out, axis=1))


def build_family_model(family: str):
    """Reduced arch of a non-dense family, quaff placeholder-init — the
    SAME model tests/test_serving_families drives (shared recipe in
    ``repro.configs.reduced_family_demo``)."""
    from repro.configs import reduced_family_demo
    return api.prepare(reduced_family_demo(family))


def run_family(family: str, tiny: bool = False):
    """Per-family engine rows: lockstep parity gate, tokens/s, state bytes
    (+ an int8 recurrent-state sibling for the ssm/hybrid families)."""
    n_req, slots, plen, max_new = (4, 2, 8, 8) if tiny else (8, 4, 16, 16)
    model = build_family_model(family)
    cfg = model.cfg
    prompts = np.asarray(Loader(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=plen,
        batch_size=n_req)).batch(0)["tokens"])
    rows, extra = [], {}
    extra["workload"] = {"family": family, "n_requests": n_req,
                         "n_slots": slots, "prompt_len": plen,
                         "max_new": max_new, "max_seq_len": plen + max_new}

    ref = _lockstep_tokens(model, prompts, max_new)
    eng = model.engine(EngineConfig(max_slots=n_req,
                                    max_seq_len=plen + max_new), fresh=True)
    outs = eng.run([GenerationRequest(p, max_new_tokens=max_new)
                    for p in prompts])
    got = np.asarray([o.token_ids for o in outs])
    parity = bool(np.array_equal(ref, got))
    rows.append(("serving_engine_greedy_parity",
                 (eng.stats.prefill_time_s + eng.stats.decode_time_s) * 1e6,
                 f"parity={parity} family={family}"))

    # mixed budgets over a tight pool: the continuous-batching win
    short = max(1, max_new // 4)
    eng2 = model.engine(EngineConfig(max_slots=slots,
                                     max_seq_len=plen + max_new), fresh=True)
    eng2.run([GenerationRequest(prompts[i],
                                max_new_tokens=short if i % 2 else max_new)
              for i in range(n_req)])
    st = eng2.stats
    rows.append((
        "serving_engine_mixed",
        (st.prefill_time_s + st.decode_time_s) * 1e6,
        f"slot_steps={st.slot_steps}<{n_req * max_new}=lockstep "
        f"occupancy={st.occupancy:.2f} tok_s={st.decode_tokens_per_s:.1f}"))
    extra["mixed_stats"] = st.as_dict()
    rows.append((
        f"serving_{family}_state_bytes", 0.0,
        f"family={family} state_bytes_per_slot={st.state_bytes_per_slot} "
        f"kv_row_equiv={st.contiguous_bytes_per_request}"))

    if family in ("ssm", "hybrid"):
        eng3 = model.engine(EngineConfig(max_slots=slots,
                                         max_seq_len=plen + max_new,
                                         state_dtype="int8"), fresh=True)
        outs3 = eng3.run([GenerationRequest(p, max_new_tokens=max_new)
                          for p in prompts])
        st3 = eng3.stats
        same = sum(int(np.array_equal(a.token_ids, b.token_ids))
                   for a, b in zip(outs, outs3))
        rows.append((
            "serving_recurrent_int8_state_bytes",
            (st3.prefill_time_s + st3.decode_time_s) * 1e6,
            f"bytes_per_slot={st3.state_bytes_per_slot}"
            f"<{st3.fp_state_bytes_per_slot}=fp "
            f"streams_matching_fp={same}/{n_req}"))
        extra["int8_state_stats"] = st3.as_dict()
    return rows, extra


def run(mode: str = "quaff", tiny: bool = False,
        kv_layout: str = "contiguous", kv_dtype: str = "fp",
        prefill_chunk: int = -1):
    if tiny:
        n_req, slots, plen, max_new = 4, 2, 8, 8
    else:
        n_req, slots, plen, max_new = 16, 4, 32, 32
    paged = kv_layout == "paged"
    if prefill_chunk < 0:                   # default: exercise chunking
        prefill_chunk = plen // 2 if paged else 0
    block_size = 4 if tiny else 16          # blocks must subdivide the rows
    kv = dict(kv_layout=kv_layout, kv_dtype=kv_dtype, block_size=block_size,
              prefill_chunk=prefill_chunk) if paged else {}

    def ecfg(n_slots, **over):
        return EngineConfig(max_slots=n_slots, max_seq_len=plen + max_new,
                            **{**kv, **over})
    cfg, frozen, adapters, qstate = common.build_mode_model(
        mode, dcfg=common.data_cfg(batch=max(n_req, 4), seq=plen,
                                   vocab=512))
    model = api.QuaffModel(cfg, frozen, adapters, qstate)
    prompts = np.asarray(Loader(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=plen,
        batch_size=n_req)).batch(0)["tokens"])

    rows, extra = [], {}
    extra["workload"] = {"n_requests": n_req, "n_slots": slots,
                         "prompt_len": plen, "max_new": max_new,
                         "max_seq_len": plen + max_new,
                         "kv_layout": kv_layout, "kv_dtype": kv_dtype,
                         "block_size": block_size if paged else 0,
                         "prefill_chunk": prefill_chunk}

    # ---- greedy parity gate: engine vs lockstep on a shared batch --------
    t0 = time.perf_counter()
    ref = _lockstep_tokens(model, prompts, max_new)
    t_lockstep = time.perf_counter() - t0
    eng = model.engine(ecfg(n_req), fresh=True)
    outs = eng.run([GenerationRequest(p, max_new_tokens=max_new)
                    for p in prompts])
    got = np.asarray([o.token_ids for o in outs])
    parity = bool(np.array_equal(ref, got))
    rows.append(("serving_engine_greedy_parity",
                 (eng.stats.prefill_time_s + eng.stats.decode_time_s) * 1e6,
                 f"parity={parity} kv={kv_layout}/{kv_dtype}"))
    rows.append(("serving_lockstep_reference", t_lockstep * 1e6,
                 f"reqs={n_req} max_new={max_new} "
                 f"max_seq_len={plen + max_new}"))

    # ---- mixed-budget workload: the continuous-batching win --------------
    short = max(1, max_new // 4)

    def mixed_reqs():
        return [GenerationRequest(prompts[i],
                                  max_new_tokens=short if i % 2 else max_new)
                for i in range(n_req)]

    obs2 = _metrics_obs()
    eng2 = model.engine(ecfg(slots), fresh=True, obs=obs2)
    outs2 = eng2.run(mixed_reqs())
    st = eng2.stats
    lockstep_slot_steps = n_req * max_new
    rows.append((
        "serving_engine_mixed",
        (st.prefill_time_s + st.decode_time_s) * 1e6,
        f"slot_steps={st.slot_steps}<{lockstep_slot_steps}=lockstep "
        f"occupancy={st.occupancy:.2f} tok_s={st.decode_tokens_per_s:.1f}"))
    extra["mixed_stats"] = st.as_dict()
    extra["mixed_completed"] = sum(o.n_generated for o in outs2)
    if not paged:    # paged runs carry their own KV-bytes rows below
        rows.append((
            "serving_dense_state_bytes", 0.0,
            f"family=dense state_bytes_per_slot={st.state_bytes_per_slot} "
            f"kv_row_equiv={st.contiguous_bytes_per_request}"))
        rows += _latency_rows(obs2, "contiguous")
        extra["latency_contiguous"] = obs2.metrics.snapshot()["histograms"]

    # ---- paged telemetry: per-request KV bytes vs the contiguous row -----
    if paged:
        # the bytes rows always compare fp-paged and int8-paged engines on
        # the mixed workload, whatever dtype the CLI picked for the
        # throughput rows — reuse eng2 when it already is the right one
        def mixed_paged(dtype):
            if kv_dtype == dtype:
                return outs2, st, obs2
            obs = _metrics_obs()
            eng = model.engine(ecfg(slots, kv_dtype=dtype), fresh=True,
                               obs=obs)
            outs = eng.run(mixed_reqs())
            return outs, eng.stats, obs

        outs_fp, st_fp, _ = mixed_paged("fp")
        rows.append((
            "serving_paged_kv_bytes", 0.0,
            f"bytes_per_req={st_fp.kv_bytes_per_request:.0f}"
            f"<{st_fp.contiguous_bytes_per_request}=contiguous "
            f"frag={st_fp.mean_fragmentation:.2f} "
            f"peak_blocks={st_fp.peak_blocks_in_use}/{st_fp.n_blocks}"))
        # int8 sibling of the same mixed workload: ~4x fewer KV bytes on
        # top of the paging win (greedy tokens may shift within int8
        # precision on this random micro model; the bytes are the gate)
        outs4, st4, obs4 = mixed_paged("int8")
        same = sum(int(np.array_equal(a.token_ids, b.token_ids))
                   for a, b in zip(outs_fp, outs4))
        rows.append((
            "serving_paged_int8_kv_bytes",
            (st4.prefill_time_s + st4.decode_time_s) * 1e6,
            f"bytes_per_req={st4.kv_bytes_per_request:.0f}"
            f"<{st_fp.kv_bytes_per_request:.0f}=paged_fp "
            f"streams_matching_fp={same}/{n_req}"))
        extra["int8_stats"] = st4.as_dict()
        rows += _latency_rows(obs4, "paged_int8")
        extra["latency_paged_int8"] = obs4.metrics.snapshot()["histograms"]

    # ---- seeded sampling path (throughput only) --------------------------
    eng3 = model.engine(ecfg(slots), fresh=True)
    eng3.run([GenerationRequest(
        prompts[i], max_new_tokens=short,
        sampling=SamplingParams(temperature=0.8, top_k=50, top_p=0.95,
                                seed=i)) for i in range(slots)])
    rows.append(("serving_engine_sampled",
                 (eng3.stats.prefill_time_s + eng3.stats.decode_time_s) * 1e6,
                 f"tok_s={eng3.stats.decode_tokens_per_s:.1f}"))
    return rows, extra


def run_prefix(mode: str = "quaff", tiny: bool = False):
    """Radix/COW prefix-sharing rows: a shared-prefix workload (every
    request opens with the same system-prompt-style tokens) served with and
    without ``prefix_share``, for BOTH fp and int8 KV. The CI gates read
    ``parity`` (sharing must be invisible to outputs), ``hit_rate`` and
    ``chunks_saved`` off the row text."""
    n_req, slots, plen, max_new = (6, 2, 8, 4) if tiny else (12, 4, 32, 16)
    block_size = 4 if tiny else 16
    chunk = plen // 2
    cfg, frozen, adapters, qstate = common.build_mode_model(
        mode, dcfg=common.data_cfg(batch=max(n_req, 4), seq=plen, vocab=512))
    model = api.QuaffModel(cfg, frozen, adapters, qstate)
    prompts = np.asarray(Loader(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=plen,
        batch_size=n_req)).batch(0)["tokens"])
    opener = plen - 2                       # shared system-prompt opener;
    prompts[:, :opener] = prompts[0, :opener]   # last 2 tokens stay unique

    rows, extra = [], {}
    extra["workload"] = {"n_requests": n_req, "n_slots": slots,
                         "prompt_len": plen, "max_new": max_new,
                         "shared_prefix_len": opener,
                         "block_size": block_size, "prefill_chunk": chunk}

    base = EngineConfig(max_slots=slots, max_seq_len=plen + max_new,
                        kv_layout="paged", block_size=block_size,
                        prefill_chunk=chunk)
    for dtype in ("fp", "int8"):
        def run_one(share):
            eng = model.engine(dataclasses.replace(
                base, kv_dtype=dtype, prefix_share=share), fresh=True)
            outs = eng.run([GenerationRequest(p, max_new_tokens=max_new)
                            for p in prompts])
            return [o.token_ids for o in outs], eng.stats
        ref, _ = run_one(False)
        got, st = run_one(True)
        parity = ref == got
        rows.append((
            f"serving_prefix_share_{dtype}",
            (st.prefill_time_s + st.decode_time_s) * 1e6,
            f"parity={parity} hit_rate={st.prefix_hit_rate:.2f} "
            f"chunks_saved={st.prefill_chunks_saved} "
            f"tokens_saved={st.prefix_tokens_saved} "
            f"tok_s={st.decode_tokens_per_s:.1f} cow={st.cow_copies} "
            f"radix_blocks={st.radix_blocks}"))
        extra[f"prefix_stats_{dtype}"] = st.as_dict()
    return rows, extra


def run_spec(mode: str = "quaff", tiny: bool = False):
    """Multi-step + self-speculative decode rows. Gates the CI reads off
    the row text: ``parity`` (greedy token identity vs the same-layout
    classic engine, fp AND int8 KV), ``acceptance`` (> 0), and the
    multi-step ``tok_s=A>B=baseline`` dispatch-amortization win over the
    ``decode_steps=1`` no-spec baseline."""
    n_req, slots, plen, max_new = (4, 4, 8, 16) if tiny else (8, 8, 16, 32)
    block_size = 4 if tiny else 16
    steps, k = 4, 3
    int8_kv = dict(kv_layout="paged", kv_dtype="int8", block_size=block_size)
    spec = dict(spec_decode=True, spec_backend=f"{mode}@8", spec_k=k)
    cfg, frozen, adapters, qstate = common.build_mode_model(
        mode, dcfg=common.data_cfg(batch=max(n_req, 4), seq=plen, vocab=512))
    model = api.QuaffModel(cfg, frozen, adapters, qstate)
    prompts = np.asarray(Loader(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=plen,
        batch_size=n_req)).batch(0)["tokens"])

    def serve(over):
        eng = model.engine(EngineConfig(max_slots=slots,
                                        max_seq_len=plen + max_new, **over),
                           fresh=True)
        outs = eng.run([GenerationRequest(p, max_new_tokens=max_new)
                        for p in prompts])
        return [o.token_ids for o in outs], eng.stats

    variants = [{}, {"decode_steps": steps}, spec, int8_kv,
                {**int8_kv, **spec}]
    for over in variants:                   # compile every dispatch shape
        serve(over)                         # (jit caches are config-keyed)

    rows, extra = [], {}
    extra["workload"] = {"n_requests": n_req, "n_slots": slots,
                         "prompt_len": plen, "max_new": max_new,
                         "decode_steps": steps, "spec_k": k,
                         "spec_backend": spec["spec_backend"]}

    # best-of-two on the timed pair: the dispatch-amortization win is
    # structural (4 steps/dispatch) but CI CPU timing is noisy
    base, st0 = serve({})
    tok_base = max(st0.decode_tokens_per_s, serve({})[1].decode_tokens_per_s)
    ms, st_ms = serve({"decode_steps": steps})
    tok_ms = max(st_ms.decode_tokens_per_s,
                 serve({"decode_steps": steps})[1].decode_tokens_per_s)
    rows.append((
        "serving_multistep_decode",
        (st_ms.prefill_time_s + st_ms.decode_time_s) * 1e6,
        f"parity={base == ms} steps_per_dispatch={st_ms.steps_per_dispatch:.2f} "
        f"tok_s={tok_ms:.1f}>{tok_base:.1f}=baseline"))
    extra["multistep_stats"] = st_ms.as_dict()
    extra["baseline_stats"] = st0.as_dict()

    got_fp, st_fp = serve(spec)
    rows.append((
        "serving_spec_greedy_fp",
        (st_fp.prefill_time_s + st_fp.decode_time_s) * 1e6,
        f"parity={base == got_fp} acceptance={st_fp.acceptance_rate:.2f} "
        f"steps_per_dispatch={st_fp.steps_per_dispatch:.2f} "
        f"tok_s={st_fp.decode_tokens_per_s:.1f}"))
    extra["spec_stats_fp"] = st_fp.as_dict()

    base8, _ = serve(int8_kv)
    got8, st8 = serve({**int8_kv, **spec})
    rows.append((
        "serving_spec_greedy_int8",
        (st8.prefill_time_s + st8.decode_time_s) * 1e6,
        f"parity={base8 == got8} acceptance={st8.acceptance_rate:.2f} "
        f"steps_per_dispatch={st8.steps_per_dispatch:.2f} "
        f"tok_s={st8.decode_tokens_per_s:.1f}"))
    extra["spec_stats_int8"] = st8.as_dict()
    return rows, extra


def run_unified(mode: str = "quaff", tiny: bool = False):
    """Unified mixed-batch step rows: the SAME staggered workload (ragged
    prompt lengths and decode budgets, more requests than slots, so fresh
    admissions land while neighbours still decode) served with
    ``unified_step=True`` against the classic two-dispatch engine on all
    four KV layouts. The CI gates read ``parity`` (greedy token identity
    on every layout), ``saved`` (> 0: decode rows stopped paying
    idle-slot pad tokens), and the best-of-two ``tok_s=A>B=baseline``
    throughput comparison off the row text."""
    n_req, slots, plen, max_new = (6, 2, 8, 6) if tiny else (12, 4, 32, 16)
    block_size = 4 if tiny else 16
    chunk = max(1, plen // 2)
    cfg, frozen, adapters, qstate = common.build_mode_model(
        mode, dcfg=common.data_cfg(batch=max(n_req, 4), seq=plen, vocab=512))
    model = api.QuaffModel(cfg, frozen, adapters, qstate)
    full = np.asarray(Loader(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=plen,
        batch_size=n_req)).batch(0)["tokens"])
    # ragged lengths + staggered budgets: completions desync, slots refill
    # with fresh prefills mid-decode, and unified dispatches genuinely mix
    prompts = [full[i][: plen - (i % 3)].tolist() for i in range(n_req)]
    budgets = [max_new + (i % 3) for i in range(n_req)]
    opener = full[0][:block_size].tolist()  # block-aligned shared prefix

    layouts = {
        "contiguous": {},
        "paged": dict(kv_layout="paged", block_size=block_size,
                      prefill_chunk=chunk),
        "paged-int8": dict(kv_layout="paged", kv_dtype="int8",
                           block_size=block_size, prefill_chunk=chunk),
        "paged-prefix": dict(kv_layout="paged", block_size=block_size,
                             prefill_chunk=chunk, prefix_share=True),
    }

    rows, extra = [], {}
    extra["workload"] = {"n_requests": n_req, "n_slots": slots,
                         "prompt_len": plen, "max_new": max_new,
                         "max_seq_len": plen + block_size + max_new + 2,
                         "block_size": block_size, "prefill_chunk": chunk,
                         "staggered_lengths": [len(p) for p in prompts],
                         "budgets": budgets}

    def serve(work, **over):
        eng = model.engine(EngineConfig(
            max_slots=slots, max_seq_len=plen + block_size + max_new + 2,
            **over), fresh=True)
        outs = eng.run([GenerationRequest(p, max_new_tokens=b)
                        for p, b in zip(work, budgets)])
        return [o.token_ids for o in outs], eng.stats

    # ---- greedy token identity on every layout (also compiles both
    # dispatch shapes per config, so the timed pair below hits jit caches)
    parity = {}
    for name, kv in layouts.items():
        work = [opener + p for p in prompts] if "prefix" in name else prompts
        base, _ = serve(work, **kv)
        got, _ = serve(work, unified_step=True, **kv)
        parity[name] = base == got
    all_ok = all(parity.values())

    # ---- timed pair on the paged layout, best-of-two (CI CPU timing is
    # noisy; the packing win is structural)
    paged = layouts["paged"]

    def tok_s(st):
        return st.tokens_per_s

    _, st_b = serve(prompts, **paged)
    tok_base = max(tok_s(st_b), tok_s(serve(prompts, **paged)[1]))
    _, st_u = serve(prompts, unified_step=True, **paged)
    tok_uni = max(tok_s(st_u), tok_s(serve(prompts, unified_step=True,
                                           **paged)[1]))
    rows.append((
        "serving_unified_tokens_s",
        (st_u.prefill_time_s + st_u.decode_time_s
         + st_u.unified_time_s) * 1e6,
        f"parity={all_ok} tok_s={tok_uni:.1f}>{tok_base:.1f}=baseline "
        f"layouts={','.join(sorted(parity))}"))
    rows.append((
        "serving_pad_tokens_saved", 0.0,
        f"saved={st_u.pad_tokens_saved}>0 mixed={st_u.mixed_batches} "
        f"dispatches={st_u.unified_dispatches} "
        f"legacy_decode_pads={st_b.decode_pad_tokens}"))
    extra["unified_stats"] = st_u.as_dict()
    extra["baseline_stats"] = st_b.as_dict()
    extra["parity"] = parity
    return rows, extra


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--tiny", action="store_true",
                   help="CI smoke shapes (seconds on CPU)")
    p.add_argument("--mode", default="quaff")
    p.add_argument("--family", default="dense",
                   choices=["dense", "ssm", "hybrid", "encdec"])
    p.add_argument("--kv-layout", default="contiguous",
                   choices=["contiguous", "paged"])
    p.add_argument("--kv-dtype", default="fp", choices=["fp", "int8"])
    p.add_argument("--prefill-chunk", type=int, default=-1,
                   help="paged chunked admission; -1 = plen/2 default")
    p.add_argument("--prefix-share", action="store_true",
                   help="emit radix/COW prefix-sharing rows (fp + int8)")
    p.add_argument("--spec-decode", action="store_true",
                   help="emit multi-step + self-speculative decode rows "
                        "(greedy identity fp + int8, acceptance rate, "
                        "dispatch-amortization win)")
    p.add_argument("--unified-step", action="store_true",
                   help="emit unified mixed-batch step rows (4-layout "
                        "greedy identity, pad tokens saved, tokens/s win "
                        "over the two-dispatch baseline)")
    p.add_argument("--json", metavar="PATH", default=None)
    args = p.parse_args(argv)
    if args.unified_step:
        rows, extra = run_unified(mode=args.mode, tiny=args.tiny)
    elif args.spec_decode:
        rows, extra = run_spec(mode=args.mode, tiny=args.tiny)
    elif args.prefix_share:
        rows, extra = run_prefix(mode=args.mode, tiny=args.tiny)
    elif args.family != "dense":
        rows, extra = run_family(args.family, tiny=args.tiny)
    else:
        rows, extra = run(mode=args.mode, tiny=args.tiny,
                          kv_layout=args.kv_layout, kv_dtype=args.kv_dtype,
                          prefill_chunk=args.prefill_chunk)
    for r in rows:
        print(f"{r[0]},{r[1]:.1f},{r[2]}")
    if args.json:
        payload = {
            "benchmark": "bench_serving",
            "tiny": args.tiny,
            "mode": args.mode,
            "family": args.family,
            "backend": jax.default_backend(),
            "rows": [{"name": r[0], "us_per_call": r[1], "derived": r[2]}
                     for r in rows],
            **extra,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)


if __name__ == "__main__":
    main()
