"""Re-run the HLO analysis over saved .hlo.zst artifacts (no recompilation)
and update the JSON records in place. Used when launch/hloparse.py improves."""
import glob
import json
import os
import sys

import zstandard

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.launch import hloparse  # noqa: E402

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def main():
    for jpath in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        hpath = jpath.replace(".json", ".hlo.zst")
        if not os.path.exists(hpath):
            print(f"skip (no hlo): {os.path.basename(jpath)}")
            continue
        txt = zstandard.ZstdDecompressor().decompress(
            open(hpath, "rb").read(), max_output_size=2 ** 32).decode()
        s = hloparse.analyze(txt)
        with open(jpath) as f:
            rec = json.load(f)
        rec["hlo"] = s.to_json()
        with open(jpath, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"reanalyzed {os.path.basename(jpath)}: "
              f"int8={s.dot_flops_int8:.2e} fp={s.dot_flops_float:.2e}")


if __name__ == "__main__":
    main()
