"""Paper Tab. 7: outlier-channel budget sweep (0 / 0.1 / 1 / 3 / 5 %) —
quantization error of the Quaff linear against fp32 on drifting
outlier-heavy activations."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quaff_linear import prepare_quaff_weights, quaff_matmul
from repro.core.scaling import momentum_update


def run() -> list:
    key = jax.random.PRNGKey(0)
    t, c_in, c_out = 128, 1000, 256
    n_outliers = 50  # 5% of channels are genuinely outlier-prone
    k1, k2, k3 = jax.random.split(key, 3)
    true_idx = jnp.sort(jax.random.choice(k3, c_in, (n_outliers,),
                                          replace=False)).astype(jnp.int32)
    w = jax.random.normal(k2, (c_in, c_out)) * 0.05
    rows = []
    for frac in (0.0, 0.001, 0.01, 0.03, 0.05):
        k = max(0, int(round(frac * c_in)))
        idx = true_idx[:k] if k else jnp.array([0], jnp.int32)
        qw, st = prepare_quaff_weights(w, idx)
        errs = []
        for step in range(4):
            x = jax.random.normal(jax.random.PRNGKey(step), (t, c_in))
            x = x.at[:, true_idx].mul(60.0 + 30.0 * step)
            y_fp = x @ w
            y_q, stats = quaff_matmul(x, qw, st.s)
            st = momentum_update(st, stats, gamma=0.2)
            errs.append(float(jnp.mean(jnp.abs(y_q - y_fp))
                              / jnp.mean(jnp.abs(y_fp))))
        rows.append((f"tab7_budget_{frac:g}", 0.0,
                     f"rel_err={np.mean(errs):.5f}"))
    return rows


def main():
    for r in run():
        print(f"{r[0]},{r[1]:.1f},{r[2]}")


if __name__ == "__main__":
    main()
