"""Roofline table generator (EXPERIMENTS.md §Roofline): reads the dry-run
JSONs and derives the three terms per (arch x shape x mesh) cell.

  compute   = int8_flops/394T + float_flops/197T   (per device, s)
  memory    = hbm_bytes / 819 GB/s                 (per device, s)
  collective= collective_bytes / (4 links x 50 GB/s)

Hardware: TPU v5e — 197 TFLOP/s bf16 per chip (int8 MXU at 2x = 394 TOPS),
819 GB/s HBM, ~50 GB/s/link ICI with 4 links usable per chip for the 2D
torus (conservative; per-axis collectives use 2).

MODEL_FLOPS = 6*N_active*D analog computed from the architecture itself
(launch/specs.model_flops_per_token); the ratio MODEL_FLOPS / HLO_dot_FLOPs
flags remat/redundant compute (ratio < 1) or undercounting (> 1).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

PEAK_BF16 = 197e12
PEAK_INT8 = 394e12
HBM_BW = 819e9
ICI_BW = 4 * 50e9

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_cells(pattern: str = "*.json") -> List[Dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, pattern))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def terms(rec: Dict) -> Dict:
    hlo = rec["hlo"]
    compute = (hlo["dot_flops_int8"] / PEAK_INT8
               + hlo["dot_flops_float"] / PEAK_BF16)
    # TPU-fusion-aware memory model (see launch/hloparse.py); the raw
    # CPU-fusion-boundary figure is reported as memory_upper_s.
    memory = hlo.get("hbm_bytes_model", hlo["hbm_bytes"]) / HBM_BW
    memory_upper = hlo["hbm_bytes"] / HBM_BW
    coll = sum(hlo["collective_bytes"].values()) / ICI_BW
    dominant = max(("compute", compute), ("memory", memory),
                   ("collective", coll), key=lambda kv: kv[1])
    total_hlo_flops = hlo["dot_flops_int8"] + hlo["dot_flops_float"]
    model_flops = rec.get(
        "model_flops_per_step",
        rec["model_flops_per_token"] * rec["tokens_per_step"])
    n_dev = rec["n_devices"]
    bound = max(compute, memory, coll)
    mfu = (model_flops / n_dev / PEAK_BF16) / bound if bound else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": compute, "memory_s": memory, "collective_s": coll,
        "memory_upper_s": memory_upper,
        "dominant": dominant[0],
        "useful_ratio": (model_flops / n_dev) / max(total_hlo_flops, 1.0),
        "roofline_frac": min(1.0, mfu),
        "mem_gb": (rec["memory"]["argument_bytes"]
                   + rec["memory"]["temp_bytes"]) / 1e9,
        "mb": rec.get("microbatches", 1),
    }


def main() -> None:
    rows = [terms(r) for r in load_cells()]
    rows.sort(key=lambda r: (r["mesh"], r["arch"], r["shape"]))
    print("name,us_per_call,derived")
    for r in rows:
        name = f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}"
        us = max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6
        print(f"{name},{us:.1f},"
              f"dom={r['dominant']};frac={r['roofline_frac']:.3f};"
              f"compute_s={r['compute_s']:.4f};memory_s={r['memory_s']:.4f};"
              f"coll_s={r['collective_s']:.4f};"
              f"mem_upper_s={r['memory_upper_s']:.4f};"
              f"useful={r['useful_ratio']:.3f};"
              f"mem_gb={r['mem_gb']:.1f};mb={r['mb']}")


if __name__ == "__main__":
    main()
