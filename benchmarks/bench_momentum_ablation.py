"""Paper Tab. 3: Quaff vs Quaff-without-momentum (gamma such that s stays at
its initial value vs Eq. 7 updates) across PEFT strategies."""
from __future__ import annotations

import dataclasses

from benchmarks import common


def run(steps: int = 10) -> list:
    dcfg = common.data_cfg()
    rows = []
    for peft in ("lora", "prompt", "ptuning", "ia3"):
        for variant, gamma in (("quaff", 0.2), ("quaff_no_momentum", 1.0)):
            cfg, frozen, adapters, qstate = common.build_mode_model(
                "quaff", peft, dcfg)
            cfg = dataclasses.replace(cfg, quant=dataclasses.replace(
                cfg.quant, gamma=gamma))
            us, losses, state = common.timed_train(
                cfg, frozen, adapters, qstate, dcfg, steps=steps, lr=2e-3)
            m = common.eval_model(cfg, frozen, state.adapters, state.quant,
                                  dcfg)
            rows.append((f"tab3_{variant}_{peft}", us,
                         f"loss={m['loss']:.4f};acc={m['acc']:.4f}"))
    return rows


def main():
    for r in run():
        print(f"{r[0]},{r[1]:.1f},{r[2]}")


if __name__ == "__main__":
    main()
