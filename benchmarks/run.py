"""Benchmark harness — one module per paper table/figure. Prints
``name,us_per_call,derived`` CSV rows (per the assignment contract).

  bench_quant_error          Fig. 2(c)  static vs momentum scaling error
  bench_hitrate              Fig. 3/8/9 OSSH hit-rate, budget allocation
  bench_latency_modes        Tab. 1/2 + Fig. 4  latency/memory/metrics per mode
  bench_momentum_ablation    Tab. 3     momentum on/off x PEFT
  bench_budget               Tab. 7     outlier budget sweep
  bench_peft_strategies      Fig. 5     PEFT x mode
  bench_convergence          Fig. 6     steps-to-loss
  bench_calibration_transfer Tab. 5     cross-domain calibration
  bench_kernels              kernel parity/timing
  roofline                   §Roofline  (from dry-run artifacts, if present)
"""
import io
import sys
import traceback

MODULES = [
    "benchmarks.bench_quant_error",
    "benchmarks.bench_budget",
    "benchmarks.bench_kernels",
    "benchmarks.bench_latency_modes",
    "benchmarks.bench_convergence",
    "benchmarks.bench_momentum_ablation",
    "benchmarks.bench_peft_strategies",
    "benchmarks.bench_hitrate",
    "benchmarks.bench_calibration_transfer",
    "benchmarks.roofline",
]


def main() -> None:
    print("name,us_per_call,derived")
    for modname in MODULES:
        try:
            mod = __import__(modname, fromlist=["main"])
            buf = io.StringIO()
            stdout = sys.stdout
            sys.stdout = buf
            try:
                mod.main()
            finally:
                sys.stdout = stdout
            for line in buf.getvalue().splitlines():
                if line and not line.startswith("name,"):
                    print(line, flush=True)
        except Exception:
            print(f"{modname},0.0,ERROR", flush=True)
            traceback.print_exc(file=sys.stderr)


if __name__ == "__main__":
    main()
