"""Paper Tab. 1/2 + Fig. 4: per-step latency, parameter memory footprint and
task metrics for every WAQ mode under LoRA fine-tuning (CPU micro-scale
stand-in for Phi3-3.8B; ordering is what reproduces — Smooth_D and LLM.int8
pay per-step weight handling, Quaff doesn't)."""
from __future__ import annotations

from benchmarks import common


def run(steps: int = 8) -> list:
    dcfg = common.data_cfg()
    rows = []
    for mode in common.MODES:
        cfg, frozen, adapters, qstate = common.build_mode_model(mode, "lora",
                                                                dcfg)
        us, losses, state = common.timed_train(cfg, frozen, adapters, qstate,
                                               dcfg, steps=steps)
        metrics = common.eval_model(cfg, frozen, state.adapters, state.quant,
                                    dcfg)
        mem = common.param_footprint_bytes(frozen) / 1e6
        rows.append((f"tab1_latency_{mode}", us,
                     f"mem_mb={mem:.2f};loss={metrics['loss']:.4f};"
                     f"ppl={metrics['ppl']:.3f};acc={metrics['acc']:.4f}"))
    return rows


def main():
    for r in run():
        print(f"{r[0]},{r[1]:.1f},{r[2]}")


if __name__ == "__main__":
    main()
