"""Paper Fig. 3/8/9: OSSH validation — hit rate of calibration-predefined
outlier channels against runtime outliers across fine-tuning iterations,
non-uniform vs uniform budget allocation."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import outliers as OUT
from repro.core.backend import CAPTURE
from repro.data.pipeline import Loader
from repro.models import model as M
from repro.models.config import TrainConfig
from repro.train import calibrate as C
from repro.train import steps as S


def _hitrate(pre_idx: np.ndarray, live: np.ndarray, ratio: float = 20.0):
    hits = total = 0
    for layer in range(pre_idx.shape[0]):
        st = live[layer]
        runtime = np.nonzero(st > ratio * np.maximum(
            np.median(st), 1e-8))[0]
        total += len(runtime)
        hits += len(set(runtime.tolist()) & set(pre_idx[layer].tolist()))
    return (hits / total) if total else 1.0


def run(steps: int = 12, uniform: bool = False) -> list:
    dcfg = common.data_cfg()
    budgets = ({k: 0.02 for k in OUT.DEFAULT_BUDGETS} if uniform else None)
    cfg0 = common.micro_phi3("fp32")
    if budgets:
        cfg0 = dataclasses.replace(cfg0, quant=dataclasses.replace(
            cfg0.quant, budgets=budgets))
    frozen, adapters, qstate = M.init_params(jax.random.PRNGKey(0), cfg0)
    from repro.data.pipeline import calibration_batches
    stats = C.capture_stats(frozen, adapters, qstate, cfg0,
                            calibration_batches(dcfg, 4))
    fz, qs = C.convert(frozen, stats, cfg0, "quaff")
    cfg = dataclasses.replace(cfg0, quant=dataclasses.replace(
        cfg0.quant, mode="quaff"))

    tcfg = TrainConfig(microbatches=1, remat=False, learning_rate=2e-3)
    state = S.init_train_state(adapters, qs, tcfg)
    step = jax.jit(S.build_train_step(cfg, tcfg))
    loader = Loader(dcfg)

    pre = {name: np.asarray(fz["blocks"]["ffn"][name]["w"].outlier_idx)
           for name in ("down", "up")}
    pre["wo"] = np.asarray(fz["blocks"]["attn"]["wo"]["w"].outlier_idx)

    rows = []
    for i in range(steps):
        state, _ = step(fz, state, jax.tree.map(jnp.asarray, loader.batch(i)))
        if i % 4 == 3:
            live = M.forward(
                fz, state.adapters, state.quant,
                jnp.asarray(loader.batch(1000 + i)["tokens"]), cfg,
                scope=CAPTURE).stats
            hr_down = _hitrate(pre["down"], np.asarray(live["ffn"]["down"]))
            hr_o = _hitrate(pre["wo"], np.asarray(live["attn"]["wo"]))
            tag = "uniform" if uniform else "nonuniform"
            rows.append((f"fig3_hitrate_{tag}_down_step{i}", 0.0,
                         f"{hr_down:.3f}"))
            rows.append((f"fig3_hitrate_{tag}_oproj_step{i}", 0.0,
                         f"{hr_o:.3f}"))
    return rows


def main():
    for r in run(uniform=False) + run(uniform=True):
        print(f"{r[0]},{r[1]:.1f},{r[2]}")


if __name__ == "__main__":
    main()
