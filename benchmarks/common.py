"""Shared benchmark scaffolding: micro model builders (CPU-scale stand-ins
for Phi3-3.8B — the paper's default), per-mode conversion via the real
calibration pipeline, timed step loops, CSV emission."""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core.peft import PEFTConfig
from repro.data.pipeline import DataConfig, Loader, calibration_batches
from repro.models.config import ModelConfig, QuantConfig, TrainConfig
from repro.train import steps as S

MODES = ["fp32", "llm_int8", "smooth_dynamic", "naive", "smooth_static",
         "quaff"]


def micro_phi3(mode: str = "fp32", peft: str = "lora") -> ModelConfig:
    """Phi3-family reduced config (dense, MHA kv==heads, SwiGLU)."""
    return ModelConfig(
        name="phi3-micro", family="dense", n_layers=4, d_model=128,
        n_heads=8, n_kv_heads=8, d_ff=256, vocab_size=512, head_dim=16,
        quant=QuantConfig(mode=mode),
        peft=PEFTConfig(method=peft, lora_rank=16, lora_alpha=16.0))


def data_cfg(batch=8, seq=64, vocab=512, noise=0.1, seed=1234) -> DataConfig:
    return DataConfig(vocab_size=vocab, seq_len=seq, batch_size=batch,
                      noise=noise, seed=seed)


def build_mode_model(mode: str, peft: str = "lora", dcfg: Optional[DataConfig]
                     = None, calib_batches: int = 4, seed: int = 0):
    """FP32-init + real calibration + conversion to ``mode`` via repro.api.
    Returns (cfg, frozen, adapters, quant_state)."""
    dcfg = dcfg or data_cfg()
    model = api.prepare(micro_phi3("fp32", peft), seed=seed)
    if mode != "fp32":
        model.calibrate(calibration_batches(dcfg, calib_batches))
        model.convert(mode)
    return model.cfg, model.frozen, model.adapters, model.quant_state


def timed_train(cfg, frozen, adapters, qstate, dcfg: DataConfig,
                steps: int = 10, warmup: int = 2, lr: float = 2e-4,
                tcfg: Optional[TrainConfig] = None):
    """Returns (us_per_step, losses, final_state)."""
    tcfg = tcfg or TrainConfig(microbatches=1, remat=False, learning_rate=lr)
    state = S.init_train_state(adapters, qstate, tcfg)
    step = jax.jit(S.build_train_step(cfg, tcfg))
    loader = Loader(dcfg)
    losses: List[float] = []
    t0 = None
    for i in range(steps + warmup):
        batch = jax.tree.map(jnp.asarray, loader.batch(i))
        state, metrics = step(frozen, state, batch)
        losses.append(float(metrics["loss"]))
        if i + 1 == warmup:
            jax.block_until_ready(metrics["loss"])
            t0 = time.perf_counter()
    jax.block_until_ready(metrics["loss"])
    us = (time.perf_counter() - t0) / steps * 1e6
    return us, losses[warmup:], state


def eval_model(cfg, frozen, adapters, qstate, dcfg: DataConfig,
               n_batches: int = 4) -> Dict[str, float]:
    ev = jax.jit(S.build_eval_step(cfg))
    loader = Loader(dataclasses.replace(dcfg, seed=dcfg.seed + 555))
    out = {"loss": 0.0, "ppl": 0.0, "acc": 0.0}
    for i in range(n_batches):
        m = ev(frozen, adapters, qstate, jax.tree.map(jnp.asarray,
                                                      loader.batch(i)))
        for k in out:
            out[k] += float(m[k]) / n_batches
    return out


def param_footprint_bytes(frozen) -> int:
    return sum(np.asarray(l).nbytes for l in jax.tree.leaves(frozen))


def emit(rows: List[Tuple]):
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
