"""KV/state-cache correctness: token-by-token decode must reproduce the
teacher-forced (full forward) logits for every cache-bearing family. This is
the strongest single test of the serving path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.peft import PEFTConfig
from repro.models import model as M
from repro.models.config import QuantConfig
from repro.train import steps as S

SEQ = 16
BATCH = 2


def _reduced(arch):
    cfg = get_config(arch).reduced()
    overrides = {}
    if cfg.n_experts:
        # ample capacity: token DROPS differ between the full forward (all
        # tokens route together) and prefill/decode (fewer tokens per
        # routing group) — that's correct MoE capacity semantics, not a
        # cache bug; this test checks CACHES, so remove drops entirely.
        overrides["capacity_factor"] = 16.0
    return dataclasses.replace(
        cfg, quant=QuantConfig(mode="quaff"),
        peft=PEFTConfig(method="lora", lora_rank=4), **overrides)


@pytest.mark.parametrize("arch", [
    "tinyllama-1.1b",      # GQA dense
    "gemma3-27b",          # sliding window local:global
    "olmoe-1b-7b",         # MoE
    "zamba2-1.2b",         # mamba2 + shared attn hybrid
    "xlstm-350m",          # mLSTM/sLSTM
])
def test_decode_matches_teacher_forcing(arch):
    cfg = _reduced(arch)
    frozen, adapters, qstate = M.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (BATCH, SEQ), 0,
                                cfg.vocab_size)

    # teacher-forced full forward
    full_logits, _, _, _ = M.forward(frozen, adapters, qstate, tokens, cfg)

    # prefill on the first half, decode the second half token by token
    half = SEQ // 2
    prefill = S.build_prefill(cfg, extra_len=SEQ - half)
    decode = S.build_decode(cfg)
    logits_p, caches = prefill(frozen, adapters, qstate,
                               {"tokens": tokens[:, :half]})
    np.testing.assert_allclose(
        np.asarray(logits_p), np.asarray(full_logits[:, half - 1, :]),
        rtol=2e-2, atol=2e-2)

    for i in range(half, SEQ):
        logits_d, caches = decode(frozen, adapters, qstate, caches,
                                  tokens[:, i:i + 1],
                                  jnp.asarray(i, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(logits_d), np.asarray(full_logits[:, i, :]),
            rtol=2e-2, atol=2e-2,
            err_msg=f"{arch}: decode step {i} diverged from teacher forcing")


def test_decode_matches_teacher_forcing_whisper():
    cfg = _reduced("whisper-large-v3")
    frozen, adapters, qstate = M.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (BATCH, SEQ), 0,
                                cfg.vocab_size)
    frames = jax.random.normal(jax.random.PRNGKey(2),
                               (BATCH, cfg.encoder_seq, cfg.d_model))
    full_logits, _, _, _ = M.forward(frozen, adapters, qstate, tokens, cfg,
                                     input_embeds=frames)
    half = SEQ // 2
    prefill = S.build_prefill(cfg, extra_len=SEQ - half)
    decode = S.build_decode(cfg)
    logits_p, caches = prefill(frozen, adapters, qstate,
                               {"tokens": tokens[:, :half], "embeds": frames})
    np.testing.assert_allclose(
        np.asarray(logits_p), np.asarray(full_logits[:, half - 1, :]),
        rtol=2e-2, atol=2e-2)
    for i in range(half, SEQ):
        logits_d, caches = decode(frozen, adapters, qstate, caches,
                                  tokens[:, i:i + 1], jnp.asarray(i, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(logits_d), np.asarray(full_logits[:, i, :]),
            rtol=2e-2, atol=2e-2, err_msg=f"whisper decode step {i}")
