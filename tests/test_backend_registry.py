"""QuantBackend registry contract:

  * every registered mode round-trips prepare -> apply against the fp32
    reference within a mode-appropriate tolerance;
  * unknown-mode lookup raises a helpful error listing registered names;
  * a toy backend registered in-test flows through init_qlinear /
    apply_qlinear untouched by core edits (the extension point works);
  * the int4 proof-of-extension backend trains the quickstart config
    end-to-end through the repro.api facade with decreasing loss.
"""
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import backend as BK
from repro.core.peft import PEFTConfig
from repro.data.pipeline import DataConfig, Loader, calibration_batches
from repro.models import layers as L
from repro.models.config import ModelConfig, QuantConfig, TrainConfig

# per-mode mean-abs-error tolerance relative to the fp32 GEMM output scale
MODE_RTOL = {
    "fp32": 1e-6,
    "naive": 0.05,
    "llm_int8": 0.05,
    "smooth_static": 0.05,
    "smooth_dynamic": 0.05,
    "quaff": 0.05,
    "int4": 0.60,       # 4-bit weights AND activations: ~16x coarser grid
    "int4_w4a8": 0.35,  # 4-bit weights, int8 activations: weight error only
}


def _gemm_setup(seed=0, t=32, c_in=64, c_out=48):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (t, c_in))
    w = jax.random.normal(k2, (c_in, c_out)) * 0.1
    return x, w


def test_every_registered_mode_roundtrips():
    x, w = _gemm_setup()
    y_ref = x @ w
    scale = float(jnp.mean(jnp.abs(y_ref)))
    calib = BK.Calibration(
        absmax=jnp.max(jnp.abs(x), axis=0),
        outlier_idx=jnp.array([3, 17, 50], jnp.int32))
    # every builtin must be registered (in-test toys may add more)
    assert set(MODE_RTOL) <= set(BK.registered_modes())
    for mode in sorted(MODE_RTOL):
        backend = BK.get_backend(mode)
        wts = backend.prepare(w, calib=calib)
        out = backend.apply(x, wts, state=backend.init_state(wts))
        assert isinstance(out, BK.LinearOut), mode
        rel = float(jnp.mean(jnp.abs(out.y - y_ref))) / scale
        tol = MODE_RTOL.get(mode, 0.25)
        assert rel < tol, (mode, rel, tol)


def test_bias_is_applied_every_mode():
    x, w = _gemm_setup(seed=1)
    bias = jnp.linspace(-1.0, 1.0, w.shape[1])
    calib = BK.Calibration(absmax=jnp.max(jnp.abs(x), axis=0),
                           outlier_idx=jnp.array([5], jnp.int32))
    for mode in sorted(MODE_RTOL):
        backend = BK.get_backend(mode)
        w0 = backend.prepare(w, None, calib=calib)
        w1 = backend.prepare(w, bias, calib=calib)
        y0 = backend.apply(x, w0, state=backend.init_state(w0)).y
        y1 = backend.apply(x, w1, state=backend.init_state(w1)).y
        np.testing.assert_allclose(np.asarray(y1 - y0),
                                   np.broadcast_to(bias, y0.shape),
                                   rtol=1e-4, atol=1e-4, err_msg=mode)


def test_unknown_mode_error_lists_registered():
    with pytest.raises(ValueError) as ei:
        BK.get_backend("no_such_mode")
    msg = str(ei.value)
    assert "no_such_mode" in msg
    for mode in ("fp32", "quaff", "int4"):
        assert mode in msg, f"error should list registered mode {mode}"


# --------------------------------------------------------------------------
# Toy backend: registered here, never mentioned in core — must flow through
# init_qlinear / apply_qlinear purely via the registry.
# --------------------------------------------------------------------------
class _ToyWeights(NamedTuple):
    w: jnp.ndarray
    bias: jnp.ndarray = None


class _ToyBackend(BK.QuantBackend):
    """fp GEMM that also counts applications via stats (marker backend)."""

    name = "toy_halved"

    def prepare(self, w, bias=None, *, calib=None, bits=8):
        return _ToyWeights(0.5 * w, bias)  # marker: halved weights

    def apply(self, x, weights, *, state=None, bits=8, bwd_int8=True):
        return BK.LinearOut(x @ weights.w.astype(x.dtype))


BK.register(_ToyBackend())


def test_toy_backend_flows_through_qlinear():
    qcfg = QuantConfig(mode="toy_halved")
    lin, state = L.init_qlinear(jax.random.PRNGKey(0), 16, 8, "q_proj", qcfg)
    assert isinstance(lin["w"], _ToyWeights)
    assert state is None
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16))
    y, stats = L.apply_qlinear(x, lin, qcfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ lin["w"].w),
                               rtol=1e-6)
    assert stats is None
    # capture scope: toy backend gets full-absmax stats for free
    y2, stats2 = L.apply_qlinear(x, lin, qcfg, scope=BK.CAPTURE)
    np.testing.assert_allclose(np.asarray(stats2),
                               np.max(np.abs(np.asarray(x)), axis=0),
                               rtol=1e-6)


def _quickstart_cfg(mode="fp32"):
    return ModelConfig(
        name="quickstart", family="dense", n_layers=4, d_model=128, n_heads=8,
        n_kv_heads=4, d_ff=256, vocab_size=512, head_dim=16,
        quant=QuantConfig(mode=mode),
        peft=PEFTConfig(method="lora", lora_rank=16))


def test_int4_trains_quickstart_through_api():
    """Acceptance: the one-file int4 backend runs the quickstart pipeline
    end-to-end through repro.api with decreasing loss."""
    data = DataConfig(vocab_size=512, seq_len=64, batch_size=8, noise=0.05)
    model = api.prepare(_quickstart_cfg())
    model.calibrate(calibration_batches(data, 2))
    model.convert("int4")
    assert model.cfg.quant.mode == "int4"
    losses = model.finetune(TrainConfig(learning_rate=2e-2, microbatches=1,
                                        remat=False),
                            Loader(data), steps=80)
    assert np.all(np.isfinite(losses))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.15, losses
    m = model.evaluate(Loader(data).batch(999))
    assert np.isfinite(m["loss"])


def test_api_convert_requires_calibration_when_needed():
    model = api.prepare(_quickstart_cfg())
    with pytest.raises(ValueError, match="calibrate"):
        model.convert("quaff")  # wants_outliers but no .calibrate() yet
