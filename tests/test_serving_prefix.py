"""Radix/COW prefix sharing over the paged KV pool + the unified
EngineConfig surface.

Covers: refcounted block allocator invariants (a block referenced by any
table or the index is never freed or re-issued), the RadixIndex
(match/insert, LRU-leaf eviction, capacity bound), PagedPool prefix
admission (cold miss then hit, COW safety net, radix leaves yielding to
live requests under block pressure), engine-level token-identical output
with prefix sharing on for BOTH fp and int8 KV with
``prefill_chunks_saved > 0``, the EngineConfig validation/deprecation
shim (legacy kwargs build the identical frozen config, warn exactly
once, and share the engine-cache entry), and feature-gated
``EngineStats.as_dict`` telemetry.
"""
import warnings

import numpy as np
import pytest

from repro import api
from repro.core.peft import PEFTConfig
from repro.data.pipeline import DataConfig, Loader, calibration_batches
from repro.models.config import ModelConfig, QuantConfig, ServingConfig
from repro.serving import Engine, EngineConfig, GenerationRequest
from repro.serving.config import _reset_legacy_warning, from_legacy_kwargs
from repro.serving.paged.blocks import BlockAllocator
from repro.serving.paged.radix import RadixIndex
from repro.serving.params import EngineStats
from repro.serving.pool import PagedPool

VOCAB, PROMPT = 128, 8
OPENER = 6      # shared prompt opener length used by the engine tests


def _tiny_cfg(mode="fp32", **over):
    base = dict(
        name="prefix-test", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=VOCAB, head_dim=16,
        quant=QuantConfig(mode=mode),
        peft=PEFTConfig(method="lora", lora_rank=4))
    base.update(over)
    return ModelConfig(**base)


@pytest.fixture(scope="module")
def quaff_model():
    dcfg = DataConfig(vocab_size=VOCAB, seq_len=PROMPT, batch_size=4)
    model = api.prepare(_tiny_cfg())
    model.calibrate(calibration_batches(dcfg, 2))
    model.convert("quaff")
    return model


@pytest.fixture(scope="module")
def shared_prompts():
    """4 prompts sharing a 6-token opener (spans one full block at
    block_size=4, plus a partial block that must never be shared)."""
    toks = np.asarray(Loader(DataConfig(
        vocab_size=VOCAB, seq_len=PROMPT, batch_size=4)).batch(0)["tokens"])
    toks[:, :OPENER] = toks[0, :OPENER]
    return toks


# ---------------------------------------------------------------------------
# allocator refcounts
# ---------------------------------------------------------------------------
def test_fork_refcount_lifecycle():
    alloc = BlockAllocator(n_blocks=6, block_size=4)
    a = alloc.acquire(2)
    assert [alloc.refcount(b) for b in a] == [1, 1]
    alloc.fork(a)
    assert [alloc.refcount(b) for b in a] == [2, 2]
    assert alloc.n_shared == 2 and alloc.n_free == 4

    alloc.release(a)            # one ref down: still allocated
    assert [alloc.refcount(b) for b in a] == [1, 1]
    assert alloc.n_free == 4 and alloc.n_shared == 0
    alloc.release(a)            # last ref: actually freed
    assert [alloc.refcount(b) for b in a] == [0, 0]
    assert alloc.n_free == 6


def test_shared_block_never_reissued_while_referenced():
    """The allocator invariant the whole COW scheme rests on: a block with
    a live reference is never handed to another request."""
    alloc = BlockAllocator(n_blocks=4, block_size=4)
    shared = alloc.acquire(2)
    alloc.fork(shared)
    alloc.release(shared)       # forked ref still live
    grabbed = alloc.acquire(2)  # must come from the 2 untouched blocks
    assert grabbed is not None and not (set(grabbed) & set(shared))
    assert alloc.acquire(1) is None     # pool genuinely exhausted now


def test_fork_unallocated_raises():
    alloc = BlockAllocator(n_blocks=4, block_size=4)
    with pytest.raises(ValueError):
        alloc.fork([3])


# ---------------------------------------------------------------------------
# radix index
# ---------------------------------------------------------------------------
def test_radix_match_insert_roundtrip():
    idx = RadixIndex(block_size=4)
    toks = list(range(12))
    new_owned, evicted = idx.insert(toks, [7, 8, 9])
    assert new_owned == [7, 8, 9] and evicted == []
    assert idx.match(toks) == [7, 8, 9]
    assert idx.match(toks[:8]) == [7, 8]        # full-chunk prefix
    assert idx.match(toks[:7]) == [7]           # partial chunk ignored
    divergent = toks[:4] + [99, 99, 99, 99]
    assert idx.match(divergent) == [7]          # diverges after block 1


def test_radix_reinsert_owns_nothing_new():
    idx = RadixIndex(block_size=4)
    idx.insert(list(range(8)), [1, 2])
    new_owned, evicted = idx.insert(list(range(8)), [3, 4])
    assert new_owned == [] and evicted == []    # existing nodes keep blocks
    assert idx.match(list(range(8))) == [1, 2]


def test_radix_lru_leaf_eviction():
    idx = RadixIndex(block_size=4)
    idx.insert(list(range(12)), [1, 2, 3])      # chain of 3
    dropped = idx.evict(1)
    assert dropped == [3]                       # deepest leaf, never the root
    assert idx.match(list(range(12))) == [1, 2]
    assert idx.n_blocks == 2


def test_radix_capacity_bound():
    idx = RadixIndex(block_size=4, capacity=2)
    a = list(range(8))
    b = [50 + t for t in range(8)]
    idx.insert(a, [1, 2])
    idx.match(a)                                # refresh a's LRU ticks
    new_owned, evicted = idx.insert(b, [3, 4])
    assert idx.n_blocks <= 2
    assert evicted                              # something had to go
    assert set(evicted) <= {1, 2, 3, 4}


def test_radix_drop_all():
    idx = RadixIndex(block_size=4)
    idx.insert(list(range(8)), [1, 2])
    assert sorted(idx.drop_all()) == [1, 2]
    assert idx.n_blocks == 0 and idx.match(list(range(8))) == []


# ---------------------------------------------------------------------------
# paged pool: prefix admission, COW, pressure eviction
# ---------------------------------------------------------------------------
def _pool(n_slots=2, n_blocks=8, **over):
    kw = dict(block_size=4, kv_dtype="fp", n_blocks=n_blocks,
              prefix_share=True)
    kw.update(over)
    return PagedPool(_tiny_cfg(), n_slots, max_seq_len=16, **kw)


def test_pool_cold_miss_then_hit():
    pool = _pool()
    key = tuple(range(8))
    s0 = pool.acquire_prefix(key, 8)
    assert s0 is not None and pool.cursor(s0) == 0      # cold: nothing shared
    pool.advance(s0, 8)
    pool.index_insert(s0, key)
    pool.release(s0)
    assert pool.radix.n_blocks == 2     # both full blocks outlive the slot

    s1 = pool.acquire_prefix(key, 8)
    # identical request: shares capped at (len-1)//bs = 1 block — the last
    # token always re-prefills so logits come from a real forward pass
    assert pool.cursor(s1) == 4
    assert pool.prefix_hits == 1 and pool.prefix_tokens_saved == 4
    shared_block = pool.tables[s1].blocks[0]
    assert pool.alloc.refcount(shared_block) == 2       # index + this table


def test_pool_min_share_drops_partial_peft_cover():
    pool = _pool()
    key = tuple(range(8))
    s0 = pool.acquire_prefix(key, 8)
    pool.advance(s0, 8)
    pool.index_insert(s0, key)
    pool.release(s0)
    # a PEFT prefix longer than the matchable span: share must drop to zero
    s1 = pool.acquire_prefix(key, 8, min_share=6)
    assert pool.cursor(s1) == 0


def test_pool_cow_safety_net():
    pool = _pool()
    key = tuple(range(8))
    s0 = pool.acquire_prefix(key, 8)
    pool.advance(s0, 8)
    pool.index_insert(s0, key)
    pool.release(s0)
    s1 = pool.acquire_prefix(key, 8)
    shared_block = pool.tables[s1].blocks[0]
    assert pool.alloc.refcount(shared_block) == 2

    # natural flow never writes inside a shared block (writes start at the
    # block-aligned cursor) — force it to prove the safety net holds
    pool.tables[s1].n_tokens = 2
    assert pool.prepare_write(s1, 1)
    assert pool.cow_copies == 1
    new_block = pool.tables[s1].blocks[0]
    assert new_block != shared_block                    # private copy
    assert pool.alloc.refcount(shared_block) == 1       # index ref intact
    assert pool.alloc.refcount(new_block) == 1


def test_pool_radix_yields_under_block_pressure():
    pool = _pool(n_slots=2, n_blocks=4)
    key = tuple(range(8))
    s0 = pool.acquire_prefix(key, 8)        # 2 blocks
    pool.advance(s0, 8)
    pool.index_insert(s0, key)
    pool.release(s0)                        # index still pins both
    assert pool.alloc.n_free == 2

    other = tuple(100 + t for t in range(12))
    s1 = pool.acquire_prefix(other, 12)     # needs 3: must shed a leaf
    assert s1 is not None
    assert pool.radix_evictions >= 1
    assert pool.radix.n_blocks < 2
    # mapped blocks were never eviction candidates: the survivor chain is
    # intact from the root, and s1 holds 3 live blocks
    assert len(pool.tables[s1].blocks) == 3


def test_pool_drop_radix_frees_everything():
    pool = _pool()
    key = tuple(range(8))
    s0 = pool.acquire_prefix(key, 8)
    pool.advance(s0, 8)
    pool.index_insert(s0, key)
    pool.release(s0)
    assert pool.alloc.n_free < pool.alloc.n_blocks
    pool.drop_radix()
    assert pool.radix.n_blocks == 0
    assert pool.alloc.n_free == pool.alloc.n_blocks


# ---------------------------------------------------------------------------
# engine: token-identical sharing, fp AND int8 KV
# ---------------------------------------------------------------------------
def _ecfg(**over):
    kw = dict(max_slots=2, max_seq_len=PROMPT + 8, kv_layout="paged",
              block_size=4, prefill_chunk=4)
    kw.update(over)
    return EngineConfig(**kw)


@pytest.mark.parametrize("kv_dtype", ["fp", "int8"])
def test_engine_prefix_share_token_identical(quaff_model, shared_prompts,
                                             kv_dtype):
    max_new = 8
    reqs = lambda: [GenerationRequest(p, max_new_tokens=max_new)
                    for p in shared_prompts]
    ref_eng = Engine(quaff_model, _ecfg(kv_dtype=kv_dtype))
    ref = np.asarray([o.token_ids for o in ref_eng.run(reqs())])

    eng = Engine(quaff_model, _ecfg(kv_dtype=kv_dtype, prefix_share=True))
    got = np.asarray([o.token_ids for o in eng.run(reqs())])
    np.testing.assert_array_equal(ref, got)

    st = eng.stats
    assert st.prefix_share and st.prefix_queries == len(shared_prompts)
    assert st.prefix_hits > 0
    assert st.prefill_chunks_saved > 0      # the acceptance gate
    assert st.prefix_tokens_saved > 0
    assert st.radix_blocks > 0              # retired prompts stayed indexed


def test_engine_second_run_hits_harder(quaff_model, shared_prompts):
    eng = Engine(quaff_model, _ecfg(prefix_share=True))
    reqs = lambda: [GenerationRequest(p, max_new_tokens=4)
                    for p in shared_prompts]
    eng.run(reqs())
    first_hits = eng.stats.prefix_hits
    eng.run(reqs())     # identical prompts: every admission can now match
    assert eng.stats.prefix_hits >= first_hits + len(shared_prompts)


def test_engine_reset_prefix_cache(quaff_model, shared_prompts):
    eng = Engine(quaff_model, _ecfg(prefix_share=True))
    reqs = lambda: [GenerationRequest(p, max_new_tokens=4)
                    for p in shared_prompts]
    ref = np.asarray([o.token_ids for o in eng.run(reqs())])
    assert eng.stats.radix_blocks > 0
    eng.reset_prefix_cache()
    assert eng.stats.radix_blocks == 0
    # cold again, and still token-identical
    got = np.asarray([o.token_ids for o in eng.run(reqs())])
    np.testing.assert_array_equal(ref, got)


def test_engine_radix_capacity_respected(quaff_model, shared_prompts):
    eng = Engine(quaff_model, _ecfg(prefix_share=True, radix_capacity=1))
    eng.run([GenerationRequest(p, max_new_tokens=4) for p in shared_prompts])
    assert eng.stats.radix_blocks <= 1


# ---------------------------------------------------------------------------
# EngineConfig: validation, legacy shim, engine cache
# ---------------------------------------------------------------------------
def test_engine_config_validation():
    with pytest.raises(ValueError, match="prefix_share needs"):
        EngineConfig(prefix_share=True)                 # contiguous layout
    with pytest.raises(ValueError, match="radix_capacity needs"):
        EngineConfig(kv_layout="paged", radix_capacity=8)
    with pytest.raises(ValueError, match="kv_dtype"):
        EngineConfig(kv_dtype="int8")
    with pytest.raises(ValueError, match="max_slots"):
        EngineConfig(max_slots=0)


def test_legacy_kwargs_build_identical_config_and_warn_once():
    _reset_legacy_warning()
    with pytest.warns(DeprecationWarning):
        cfg = from_legacy_kwargs(dict(max_slots=8, max_seq_len=64,
                                      kv_layout="paged", kv_dtype="int8",
                                      block_size=4))
    assert cfg == EngineConfig(max_slots=8, max_seq_len=64,
                               kv_layout="paged", kv_dtype="int8",
                               block_size=4)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        from_legacy_kwargs(dict(max_slots=2))           # second use: silent
    assert not [w for w in caught
                if issubclass(w.category, DeprecationWarning)]


def test_legacy_kwargs_unknown_name_raises():
    with pytest.raises(TypeError, match="unknown engine"):
        from_legacy_kwargs(dict(max_slots=2, block_sizee=4))


def test_engine_cache_keyed_on_config(quaff_model):
    cfg = EngineConfig(max_slots=2, max_seq_len=16)
    e1 = quaff_model.engine(cfg)
    # equivalent legacy spelling resolves to the SAME cached engine
    e2 = quaff_model.engine(max_slots=2, max_seq_len=16)
    assert e1 is e2
    assert quaff_model.engine(cfg, fresh=True) is not e1
    with pytest.raises(TypeError, match="not both"):
        quaff_model.engine(cfg, max_slots=2)
    with pytest.raises(TypeError, match="EngineConfig"):
        quaff_model.engine({"max_slots": 2})


def test_serving_config_to_engine_config():
    scfg = ServingConfig(max_slots=3, max_seq_len=32, kv_layout="paged",
                         kv_dtype="int8", block_size=4, prefill_chunk=8,
                         prefix_share=True, radix_capacity=16)
    ecfg = scfg.to_engine_config()
    assert isinstance(ecfg, EngineConfig)
    assert (ecfg.max_slots, ecfg.max_seq_len) == (3, 32)
    assert (ecfg.kv_layout, ecfg.kv_dtype) == ("paged", "int8")
    assert (ecfg.prefix_share, ecfg.radix_capacity) == (True, 16)


# ---------------------------------------------------------------------------
# feature-gated telemetry
# ---------------------------------------------------------------------------
def test_as_dict_keys_follow_features_not_layout_strings():
    bare = EngineStats().as_dict()
    assert "peak_blocks_in_use" not in bare and "prefix_hits" not in bare
    # block telemetry keys off an actual block pool, not the layout string
    blocks = EngineStats(kv_layout="paged-v2", n_blocks=8).as_dict()
    assert "peak_blocks_in_use" in blocks and "prefix_hits" not in blocks
    shared = EngineStats(n_blocks=8, prefix_share=True,
                         prefix_queries=4, prefix_hits=3).as_dict()
    assert shared["prefix_hits"] == 3
    assert shared["prefix_hit_rate"] == 0.75
