"""Family-agnostic DecodeState pools: the serving engine must serve the
ssm / hybrid / encdec families token-identically to the pre-engine
lockstep loop (the old ``api.generate`` fallback, reproduced here on the
raw step builders), with mid-decode admission, slot-reset isolation,
int8 recurrent-state storage, and lazy paged-block growth.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.configs import get_config, reduced_family_demo
from repro.core.peft import PEFTConfig
from repro.data.pipeline import DataConfig, Loader, calibration_batches
from repro.models.config import QuantConfig
from repro.serving import Engine, GenerationRequest
from repro.serving.state import RecurrentPool

VOCAB, PROMPT = 512, 8

ARCH = {"ssm": "xlstm-350m", "hybrid": "zamba2-1.2b",
        "encdec": "whisper-large-v3"}


def _family_cfg(family):
    # shared with benchmarks/bench_serving (CI gates the same model)
    return reduced_family_demo(family)


@pytest.fixture(scope="module")
def models():
    return {fam: api.prepare(_family_cfg(fam)) for fam in ARCH}


@pytest.fixture(scope="module")
def prompts():
    return np.asarray(Loader(DataConfig(vocab_size=VOCAB, seq_len=PROMPT,
                                        batch_size=4)).batch(0)["tokens"])


def _lockstep_reference(model, prompts, max_new, embeds=None):
    """The pre-engine greedy loop, straight on the step builders (this WAS
    ``api._generate_lockstep`` before the fallback was deleted)."""
    tokens = jnp.asarray(prompts)
    prompt_len = tokens.shape[1]
    batch = {"tokens": tokens}
    if embeds is not None:
        batch["embeds"] = jnp.asarray(embeds)
    logits, caches = model.prefill(batch, extra_len=max_new)
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    for i in range(max_new - 1):
        logits, caches = model.decode_step(caches, tok, prompt_len + i)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    return np.asarray(jnp.concatenate(out, axis=1))


# ---------------------------------------------------------------------------
# engine-vs-lockstep greedy parity, every non-KV family
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("family", ["ssm", "hybrid", "encdec"])
def test_family_engine_greedy_parity(models, prompts, family):
    """Engine greedy decode must be token-identical to the lockstep loop
    (the acceptance criterion, per family)."""
    model, max_new = models[family], 8
    ref = _lockstep_reference(model, prompts, max_new)
    eng = Engine(model, max_slots=len(prompts),
                 max_seq_len=PROMPT + max_new)
    outs = eng.run([GenerationRequest(p, max_new_tokens=max_new)
                    for p in prompts])
    got = np.asarray([o.token_ids for o in outs])
    np.testing.assert_array_equal(ref, got)
    assert eng.stats.family == family
    assert eng.stats.requests_completed == len(prompts)
    assert eng.stats.state_bytes_per_slot > 0


@pytest.mark.parametrize("family", ["ssm", "hybrid", "encdec"])
def test_family_generate_is_engine_backed(models, prompts, family):
    """facade generate == lockstep reference, through the engine (the
    lockstep fallback is gone)."""
    model = models[family]
    ref = _lockstep_reference(model, prompts, 6)
    got = np.asarray(model.generate(prompts, max_new=6))
    np.testing.assert_array_equal(ref, got)
    assert model._engines, "generate() must route through a cached engine"


# ---------------------------------------------------------------------------
# scheduling: mid-decode admission + interleaved retire/admit
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("family", ["ssm", "hybrid"])
def test_family_mid_decode_admission(models, prompts, family):
    """Requests submitted while others are mid-decode produce the same
    tokens as a fresh batch run — recurrent-state admission (slot reset +
    live-masked carry) never perturbs live slots."""
    model, max_new = models[family], 6
    ref = _lockstep_reference(model, prompts, max_new)
    eng = Engine(model, max_slots=2, max_seq_len=PROMPT + max_new)
    for i in range(2):
        eng.submit(GenerationRequest(prompts[i], max_new_tokens=max_new,
                                     request_id=f"r{i}"))
    eng.step()
    eng.step()                      # two requests now mid-generation
    for i in range(2, 4):
        eng.submit(GenerationRequest(prompts[i], max_new_tokens=max_new,
                                     request_id=f"r{i}"))
    outs = {o.request_id: o for o in eng.run()}
    got = np.asarray([outs[f"r{i}"].token_ids for i in range(4)])
    np.testing.assert_array_equal(ref, got)


@pytest.mark.parametrize("family", ["ssm", "hybrid", "encdec"])
def test_family_interleaved_retire_admit_budgets(models, prompts, family):
    """Mixed budgets force retire-then-admit slot reuse; every stream must
    match its own single-request decode."""
    model = models[family]
    budgets = [3, 9, 5, 7]
    eng = Engine(model, max_slots=2, max_seq_len=PROMPT + max(budgets))
    outs = eng.run([GenerationRequest(prompts[i], max_new_tokens=b)
                    for i, b in enumerate(budgets)])
    for i, (b, out) in enumerate(zip(budgets, outs)):
        solo = _lockstep_reference(model, prompts[i:i + 1], b)
        np.testing.assert_array_equal(
            solo[0], np.asarray(out.token_ids),
            err_msg=f"{family} request {i} (budget {b}) diverged")
    assert eng.stats.slot_steps < len(budgets) * max(budgets)


# ---------------------------------------------------------------------------
# slot-reset isolation
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("family", ["ssm", "hybrid", "encdec"])
def test_slot_reset_isolation(models, prompts, family):
    """A retired request's state never leaks into its slot's next tenant:
    with ONE slot, the second request must match its solo decode exactly."""
    model, max_new = models[family], 6
    eng = Engine(model, max_slots=1, max_seq_len=PROMPT + max_new)
    outs = eng.run([GenerationRequest(prompts[0], max_new_tokens=max_new),
                    GenerationRequest(prompts[1], max_new_tokens=max_new)])
    solo = _lockstep_reference(model, prompts[1:2], max_new)
    np.testing.assert_array_equal(solo[0], np.asarray(outs[1].token_ids))


# ---------------------------------------------------------------------------
# encdec: per-request encoder frames
# ---------------------------------------------------------------------------
def test_encdec_engine_with_frames_parity(models, prompts):
    model, max_new = models["encdec"], 6
    cfg = model.cfg
    frames = np.asarray(jax.random.normal(
        jax.random.PRNGKey(7), (2, cfg.encoder_seq, cfg.d_model)))
    ref = _lockstep_reference(model, prompts[:2], max_new, embeds=frames)
    eng = Engine(model, max_slots=2, max_seq_len=PROMPT + max_new)
    outs = eng.run([GenerationRequest(prompts[i], max_new_tokens=max_new,
                                      input_embeds=frames[i])
                    for i in range(2)])
    got = np.asarray([o.token_ids for o in outs])
    np.testing.assert_array_equal(ref, got)
    # frames must actually matter: no-frames decode differs somewhere
    bare = _lockstep_reference(model, prompts[:2], max_new)
    assert not np.array_equal(ref, bare)


def test_encdec_frames_validation(models):
    model = models["encdec"]
    eng = Engine(model, max_slots=1, max_seq_len=PROMPT + 4)
    bad = np.zeros((3, model.cfg.d_model), np.float32)   # != encoder_seq
    with pytest.raises(ValueError, match="encoder_seq"):
        eng.submit(GenerationRequest(np.arange(4), max_new_tokens=2,
                                     input_embeds=bad))


# ---------------------------------------------------------------------------
# vlm: prepended patch embeddings (engine decode positions must account
# for the image-token offset — there is no lockstep reference, the old
# fallback never supported embeds, so the oracle is teacher forcing)
# ---------------------------------------------------------------------------
def test_vlm_engine_with_patches_matches_full_forward(prompts):
    cfg = dataclasses.replace(
        get_config("pixtral-12b").reduced(),
        quant=QuantConfig(mode="fp32"), peft=PEFTConfig(method="none"))
    model = api.prepare(cfg)
    max_new, bsz = 4, 2
    toks = prompts[:bsz, :6]
    patches = np.asarray(jax.random.normal(
        jax.random.PRNGKey(3), (bsz, cfg.n_image_tokens, cfg.d_model)))

    # teacher-forced oracle: re-run the full forward after each token
    cur = jnp.asarray(toks)
    ref = []
    for _ in range(max_new):
        logits = model.forward(cur, input_embeds=jnp.asarray(patches)).logits
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        ref.append(np.asarray(nxt))
        cur = jnp.concatenate([cur, nxt[:, None]], axis=1)
    ref = np.stack(ref, axis=1)

    got = np.asarray(model.generate(toks, max_new=max_new,
                                    input_embeds=patches))
    np.testing.assert_array_equal(ref, got)


def test_vlm_paged_rejects_embeds(prompts):
    cfg = dataclasses.replace(
        get_config("pixtral-12b").reduced(),
        quant=QuantConfig(mode="fp32"), peft=PEFTConfig(method="none"))
    model = api.prepare(cfg)
    eng = Engine(model, max_slots=1, max_seq_len=64, kv_layout="paged")
    patches = np.zeros((cfg.n_image_tokens, cfg.d_model), np.float32)
    with pytest.raises(ValueError, match="contiguous"):
        eng.submit(GenerationRequest(prompts[0][:4], max_new_tokens=2,
                                     input_embeds=patches))


# ---------------------------------------------------------------------------
# int8 recurrent state (OSSH-static channel scales)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("family", ["ssm", "hybrid"])
def test_recurrent_pool_int8_roundtrip(models, prompts, family):
    """Admitting a prefilled row into an int8 pool and reading it back
    must bound the per-leaf error by one quantization bin (margin check:
    bin width = channel absmax / 127), with dtype-verified int8 storage."""
    from repro.serving.state import _is_quantized_path
    from repro.runtime.treepath import path_str
    model = models[family]
    fp = Engine(model, max_slots=2, max_seq_len=PROMPT + 4)
    q = Engine(model, max_slots=2, max_seq_len=PROMPT + 4,
               state_dtype="int8")
    req = GenerationRequest(prompts[0], max_new_tokens=1)
    fp.run([req])
    q.run([dataclasses.replace(req, request_id=None)])
    assert isinstance(q._pool, RecurrentPool)
    flat_q = jax.tree_util.tree_flatten_with_path(q._pool.caches)[0]
    flat_f = jax.tree_util.tree_flatten_with_path(
        q._pool.live_assemble([True, False]))[0]
    flat_ref = jax.tree_util.tree_flatten_with_path(fp._pool.caches)[0]
    n_quant = 0
    for (p, leaf_q), (_, leaf_d), (_, leaf_r) in zip(flat_q, flat_f,
                                                     flat_ref):
        ps = path_str(p)
        if not _is_quantized_path(ps):
            continue
        n_quant += 1
        assert leaf_q.dtype == jnp.int8, ps
        scale = q._pool.scales[ps]
        err = np.abs(np.asarray(leaf_d, np.float32)
                     - np.asarray(leaf_r, np.float32))
        bound = np.broadcast_to(np.asarray(scale), leaf_d.shape)
        # one bin of the static grid, plus clip slack for the probe seed
        assert np.all(err <= 0.75 * bound + 1e-6), \
            f"{ps}: max err {err.max()} vs bin {bound.max()}"
    assert n_quant >= 1


def test_recurrent_int8_engine_completes_and_saves_bytes(models, prompts):
    model = models["ssm"]
    eng = Engine(model, max_slots=2, max_seq_len=PROMPT + 6,
                 state_dtype="int8")
    outs = eng.run([GenerationRequest(prompts[i], max_new_tokens=6)
                    for i in range(3)])
    assert all(o.n_generated == 6 for o in outs)
    st = eng.stats
    assert st.state_dtype == "int8"
    assert 0 < st.state_bytes_per_slot < st.fp_state_bytes_per_slot


def test_recurrent_int8_seeded_from_calibration(prompts):
    """A calibrated model carries per-channel STATE absmax in its capture;
    the int8 pool must seed its static grid from it (probe otherwise)."""
    cfg = _family_cfg("ssm")
    fp32 = dataclasses.replace(cfg, quant=QuantConfig(mode="fp32"))
    model = api.prepare(fp32)
    dcfg = DataConfig(vocab_size=VOCAB, seq_len=PROMPT, batch_size=4)
    model.calibrate(calibration_batches(dcfg, 2))
    model.convert("quaff")
    eng = Engine(model, max_slots=1, max_seq_len=PROMPT + 4,
                 state_dtype="int8")
    eng.run([GenerationRequest(prompts[0], max_new_tokens=2)])
    assert eng._pool.seeded_source == "calibration"

    bare = api.prepare(_family_cfg("ssm"))     # no capture -> probe seed
    eng2 = Engine(bare, max_slots=1, max_seq_len=PROMPT + 4,
                  state_dtype="int8")
    eng2.run([GenerationRequest(prompts[0], max_new_tokens=2)])
    assert eng2._pool.seeded_source == "probe"


# ---------------------------------------------------------------------------
# lazy paged-block allocation (KV families)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def dense_model():
    from repro.models.config import ModelConfig
    c = ModelConfig(
        name="lazy-test", family="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=VOCAB, head_dim=16,
        quant=QuantConfig(mode="fp32"),
        peft=PEFTConfig(method="none"))
    return api.prepare(c)


def test_lazy_blocks_grow_and_save(dense_model, prompts):
    """Lazy tables start at the prompt footprint and grow at decode time;
    EOS-stopping requests pin fewer blocks than the eager max_new
    reservation, and EngineStats reports the reserved-vs-used delta."""
    max_new = 16
    base = Engine(dense_model, max_slots=4, max_seq_len=PROMPT + max_new,
                  kv_layout="paged", block_size=4)
    ref0 = base.run([GenerationRequest(prompts[i], max_new_tokens=max_new)
                     for i in range(4)])
    eos = [int(o.token_ids[2]) for o in ref0]   # stop each row early

    def reqs():
        return [GenerationRequest(prompts[i], max_new_tokens=max_new,
                                  eos_id=eos[i]) for i in range(4)]

    eager = Engine(dense_model, max_slots=4, max_seq_len=PROMPT + max_new,
                   kv_layout="paged", block_size=4)
    lazy = Engine(dense_model, max_slots=4, max_seq_len=PROMPT + max_new,
                  kv_layout="paged", block_size=4, lazy_blocks=True)
    ref = eager.run(reqs())
    got = lazy.run(reqs())
    for a, b in zip(ref, got):
        assert a.token_ids == b.token_ids
    st = lazy.stats
    assert st.block_grows > 0
    assert st.lazy_blocks_saved_per_request > 0
    assert st.peak_blocks_in_use <= eager.stats.peak_blocks_in_use
    assert st.kv_bytes_per_request < eager.stats.kv_bytes_per_request


def test_lazy_blocks_preemption_unwedges(dense_model, prompts):
    """When every decoder is out of blocks, the youngest stream is
    preempted (requeued with its generated tokens) so the pool makes
    progress — and the preempted request still finishes with the exact
    greedy continuation."""
    max_new = 8
    # full need = 8 + 8 = 16 positions = 4 blocks/req; a pool of 6 blocks
    # admits both lazily (2+2), grows each once (3+3), then BOTH stall at
    # their next growth — only preemption can unwedge it.
    eng = Engine(dense_model, max_slots=2, max_seq_len=PROMPT + max_new,
                 kv_layout="paged", block_size=4, n_blocks=6,
                 lazy_blocks=True)
    ref = _lockstep_reference(dense_model, prompts[:2], max_new)
    outs = eng.run([GenerationRequest(prompts[i], max_new_tokens=max_new)
                    for i in range(2)])
    got = np.asarray([o.token_ids for o in outs])
    np.testing.assert_array_equal(ref, got)
    assert eng.stats.preemptions > 0
    assert eng.stats.block_stalls > 0
    assert eng.stats.requests_completed == 2
