"""repro.obs: span nesting + Chrome-trace schema, histogram percentile
math vs numpy, disabled-mode no-op guarantees, engine wiring (per-request
latency fields, trace + latency histograms), and the OSSH drift monitor
on a margin-checked fixture with engineered stable outlier channels."""
import json

import numpy as np
import pytest

from repro import api
from repro import obs as OBS
from repro.core.peft import PEFTConfig
from repro.data.pipeline import DataConfig, Loader
from repro.models.config import ModelConfig, QuantConfig, TrainConfig
from repro.obs import clock
from repro.serving import Engine, EngineConfig, GenerationRequest

VOCAB, PROMPT = 128, 8


def _tiny_cfg(mode="fp32"):
    return ModelConfig(
        name="obs-test", family="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=VOCAB, head_dim=16,
        quant=QuantConfig(mode=mode),
        peft=PEFTConfig(method="lora", lora_rank=4))


@pytest.fixture
def fake_clock():
    """Deterministic clock: each read advances 1ms."""
    state = {"t": 0.0}

    def tick():
        state["t"] += 1e-3
        return state["t"]

    prev = clock.set_source(tick)
    yield state
    clock.set_source(prev)


# ---------------------------------------------------------------- trace


def test_span_nesting_and_chrome_schema(fake_clock, tmp_path):
    tr = OBS.Tracer()
    with tr.span("outer", cat="test", a=1):
        with tr.span("inner", cat="test"):
            tr.instant("mark", cat="test")
        tr.counter("depth", {"value": 1})
    tr.async_begin("request", 7, prompt_len=4)
    tr.async_instant("request", 7, "first_token")
    tr.async_end("request", 7, reason="length")
    assert tr.open_spans() == {}

    payload = tr.to_chrome_trace()
    assert OBS.validate_chrome_trace(payload) is None
    evs = [e for e in payload["traceEvents"] if e["ph"] != "M"]
    names = [e["name"] for e in evs]
    # B/E properly nested: inner closes before outer
    assert names.index("inner") > names.index("outer")
    b_inner = next(e for e in evs if e["name"] == "inner" and e["ph"] == "B")
    e_inner = next(e for e in evs if e["name"] == "inner" and e["ph"] == "E")
    b_outer = next(e for e in evs if e["name"] == "outer" and e["ph"] == "B")
    e_outer = next(e for e in evs if e["name"] == "outer" and e["ph"] == "E")
    assert b_outer["ts"] < b_inner["ts"] <= e_inner["ts"] < e_outer["ts"]
    # async lane events carry a shared id
    reqs = [e for e in evs if e["name"] == "request"]
    assert {e["ph"] for e in reqs} == {"b", "n", "e"}
    assert len({e["id"] for e in reqs}) == 1

    out = tmp_path / "trace.json"
    tr.write(str(out))
    assert OBS.validate_chrome_trace(json.loads(out.read_text())) is None


def test_unbalanced_trace_is_rejected():
    tr = OBS.Tracer()
    tr._begin("dangling", "test", clock.now(), {}, OBS.TID_ENGINE)
    err = OBS.validate_chrome_trace(tr.to_chrome_trace())
    assert err is not None and "dangling" in err


# -------------------------------------------------------------- metrics


def test_histogram_percentiles_match_numpy():
    rng = np.random.default_rng(0)
    samples = rng.uniform(0.001, 1.0, size=2000)
    h = OBS.Histogram("lat_s", buckets=OBS.DEFAULT_LATENCY_BUCKETS)
    for s in samples:
        h.observe(float(s))
    for p in (50.0, 95.0, 99.0):
        true = float(np.quantile(samples, p / 100.0))
        est = h.percentile(p)
        # bucket-width accuracy: the estimate interpolates inside the
        # bucket containing the true quantile
        edges = [0.0] + list(OBS.DEFAULT_LATENCY_BUCKETS)
        hi = next(b for b in edges[1:] if true <= b)
        lo = edges[edges.index(hi) - 1]
        assert lo <= est <= hi, (p, true, est, lo, hi)
    d = h.as_dict()
    assert d["count"] == 2000
    assert d["sum"] == pytest.approx(float(samples.sum()), rel=1e-6)
    assert d["min"] == pytest.approx(samples.min())
    assert d["max"] == pytest.approx(samples.max())


def test_histogram_empty_and_overflow():
    h = OBS.Histogram("x", buckets=(1.0, 2.0))
    assert np.isnan(h.percentile(50.0))
    h.observe(5.0)  # beyond the last bucket -> overflow bucket
    # overflow interpolates between the last edge and the observed max
    assert 2.0 <= h.percentile(50.0) <= 5.0
    assert h.percentile(100.0) == pytest.approx(5.0)


def test_registry_snapshot_and_prometheus():
    reg = OBS.MetricsRegistry()
    reg.inc("requests", 2)
    reg.set_gauge("jaccard", 0.75, labels={"layer": "wq"})
    reg.observe("ttft_s", 0.05)
    snap = reg.snapshot()
    assert snap["counters"]["requests"] == 2
    assert snap["gauges"]["jaccard{layer=wq}"] == 0.75
    assert snap["histograms"]["ttft_s"]["count"] == 1
    text = reg.to_prometheus()
    assert "# TYPE requests counter" in text
    assert 'jaccard{layer="wq"} 0.75' in text
    assert 'ttft_s_bucket{le="+Inf"} 1' in text
    assert "ttft_s_count 1" in text


# -------------------------------------------------------- disabled mode


def test_disabled_mode_is_true_noop():
    before = OBS.mutation_count()
    obs = OBS.NULL_OBS
    assert not obs.enabled
    # span path: the module singleton, no allocation, no clock
    s = obs.span("anything", cat="x", step=3)
    assert s is OBS.NULL_SPAN
    with s:
        pass
    assert s.elapsed_s == 0.0
    obs.inc("c")
    obs.set_gauge("g", 1.0)
    obs.observe("h", 0.5)
    obs.instant("i")
    obs.async_begin("r", 1)
    obs.async_end("r", 1)
    obs.counter("k", {"v": 1})
    assert obs.export() == {}
    assert OBS.mutation_count() == before  # zero registry mutations


def test_null_obs_phase_pair_still_times(fake_clock):
    """EngineStats accounting must work with observability off: the
    phase pair reads the clock (CI gates on decode tokens/s > 0) but
    emits nothing."""
    obs = OBS.NULL_OBS
    t0 = obs.phase_begin("decode")
    dt = obs.phase_end("decode", t0, hist="decode_dispatch_s")
    assert dt == pytest.approx(1e-3)  # exactly two fake-clock ticks


# ------------------------------------------------------- engine wiring


def test_engine_request_latency_and_trace():
    model = api.prepare(_tiny_cfg())
    obs = OBS.Obs.from_config(OBS.ObsConfig(trace=True, metrics=True))
    eng = Engine(model, EngineConfig(max_slots=2, max_seq_len=PROMPT + 4),
                 obs=obs)
    prompts = np.asarray(Loader(DataConfig(
        vocab_size=VOCAB, seq_len=PROMPT, batch_size=3)).batch(0)["tokens"])
    outs = eng.run([GenerationRequest(p, max_new_tokens=4) for p in prompts])

    # satellite: RequestOutput latency fields always populated
    for o in outs:
        assert o.ttft_s > 0.0
        assert o.e2e_s >= o.ttft_s
        assert o.queue_s >= 0.0
    # 3 requests on 2 slots: someone waited in the queue
    assert max(o.queue_s for o in outs) > 0.0

    payload = obs.tracer.to_chrome_trace()
    assert OBS.validate_chrome_trace(payload) is None
    names = {e["name"] for e in payload["traceEvents"]}
    assert {"prefill", "decode", "request", "first_token"} <= names

    snap = obs.metrics.snapshot()
    assert snap["counters"]["requests_submitted"] == 3
    assert snap["counters"]["requests_completed"] == 3
    assert snap["histograms"]["ttft_s"]["count"] == 3
    assert snap["histograms"]["itl_s"]["count"] == 3 * (4 - 1)
    assert snap["histograms"]["e2e_s"]["count"] == 3


# --------------------------------------------------------- OSSH drift


def test_ossh_drift_monitor_on_finetune():
    """Margin-checked fixture: inflating a few embedding columns 40x
    makes those channels dominate every layer's input magnitude (RMSNorm
    normalizes per token, preserving channel dominance), so the top-k
    outlier sets are genuinely stable under a few optimizer steps — the
    monitor must report near-perfect overlap, not coincidence."""
    dcfg = DataConfig(vocab_size=VOCAB, seq_len=PROMPT, batch_size=4)
    loader = Loader(dcfg)
    model = api.prepare(_tiny_cfg())
    emb = np.array(model.frozen["embed"]["tokens"])
    emb[:, [3, 17, 41]] *= 40.0
    model.frozen["embed"]["tokens"] = emb
    model.calibrate([loader.batch(0)])
    model.convert("quaff")

    tcfg = TrainConfig(microbatches=1, remat=False, learning_rate=1e-4)
    obs = OBS.Obs.from_config(OBS.ObsConfig(trace=True, metrics=True))
    model.finetune(tcfg, loader, steps=4, obs=obs, ossh_monitor_every=2)

    assert len(model.ossh_drift) == 2
    total_stable = total_entered = 0
    for step, drifts in model.ossh_drift:
        assert drifts, "monitor produced no per-layer observations"
        for ld in drifts.values():
            assert 0.0 <= ld.jaccard <= 1.0
            assert 0.0 <= ld.jaccard_min <= 1.0
            assert ld.entered == ld.exited  # both sets have size k
            total_stable += ld.stable
            total_entered += ld.entered
    # engineered outliers survive a few small steps: overwhelmingly stable
    assert total_stable >= total_entered
    mean_jac = np.mean([ld.jaccard for _, d in model.ossh_drift
                        for ld in d.values()])
    assert mean_jac > 0.8

    # telemetry flowed into gauges + the trace
    snap = obs.metrics.snapshot()
    assert any(k.startswith("ossh_jaccard") for k in snap["gauges"])
    names = {e["name"] for e in obs.tracer.events()}
    assert "ossh_monitor" in names and "train_step" in names


def test_ossh_monitor_requires_calibration():
    model = api.prepare(_tiny_cfg())  # never calibrated
    loader = Loader(DataConfig(vocab_size=VOCAB, seq_len=PROMPT,
                               batch_size=4))
    tcfg = TrainConfig(microbatches=1, remat=False)
    with pytest.raises(ValueError, match="calibrate"):
        model.finetune(tcfg, loader, steps=1, ossh_monitor_every=1)
