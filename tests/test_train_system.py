"""System-level training behaviour: convergence on the synthetic task,
microbatch-count invariance, momentum-state evolution, OSSH hit-rate
during fine-tuning (the paper's Fig. 3 claim, in miniature)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.backend import CAPTURE
from repro.core.peft import PEFTConfig
from repro.data.pipeline import DataConfig, Loader, SyntheticLM, calibration_batches
from repro.models import model as M
from repro.models.config import ModelConfig, QuantConfig, TrainConfig
from repro.train import calibrate as C
from repro.train import steps as S

pytestmark = pytest.mark.slow  # multi-minute system tests (see pyproject)


def _cfg(mode="quaff"):
    return ModelConfig(
        name="sys-test", family="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab_size=64, head_dim=16,
        quant=QuantConfig(mode=mode),
        peft=PEFTConfig(method="lora", lora_rank=8))


def test_loss_decreases_quaff():
    cfg = _cfg("quaff")
    tcfg = TrainConfig(microbatches=1, remat=False, learning_rate=1e-2)
    frozen, adapters, qstate = M.init_params(jax.random.PRNGKey(0), cfg)
    state = S.init_train_state(adapters, qstate, tcfg)
    step = jax.jit(S.build_train_step(cfg, tcfg))
    dcfg = DataConfig(vocab_size=64, seq_len=32, batch_size=8, noise=0.05)
    loader = Loader(dcfg)
    losses = []
    for i in range(25):
        state, metrics = step(frozen, state, jax.tree.map(jnp.asarray,
                                                          loader.batch(i)))
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses
    floor = SyntheticLM(dcfg).entropy_floor()
    assert losses[-1] > floor - 0.05  # can't beat the generating entropy


def test_microbatch_invariance():
    """mb=1 vs mb=2 produce (nearly) the same updated adapters."""
    cfg = _cfg("quaff")
    loader = Loader(DataConfig(vocab_size=64, seq_len=16, batch_size=8))
    batch = jax.tree.map(jnp.asarray, loader.batch(0))
    results = []
    for mb in (1, 2):
        tcfg = TrainConfig(microbatches=mb, remat=False, grad_clip=0.0)
        frozen, adapters, qstate = M.init_params(jax.random.PRNGKey(0), cfg)
        state = S.init_train_state(adapters, qstate, tcfg)
        step = jax.jit(S.build_train_step(cfg, tcfg))  # repro: noqa[RPR001] fresh tcfg each iter
        new_state, _ = step(frozen, state, batch)
        results.append(new_state.adapters)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-5),
        results[0], results[1])


def test_momentum_state_moves_toward_beta():
    cfg = _cfg("quaff")
    tcfg = TrainConfig(microbatches=1, remat=False)
    frozen, adapters, qstate = M.init_params(jax.random.PRNGKey(0), cfg)
    state = S.init_train_state(adapters, qstate, tcfg)
    step = jax.jit(S.build_train_step(cfg, tcfg))
    loader = Loader(DataConfig(vocab_size=64, seq_len=16, batch_size=4))
    s0 = np.asarray(state.quant["attn"]["wq"].s)
    for i in range(5):
        state, _ = step(frozen, state, jax.tree.map(jnp.asarray,
                                                    loader.batch(i)))
    s5 = np.asarray(state.quant["attn"]["wq"].s)
    assert np.all(s5 >= 1.0 - 1e-6)
    assert not np.allclose(s0, s5), "momentum state never updated"


def test_ossh_hitrate_during_finetuning():
    """Calibrate outliers on held-out data, fine-tune, then measure the
    hit rate of the predefined set against runtime outliers (Fig. 3)."""
    cfg = _cfg("fp32")
    frozen, adapters, qstate = M.init_params(jax.random.PRNGKey(0), cfg)
    dcfg = DataConfig(vocab_size=64, seq_len=32, batch_size=8)
    stats = C.capture_stats(frozen, adapters, qstate, cfg,
                            calibration_batches(dcfg, 3))
    fq, qs = C.convert(frozen, stats, cfg, "quaff")
    cfg_q = dataclasses.replace(
        cfg, quant=dataclasses.replace(cfg.quant, mode="quaff"))

    tcfg = TrainConfig(microbatches=1, remat=False, learning_rate=5e-3)
    state = S.init_train_state(adapters, qs, tcfg)
    step = jax.jit(S.build_train_step(cfg_q, tcfg))
    loader = Loader(dcfg)
    for i in range(10):
        state, _ = step(fq, state, jax.tree.map(jnp.asarray, loader.batch(i)))

    # runtime outliers after fine-tuning (capture through the quaff model)
    live_stats = M.forward(
        fq, state.adapters, state.quant,
        jnp.asarray(loader.batch(99)["tokens"]), cfg_q, scope=CAPTURE).stats
    # hit rate: predefined channels (down_proj has the largest budget)
    pre = np.asarray(fq["blocks"]["ffn"]["down"]["w"].outlier_idx)  # (L, k)
    live = np.asarray(live_stats["ffn"]["down"])                    # (L, c)
    hits, total = 0, 0
    for layer in range(pre.shape[0]):
        st_l = live[layer]
        runtime = np.nonzero(st_l > 20.0 * np.median(st_l))[0]
        total += len(runtime)
        hits += len(set(runtime) & set(pre[layer]))
    if total:
        assert hits / total >= 0.5, (hits, total)
