"""MoE dispatch correctness: grouped capacity dispatch must equal a dense
per-token expert evaluation when nothing is dropped, and must be invariant
to the group count."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.peft import PEFTConfig
from repro.models import moe as MOE
from repro.models.config import ModelConfig, QuantConfig


def _cfg(groups=1, mode="fp32", cf=8.0):
    return ModelConfig(
        name="moe-test", family="moe", n_layers=1, d_model=32, n_heads=4,
        n_kv_heads=4, d_ff=16, vocab_size=64, head_dim=8, n_experts=4,
        top_k=2, capacity_factor=cf, moe_groups=groups,
        quant=QuantConfig(mode=mode), peft=PEFTConfig(method="none"))


def _setup(cfg, seed=0):
    params, states = MOE.init_moe(jax.random.PRNGKey(seed), cfg, cfg.quant,
                                  jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 16, cfg.d_model))
    return params, states, x


def _dense_reference(x, params, cfg):
    """Evaluate EVERY expert on EVERY token, combine with top-k gates."""
    t = x.shape[0] * x.shape[1]
    xt = x.reshape(t, -1)
    logits = xt @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, cfg.top_k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    outs = []
    for e in range(cfg.n_experts):
        w = jax.tree.map(lambda a: a[e], params["experts"])
        gate = xt @ w["gate"]["w"].w
        up = xt @ w["up"]["w"].w
        h = jax.nn.silu(gate) * up
        outs.append(h @ w["down"]["w"].w)
    outs = jnp.stack(outs, axis=1)  # (T, E, D)
    y = jnp.zeros_like(xt)
    for j in range(cfg.top_k):
        y = y + jnp.take_along_axis(
            outs, gate_idx[:, j][:, None, None], axis=1)[:, 0] * gate_vals[:, j:j+1]
    return y.reshape(x.shape)


def test_dispatch_matches_dense_no_drop():
    cfg = _cfg(groups=1, mode="fp32", cf=8.0)  # capacity >> tokens: no drops
    params, states, x = _setup(cfg)
    y, aux, _ = MOE.moe_ffn(x, params, states, cfg)
    y_ref = _dense_reference(x, params, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-5)


def test_group_invariance():
    """moe_groups=1 vs 4 give identical outputs when capacity is ample."""
    params, states, x = _setup(_cfg(groups=1))
    y1, _, _ = MOE.moe_ffn(x, params, states, _cfg(groups=1))
    y4, _, _ = MOE.moe_ffn(x, params, states, _cfg(groups=4))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y4),
                               rtol=1e-5, atol=1e-6)


def test_capacity_drops_bounded():
    """Tiny capacity: output differs but stays finite; aux loss ~1."""
    cfg = _cfg(cf=0.25)
    params, states, x = _setup(cfg)
    y, aux, _ = MOE.moe_ffn(x, params, states, cfg)
    assert bool(jnp.all(jnp.isfinite(y)))
    assert 0.5 < float(aux) < 4.0  # balanced-ish random router


def test_quaff_moe_stats_shared():
    cfg = _cfg(mode="quaff")
    params, states, x = _setup(cfg)
    y, aux, stats = MOE.moe_ffn(x, params, states, cfg)
    assert bool(jnp.all(jnp.isfinite(y)))
    # stats are per-layer (n_o,), shared across experts
    assert stats["gate"].shape == states["gate"].s.shape


def test_moe_grads_flow_to_input():
    cfg = _cfg(mode="quaff")
    params, states, x = _setup(cfg)
    g = jax.grad(lambda xx: MOE.moe_ffn(xx, params, states, cfg)[0].sum())(x)
    assert bool(jnp.all(jnp.isfinite(g)))
    assert float(jnp.max(jnp.abs(g))) > 0
