"""Packed-nibble INT4 path: pack/unpack exactness over the full nibble
space, packed-vs-unpacked GEMM equivalence (forward AND backward), Pallas
kernel parity against the jnp oracles, group-wise scale behavior, and the
w4a4 / w4a8 backends end-to-end through the ``repro.api`` facade."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import backend as BK
from repro.core import int4 as int4_mod
from repro.core import quant
from repro.core.int4 import Int4Weights, prepare_int4_weights
from repro.core.peft import PEFTConfig
from repro.data.pipeline import DataConfig, Loader, calibration_batches
from repro.kernels import int4_matmul, int4_pack, ops, ref
from repro.models.config import ModelConfig, QuantConfig, TrainConfig

KEY = jax.random.PRNGKey(7)


# ---------------------------------------------------------------------------
# Pack / unpack
# ---------------------------------------------------------------------------
def test_pack_unpack_roundtrip_full_nibble_space():
    """Exact over every (lo, hi) pair in [-8, 7]^2 — the whole byte space."""
    vals = np.arange(-8, 8)
    lo, hi = np.meshgrid(vals, vals)
    w = jnp.asarray(np.stack([lo.ravel(), hi.ravel()]), jnp.int8)  # (2, 256)
    packed = quant.pack_int4(w)
    assert packed.shape == (1, 256) and packed.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(quant.unpack_int4(packed)),
                                  np.asarray(w))
    # Pallas kernels agree byte-for-byte on the same exhaustive grid
    p_k = int4_pack.pack_int4_pallas(w, interpret=True)
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(packed))
    np.testing.assert_array_equal(
        np.asarray(int4_pack.unpack_int4_pallas(p_k, interpret=True)),
        np.asarray(w))


@pytest.mark.parametrize("k,n", [(4, 8), (64, 32), (128, 256), (30, 12)])
def test_pack_unpack_roundtrip_random(k, n):
    w = jax.random.randint(KEY, (k, n), -8, 8, jnp.int8)
    packed = quant.pack_int4(w)
    assert packed.nbytes * 2 == w.nbytes
    np.testing.assert_array_equal(np.asarray(quant.unpack_int4(packed)),
                                  np.asarray(w))


def test_pack_odd_c_in_raises():
    with pytest.raises(ValueError, match="even"):
        quant.pack_int4(jnp.zeros((3, 4), jnp.int8))
    with pytest.raises(ValueError, match="even"):
        prepare_int4_weights(jnp.zeros((3, 4)))


@pytest.mark.parametrize("k,n", [(32, 64), (256, 128)])
def test_pack_kernels_match_core(k, n):
    w = jax.random.randint(KEY, (k, n), -7, 8, jnp.int8)
    want = quant.pack_int4(w)
    got = int4_pack.pack_int4_pallas(w, block_k=8, block_n=32, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    got_u = int4_pack.unpack_int4_pallas(want, block_k=8, block_n=32,
                                         interpret=True)
    np.testing.assert_array_equal(np.asarray(got_u), np.asarray(w))
    np.testing.assert_array_equal(np.asarray(ref.int4_pack_ref(w)),
                                  np.asarray(want))
    np.testing.assert_array_equal(np.asarray(ref.int4_unpack_ref(want)),
                                  np.asarray(w))


# ---------------------------------------------------------------------------
# Group-wise quantization
# ---------------------------------------------------------------------------
def test_quantize_grouped_reduces_to_per_oc():
    w = jax.random.normal(KEY, (64, 16)) * 0.2
    wi_g, wd_g = quant.quantize_grouped(w, 64, bits=4)   # one group == per-OC
    wi_o, wd_o = quant.quantize(w, axis=0, bits=4)
    np.testing.assert_array_equal(np.asarray(wi_g), np.asarray(wi_o))
    np.testing.assert_allclose(np.asarray(wd_g), np.asarray(wd_o.reshape(
        1, -1)), rtol=1e-7)


def test_quantize_grouped_fallback_when_not_dividing():
    w = jax.random.normal(KEY, (60, 16)) * 0.2
    wi, wd = quant.quantize_grouped(w, 32, bits=4)       # 32 does not divide
    assert wd.shape == (1, 16)                           # -> per-OC fallback
    assert np.all(np.abs(np.asarray(wi)) <= 7)


def test_groupwise_scales_cut_quant_error():
    """Heterogeneous row magnitudes are the case group-wise scales exist
    for: a per-OC step must cover the loudest c_in row, flushing the quiet
    rows to zero; per-group steps keep them."""
    w = jax.random.normal(KEY, (128, 32)) * 0.02
    w = w.at[:16].mul(40.0)                              # one loud group

    def recon_err(group_size):
        wi, wd = quant.quantize_grouped(w, group_size, bits=4)
        w_hat = quant.dequantize_grouped(wi, wd)
        return float(jnp.mean(jnp.abs(w_hat - w)[16:]))  # quiet rows

    assert recon_err(16) < 0.3 * recon_err(128), (
        recon_err(16), recon_err(128))


# ---------------------------------------------------------------------------
# Packed GEMM == unpacked GEMM (forward and backward)
# ---------------------------------------------------------------------------
def _setup(t=32, k=128, n=64, w_scale=0.1):
    k1, k2 = jax.random.split(KEY)
    x = jax.random.normal(k1, (t, k))
    w = jax.random.normal(k2, (k, n)) * w_scale
    return x, w


@pytest.mark.parametrize("x_bits", [4, 8])
def test_packed_matmul_matches_unpacked_per_oc(x_bits):
    x, w = _setup()
    w_int, w_delta = quant.quantize(w, axis=0, bits=4)
    wp = quant.pack_int4(w_int)
    y_p = quant.quantized_matmul_packed(x, wp, w_delta.reshape(1, -1),
                                        x_bits=x_bits)
    y_u = quant.quantized_matmul(x, w_int, w_delta, bits=x_bits)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_u),
                               rtol=1e-5, atol=1e-6)
    g_p = jax.grad(lambda x: jnp.sum(quant.quantized_matmul_packed(
        x, wp, w_delta.reshape(1, -1), x_bits) ** 2))(x)
    g_u = jax.grad(lambda x: jnp.sum(quant.quantized_matmul(
        x, w_int, w_delta, x_bits) ** 2))(x)
    np.testing.assert_allclose(np.asarray(g_p), np.asarray(g_u),
                               rtol=1e-4, atol=1e-5)


def test_grouped_backward_int8_close_to_fp():
    x, w = _setup()
    w_int, w_delta = quant.quantize_grouped(w, 32, bits=4)
    wp = quant.pack_int4(w_int)

    def loss(x, bwd_int8):
        return jnp.sum(quant.quantized_matmul_packed(
            x, wp, w_delta, 8, bwd_int8) ** 2)

    g_i = jax.grad(lambda x: loss(x, True))(x)
    g_f = jax.grad(lambda x: loss(x, False))(x)
    rel = float(jnp.mean(jnp.abs(g_i - g_f)) / jnp.mean(jnp.abs(g_f)))
    assert rel < 0.05, rel


# ---------------------------------------------------------------------------
# Pallas fused kernel parity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("t,k,n,g,x_bits", [
    (16, 64, 32, 1, 8), (64, 256, 128, 4, 8), (32, 128, 64, 2, 4),
    (16, 512, 32, 8, 4),
])
def test_int4_matmul_fused_vs_ref(t, k, n, g, x_bits):
    keys = jax.random.split(KEY, 4)
    qm = int(quant.qmax_for_bits(x_bits))
    x_int = jax.random.randint(keys[0], (t, k), -qm, qm + 1, jnp.int8)
    w_int = jax.random.randint(keys[1], (k, n), -7, 8, jnp.int8)
    wp = quant.pack_int4(w_int)
    x_delta = jnp.abs(jax.random.normal(keys[2], (t, 1))) / 100 + 1e-3
    w_delta = jnp.abs(jax.random.normal(keys[3], (g, n))) / 100 + 1e-3
    got = int4_matmul.int4_matmul_fused(
        x_int, wp, x_delta, w_delta, block_t=16, block_n=32, block_k=32,
        interpret=True)
    want = ref.int4_matmul_ref(x_int, wp, x_delta, w_delta)
    # int32 accumulation is exact; group scaling in the kernel associates
    # per K-step instead of per group -> fp32 ULP noise only
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-5, atol=1e-6)


@pytest.mark.parametrize("x_bits,group_size", [(4, 0), (8, 64)])
def test_int4_forward_pallas_vs_backend(x_bits, group_size):
    """Full kernel pipeline == the backend's jnp apply path."""
    x, w = _setup(t=32, k=128, n=64)
    bias = jnp.linspace(-0.5, 0.5, 64)
    wts = prepare_int4_weights(w, bias, group_size)
    y_k = ops.int4_forward_pallas(x, wts, x_bits=x_bits, interpret=True,
                                  block_t=16, block_n=32, block_k=32)
    y_c = quant.quantized_matmul_packed(x, wts.w_packed, wts.w_delta,
                                        x_bits=x_bits) + bias
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_c),
                               rtol=1e-4, atol=1e-4)


def test_backend_kernel_route_matches_jnp(monkeypatch):
    """Flipping USE_PALLAS_KERNEL reroutes apply() through the fused Pallas
    kernel with identical integer math (forward and STE backward)."""
    x, w = _setup()
    for mode in ("int4", "int4_w4a8"):
        backend = BK.get_backend(mode)
        wts = backend.prepare(w, calib=BK.Calibration(init_placeholder=True,
                                                      group_size=32))
        monkeypatch.setattr(int4_mod, "USE_PALLAS_KERNEL", False)
        y_jnp = backend.apply(x, wts).y
        monkeypatch.setattr(int4_mod, "USE_PALLAS_KERNEL", True)
        y_pal = backend.apply(x, wts).y
        np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_jnp),
                                   rtol=5e-5, atol=1e-5, err_msg=mode)


# ---------------------------------------------------------------------------
# Backends: memory claim + registry behavior
# ---------------------------------------------------------------------------
def test_int4_weight_bytes_at_most_half_of_int8_carrier():
    """Acceptance: mode="int4" stores packed nibbles — weight bytes <= 0.5x
    the int8 carrier for the same layer."""
    _, w = _setup(k=256, n=128)
    int8_carrier_bytes = quant.quantize(w, axis=0, bits=4)[0].nbytes
    for mode in ("int4", "int4_w4a8"):
        wts = BK.get_backend(mode).prepare(
            w, calib=BK.Calibration(init_placeholder=True))
        assert isinstance(wts, Int4Weights), mode
        assert wts.w_packed.nbytes * 2 <= int8_carrier_bytes, mode
        assert wts.w_packed.dtype == jnp.int8


def test_w4a8_tighter_than_w4a4():
    """Per-token INT8 activations must beat INT4 activations at equal
    weight precision — the reason the OWQ-style mode exists. Weights are
    chosen exactly 4-bit representable so the comparison isolates the
    activation grid (the only thing the two modes differ in)."""
    x, _ = _setup()
    w = jax.random.randint(KEY, (128, 64), -7, 8).astype(jnp.float32) * 0.05
    y_fp = x @ w
    calib = BK.Calibration(init_placeholder=True)

    def err(mode):
        backend = BK.get_backend(mode)
        y = backend.apply(x, backend.prepare(w, calib=calib)).y
        return float(jnp.mean(jnp.abs(y - y_fp)))

    assert err("int4_w4a8") < 0.2 * err("int4"), (
        err("int4_w4a8"), err("int4"))


def test_group_size_threads_through_registry_prepare():
    _, w = _setup(k=128, n=32)
    wts = BK.get_backend("int4").prepare(
        w, calib=BK.Calibration(init_placeholder=True, group_size=16))
    assert wts.w_delta.shape == (8, 32)
    wts = BK.get_backend("int4").prepare(
        w, calib=BK.Calibration(init_placeholder=True))
    assert wts.w_delta.shape == (1, 32)


# ---------------------------------------------------------------------------
# End-to-end through the repro.api facade
# ---------------------------------------------------------------------------
def _quickstart_cfg(group_size=0):
    return ModelConfig(
        name="quickstart", family="dense", n_layers=4, d_model=128, n_heads=8,
        n_kv_heads=4, d_ff=256, vocab_size=512, head_dim=16,
        quant=QuantConfig(mode="fp32", group_size=group_size),
        peft=PEFTConfig(method="lora", lora_rank=16))


def _packed_bytes(frozen):
    leaves = jax.tree.leaves(
        frozen, is_leaf=lambda x: isinstance(x, Int4Weights))
    return sum(l.w_packed.nbytes for l in leaves
               if isinstance(l, Int4Weights))


def test_w4a8_groupwise_trains_through_api():
    """Acceptance: mode="int4_w4a8" + group_size=128 runs calibrate ->
    convert -> finetune -> evaluate end-to-end, loss decreasing, no NaNs."""
    data = DataConfig(vocab_size=512, seq_len=64, batch_size=8, noise=0.05)
    model = api.prepare(_quickstart_cfg(group_size=128))
    model.calibrate(calibration_batches(data, 2))
    model.convert("int4_w4a8")
    assert model.cfg.quant.mode == "int4_w4a8"
    assert _packed_bytes(model.frozen) > 0   # frozen tree really is packed
    losses = model.finetune(TrainConfig(learning_rate=2e-2, microbatches=1,
                                        remat=False),
                            Loader(data), steps=60)
    assert np.all(np.isfinite(losses))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05, losses
    m = model.evaluate(Loader(data).batch(999))
    assert np.isfinite(m["loss"])


def test_int4_groupwise_step_through_api():
    """Group-wise w4a4 takes a finite train step + eval (NaN-free)."""
    data = DataConfig(vocab_size=512, seq_len=64, batch_size=8, noise=0.05)
    model = api.prepare(_quickstart_cfg(group_size=32))
    model.calibrate(calibration_batches(data, 2))
    model.convert("int4")
    losses = model.finetune(TrainConfig(learning_rate=2e-2, microbatches=1,
                                        remat=False),
                            Loader(data), steps=5)
    assert np.all(np.isfinite(losses))
    assert np.isfinite(model.evaluate(Loader(data).batch(999))["loss"])
