"""Tests for repro.analysis: paired good/bad fixtures per rule, noqa
suppression, CLI exit codes, and a self-check that the shipped tree is
clean. Fixtures are inline strings (never executed, only parsed) so the
intentionally-bad code can't trip pytest collection or the analyzer's own
CI run over tests/."""

import json
import textwrap
from pathlib import Path

import pytest

from repro.analysis import analyze_paths, analyze_source
from repro.analysis.cli import main as cli_main
from repro.analysis.registry import get_rules

REPO = Path(__file__).resolve().parents[1]


def run(src, rule, path="mod.py"):
    return analyze_source(textwrap.dedent(src), select=[rule], path=path)


def ids(findings):
    return [f.rule_id for f in findings]


# ---------------------------------------------------------------- registry


def test_rule_catalogue():
    rules = get_rules()
    assert [r.rule_id for r in rules] == [
        "RPR001", "RPR002", "RPR003", "RPR004", "RPR005", "RPR006",
        "RPR007", "RPR009", "RPR010", "RPR011",
    ]
    assert all(r.severity in ("error", "warning") for r in rules)
    assert all(r.description for r in rules)


def test_select_unknown_rule_raises():
    with pytest.raises(ValueError):
        get_rules(["RPR999"])


# ------------------------------------------------------------------ RPR001


BAD_JIT_IN_LOOP = """
    import jax

    def serve(xs):
        out = []
        for x in xs:
            f = jax.jit(lambda v: v * 2)
            out.append(f(x))
        return out
"""

GOOD_JIT_HOISTED = """
    import jax

    def serve(xs):
        f = jax.jit(lambda v: v * 2)
        return [f(x) for x in xs]
"""

GOOD_JIT_MEMO = """
    import jax

    def serve(xs):
        f = None
        out = []
        for x in xs:
            if f is None:
                f = jax.jit(lambda v: v * 2)
            out.append(f(x))
        return out
"""

BAD_JIT_IMMEDIATE = """
    import jax

    def step(x):
        return jax.jit(lambda v: v + 1)(x)
"""

BAD_UNHASHABLE_STATIC = """
    import jax

    def g(x, shape):
        return x.reshape(shape)

    f = jax.jit(g, static_argnames=("shape",))

    def use(x):
        return f(x, shape=[4, 4])
"""

GOOD_HASHABLE_STATIC = """
    import jax

    def g(x, shape):
        return x.reshape(shape)

    f = jax.jit(g, static_argnames=("shape",))

    def use(x):
        return f(x, shape=(4, 4))
"""


def test_rpr001_jit_in_loop_flagged():
    assert ids(run(BAD_JIT_IN_LOOP, "RPR001")) == ["RPR001"]


def test_rpr001_hoisted_and_memoized_pass():
    assert run(GOOD_JIT_HOISTED, "RPR001") == []
    assert run(GOOD_JIT_MEMO, "RPR001") == []


def test_rpr001_immediate_invoke_flagged():
    assert ids(run(BAD_JIT_IMMEDIATE, "RPR001")) == ["RPR001"]


def test_rpr001_unhashable_static_arg():
    assert ids(run(BAD_UNHASHABLE_STATIC, "RPR001")) == ["RPR001"]
    assert run(GOOD_HASHABLE_STATIC, "RPR001") == []


# ------------------------------------------------------------------ RPR002


BAD_IF_ON_TRACER = """
    import jax

    @jax.jit
    def f(x):
        if x > 0:
            return x
        return -x
"""

GOOD_STATIC_BRANCH = """
    import jax
    from functools import partial

    @partial(jax.jit, static_argnames=("n",))
    def f(x, n):
        if n > 1:
            return x
        return -x
"""

GOOD_SHAPE_BRANCH = """
    import jax

    @jax.jit
    def f(x):
        if x.ndim > 1:
            return x.sum(-1)
        return x
"""

GOOD_MEMBERSHIP = """
    import jax

    @jax.jit
    def f(x, scales):
        if "w" in scales:
            return x * scales["w"]
        return x
"""

BAD_PRINT = """
    import jax

    @jax.jit
    def f(x):
        print(x)
        return x
"""

BAD_CLOSURE_MUTATION = """
    import jax

    log = []

    @jax.jit
    def f(x):
        log.append(x)
        return x
"""

GOOD_UNTRACED = """
    def f(x):
        if x > 0:
            print(x)
        return x
"""


def test_rpr002_if_on_tracer_flagged():
    assert ids(run(BAD_IF_ON_TRACER, "RPR002")) == ["RPR002"]


def test_rpr002_static_shape_membership_pass():
    assert run(GOOD_STATIC_BRANCH, "RPR002") == []
    assert run(GOOD_SHAPE_BRANCH, "RPR002") == []
    assert run(GOOD_MEMBERSHIP, "RPR002") == []


def test_rpr002_print_and_closure_mutation_flagged():
    assert ids(run(BAD_PRINT, "RPR002")) == ["RPR002"]
    assert ids(run(BAD_CLOSURE_MUTATION, "RPR002")) == ["RPR002"]


def test_rpr002_untraced_function_ignored():
    assert run(GOOD_UNTRACED, "RPR002") == []


# ------------------------------------------------------------------ RPR003


BAD_KEY_REUSE = """
    import jax

    def sample(key):
        a = jax.random.normal(key, (4,))
        b = jax.random.normal(key, (4,))
        return a + b
"""

GOOD_KEY_SPLIT = """
    import jax

    def sample(key):
        k1, k2 = jax.random.split(key)
        a = jax.random.normal(k1, (4,))
        b = jax.random.normal(k2, (4,))
        return a + b
"""

BAD_KEY_REUSE_IN_LOOP = """
    import jax

    def sample(key, n):
        out = []
        for _ in range(n):
            out.append(jax.random.normal(key, (4,)))
        return out
"""

GOOD_KEY_RESPLIT_IN_LOOP = """
    import jax

    def sample(key, n):
        out = []
        for _ in range(n):
            key, sub = jax.random.split(key)
            out.append(jax.random.normal(sub, (4,)))
        return out
"""

GOOD_EXCLUSIVE_BRANCHES = """
    import jax

    def sample(key, uniform):
        if uniform:
            return jax.random.uniform(key, (4,))
        else:
            return jax.random.normal(key, (4,))
"""

GOOD_DISTINCT_SUBSCRIPTS = """
    import jax

    def init(keys):
        a = jax.random.normal(keys[0], (4,))
        b = jax.random.normal(keys[1], (4,))
        return a, b
"""

BAD_SAME_SUBSCRIPT = """
    import jax

    def init(keys):
        a = jax.random.normal(keys[0], (4,))
        b = jax.random.normal(keys[0], (4,))
        return a, b
"""

BAD_DOUBLE_SPLIT = """
    import jax

    def init(key):
        ks = jax.random.split(key, 4)
        more = jax.random.split(key, 2)
        return ks, more
"""


def test_rpr003_reuse_flagged():
    assert ids(run(BAD_KEY_REUSE, "RPR003")) == ["RPR003"]
    assert ids(run(BAD_SAME_SUBSCRIPT, "RPR003")) == ["RPR003"]
    assert ids(run(BAD_DOUBLE_SPLIT, "RPR003")) == ["RPR003"]


def test_rpr003_split_and_branches_pass():
    assert run(GOOD_KEY_SPLIT, "RPR003") == []
    assert run(GOOD_EXCLUSIVE_BRANCHES, "RPR003") == []
    assert run(GOOD_DISTINCT_SUBSCRIPTS, "RPR003") == []


def test_rpr003_loop_reuse():
    assert ids(run(BAD_KEY_REUSE_IN_LOOP, "RPR003")) == ["RPR003"]
    assert run(GOOD_KEY_RESPLIT_IN_LOOP, "RPR003") == []


# ------------------------------------------------------------------ RPR004


_PALLAS_PRELUDE = """
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl
    from repro.kernels.common import interpret_mode

    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...] * 2
"""

BAD_NO_INTERPRET = _PALLAS_PRELUDE + """
    def call(x):
        return pl.pallas_call(
            kernel,
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        )(x)
"""

BAD_ADHOC_INTERPRET = _PALLAS_PRELUDE + """
    def call(x):
        return pl.pallas_call(
            kernel,
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=True,
        )(x)
"""

GOOD_INTERPRET_DIRECT = _PALLAS_PRELUDE + """
    def call(x, interpret=False):
        return pl.pallas_call(
            kernel,
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=interpret_mode(interpret),
        )(x)
"""

GOOD_INTERPRET_VIA_NAME = _PALLAS_PRELUDE + """
    def call(x, interpret=False):
        mode = interpret_mode(interpret)
        return pl.pallas_call(
            kernel,
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=mode,
        )(x)
"""

BAD_GRID_UNGUARDED = _PALLAS_PRELUDE + """
    def call(x, block):
        return pl.pallas_call(
            kernel,
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            grid=(x.shape[0] // block,),
            interpret=interpret_mode(False),
        )(x)
"""

GOOD_GRID_ASSERTED = _PALLAS_PRELUDE + """
    def call(x, block):
        assert x.shape[0] % block == 0
        return pl.pallas_call(
            kernel,
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            grid=(x.shape[0] // block,),
            interpret=interpret_mode(False),
        )(x)
"""

_MM_PRELUDE = """
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu
    from repro.kernels.common import interpret_mode

    def mm_kernel(a_ref, b_ref, o_ref, acc_ref):
        acc_ref[...] += a_ref[...] @ b_ref[...]
"""

BAD_NARROW_ACC = _MM_PRELUDE + """
    def call(a, b):
        return pl.pallas_call(
            mm_kernel,
            out_shape=jax.ShapeDtypeStruct((8, 8), a.dtype),
            scratch_shapes=[pltpu.VMEM((8, 8), jnp.bfloat16)],
            interpret=interpret_mode(False),
        )(a, b)
"""

GOOD_F32_ACC = _MM_PRELUDE + """
    def call(a, b):
        return pl.pallas_call(
            mm_kernel,
            out_shape=jax.ShapeDtypeStruct((8, 8), a.dtype),
            scratch_shapes=[pltpu.VMEM((8, 8), jnp.float32)],
            interpret=interpret_mode(False),
        )(a, b)
"""


def test_rpr004_interpret_routing():
    assert ids(run(BAD_NO_INTERPRET, "RPR004")) == ["RPR004"]
    assert ids(run(BAD_ADHOC_INTERPRET, "RPR004")) == ["RPR004"]
    assert run(GOOD_INTERPRET_DIRECT, "RPR004") == []
    assert run(GOOD_INTERPRET_VIA_NAME, "RPR004") == []


def test_rpr004_grid_divisibility():
    assert ids(run(BAD_GRID_UNGUARDED, "RPR004")) == ["RPR004"]
    assert run(GOOD_GRID_ASSERTED, "RPR004") == []


def test_rpr004_accumulator_dtype():
    assert ids(run(BAD_NARROW_ACC, "RPR004")) == ["RPR004"]
    assert run(GOOD_F32_ACC, "RPR004") == []


# ------------------------------------------------------------------ RPR005


BAD_DROPPED_DELTA = """
    from repro.core.quant import quantize

    def forward(x, w):
        w_int, w_delta = quantize(w, axis=0)
        return x @ w_int
"""

GOOD_DELTA_APPLIED = """
    from repro.core.quant import quantize

    def forward(x, w):
        w_int, w_delta = quantize(w, axis=0)
        return (x @ w_int) * w_delta
"""

BAD_UNPACK_NO_SCALE = """
    from repro.core.quant import int_matmul, unpack_int4

    def forward(x_int, w_packed):
        w_int = unpack_int4(w_packed)
        return int_matmul(x_int, w_int)
"""

GOOD_UNPACK_WITH_SCALE = """
    from repro.core.quant import int_matmul, unpack_int4

    def forward(x_int, w_packed, w_scale):
        w_int = unpack_int4(w_packed)
        return int_matmul(x_int, w_int) * w_scale
"""

BAD_DELTA_LOST_THROUGH_RESHAPE = """
    from repro.core.quant import quantize

    def forward(x, w):
        w_int, w_delta = quantize(w, axis=0)
        w2 = w_int.reshape(-1, 8).astype("int8")
        return x @ w2
"""


def test_rpr005_dropped_scale_flagged():
    assert ids(run(BAD_DROPPED_DELTA, "RPR005")) == ["RPR005"]
    assert ids(run(BAD_UNPACK_NO_SCALE, "RPR005")) == ["RPR005"]
    assert ids(run(BAD_DELTA_LOST_THROUGH_RESHAPE, "RPR005")) == ["RPR005"]


def test_rpr005_scale_applied_passes():
    assert run(GOOD_DELTA_APPLIED, "RPR005") == []
    assert run(GOOD_UNPACK_WITH_SCALE, "RPR005") == []


# ------------------------------------------------------------------ RPR006


_PROTOCOL = """
    class QuantBackend:
        name = ""

        def prepare(self, w, bias=None, *, calib=None, bits=8):
            raise NotImplementedError

        def apply(self, x, weights, *, state=None, bits=8, bwd_int8=True):
            raise NotImplementedError

        def init_state(self, weights):
            return None


    def register(cls):
        return cls
"""

BAD_BACKEND = _PROTOCOL + """
    class BrokenBackend(QuantBackend):
        def prepare(self, w):
            return w
"""

GOOD_BACKEND = _PROTOCOL + """
    @register
    class GoodBackend(QuantBackend):
        name = "good"

        def prepare(self, w, bias=None, *, calib=None, bits=8):
            return (w, bias)

        def apply(self, x, weights, *, state=None, bits=8, bwd_int8=True):
            return x
"""

UNREGISTERED_BACKEND = _PROTOCOL + """
    class GhostBackend(QuantBackend):
        name = "ghost"

        def prepare(self, w, bias=None, *, calib=None, bits=8):
            return (w, bias)

        def apply(self, x, weights, *, state=None, bits=8, bwd_int8=True):
            return x
"""


def _run_backend(src):
    return run(src, "RPR006", path="repro/core/backend.py")


def test_rpr006_broken_backend():
    msgs = [f.message for f in _run_backend(BAD_BACKEND)]
    assert any("apply" in m and "required" in m for m in msgs)  # missing method
    assert any("name" in m for m in msgs)  # missing registry key
    assert any("positional" in m for m in msgs)  # arity mismatch
    assert any("keyword-only" in m for m in msgs)  # dropped kwonly params


def test_rpr006_complete_backend_passes():
    assert _run_backend(GOOD_BACKEND) == []


def test_rpr006_unregistered_backend():
    msgs = [f.message for f in _run_backend(UNREGISTERED_BACKEND)]
    assert len(msgs) == 1 and "never registered" in msgs[0]


# ------------------------------------------------------------------ RPR007


BAD_AXIS_TYPO = """
    from jax.sharding import PartitionSpec as P

    SPEC = P("modle", None)
    NESTED = P(None, ("data", "tensor"))
"""

GOOD_AXES = """
    from jax.sharding import PartitionSpec as P

    ROW = P("model", None)
    BOTH = P("data", ("data", "model"))
    POD = P("pod", None)
    DYN = P(*(None,) * 3)

    def spec_for(axis):
        return P(axis, None)       # variable axis: out of lexical reach
"""

BAD_JIT_ARITY = """
    import jax

    def step(state, batch):
        return state

    jitted = jax.jit(step, in_shardings=(None,))
"""

GOOD_JIT_ARITY = """
    import jax

    def step(state, batch):
        return state

    jitted = jax.jit(step, in_shardings=(None, None))
    partial_static = jax.jit(step, in_shardings=(None,), static_argnums=(1,))
"""

BAD_AXIS_NOQA = """
    from jax.sharding import PartitionSpec as P

    SPEC = P("replica", None)  # repro: noqa[RPR007] foreign-mesh interop
"""


def test_rpr007_axis_typo_flagged():
    findings = run(BAD_AXIS_TYPO, "RPR007")
    assert ids(findings) == ["RPR007", "RPR007"]
    assert "'modle'" in findings[0].message
    assert "'tensor'" in findings[1].message


def test_rpr007_valid_axes_pass():
    assert run(GOOD_AXES, "RPR007") == []


def test_rpr007_jit_arity_mismatch():
    findings = run(BAD_JIT_ARITY, "RPR007")
    assert ids(findings) == ["RPR007"]
    assert "2 positional" in findings[0].message


def test_rpr007_jit_arity_ok_and_static_skip():
    assert run(GOOD_JIT_ARITY, "RPR007") == []


def test_rpr007_noqa_suppresses():
    assert run(BAD_AXIS_NOQA, "RPR007") == []


def test_rpr007_mesh_axes_harvested(tmp_path):
    """The axis vocabulary comes from repro.launch.mesh when analyzed
    together; names outside the harvested tuples are flagged even if
    they belong to the fallback vocabulary."""
    ldir = tmp_path / "src" / "repro" / "launch"
    ldir.mkdir(parents=True)
    (ldir / "mesh.py").write_text(textwrap.dedent("""
        def make_mesh(multi_pod=False, axes=("x", "y")):
            axes = ("pod", "x", "y") if multi_pod else axes
            return axes
    """))
    (tmp_path / "user.py").write_text(textwrap.dedent("""
        from jax.sharding import PartitionSpec as P

        A = P("x", ("pod", "y"))
        B = P("data", None)
    """))
    findings, _ = analyze_paths([str(tmp_path)], select=["RPR007"])
    assert ids(findings) == ["RPR007"]
    assert "'data'" in findings[0].message


# ------------------------------------------------------------------ RPR009


KERNEL_MOD = """
    def fit_block(n, cap):                       # no interpret param
        return min(n, cap)

    def _rowmax_kernel(x_ref, o_ref):            # private helper
        pass

    def rowmax_fused(x, *, interpret=False):
        return x

    def scale_quant_fused(x, scales, *, interpret=False):
        return x * scales
"""

COVERING_TEST = """
    from pkg.kernels.quant import rowmax_fused, scale_quant_fused

    def test_rowmax():
        assert rowmax_fused(1, interpret=True)

    def test_scale_quant():
        assert scale_quant_fused(2, 3, interpret=True)
"""

PARTIAL_TEST = """
    from pkg.kernels.quant import rowmax_fused, scale_quant_fused

    def test_rowmax():
        assert rowmax_fused(1, interpret=True)

    def test_scale_quant_compiled_only():
        assert scale_quant_fused(2, 3, interpret=False)
"""

FOREIGN_TEST = """
    from other.helpers import scale_quant_fused
    from pkg.kernels.quant import rowmax_fused

    def test_rowmax():
        assert rowmax_fused(1, interpret=True)

    def test_unrelated_same_name():
        assert scale_quant_fused(2, 3, interpret=True)
"""


def _run_interpret(tmp_path, test_src, kernel_src=KERNEL_MOD):
    kdir = tmp_path / "src" / "pkg" / "kernels"
    kdir.mkdir(parents=True)
    (kdir / "quant.py").write_text(textwrap.dedent(kernel_src))
    tdir = tmp_path / "tests"
    tdir.mkdir()
    (tdir / "test_quant.py").write_text(textwrap.dedent(test_src))
    findings, _ = analyze_paths(
        [str(tmp_path / "src"), str(tmp_path / "tests")], select=["RPR009"])
    return findings


def test_rpr009_covered_wrappers_pass(tmp_path):
    assert _run_interpret(tmp_path, COVERING_TEST) == []


def test_rpr009_uncovered_wrapper_flagged(tmp_path):
    findings = _run_interpret(tmp_path, PARTIAL_TEST)
    assert len(findings) == 1
    f = findings[0]
    assert f.rule_id == "RPR009" and f.severity == "error"
    assert "scale_quant_fused" in f.message and f.path.endswith("quant.py")


def test_rpr009_foreign_same_name_does_not_vouch(tmp_path):
    # interpret=True on an identically-named function imported from a
    # different package must not count as coverage of the kernel wrapper
    msgs = [f.message for f in _run_interpret(tmp_path, FOREIGN_TEST)]
    assert len(msgs) == 1 and "scale_quant_fused" in msgs[0]


def test_rpr009_silent_without_test_modules(tmp_path):
    kdir = tmp_path / "src" / "pkg" / "kernels"
    kdir.mkdir(parents=True)
    (kdir / "quant.py").write_text(textwrap.dedent(KERNEL_MOD))
    findings, _ = analyze_paths([str(tmp_path / "src")], select=["RPR009"])
    assert findings == []  # coverage is unknowable with no tests analyzed


def test_rpr009_noqa_suppression(tmp_path):
    noqa_kernel = KERNEL_MOD.replace(
        "def scale_quant_fused(x, scales, *, interpret=False):",
        "def scale_quant_fused(x, scales, *, interpret=False):"
        "  # repro: noqa[RPR009] GPU-only",
    )
    assert _run_interpret(tmp_path, PARTIAL_TEST, noqa_kernel) == []


# ------------------------------------------------------------------ RPR010


FACADE_API = """
    def prepare(cfg, seed=0):
        return QuaffModel(cfg, None, None, None)

    class QuaffModel:
        def __init__(self, cfg, frozen, adapters, quant_state):
            self.cfg = cfg

        def convert(self, mode):
            return self

        def finetune(self, tcfg, loader, steps, start_step=None):
            return {}

        def engine(self, cfg=None, fresh=False, **legacy):
            return None

        @classmethod
        def load(cls, directory, step=None):
            return cls(None, None, None, None)

        @property
        def stats(self):
            return {}
    """

GOOD_README = """\
# demo

```python
from repro import api

model = api.prepare(cfg)
model.convert("quaff")
model.finetune(tcfg, loader, steps=40)
eng = model.engine(anything_goes=1)   # **legacy swallows unknown kwargs
m2 = api.QuaffModel.load("ckpts/demo")
```

```bash
model.no_such_thing()   # shell fence: never parsed as Python
```
"""

DRIFTED_README = """\
# demo

```python
from repro import api

model = api.prepare(cfg, seed=0, ratio=0.05)   # unknown kwarg
model.quantize("quaff")                        # renamed method
model.convert()                                # required arg dropped
api.make_model(cfg)                            # nonexistent function
```
"""


def _run_facade(tmp_path, readme_text):
    api_dir = tmp_path / "src" / "repro"
    api_dir.mkdir(parents=True)
    (api_dir / "api.py").write_text(textwrap.dedent(FACADE_API))
    (tmp_path / "README.md").write_text(readme_text)
    findings, _ = analyze_paths([str(tmp_path / "src")], select=["RPR010"])
    return findings


def test_rpr010_matching_readme_passes(tmp_path):
    assert _run_facade(tmp_path, GOOD_README) == []


def test_rpr010_drifted_readme_fails(tmp_path):
    findings = _run_facade(tmp_path, DRIFTED_README)
    msgs = [f.message for f in findings]
    assert any("ratio" in m for m in msgs)            # unknown kwarg
    assert any("quantize" in m for m in msgs)         # renamed method
    assert any("mode" in m and "unbound" in m for m in msgs)
    assert any("make_model" in m for m in msgs)       # nonexistent function
    # findings anchor to the README, inside the fence
    assert all(f.path.endswith("README.md") for f in findings)
    assert all(f.line > 3 for f in findings)
    assert all(f.severity == "error" for f in findings)


def test_rpr010_no_readme_no_findings(tmp_path):
    api_dir = tmp_path / "src" / "repro"
    api_dir.mkdir(parents=True)
    (api_dir / "api.py").write_text(textwrap.dedent(FACADE_API))
    findings, _ = analyze_paths([str(tmp_path / "src")], select=["RPR010"])
    assert findings == []


def test_rpr010_shipped_readme_matches_facade():
    """The acceptance gate: the repo's own README examples bind against
    the real repro.api signatures."""
    findings, _ = analyze_paths([str(REPO / "src")], select=["RPR010"])
    assert findings == [], "\n".join(f.render() for f in findings)


# ------------------------------------------------------------------ RPR011


BAD_DIRECT_CLOCK = """
    import time

    def admit(slot):
        t0 = time.perf_counter()
        slot.run()
        return time.perf_counter() - t0
"""

BAD_CLOCK_FROM_IMPORT = """
    from time import monotonic

    def tick():
        return monotonic()
"""

GOOD_OBS_CLOCK = """
    from repro.obs import clock

    def admit(slot):
        t0 = clock.now()
        slot.run()
        return clock.now() - t0
"""

GOOD_WALL_CLOCK = """
    import time

    def heartbeat(path, step):
        return {"step": step, "time": time.time()}
"""

BAD_CLOCK_NOQA = """
    import time

    def legacy():
        return time.monotonic()  # repro: noqa[RPR011] pre-obs shim
"""

LIB = "src/repro/serving/engine.py"


def test_rpr011_flags_direct_clock_in_library():
    assert ids(run(BAD_DIRECT_CLOCK, "RPR011", path=LIB)) == [
        "RPR011", "RPR011"]
    assert ids(run(BAD_CLOCK_FROM_IMPORT, "RPR011", path=LIB)) == ["RPR011"]


def test_rpr011_good_patterns_pass():
    assert run(GOOD_OBS_CLOCK, "RPR011", path=LIB) == []
    assert run(GOOD_WALL_CLOCK, "RPR011", path=LIB) == []


def test_rpr011_scope():
    # obs/ itself is the sanctioned home of the clock
    assert run(BAD_DIRECT_CLOCK, "RPR011",
               path="src/repro/obs/clock.py") == []
    # tests/benchmarks are outside the library
    assert run(BAD_DIRECT_CLOCK, "RPR011",
               path="benchmarks/bench_serving.py") == []
    assert run(BAD_DIRECT_CLOCK, "RPR011",
               path="tests/test_serving.py") == []


def test_rpr011_noqa():
    assert run(BAD_CLOCK_NOQA, "RPR011", path=LIB) == []


# --------------------------------------------------------------- noqa


BAD_KEY_REUSE_NOQA = """
    import jax

    def sample(key):
        a = jax.random.normal(key, (4,))
        b = jax.random.normal(key, (4,))  # repro: noqa[RPR003] shared on purpose
        return a + b
"""

BAD_KEY_REUSE_BARE_NOQA = """
    import jax

    def sample(key):
        a = jax.random.normal(key, (4,))
        b = jax.random.normal(key, (4,))  # repro: noqa
        return a + b
"""

BAD_KEY_REUSE_WRONG_NOQA = """
    import jax

    def sample(key):
        a = jax.random.normal(key, (4,))
        b = jax.random.normal(key, (4,))  # repro: noqa[RPR001]
        return a + b
"""


def test_noqa_suppression():
    assert run(BAD_KEY_REUSE_NOQA, "RPR003") == []
    assert run(BAD_KEY_REUSE_BARE_NOQA, "RPR003") == []
    assert ids(run(BAD_KEY_REUSE_WRONG_NOQA, "RPR003")) == ["RPR003"]


# ---------------------------------------------------------------- CLI


def test_cli_exit_codes(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text(textwrap.dedent(BAD_KEY_REUSE))
    good = tmp_path / "good.py"
    good.write_text(textwrap.dedent(GOOD_KEY_SPLIT))

    assert cli_main([str(bad)]) == 1
    assert cli_main([str(good)]) == 0
    assert cli_main([str(tmp_path / "missing.py"), "--select", "RPR003"]) == 2
    assert cli_main([str(good), "--select", "RPR999"]) == 2


def test_cli_parse_error_is_rpr000(tmp_path, capsys):
    broken = tmp_path / "broken.py"
    broken.write_text("def f(:\n")
    assert cli_main([str(broken)]) == 1
    assert "RPR000" in capsys.readouterr().out


def test_cli_json_report(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text(textwrap.dedent(BAD_KEY_REUSE))
    out = tmp_path / "report.json"

    assert cli_main([str(bad), "--format", "json", "--json-out", str(out)]) == 1
    printed = json.loads(capsys.readouterr().out)
    on_disk = json.loads(out.read_text())
    assert printed == on_disk
    assert on_disk["tool"] == "repro.analysis"
    assert on_disk["files_analyzed"] == 1
    assert on_disk["errors"] == 1
    f = on_disk["findings"][0]
    assert f["rule_id"] == "RPR003" and f["line"] > 0 and f["path"]


def test_cli_list_rules(capsys):
    assert cli_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rid in ("RPR001", "RPR002", "RPR003", "RPR004", "RPR005", "RPR006",
                "RPR007", "RPR009", "RPR010", "RPR011"):
        assert rid in out


def test_cli_fixture_dirs_excluded_by_default(tmp_path):
    fixture_dir = tmp_path / "fixtures"
    fixture_dir.mkdir()
    (fixture_dir / "bad.py").write_text(textwrap.dedent(BAD_KEY_REUSE))
    assert cli_main([str(tmp_path)]) == 0
    assert cli_main([str(tmp_path), "--no-default-excludes"]) == 1


# ----------------------------------------------------------- self-check


def test_shipped_tree_is_clean():
    """The gate CI enforces: the repo's own code has no error findings."""
    paths = [
        str(REPO / d)
        for d in ("src", "tests", "benchmarks", "examples")
        if (REPO / d).is_dir()
    ]
    findings, n_files = analyze_paths(paths)
    errors = [f for f in findings if f.severity == "error"]
    assert not errors, "\n".join(f.render() for f in errors)
    assert n_files > 50
