"""The paper's core algebra, property-tested (DESIGN.md §2):

  1. Eq. 5 is an exact identity in fp arithmetic: X_hat W + x_hat w_hat = XW
     for ANY s supported on O.
  2. Quaff's quantized error on outlier-heavy activations beats naive WAQ
     once s tracks the outlier scale (Fig. 2c).
  3. Momentum dynamics (Eq. 7/8): s stays >= 1, gamma=1 freezes, gamma=0
     jumps to beta, fixed point = beta under constant stats.

hypothesis is optional: the properties are widened over random inputs when
it is installed, and a deterministic fixed-case sweep exercises the same
invariants either way (the module never aborts collection).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.core.quaff_linear import prepare_quaff_weights, quaff_matmul
from repro.core.scaling import ScaleState, beta_from_stats, momentum_update

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: deterministic fallbacks below still run
    given = None

if given is not None:
    settings.register_profile("ci", max_examples=20, deadline=None)
    settings.load_profile("ci")


# --------------------------------------------------------------------------
# Deterministic invariant checks (always collected)
# --------------------------------------------------------------------------
def _check_eq5_identity_fp(seed, n_out, s_val):
    """X_hat W + X_hat[:,O] (s_O - 1) W[O,:] == X W exactly (no quant)."""
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    t, c_in, c_out = 8, 32, 16
    x = jax.random.normal(keys[0], (t, c_in), jnp.float64
                          if jax.config.read("jax_enable_x64") else jnp.float32)
    w = jax.random.normal(keys[1], (c_in, c_out))
    idx = np.sort(np.asarray(
        jax.random.choice(keys[2], c_in, (n_out,), replace=False)))
    s = jnp.full((n_out,), s_val)
    s_inv = jnp.ones((c_in,)).at[idx].set(1.0 / s)
    x_hat = x * s_inv[None, :]
    w_hat = (s - 1.0)[:, None] * w[idx, :]
    y = x_hat @ w + x_hat[:, idx] @ w_hat
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("seed,n_out,s_val",
                         [(0, 1, 1.0), (1, 3, 7.5), (2, 6, 50.0),
                          (12345, 4, 23.0)])
def test_eq5_identity_fp_fixed(seed, n_out, s_val):
    _check_eq5_identity_fp(seed, n_out, s_val)


def _check_quaff_beats_naive(seed, outlier_scale):
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    t, c_in, c_out = 32, 64, 48
    x = jax.random.normal(keys[0], (t, c_in))
    idx = jnp.array([3, 17, 50], jnp.int32)
    x = x.at[:, idx].mul(outlier_scale)
    w = jax.random.normal(keys[1], (c_in, c_out)) * 0.05
    y_fp = x @ w

    qw, st0 = prepare_quaff_weights(w, idx)
    _, stats = quaff_matmul(x, qw, st0.s)
    st1 = momentum_update(st0, stats, gamma=0.0)  # jump to beta
    y_q, _ = quaff_matmul(x, qw, st1.s)

    w_int, w_delta = quant.quantize(w, axis=0)
    y_n = quant.quantized_matmul(x, w_int, w_delta)

    err_q = float(jnp.mean(jnp.abs(y_q - y_fp)))
    err_n = float(jnp.mean(jnp.abs(y_n - y_fp)))
    assert err_q < err_n, (err_q, err_n)


@pytest.mark.parametrize("seed,outlier_scale",
                         [(0, 30.0), (7, 80.0), (42, 200.0)])
def test_quaff_beats_naive_on_outliers_fixed(seed, outlier_scale):
    _check_quaff_beats_naive(seed, outlier_scale)


def test_eq9_shares_per_token_delta():
    """x_hat_int must be a column GATHER of X_hat_int (Delta_xhat == Delta_x)
    — no second quantization of the outlier slab (Eq. 9)."""
    keys = jax.random.split(jax.random.PRNGKey(0), 2)
    x = jax.random.normal(keys[0], (16, 32)).at[:, 4].mul(100.0)
    w = jax.random.normal(keys[1], (32, 8)) * 0.1
    idx = jnp.array([4], jnp.int32)
    qw, st0 = prepare_quaff_weights(w, idx)
    s = jnp.array([10.0])
    s_inv = jnp.ones((32,)).at[idx].set(1.0 / s)
    x_int, x_delta = quant.quantize(x * s_inv[None, :], axis=-1)
    # the kernel's gathered slab must equal re-gathering from x_int
    xo = jnp.take(x_int, idx, axis=1)
    assert xo.dtype == jnp.int8
    # and the forward must be reproducible from those exact pieces
    w_hat = (s - 1.0)[:, None] * qw.w_outlier
    wo_int, wo_delta = quant.quantize(w_hat, axis=0)
    y_manual = (quant.int_matmul(x_int, qw.w_int).astype(jnp.float32)
                * x_delta * qw.w_delta
                + quant.int_matmul(xo, wo_int).astype(jnp.float32)
                * x_delta * wo_delta)
    y, _ = quaff_matmul(x, qw, s)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_manual), rtol=1e-5)


def _check_momentum_properties(gamma, xmax):
    st0 = ScaleState(s=jnp.array([2.0, 5.0]),
                     w_absmax=jnp.array([0.5, 0.25]))
    stats = jnp.array([xmax, xmax])
    st1 = momentum_update(st0, stats, gamma=gamma)
    beta = beta_from_stats(stats, st0.w_absmax)
    assert bool(jnp.all(st1.s >= 1.0 - 1e-6))
    np.testing.assert_allclose(np.asarray(st1.s),
                               np.asarray(gamma * st0.s + (1 - gamma) * beta),
                               rtol=1e-6)
    # fixed point: repeated updates with constant stats converge to beta
    stx = st0
    for _ in range(200):
        stx = momentum_update(stx, stats, gamma=0.5)
    np.testing.assert_allclose(np.asarray(stx.s), np.asarray(beta), rtol=1e-4)


@pytest.mark.parametrize("gamma,xmax",
                         [(0.0, 0.1), (0.2, 10.0), (0.5, 1000.0), (1.0, 5.0)])
def test_momentum_properties_fixed(gamma, xmax):
    _check_momentum_properties(gamma, xmax)


def test_beta_floor_is_one():
    beta = beta_from_stats(jnp.array([1e-6]), jnp.array([100.0]))
    assert float(beta[0]) == 1.0


# --------------------------------------------------------------------------
# Hypothesis property tests (skipped cleanly when hypothesis is absent)
# --------------------------------------------------------------------------
if given is not None:

    @given(st.integers(0, 2 ** 31 - 1), st.integers(1, 6),
           st.floats(1.0, 50.0))
    def test_eq5_identity_fp(seed, n_out, s_val):
        _check_eq5_identity_fp(seed, n_out, s_val)

    @given(st.integers(0, 2 ** 31 - 1), st.floats(30.0, 200.0))
    def test_quaff_beats_naive_on_outliers(seed, outlier_scale):
        _check_quaff_beats_naive(seed, outlier_scale)

    @given(st.floats(0.0, 1.0), st.floats(0.1, 1000.0))
    def test_momentum_properties(gamma, xmax):
        _check_momentum_properties(gamma, xmax)
