"""Paged + quantized KV-cache subsystem (repro.serving.paged).

Covers: the block allocator (exhaustion = admission refusal not crash,
release/reacquire reuse, interleaved retire/admit), paged-vs-contiguous
greedy parity across transformer + moe families (exact in fp, including
chunked prefill and batched same-length admission), int8 KV token-identity
on the tiny transformer config, the Pallas block-table attention kernel vs
its jnp oracle, and the block-pool telemetry.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core.peft import PEFTConfig
from repro.data.pipeline import DataConfig, Loader, calibration_batches
from repro.models.config import ModelConfig, QuantConfig
from repro.serving import Engine, GenerationRequest
from repro.serving.paged import kvquant as KVQ
from repro.serving.paged.blocks import BlockAllocator, BlockTable
from repro.serving.paged.kernels.paged_attention import (paged_attention,
                                                         paged_attention_ref)

VOCAB, PROMPT = 128, 8


def _tiny_cfg(mode="fp32", **over):
    base = dict(
        name="paged-test", family="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=VOCAB, head_dim=16,
        quant=QuantConfig(mode=mode),
        peft=PEFTConfig(method="lora", lora_rank=4))
    base.update(over)
    return ModelConfig(**base)


@pytest.fixture(scope="module")
def quaff_model():
    dcfg = DataConfig(vocab_size=VOCAB, seq_len=PROMPT, batch_size=4)
    model = api.prepare(_tiny_cfg())
    model.calibrate(calibration_batches(dcfg, 2))
    model.convert("quaff")
    return model


@pytest.fixture(scope="module")
def moe_model():
    cfg = dataclasses.replace(
        _tiny_cfg(), family="moe", n_experts=4, top_k=2, capacity_factor=4.0)
    return api.prepare(cfg)


@pytest.fixture(scope="module")
def prompts():
    return np.asarray(Loader(DataConfig(vocab_size=VOCAB, seq_len=PROMPT,
                                        batch_size=4)).batch(0)["tokens"])


def _lockstep_reference(model, prompts, max_new):
    tokens = jnp.asarray(prompts)
    prompt_len = tokens.shape[1]
    logits, caches = model.prefill({"tokens": tokens}, extra_len=max_new)
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    for i in range(max_new - 1):
        logits, caches = model.decode_step(caches, tok, prompt_len + i)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    return np.asarray(jnp.concatenate(out, axis=1))


# ---------------------------------------------------------------------------
# allocator
# ---------------------------------------------------------------------------
def test_allocator_sizing_and_reuse():
    alloc = BlockAllocator(n_blocks=6, block_size=4)
    assert [alloc.blocks_for(n) for n in (0, 1, 4, 5, 8)] == [0, 1, 1, 2, 2]
    a = alloc.acquire(2)
    b = alloc.acquire(3)
    assert a == [1, 2] and b == [3, 4, 5]
    assert alloc.n_free == 1 and alloc.n_used == 5
    assert alloc.acquire(2) is None          # exhaustion: refusal, not crash
    assert alloc.n_free == 1                 # failed acquire takes nothing
    alloc.release(a)
    assert alloc.acquire(2) == [1, 2]        # released ids are reused
    assert alloc.stats()["blocks_in_use"] == 5


def test_allocator_release_validation():
    alloc = BlockAllocator(n_blocks=3, block_size=4)
    got = alloc.acquire(2)
    alloc.release(got)
    with pytest.raises(ValueError, match="already free"):
        alloc.release([got[0]])
    with pytest.raises(ValueError, match="outside pool"):
        alloc.release([99])


def test_block_table_row_and_waste():
    t = BlockTable([3, 7], block_size=4, n_tokens=5)
    assert t.capacity == 8 and t.waste == 3
    row = t.as_row(max_pages=4)
    assert row.tolist() == [3, 7, 0, 0]      # tail points at the trash page


# ---------------------------------------------------------------------------
# paged vs contiguous greedy parity (fp: exact machinery equivalence)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("prefill_chunk", [0, 3])
def test_paged_fp_matches_lockstep(quaff_model, prompts, prefill_chunk):
    max_new = 8
    ref = _lockstep_reference(quaff_model, prompts, max_new)
    eng = Engine(quaff_model, max_slots=len(prompts),
                 max_seq_len=PROMPT + max_new, kv_layout="paged",
                 kv_dtype="fp", block_size=4, prefill_chunk=prefill_chunk)
    outs = eng.run([GenerationRequest(p, max_new_tokens=max_new)
                    for p in prompts])
    got = np.asarray([o.token_ids for o in outs])
    np.testing.assert_array_equal(ref, got)
    assert eng.stats.requests_completed == len(prompts)


def test_paged_fp_matches_lockstep_moe(moe_model, prompts):
    """MoE family through the block-table read path (ample expert capacity,
    same decode batch composition as contiguous slot decode)."""
    max_new = 6
    ref = _lockstep_reference(moe_model, prompts, max_new)
    eng = Engine(moe_model, max_slots=len(prompts),
                 max_seq_len=PROMPT + max_new, kv_layout="paged",
                 block_size=4)
    outs = eng.run([GenerationRequest(p, max_new_tokens=max_new)
                    for p in prompts])
    np.testing.assert_array_equal(
        ref, np.asarray([o.token_ids for o in outs]))


def test_paged_fp_matches_lockstep_sliding_window(prompts):
    """gemma3-style local:global pattern through the block-table path —
    the window mask must survive the page-padded key axis."""
    cfg = _tiny_cfg(n_layers=4, sliding_window=4, global_every=2)
    model = api.prepare(cfg)
    max_new = 6
    ref = _lockstep_reference(model, prompts[:3], max_new)
    eng = Engine(model, max_slots=3, max_seq_len=PROMPT + max_new,
                 kv_layout="paged", block_size=4, prefill_chunk=3)
    outs = eng.run([GenerationRequest(p, max_new_tokens=max_new)
                    for p in prompts[:3]])
    np.testing.assert_array_equal(
        ref, np.asarray([o.token_ids for o in outs]))


def test_paged_mixed_prompt_lengths_parity(quaff_model, prompts):
    """Each request equals ITS OWN single-request decode regardless of what
    shares the block pool — mixed prompt lengths, slots < requests, so the
    run also interleaves retire/admit block reuse."""
    max_new = 6
    lens = [PROMPT, PROMPT - 2, PROMPT - 3, PROMPT - 1]
    eng = Engine(quaff_model, max_slots=2, max_seq_len=PROMPT + max_new,
                 kv_layout="paged", block_size=4)
    outs = eng.run([GenerationRequest(prompts[i][:n], max_new_tokens=max_new)
                    for i, n in enumerate(lens)])
    for i, (n, out) in enumerate(zip(lens, outs)):
        solo = _lockstep_reference(quaff_model, prompts[i:i + 1, :n], max_new)
        np.testing.assert_array_equal(
            solo[0], np.asarray(out.token_ids),
            err_msg=f"request {i} (prompt len {n}) diverged in shared pool")


def test_prompt_peft_layouts_agree(prompts):
    """Prompt-PEFT decode must not re-prepend the virtual-token prefix in
    either layout (it is in the cache from prefill): both engines strip it
    from decode-step adapters, so their streams agree token-for-token —
    including chunked admission, where only the FIRST chunk carries the
    prefix and continuation chunks run on stripped adapters."""
    cfg = _tiny_cfg(peft=PEFTConfig(method="prompt", n_virtual_tokens=4))
    model = api.prepare(cfg)
    outs = {}
    for name, layout, kw in (
            ("contiguous", "contiguous", {}),
            ("paged", "paged", {"block_size": 4}),
            ("paged-chunked", "paged", {"block_size": 4,
                                        "prefill_chunk": 3})):
        eng = Engine(model, max_slots=2, max_seq_len=PROMPT + 4 + 6,
                     kv_layout=layout, **kw)
        outs[name] = [o.token_ids for o in eng.run(
            [GenerationRequest(p, max_new_tokens=6) for p in prompts[:2]])]
    assert outs["contiguous"] == outs["paged"] == outs["paged-chunked"]


def test_block_reuse_interleaved_retire_admit(quaff_model, prompts):
    """Mixed budgets force retire-then-admit into RECYCLED blocks mid-run;
    every stream must still match a fresh full-capacity engine run."""
    short, long = 3, 12
    eng_ref = Engine(quaff_model, max_slots=6,
                     max_seq_len=PROMPT + long, kv_layout="paged",
                     block_size=4)
    def reqs():
        return [GenerationRequest(prompts[i % 4], request_id=f"r{i}",
                                  max_new_tokens=short if i % 2 else long)
                for i in range(6)]
    ref = {o.request_id: o.token_ids for o in eng_ref.run(reqs())}
    eng = Engine(quaff_model, max_slots=2, max_seq_len=PROMPT + long,
                 kv_layout="paged", block_size=4)
    got = {o.request_id: o.token_ids for o in eng.run(reqs())}
    assert ref == got
    assert eng.stats.blocks_in_use == 0      # everything released at the end


# ---------------------------------------------------------------------------
# int8 KV
# ---------------------------------------------------------------------------
def test_paged_int8_token_identical_tiny_transformer(quaff_model):
    """Acceptance: paged int8-KV greedy decode is token-identical to the
    contiguous fp greedy decode on the tiny transformer config — plain and
    chunked admission. The workload (prompt seed 219) was picked with a
    margin check: parity also holds with the key-channel grid perturbed
    +/-3%, so it does not sit on a knife-edge argmax tie."""
    max_new = 6
    ints = np.asarray(Loader(DataConfig(vocab_size=VOCAB, seq_len=PROMPT,
                                        batch_size=4, seed=219)
                             ).batch(0)["tokens"])
    ref = _lockstep_reference(quaff_model, ints, max_new)
    for chunk in (0, 3):
        eng = Engine(quaff_model, max_slots=4, max_seq_len=PROMPT + max_new,
                     kv_layout="paged", kv_dtype="int8", prefill_chunk=chunk)
        outs = eng.run([GenerationRequest(p, max_new_tokens=max_new)
                        for p in ints])
        np.testing.assert_array_equal(
            ref, np.asarray([o.token_ids for o in outs]),
            err_msg=f"int8 paged diverged from contiguous fp (chunk={chunk})")


def test_int8_scales_seeded_from_calibration(quaff_model):
    """A calibrated model carries the KV capture; the pool's key grid must
    come from it (no probe prefill) and bytes drop ~4x vs fp."""
    scales = KVQ.k_scales_from_stats(quaff_model.stats, quaff_model.cfg)
    assert scales is not None and scales.shape == (2, 2, 16)
    eng = Engine(quaff_model, max_slots=2, max_seq_len=16,
                 kv_layout="paged", kv_dtype="int8")
    assert eng._paged.needs_k_seed
    eng.run([GenerationRequest(np.arange(1, 7), max_new_tokens=4)])
    assert not eng._paged.needs_k_seed
    np.testing.assert_allclose(np.asarray(eng._paged.pools["k_scale"]),
                               np.asarray(scales))
    fp_tok = KVQ.kv_bytes_per_token(quaff_model.cfg, "fp")
    int8_tok = KVQ.kv_bytes_per_token(quaff_model.cfg, "int8")
    assert fp_tok / int8_tok > 3.5


def test_int8_probe_seeding_without_calibration(prompts):
    """No calibration artifacts -> the key grid is probed from the first
    admitted prompt's fp prefill; decode still runs and stays in-vocab."""
    model = api.prepare(_tiny_cfg())          # fp32 mode, stats=None
    assert model.stats is None
    eng = Engine(model, max_slots=2, max_seq_len=16,
                 kv_layout="paged", kv_dtype="int8")
    outs = eng.run([GenerationRequest(p, max_new_tokens=4)
                    for p in prompts[:2]])
    assert not eng._paged.needs_k_seed
    assert all(0 <= t < VOCAB for o in outs for t in o.token_ids)


def test_quantize_roundtrip_error_bound():
    rng = np.random.RandomState(0)
    k = jnp.asarray(rng.randn(4, 5, 2, 16).astype(np.float32))
    scale = jnp.asarray(np.abs(k).max(axis=(0, 1)) / 127.0)
    err = KVQ.dequant_k(KVQ.quantize_k(k, scale), scale) - k
    assert float(jnp.max(jnp.abs(err))) <= float(jnp.max(scale)) / 2 + 1e-7
    qv, vs = KVQ.quantize_v(k)
    verr = KVQ.dequant_v(qv, vs) - k
    assert float(jnp.max(jnp.abs(verr))) <= float(jnp.max(vs)) / 2 + 1e-7


# ---------------------------------------------------------------------------
# admission under block exhaustion
# ---------------------------------------------------------------------------
def test_exhaustion_defers_admission_then_completes(quaff_model, prompts):
    """A pool with room for ONE request at a time serves them all anyway:
    later requests wait for blocks, nothing crashes, streams stay correct."""
    max_new = 6
    ref = _lockstep_reference(quaff_model, prompts, max_new)
    eng = Engine(quaff_model, max_slots=4, max_seq_len=PROMPT + max_new,
                 kv_layout="paged", block_size=4,
                 n_blocks=(PROMPT + max_new + 3) // 4)   # one request's worth
    outs = eng.run([GenerationRequest(p, max_new_tokens=max_new)
                    for p in prompts])
    np.testing.assert_array_equal(
        ref, np.asarray([o.token_ids for o in outs]))
    assert eng.stats.admission_deferrals > 0
    assert eng.stats.requests_completed == 4


def test_submit_rejects_impossible_request(quaff_model, prompts):
    eng = Engine(quaff_model, max_slots=2, max_seq_len=64,
                 kv_layout="paged", block_size=4, n_blocks=2)
    with pytest.raises(ValueError, match="KV blocks"):
        eng.submit(GenerationRequest(prompts[0], max_new_tokens=16))


def test_engine_kv_knob_validation(quaff_model):
    with pytest.raises(ValueError, match="kv_layout"):
        Engine(quaff_model, kv_layout="banana")
    with pytest.raises(ValueError, match="paged"):
        Engine(quaff_model, kv_dtype="int8")
    with pytest.raises(ValueError, match="paged"):
        Engine(quaff_model, prefill_chunk=4)


# ---------------------------------------------------------------------------
# batched same-length admission + telemetry
# ---------------------------------------------------------------------------
def test_batched_same_length_admission(quaff_model, prompts):
    """Four same-length prompts admitted together must prefill as ONE
    compiled call per chunk step, not one call per request."""
    eng = Engine(quaff_model, max_slots=4, max_seq_len=PROMPT + 4,
                 kv_layout="paged", block_size=4, prefill_chunk=4)
    eng.run([GenerationRequest(p, max_new_tokens=4) for p in prompts])
    assert eng.stats.prefills == 4
    assert eng.stats.prefill_chunks == 8            # 4 reqs x 2 chunks
    assert eng.stats.prefill_batches == 2           # batched: one per step
    # contiguous admission pays one call per request
    eng_c = Engine(quaff_model, max_slots=4, max_seq_len=PROMPT + 4)
    eng_c.run([GenerationRequest(p, max_new_tokens=4) for p in prompts])
    assert eng_c.stats.prefill_batches == 4


def test_block_pool_telemetry(quaff_model, prompts):
    max_new = 6
    eng = Engine(quaff_model, max_slots=2, max_seq_len=PROMPT + max_new,
                 kv_layout="paged", block_size=4)
    eng.run([GenerationRequest(prompts[i][:PROMPT - 2 * i],
                               max_new_tokens=max_new) for i in range(3)])
    st = eng.stats
    need = [PROMPT + max_new, PROMPT - 2 + max_new, PROMPT - 4 + max_new]
    blocks = sum(-(-n // 4) for n in need)
    assert st.peak_blocks_in_use <= st.n_blocks
    assert st.kv_bytes_per_request_sum == \
        blocks * 4 * KVQ.kv_bytes_per_token(quaff_model.cfg, "fp")
    assert st.kv_bytes_per_request < st.contiguous_bytes_per_request
    assert st.kv_bytes_saved_vs_contiguous > 0
    d = st.as_dict()
    for key in ("blocks_in_use", "fragmentation", "mean_fragmentation",
                "kv_bytes_per_request", "kv_bytes_saved_vs_contiguous",
                "prefill_chunks"):
        assert key in d
    # the current gauge reads 0 once drained; the decode-step-sampled mean
    # is the reportable number and must be nonzero here (needs of 14/12/10
    # tokens do not fill whole 4-token blocks while decoding)
    assert st.fragmentation == 0.0
    assert 0.0 < st.mean_fragmentation <= 1.0


# ---------------------------------------------------------------------------
# Pallas block-table attention kernel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("quantized", [False, True])
def test_paged_attention_kernel_matches_ref(quantized):
    rng = np.random.RandomState(3)
    b, kh, g, hd, bs, pages, pool = 3, 2, 2, 16, 8, 4, 13
    q = jnp.asarray(rng.randn(b, kh, g, hd).astype(np.float32))
    if quantized:
        k_pool = jnp.asarray(rng.randint(-127, 128, (pool, bs, kh, hd)),
                             jnp.int8)
        v_pool = jnp.asarray(rng.randint(-127, 128, (pool, bs, kh, hd)),
                             jnp.int8)
        k_scale = jnp.asarray(
            rng.rand(kh, hd).astype(np.float32) * 0.02 + 1e-3)
        v_scale = jnp.asarray(
            rng.rand(pool, bs, kh).astype(np.float32) * 0.02 + 1e-3)
        ref_scales = (k_scale, v_scale)
    else:
        k_pool = jnp.asarray(rng.randn(pool, bs, kh, hd).astype(np.float32))
        v_pool = jnp.asarray(rng.randn(pool, bs, kh, hd).astype(np.float32))
        k_scale = jnp.ones((kh, hd), jnp.float32)
        v_scale = jnp.ones((pool, bs, kh), jnp.float32)
        ref_scales = (None, None)
    bt = jnp.asarray(rng.randint(1, pool, (b, pages)), jnp.int32)
    cl = jnp.asarray([5, 17, 32], jnp.int32)     # partial / mid / full window
    out = paged_attention(q, k_pool, v_pool, bt, cl, k_scale, v_scale,
                          interpret=True)
    ref = paged_attention_ref(q, k_pool, v_pool, bt, cl, *ref_scales)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_paged_attention_kernel_free_row_finite():
    """context_len 0 (free slot riding the batch) must stay finite."""
    rng = np.random.RandomState(4)
    q = jnp.asarray(rng.randn(2, 2, 2, 16).astype(np.float32))
    k_pool = jnp.asarray(rng.randn(5, 8, 2, 16).astype(np.float32))
    bt = jnp.zeros((2, 2), jnp.int32)
    cl = jnp.asarray([0, 0], jnp.int32)
    out = paged_attention(q, k_pool, k_pool, bt, cl,
                          jnp.ones((2, 16), jnp.float32),
                          jnp.ones((5, 8, 2), jnp.float32), interpret=True)
    assert bool(jnp.all(jnp.isfinite(out)))


@pytest.mark.slow
def test_kernel_routed_engine_decode_parity():
    """REPRO_PAGED_PALLAS=1 decode (block-table kernel, interpret mode off
    TPU) is token-identical to the lockstep fp reference. Runs in a
    subprocess: the flag is read once at import so jit cache keys stay
    consistent, which means it cannot be flipped inside this process."""
    import os
    import subprocess
    import sys
    script = """
import numpy as np, jax.numpy as jnp
from repro import api
from repro.core.peft import PEFTConfig
from repro.data.pipeline import DataConfig, Loader
from repro.models.config import ModelConfig, QuantConfig
from repro.models import layers as L
from repro.serving import Engine, GenerationRequest
assert L._PAGED_PALLAS
cfg = ModelConfig(name="kr", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                  head_dim=16, quant=QuantConfig(mode="fp32"),
                  peft=PEFTConfig(method="lora", lora_rank=4))
model = api.prepare(cfg)
prompts = np.asarray(Loader(DataConfig(vocab_size=128, seq_len=8,
                                       batch_size=2)).batch(0)["tokens"])
logits, caches = model.prefill({"tokens": jnp.asarray(prompts)}, extra_len=4)
tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
ref = [tok]
for i in range(3):
    logits, caches = model.decode_step(caches, tok, 8 + i)
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    ref.append(tok)
ref = np.asarray(jnp.concatenate(ref, axis=1))
eng = Engine(model, max_slots=2, max_seq_len=12, kv_layout="paged",
             block_size=4)
outs = eng.run([GenerationRequest(p, max_new_tokens=4) for p in prompts])
np.testing.assert_array_equal(ref, np.asarray([o.token_ids for o in outs]))
print("KERNEL_PARITY_OK")
"""
    env = dict(os.environ, REPRO_PAGED_PALLAS="1", JAX_PLATFORMS="cpu")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(os.path.dirname(__file__), "..", "src"),
                    env.get("PYTHONPATH")) if p)
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "KERNEL_PARITY_OK" in proc.stdout
