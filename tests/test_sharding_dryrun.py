"""Sharding/dry-run machinery tests. Multi-device bits run in subprocesses
(XLA_FLAGS must be set before jax init; the main pytest process keeps one
device, per the assignment)."""
import json
import os
import subprocess
import sys

import pytest

import conftest

pytestmark = [
    pytest.mark.slow,  # subprocess compiles: minutes
    conftest.requires_modern_jax,
]

_MINI_DRYRUN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import warnings; warnings.filterwarnings("ignore")
import dataclasses, jax
from repro.configs import get_config
from repro.core.peft import PEFTConfig
from repro.launch import hloparse, shardings, specs
from repro.launch.mesh import make_test_mesh
from repro.models.config import QuantConfig, ShapeConfig, TrainConfig
from repro.runtime.pspec import use_rules
from repro.train import steps as STEPS

cfg = get_config("%(arch)s").reduced()
cfg = dataclasses.replace(cfg, quant=QuantConfig(mode="quaff"),
                          peft=PEFTConfig(method="lora", lora_rank=4),
                          moe_groups=4)
shape = ShapeConfig("mini", seq_len=32, global_batch=8, kind="%(kind)s")
mesh = make_test_mesh((4, 2), ("data", "model"))
tcfg = TrainConfig(microbatches=2, remat=True)
rules = shardings.build_rules(cfg, mesh, shape)
frozen_a, adapters_a, qstate_a = specs.model_specs(cfg)
frozen_sh = shardings.frozen_shardings(frozen_a, cfg, mesh)
with jax.set_mesh(mesh), use_rules(rules):
    if shape.kind == "train":
        state_a = specs.state_specs(adapters_a, qstate_a, tcfg)
        step = STEPS.build_train_step(cfg, tcfg)
        lowered = jax.jit(step, in_shardings=(
            frozen_sh, shardings.replicated_shardings(state_a, mesh),
            shardings.batch_shardings(
                specs.batch_specs(cfg, shape, with_labels=True), mesh)),
            donate_argnums=(1,)).lower(
            frozen_a, state_a, specs.batch_specs(cfg, shape, with_labels=True))
    else:
        d = specs.decode_specs(cfg, shape)
        step = STEPS.build_decode(cfg)
        lowered = jax.jit(step, in_shardings=(
            frozen_sh, shardings.replicated_shardings(adapters_a, mesh),
            shardings.replicated_shardings(qstate_a, mesh),
            shardings.cache_shardings(d["caches"], cfg, mesh),
            shardings.batch_shardings(d["token"], mesh),
            jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()))
        ).lower(frozen_a, adapters_a, qstate_a, d["caches"], d["token"],
                d["pos"])
    compiled = lowered.compile()
summary = hloparse.analyze(compiled.as_text())
mem = compiled.memory_analysis()
assert summary.total_flops > 0
print("OK", int(summary.total_collective_bytes), int(summary.dot_flops_int8))
"""


@pytest.mark.parametrize("arch,kind", [
    ("tinyllama-1.1b", "train"),
    ("olmoe-1b-7b", "train"),      # MoE: grouped dispatch + EP
    ("zamba2-1.2b", "decode"),     # hybrid caches
    ("whisper-large-v3", "decode"),
])
def test_mini_dryrun_compiles(arch, kind):
    script = _MINI_DRYRUN % {"arch": arch, "kind": kind}
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=900,
                       env={**os.environ, "PYTHONPATH": "src"})
    assert r.returncode == 0, (r.stderr or r.stdout)[-3000:]
    assert "OK" in r.stdout
    # int8 GEMMs must dominate the partitioned program (Quaff on TPU MXU)
    parts = r.stdout.split()
    assert int(parts[-1]) > 0, "no int8 dot flops in partitioned HLO"


def test_dryrun_artifacts_schema():
    """Any dry-run JSONs produced so far must carry the roofline fields."""
    d = os.path.join("experiments", "dryrun")
    if not os.path.isdir(d) or not os.listdir(d):
        pytest.skip("no dry-run artifacts yet")
    for name in os.listdir(d):
        if not name.endswith(".json"):
            continue
        with open(os.path.join(d, name)) as f:
            rec = json.load(f)
        for key in ("memory", "hlo", "model_flops_per_token",
                    "tokens_per_step", "mesh"):
            assert key in rec, (name, key)
        assert rec["hlo"]["dot_flops_int8"] >= 0
