"""Per-kernel validation: sweep shapes/dtypes in interpret mode and compare
against the pure-jnp oracles (ref.py) and the core Quaff path. Integer GEMM
accumulation is exact, so tolerances are fp32-epsilon tight."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.core.quaff_linear import prepare_quaff_weights, quaff_matmul
from repro.kernels import int8_quant, ops, quaff_matmul as qmk, ref

KEY = jax.random.PRNGKey(42)


def _mk(shape, key, scale=1.0, dtype=jnp.float32):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


@pytest.mark.parametrize("t,k", [(16, 64), (64, 256), (32, 512), (128, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rowmax(t, k, dtype):
    x = _mk((t, k), KEY, 3.0, dtype)
    got = int8_quant.rowmax(x, block_t=16, block_k=64, interpret=True)
    np.testing.assert_allclose(got, ref.rowmax_ref(x), rtol=1e-6)


@pytest.mark.parametrize("t,k", [(16, 64), (64, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_scale_quant(t, k, dtype):
    keys = jax.random.split(KEY, 3)
    x = _mk((t, k), keys[0], 2.0, dtype)
    s_inv = jnp.abs(_mk((k,), keys[1])) + 0.5
    delta = ref.rowmax_ref(x.astype(jnp.float32) * s_inv[None, :]) / 127.0
    got = int8_quant.scale_quant(x, s_inv, delta, block_t=16, block_k=32,
                                 interpret=True)
    want = ref.scale_quant_ref(x, s_inv, delta)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("t,k,n,o", [
    (16, 64, 32, 2), (64, 256, 128, 8), (32, 128, 256, 16), (128, 512, 64, 4),
])
def test_quaff_matmul_fused(t, k, n, o):
    keys = jax.random.split(KEY, 5)
    x_int = jax.random.randint(keys[0], (t, k), -127, 128, jnp.int8)
    w_int = jax.random.randint(keys[1], (k, n), -127, 128, jnp.int8)
    xo_int = jax.random.randint(keys[2], (t, o), -127, 128, jnp.int8)
    wo_int = jax.random.randint(keys[3], (o, n), -127, 128, jnp.int8)
    x_delta = jnp.abs(_mk((t, 1), keys[4])) / 100 + 1e-3
    w_delta = jnp.abs(_mk((1, n), keys[0])) / 100 + 1e-3
    wo_delta = jnp.abs(_mk((1, n), keys[1])) / 100 + 1e-3
    got = qmk.quaff_matmul_fused(
        x_int, w_int, x_delta, w_delta, xo_int, wo_int, wo_delta,
        block_t=16, block_n=32, block_k=32, interpret=True)
    want = ref.quaff_matmul_ref(x_int, w_int, x_delta, w_delta,
                                xo_int, wo_int, wo_delta)
    # int32 accumulation is exact; the dequant epilogue multiplies in a
    # different association order than the oracle -> fp32 ULP noise only
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-5, atol=1e-6)


@pytest.mark.parametrize("t,k,n,n_out", [(32, 128, 64, 3), (64, 256, 128, 12)])
def test_quaff_forward_pallas_vs_core(t, k, n, n_out):
    """Full kernel pipeline == core (non-kernel) Quaff path."""
    keys = jax.random.split(KEY, 3)
    x = _mk((t, k), keys[0], 1.0)
    idx = jnp.sort(jax.random.choice(keys[1], k, (n_out,), replace=False)
                   ).astype(jnp.int32)
    x = x.at[:, idx].mul(80.0)
    w = _mk((k, n), keys[2], 0.05)
    qw, st = prepare_quaff_weights(w, idx)
    s = jnp.abs(_mk((n_out,), keys[0])) * 4 + 1.0
    y_k, st_k = ops.quaff_forward_pallas(x, qw, s, interpret=True,
                                         block_t=16, block_n=32, block_k=64)
    y_c, st_c = quaff_matmul(x, qw, s)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_c),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_c), rtol=1e-6)


def test_naive_forward_pallas():
    keys = jax.random.split(KEY, 2)
    x = _mk((32, 128), keys[0])
    w = _mk((128, 64), keys[1], 0.05)
    w_int, w_delta = quant.quantize(w, axis=0)
    y_k = ops.naive_forward_pallas(x, w_int, w_delta, interpret=True)
    y_ref = quant.quantized_matmul(x, w_int, w_delta)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


def test_kernel_outlier_suppression_wins():
    """The fused kernel with real scales beats naive on outlier data."""
    keys = jax.random.split(KEY, 2)
    x = _mk((64, 256), keys[0]).at[:, 7].mul(150.0)
    w = _mk((256, 64), keys[1], 0.05)
    idx = jnp.array([7], jnp.int32)
    qw, st = prepare_quaff_weights(w, idx)
    y_fp = x @ w
    s_beta = jnp.sqrt(jnp.array([150.0]) / jnp.maximum(st.w_absmax, 1e-8))
    y_q, _ = ops.quaff_forward_pallas(x, qw, s_beta, interpret=True,
                                      block_t=16, block_n=32, block_k=64)
    w_int, w_delta = quant.quantize(w, axis=0)
    y_n = ops.naive_forward_pallas(x, w_int, w_delta, interpret=True)
    err_q = float(jnp.mean(jnp.abs(y_q - y_fp)))
    err_n = float(jnp.mean(jnp.abs(y_n - y_fp)))
    assert err_q < err_n * 0.5, (err_q, err_n)


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("s,hd,causal", [(64, 32, True), (128, 64, True),
                                         (64, 32, False)])
def test_flash_attention_vs_softmax(s, hd, causal):
    from repro.kernels.flash_attention import flash_attention
    keys = jax.random.split(KEY, 3)
    bh = 4
    q = _mk((bh, s, hd), keys[0])
    k = _mk((bh, s, hd), keys[1])
    v = _mk((bh, s, hd), keys[2])
    got = flash_attention(q, k, v, causal=causal, block_q=32, block_k=32,
                          interpret=True)
    scores = jnp.einsum("bqh,bkh->bqk", q, k) / np.sqrt(hd)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(mask[None], scores, -1e30)
    want = jnp.einsum("bqk,bkh->bqh", jax.nn.softmax(scores, -1), v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


def test_gqa_flash_attention_vs_model_attention():
    """GQA wrapper == the model's einsum attention path."""
    from repro.kernels.flash_attention import gqa_flash_attention
    from repro.models.layers import _gqa_scores_softmax_out
    keys = jax.random.split(KEY, 3)
    b, s, kh, g, hd = 2, 64, 2, 3, 32
    q = _mk((b, s, kh, g, hd), keys[0])
    k = _mk((b, s, kh, hd), keys[1])
    v = _mk((b, s, kh, hd), keys[2])
    got = gqa_flash_attention(q, k, v, causal=True, interpret=True,
                              block_q=32, block_k=32)
    mask = jnp.tril(jnp.ones((s, s), bool))[None, None, None]
    want = _gqa_scores_softmax_out(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)
