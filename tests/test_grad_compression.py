"""INT8 gradient all-reduce (shard_map) + error-feedback compression."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import conftest

from repro.optim import adamw

_SHARD_MAP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.optim.compress import compressed_psum_tree, exact_psum_tree

mesh = jax.make_mesh((8,), ("data",), devices=jax.devices()[:8],
                     axis_types=(jax.sharding.AxisType.Auto,))
grads = jax.random.normal(jax.random.PRNGKey(0), (8, 64, 32))

@jax.jit
def reduce_both(g):
    def inner(g_local):
        c = compressed_psum_tree({"g": g_local[0]}, ("data",))["g"]
        e = exact_psum_tree({"g": g_local[0]}, ("data",))["g"]
        return c[None], e[None]
    return jax.shard_map(inner, mesh=mesh, in_specs=P("data"),
                         out_specs=(P("data"), P("data")))(g)

with jax.set_mesh(mesh):
    comp, exact = reduce_both(grads)
comp, exact = np.asarray(comp)[0], np.asarray(exact)[0]
rel = np.mean(np.abs(comp - exact)) / np.mean(np.abs(exact))
assert rel < 0.02, rel
# int8 payload: errors bounded by the shared step size
delta = np.max(np.abs(grads)) / 127.0
assert np.max(np.abs(comp - exact)) <= delta * 1.01, "per-element bound"
print("OK", rel)
"""


@conftest.requires_modern_jax
def test_compressed_psum_matches_exact_subprocess():
    """Runs under 8 forced host devices in a subprocess so the main test
    process keeps its single-device view."""
    r = subprocess.run([sys.executable, "-c", _SHARD_MAP_SCRIPT],
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


def test_error_feedback_unbiased():
    """With EF, the long-run mean of compressed grads tracks the true mean."""
    key = jax.random.PRNGKey(0)
    true_g = jax.random.normal(key, (32, 16)) * 0.1
    err = {"g": jnp.zeros_like(true_g)}
    acc = jnp.zeros_like(true_g)
    n = 200
    for i in range(n):
        noise = jax.random.normal(jax.random.PRNGKey(i), true_g.shape) * 0.05
        g_hat, new_err = adamw.ef_compress({"g": true_g + noise}, err)
        err = new_err
        acc = acc + g_hat["g"]
    bias = float(jnp.mean(jnp.abs(acc / n - true_g)))
    assert bias < 0.01, bias


def test_ef_residual_bounded():
    g = {"g": jax.random.normal(jax.random.PRNGKey(1), (64,))}
    err = {"g": jnp.zeros((64,))}
    for _ in range(10):
        _, err = adamw.ef_compress(g, err)
    delta = float(jnp.max(jnp.abs(g["g"]))) / 127.0
    assert float(jnp.max(jnp.abs(err["g"]))) <= delta * 0.51
