"""Shared test fixtures/markers."""
import jax
import pytest

# The multi-device sharding machinery targets mesh axis_types /
# jax.set_mesh / jax.shard_map; older jax (e.g. 0.4.x) lacks them and the
# subprocess suites skip rather than fail on the missing APIs.
requires_modern_jax = pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType") or not hasattr(jax, "set_mesh"),
    reason="mesh axis_types / jax.set_mesh / jax.shard_map need a newer jax "
           "than this environment provides")
