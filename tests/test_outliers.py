"""Outlier identification (Eq. 6 analog) + budget allocation."""
import jax
import numpy as np

from repro.core import outliers as O


def _acts_with_planted(planted, n=6, t=32, c=256, scale=60.0, seed=0):
    rng = jax.random.PRNGKey(seed)
    x = jax.random.normal(rng, (n, t, c))
    for ch in planted:
        x = x.at[:, :, ch].mul(scale)
    return x


def test_planted_outliers_found():
    planted = [7, 99, 200]
    acts = _acts_with_planted(planted)
    spec = O.identify_outliers(acts, "down_proj")  # 10% of 256 = 25 channels
    for ch in planted:
        assert ch in spec.indices


def test_budget_fractions():
    acts = _acts_with_planted([1], c=10000)
    q = O.identify_outliers(acts, "q_proj")
    d = O.identify_outliers(acts, "down_proj")
    o = O.identify_outliers(acts, "o_proj")
    assert q.count == max(1, round(0.0003 * 10000))
    assert o.count == round(0.04 * 10000)
    assert d.count == round(0.10 * 10000)


def test_total_budget_reallocation():
    dims = {f"layer{i}.down_proj": 1024 for i in range(8)}
    dims.update({f"layer{i}.q_proj": 1024 for i in range(8)})
    counts = O.reallocate_budgets(dims, total_budget=0.05)
    total_cin = sum(dims.values())
    assert sum(counts.values()) <= int(0.05 * total_cin)
    # q_proj keeps at least its tiny share
    assert all(counts[k] >= 1 for k in counts)


def test_hit_rate_perfect_and_zero():
    acts = _acts_with_planted([5, 9], n=1)[0]  # (t, c)
    assert O.hit_rate([5, 9], acts) == 1.0
    assert O.hit_rate([0, 1], acts) == 0.0


def test_scores_rank_outliers_first():
    planted = [3, 77]
    acts = _acts_with_planted(planted, scale=100.0)
    xi = np.asarray(O.outlier_scores(acts))
    top2 = set(np.argsort(-xi)[:2].tolist())
    assert top2 == set(planted)
