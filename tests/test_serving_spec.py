"""serving.spec: multi-step scheduled decode and self-speculative decoding.

The contract under test is TOKEN IDENTITY: for greedy requests, a
``decode_steps=N`` engine and a ``spec_decode`` engine must emit exactly
the byte-for-byte streams of the classic one-token-per-dispatch engine on
the SAME kv layout — across contiguous/paged/int8 pools, mid-decode
admission, EOS inside a scheduled window, and prefix sharing. Seeded
sampling must survive multi-step scheduling unchanged (same per-token key
derivation); speculative sampling is distributionally correct, so sampled
rows only get shape/termination checks here.
"""
import dataclasses

import numpy as np
import pytest

from repro import api
from repro.core.peft import PEFTConfig
from repro.data.pipeline import DataConfig, Loader, calibration_batches
from repro.models.config import ModelConfig, QuantConfig
from repro.serving import Engine, GenerationRequest, SamplingParams
from repro.serving.config import EngineConfig
from repro.serving.spec import draft_model_config, parse_spec_backend

VOCAB, PROMPT, MAX_NEW = 128, 8, 8

LAYOUTS = {
    "contiguous": {},
    "paged": {"kv_layout": "paged"},
    "paged-int8": {"kv_layout": "paged", "kv_dtype": "int8"},
    "paged-prefix": {"kv_layout": "paged", "prefix_share": True},
}


def _tiny_cfg(mode="fp32"):
    return ModelConfig(
        name="spec-test", family="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=VOCAB, head_dim=16,
        quant=QuantConfig(mode=mode),
        peft=PEFTConfig(method="lora", lora_rank=4))


@pytest.fixture(scope="module")
def quaff_model():
    dcfg = DataConfig(vocab_size=VOCAB, seq_len=PROMPT, batch_size=4)
    model = api.prepare(_tiny_cfg())
    model.calibrate(calibration_batches(dcfg, 2))
    model.convert("quaff")
    return model


@pytest.fixture(scope="module")
def prompts():
    return np.asarray(Loader(DataConfig(vocab_size=VOCAB, seq_len=PROMPT,
                                        batch_size=4)).batch(0)["tokens"])


def _engine(model, **kw):
    kw.setdefault("max_slots", 4)
    kw.setdefault("max_seq_len", PROMPT + MAX_NEW)
    return Engine(model, EngineConfig(**kw))


def _run(model, prompts, cfg_kw, sampling=None, eos_id=None,
         max_new=MAX_NEW):
    eng = _engine(model, **cfg_kw)
    outs = eng.run([
        GenerationRequest(p, max_new_tokens=max_new, eos_id=eos_id,
                          sampling=sampling or SamplingParams())
        for p in prompts])
    return outs, eng


def _token_matrix(outs):
    width = max(len(o.token_ids) for o in outs)
    return np.asarray([list(o.token_ids) + [-1] * (width - len(o.token_ids))
                       for o in outs])


# ---------------------------------------------------------------------------
# multi-step scheduled decode
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("layout", sorted(LAYOUTS), ids=sorted(LAYOUTS))
def test_multistep_greedy_parity(quaff_model, prompts, layout):
    """decode_steps=4 must be token-identical to decode_steps=1 on the
    same kv layout — the in-graph EOS/budget masking is a pure reshaping
    of the dispatch schedule, never of the math."""
    base, _ = _run(quaff_model, prompts, LAYOUTS[layout])
    got, eng = _run(quaff_model, prompts,
                    {**LAYOUTS[layout], "decode_steps": 4})
    np.testing.assert_array_equal(_token_matrix(base), _token_matrix(got))
    d = eng.stats.as_dict()
    assert d["steps_per_dispatch"] > 1.0
    assert eng.stats.decode_dispatches < eng.stats.decode_steps


def test_multistep_mid_decode_admission(quaff_model, prompts):
    """Requests admitted while others sit mid-window decode the same
    streams as a fresh batch — scan windows never perturb live slots."""
    base, _ = _run(quaff_model, prompts, {})
    eng = _engine(quaff_model, max_slots=2, decode_steps=3)
    for i in range(2):
        eng.submit(GenerationRequest(prompts[i], max_new_tokens=MAX_NEW,
                                     request_id=f"r{i}"))
    eng.step()
    eng.step()                          # two requests now mid-generation
    for i in range(2, 4):
        eng.submit(GenerationRequest(prompts[i], max_new_tokens=MAX_NEW,
                                     request_id=f"r{i}"))
    outs = {o.request_id: o for o in eng.run()}
    got = np.asarray([outs[f"r{i}"].token_ids for i in range(4)])
    np.testing.assert_array_equal(_token_matrix(base), got)


def test_multistep_eos_mid_window(quaff_model, prompts):
    """A row hitting EOS inside a scheduled window must stop exactly where
    the one-step engine stops, and the window's remaining iterations must
    not leak tokens into its stream."""
    base, _ = _run(quaff_model, prompts, {})
    eos = int(_token_matrix(base)[0][2])   # forces a mid-window stop
    ref, _ = _run(quaff_model, prompts, {}, eos_id=eos)
    got, _ = _run(quaff_model, prompts, {"decode_steps": 4}, eos_id=eos)
    np.testing.assert_array_equal(_token_matrix(ref), _token_matrix(got))
    assert [o.finish_reason for o in ref] == [o.finish_reason for o in got]
    assert any(o.finish_reason == "eos" for o in got)
    assert any(len(o.token_ids) < MAX_NEW for o in got)


def test_multistep_seeded_sampling_parity(quaff_model, prompts):
    """Seeded sampling keys are derived per TOKEN INDEX, not per dispatch,
    so the scan window must reproduce the sequential draws exactly."""
    sp = SamplingParams(temperature=0.9, top_k=20, top_p=0.95, seed=13)
    base, _ = _run(quaff_model, prompts, {}, sampling=sp)
    got, _ = _run(quaff_model, prompts, {"decode_steps": 3}, sampling=sp)
    np.testing.assert_array_equal(_token_matrix(base), _token_matrix(got))


# ---------------------------------------------------------------------------
# self-speculative decoding
# ---------------------------------------------------------------------------
SPEC = {"spec_decode": True, "spec_backend": "quaff@8", "spec_k": 3}


@pytest.mark.parametrize("layout", sorted(LAYOUTS), ids=sorted(LAYOUTS))
def test_spec_greedy_identity(quaff_model, prompts, layout):
    """The acceptance criterion: greedy spec decode is token-identical to
    non-speculative decode — for fp AND int8 KV (the verify chunk reads
    the same quantized bytes sequential decode would have read)."""
    base, _ = _run(quaff_model, prompts, LAYOUTS[layout])
    got, eng = _run(quaff_model, prompts, {**LAYOUTS[layout], **SPEC})
    np.testing.assert_array_equal(_token_matrix(base), _token_matrix(got))
    d = eng.stats.as_dict()
    assert d["acceptance_rate"] > 0.0
    assert d["steps_per_dispatch"] > 0.5
    assert eng.stats.draft_tokens > 0
    assert eng.stats.accepted_tokens > 0


def test_spec_eos_and_budget_rollback(quaff_model, prompts):
    """EOS inside an accepted draft run and budgets not divisible by the
    cycle length both truncate exactly like sequential decode."""
    base, _ = _run(quaff_model, prompts, {}, max_new=7)
    eos = int(_token_matrix(base)[1][3])
    ref, _ = _run(quaff_model, prompts, {}, eos_id=eos, max_new=7)
    got, _ = _run(quaff_model, prompts, SPEC, eos_id=eos, max_new=7)
    np.testing.assert_array_equal(_token_matrix(ref), _token_matrix(got))
    assert [o.finish_reason for o in ref] == [o.finish_reason for o in got]


def test_spec_per_request_sampling_composes(quaff_model, prompts):
    """Greedy and seeded-sampled requests share one spec engine: greedy
    rows keep token identity; sampled rows run rejection sampling
    (distributionally correct, not bit-identical) and must still
    terminate with full budgets."""
    base, _ = _run(quaff_model, prompts, {})
    sps = [SamplingParams(),
           SamplingParams(temperature=0.8, top_k=16, seed=7),
           SamplingParams(),
           SamplingParams(temperature=1.1, top_p=0.9, seed=11)]
    eng = _engine(quaff_model, **SPEC)
    outs = eng.run([GenerationRequest(p, max_new_tokens=MAX_NEW, sampling=sp)
                    for p, sp in zip(prompts, sps)])
    got = _token_matrix(outs)
    for i in (0, 2):                      # greedy rows: exact identity
        np.testing.assert_array_equal(_token_matrix(base)[i], got[i])
    for o in outs:
        assert len(o.token_ids) == MAX_NEW
        assert all(0 <= t < VOCAB for t in o.token_ids)


def test_spec_stats_gating(quaff_model, prompts):
    """as_dict only grows the new sections when the features are on."""
    _, plain = _run(quaff_model, prompts, {})
    d = plain.stats.as_dict()
    assert "steps_per_dispatch" not in d and "acceptance_rate" not in d

    _, ms = _run(quaff_model, prompts, {"decode_steps": 2})
    d = ms.stats.as_dict()
    assert "steps_per_dispatch" in d and "acceptance_rate" not in d

    _, spec = _run(quaff_model, prompts, SPEC)
    d = spec.stats.as_dict()
    assert d["spec_backend"] == "quaff@8"
    assert d["spec_k"] == 3
    assert 0.0 < d["acceptance_rate"] <= 1.0


# ---------------------------------------------------------------------------
# config + backend-pairing validation
# ---------------------------------------------------------------------------
def test_config_validation():
    kw = dict(max_slots=2, max_seq_len=32)
    with pytest.raises(ValueError):
        EngineConfig(decode_steps=0, **kw)
    with pytest.raises(ValueError):
        EngineConfig(spec_decode=True, **kw)            # backend required
    with pytest.raises(ValueError):
        EngineConfig(spec_backend="quaff@8", **kw)      # spec_decode off
    with pytest.raises(ValueError):
        EngineConfig(spec_decode=True, spec_backend="quaff@8", spec_k=0,
                     **kw)
    with pytest.raises(ValueError):                     # mutually exclusive
        EngineConfig(spec_decode=True, spec_backend="quaff@8",
                     decode_steps=2, **kw)


def test_parse_spec_backend():
    assert parse_spec_backend("quaff") == ("quaff", None)
    assert parse_spec_backend("quaff@4") == ("quaff", 4)
    assert parse_spec_backend("int4") == ("int4", None)
    for bad in ("", "@4", "quaff@x", "quaff@0"):
        with pytest.raises(ValueError):
            parse_spec_backend(bad)


def test_draft_config_carrier_pairing():
    cfg = _tiny_cfg()
    quaff_cfg = dataclasses.replace(
        cfg, quant=dataclasses.replace(cfg.quant, mode="quaff"))
    draft = draft_model_config(quaff_cfg, "quaff@4")
    assert draft.quant.mode == "quaff" and draft.quant.bits == 4
    assert draft.d_model == quaff_cfg.d_model
    # int4 weights cannot be drafted by a backend reading fp/quaff trees
    int4_cfg = dataclasses.replace(
        cfg, quant=dataclasses.replace(cfg.quant, mode="int4_w4a8"))
    with pytest.raises(ValueError, match="carrier"):
        draft_model_config(int4_cfg, "quaff@8")


def test_spec_engine_rejects_mismatched_carrier(quaff_model):
    with pytest.raises(ValueError, match="carrier"):
        _engine(quaff_model, spec_decode=True, spec_backend="int4")


# ---------------------------------------------------------------------------
# prefix-cache invalidation on weight updates (satellite of this PR)
# ---------------------------------------------------------------------------
def test_weights_version_bump_rescopes_radix(quaff_model, prompts):
    """After a finetune/convert bumps ``model.weights_version``, the next
    engine step must drop every radix-cached block automatically — stale
    prefix KV from the old weights can never be mapped into new requests."""
    eng = _engine(quaff_model, kv_layout="paged", prefix_share=True,
                  block_size=4)
    eng.run([GenerationRequest(prompts[0], max_new_tokens=4)])  # warm it
    eng.run([GenerationRequest(prompts[0], max_new_tokens=4)
             for _ in range(2)])
    assert eng.stats.prefix_hits > 0          # the cache is warm and used
    old_scope = eng._paged.radix.scope
    warm_blocks = eng._paged.radix.n_blocks
    assert warm_blocks > 0

    version = quaff_model.weights_version
    try:
        quaff_model.weights_version = version + 1   # what finetune() does
        eng.run([GenerationRequest(prompts[0], max_new_tokens=4)])
        assert eng._paged.radix.scope != old_scope

        # same-version re-runs keep the scope (no spurious flushes)
        scope = eng._paged.radix.scope
        eng.run([GenerationRequest(prompts[0], max_new_tokens=4)])
        assert eng._paged.radix.scope == scope
    finally:
        quaff_model.weights_version = version       # module-scoped fixture
