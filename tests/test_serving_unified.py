"""Unified mixed-batch serving step (``EngineConfig(unified_step=True)``).

ONE ragged dispatch per engine iteration flattens admitted prefill tails
and live decode slots into a packed token stream (train.steps.
build_unified_step -> models.layers._ragged_mixed_step). These tests pin
the contract: greedy output token-identical to the legacy two-dispatch
path on every KV layout (contiguous / paged / paged-int8 / paged-prefix),
seeded sampling identical, composition with multi-step scheduled decode
and self-speculative decoding, the pad-packing telemetry actually firing
on mixed traffic, and the REPRO_RAGGED_PALLAS kernel route (ragged flash
attention + fused int4 QKV) producing the same stream end to end.
"""
import dataclasses

import numpy as np
import pytest

from repro import api
from repro.core.peft import PEFTConfig
from repro.data.pipeline import DataConfig, calibration_batches
from repro.models import layers as L
from repro.models.config import ModelConfig, QuantConfig
from repro.serving import Engine, GenerationRequest
from repro.serving.config import EngineConfig
from repro.serving.params import SamplingParams

VOCAB = 128
MAX_NEW = 6
SEQ = 48


def _tiny_cfg(**over):
    base = dict(
        name="unified-test", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=VOCAB, head_dim=16,
        quant=QuantConfig(mode="fp32"),
        peft=PEFTConfig(method="lora", lora_rank=4))
    base.update(over)
    return ModelConfig(**base)


def _prepare(mode="quaff", **over):
    model = api.prepare(_tiny_cfg(**over))
    model.calibrate(calibration_batches(
        DataConfig(vocab_size=VOCAB, seq_len=8, batch_size=4), 2))
    model.convert(mode)
    return model


@pytest.fixture(scope="module")
def quaff_model():
    return _prepare("quaff")


@pytest.fixture(scope="module")
def prompts():
    # staggered lengths, more requests than slots: admission happens
    # mid-decode, so unified dispatches genuinely mix both row kinds
    rng = np.random.default_rng(7)
    return [rng.integers(1, VOCAB, size=n).tolist() for n in (9, 5, 12, 7)]


LAYOUTS = {
    "contiguous": dict(),
    "paged": dict(kv_layout="paged", block_size=4, prefill_chunk=3),
    "paged-int8": dict(kv_layout="paged", kv_dtype="int8", block_size=4,
                       prefill_chunk=3),
    "paged-prefix": dict(kv_layout="paged", block_size=4, prefill_chunk=4,
                         prefix_share=True),
}


def _run(model, prompts, sampling=None, **knobs):
    eng = Engine(model, EngineConfig(max_slots=2, max_seq_len=SEQ, **knobs))
    # staggered budgets desync completions, so slots free (and refill with
    # fresh prefills) while their neighbours are still decoding
    outs = eng.run([
        GenerationRequest(p, max_new_tokens=MAX_NEW + i,
                          sampling=sampling or SamplingParams())
        for i, p in enumerate(prompts)])
    return [o.token_ids for o in outs], eng.stats


# ---------------------------------------------------------------------------
# greedy token identity vs the two-dispatch baseline, every layout
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("layout", sorted(LAYOUTS))
def test_unified_greedy_identity(quaff_model, prompts, layout):
    knobs = LAYOUTS[layout]
    work = prompts
    if layout == "paged-prefix":
        shared = list(range(1, 9))
        work = [shared + p for p in prompts]
    base, base_stats = _run(quaff_model, work, **knobs)
    got, stats = _run(quaff_model, work, unified_step=True, **knobs)
    assert got == base
    assert stats.unified_dispatches > 0
    assert stats.mixed_batches > 0
    # packing removed the legacy decode pads the baseline actually paid
    assert stats.pad_tokens_saved > 0
    assert base_stats.decode_pad_tokens > 0
    assert stats.requests_completed == len(work)


def test_unified_seeded_sampling_identity(quaff_model, prompts):
    sp = SamplingParams(temperature=0.8, top_k=16, seed=11)
    base, _ = _run(quaff_model, prompts, sampling=sp, kv_layout="paged",
                   block_size=4, prefill_chunk=3)
    got, _ = _run(quaff_model, prompts, sampling=sp, kv_layout="paged",
                  block_size=4, prefill_chunk=3, unified_step=True)
    assert got == base


# ---------------------------------------------------------------------------
# composition: multi-step windows and self-speculative decode keep their
# own compiled decode dispatch; the unified call carries the prefill rows
# (and spec verify chunks route through the same ragged kernel in-model)
# ---------------------------------------------------------------------------
def test_unified_composes_with_multistep(quaff_model, prompts):
    base, _ = _run(quaff_model, prompts, kv_layout="paged", block_size=4,
                   prefill_chunk=3)
    got, stats = _run(quaff_model, prompts, kv_layout="paged", block_size=4,
                      prefill_chunk=3, decode_steps=3, unified_step=True)
    assert got == base
    assert stats.unified_dispatches > 0


def test_unified_composes_with_spec_decode(quaff_model, prompts):
    base, _ = _run(quaff_model, prompts, kv_layout="paged", block_size=4,
                   prefill_chunk=3)
    got, stats = _run(quaff_model, prompts, kv_layout="paged", block_size=4,
                      prefill_chunk=3, spec_decode=True,
                      spec_backend="quaff@8", spec_k=2, unified_step=True)
    assert got == base
    assert stats.draft_tokens > 0


# ---------------------------------------------------------------------------
# REPRO_RAGGED_PALLAS route: the interpret-mode Pallas ragged kernel (and
# the fused int4 QKV GEMM) must reproduce the stream end to end
# ---------------------------------------------------------------------------
def test_unified_ragged_pallas_route(quaff_model, prompts, monkeypatch):
    base, _ = _run(quaff_model, prompts, kv_layout="paged", block_size=4,
                   prefill_chunk=3)
    monkeypatch.setattr(L, "_RAGGED_PALLAS", True)
    got, _ = _run(quaff_model, prompts, kv_layout="paged", block_size=4,
                  prefill_chunk=3, unified_step=True)
    assert got == base


def test_unified_fused_int4_qkv_route(prompts, monkeypatch):
    model = _prepare("int4_w4a8")
    base, _ = _run(model, prompts, kv_layout="paged", block_size=4,
                   prefill_chunk=3)
    monkeypatch.setattr(L, "_RAGGED_PALLAS", True)
    got, _ = _run(model, prompts, kv_layout="paged", block_size=4,
                  prefill_chunk=3, unified_step=True)
    assert got == base


# ---------------------------------------------------------------------------
# validation and telemetry plumbing
# ---------------------------------------------------------------------------
def test_unified_rejects_non_kv_and_sliding_window(quaff_model):
    sw_model = api.prepare(_tiny_cfg(n_layers=4, sliding_window=4,
                                     global_every=2))
    with pytest.raises(ValueError, match="sliding_window"):
        Engine(sw_model, EngineConfig(max_slots=2, max_seq_len=SEQ,
                                      unified_step=True))
    from repro.configs import reduced_family_demo
    ssm_model = api.prepare(dataclasses.replace(
        reduced_family_demo("ssm"), quant=QuantConfig(mode="fp32")))
    with pytest.raises(ValueError, match="unified_step"):
        Engine(ssm_model, EngineConfig(max_slots=2, max_seq_len=SEQ,
                                       unified_step=True))


def test_unified_contiguous_chunking_knob():
    # prefill_chunk on the contiguous layout is only meaningful under the
    # unified step (legacy contiguous admission prefills whole prompts)
    with pytest.raises(ValueError, match="prefill_chunk"):
        EngineConfig(prefill_chunk=4)
    cfg = EngineConfig(prefill_chunk=4, unified_step=True)
    assert cfg.prefill_chunk == 4


def test_unified_stats_sections(quaff_model, prompts):
    _, stats = _run(quaff_model, prompts, kv_layout="paged", block_size=4,
                    prefill_chunk=3, unified_step=True)
    d = stats.as_dict()
    assert d["unified_step"] is True
    assert d["unified_dispatches"] == stats.unified_dispatches
    assert d["pad_tokens_saved"] == stats.pad_tokens_saved
    assert d["mixed_batches"] == stats.mixed_batches
    assert stats.unified_time_s > 0
    assert stats.tokens_per_s > 0
    # legacy runs expose the geometry padding the unified step removes
    _, legacy = _run(quaff_model, prompts, kv_layout="paged", block_size=4,
                     prefill_chunk=3)
    ld = legacy.as_dict()
    assert ld["decode_pad_tokens"] > 0
    assert ld["prefill_pad_tokens"] == 0     # same-length grouping is exact
    assert "unified_dispatches" not in ld
