"""Ragged kernels (kernels/ragged_attention.py, kernels/ragged_matmul.py):
interpret-mode parity against the jnp oracles on random ragged geometries —
mixed prefill/decode rows, len-1 decode rows, dead rows (empty tails), fp
and int8 pools — plus cross-checks against flash_attention and the dense
int4 GEMM."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.kernels.flash_attention import gqa_flash_attention
from repro.kernels.int4_matmul import int4_matmul_fused
from repro.kernels.ragged_attention import (
    ragged_attention,
    ragged_attention_ref,
)
from repro.kernels.ragged_matmul import (
    ragged_int4_matmul,
    ragged_int4_matmul_ref,
    ragged_qkv_matmul,
)

KEY = jax.random.PRNGKey(7)
KH, G, HD = 2, 2, 8
PAGE = 8


def _ragged_case(key, rows, pages, int8=False):
    """rows: [(row_len, cursor), ...] -> full kernel input set. Every row
    owns ``pages`` distinct pool pages; pool contents are random (positions
    past each cursor are garbage the masking must ignore)."""
    n_rows = len(rows)
    row_len = jnp.asarray([r for r, _ in rows], jnp.int32)
    cursor = jnp.asarray([c for _, c in rows], jnp.int32)
    row_start = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(row_len)[:-1]])
    total = int(row_len.sum())
    n_pool = 1 + n_rows * pages                   # page 0 = trash
    bt = 1 + np.arange(n_rows * pages, dtype=np.int32).reshape(n_rows, pages)

    ks = jax.random.split(key, 6)
    q = jax.random.normal(ks[0], (max(total, 1), KH, G, HD), jnp.float32)
    k_self = jax.random.normal(ks[1], (max(total, 1), KH, HD), jnp.float32)
    v_self = jax.random.normal(ks[2], (max(total, 1), KH, HD), jnp.float32)
    kp = jax.random.normal(ks[3], (n_pool, PAGE, KH, HD), jnp.float32)
    vp = jax.random.normal(ks[4], (n_pool, PAGE, KH, HD), jnp.float32)
    k_scale = v_scale = None
    if int8:
        k_scale = jnp.abs(kp).max(axis=(0, 1)) / 127.0 + 1e-6    # (KH, HD)
        kp = jnp.clip(jnp.round(kp / k_scale), -127, 127).astype(jnp.int8)
        v_scale = jnp.abs(vp).max(axis=-1) / 127.0 + 1e-6
        vp = jnp.clip(jnp.round(vp / v_scale[..., None]),
                      -127, 127).astype(jnp.int8)
    return (q, k_self, v_self, kp, vp, jnp.asarray(bt),
            row_start, row_len, cursor, k_scale, v_scale)


GEOMETRIES = [
    # mixed prefill chunks + decode rows
    [(4, 0), (1, 9), (6, 3), (1, 17)],
    # all decode (what the old paged kernel served), incl. cursor=0 row
    [(1, 0), (1, 5), (1, 31), (1, 1)],
    # dead rows (empty tails) interleaved with live ones
    [(0, 0), (5, 2), (0, 0), (1, 7), (0, 4)],
    # lone full prefill row
    [(8, 0)],
]


@pytest.mark.parametrize("rows", GEOMETRIES)
@pytest.mark.parametrize("int8", [False, True])
def test_ragged_attention_matches_ref(rows, int8):
    args = _ragged_case(KEY, rows, pages=4, int8=int8)
    bq = max(max(r for r, _ in rows), 1)
    got = ragged_attention(*args, max_row_len=bq, interpret=True)
    want = ragged_attention_ref(*args, max_row_len=bq)
    row_start, row_len = args[6], args[7]
    for r, (rl, _) in enumerate(rows):
        np.testing.assert_allclose(got[r, :rl], want[r, :rl],
                                   rtol=2e-4, atol=2e-5,
                                   err_msg=f"row {r} of {rows}")


def test_ragged_attention_matches_flash_on_fresh_row():
    # a cursor=0 prefill row is plain causal attention over its own span:
    # the ragged kernel must agree with the flash-attention kernel
    s = 16
    args = _ragged_case(KEY, [(s, 0)], pages=2)
    q, k_self, v_self = args[0], args[1], args[2]
    got = ragged_attention(*args, max_row_len=s, interpret=True)
    want = gqa_flash_attention(q[None], k_self[None], v_self[None],
                               causal=True, interpret=True)
    np.testing.assert_allclose(got[0], want[0], rtol=2e-4, atol=2e-5)


def test_ragged_attention_decode_row_reads_pool_prefix():
    # a len-1 decode row with a live prefix must differ from the same row
    # with the prefix masked off (cursor=0) — the pool pages are being read
    args = list(_ragged_case(KEY, [(1, 12)], pages=4))
    with_ctx = ragged_attention(*args, max_row_len=1, interpret=True)
    args[8] = jnp.zeros_like(args[8])             # cursor -> 0
    without = ragged_attention(*args, max_row_len=1, interpret=True)
    assert not np.allclose(np.asarray(with_ctx[0, 0]),
                           np.asarray(without[0, 0]))


def _int4_case(key, t, k, n, group_size):
    ks = jax.random.split(key, 2)
    w = jax.random.normal(ks[0], (k, n), jnp.float32)
    w_int, w_delta = quant.quantize_grouped(w, group_size, bits=4)
    x = jax.random.normal(ks[1], (t, k), jnp.float32)
    x_int, x_delta = quant.quantize(x, axis=-1, bits=8)
    return x_int, quant.pack_int4(w_int), x_delta, w_delta


def test_ragged_int4_matmul_matches_ref_and_skips_pad_blocks():
    t, n_tok = 32, 20
    x_int, wp, xd, wd = _int4_case(KEY, t, 32, 48, group_size=16)
    got = ragged_int4_matmul(x_int, wp, xd, wd, jnp.int32(n_tok),
                             block_t=8, interpret=True)
    want = ragged_int4_matmul_ref(x_int, wp, xd, wd)
    np.testing.assert_allclose(got[:n_tok], want[:n_tok],
                               rtol=1e-5, atol=1e-6)
    # token blocks entirely past n_tok never ran: exact zeros
    np.testing.assert_array_equal(np.asarray(got[24:]), 0.0)


def test_ragged_int4_matmul_full_stream_matches_dense_kernel():
    x_int, wp, xd, wd = _int4_case(KEY, 16, 32, 32, group_size=0)
    ragged = ragged_int4_matmul(x_int, wp, xd, wd, jnp.int32(16),
                                interpret=True)
    dense = int4_matmul_fused(x_int, wp, xd, wd, interpret=True)
    np.testing.assert_allclose(ragged, dense, rtol=1e-6, atol=1e-7)


def test_ragged_qkv_matmul_matches_per_projection_dense():
    d, qd, kvd = 32, 32, 16
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (24, d), jnp.float32)
    x_int, x_delta = quant.quantize(x, axis=-1, bits=8)
    packed, deltas = [], []
    for key, c_out in zip(ks[1:], (qd, kvd, kvd)):
        w = jax.random.normal(key, (d, c_out), jnp.float32)
        w_int, w_delta = quant.quantize_grouped(w, 16, bits=4)
        packed.append(quant.pack_int4(w_int))
        deltas.append(w_delta)
    q, k, v = ragged_qkv_matmul(x_int, x_delta, packed, deltas,
                                jnp.int32(24), interpret=True)
    for got, wp, wd in zip((q, k, v), packed, deltas):
        want = ragged_int4_matmul_ref(x_int, wp, x_delta, wd)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
