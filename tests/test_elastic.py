"""Elastic re-scaling: a checkpoint saved from one device layout restores
onto a different mesh (the shard-agnostic save format contract), verified in
a subprocess with 8 forced host devices."""
import subprocess
import sys

import conftest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import warnings; warnings.filterwarnings("ignore")
import dataclasses, tempfile
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint.manager import CheckpointManager
from repro.core.peft import PEFTConfig
from repro.models import model as M
from repro.models.config import ModelConfig, QuantConfig, TrainConfig
from repro.train import steps as S
from repro.launch.mesh import make_test_mesh

cfg = ModelConfig(name="el", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                  head_dim=16, quant=QuantConfig(mode="quaff"),
                  peft=PEFTConfig(method="lora", lora_rank=4))
tcfg = TrainConfig()
frozen, adapters, qstate = M.init_params(jax.random.PRNGKey(0), cfg)
state = S.init_train_state(adapters, qstate, tcfg)

tmp = tempfile.mkdtemp()
mgr = CheckpointManager(tmp, async_save=False)

# "train" on a 4x2 mesh: place state sharded, save
mesh_a = make_test_mesh((4, 2), ("data", "model"))
with jax.set_mesh(mesh_a):
    state_a = jax.device_put(state, jax.tree.map(
        lambda l: NamedSharding(mesh_a, P()), state))
mgr.save(7, state_a)

# "resume" on a DIFFERENT mesh shape (2x4) — elastic re-scale
mesh_b = make_test_mesh((2, 4), ("data", "model"))
restored, meta = mgr.restore(state)
with jax.set_mesh(mesh_b):
    state_b = jax.device_put(restored, jax.tree.map(
        lambda l: NamedSharding(mesh_b, P()), restored))
assert meta["step"] == 7
for a, b in zip(jax.tree.leaves(state_a), jax.tree.leaves(state_b)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("OK")
"""


@conftest.requires_modern_jax
def test_elastic_restore_different_mesh():
    r = subprocess.run([sys.executable, "-c", _SCRIPT], capture_output=True,
                       text=True, timeout=600, env={"PYTHONPATH": "src"})
    assert r.returncode == 0, (r.stderr or r.stdout)[-3000:]
    assert "OK" in r.stdout
