"""Continuous-batching engine: token parity with the lockstep reference,
mid-stream admission, mixed-length scheduling wins, seeded sampling, and the
facade ``generate`` wrapper (EOS/pad semantics)."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core.peft import PEFTConfig
from repro.data.pipeline import DataConfig, Loader, calibration_batches
from repro.models.config import ModelConfig, QuantConfig
from repro.serving import Engine, GenerationRequest, SamplingParams

VOCAB, PROMPT = 128, 8


def _tiny_cfg(mode="fp32"):
    return ModelConfig(
        name="serve-test", family="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=VOCAB, head_dim=16,
        quant=QuantConfig(mode=mode),
        peft=PEFTConfig(method="lora", lora_rank=4))


@pytest.fixture(scope="module")
def quaff_model():
    dcfg = DataConfig(vocab_size=VOCAB, seq_len=PROMPT, batch_size=4)
    model = api.prepare(_tiny_cfg())
    model.calibrate(calibration_batches(dcfg, 2))
    model.convert("quaff")
    return model


@pytest.fixture(scope="module")
def prompts():
    return np.asarray(Loader(DataConfig(vocab_size=VOCAB, seq_len=PROMPT,
                                        batch_size=4)).batch(0)["tokens"])


def _lockstep_reference(model, prompts, max_new):
    """The pre-engine greedy loop, straight on the step builders."""
    tokens = jnp.asarray(prompts)
    prompt_len = tokens.shape[1]
    logits, caches = model.prefill({"tokens": tokens}, extra_len=max_new)
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    for i in range(max_new - 1):
        logits, caches = model.decode_step(caches, tok, prompt_len + i)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    return np.asarray(jnp.concatenate(out, axis=1))


# ---------------------------------------------------------------------------
# greedy parity
# ---------------------------------------------------------------------------
def test_engine_greedy_token_parity(quaff_model, prompts):
    """Engine greedy decode on a shared prompt batch must be token-identical
    to the lockstep loop (the acceptance criterion)."""
    max_new = 8
    ref = _lockstep_reference(quaff_model, prompts, max_new)
    eng = Engine(quaff_model, max_slots=len(prompts),
                 max_seq_len=PROMPT + max_new)
    outs = eng.run([GenerationRequest(p, max_new_tokens=max_new)
                    for p in prompts])
    got = np.asarray([o.token_ids for o in outs])
    np.testing.assert_array_equal(ref, got)
    assert all(o.finish_reason == "length" for o in outs)
    assert eng.stats.requests_completed == len(prompts)
    assert eng.stats.tokens_generated == len(prompts) * max_new


def test_generate_is_engine_backed(quaff_model, prompts):
    """facade generate == lockstep reference (thin wrapper contract)."""
    ref = _lockstep_reference(quaff_model, prompts, 6)
    got = np.asarray(quaff_model.generate(prompts, max_new=6))
    np.testing.assert_array_equal(ref, got)


def test_mixed_prompt_lengths_parity(quaff_model, prompts):
    """Each request's stream must equal ITS OWN single-request lockstep
    decode, no matter what shares the pool (mixed prompt lengths)."""
    max_new = 6
    lens = [PROMPT, PROMPT - 2, PROMPT - 3, PROMPT - 1]
    eng = Engine(quaff_model, max_slots=2, max_seq_len=PROMPT + max_new)
    outs = eng.run([GenerationRequest(prompts[i][:n], max_new_tokens=max_new)
                    for i, n in enumerate(lens)])
    for i, (n, out) in enumerate(zip(lens, outs)):
        solo = _lockstep_reference(quaff_model, prompts[i:i + 1, :n], max_new)
        np.testing.assert_array_equal(
            solo[0], np.asarray(out.token_ids),
            err_msg=f"request {i} (prompt len {n}) diverged in shared pool")


# ---------------------------------------------------------------------------
# scheduling
# ---------------------------------------------------------------------------
def test_mid_stream_admission(quaff_model, prompts):
    """Requests submitted while others are mid-decode produce the same
    tokens as a fresh batch run — admission never perturbs live slots."""
    max_new = 6
    ref = _lockstep_reference(quaff_model, prompts, max_new)
    eng = Engine(quaff_model, max_slots=2, max_seq_len=PROMPT + max_new)
    for i in range(2):
        eng.submit(GenerationRequest(prompts[i], max_new_tokens=max_new,
                                     request_id=f"r{i}"))
    eng.step()
    eng.step()                      # two requests now mid-generation
    for i in range(2, 4):
        eng.submit(GenerationRequest(prompts[i], max_new_tokens=max_new,
                                     request_id=f"r{i}"))
    outs = {o.request_id: o for o in eng.run()}
    got = np.asarray([outs[f"r{i}"].token_ids for i in range(4)])
    np.testing.assert_array_equal(ref, got)


def test_mixed_budgets_beat_lockstep_slot_steps(quaff_model, prompts):
    """A mixed-budget workload must finish in strictly fewer slot-steps than
    the lockstep equivalent (batch waits for its slowest request)."""
    short, long = 4, 16
    n_req, slots = 6, 2
    eng = Engine(quaff_model, max_slots=slots, max_seq_len=PROMPT + long)
    outs = eng.run([GenerationRequest(prompts[i % 4],
                                      max_new_tokens=short if i % 2 else long)
                    for i in range(n_req)])
    assert [o.n_generated for o in outs] == [long, short] * 3
    lockstep_slot_steps = n_req * long
    assert eng.stats.slot_steps < lockstep_slot_steps
    assert eng.stats.busy_slot_steps <= eng.stats.slot_steps
    assert 0.0 < eng.stats.occupancy <= 1.0
    assert eng.stats.decode_tokens_per_s > 0


def test_streaming_callback(quaff_model, prompts):
    events = []
    eng = Engine(quaff_model, max_slots=1, max_seq_len=PROMPT + 4)
    out = eng.run([GenerationRequest(
        prompts[0], max_new_tokens=4, request_id="s0",
        on_token=lambda rid, tok: events.append((rid, tok)))])[0]
    assert events == [("s0", t) for t in out.token_ids]


def test_capacity_validation(quaff_model, prompts):
    eng = Engine(quaff_model, max_slots=1, max_seq_len=10)
    with pytest.raises(ValueError, match="cache positions"):
        eng.submit(GenerationRequest(prompts[0], max_new_tokens=32))
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit(GenerationRequest(prompts[0], max_new_tokens=0))


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------
def test_seeded_sampling_determinism(quaff_model, prompts):
    """Same seed -> identical stream, independent of pool size / admission
    order; different seed -> allowed (and here, expected) to differ."""
    sp = SamplingParams(temperature=0.9, top_k=20, top_p=0.9, seed=11)

    def run_one(slots, extra_load):
        eng = Engine(quaff_model, max_slots=slots, max_seq_len=PROMPT + 16)
        reqs = [GenerationRequest(prompts[0], max_new_tokens=8, sampling=sp,
                                  request_id="probe")]
        if extra_load:
            reqs += [GenerationRequest(prompts[i], max_new_tokens=12)
                     for i in (1, 2)]
        outs = {o.request_id: o for o in eng.run(reqs)}
        return outs["probe"].token_ids

    a = run_one(slots=1, extra_load=False)
    b = run_one(slots=3, extra_load=True)
    assert a == b
    assert all(0 <= t < VOCAB for t in a)

    c_eng = Engine(quaff_model, max_slots=1, max_seq_len=PROMPT + 16)
    c = c_eng.run([GenerationRequest(
        prompts[0], max_new_tokens=8,
        sampling=dataclasses.replace(sp, seed=12))])[0].token_ids
    assert c != a


def test_greedy_param_matches_zero_temperature(quaff_model, prompts):
    ref = _lockstep_reference(quaff_model, prompts[:1], 5)
    eng = Engine(quaff_model, max_slots=1, max_seq_len=PROMPT + 5)
    out = eng.run([GenerationRequest(
        prompts[0], max_new_tokens=5,
        sampling=SamplingParams(temperature=0.0, top_k=3, top_p=0.5))])[0]
    np.testing.assert_array_equal(ref[0], np.asarray(out.token_ids))


def test_sampling_params_validation():
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        SamplingParams(top_k=-1)


# ---------------------------------------------------------------------------
# facade generate: EOS / pad satellite
# ---------------------------------------------------------------------------
def test_generate_eos_stops_and_pads(quaff_model, prompts):
    max_new, pad = 8, 0
    ref = np.asarray(quaff_model.generate(prompts, max_new=max_new))
    eos = int(ref[0, 2])            # force row 0 to stop at its 3rd token
    got = np.asarray(quaff_model.generate(prompts, max_new=max_new,
                                          eos_id=eos, pad_id=pad))
    assert got.shape == ref.shape
    for r in range(len(prompts)):
        row, ref_row = got[r].tolist(), ref[r].tolist()
        if eos in ref_row:
            stop = ref_row.index(eos)
            assert row[:stop + 1] == ref_row[:stop + 1]
            assert row[stop + 1:] == [pad] * (max_new - stop - 1)
        else:
            assert row == ref_row


def test_generate_exact_budget_without_eos(quaff_model, prompts):
    """eos_id=None keeps the exact-budget contract (no early stop)."""
    out = np.asarray(quaff_model.generate(prompts, max_new=5))
    assert out.shape == (len(prompts), 5)
    assert np.asarray(quaff_model.generate(prompts, max_new=0)).shape == \
        (len(prompts), 0)


def test_engine_knob_family_validation(quaff_model):
    """Every family builds an Engine now (see test_serving_families), but
    the state knobs stay family-checked: paged KV is for KV-cache
    families, int8 state for recurrent ones."""
    import repro.configs as CFGS
    cfg = dataclasses.replace(
        CFGS.get_config("xlstm-350m").reduced(),
        quant=QuantConfig(mode="fp32"), peft=PEFTConfig(method="none"))
    model = api.prepare(cfg)
    eng = Engine(model, max_slots=1, max_seq_len=16)   # accepted (ssm)
    assert eng.stats.family == "ssm"
    with pytest.raises(ValueError, match="paged"):
        Engine(model, max_slots=1, max_seq_len=16, kv_layout="paged")
    with pytest.raises(ValueError, match="state_dtype"):
        Engine(quaff_model, max_slots=1, max_seq_len=16, state_dtype="int8")
    with pytest.raises(ValueError, match="lazy_blocks"):
        Engine(quaff_model, max_slots=1, max_seq_len=16, lazy_blocks=True)
